"""Model aggregation — paper Eq. 1 (|D_n|-weighted global objective) and
Eq. 2 (FedAvg of full models), plus the hierarchical edge→cloud tier used by
the multi-RSU scenario layer (DESIGN.md §7): per-RSU FedAvg at the edge,
then a sample-weighted merge across RSUs at the cloud.  The two-tier form is
numerically the flat weighted FedAvg whenever the cloud weights are the
per-edge sample sums — asserted in tests/test_scenario.py."""
from __future__ import annotations

from typing import Any, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np


def fedavg(trees: Sequence[Any], weights: Optional[Sequence[float]] = None) -> Any:
    """Weighted average of pytrees.  Uniform weights give paper Eq. 2;
    |D_n|-proportional weights realise the Eq. 1 objective."""
    n = len(trees)
    assert n > 0
    if weights is None:
        w = np.full((n,), 1.0 / n)
    else:
        w = np.asarray(weights, dtype=np.float64)
        w = w / w.sum()

    def avg(*leaves):
        acc = sum(float(w[i]) * leaves[i].astype(jnp.float32) for i in range(n))
        return acc.astype(leaves[0].dtype)

    return jax.tree.map(avg, *trees)


def fedavg_delta(global_tree: Any, client_trees: Sequence[Any],
                 weights: Optional[Sequence[float]] = None,
                 server_lr: float = 1.0) -> Any:
    """Eq. 2 in delta form: w_{t+1} = w_t - eta_s * sum_n p_n (w_t - w_n).
    With server_lr=1 and uniform p_n this equals fedavg(client_trees)."""
    avg_clients = fedavg(client_trees, weights)

    def upd(g, a):
        return (g.astype(jnp.float32)
                - server_lr * (g.astype(jnp.float32) - a.astype(jnp.float32))
                ).astype(g.dtype)

    return jax.tree.map(upd, global_tree, avg_clients)


def stacked_weighted_sum(stacked_tree: Any, weights: jnp.ndarray) -> Any:
    """On-device FedAvg numerator over a stacked replica axis: every leaf of
    ``stacked_tree`` carries the replicas on its leading axis and is reduced
    with one tensordot — no Python list of per-replica trees, so it is jit-
    traceable inside the cohort engine's round program.  A zero weight
    excludes a replica (padding slots, out-of-coverage vehicles)."""
    w = jnp.asarray(weights, jnp.float32)

    def f(a):
        return jnp.tensordot(w, a.astype(jnp.float32), axes=(0, 0))

    return jax.tree.map(f, stacked_tree)


def stacked_fedavg(stacked_tree: Any, weights: jnp.ndarray) -> Any:
    """Weighted average over the stacked leading axis (Eq. 1/2 realised as
    one on-device reduction).  Weights need not be normalised."""
    w = jnp.asarray(weights, jnp.float32)
    num = stacked_weighted_sum(stacked_tree, w)
    den = jnp.sum(w)
    return jax.tree.map(
        lambda n, ref: (n / den).astype(ref.dtype), num, stacked_tree)


def survivor_weighted_sum(stacked_tree: Any, weights: jnp.ndarray,
                          survivors: jnp.ndarray) -> Any:
    """Partial-aggregation numerator (DESIGN.md §13): a failed replica folds
    in as an exact ``+0`` — its weight is zeroed by the bool ``survivors``
    mask before the same tensordot :func:`stacked_weighted_sum` uses, so the
    reduction order (and therefore the floats) is identical to the
    full-participation sum whenever the mask is all-True.  The caller
    renormalises by the surviving weight, not the cohort weight."""
    w = jnp.asarray(weights, jnp.float32) * jnp.asarray(survivors, jnp.float32)
    return stacked_weighted_sum(stacked_tree, w)


def survivor_fedavg(stacked_tree: Any, weights: jnp.ndarray,
                    survivors: jnp.ndarray, fallback: Any) -> Any:
    """Survivor-weighted FedAvg: Eq. 1/2 restricted to the surviving
    replicas, with the weight renormalised over survivors so the effective
    weights still sum to 1.  When no replica survives the ``fallback`` tree
    (the pre-round model) is returned unchanged — the at-least-one-
    participant guarantee upstream makes this a rare degenerate case, but
    the merge must stay well-defined under arbitrary fault schedules."""
    w = jnp.asarray(weights, jnp.float32) * jnp.asarray(survivors, jnp.float32)
    total = jnp.sum(w)
    # NOT maximum(total, 1): surviving weight in (0, 1) must still
    # renormalize exactly (fractional weights under staleness discounts)
    den = jnp.where(total > 0.0, total, 1.0)
    num = stacked_weighted_sum(stacked_tree, w)

    def f(n, fb):
        return jnp.where(total > 0.0, (n / den).astype(fb.dtype), fb)

    return jax.tree.map(f, num, fallback)


def discounted_survivor_fedavg(stacked_tree: Any, weights: jnp.ndarray,
                               survivors: jnp.ndarray,
                               discounts: jnp.ndarray, fallback: Any) -> Any:
    """Staleness-weighted survivor FedAvg (DESIGN.md §14): each replica's
    sample weight is additionally scaled by a per-replica ``discount``
    (typically ``streaming.staleness_kernel`` of its buffered age) before
    the survivor-masked renormalised mean.  With all discounts exactly 1.0
    this is *bitwise* :func:`survivor_fedavg` — ``w * 1.0`` is an IEEE
    identity, so the tensordot reduces the identical floats
    (tests/test_properties.py pins this)."""
    w = (jnp.asarray(weights, jnp.float32)
         * jnp.asarray(survivors, jnp.float32)
         * jnp.asarray(discounts, jnp.float32))
    total = jnp.sum(w)
    den = jnp.where(total > 0.0, total, 1.0)
    num = stacked_weighted_sum(stacked_tree, w)

    def f(n, fb):
        return jnp.where(total > 0.0, (n / den).astype(fb.dtype), fb)

    return jax.tree.map(f, num, fallback)


def unitwise_fedavg(unit_replicas: List[List[Any]],
                    weights_per_unit: List[List[float]]) -> List[Any]:
    """ASFL heterogeneous-cut aggregation: each stack unit is averaged over
    every replica that trained it this round (vehicle-side copies for units
    before each client's cut, RSU-side copies after)."""
    out = []
    for reps, ws in zip(unit_replicas, weights_per_unit):
        out.append(fedavg(reps, ws))
    return out


def stacked_cloud_merge(edge_stack: Any, edge_weights: jnp.ndarray,
                        fallback: Any) -> Any:
    """Traced cloud tier over an RSU-stacked edge tree: every leaf of
    ``edge_stack`` carries the per-RSU edge models on its leading axis and is
    reduced with one weighted mean (:func:`cloud_aggregate` without the
    Python list of trees, so it runs inside the fused super-step scan).
    Zero-weight RSUs are excluded, matching the host path's ``served``
    filter; when every weight is zero the ``fallback`` tree (the previous
    global model) is returned unchanged."""
    w = jnp.asarray(edge_weights, jnp.float32)
    total = jnp.sum(w)
    den = jnp.maximum(total, 1.0)

    def f(stacked, fb):
        num = jnp.tensordot(w, stacked.astype(jnp.float32), axes=(0, 0))
        return jnp.where(total > 0.0, (num / den).astype(stacked.dtype), fb)

    return jax.tree.map(f, edge_stack, fallback)


def sharded_weighted_sum(stacked_tree: Any, weights: jnp.ndarray,
                         axis_name: "str | tuple") -> Any:
    """:func:`stacked_weighted_sum` across a device-sharded replica axis:
    each shard reduces its local slots, then one ``psum`` over ``axis_name``
    completes the FedAvg numerator — the weighted all-reduce form of Eq. 1/2
    used by the sharded cohort engine (zero-weight padding slots stay
    excluded shard-locally).  ``axis_name`` is one mesh axis name or a
    tuple of them: the 2-D ``(rsu, vehicle)`` mesh (DESIGN.md §15) reduces
    slot partials over ``fleet_sharding.ALL_AXES`` in one psum."""
    part = stacked_weighted_sum(stacked_tree, weights)
    return jax.tree.map(lambda a: jax.lax.psum(a, axis_name), part)


def sharded_fedavg(stacked_tree: Any, weights: jnp.ndarray,
                   axis_name: "str | tuple") -> Any:
    """:func:`stacked_fedavg` across a device-sharded replica axis (psum'd
    numerator and denominator, single axis name or tuple as above)."""
    w = jnp.asarray(weights, jnp.float32)
    num = sharded_weighted_sum(stacked_tree, w, axis_name)
    den = jax.lax.psum(jnp.sum(w), axis_name)
    return jax.tree.map(
        lambda n, ref: (n / den).astype(ref.dtype), num, stacked_tree)


def gathered_stack(local_stack: Any, axis_name: str) -> Any:
    """All-gather a device-sharded leading axis back into the full stack,
    in mesh order.  This is the *order-preserving* form of a weighted
    all-reduce: gather first, then reduce every shard's copy with the exact
    reduction the single-device program uses — which keeps the sharded
    edge→cloud merge bit-for-bit equal to the unsharded one (a plain
    ``psum`` of per-shard partial sums would reassociate the floating-point
    additions).  The gathered bytes are the natural cost of a cloud merge:
    it is a model exchange."""
    return jax.tree.map(
        lambda a: jax.lax.all_gather(a, axis_name, tiled=True), local_stack)


def edge_aggregate(trees: Sequence[Any], weights: Sequence[float],
                   groups: Sequence[int]):
    """Edge tier of hierarchical FedAvg: one |D_n|-weighted FedAvg per RSU.
    ``groups[i]`` is the serving-RSU index of client ``i``.  Returns
    (group_ids, edge_trees, edge_weights) where ``edge_weights`` are the
    per-RSU sample sums — exactly the cloud weights that make the cloud
    merge equal flat FedAvg."""
    groups = np.asarray(groups)
    w = np.asarray(weights, dtype=np.float64)
    gids = sorted(set(int(g) for g in groups))
    edge_trees, edge_w = [], []
    for g in gids:
        sel = [i for i in range(len(trees)) if groups[i] == g]
        edge_trees.append(fedavg([trees[i] for i in sel], w[sel]))
        edge_w.append(float(w[sel].sum()))
    return gids, edge_trees, edge_w


def cloud_aggregate(edge_trees: Sequence[Any],
                    edge_weights: Sequence[float]) -> Any:
    """Cloud tier: sample-weighted merge of per-RSU edge models (Eq. 2 one
    level up — the edge models are themselves weighted means)."""
    return fedavg(edge_trees, edge_weights)


def hierarchical_fedavg(trees: Sequence[Any], weights: Sequence[float],
                        groups: Sequence[int]) -> Any:
    """Two-tier FedAvg: per-RSU edge aggregation, then cloud merge.  Because
    both tiers are weighted means, sum_g (W_g/W) * (sum_{i in g} w_i/W_g *
    x_i) = sum_i w_i/W * x_i — equal to ``fedavg(trees, weights)`` up to fp
    reassociation for ANY grouping (tests/test_scenario.py)."""
    _, edge_trees, edge_w = edge_aggregate(trees, weights, groups)
    return cloud_aggregate(edge_trees, edge_w)


def tree_sub(a: Any, b: Any) -> Any:
    return jax.tree.map(lambda x, y: x - y, a, b)


def tree_l2(a: Any) -> float:
    return float(jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                              for l in jax.tree.leaves(a))))
