"""Pluggable VEI mobility scenarios: multi-RSU fleet state per round.

The seed repo hardcoded ONE RSU on one straight road (the drive-by trace in
``core/channel.py``).  This module generalizes mobility into a
:class:`Scenario` protocol that produces **vectorized per-round fleet state**
— positions, velocities, serving RSU, uplink rates, and remaining residence
time — for multiple RSUs, so the federation layer can model the paper's
defining challenge: vehicles entering and leaving coverage mid-training
(§II-C), handover between cells, and residence-time-aware scheduling
(ASFL, arXiv:2405.18707).

Layering: ``channel.py`` is the radio (Shannon rates from distance);
this module is the kinematics + cell association on top of it.  Everything
is a numpy vector op over the fleet — a 256-vehicle state query is a handful
of array expressions, never a Python loop per vehicle.

Concrete scenarios:

* :func:`highway_corridor` — N RSUs strung along a multi-lane road; vehicles
  wrap around the corridor (wrap = one departure + one fresh arrival, so
  fleet membership is dynamic while arrays stay fixed-shape).
* :func:`urban_grid` — Manhattan-style grid with pseudo-random turns at
  intersections and an intersection dwell time; RSUs at every k-th
  intersection.
* :func:`trace_replay` — deterministic, array-driven trajectories (the test
  scenario: handover instants are exactly known).

Handover moves a vehicle's RSU association only; everything keyed by
vehicle — data shards, schedule membership, and the wire error-feedback
residual plane (``wire_res`` in the super-step carry, DESIGN.md §11) —
is fleet-indexed and therefore migrates with the vehicle for free.  A
residual is invalidated by a *cut change* (its tensor changes meaning),
never by a handover alone.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Protocol, Sequence, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import channel

RSU_HEIGHT_M = channel.RSU_HEIGHT_M

# residence cap: a vehicle dwelling (v=0) inside coverage would otherwise
# report an infinite deadline; every consumer treats >= this as "no deadline"
RESIDENCE_CAP_S = 1e6


@dataclasses.dataclass
class FleetState:
    """Vectorized per-round fleet snapshot.  Every field is an (n,) or (n,2)
    array over the whole fleet; ``serving_rsu == -1`` marks a vehicle outside
    every RSU's coverage (it skips the round)."""
    t: float
    positions: np.ndarray      # (n, 2) planar position, metres
    velocities: np.ndarray     # (n, 2) metres/second
    serving_rsu: np.ndarray    # (n,) int32 cell index, -1 = uncovered
    rates_bps: np.ndarray      # (n,) uplink Shannon rate to the serving RSU
    residence_s: np.ndarray    # (n,) remaining time inside the serving cell

    @property
    def active(self) -> np.ndarray:
        return self.serving_rsu >= 0

    @property
    def n_vehicles(self) -> int:
        return self.positions.shape[0]


# a pytree, so traced-step paths can hand FleetStates across jit boundaries
jax.tree_util.register_pytree_node(
    FleetState,
    lambda s: ((s.t, s.positions, s.velocities, s.serving_rsu, s.rates_bps,
                s.residence_s), None),
    lambda _, c: FleetState(*c))


def apply_presence(state: FleetState, present) -> FleetState:
    """Continuous arrivals/departures over any scenario (DESIGN.md §14): a
    departed vehicle is indistinguishable from one outside coverage —
    ``serving_rsu = -1``, zero rate, zero residence — so every downstream
    consumer (cut selection, slot grouping, telemetry) handles churn through
    the invariants it already honors.  Pure and backend-agnostic: works on
    the host (np) snapshots and on traced (jnp) states alike, because the
    streaming plane's presence bits live on the super-step carry."""
    xp = jnp if isinstance(state.serving_rsu, jnp.ndarray) else np
    present = xp.asarray(present)
    return FleetState(
        t=state.t,
        positions=state.positions,
        velocities=state.velocities,
        serving_rsu=xp.where(present, state.serving_rsu,
                             -1).astype(xp.int32),
        rates_bps=xp.where(present, state.rates_bps,
                           0.0).astype(xp.float32),
        residence_s=xp.where(present, state.residence_s,
                             0.0).astype(xp.float32))


@runtime_checkable
class Scenario(Protocol):
    """A mobility scenario: static RSU deployment + a fleet-state query.

    ``fleet_state(t, seed)`` must be a pure function of (t, seed) so the
    simulator can replay rounds deterministically (benchmark warm re-runs,
    parity tests).

    Scenarios may additionally provide a **traced-step path**
    ``traced_fleet_state(t, key)`` (t a traced scalar, key a jax PRNG key or
    None) returning a :class:`FleetState` of jnp arrays.  The fused
    super-step engine (DESIGN.md §8) calls it *inside* its round scan so K
    rounds of mobility, association, and rate sampling never return to
    Python; scenarios without it are staged per-window on the host instead
    (see ``ScenarioEngine``)."""
    name: str
    n_vehicles: int
    rsu_positions: np.ndarray          # (n_rsus, 2) planar RSU positions
    fleet_arrays: Dict[str, np.ndarray]  # per-vehicle radio/compute attrs

    def fleet_state(self, t: float, seed: int) -> FleetState: ...


# --------------------------------------------------------------------------
# shared vectorized geometry
# --------------------------------------------------------------------------

def nearest_rsu(positions: np.ndarray, rsu_positions: np.ndarray,
                range_m: float):
    """Cell association: nearest RSU within coverage.  Returns
    (serving (n,) int32 with -1 = uncovered, planar distance (n,))."""
    diff = positions[:, None, :] - rsu_positions[None, :, :]
    d2 = np.einsum("nmd,nmd->nm", diff, diff)
    serving = np.argmin(d2, axis=1)
    dmin = np.sqrt(d2[np.arange(len(positions)), serving])
    return np.where(dmin <= range_m, serving, -1).astype(np.int32), dmin


def coverage_exit_time(positions: np.ndarray, velocities: np.ndarray,
                       centers: np.ndarray, range_m: float) -> np.ndarray:
    """Time until each vehicle, moving at constant velocity, exits the disc
    of radius ``range_m`` around its (given) serving RSU — the residence
    time that deadlines the round (capped at RESIDENCE_CAP_S for parked /
    dwelling vehicles)."""
    rel = positions - centers
    a = np.einsum("nd,nd->n", velocities, velocities)
    b = 2.0 * np.einsum("nd,nd->n", rel, velocities)
    c = np.einsum("nd,nd->n", rel, rel) - range_m ** 2
    disc = np.maximum(b * b - 4.0 * a * c, 0.0)
    with np.errstate(divide="ignore", invalid="ignore"):
        t_exit = (-b + np.sqrt(disc)) / (2.0 * a)
    t_exit = np.where(a > 1e-12, t_exit, RESIDENCE_CAP_S)
    return np.clip(t_exit, 0.0, RESIDENCE_CAP_S)


def _rates_to_serving(ch: channel.ChannelConfig, planar_dist: np.ndarray,
                      tx_power_w: np.ndarray, serving: np.ndarray,
                      seed: int) -> np.ndarray:
    """Uplink Shannon rates to the serving RSU (RSU height folded in);
    uncovered vehicles get rate 0."""
    d = np.sqrt(planar_dist ** 2 + RSU_HEIGHT_M ** 2)
    rates = channel.rates_from_distance(ch, d, tx_power_w, seed)
    return np.where(serving >= 0, rates, 0.0)


def nearest_rsu_traced(positions, rsu_positions: np.ndarray, range_m: float):
    """jit-traceable :func:`nearest_rsu`: positions may be a tracer, the RSU
    deployment is a static constant."""
    rsus = jnp.asarray(rsu_positions, jnp.float32)
    diff = positions[:, None, :] - rsus[None, :, :]
    d2 = jnp.einsum("nmd,nmd->nm", diff, diff)
    serving = jnp.argmin(d2, axis=1).astype(jnp.int32)
    dmin = jnp.sqrt(jnp.take_along_axis(d2, serving[:, None], axis=1)[:, 0])
    return jnp.where(dmin <= range_m, serving, -1), dmin


def coverage_exit_time_traced(positions, velocities, centers, range_m: float):
    """jit-traceable :func:`coverage_exit_time` (same quadratic)."""
    rel = positions - centers
    a = jnp.einsum("nd,nd->n", velocities, velocities)
    b = 2.0 * jnp.einsum("nd,nd->n", rel, velocities)
    c = jnp.einsum("nd,nd->n", rel, rel) - range_m ** 2
    disc = jnp.maximum(b * b - 4.0 * a * c, 0.0)
    t_exit = (-b + jnp.sqrt(disc)) / jnp.maximum(2.0 * a, 1e-12)
    t_exit = jnp.where(a > 1e-12, t_exit, RESIDENCE_CAP_S)
    return jnp.clip(t_exit, 0.0, RESIDENCE_CAP_S)


def _rates_to_serving_traced(ch: channel.ChannelConfig, planar_dist,
                             tx_power_w, serving, key):
    """Traced twin of :func:`_rates_to_serving`: one shadow-fading draw per
    vehicle from ``key`` (None, or fading disabled, means no fading)."""
    d = jnp.sqrt(planar_dist ** 2 + RSU_HEIGHT_M ** 2)
    fading = 0.0
    if key is not None and ch.fading_std_db > 0:
        fading = ch.fading_std_db * jax.random.normal(key, planar_dist.shape)
    rates = channel.shannon_rate_traced(ch, d, tx_power_w, fading)
    return jnp.where(serving >= 0, rates, 0.0)


def _resolve_fleet(n: int, seed: int, fleet) -> Dict[str, np.ndarray]:
    if fleet is None:
        fleet = channel.make_fleet(n, seed)
    if not isinstance(fleet, dict):
        fleet = channel.fleet_arrays(fleet)
    return fleet


# --------------------------------------------------------------------------
# highway corridor
# --------------------------------------------------------------------------

@dataclasses.dataclass
class HighwayCorridor:
    """N RSUs every ``rsu_spacing_m`` along a straight multi-lane road.

    Vehicles drive at per-lane base speeds (plus per-vehicle jitter) and wrap
    around the corridor: a wrap is one departure at the end of the road plus
    one fresh arrival at the start, so the fleet membership seen by any one
    RSU is genuinely dynamic while the arrays stay fixed-shape (the cohort
    engine's compiled programs are keyed by bucket signature, not by which
    vehicles fill the rows).

    ``load_skew="zipf"`` biases the *initial* positions toward the low-index
    cells (a vehicle starts in segment s with probability ~ 1/(s+1)), the
    classic rush-hour profile: one crowded cell, a long sparse tail.  It is
    the stress fixture for the occupancy-compacted ragged super-step layout
    (DESIGN.md §12) — a dense per-RSU slot table pads every cell to the
    crowded cell's cohort, a compacted one only pays for occupied slots.
    Kinematics are unchanged, so the skew decays as the fleet wraps."""
    name: str = "highway_corridor"
    n_vehicles: int = 8
    n_rsus: int = 4
    rsu_spacing_m: float = 700.0
    n_lanes: int = 3
    lane_speeds_mps: Sequence[float] = (24.0, 31.0, 38.0)
    lane_width_m: float = 3.7
    seed: int = 0
    load_skew: Optional[str] = None         # None (uniform) | "zipf"
    ch: channel.ChannelConfig = dataclasses.field(
        default_factory=channel.ChannelConfig)
    fleet: Optional[object] = None          # VehicleProfile list or arrays

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        self.fleet_arrays = _resolve_fleet(self.n_vehicles, self.seed,
                                           self.fleet)
        self.road_len_m = self.n_rsus * self.rsu_spacing_m
        rsu_x = (np.arange(self.n_rsus) + 0.5) * self.rsu_spacing_m
        self.rsu_positions = np.stack([rsu_x, np.zeros_like(rsu_x)], axis=-1)
        self._lane = rng.integers(0, self.n_lanes, size=self.n_vehicles)
        base = np.asarray(self.lane_speeds_mps)[self._lane]
        self._speed = base * rng.uniform(0.9, 1.1, size=self.n_vehicles)
        if self.load_skew is None:
            self._x0 = rng.uniform(0.0, self.road_len_m,
                                   size=self.n_vehicles)
        elif self.load_skew == "zipf":
            w = 1.0 / (np.arange(self.n_rsus) + 1.0)
            seg = rng.choice(self.n_rsus, size=self.n_vehicles,
                             p=w / w.sum())
            self._x0 = ((seg + rng.uniform(0.0, 1.0, size=self.n_vehicles))
                        * self.rsu_spacing_m)
        else:
            raise ValueError(f"unknown load_skew {self.load_skew!r}; "
                             f"expected None or 'zipf'")
        self._y = (self._lane - (self.n_lanes - 1) / 2.0) * self.lane_width_m

    def fleet_state(self, t: float, seed: int) -> FleetState:
        x = (self._x0 + self._speed * t) % self.road_len_m
        pos = np.stack([x, self._y], axis=-1)
        vel = np.stack([self._speed, np.zeros_like(self._speed)], axis=-1)
        serving, dist = nearest_rsu(pos, self.rsu_positions,
                                    self.ch.rsu_range_m)
        rates = _rates_to_serving(self.ch, dist,
                                  self.fleet_arrays["tx_power_w"], serving,
                                  seed)
        centers = self.rsu_positions[np.maximum(serving, 0)]
        # residence ends either at the cell border or at the corridor wrap
        # (a wrap is a departure: the vehicle re-enters as a fresh arrival
        # at the road start, leaving its serving cell instantly)
        t_exit = coverage_exit_time(pos, vel, centers, self.ch.rsu_range_m)
        t_wrap = (self.road_len_m - x) / np.maximum(self._speed, 1e-9)
        res = np.where(serving >= 0, np.minimum(t_exit, t_wrap), 0.0)
        return FleetState(t, pos, vel, serving, rates, res)

    def traced_fleet_state(self, t, key) -> FleetState:
        """Traced-step path: the same kinematics/association/radio math in
        jnp, so the fused super-step scan advances the corridor on-device."""
        speed = jnp.asarray(self._speed, jnp.float32)
        x = (jnp.asarray(self._x0, jnp.float32) + speed * t) % self.road_len_m
        pos = jnp.stack([x, jnp.asarray(self._y, jnp.float32)], axis=-1)
        vel = jnp.stack([speed, jnp.zeros_like(speed)], axis=-1)
        serving, dist = nearest_rsu_traced(pos, self.rsu_positions,
                                           self.ch.rsu_range_m)
        tx = jnp.asarray(self.fleet_arrays["tx_power_w"], jnp.float32)
        rates = _rates_to_serving_traced(self.ch, dist, tx, serving, key)
        centers = jnp.asarray(self.rsu_positions, jnp.float32)[
            jnp.maximum(serving, 0)]
        t_exit = coverage_exit_time_traced(pos, vel, centers,
                                           self.ch.rsu_range_m)
        t_wrap = (self.road_len_m - x) / jnp.maximum(speed, 1e-9)
        res = jnp.where(serving >= 0, jnp.minimum(t_exit, t_wrap), 0.0)
        return FleetState(t, pos, vel, serving, rates, res)


# --------------------------------------------------------------------------
# urban grid
# --------------------------------------------------------------------------

_DIRS = np.array([[1, 0], [0, 1], [-1, 0], [0, -1]], dtype=np.int64)  # ENWS


@dataclasses.dataclass
class UrbanGrid:
    """Manhattan grid: ``grid_size`` x ``grid_size`` intersections,
    ``block_m`` apart; vehicles traverse one block at a time, dwell
    ``dwell_s`` at each intersection, and turn pseudo-randomly (straight /
    left / right, U-turn forced at the boundary).  RSUs sit at every
    ``rsu_every``-th intersection.

    The trajectory is procedural — a pure function of (vehicle, segment
    index, scenario seed) — so any ``fleet_state(t)`` query is answered by a
    loop over *completed blocks* (bounded, shared by the fleet), with every
    per-vehicle quantity a vector op."""
    name: str = "urban_grid"
    n_vehicles: int = 8
    grid_size: int = 5
    block_m: float = 250.0
    dwell_s: float = 4.0
    speed_mps: float = 12.0
    rsu_every: int = 2
    seed: int = 0
    ch: channel.ChannelConfig = dataclasses.field(
        default_factory=channel.ChannelConfig)
    fleet: Optional[object] = None

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        self.fleet_arrays = _resolve_fleet(self.n_vehicles, self.seed,
                                           self.fleet)
        n = self.n_vehicles
        self._node0 = rng.integers(0, self.grid_size, size=(n, 2))
        self._h0 = rng.integers(0, 4, size=n)
        self._speed = self.speed_mps * rng.uniform(0.85, 1.15, size=n)
        ticks = np.arange(0, self.grid_size, self.rsu_every)
        gx, gy = np.meshgrid(ticks, ticks, indexing="ij")
        self.rsu_positions = (np.stack([gx.ravel(), gy.ravel()], axis=-1)
                              * self.block_m).astype(np.float64)

    def _kinematics(self, t: float):
        """Vectorized block-walk: returns (pos (n,2) m, step_dir (n,2),
        moving (n,) bool)."""
        n = self.n_vehicles
        per_block = self.block_m / self._speed + self.dwell_s
        k = np.floor(t / per_block).astype(np.int64)      # completed blocks
        frac = t - k * per_block
        offset = np.minimum(frac * self._speed, self.block_m)
        moving = frac * self._speed < self.block_m

        node = self._node0.copy()
        h = self._h0.copy()
        cur_dir = np.zeros((n, 2), dtype=np.int64)
        k_max = int(k.max(initial=0))
        for j in range(k_max + 1):
            if j > 0:
                turn = np.random.default_rng(
                    self.seed * 7919 + j).integers(-1, 2, size=n)
                h = (h + turn) % 4
            step = _DIRS[h]
            out = ((node + step < 0) | (node + step >= self.grid_size)
                   ).any(axis=-1)
            h = np.where(out, (h + 2) % 4, h)
            step = _DIRS[h]
            at = j == k                      # this is the current segment
            cur_dir = np.where(at[:, None], step, cur_dir)
            done = j < k                     # block completed: advance node
            node = np.where(done[:, None], node + step, node)
        pos = node * self.block_m + cur_dir * offset[:, None]
        return pos.astype(np.float64), cur_dir.astype(np.float64), moving

    def fleet_state(self, t: float, seed: int) -> FleetState:
        pos, cur_dir, moving = self._kinematics(t)
        vel = cur_dir * (self._speed * moving)[:, None]
        serving, dist = nearest_rsu(pos, self.rsu_positions,
                                    self.ch.rsu_range_m)
        rates = _rates_to_serving(self.ch, dist,
                                  self.fleet_arrays["tx_power_w"], serving,
                                  seed)
        # residence uses the nominal (non-dwelling) velocity: a vehicle
        # pausing at an intersection still has a finite deadline once it
        # resumes along its heading
        nominal = cur_dir * self._speed[:, None]
        centers = self.rsu_positions[np.maximum(serving, 0)]
        res = np.where(serving >= 0,
                       coverage_exit_time(pos, nominal, centers,
                                          self.ch.rsu_range_m), 0.0)
        return FleetState(t, pos, vel, serving, rates, res)


# --------------------------------------------------------------------------
# city grid (scale-out fixture)
# --------------------------------------------------------------------------

@dataclasses.dataclass
class CityGrid:
    """City-scale deployment: a ``grid_x`` x ``grid_y`` lattice of RSU cells
    (hundreds to thousands of RSUs) serving thousands of vehicles.

    Each vehicle is anchored to a *home cell* drawn from the Zipf popularity
    law over the flattened cell index (the skewed-load pattern introduced
    with the ragged layout: downtown cells crowded, the periphery a long
    sparse tail) and follows an *eccentric orbit* around that cell's center:
    the radius breathes between ``r0*(1 - ecc)`` and ``r0*(1 + ecc)`` while
    the phase advances at an individual angular rate.  Because the radius
    band straddles the RSU coverage radius for much of the fleet, vehicles
    periodically swing through the inter-cell coverage gap:
    ``serving_rsu == -1`` episodes — the signal the mobility-coupled churn
    source (``stream_churn_source="mobility"``) turns into departures and
    re-registrations — arise from the geometry, not from a sampled process,
    and wide orbits near cell edges hand over to neighbouring cells.

    Built for scale: every kinematic quantity is a closed-form function of
    ``t`` (no per-segment walk like :class:`UrbanGrid`), the fleet attribute
    arrays are drawn vectorized (no per-vehicle profile objects), and cell
    association exploits the lattice — the nearest center of a square grid
    is found by flooring, O(n), instead of the O(n x n_rsus) distance
    matrix — so a 100k-vehicle fleet over a 1000-cell grid answers
    ``fleet_state`` in a handful of vector ops."""
    name: str = "city"
    n_vehicles: int = 4096
    grid_x: int = 16
    grid_y: int = 16
    cell_m: float = 900.0        # lattice pitch; > 2*rsu_range_m leaves gaps
    orbit_frac: Sequence[float] = (0.35, 1.15)  # mean orbit r / rsu_range_m
    eccentricity: float = 0.45   # radial breathing amplitude, x mean radius
    speed_mps: float = 14.0
    seed: int = 0
    load_skew: Optional[str] = "zipf"       # "zipf" | None (uniform)
    ch: channel.ChannelConfig = dataclasses.field(
        default_factory=channel.ChannelConfig)
    fleet: Optional[object] = None

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        n = self.n_vehicles
        self.n_rsus = self.grid_x * self.grid_y
        self.fleet_arrays = (self._vector_fleet(rng) if self.fleet is None
                             else _resolve_fleet(n, self.seed, self.fleet))
        gx, gy = np.meshgrid(np.arange(self.grid_x), np.arange(self.grid_y),
                             indexing="ij")
        self.rsu_positions = ((np.stack([gx.ravel(), gy.ravel()], axis=-1)
                               + 0.5) * self.cell_m).astype(np.float64)
        if self.load_skew is None:
            home = rng.integers(0, self.n_rsus, size=n)
        elif self.load_skew == "zipf":
            w = 1.0 / (np.arange(self.n_rsus) + 1.0)
            home = rng.choice(self.n_rsus, size=n, p=w / w.sum())
        else:
            raise ValueError(f"unknown load_skew {self.load_skew!r}; "
                             f"expected None or 'zipf'")
        self._center = self.rsu_positions[home]
        lo, hi = self.orbit_frac
        self._radius = self.ch.rsu_range_m * rng.uniform(lo, hi, size=n)
        self._phase = rng.uniform(0.0, 2.0 * np.pi, size=n)
        speed = self.speed_mps * rng.uniform(0.85, 1.15, size=n)
        spin = rng.choice(np.array([-1.0, 1.0]), size=n)
        self._omega = spin * speed / np.maximum(self._radius, 1e-9)
        # radial breathing: r(t) = r0 * (1 + ecc * sin(nu t + psi)) — an
        # incommensurate rate vs the angular sweep, so the coverage-boundary
        # crossings don't phase-lock to the revolution
        self._nu = np.abs(self._omega) * rng.uniform(0.4, 0.9, size=n)
        self._psi = rng.uniform(0.0, 2.0 * np.pi, size=n)

    def _vector_fleet(self, rng) -> Dict[str, np.ndarray]:
        """Vectorized twin of ``channel.make_fleet`` + ``fleet_arrays``
        (same attribute distributions, one draw per column instead of a
        Python loop per vehicle — the loop is what caps make_fleet at a few
        thousand vehicles)."""
        n = self.n_vehicles
        return {
            "compute_flops": rng.uniform(5e9, 50e9, size=n),
            "tx_power_w": rng.uniform(0.2, 1.0, size=n),
            "compute_power_w": rng.uniform(8.0, 25.0, size=n),
            "x0_m": rng.uniform(-350.0, -50.0, size=n),
            "speed_mps": rng.uniform(8.0, 30.0, size=n),
            "memory_budget_bytes": np.full(n, float("inf")),
        }

    def _associate(self, pos: np.ndarray):
        """Lattice cell association: the Voronoi cell of a square grid is
        the enclosing cell, so nearest-center is floor + clip, O(n)."""
        ij = np.floor(pos / self.cell_m).astype(np.int64)
        ij = np.clip(ij, 0, [self.grid_x - 1, self.grid_y - 1])
        flat = ij[:, 0] * self.grid_y + ij[:, 1]
        rel = pos - self.rsu_positions[flat]
        dist = np.sqrt(np.einsum("nd,nd->n", rel, rel))
        serving = np.where(dist <= self.ch.rsu_range_m, flat, -1)
        return serving.astype(np.int32), dist

    def fleet_state(self, t: float, seed: int) -> FleetState:
        theta = self._phase + self._omega * t
        ct, st = np.cos(theta), np.sin(theta)
        breathe = self._nu * t + self._psi
        r = self._radius * (1.0 + self.eccentricity * np.sin(breathe))
        dr = self._radius * self.eccentricity * self._nu * np.cos(breathe)
        pos = self._center + r[:, None] * np.stack([ct, st], -1)
        vel = (dr[:, None] * np.stack([ct, st], -1)
               + (r * self._omega)[:, None] * np.stack([-st, ct], -1))
        serving, dist = self._associate(pos)
        rates = _rates_to_serving(self.ch, dist,
                                  self.fleet_arrays["tx_power_w"], serving,
                                  seed)
        # residence linearizes the orbit at the current velocity — the same
        # tangent-line deadline every other scenario reports
        centers = self.rsu_positions[np.maximum(serving, 0)]
        res = np.where(serving >= 0,
                       coverage_exit_time(pos, vel, centers,
                                          self.ch.rsu_range_m), 0.0)
        return FleetState(t, pos, vel, serving, rates, res)


# --------------------------------------------------------------------------
# trace replay
# --------------------------------------------------------------------------

@dataclasses.dataclass
class TraceReplay:
    """Deterministic array-driven trajectories: ``positions[i]`` is the fleet
    at ``times[i]``.  Association, residence, and (fading-free by default)
    rates are precomputed per trace step in ``__post_init__``, so tests know
    the exact round a handover happens."""
    times: np.ndarray            # (T,) strictly increasing
    positions: np.ndarray        # (T, n, 2)
    rsu_positions: np.ndarray    # (n_rsus, 2)
    name: str = "trace_replay"
    ch: channel.ChannelConfig = dataclasses.field(default_factory=lambda:
                                                  channel.ChannelConfig(
                                                      fading_std_db=0.0))
    fleet: Optional[object] = None
    seed: int = 0

    def __post_init__(self):
        self.times = np.asarray(self.times, dtype=np.float64)
        self.positions = np.asarray(self.positions, dtype=np.float64)
        self.rsu_positions = np.asarray(self.rsu_positions, dtype=np.float64)
        T, n, _ = self.positions.shape
        assert self.times.shape == (T,)
        self.n_vehicles = n
        self.fleet_arrays = _resolve_fleet(n, self.seed, self.fleet)
        serving = np.empty((T, n), dtype=np.int32)
        dist = np.empty((T, n))
        for i in range(T):
            serving[i], dist[i] = nearest_rsu(self.positions[i],
                                              self.rsu_positions,
                                              self.ch.rsu_range_m)
        self._serving, self._dist = serving, dist
        # velocities: forward finite difference over the trace
        vel = np.zeros_like(self.positions)
        if T > 1:
            dt = np.diff(self.times)[:, None, None]
            vel[:-1] = np.diff(self.positions, axis=0) / np.maximum(dt, 1e-9)
            vel[-1] = vel[-2]
        self._vel = vel
        # residence[i] = min(time until the serving cell next changes along
        # the trace, geometric coverage-exit time at the current velocity) —
        # the scan catches handovers between cells, the geometry resolves
        # exits finer than the trace step
        res = np.empty((T, n))
        dt_end = (self.times[-1] - self.times[-2]) if T > 1 else 0.0
        next_change = np.full(n, self.times[-1] + dt_end)
        for i in range(T - 1, -1, -1):
            if i < T - 1:
                changed = serving[i + 1] != serving[i]
                next_change = np.where(changed, self.times[i + 1],
                                       next_change)
            geo = coverage_exit_time(self.positions[i], vel[i],
                                     self.rsu_positions[np.maximum(
                                         serving[i], 0)],
                                     self.ch.rsu_range_m)
            res[i] = np.minimum(next_change - self.times[i], geo)
        self._residence = np.clip(res, 0.0, RESIDENCE_CAP_S)

    def _step(self, t: float) -> int:
        return int(np.clip(np.searchsorted(self.times, t, side="right") - 1,
                           0, len(self.times) - 1))

    def fleet_state(self, t: float, seed: int) -> FleetState:
        i = self._step(t)
        serving = self._serving[i]
        rates = _rates_to_serving(self.ch, self._dist[i],
                                  self.fleet_arrays["tx_power_w"], serving,
                                  seed)
        return FleetState(float(self.times[i]), self.positions[i],
                          self._vel[i], serving, rates,
                          np.where(serving >= 0, self._residence[i], 0.0))

    def traced_fleet_state(self, t, key) -> FleetState:
        """Traced-step path: the precomputed per-step association/distance/
        residence tables become on-device constants indexed by the (traced)
        trace step — exactly the host tables, so fused and per-round
        dispatch paths see identical states (fading-free traces exactly)."""
        times = jnp.asarray(self.times, jnp.float32)
        i = jnp.clip(jnp.searchsorted(times, t, side="right") - 1, 0,
                     len(self.times) - 1)
        serving = jnp.asarray(self._serving)[i]
        dist = jnp.asarray(self._dist, jnp.float32)[i]
        tx = jnp.asarray(self.fleet_arrays["tx_power_w"], jnp.float32)
        rates = _rates_to_serving_traced(self.ch, dist, tx, serving, key)
        res = jnp.where(serving >= 0,
                        jnp.asarray(self._residence, jnp.float32)[i], 0.0)
        return FleetState(times[i], jnp.asarray(self.positions,
                                                jnp.float32)[i],
                          jnp.asarray(self._vel, jnp.float32)[i],
                          serving, rates, res)


def crossing_trace(n_vehicles: int, n_rsus: int = 2, t_end: float = 120.0,
                   n_steps: int = 60, rsu_spacing_m: float = 600.0,
                   speed_mps: float = 20.0, seed: int = 0,
                   ch: Optional[channel.ChannelConfig] = None,
                   fleet=None) -> TraceReplay:
    """Deterministic linear trace: the fleet drives the corridor end to end,
    crossing every cell boundary — the canonical handover fixture (and the
    trace_replay entry in the scenario benchmark)."""
    rng = np.random.default_rng(seed)
    times = np.linspace(0.0, t_end, n_steps)
    x0 = rng.uniform(-0.25 * rsu_spacing_m, 0.25 * rsu_spacing_m, n_vehicles)
    speeds = speed_mps * rng.uniform(0.9, 1.1, n_vehicles)
    x = x0[None, :] + speeds[None, :] * times[:, None]
    y = np.zeros_like(x)
    rsu_x = (np.arange(n_rsus) + 0.5) * rsu_spacing_m
    rsus = np.stack([rsu_x, np.zeros_like(rsu_x)], axis=-1)
    return TraceReplay(times, np.stack([x, y], axis=-1), rsus, seed=seed,
                       fleet=fleet,
                       ch=ch or channel.ChannelConfig(fading_std_db=0.0))


# --------------------------------------------------------------------------
# registry
# --------------------------------------------------------------------------

def highway_corridor(n_vehicles: int, seed: int = 0, **kw) -> HighwayCorridor:
    return HighwayCorridor(n_vehicles=n_vehicles, seed=seed, **kw)


def urban_grid(n_vehicles: int, seed: int = 0, **kw) -> UrbanGrid:
    return UrbanGrid(n_vehicles=n_vehicles, seed=seed, **kw)


def trace_replay(n_vehicles: int, seed: int = 0, **kw) -> TraceReplay:
    return crossing_trace(n_vehicles, seed=seed, **kw)


def highway_zipf(n_vehicles: int, seed: int = 0, **kw) -> HighwayCorridor:
    """Highway corridor with Zipf-skewed initial cell load (one crowded
    cell, a sparse tail) — the ragged-layout stress scenario."""
    kw.setdefault("load_skew", "zipf")
    kw.setdefault("name", "highway_zipf")
    return HighwayCorridor(n_vehicles=n_vehicles, seed=seed, **kw)


def city(n_vehicles: int, seed: int = 0, **kw) -> CityGrid:
    """City-scale RSU lattice with Zipf cell popularity, orbit mobility,
    and geometric coverage gaps — the scale-out / paging fixture."""
    return CityGrid(n_vehicles=n_vehicles, seed=seed, **kw)


SCENARIOS = {
    "highway_corridor": highway_corridor,
    "highway_zipf": highway_zipf,
    "urban_grid": urban_grid,
    "trace_replay": trace_replay,
    "city": city,
}


def make_scenario(name: str, n_vehicles: int, seed: int = 0, **kw) -> Scenario:
    try:
        return SCENARIOS[name](n_vehicles, seed=seed, **kw)
    except KeyError:
        raise ValueError(f"unknown scenario {name!r}; "
                         f"available: {sorted(SCENARIOS)}") from None
