"""Quickstart: train the paper's case study end-to-end on CPU.

ASFL (adaptive split federated learning) on a CIFAR-like task with 4
vehicles, non-IID data (6-of-10 labels, power-law sizes), ResNet18, and the
rate-adaptive cut-layer rule — the full Fig. 3 workflow, driven through the
declarative front door ``repro.api.run`` (DESIGN.md §9).

  PYTHONPATH=src python examples/quickstart.py [--rounds 3]
"""
import argparse

from repro import api


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=3)
    ap.add_argument("--local-steps", type=int, default=8)
    ap.add_argument("--scheme", default="asfl",
                    choices=["cl", "fl", "sl", "sfl", "asfl"])
    ap.add_argument("--compress", action="store_true",
                    help="int8-quantise the smashed data (beyond-paper)")
    args = ap.parse_args()

    print("== ASFL quickstart: 4 vehicles, non-IID CIFAR-like, ResNet18 ==")
    spec = api.ExperimentSpec(
        model="resnet18",
        train=api.TrainConfig(scheme=args.scheme, rounds=args.rounds,
                              local_steps=args.local_steps, lr=1e-3,
                              batch_size=16,
                              compress_smashed=args.compress),
        fleet=api.FleetConfig(n_vehicles=4, per_vehicle_samples=512,
                              test_samples=512),
    )
    # peek at the non-IID shards the registry's data builder produces
    f = spec.fleet
    clients, _ = api.model_entry(spec.model).make_data(
        f.n_vehicles, f.per_vehicle_samples, f.test_samples, f.data_seed)
    for c in clients:
        labs = sorted(set(c.labels.tolist()))
        print(f"  vehicle {c.client_id}: {len(c)} samples, labels {labs}")

    api.run(spec, on_round=lambda m: print(
        f"round {m.round}: loss={m.loss:.3f} acc={m.test_acc:.3f} "
        f"comm={m.comm_bytes/1e6:.0f}MB sim_time={m.sim_time_s:.1f}s "
        f"cuts={m.cuts}"))
    print("done — the adaptive cuts respond to each vehicle's channel rate;")
    print("see examples/vehicular_sim.py for the full mobility story.")


if __name__ == "__main__":
    main()
