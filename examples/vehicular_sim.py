"""Vehicular mobility simulation: watch the adaptive cut-layer rule react as
vehicles drive past the RSU (the paper's core 'adaptive' story).

Vehicles approach, pass, and leave the RSU's coverage; at each round the
channel model yields per-vehicle Shannon rates (one vectorized draw for the
whole fleet), and the three cut strategies (paper Eq. 3, latency-optimal,
energy-aware) pick cut layers.  Also demonstrates the memory-constrained
clamp (a vehicle-side budget the DBRX-scale architectures force — DESIGN.md
§4), and finishes by training the fleet for a few ASFL rounds through the
declarative front door, ``repro.api.run`` (DESIGN.md §9), with per-vehicle
memory budgets.

  PYTHONPATH=src python examples/vehicular_sim.py          # strategy trace
  PYTHONPATH=src python examples/vehicular_sim.py --train  # + api.run rounds
  PYTHONPATH=src python examples/vehicular_sim.py --train --vehicles 4 \\
      --rounds 1                                           # tiny (CI smoke)
"""
import argparse
import time

import numpy as np

from repro.core import adaptive, channel
from repro.core.cost import resnet_profile, sfl_client_round_cost


def strategy_trace(n_vehicles: int):
    prof = resnet_profile()
    fleet = channel.make_fleet(n_vehicles, seed=7)
    ch = channel.ChannelConfig()
    flops = [v.compute_flops for v in fleet]
    n_batches, batch, sf = 32, 16, 2e12

    print("t(s) | vehicle rates (Mbit/s) -> cuts [paper Eq.3] "
          "[latency-opt] [energy-aware]")
    for t in np.linspace(0, 30, 7):
        rates = channel.sample_round_rates(ch, fleet, float(t), seed=int(t))
        in_rng = [channel.in_range(ch, v, float(t)) for v in fleet]
        cuts_p = adaptive.paper_threshold(rates)
        cuts_l = adaptive.latency_optimal(prof, rates, flops, sf, n_batches,
                                          batch, candidate_cuts=(2, 4, 6, 8))
        cuts_e = adaptive.energy_aware(prof, rates, flops, sf, n_batches,
                                       batch, candidate_cuts=(2, 4, 6, 8))
        rstr = " ".join(f"{r/1e6:5.1f}{'' if ok else '!'}"
                        for r, ok in zip(rates, in_rng))
        print(f"{t:4.0f} | {rstr} -> {cuts_p} {cuts_l} {cuts_e}")
    print("('!' marks vehicles outside RSU coverage: they skip the round —")
    print(" the mobility interruption problem the paper highlights)")

    # round latency comparison at t=15
    rates = channel.sample_round_rates(ch, fleet, 15.0, seed=15)
    for name, cuts in [
        ("fixed cut 4 (SFL)", [4] * n_vehicles),
        ("paper Eq.3 (ASFL)", adaptive.paper_threshold(rates)),
        ("latency-optimal  ", adaptive.latency_optimal(
            prof, rates, flops, sf, n_batches, batch,
            candidate_cuts=(2, 4, 6, 8))),
    ]:
        lat = max(sfl_client_round_cost(prof, c, n_batches, batch, r, f, sf,
                                        local_epochs=5).latency
                  for c, r, f in zip(cuts, rates, flops))
        print(f"round latency {name}: {lat:7.1f}s  cuts={cuts}")

    # vehicle-side memory budget (the DBRX argument): fleet-wide scalar ...
    budget = 64 * 1024 * 1024  # 64 MiB on-vehicle budget
    cuts = adaptive.memory_constrained(prof, budget, adaptive.paper_threshold,
                                       rates)
    print(f"with a {budget>>20} MiB vehicle budget the cuts clamp to {cuts}")
    # ... or per-vehicle (VehicleProfile.memory_budget_bytes)
    het = channel.make_fleet(n_vehicles, seed=7,
                             memory_budget_bytes=(1e5, 8e6))
    cuts = adaptive.memory_constrained(
        prof, channel.fleet_arrays(het)["memory_budget_bytes"],
        adaptive.paper_threshold, rates)
    print(f"with per-vehicle budgets (0.1-8 MB) they clamp to    {cuts}")


def train(n_vehicles: int, rounds: int, cache):
    """ASFL rounds over the fleet through ``repro.api.run``: one declarative
    :class:`ExperimentSpec` routes to the compiled cohort engine (DESIGN.md
    §6/§9) with per-vehicle memory-clamped cuts; ``on_round`` streams each
    round's metrics as it completes.

    ``--compilation-cache DIR`` points JAX's persistent compilation cache at
    DIR: a second invocation deserializes the compiled round programs
    instead of re-running XLA (README quickstart / DESIGN.md §8)."""
    from repro import api

    spec = api.ExperimentSpec(
        model="resnet18",
        train=api.TrainConfig(scheme="asfl", rounds=rounds, local_steps=2,
                              batch_size=8, lr=1e-3),
        adaptive=api.AdaptiveConfig(strategy="memory"),
        fleet=api.FleetConfig(n_vehicles=n_vehicles,
                              per_vehicle_samples=32, test_samples=128,
                              memory_budget_bytes=(5e5, 5e7)),
        runtime=api.RuntimeConfig(compilation_cache_dir=cache),
    )
    print(f"\ntraining {n_vehicles} vehicles through api.run: "
          f"model={spec.model}, scheme=asfl(memory), "
          f"engine={spec.engine_kind}")
    t0 = time.time()
    result = api.run(spec, on_round=lambda m: print(
        f"round {m.round}: loss={m.loss:.3f} acc={m.test_acc:.3f} "
        f"cuts={m.cuts}"))
    print(f"({time.time()-t0:.1f}s wall incl. compile; engine mode="
          f"{result.diagnostics['mode']}, "
          f"total comm={result.totals['comm_bytes']/1e6:.1f} MB)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--train", action="store_true",
                    help="also run ASFL rounds through repro.api.run")
    ap.add_argument("--vehicles", type=int, default=8)
    ap.add_argument("--rounds", type=int, default=2)
    ap.add_argument("--compilation-cache", default=None, metavar="DIR",
                    help="persistent XLA cache: re-runs skip compilation")
    args = ap.parse_args()
    strategy_trace(args.vehicles)
    if args.train:
        train(args.vehicles, args.rounds, args.compilation_cache)


if __name__ == "__main__":
    main()
