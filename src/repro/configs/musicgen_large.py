"""musicgen-large — decoder-only over EnCodec tokens [arXiv:2306.05284].

[audio] 48L d_model=2048 32H (kv=32, MHA) d_ff=8192 vocab=2048.
The EnCodec conv codec + mel frontend is a STUB: ``input_specs`` provides
per-codebook token ids; the model sums 4 codebook embeddings per frame
(the MusicGen delay-pattern interleave collapses to this at the backbone).
Plain (non-gated) GeLU FFN + sinusoidal positions per the paper.
Pure full attention -> long_500k skipped.
"""
from repro.configs.base import ATTN, ArchConfig

CONFIG = ArchConfig(
    name="musicgen-large",
    family="audio",
    source="arXiv:2306.05284",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=2048,
    pattern=(ATTN,),
    mlp_variant="gelu",
    pos="sinusoidal",
    frontend="audio",
    n_codebooks=4,
    default_cut=4,
    subquadratic=False,
)
