"""``run(spec)``: one front door over the federation engines.

The router inspects :attr:`ExperimentSpec.engine_kind` and drives the right
engine — :class:`~repro.core.fedsim.FederationSim` (single-RSU cohort
rounds) or :class:`~repro.core.fedsim.ScenarioEngine` (multi-RSU fused
super-steps, honoring ``runtime.superstep``/``precompile``/compilation
cache) — then returns a :class:`RunResult`: the full round-metrics history,
aggregate cost accounting, wall-clock timing, and ``save``/``load``.

Streaming: ``on_round(metrics)`` fires for every completed round,
``on_cloud_merge(rnd, engine)`` after every multi-RSU cloud sync, and
``on_stream_merge(metrics, engine)`` after every round in which a
StreamBuffer fired (``train.server_schedule="streaming"``).  On the fused
path all fire after each K-round window from the window's single host
pull, so callbacks never add host syncs to the compiled program
(DESIGN.md §8/§9/§14).

``timeit=True`` runs the benchmark protocol: one warmup run (compiles every
program), ``reset()``, then the timed re-run — ``timing["round_s"]`` is the
steady-state per-round cost the benchmarks report (and compare against a
direct engine call for the ``api_overhead_s`` key).
"""
from __future__ import annotations

import dataclasses
import json
import time
from typing import Any, Callable, Dict, List, Optional, Union

import jax
import numpy as np

from repro.api import registry
from repro.api.spec import ExperimentSpec
from repro.core import fleet_sharding
from repro.core.fedsim import (FederationSim, RoundMetrics, ScenarioEngine,
                               ScenarioRoundMetrics)

__all__ = ["RunResult", "run", "build_engine"]


def _json_default(o):
    """Type-faithful JSON fallback: numpy ints stay ints (a loaded
    RunResult's cuts/loads must compare like a live run's)."""
    if isinstance(o, np.integer):
        return int(o)
    if isinstance(o, np.ndarray):
        return o.tolist()
    return float(o)


@dataclasses.dataclass
class RunResult:
    """Everything one experiment produced.

    ``history`` rows are :class:`RoundMetrics` (federation) or
    :class:`ScenarioRoundMetrics` (scenario).  ``final_params`` is the
    trained global model ``(units, head)``, gathered to host numpy arrays
    (mesh-independent); not serialized by :meth:`save`."""
    spec: ExperimentSpec
    engine_kind: str
    history: List[Any]
    totals: Dict[str, float]
    timing: Dict[str, float]
    diagnostics: Dict[str, Any]
    final_params: Any = dataclasses.field(default=None, repr=False)

    def to_dict(self) -> Dict[str, Any]:
        return {
            "spec": self.spec.to_dict(),
            "engine_kind": self.engine_kind,
            "history": [dataclasses.asdict(m) for m in self.history],
            "totals": self.totals,
            "timing": self.timing,
            "diagnostics": self.diagnostics,
        }

    def save(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.to_dict(), f, indent=1, default=_json_default)
        return path

    @classmethod
    def load(cls, path: str) -> "RunResult":
        with open(path) as f:
            d = json.load(f)
        metrics_cls = (ScenarioRoundMetrics
                       if d["engine_kind"] == registry.SCENARIO
                       else RoundMetrics)
        return cls(spec=ExperimentSpec.from_dict(d["spec"]),
                   engine_kind=d["engine_kind"],
                   history=[metrics_cls(**m) for m in d["history"]],
                   totals=d["totals"], timing=d["timing"],
                   diagnostics=d["diagnostics"])


def build_engine(spec: ExperimentSpec):
    """Instantiate the engine a spec routes to (model + fleet data + config
    assembled from the registries).  ``run`` uses this; benchmarks and
    parity tests may call it directly to hold an engine across re-runs.

    The device mesh is built HERE (``runtime.mesh_devices > 1`` —
    core/fleet_sharding.py), so a machine with too few devices fails with
    the ``--xla_force_host_platform_device_count`` recipe before any data
    is staged."""
    rt = spec.runtime
    # multi-host rendezvous first (DESIGN.md §15): jax.distributed must
    # initialize before the first backend touch so the mesh below spans
    # every process's devices.  No-op for the single-process default
    fleet_sharding.maybe_init_distributed(rt.coordinator_address,
                                          rt.num_processes, rt.process_id)
    entry = registry.model_entry(spec.model)
    model = entry.build(**spec.model_kwargs)
    f = spec.fleet
    clients, test = entry.make_data(f.n_vehicles, f.per_vehicle_samples,
                                    f.test_samples, f.data_seed)
    cfg = spec.to_sim_config()
    mesh = fleet_sharding.from_config(cfg, spec.engine_kind,
                                      fleet_size=f.n_vehicles)
    if spec.engine_kind == registry.SCENARIO:
        kw = dict(f.scenario_kwargs)
        kw.setdefault("seed", spec.runtime.seed)
        sc = registry.build_scenario(f.scenario, f.n_vehicles, **kw)
        return ScenarioEngine(model, clients, test, cfg, sc,
                              cloud_sync_every=f.cloud_sync_every,
                              mesh=mesh)
    fleet = None
    if f.memory_budget_bytes is not None:
        from repro.core import channel
        fleet = channel.make_fleet(f.n_vehicles, cfg.seed,
                                   memory_budget_bytes=f.memory_budget_bytes)
    return FederationSim(model, clients, test, cfg, fleet=fleet, mesh=mesh)


def _drive(engine, on_round, on_cloud_merge, on_stream_merge=None):
    if isinstance(engine, ScenarioEngine):
        return engine.run(on_round=on_round, on_cloud_merge=on_cloud_merge,
                          on_stream_merge=on_stream_merge)
    return engine.run(on_round=on_round)


def _totals(history) -> Dict[str, float]:
    accs = [m.test_acc for m in history if np.isfinite(m.test_acc)]
    totals = {
        "rounds": len(history),
        "comm_bytes": float(sum(m.comm_bytes for m in history)),
        "energy_j": float(sum(m.energy_j for m in history)),
        "sim_time_s": float(sum(m.sim_time_s for m in history)),
        "final_loss": float(history[-1].loss) if history else float("nan"),
        "final_acc": float(accs[-1]) if accs else float("nan"),
    }
    if history:
        # fault-plane robustness telemetry (DESIGN.md §13): effective
        # participation and the update mass that never merged.  getattr
        # defaults keep loaded pre-fault histories working
        totals["survivor_frac"] = float(np.mean(
            [getattr(m, "survivor_frac", 1.0) for m in history]))
        totals["lost_update_bytes"] = float(sum(
            getattr(m, "lost_update_bytes", 0.0) for m in history))
        totals["n_dropout"] = int(sum(
            getattr(m, "n_dropout", 0) for m in history))
        totals["n_upload_lost"] = int(sum(
            getattr(m, "n_upload_lost", 0) for m in history))
        totals["n_straggler"] = int(sum(
            getattr(m, "n_straggler", 0) for m in history))
        # streaming-plane telemetry (DESIGN.md §14): sample mass absorbed
        # into the global model (the goodput numerator), buffered-merge
        # count, and continuous-arrival volume
        totals["absorbed_samples"] = float(sum(
            getattr(m, "absorbed_samples", 0.0) for m in history))
        totals["stream_merges"] = int(sum(
            getattr(m, "stream_merges", 0) for m in history))
        totals["n_arrived"] = int(sum(
            getattr(m, "n_arrived", 0) for m in history))
    return totals


def run(spec: ExperimentSpec, *,
        on_round: Optional[Callable[[Any], None]] = None,
        on_cloud_merge: Optional[Callable[[int, Any], None]] = None,
        on_stream_merge: Optional[Callable[[Any, Any], None]] = None,
        timeit: Union[bool, int] = False) -> RunResult:
    """Execute an :class:`ExperimentSpec` end to end and return a
    :class:`RunResult`.

    ``on_round``/``on_cloud_merge``/``on_stream_merge`` stream progress
    (see module docstring);
    ``timeit`` truthy adds a warmup run plus ``int(timeit)`` timed
    **callback-free** re-runs (reset between; min wins) before the final
    callback-visible run, so ``round_s``/``rounds_per_s`` report
    compile-free engine steady state regardless of callback cost — an int
    > 1 strips scheduler noise on small containers."""
    engine = build_engine(spec)
    timing: Dict[str, float] = {}
    warmup = 0.0
    if isinstance(engine, ScenarioEngine) and spec.runtime.precompile:
        t0 = time.perf_counter()
        engine.precompile()
        warmup += time.perf_counter() - t0
    best = None
    if timeit:
        t0 = time.perf_counter()
        _drive(engine, None, None)
        warmup += time.perf_counter() - t0
        # timed samples are always callback-free, so round_s reports pure
        # engine steady state even when on_round does expensive work
        for _ in range(max(int(timeit), 1)):
            engine.reset()
            t0 = time.perf_counter()
            _drive(engine, None, None)
            rep = time.perf_counter() - t0
            best = rep if best is None else min(best, rep)
        engine.reset()
    t0 = time.perf_counter()
    history = _drive(engine, on_round, on_cloud_merge, on_stream_merge)
    run_s = time.perf_counter() - t0
    fastest = best if best is not None else run_s
    timing["warmup_s"] = warmup
    timing["run_s"] = run_s
    timing["round_s"] = fastest / max(len(history), 1)
    timing["rounds_per_s"] = (max(len(history), 1) / fastest
                              if fastest else 0.0)

    diagnostics: Dict[str, Any] = {"model": spec.model,
                                   "wire": spec.train.wire}
    if isinstance(engine, ScenarioEngine):
        diagnostics.update(
            mode=engine.mode, n_rsus=engine.n_rsus,
            compile_fallbacks=engine.programs.compile_fallbacks,
            superstep_layout=engine.programs.layout,
            occupancy=engine.occupancy_stats())
        mesh = engine.fleet_mesh
    else:
        diagnostics.update(mode=engine.engine.mode, n_rsus=1)
        mesh = engine.engine.fleet_mesh
    diagnostics.update(
        mesh_devices=(mesh.n_devices if mesh is not None else 1),
        fleet_axis=(mesh.axis if mesh is not None else None),
        mesh_shape=([mesh.rsu_devices, mesh.veh_devices]
                    if mesh is not None else None),
        n_processes=jax.process_count())
    if spec.runtime.mesh_devices == "auto":
        # the mesh_devices="auto" decision (core/fleet_sharding.py):
        # chosen device count, the slots-per-device floor that drove it,
        # and what was available — None mesh means auto chose 1
        diagnostics["mesh_auto"] = (
            mesh.auto_info if mesh is not None
            else fleet_sharding.resolve_mesh_devices(
                "auto", spec.fleet.n_vehicles)[1])
    if spec.runtime.page_slots > 0:
        diagnostics["page_slots"] = spec.runtime.page_slots
    if spec.faults.straggler_factor > 0.0:
        # staleness histogram (DESIGN.md §13): distribution of the banked
        # straggler weight merged per round across the run
        stale = [float(getattr(m, "stale_merged", 0.0)) for m in history]
        counts, edges = np.histogram(stale, bins=8)
        diagnostics["staleness_hist"] = {"counts": counts.tolist(),
                                         "edges": edges.tolist()}
    elif spec.train.server_schedule == "streaming":
        # streaming twin (DESIGN.md §14): distribution of the buffered
        # slot-age mass discharged per round by StreamBuffer merges
        stale = [float(getattr(m, "stream_stale", 0.0)) for m in history]
        counts, edges = np.histogram(stale, bins=8)
        diagnostics["staleness_hist"] = {"counts": counts.tolist(),
                                         "edges": edges.tolist()}
    totals = _totals(history)
    # goodput (DESIGN.md §14): sample mass the global model absorbed per
    # steady-state second — the continuous-fleet throughput metric
    # BENCH_streaming sweeps against churn
    totals["goodput_samples_per_s"] = (
        totals.get("absorbed_samples", 0.0) / fastest if fastest else 0.0)
    # final_params come home to host numpy: results must not pin (or be
    # stranded on) mesh device buffers after the run
    return RunResult(spec=spec, engine_kind=spec.engine_kind,
                     history=list(history), totals=totals,
                     timing=timing, diagnostics=diagnostics,
                     final_params=fleet_sharding.host_fetch(
                         (list(engine.units), engine.head)))
