"""Wireless channel + vehicle mobility model (VEI communication layer).

Shannon-capacity rates with log-distance path loss over a drive-by mobility
trace.  This supplies the per-vehicle, per-round transmission rates `r_n^t`
that drive the paper's cut-layer selection rule (Eq. 3) and the latency /
energy accounting of Fig. 5b.
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence

import numpy as np


@dataclasses.dataclass
class VehicleProfile:
    """Static per-vehicle characteristics."""
    compute_flops: float = 20e9     # sustained vehicle-side FLOP/s (CPU-class)
    tx_power_w: float = 0.5         # uplink transmit power
    compute_power_w: float = 15.0   # power draw while computing
    x0_m: float = -200.0            # initial position along the road
    speed_mps: float = 15.0         # vehicle speed (m/s)


@dataclasses.dataclass
class ChannelConfig:
    bandwidth_hz: float = 10e6      # per-vehicle allocated bandwidth
    noise_dbm_hz: float = -174.0    # thermal noise density
    path_loss_exp: float = 3.0
    ref_gain_db: float = -30.0      # gain at 1 m
    rsu_range_m: float = 400.0
    fading_std_db: float = 4.0      # shadow fading (log-normal)


def distance_at(v: VehicleProfile, t: float) -> float:
    """Distance to the RSU (at x=0, height folded in) at time t."""
    x = v.x0_m + v.speed_mps * t
    return float(np.sqrt(x * x + 10.0 ** 2))


def rate_bps(cfg: ChannelConfig, v: VehicleProfile, t: float,
             rng: np.random.Generator | None = None) -> float:
    """Shannon rate B log2(1 + SNR) with path loss + optional shadow fading."""
    d = distance_at(v, t)
    pl_db = -cfg.ref_gain_db + 10 * cfg.path_loss_exp * np.log10(max(d, 1.0))
    if rng is not None and cfg.fading_std_db > 0:
        pl_db += rng.normal(0.0, cfg.fading_std_db)
    p_rx_dbm = 10 * np.log10(v.tx_power_w * 1e3) - pl_db
    noise_dbm = cfg.noise_dbm_hz + 10 * np.log10(cfg.bandwidth_hz)
    snr = 10 ** ((p_rx_dbm - noise_dbm) / 10)
    return float(cfg.bandwidth_hz * np.log2(1.0 + snr))


def in_range(cfg: ChannelConfig, v: VehicleProfile, t: float) -> bool:
    return abs(v.x0_m + v.speed_mps * t) <= cfg.rsu_range_m


def residence_time(cfg: ChannelConfig, v: VehicleProfile, t: float) -> float:
    """Remaining time within RSU coverage (the training-completion deadline)."""
    x = v.x0_m + v.speed_mps * t
    if abs(x) > cfg.rsu_range_m:
        return 0.0
    return (cfg.rsu_range_m - x) / max(v.speed_mps, 1e-9)


def make_fleet(n: int, seed: int = 0) -> List[VehicleProfile]:
    """Heterogeneous fleet: compute speeds and mobility vary per vehicle."""
    rng = np.random.default_rng(seed)
    fleet = []
    for i in range(n):
        fleet.append(VehicleProfile(
            compute_flops=float(rng.uniform(5e9, 50e9)),
            tx_power_w=float(rng.uniform(0.2, 1.0)),
            compute_power_w=float(rng.uniform(8.0, 25.0)),
            x0_m=float(rng.uniform(-350.0, -50.0)),
            speed_mps=float(rng.uniform(8.0, 30.0)),
        ))
    return fleet


def sample_round_rates(cfg: ChannelConfig, fleet: Sequence[VehicleProfile],
                       t: float, seed: int) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return np.array([rate_bps(cfg, v, t, rng) for v in fleet])
