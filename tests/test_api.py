"""The declarative experiment layer (ISSUE 4): spec validation + JSON
round-trips, the SimConfig deprecation shim (field-for-field), registry
combination coverage (every model x scenario x strategy x schedule either
runs or fails at spec-build with an actionable error), engine routing,
streaming callbacks, RunResult save/load, and the API-vs-direct-engine
bit-for-bit parity for fused super-steps (sgd)."""
import dataclasses
import itertools

import jax
import numpy as np
import pytest

from repro import api
from repro.core.fedsim import ScenarioEngine, SimConfig

# ---------------------------------------------------------------- fixtures

TINY_TRAIN = dict(rounds=1, local_steps=1, batch_size=4, lr=1e-3,
                  eval_every=0)


def _spec(model="mlp9", scenario=api.SINGLE_RSU, strategy="paper",
          schedule="sequential", n=2, scheme="asfl", **runtime):
    return api.ExperimentSpec(
        model=model,
        train=api.TrainConfig(scheme=scheme, server_schedule=schedule,
                              **TINY_TRAIN),
        adaptive=api.AdaptiveConfig(strategy=strategy),
        fleet=api.FleetConfig(n_vehicles=n, scenario=scenario,
                              per_vehicle_samples=16, test_samples=16),
        runtime=api.RuntimeConfig(**runtime))


@pytest.fixture(scope="module")
def scenario_run():
    """One fused scenario run through the front door, with callbacks —
    shared by the streaming/save-load/parity tests (compiles once)."""
    spec = api.ExperimentSpec(
        model="mlp9",
        train=api.TrainConfig(scheme="asfl", rounds=4, local_steps=2,
                              batch_size=4, lr=1e-2, optimizer="sgd",
                              eval_every=0),
        adaptive=api.AdaptiveConfig(strategy="paper"),
        fleet=api.FleetConfig(n_vehicles=4, scenario="trace_replay",
                              cloud_sync_every=2, per_vehicle_samples=16,
                              test_samples=16),
        runtime=api.RuntimeConfig(superstep=2, precompile=False))
    rounds_seen, merges = [], []
    res = api.run(spec, on_round=lambda m: rounds_seen.append(m.round),
                  on_cloud_merge=lambda rnd, eng: merges.append(rnd))
    return spec, res, rounds_seen, merges


# ------------------------------------------------------- public API surface

API_SURFACE = sorted([
    "ExperimentSpec", "TrainConfig", "AdaptiveConfig", "FleetConfig",
    "RuntimeConfig", "FaultsConfig", "StreamConfig", "SIM_CONFIG_FIELD_MAP",
    "MODELS", "SCENARIOS", "STRATEGIES", "SCHEDULES", "WIRES",
    "ModelEntry", "StrategyEntry", "ScheduleEntry", "WireEntry",
    "register_model", "register_scenario", "register_strategy",
    "register_schedule", "register_wire", "model_entry", "build_model",
    "build_scenario", "make_lm_fleet_data",
    "FEDERATION", "SCENARIO", "SINGLE_RSU",
    "run", "build_engine", "RunResult",
])


def test_api_surface_snapshot():
    """The public contract: additions must update this snapshot (and
    DESIGN.md §9); accidental removals fail tier-1."""
    assert sorted(api.__all__) == API_SURFACE
    for name in api.__all__:
        assert hasattr(api, name), name


def test_builtin_registries_present():
    assert {"resnet18", "mlp9", "smollm-360m"} <= set(api.MODELS)
    # every TransformerUnitModel-eligible (text) arch config is registered
    from repro.configs import ARCH_IDS, get_config
    text = {a for a in ARCH_IDS if get_config(a).frontend == "none"}
    assert text <= set(api.MODELS)
    assert set(api.SCENARIOS) == {"single_rsu", "highway_corridor",
                                  "highway_zipf", "urban_grid",
                                  "trace_replay", "city"}
    assert set(api.SCHEDULES) == {"sequential", "parallel", "streaming"}
    assert {"paper", "paper-literal", "latency", "energy", "memory",
            "residence"} == set(api.STRATEGIES)
    assert set(api.WIRES) == {"none", "int8", "topk_int8"}


# -------------------------------------------------------- JSON round-trips

def _roundtrip(spec):
    again = api.ExperimentSpec.from_json(spec.to_json())
    assert again == spec
    return again


def test_spec_json_roundtrips_every_registry_entry():
    for model in api.MODELS:
        _roundtrip(_spec(model=model))
    for scenario in api.SCENARIOS:
        _roundtrip(_spec(scenario=scenario))
    for name, strat in api.STRATEGIES.items():
        eng = strat.engines[0]
        _roundtrip(_spec(strategy=name,
                         scenario=(api.SINGLE_RSU
                                   if eng == api.FEDERATION
                                   else "highway_corridor")))
    for name, sched in api.SCHEDULES.items():
        _roundtrip(_spec(schedule=name,
                         scenario=(api.SINGLE_RSU
                                   if api.FEDERATION in sched.engines
                                   else "urban_grid")))


def test_spec_json_roundtrips_non_defaults():
    spec = api.ExperimentSpec(
        model="resnet18",
        train=api.TrainConfig(scheme="sfl", batch_size=4, local_epochs=2,
                              lr=5e-3, rounds=3, optimizer="momentum",
                              eval_every=0, compress_smashed=True),
        adaptive=api.AdaptiveConfig(strategy="latency", cut=6),
        fleet=api.FleetConfig(n_vehicles=8, per_vehicle_samples=32,
                              mobility_dropout=True,
                              memory_budget_bytes=(1e5, 8e6)),
        runtime=api.RuntimeConfig(seed=3, cohort_parallel="scan",
                                  compilation_cache_dir="/tmp/x"))
    again = _roundtrip(spec)
    # JSON has no tuples: the (lo, hi) budget pair must come back a tuple
    assert again.fleet.memory_budget_bytes == (1e5, 8e6)


# ------------------------------------------------- the SimConfig shim

def test_sim_config_field_map_is_exhaustive():
    """Every flat SimConfig field maps onto exactly one nested group field
    (the deprecation shim is field-for-field, never lossy)."""
    sim_fields = {f.name for f in dataclasses.fields(SimConfig)}
    assert set(api.SIM_CONFIG_FIELD_MAP) == sim_fields
    for group, field in api.SIM_CONFIG_FIELD_MAP.values():
        group_type = type(getattr(api.ExperimentSpec(), group))
        assert field in {f.name for f in dataclasses.fields(group_type)}, \
            (group, field)


def test_sim_config_shim_roundtrip():
    cfg = SimConfig(scheme="asfl", cut=2, n_clients=16, batch_size=4,
                    local_epochs=3, local_steps=7, lr=2e-3, rounds=5,
                    seed=11, optimizer="sgd", adaptive_strategy="residence",
                    compress_smashed=True, server_flops=1e12,
                    round_interval_s=2.5, mobility_dropout=False,
                    cohort_parallel="vmap", eval_every=2,
                    server_schedule="parallel", slot_capacity="tight8",
                    superstep=4, compilation_cache_dir="/tmp/c")
    spec = api.ExperimentSpec.from_sim_config(cfg, model="mlp9",
                                              scenario="highway_corridor")
    assert spec.to_sim_config() == cfg
    for sim_field, (group, field) in api.SIM_CONFIG_FIELD_MAP.items():
        assert getattr(getattr(spec, group), field) == \
            getattr(cfg, sim_field), sim_field


def test_from_sim_config_extras_override():
    spec = api.ExperimentSpec.from_sim_config(
        SimConfig(rounds=2), model="mlp9", scenario="urban_grid",
        **{"fleet.cloud_sync_every": 3, "runtime.precompile": False})
    assert spec.fleet.cloud_sync_every == 3
    assert not spec.runtime.precompile
    with pytest.raises(ValueError, match="group.field"):
        api.ExperimentSpec.from_sim_config(SimConfig(), **{"bogus": 1})


# ------------------------------------------------ construction validation

@pytest.mark.parametrize("field,value", [
    ("scheme", "federated"), ("adaptive_strategy", "psychic"),
    ("server_schedule", "roundrobin"), ("slot_capacity", "pow3"),
    ("cohort_parallel", "threads"), ("optimizer", "lion")])
def test_sim_config_rejects_invalid_values(field, value):
    with pytest.raises(ValueError) as e:
        SimConfig(**{field: value})
    msg = str(e.value)
    assert field in msg and "allowed values" in msg


@pytest.mark.parametrize("field,value", [
    ("rounds", 0), ("batch_size", 0), ("superstep", 0), ("n_clients", 0)])
def test_sim_config_rejects_invalid_ints(field, value):
    with pytest.raises(ValueError, match=field):
        SimConfig(**{field: value})


@pytest.mark.parametrize("build,needle", [
    (lambda: _spec(model="vgg"), "registered models"),
    (lambda: _spec(scenario="mars"), "registered:"),
    (lambda: _spec(strategy="latency", scenario="highway_corridor"),
     "scenario engine"),
    (lambda: _spec(strategy="residence"), "federation engine"),
    (lambda: _spec(schedule="parallel"), "multi-RSU scenario"),
    (lambda: _spec(superstep=4), "superstep"),
    (lambda: _spec(scheme="fl", scenario="urban_grid"), "asfl"),
    (lambda: api.ExperimentSpec(train=api.TrainConfig(scheme="sfl"),
                                adaptive=api.AdaptiveConfig(cut=42)),
     "out of range"),
])
def test_spec_build_rejects_invalid_combos(build, needle):
    with pytest.raises(ValueError, match=needle):
        build()


def test_every_registry_combination_builds_or_fails_actionably():
    """The acceptance grid: every (model x scenario x strategy x schedule)
    either constructs a runnable spec or raises ValueError at build time
    whose message names the offending value AND what is allowed."""
    built = failed = 0
    for model, scenario, strategy, schedule in itertools.product(
            api.MODELS, api.SCENARIOS, api.STRATEGIES, api.SCHEDULES):
        try:
            spec = _spec(model=model, scenario=scenario, strategy=strategy,
                         schedule=schedule)
            assert spec.engine_kind in (api.FEDERATION, api.SCENARIO)
            built += 1
        except ValueError as e:
            msg = str(e)
            # actionable: the message lists what this engine supports
            assert "engine" in msg and ("supports" in msg or
                                        "allowed" in msg), msg
            failed += 1
    # both populations exist, and the valid grid is the expected size:
    # models x (1 single-RSU x 5 strategies + 5 scenarios x 3 strategies
    #           x 3 schedules)
    assert built == len(api.MODELS) * (5 + 5 * 3 * 3)
    assert failed > 0


# ------------------------------------------------------- running the grid

FEDERATION_STRATS = sorted(n for n, s in api.STRATEGIES.items()
                           if api.FEDERATION in s.engines)
SCENARIO_STRATS = sorted(n for n, s in api.STRATEGIES.items()
                         if api.SCENARIO in s.engines)


@pytest.mark.parametrize("strategy", FEDERATION_STRATS)
def test_single_rsu_grid_runs(strategy):
    res = api.run(_spec(strategy=strategy))
    assert len(res.history) == 1
    assert np.isfinite(res.history[-1].loss)
    assert res.engine_kind == api.FEDERATION
    assert res.diagnostics["n_rsus"] == 1


@pytest.mark.parametrize("schedule", sorted(api.SCHEDULES))
@pytest.mark.parametrize("strategy", SCENARIO_STRATS)
def test_scenario_grid_runs(strategy, schedule):
    res = api.run(_spec(scenario="trace_replay", strategy=strategy,
                        schedule=schedule, n=4, precompile=False))
    assert len(res.history) == 1
    assert np.isfinite(res.history[-1].loss)
    assert res.engine_kind == api.SCENARIO
    assert res.diagnostics["compile_fallbacks"] == 0 \
        or not res.spec.runtime.precompile


@pytest.mark.parametrize("scenario", ["highway_corridor", "urban_grid"])
def test_other_scenarios_run(scenario):
    res = api.run(_spec(scenario=scenario, n=4, precompile=False))
    assert np.isfinite(res.history[-1].loss)


@pytest.mark.slow
def test_lm_arch_runs_through_both_engines():
    """A TransformerUnitModel registry entry trains through the cohort
    engine AND the fused multi-RSU engine (reduced config, tiny shards)."""
    for scenario in (api.SINGLE_RSU, "trace_replay"):
        res = api.run(_spec(model="smollm-360m", scenario=scenario, n=2,
                            precompile=False))
        assert np.isfinite(res.history[-1].loss)


# -------------------------------------------------- streaming + RunResult

def test_streaming_callbacks(scenario_run):
    spec, res, rounds_seen, merges = scenario_run
    assert rounds_seen == [0, 1, 2, 3]          # every round, in order
    assert merges == [1, 3]                     # cloud_sync_every=2
    assert res.totals["rounds"] == 4
    assert res.timing["run_s"] > 0


def test_run_result_totals_and_params(scenario_run):
    _, res, _, _ = scenario_run
    assert res.totals["comm_bytes"] > 0
    assert np.isfinite(res.totals["final_loss"])
    units, head = res.final_params
    assert len(units) == api.model_entry("mlp9").n_units
    assert all(np.isfinite(np.asarray(u["w"])).all() for u in units)


def test_run_result_save_load(tmp_path, scenario_run):
    spec, res, _, _ = scenario_run
    path = res.save(str(tmp_path / "run.json"))
    again = api.RunResult.load(path)
    assert again.spec == spec
    assert again.engine_kind == res.engine_kind
    assert len(again.history) == len(res.history)
    assert again.history[-1].rsu_loads == res.history[-1].rsu_loads
    np.testing.assert_allclose(
        [m.loss for m in again.history], [m.loss for m in res.history])
    assert again.totals == pytest.approx(res.totals, nan_ok=True)


# ------------------------------------- API == direct engine, bit for bit

def test_api_superstep_matches_direct_engine_bitforbit(scenario_run):
    """The front door adds routing, not math: a K-fused sgd run through
    repro.api.run equals the direct ScenarioEngine (PR 3) bit for bit —
    same model init, data shards, scenario, and fused programs."""
    spec, res, _, _ = scenario_run
    entry = api.model_entry(spec.model)
    f = spec.fleet
    clients, test = entry.make_data(f.n_vehicles, f.per_vehicle_samples,
                                    f.test_samples, f.data_seed)
    sc = api.build_scenario(f.scenario, f.n_vehicles,
                            seed=spec.runtime.seed, **f.scenario_kwargs)
    eng = ScenarioEngine(entry.build(), clients, test, spec.to_sim_config(),
                         sc, cloud_sync_every=f.cloud_sync_every)
    hist = eng.run()
    np.testing.assert_array_equal([m.loss for m in hist],
                                  [m.loss for m in res.history])
    assert [m.cuts for m in hist] == [m.cuts for m in res.history]
    api_units, api_head = res.final_params
    jax.tree.map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a),
                                                   np.asarray(b)),
        {"units": list(eng.units), "head": eng.head},
        {"units": list(api_units), "head": api_head})


def test_build_engine_routes(scenario_run):
    spec, _, _, _ = scenario_run
    assert isinstance(api.build_engine(spec), ScenarioEngine)
    from repro.core.fedsim import FederationSim
    assert isinstance(api.build_engine(_spec()), FederationSim)
