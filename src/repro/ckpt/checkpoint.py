"""Pytree <-> .npz checkpointing (no orbax offline).

Leaves are stored under their tree path; restore rebuilds into a reference
pytree (``like``) so dtypes/structure round-trip exactly.  Writes are atomic
(tmp file + rename) — a killed run never leaves a torn checkpoint.
"""
from __future__ import annotations

import os
import re
import tempfile
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(p) for p in path)
        arr = np.asarray(leaf)
        if arr.dtype.name not in ("float64", "float32", "float16", "int64",
                                  "int32", "int16", "int8", "uint64",
                                  "uint32", "uint16", "uint8", "bool"):
            # .npz cannot serialise ml_dtypes (bfloat16 &co): upcast
            # losslessly to f32 — restore casts back to the reference dtype.
            arr = np.asarray(jax.numpy.asarray(leaf, jax.numpy.float32))
        flat[key] = arr
    return flat


def save_checkpoint(directory: str, step: int, tree: Any) -> str:
    os.makedirs(directory, exist_ok=True)
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    fd, tmp = tempfile.mkstemp(dir=directory, suffix=".tmp")
    os.close(fd)
    try:
        with open(tmp, "wb") as f:
            np.savez(f, **_flatten(tree))
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)
    return path


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(m.group(1)) for f in os.listdir(directory)
             if (m := re.fullmatch(r"ckpt_(\d+)\.npz", f))]
    return max(steps) if steps else None


def restore_checkpoint(directory: str, step: int, like: Any) -> Any:
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    with np.load(path) as data:
        flat = dict(data)
    paths, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for path_elts, ref in paths:
        key = "/".join(str(p) for p in path_elts)
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = flat[key]
        ref_arr = np.asarray(ref)
        if arr.shape != ref_arr.shape:
            raise ValueError(f"shape mismatch for {key}: {arr.shape} vs {ref_arr.shape}")
        leaves.append(jax.numpy.asarray(arr, dtype=ref_arr.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)
