"""Streaming plane: seeded, fully traced arrival/departure processes and the
buffered-asynchronous merge policy for continuous fleets (DESIGN.md §14).

The paper's future-directions section argues synchronous SFL rounds break
down under vehicular mobility: vehicles arrive, train, and vanish
continuously, so a server that waits for the slowest survivor wastes the
goodput of everyone who already finished.  This module owns the *streaming
processes* — who is present this round, and how pending updates are
discounted by age — while ``superstep.py`` owns their consequences (the
``StreamBuffer`` carry plane and the ``streaming`` server schedule's
buffer-fires-at-B merges).

Two pieces:

- **presence stream**: a per-vehicle Markov toggle chain.  Each round every
  vehicle flips its presence bit with probability ``churn_rate``, drawn from
  a dedicated PRNG stream (``fold_in(stream_key, round)`` — the fault-plane
  construction, so a K-fused super-step samples identically to K single
  rounds).  The chain's stationary presence is 1/2 regardless of churn, so
  raising ``churn_rate`` raises the *arrival rate* (≈ n·churn/2 vehicles per
  round) without starving the fleet — the knob sweeps event frequency, not
  fleet size.  Presence lives on the donated scan carry; churn is data,
  never a program signature.
- **staleness kernel**: the pluggable discount the buffered merge applies to
  a pending delta of age ``s`` rounds: ``constant`` (1.0 — FedAvg weights
  untouched, bitwise, since ``x * 1.0`` is an IEEE identity) or ``poly``
  (``1/(1+s)**alpha``, the FedBuff/arXiv:2210.15496 polynomial family).

Zero-streaming invariant: every engine hook is gated at Python level on
``StreamConfig.churning`` / the ``streaming`` schedule (the ``wire="none"``
and zero-fault precedents), so a default config compiles to a byte-identical
program and trains bit-for-bit vs a build without the streaming plane.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

# domain-separates the streaming stream from the batch-index (seed*1000+rnd),
# fading (seed^0x5EED5EED) and fault (seed^0xFA17) streams
STREAM_SALT = 0xB0FF

STALENESS_KERNELS = ("constant", "poly")

# where presence departures come from: the seeded Markov toggle chain
# ("markov", gated on churn_rate > 0) or the scenario's coverage state
# ("mobility": a vehicle with serving_rsu == -1 has departed the stream,
# and a vehicle re-entering coverage re-registers — synchronous schedules
# admit it next round, the streaming schedule immediately)
CHURN_SOURCES = ("markov", "mobility")


@dataclasses.dataclass(frozen=True)
class StreamConfig:
    """Seeded streaming-federation processes for a federation engine.

    All-defaults means *no streaming*: engines gate every streaming hook at
    Python level on ``churning`` (and the ``streaming`` schedule flag), so
    the zero-streaming program is byte-identical to one built before the
    streaming plane existed.
    """

    buffer_size: int = 4       # B: buffered deltas per RSU before a merge fires
    churn_rate: float = 0.0    # P[vehicle toggles presence each round]
    kernel: str = "constant"   # staleness discount: constant | poly
    alpha: float = 0.5         # poly kernel exponent: 1/(1+s)**alpha
    seed: int = 0
    churn_source: str = "markov"  # markov (toggle chain) | mobility

    def __post_init__(self):
        if self.kernel not in STALENESS_KERNELS:
            raise ValueError(
                f"kernel must be one of {STALENESS_KERNELS}, got {self.kernel!r}")
        if self.churn_source not in CHURN_SOURCES:
            raise ValueError(
                f"churn_source must be one of {CHURN_SOURCES}, "
                f"got {self.churn_source!r}")
        if not 0.0 <= float(self.churn_rate) < 1.0:
            raise ValueError(
                f"churn_rate must be in [0, 1), got {self.churn_rate!r}")
        if self.churn_source == "mobility" and float(self.churn_rate) > 0.0:
            raise ValueError(
                "churn_source='mobility' derives departures from coverage; "
                "churn_rate must stay 0 (the Markov chain is the 'markov' "
                "source)")
        if int(self.buffer_size) < 1:
            raise ValueError(
                f"buffer_size must be >= 1, got {self.buffer_size!r}")
        if float(self.alpha) < 0.0:
            raise ValueError(f"alpha must be >= 0, got {self.alpha!r}")

    @property
    def churning(self) -> bool:
        """Any traced presence process active (a sampled toggle chain or
        the mobility-coupled coverage stream)."""
        return float(self.churn_rate) > 0.0 or self.churn_source == "mobility"


def stream_key(cfg: StreamConfig, rnd) -> jax.Array:
    """Per-round streaming PRNG key; ``rnd`` may be traced
    (window-independent, so K-fused == per-round)."""
    return jax.random.fold_in(jax.random.PRNGKey(cfg.seed ^ STREAM_SALT), rnd)


def sample_toggles_traced(cfg: StreamConfig, rnd, n_vehicles: int):
    """Draw one round of presence toggles inside the traced program.

    Returns bool (n,): True where the vehicle flips between present and
    departed this round.  The engine XORs this into the presence plane on
    the carry — arrivals and departures are the two edges of the same
    toggle, which is what keeps the stationary fleet size churn-invariant.
    """
    u = jax.random.uniform(stream_key(cfg, rnd), (n_vehicles,))
    return u < cfg.churn_rate


def sample_toggles_host(cfg: StreamConfig, rnd: int, n_vehicles: int):
    """Host-side twin of :func:`sample_toggles_traced`.

    An independent stream from the traced sampler (numpy vs threefry) — a
    host consumer never shares a toggle schedule with the traced engines,
    only a distribution (the fault-plane convention).
    """
    rng = np.random.default_rng((cfg.seed ^ STREAM_SALT) * 1_000_003 + rnd)
    return rng.random(n_vehicles) < cfg.churn_rate


def gate_presence(serving, rates, residence, admit):
    """Apply an admission mask to the per-round fleet triple: a vehicle not
    admitted this round is indistinguishable from one outside coverage
    (``serving_rsu = -1``, zero rate, zero residence), so cut selection,
    slot grouping, and telemetry all handle churn through invariants they
    already honor.  :func:`repro.core.scenario.apply_presence` is the
    FleetState-level twin for host consumers."""
    admit = jnp.asarray(admit)
    return (jnp.where(admit, serving, -1).astype(jnp.int32),
            jnp.where(admit, rates, 0.0).astype(jnp.float32),
            jnp.where(admit, residence, 0.0).astype(jnp.float32))


def staleness_kernel(kind: str, alpha: float, staleness):
    """Discount applied to a buffered delta of age ``staleness`` rounds.

    ``constant`` returns exactly 1.0 per slot — multiplying a weight by it
    is an IEEE identity, which is what makes the constant-kernel buffered
    merge *bitwise* equal to plain survivor FedAvg
    (tests/test_properties.py).  ``poly`` is the FedBuff polynomial family
    ``1/(1+s)**alpha`` — monotone non-increasing in ``s`` for alpha >= 0.
    """
    s = jnp.asarray(staleness, jnp.float32)
    if kind == "constant":
        return jnp.ones_like(s)
    if kind == "poly":
        return (1.0 + s) ** (-float(alpha))
    raise ValueError(f"unknown staleness kernel {kind!r}")
