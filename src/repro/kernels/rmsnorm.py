"""Fused RMSNorm (Pallas): one VMEM pass computes the mean-square and applies
the scaled normalisation — the memory-bound fusion on the residual stream.

Tiles are (block_rows, d_model): the full feature dim stays resident so the
reduction needs no cross-tile accumulation (d_model <= 8192 for every
assigned arch -> max tile 8192*4B*rows; block_rows is chosen to stay within
a ~4 MiB VMEM budget).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

VMEM_BUDGET = 4 * 1024 * 1024


def _rmsnorm_kernel(x_ref, g_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(var + eps)
                  * g_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def rmsnorm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6,
            interpret: bool = False) -> jnp.ndarray:
    """x (..., d), scale (d,)."""
    *lead, d = x.shape
    rows = 1
    for s in lead:
        rows *= s
    x2 = x.reshape(rows, d)
    br = max(1, min(rows, VMEM_BUDGET // (4 * d)))
    while rows % br:
        br -= 1
    grid = (rows // br,)
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=grid,
        in_specs=[pl.BlockSpec((br, d), lambda i: (i, 0)),
                  pl.BlockSpec((1, d), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), x.dtype),
        interpret=interpret,
    )(x2, scale.reshape(1, d))
    return out.reshape(*lead, d)
