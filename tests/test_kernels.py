"""Per-kernel validation: shape/dtype sweeps, interpret mode vs jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref as REF
from repro.kernels.flash_attention import flash_attention
from repro.kernels.quant import dequantize_int8, quantize_int8
from repro.kernels.rmsnorm import rmsnorm as rmsnorm_k
from repro.kernels.ssd import ssd_chunk_scan
from repro.kernels.wire import (sparsify_quant_pack, unpack_dequant,
                                unpack_dequant_matmul)
from repro.core import compression as COMP

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------- flash attn
@pytest.mark.parametrize("b,sq,sk,h,kv,d", [
    (2, 256, 256, 4, 2, 64),
    (1, 128, 128, 8, 8, 128),
    (1, 128, 128, 4, 1, 256),    # MQA, gemma-class head_dim
    (2, 192, 192, 6, 3, 64),     # non-pow2 seq (pad path)
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_causal(b, sq, sk, h, kv, d, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, sq, h, d), dtype)
    k = jax.random.normal(ks[1], (b, sk, kv, d), dtype)
    v = jax.random.normal(ks[2], (b, sk, kv, d), dtype)
    out = flash_attention(q, k, v, causal=True, block_q=64, block_k=64,
                          interpret=True)
    refo = REF.attention_ref(q, k, v, causal=True)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(refo, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("window", [32, 64, 128])
def test_flash_attention_sliding_window(window):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 256, 4, 64))
    k = jax.random.normal(ks[1], (1, 256, 2, 64))
    v = jax.random.normal(ks[2], (1, 256, 2, 64))
    out = flash_attention(q, k, v, causal=True, window=window,
                          block_q=64, block_k=64, interpret=True)
    refo = REF.attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(refo),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_noncausal():
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (2, 128, 2, 64))
    k = jax.random.normal(ks[1], (2, 128, 2, 64))
    v = jax.random.normal(ks[2], (2, 128, 2, 64))
    out = flash_attention(q, k, v, causal=False, block_q=64, block_k=64,
                          interpret=True)
    refo = REF.attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(refo),
                               rtol=2e-5, atol=2e-5)


# ------------------------------------------------------------------- quant
_JAX_VERSION = tuple(int(p) for p in jax.__version__.split(".")[:2])


@pytest.mark.parametrize("shape", [(4, 256), (2, 64, 128), (3, 5, 384),
                                   (4, 200), (8, 48)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_quant_roundtrip_matches_ref(shape, dtype):
    # (4, 200): non-divisible trailing dim — both sides pad internally to
    # the group boundary; (8, 48): whole-row group smaller than GROUP
    if dtype == jnp.bfloat16 and _JAX_VERSION < (0, 5):
        pytest.skip("bf16 interpret-mode rounding disagrees with the XLA "
                    "reference by 1 int8 ulp on jax < 0.5 (env gate)")
    x = (jax.random.normal(KEY, shape) * 5).astype(dtype)
    qk, sk_ = quantize_int8(x, interpret=True)
    qr, sr = COMP.quantize_int8(x)
    np.testing.assert_array_equal(np.asarray(qk), np.asarray(qr))
    np.testing.assert_allclose(np.asarray(sk_), np.asarray(sr), rtol=1e-5)
    xk = dequantize_int8(qk, sk_, interpret=True)
    xr = COMP.dequantize_int8(qr, sr)
    np.testing.assert_allclose(np.asarray(xk), np.asarray(xr),
                               rtol=1e-5, atol=1e-6)


def test_quant_error_bound():
    """|x - dq(q(x))| <= scale/2 per group (half-ulp of the int8 grid)."""
    x = jax.random.normal(KEY, (16, 256)) * 3
    q, s = COMP.quantize_int8(x)
    xd = COMP.dequantize_int8(q, s)
    err = np.abs(np.asarray(x) - np.asarray(xd))
    bound = np.repeat(np.asarray(s), 128, axis=-1) * 0.5 + 1e-7
    assert (err <= bound).all()


# ------------------------------------------------------------------ rmsnorm
@pytest.mark.parametrize("shape", [(8, 256), (2, 33, 512), (1, 7, 960)])
def test_rmsnorm_kernel(shape):
    x = jax.random.normal(KEY, shape)
    g = jax.random.normal(jax.random.PRNGKey(1), shape[-1:]) * 0.1 + 1.0
    out = rmsnorm_k(x, g, interpret=True)
    refo = REF.rmsnorm_ref(x, g)
    np.testing.assert_allclose(np.asarray(out), np.asarray(refo),
                               rtol=2e-5, atol=2e-6)


# --------------------------------------------------------------------- ssd
@pytest.mark.parametrize("s,chunk", [(64, 32), (96, 32), (128, 128), (100, 32)])
def test_ssd_kernel_vs_naive(s, chunk):
    b, h, p, g, n = 2, 4, 32, 2, 16
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (b, s, h, p)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    B = jax.random.normal(ks[3], (b, s, g, n)) * 0.5
    C = jax.random.normal(ks[4], (b, s, g, n)) * 0.5
    yk = ssd_chunk_scan(x, dt, A, B, C, chunk=chunk, interpret=True)
    yn, _ = REF.ssd_naive(x, dt, A, B, C)
    np.testing.assert_allclose(np.asarray(yk), np.asarray(yn),
                               rtol=2e-4, atol=2e-4)


def test_model_ssd_reference_vs_naive():
    """The model's chunked jnp SSD (used in training) is itself validated
    against the literal recurrence."""
    b, s, h, p, g, n = 1, 64, 2, 16, 1, 8
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (b, s, h, p)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    B = jax.random.normal(ks[3], (b, s, g, n)) * 0.5
    C = jax.random.normal(ks[4], (b, s, g, n)) * 0.5
    ym, fm = REF.ssd_ref(x, dt, A, B, C, chunk=16)
    yn, fn = REF.ssd_naive(x, dt, A, B, C)
    np.testing.assert_allclose(np.asarray(ym), np.asarray(yn),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(fm), np.asarray(fn),
                               rtol=2e-4, atol=2e-4)


# -------------------------------------------------------------------- wire
@pytest.mark.parametrize("shape", [(4, 256), (2, 64, 128), (3, 5, 384),
                                   (4, 200), (8, 48)])
@pytest.mark.parametrize("k_frac", [0.1, 0.25, 1.0])
def test_wire_pack_kernel_bit_exact(shape, k_frac):
    """The fused sparsify+quant+pack kernel emits the SAME int32 words as
    the jnp oracle — bitmap, bitcast scale, and value lanes all included
    (exact equality, not allclose)."""
    x = jax.random.normal(KEY, shape) * 5
    buf_k = sparsify_quant_pack(x, k_frac, interpret=True)
    buf_r = COMP.sparsify_quant_pack_ref(x, k_frac)
    np.testing.assert_array_equal(np.asarray(buf_k), np.asarray(buf_r))


@pytest.mark.parametrize("shape,d", [((4, 256), 256), ((2, 64, 128), 128),
                                     ((4, 200), 200), ((8, 48), 48)])
def test_wire_unpack_dequant_kernel_bit_exact(shape, d):
    x = jax.random.normal(KEY, shape) * 5
    buf = COMP.sparsify_quant_pack_ref(x)
    xk = unpack_dequant(buf, d, interpret=True)
    xr = COMP.wire_dequant_ref(buf, d)
    np.testing.assert_array_equal(np.asarray(xk), np.asarray(xr))


@pytest.mark.parametrize("d,n", [(256, 64), (200, 32), (48, 16)])
def test_wire_unpack_matmul_kernel_bit_exact(d, n):
    """Dequant fused into the consuming matmul: the kernel accumulates
    group-by-group in the same order as the oracle, so the fp32 results
    are bit-identical — the dense smashed tensor never materialises."""
    ks = jax.random.split(KEY, 2)
    x = jax.random.normal(ks[0], (16, d)) * 5
    w = jax.random.normal(ks[1], (d, n))
    buf = COMP.sparsify_quant_pack_ref(x)
    ok = unpack_dequant_matmul(buf, w, interpret=True)
    orf = COMP.wire_dequant_matmul_ref(buf, w)
    np.testing.assert_array_equal(np.asarray(ok), np.asarray(orf))


def test_wire_k1_pack_equals_full_quant():
    """k_frac=1.0 keeps every value: the survivors ARE the int8 quantised
    tensor, and the packed scales bit-match ``quantize_int8``'s (the whole
    quant family shares the INV127 multiply form)."""
    x = jax.random.normal(KEY, (4, 256)) * 3
    q_ref, s_ref = COMP.quantize_int8(x)
    q, s, mask = COMP.unpack_wire(COMP.sparsify_quant_pack_ref(x, 1.0), 256,
                                  1.0)
    assert np.asarray(mask).all()
    np.testing.assert_array_equal(np.asarray(q), np.asarray(q_ref))
    np.testing.assert_array_equal(np.asarray(s), np.asarray(s_ref))


# ---------------------------------------------------------------- ops layer
def test_ops_dispatch():
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 64, 2, 64))
    k = jax.random.normal(ks[1], (1, 64, 2, 64))
    v = jax.random.normal(ks[2], (1, 64, 2, 64))
    a = ops.attention(q, k, v, use_kernel=True, block_q=32, block_k=32)
    b = ops.attention(q, k, v, use_kernel=False)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5,
                               atol=2e-5)
    x = jax.random.normal(KEY, (4, 256))
    qq, ss = ops.quantize(x)
    np.testing.assert_allclose(np.asarray(ops.dequantize(qq, ss)),
                               np.asarray(x), atol=0.05)
