"""command-r-35b — dense GQA, no-bias [hf:CohereForAI/c4ai-command-r-v01].

[dense] 40L d_model=8192 64H (GQA kv=8) d_ff=22528 vocab=256000.
Pure full attention -> long_500k skipped.
"""
from repro.configs.base import ATTN, ArchConfig

CONFIG = ArchConfig(
    name="command-r-35b",
    family="dense",
    source="hf:CohereForAI/c4ai-command-r-v01",
    n_layers=40,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=22528,
    vocab_size=256000,
    pattern=(ATTN,),
    mlp_variant="swiglu",
    rope_theta=8_000_000.0,
    default_cut=2,
    param_dtype="bfloat16",
    subquadratic=False,
)
