"""ResNet18 (the paper's case-study model, Fig. 4) in functional JAX.

The stack is expressed as 9 *units* = [stem] + 8 BasicBlocks; the paper's
9 split points are the unit boundaries, and its cut-layer rule (Eq. 3)
selects c in {2,4,6,8}.  ``resnet_forward(params, x, start, end)`` runs units
[start, end) so the same code serves vehicle-side and RSU-side sub-models.

BatchNorm uses batch statistics in both train and eval (common practice in
FL simulations; avoids FedBN running-stat aggregation questions — noted in
DESIGN.md).
"""
from __future__ import annotations

import math
from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp

Params = Dict[str, Any]

N_UNITS = 9          # stem + 8 basic blocks  (the paper's 9 split points)
STAGE_CHANNELS = (64, 64, 128, 128, 256, 256, 512, 512)
STAGE_STRIDES = (1, 1, 2, 1, 2, 1, 2, 1)


def _conv_init(key, kh, kw, cin, cout):
    fan_in = kh * kw * cin
    return jax.random.normal(key, (kh, kw, cin, cout), jnp.float32) * math.sqrt(2.0 / fan_in)


def _conv(x, w, stride=1):
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def _bn_init(c):
    return {"scale": jnp.ones((c,)), "bias": jnp.zeros((c,))}


def _bn(p, x, eps=1e-5):
    mean = jnp.mean(x, axis=(0, 1, 2), keepdims=True)
    var = jnp.var(x, axis=(0, 1, 2), keepdims=True)
    xn = (x - mean) * jax.lax.rsqrt(var + eps)
    return xn * p["scale"] + p["bias"]


def init_resnet18(key, n_classes: int = 10) -> Params:
    ks = list(jax.random.split(key, 2 + 3 * len(STAGE_CHANNELS)))
    units: List[Params] = [{
        "conv": _conv_init(ks[0], 3, 3, 3, 64), "bn": _bn_init(64)}]
    cin = 64
    ki = 1
    for cout, stride in zip(STAGE_CHANNELS, STAGE_STRIDES):
        blk = {
            "conv1": _conv_init(ks[ki], 3, 3, cin, cout), "bn1": _bn_init(cout),
            "conv2": _conv_init(ks[ki + 1], 3, 3, cout, cout), "bn2": _bn_init(cout),
        }
        if stride != 1 or cin != cout:
            blk["proj"] = _conv_init(ks[ki + 2], 1, 1, cin, cout)
            blk["bn_proj"] = _bn_init(cout)
        units.append(blk)
        cin = cout
        ki += 3
    head = {
        "w": jax.random.normal(ks[-1], (512, n_classes)) * math.sqrt(1.0 / 512),
        "b": jnp.zeros((n_classes,)),
    }
    return {"units": units, "head": head}


def _apply_unit(p: Params, x: jnp.ndarray, idx: int) -> jnp.ndarray:
    if idx == 0:
        return jax.nn.relu(_bn(p["bn"], _conv(x, p["conv"], 1)))
    stride = STAGE_STRIDES[idx - 1]
    h = jax.nn.relu(_bn(p["bn1"], _conv(x, p["conv1"], stride)))
    h = _bn(p["bn2"], _conv(h, p["conv2"], 1))
    sc = x
    if "proj" in p:
        sc = _bn(p["bn_proj"], _conv(x, p["proj"], stride))
    return jax.nn.relu(h + sc)


def resnet_forward(params: Params, x: jnp.ndarray,
                   start: int = 0, end: int = N_UNITS) -> jnp.ndarray:
    """Run units [start, end).  x: images (b,32,32,3) if start==0, else the
    smashed activation at split point `start`."""
    for i in range(start, end):
        x = _apply_unit(params["units"][i], x, i)
    return x


def resnet_logits(params: Params, x: jnp.ndarray) -> jnp.ndarray:
    feats = jnp.mean(x, axis=(1, 2))
    return feats @ params["head"]["w"] + params["head"]["b"]


def _hw_at(cut: int) -> int:
    """Spatial size of the activation at split point `cut` (32x32 inputs)."""
    if cut <= 3:
        return 32
    return 32 // (2 ** min((cut - 2) // 2, 3))


def smashed_shape(cut: int, batch: int) -> Tuple[int, ...]:
    """Activation shape at split point `cut` for 32x32 inputs (Fig 5a)."""
    assert 1 <= cut <= N_UNITS
    ch = 64 if cut == 1 else STAGE_CHANNELS[cut - 2]
    hw = _hw_at(cut)
    return (batch, hw, hw, ch)


def unit_flops(idx: int) -> int:
    """Forward matmul FLOPs per sample for unit idx (3x3 convs dominate)."""
    if idx == 0:
        return 2 * 32 * 32 * 3 * 3 * 3 * 64
    cout = STAGE_CHANNELS[idx - 1]
    cin = 64 if idx == 1 else STAGE_CHANNELS[idx - 2]
    stride = STAGE_STRIDES[idx - 1]
    hw_out = _hw_at(idx + 1) if idx < N_UNITS - 1 else 4
    f = 2 * hw_out * hw_out * 3 * 3 * cin * cout          # conv1
    f += 2 * hw_out * hw_out * 3 * 3 * cout * cout        # conv2
    if stride != 1 or cin != cout:
        f += 2 * hw_out * hw_out * cin * cout
    return f


def param_bytes(params: Params, start: int, end: int) -> int:
    units = params["units"][start:end]
    leaves = jax.tree.leaves(units)
    return sum(l.size * 4 for l in leaves)
