"""Device-sharded fleets: a 2-D ``(rsu, vehicle)`` mesh over the
federation's scale axes.

The paper's ASFL scheme targets fleets far beyond what one accelerator can
hold; this module is the partitioning layer that lets the compiled
federation programs (the CohortEngine's round programs and the fused
multi-RSU super-steps, DESIGN.md §6/§8) execute across a device mesh while
staying *the same programs* — ``mesh_devices=1`` (the default) bypasses
every collective and reproduces today's single-device executables exactly.

One 2-D mesh, two axis names (:data:`RSU_AXIS`, :data:`VEH_AXIS`), three
partitionings (DESIGN.md §15):

* ``axis="vehicle"`` — mesh shape ``(1, n)``.  The single-RSU cohort engine
  shards the stacked client-replica (slot) axis of each cut bucket:
  per-vehicle forward/backward passes and optimizer updates are
  shard-local, the shared RSU server state is **replicated** (every shard
  consumes the all-gathered smashed batches in the same canonical order, so
  paper §III-B sequential semantics survive sharding), and the unit-wise
  FedAvg becomes a ``psum``-weighted all-reduce
  (:func:`repro.core.aggregation.sharded_weighted_sum`).
* ``axis="rsu"`` — mesh shape ``(n, 1)``.  The scenario engine shards the
  RSU axis of the fused super-step: each device trains
  ``n_rsus / n_devices`` whole RSU cohorts (per-RSU rounds are independent
  between cloud syncs, so this axis is embarrassingly parallel), and the
  edge→cloud merge all-gathers the edge stack so the weighted reduction
  runs in the *identical order* on every shard — which is what makes the
  sharded K-fused sgd path bit-for-bit equal to the single-device one
  (tests/test_fleet_sharding.py).
* ``axis="grid"`` — mesh shape ``(dr, dv)``, both > 1 allowed.  The
  scenario engine shards the RSU axis ``dr``-way AND each RSU's slot axis
  ``dv``-way simultaneously.  Dense layout: the per-RSU slot tables split
  into RSU-aligned column blocks whose segment-sums come home through an
  order-restoring all-gather over the vehicle sub-axis (bit-for-bit with
  the single-device program); ragged layout: :meth:`FleetMesh.
  balanced_slots` splits the compacted occupied-slot axis over the
  flattened ``(rsu, vehicle)`` grid with psum'd segment partials
  (tolerance-level parity).  The sequential server schedule is a per-RSU
  slot *chain* — inherently serial — so it shards only the RSU axis and
  replicates across the vehicle sub-axis.

Ragged slot sharding (DESIGN.md §12): with ``superstep_layout="ragged"``
and a non-sequential server schedule, the super-step's unit of work is no
longer an RSU row but a slot of the globally compacted occupied-slot axis.
The mesh then splits THAT axis into equal contiguous blocks
(:meth:`FleetMesh.balanced_slots` pads the compacted capacity to a device
multiple): every device carries the same number of *occupied* slots
regardless of how skewed the per-RSU load is, which removes the 256-fleet
sharding inversions where one device trained a crowded cell's whole padded
table while its neighbors trained phantoms.  The per-RSU segment-sums
become psum'd partials and the edge stack replicates — tolerance-level
(not bit-for-bit) parity with the single-device program, asserted in
tests/test_fleet_sharding.py.

Padding rules (DESIGN.md §10/§15): bucket slot counts are padded
pow2-first, then up to the next multiple of the device count; the RSU axis
is padded to an ``rsu``-axis multiple with phantom cells no vehicle can be
served by; under a grid mesh the dense per-RSU capacity additionally pads
to a ``vehicle``-axis multiple (phantom columns).  All paddings are inert —
padded slots carry zero aggregation weight and padded RSUs never
accumulate samples — asserted by the padding-inertness tests.

Data placement: the master :class:`~repro.data.pipeline.StackedClients`
tensors stay **replicated** on the mesh.  Handover moves a vehicle (and the
slot that gathers its rows) between RSUs — and therefore between shards —
every round, so the per-round gathers must be able to reach any vehicle's
shard from any device; what is sharded is everything derived per round
(replica stacks, optimizer moments, batch index slabs), which is where the
O(fleet x params) memory actually lives.

Multi-host (DESIGN.md §15): :func:`maybe_init_distributed` wires
``jax.distributed.initialize`` from the runtime config (coordinator
address / process id / process count) before the first backend touch; the
mesh is then built over the *global* device list (host-local discovery is
jax's — each process contributes its addressable devices), and
:func:`host_fetch` gathers non-addressable shards home so
``RunResult.final_params`` lands as plain host-0 numpy regardless of where
training ran.

CPU note: ``--xla_force_host_platform_device_count=N`` (the same trick
``launch/dryrun.py`` uses) splits the host into N XLA devices for testing
and CI; on a 2-core container this demonstrates partitioning, not speed —
the benchmarks record per-device-count rounds/s honestly.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.data.pipeline import StackedClients

RSU_AXIS = "rsu"                    # leading mesh axis: RSU rows
VEH_AXIS = "vehicle"                # trailing mesh axis: per-RSU slots
ALL_AXES = (RSU_AXIS, VEH_AXIS)     # the flattened device grid
# SimConfig.fleet_axis values ("grid" = both engine axes simultaneously)
FLEET_AXES = ("auto", "vehicle", "rsu", "grid")

# mesh_devices="auto" floor: shard only when every device would own at
# least this many vehicle slots — below it the collective overhead and the
# 2-core CPU floor invert the win (ROADMAP "City-scale scale-out")
AUTO_SLOTS_PER_DEVICE = 64


@dataclasses.dataclass(frozen=True)
class FleetMesh:
    """A 2-D ``(rsu, vehicle)`` device mesh plus which fleet dimension(s)
    it partitions.

    ``axis`` is ``"vehicle"`` (cohort-engine slot axis, mesh ``(1, n)``),
    ``"rsu"`` (super-step RSU axis, mesh ``(n, 1)``) or ``"grid"`` (both
    super-step axes, mesh ``(dr, dv)``).  The mesh axis names are always
    :data:`RSU_AXIS` and :data:`VEH_AXIS`; 1-D configurations are the
    degenerate shapes, so every program traces against the same axis pair.
    """
    mesh: Mesh
    axis: str
    # mesh_devices="auto" provenance (None when the count was explicit):
    # {"requested", "chosen", "floor", "fleet_size", "available"}
    auto_info: Optional[dict] = None

    @property
    def n_devices(self) -> int:
        return self.mesh.size

    @property
    def rsu_devices(self) -> int:
        """Devices along the RSU sub-axis."""
        return self.mesh.shape[RSU_AXIS]

    @property
    def veh_devices(self) -> int:
        """Devices along the vehicle (slot) sub-axis."""
        return self.mesh.shape[VEH_AXIS]

    @property
    def primary(self) -> str:
        """The mesh axis name the *leading* engine axis shards over: the
        RSU axis for scenario meshes (``rsu``/``grid``), the vehicle axis
        for cohort meshes."""
        return VEH_AXIS if self.axis == "vehicle" else RSU_AXIS

    @property
    def primary_devices(self) -> int:
        return self.mesh.shape[self.primary]

    # ---- padding ------------------------------------------------------
    def pad(self, n: int) -> int:
        """Smallest multiple of the PRIMARY axis device count >= max(n, 1)
        — the padding rule for the engine's leading axis (RSU rows for
        scenario meshes, cohort slots for vehicle meshes)."""
        d = self.primary_devices
        return ((max(int(n), 1) + d - 1) // d) * d

    def pad_slots(self, n: int) -> int:
        """Smallest multiple of the VEHICLE sub-axis device count
        >= max(n, 1): the dense per-RSU slot capacity must split evenly
        into the grid mesh's column blocks (phantom columns are inert)."""
        d = self.veh_devices
        return ((max(int(n), 1) + d - 1) // d) * d

    def balanced_slots(self, n_slots: int) -> int:
        """Occupancy-balanced capacity of the ragged super-step's compacted
        slot axis (module docstring; DESIGN.md §12): the axis counts
        OCCUPIED slots fleet-wide, so padding it to a multiple of the WHOLE
        device grid and splitting contiguously gives every device an equal
        share of real work even under fully skewed per-RSU load — unlike
        padded per-RSU tables, whose shards inherit the load imbalance."""
        d = self.n_devices
        return ((max(int(n_slots), 1) + d - 1) // d) * d

    # ---- shardings ----------------------------------------------------
    def leading_sharding(self) -> NamedSharding:
        """Leading axis split over the primary mesh axis, everything else
        replicated (including over the other mesh axis)."""
        return NamedSharding(self.mesh, P(self.primary))

    def slot_sharding(self) -> NamedSharding:
        """Leading (flat slot) axis split over the WHOLE device grid — the
        ragged compacted axis placement."""
        return NamedSharding(self.mesh, P(ALL_AXES))

    def replicated_sharding(self) -> NamedSharding:
        return NamedSharding(self.mesh, P())

    # ---- placement ----------------------------------------------------
    def _put(self, a: Any, s: NamedSharding) -> jax.Array:
        """Place one host array under ``s``.  Single-process: plain
        ``device_put``.  Multi-process: every host holds the full array
        (the engines stage identical host state everywhere), so build the
        global array from each process's addressable shards — collective-
        free, unlike ``device_put`` on a cross-process sharding, whose
        implicit equality check broadcasts every leaf through the CPU
        collectives layer (and trips gloo's in-order message matching)."""
        if jax.process_count() > 1:
            arr = np.asarray(a)
            return jax.make_array_from_callback(
                arr.shape, s, lambda idx: arr[idx])
        return jax.device_put(a, s)

    def shard_leading(self, tree: Any) -> Any:
        """Place every leaf with its leading axis split over the primary
        mesh axis (leaf leading dims must be :meth:`pad` multiples)."""
        s = self.leading_sharding()
        return jax.tree.map(lambda a: self._put(a, s), tree)

    def replicate(self, tree: Any) -> Any:
        """Place every leaf fully replicated on the mesh."""
        s = self.replicated_sharding()
        return jax.tree.map(lambda a: self._put(a, s), tree)

    def place_stacked(self, stacked: StackedClients) -> StackedClients:
        """The master client tensors, replicated on the mesh (see module
        docstring for why they cannot shard by vehicle: handover makes the
        per-round gather pattern cross-shard by design)."""
        return StackedClients(
            images=self._put(stacked.images, self.replicated_sharding()),
            labels=self._put(stacked.labels, self.replicated_sharding()),
            lengths=stacked.lengths)


def resolve_axis(fleet_axis: str, engine_kind: str) -> str:
    """``"auto"`` -> the engine's natural partitioning: RSU axis for the
    multi-RSU scenario engine, vehicle axis for the single-RSU cohort
    engine."""
    if fleet_axis == "auto":
        return "rsu" if engine_kind == "scenario" else "vehicle"
    return fleet_axis


def grid_shape(n_devices: int) -> Tuple[int, int]:
    """Default ``(dr, dv)`` factorization of a grid mesh: the vehicle
    sub-axis takes the largest power of two <= sqrt(n) that divides n
    (dense capacities pad to ``dv`` — keeping it small keeps phantom
    columns rare), the RSU axis takes the rest."""
    n = int(n_devices)
    dv = 1
    while dv * 2 <= n and n % (dv * 2) == 0 and (dv * 2) ** 2 <= n:
        dv *= 2
    return n // dv, dv


def parse_shape_spec(spec) -> Optional[Tuple[int, int]]:
    """Syntax-only ``mesh_shape`` validation: ``"auto"`` -> None, ``"RxV"``
    (e.g. ``"4x2"``) -> ``(dr, dv)``.  Device-count consistency is checked
    at mesh-build time (:func:`parse_mesh_shape`) — config construction
    must not depend on how many devices this process happens to see."""
    if spec in (None, "", "auto"):
        return None
    try:
        dr, dv = (int(p) for p in str(spec).lower().split("x"))
    except ValueError:
        raise ValueError(f"mesh_shape must be 'auto' or 'RxV' (e.g. '4x2'),"
                         f" got {spec!r}") from None
    if dr < 1 or dv < 1:
        raise ValueError(f"mesh_shape={spec!r} must have both factors >= 1")
    return dr, dv


def parse_mesh_shape(spec: str, n_devices: int, axis: str) -> Tuple[int, int]:
    """``mesh_shape`` -> ``(dr, dv)``.  ``"auto"`` places all devices on
    the resolved engine axis (``grid`` axis: :func:`grid_shape`); an
    explicit ``"RxV"`` (e.g. ``"4x2"``) must multiply to ``n_devices``."""
    parsed = parse_shape_spec(spec)
    if parsed is None:
        if axis == "vehicle":
            return 1, n_devices
        if axis == "rsu":
            return n_devices, 1
        return grid_shape(n_devices)
    dr, dv = parsed
    if dr * dv != n_devices:
        raise ValueError(
            f"mesh_shape={spec!r} asks for {dr}x{dv}={dr * dv} devices but "
            f"mesh_devices={n_devices}")
    return dr, dv


def build_fleet_mesh(n_devices: int, axis: str,
                     devices: Optional[list] = None,
                     shape: Optional[Tuple[int, int]] = None,
                     auto_info: Optional[dict] = None) -> FleetMesh:
    """A :class:`FleetMesh` over the first ``n_devices`` devices.

    Raises with the ``--xla_force_host_platform_device_count`` recipe when
    the process has fewer devices than requested (on CPU the flag must be
    set *before* jax initialises its backend — benchmarks set it from the
    ``--devices`` flag before importing jax).  Under multi-host
    ``jax.distributed`` the default device list is the GLOBAL one, so the
    mesh spans every process's addressable devices."""
    if axis not in ("vehicle", "rsu", "grid"):
        raise ValueError(f"fleet mesh axis must be 'vehicle', 'rsu' or "
                         f"'grid', got {axis!r}")
    devs = list(devices if devices is not None else jax.devices())
    if n_devices < 1:
        raise ValueError(f"mesh_devices={n_devices!r} must be >= 1")
    if n_devices > len(devs):
        raise RuntimeError(
            f"mesh_devices={n_devices} but only {len(devs)} device(s) are "
            f"visible; on CPU set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={n_devices} "
            f"before the first jax import (launch/dryrun.py and the "
            f"benchmark --devices flag do exactly this)")
    dr, dv = shape if shape is not None \
        else parse_mesh_shape("auto", n_devices, axis)
    if dr * dv != n_devices:
        raise ValueError(f"mesh shape {dr}x{dv} != mesh_devices={n_devices}")
    if axis == "vehicle" and dr != 1:
        raise ValueError(f"axis='vehicle' requires a (1, n) mesh, "
                         f"got {dr}x{dv}")
    if axis == "rsu" and dv != 1:
        raise ValueError(f"axis='rsu' requires a (n, 1) mesh, got {dr}x{dv}")
    grid = np.asarray(devs[:n_devices]).reshape(dr, dv)
    return FleetMesh(Mesh(grid, ALL_AXES), axis, auto_info)


def resolve_mesh_devices(requested, fleet_size: Optional[int] = None,
                         available: Optional[int] = None):
    """``mesh_devices`` -> ``(n_devices, info)``.

    ``"auto"`` picks the largest power of two <= the available device count
    that keeps >= :data:`AUTO_SLOTS_PER_DEVICE` vehicles per device — small
    fleets stay on one device and never pay the sharding tax that inverts
    the 256-fleet rows on the 2-core CPU floor.  ``info`` records the
    decision for ``RunResult.diagnostics`` (None for explicit counts)."""
    if requested != "auto":
        return max(int(requested or 1), 1), None
    avail = int(available if available is not None else len(jax.devices()))
    fleet = int(fleet_size) if fleet_size else 0
    n = 1
    while (n * 2 <= avail
           and fleet // (n * 2) >= AUTO_SLOTS_PER_DEVICE):
        n *= 2
    info = {"requested": "auto", "chosen": n,
            "floor": AUTO_SLOTS_PER_DEVICE,
            "fleet_size": fleet, "available": avail}
    return n, info


def from_config(cfg, engine_kind: str,
                fleet_size: Optional[int] = None) -> Optional[FleetMesh]:
    """The mesh a :class:`~repro.core.fedsim.SimConfig` asks for — ``None``
    when it resolves to one device (the default single-device path, which
    must stay bit-identical to the pre-mesh engines and therefore never
    wraps anything in ``shard_map``).  ``fleet_size`` feeds the
    ``mesh_devices="auto"`` occupied-slots-per-device floor."""
    n, info = resolve_mesh_devices(getattr(cfg, "mesh_devices", 1) or 1,
                                   fleet_size)
    if n <= 1:
        return None
    axis = resolve_axis(cfg.fleet_axis, engine_kind)
    shape = parse_mesh_shape(getattr(cfg, "mesh_shape", "auto"), n, axis)
    return build_fleet_mesh(n, axis, shape=shape, auto_info=info)


def maybe_init_distributed(coordinator_address: Optional[str],
                           num_processes: int = 1,
                           process_id: int = 0) -> bool:
    """Initialize ``jax.distributed`` for multi-host meshes (no-op for the
    single-process default, and idempotent: re-entry with an already-live
    runtime is ignored so repeated ``build_engine`` calls in one process
    stay cheap).  Returns True when this call (or a previous one)
    initialized the runtime."""
    if num_processes <= 1 or not coordinator_address:
        return False
    from jax._src import distributed as _dist   # no public state accessor
    if getattr(_dist.global_state, "client", None) is not None:
        return True                 # already initialized (idempotent)
    try:
        # XLA:CPU builds its client without cross-process collectives by
        # default ("Multiprocess computations aren't implemented on the
        # CPU backend"); the gloo implementation must be selected BEFORE
        # the first backend touch.  The flag ignores its env var on this
        # jax, so set it programmatically; only make_cpu_client reads it,
        # so accelerator backends are unaffected.
        if jax.config.read("jax_cpu_collectives_implementation") == "none":
            jax.config.update("jax_cpu_collectives_implementation", "gloo")
        # gloo matches messages by posting order per TCP pair: async CPU
        # dispatch lets concurrently-executing programs interleave their
        # collectives differently per process, which gloo rejects with a
        # preamble-length mismatch.  Lockstep dispatch is the documented
        # multi-process CPU mode.
        jax.config.update("jax_cpu_enable_async_dispatch", False)
    except AttributeError:          # options absent on this jax version
        pass
    jax.distributed.initialize(coordinator_address=coordinator_address,
                               num_processes=int(num_processes),
                               process_id=int(process_id))
    return True


def host_fetch(tree: Any) -> Any:
    """Pull a (possibly mesh-sharded) pytree to host numpy arrays — the
    runner calls this on ``RunResult.final_params`` so results survive the
    mesh (and serialize) regardless of where training ran.  Under
    multi-host meshes, shards another process owns come home through an
    all-gather so every host (host 0 included) sees the full array."""
    def fetch(a):
        if isinstance(a, jax.Array) and not a.is_fully_addressable:
            from jax.experimental import multihost_utils
            return np.asarray(multihost_utils.process_allgather(
                a, tiled=True))
        return np.asarray(a)

    return jax.tree.map(fetch, tree)


def _flat_device_index(axes: Sequence[str]):
    """This device's rank in the row-major flattening of ``axes``."""
    idx = jax.lax.axis_index(axes[0])
    for a in axes[1:]:
        idx = idx * jax.lax.psum(1, a) + jax.lax.axis_index(a)
    return idx


def local_slice(x: jnp.ndarray, n_local: int, axis: int = 0,
                axes: Sequence[str] = ALL_AXES) -> jnp.ndarray:
    """Inside ``shard_map``: this shard's contiguous block of a replicated
    array whose logical leading axis is split ``n_local`` per device over
    ``axes`` (default: the whole device grid; pass ``(RSU_AXIS,)`` for
    RSU-row blocks that replicate across the vehicle sub-axis)."""
    start = _flat_device_index(axes) * n_local
    return jax.lax.dynamic_slice_in_dim(x, start, n_local, axis=axis)


def local_block2d(x: jnp.ndarray, r_local: int,
                  c_local: int) -> jnp.ndarray:
    """Inside ``shard_map``: this device's ``(r_local, c_local)`` tile of a
    replicated 2-D table whose rows split over the RSU axis and columns
    over the vehicle axis — the dense grid-mesh slot-table partitioning."""
    r0 = jax.lax.axis_index(RSU_AXIS) * r_local
    c0 = jax.lax.axis_index(VEH_AXIS) * c_local
    return jax.lax.dynamic_slice(x, (r0, c0), (r_local, c_local))


def scalar_allsum(x: jnp.ndarray,
                  axes: Sequence[str] = ALL_AXES) -> jnp.ndarray:
    """Inside ``shard_map``: sum a shard-local scalar (a telemetry total
    reduced from sharded per-RSU state — staleness-bank weight, stream-
    buffer occupancy/absorption) home across the mesh.  Scalars carry no
    reduction-order contract, so a plain psum is the right tool here — the
    bit-for-bit gather-then-reduce discipline applies to model planes, not
    counters.  Pass ``(RSU_AXIS,)`` when the value is replicated across the
    vehicle sub-axis (a psum over a replicated axis would multiply it)."""
    return jax.lax.psum(x, tuple(axes))
