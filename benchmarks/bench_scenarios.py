"""Scenario-layer benchmark: rounds/s per mobility scenario at fleet scale.

Runs the multi-RSU fused super-step engine (DESIGN.md §8) through the
declarative front door: every row is one ``repro.api.run(ExperimentSpec)``
call with ``timeit=True`` — AOT ``precompile()`` + a warmup run, a reset,
then the timed compile-free re-run.  ``--superstep K`` fuses K rounds into
one ``lax.scan`` dispatch with donated carries; ``--compilation-cache DIR``
wires JAX's persistent compilation cache so a second invocation skips XLA
entirely (the ``compile_cache_hit`` key records whether this run started
warm).  The ``api_overhead_s`` key compares the API-routed per-round time
against a direct ``ScenarioEngine`` call at fleet 64 — the front door adds
no measurable per-round cost.

  PYTHONPATH=src python benchmarks/bench_scenarios.py
  -> BENCH_scenarios.json (repo root) + benchmarks/out/BENCH_scenarios.json

``--check-baseline BASELINE.json [--max-regress 0.30]`` compares this run's
rounds/s against a committed baseline and exits non-zero on a >30%
regression (the CI perf smoke); rows missing from the baseline are skipped
gracefully.
"""
from __future__ import annotations

import argparse
import gc
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

from bench_devices import parse_devices_early

# --devices N[,M,...]: per-device-count rows; the host device count must be
# forced BEFORE the first jax import (jax locks it on backend init)
DEVICE_COUNTS = parse_devices_early()

import jax
import numpy as np

from bench_io import device_row_key, write_bench
from bench_timing import interleaved_overhead
from repro import api
from repro.configs.base import cache_dir_is_warm
from repro.core.fedsim import ScenarioEngine


def _spec(name: str, n: int, args, devices: int = 1,
          fault_dropout: float = None,
          fault_upload_loss: float = None) -> api.ExperimentSpec:
    fd = args.fault_dropout if fault_dropout is None else fault_dropout
    fu = args.fault_upload_loss if fault_upload_loss is None else fault_upload_loss
    return api.ExperimentSpec(
        model="mlp9",
        train=api.TrainConfig(scheme="asfl", rounds=args.rounds,
                              local_steps=args.local_steps,
                              batch_size=args.batch, lr=1e-3, eval_every=0,
                              server_schedule=args.schedule,
                              wire=args.wire, wire_k=args.wire_k),
        faults=api.FaultsConfig(dropout_rate=fd, upload_loss_rate=fu,
                                seed=args.fault_seed),
        adaptive=api.AdaptiveConfig(strategy=args.strategy),
        fleet=api.FleetConfig(n_vehicles=n, scenario=name,
                              scenario_kwargs={"seed": n},
                              cloud_sync_every=args.sync,
                              round_interval_s=10.0,
                              per_vehicle_samples=64, data_seed=n),
        runtime=api.RuntimeConfig(superstep=args.superstep,
                                  slot_capacity=args.slot_capacity,
                                  superstep_layout=args.layout,
                                  precompile=True,
                                  mesh_devices=devices,
                                  compilation_cache_dir=args.compilation_cache))


def bench_one(name: str, n: int, args, devices: int = 1,
              fault_dropout: float = None,
              fault_upload_loss: float = None) -> dict:
    spec = _spec(name, n, args, devices, fault_dropout, fault_upload_loss)
    res = api.run(spec, timeit=args.timeit)
    assert all(np.isfinite(m.loss) for m in res.history)
    # zero retraces even under fault churn (DESIGN.md §13): fault masks are
    # data on the carry, never part of a program signature
    assert res.diagnostics["compile_fallbacks"] == 0
    occ = res.diagnostics["occupancy"]
    row = {
        "scenario": name, "n_vehicles": n, "devices": devices,
        # fault plane: rates + robustness telemetry (zero-fault rows report
        # the trivial values, keeping the row schema uniform)
        "fault_dropout": spec.faults.dropout_rate,
        "fault_upload_loss": spec.faults.upload_loss_rate,
        "survivor_frac": res.totals["survivor_frac"],
        "lost_update_bytes": res.totals["lost_update_bytes"],
        "n_dropout": res.totals["n_dropout"],
        "n_upload_lost": res.totals["n_upload_lost"],
        "n_rsus": res.diagnostics["n_rsus"],
        "mode": res.diagnostics["mode"], "schedule": args.schedule,
        "superstep": args.superstep, "rounds": args.rounds,
        "superstep_layout": res.diagnostics["superstep_layout"],
        "round_s": res.timing["round_s"],
        "rounds_per_s": res.timing["rounds_per_s"],
        "warmup_s": res.timing["warmup_s"],
        # occupancy accounting (DESIGN.md §12): how much of the executed
        # slot table / parameter plane was real work
        "padded_slot_frac": occ["padded_slot_frac"],
        "owned_plane_frac": occ["owned_plane_frac"],
        "effective_flops_utilization": occ["effective_flops_utilization"],
        "scheduled_per_round": [m.n_scheduled for m in res.history],
        "handovers": int(sum(m.n_handover for m in res.history)),
        "final_loss": float(res.history[-1].loss),
    }
    if "staleness_hist" in res.diagnostics:
        row["staleness_hist"] = res.diagnostics["staleness_hist"]
    return row


def measure_api_overhead(args, fleet: int = 64,
                         scenario: str = "highway_corridor",
                         repeats: int = 3) -> dict:
    """Per-round cost of the front door: an engine built by
    ``api.build_engine(spec)`` and driven exactly as ``api.run`` drives it
    vs a hand-constructed ScenarioEngine with the same model, data,
    scenario, and config.  Both AOT-precompile and warm up once, then
    timed re-runs INTERLEAVE (min wins per side) so container scheduler
    drift hits both sides equally instead of masquerading as overhead."""
    spec = _spec(scenario, fleet, args)
    api_eng = api.build_engine(spec)
    entry = api.model_entry(spec.model)
    f = spec.fleet
    clients, test = entry.make_data(f.n_vehicles, f.per_vehicle_samples,
                                    f.test_samples, f.data_seed)
    sc = api.build_scenario(f.scenario, f.n_vehicles, **f.scenario_kwargs)
    direct = ScenarioEngine(entry.build(), clients, test,
                            spec.to_sim_config(), sc,
                            cloud_sync_every=f.cloud_sync_every)
    api_eng.precompile()
    direct.precompile()
    out = interleaved_overhead(
        (api_eng, lambda: api_eng.run(on_round=None, on_cloud_merge=None)),
        (direct, direct.run), repeats)
    return {"fleet": fleet, "scenario": scenario, **out}


def check_baseline(out: dict, baseline_path: str, max_regress: float) -> int:
    """Exit status for the CI perf smoke: 1 if any matching row's rounds/s
    dropped more than ``max_regress`` below the baseline."""
    if not os.path.exists(baseline_path):
        print(f"baseline {baseline_path} missing; skipping perf check")
        return 0
    with open(baseline_path) as f:
        base = json.load(f)
    # rounds/s is only comparable when the per-round work matches: skip
    # (don't spuriously fail) if the bench config drifted from the
    # committed baseline's — that means the baseline needs regenerating
    keys = ("local_steps", "batch", "strategy", "cloud_sync_every",
            "superstep", "schedule", "slot_capacity", "wire",
            "superstep_layout", "fault_dropout", "fault_upload_loss")
    mismatch = {k: (base.get("config", {}).get(k), out["config"].get(k))
                for k in keys
                if base.get("config", {}).get(k) != out["config"].get(k)}
    if mismatch:
        print(f"baseline config mismatch {mismatch}; skipping perf check "
              f"(regenerate {baseline_path})")
        return 0
    def _perf_key(r):
        # the chaos row times different work than its zero-fault twin —
        # give it its own baseline slot
        faulted = bool(r.get("fault_dropout") or r.get("fault_upload_loss"))
        return (r["scenario"], r["n_vehicles"], r.get("devices", 1),
                "faulted" if faulted else "clean")

    base_rows = {_perf_key(r): r["rounds_per_s"]
                 for r in base.get("results", [])}
    failures = []
    for row in out["results"]:
        key = _perf_key(row)
        if key not in base_rows:
            print(f"no baseline row for {key}; skipping")
            continue
        floor = base_rows[key] * (1.0 - max_regress)
        status = "OK" if row["rounds_per_s"] >= floor else "REGRESSION"
        print(f"perf {key}: {row['rounds_per_s']:.2f} r/s vs baseline "
              f"{base_rows[key]:.2f} (floor {floor:.2f}) {status}")
        if row["rounds_per_s"] < floor:
            failures.append(key)
    if failures:
        print(f"perf regression >{max_regress:.0%} in rows: {failures}")
        return 1
    return 0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--sizes", default="64,256")
    ap.add_argument("--scenarios",
                    default=",".join(sorted(n for n, b in api.SCENARIOS.items()
                                            if b is not None)))
    ap.add_argument("--rounds", type=int, default=4)
    ap.add_argument("--local-steps", type=int, default=2)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--strategy", default="paper",
                    help="cut strategy (paper | residence | ...)")
    ap.add_argument("--sync", type=int, default=1)
    ap.add_argument("--superstep", type=int, default=8,
                    help="rounds fused per dispatch (1 = per-round); the "
                         "default benchmarks the engine's recommended "
                         "fused operating point")
    ap.add_argument("--schedule", default="sequential",
                    choices=sorted(api.SCHEDULES))
    ap.add_argument("--slot-capacity", default="tight8",
                    choices=["pow2", "tight8"])
    ap.add_argument("--layout", default="ragged",
                    choices=["ragged", "dense"],
                    help="super-step slot layout (DESIGN.md §12): ragged "
                         "compacts occupied slots + cut-prefix planes")
    ap.add_argument("--wire", default="none", choices=sorted(api.WIRES),
                    help="cut-boundary wire scheme (kernels/wire.py)")
    ap.add_argument("--wire-k", type=float, default=0.25,
                    help="topk_int8 keep fraction per group")
    ap.add_argument("--fault-dropout", type=float, default=0.0,
                    help="P[vehicle drops mid-round] applied to EVERY row "
                         "(core/faults.py; 0 = clean rows + one dedicated "
                         "chaos row)")
    ap.add_argument("--fault-upload-loss", type=float, default=0.0,
                    help="P[update lost after full local work], every row")
    ap.add_argument("--fault-seed", type=int, default=0)
    ap.add_argument("--no-fault-row", action="store_true",
                    help="skip the dedicated seeded-chaos row (dropout 0.2 "
                         "+ upload loss 0.1 on the first scenario) that the "
                         "CI perf gate tracks")
    ap.add_argument("--compilation-cache", default=None, metavar="DIR",
                    help="persistent XLA compilation cache directory")
    ap.add_argument("--devices", default="1", metavar="N[,M...]",
                    help="device counts to bench (RSU-axis mesh rows; on "
                         "CPU the host device count is forced pre-import "
                         "— parsed by bench_devices before jax loads)")
    ap.add_argument("--timeit", type=int, default=3,
                    help="timed compile-free re-runs per row (min wins); "
                         ">1 strips scheduler noise on small containers")
    ap.add_argument("--check-baseline", default=None, metavar="JSON",
                    help="compare rounds/s against a committed baseline")
    ap.add_argument("--max-regress", type=float, default=0.30)
    ap.add_argument("--skip-api-overhead", action="store_true",
                    help="skip the api-vs-direct overhead measurement")
    ap.add_argument("--no-write", action="store_true",
                    help="don't overwrite BENCH_scenarios.json")
    args = ap.parse_args()

    cache_hit = cache_dir_is_warm(args.compilation_cache)
    results = []
    for devices in DEVICE_COUNTS:
        for name in args.scenarios.split(","):
            for n in (int(s) for s in args.sizes.split(",")):
                # drop the previous row's engine, staged data, and compiled
                # programs before timing: later rows must not inherit the
                # sweep's accumulated memory pressure (2-core containers)
                gc.collect()
                row = bench_one(name, n, args, devices)
                results.append(row)
                print(f"{name:17s} n={n:4d} dev={devices} "
                      f"rsus={row['n_rsus']} "
                      f"mode={row['mode']:12s} K={args.superstep} "
                      f"warmup={row['warmup_s']:6.1f}s "
                      f"round={row['round_s']*1e3:9.1f} ms "
                      f"({row['rounds_per_s']:.2f} rounds/s) "
                      f"handovers={row['handovers']}", flush=True)

    if (args.fault_dropout == 0.0 and args.fault_upload_loss == 0.0
            and not args.no_fault_row):
        # dedicated chaos row (DESIGN.md §13): seeded 20% dropout + 10%
        # upload loss on the first scenario at the smallest fleet, so the
        # perf gate tracks the survivor-weighted merge path too
        name = args.scenarios.split(",")[0]
        n = min(int(s) for s in args.sizes.split(","))
        gc.collect()
        row = bench_one(name, n, args, DEVICE_COUNTS[0],
                        fault_dropout=0.2, fault_upload_loss=0.1)
        results.append(row)
        print(f"{name:17s} n={n:4d} CHAOS drop=0.20 loss=0.10 "
              f"survivor_frac={row['survivor_frac']:.2f} "
              f"lost={row['lost_update_bytes']/1e6:.2f} MB "
              f"round={row['round_s']*1e3:9.1f} ms "
              f"({row['rounds_per_s']:.2f} rounds/s)", flush=True)

    api_overhead = None
    if not args.skip_api_overhead:
        fleet = (64 if 64 in [int(s) for s in args.sizes.split(",")]
                 else max(int(s) for s in args.sizes.split(",")))
        api_overhead = measure_api_overhead(args, fleet=fleet)
        print(f"api overhead @ fleet {fleet}: "
              f"{api_overhead['api_overhead_s']*1e3:+.2f} ms/round "
              f"(api {api_overhead['api_round_s']*1e3:.1f} vs direct "
              f"{api_overhead['direct_round_s']*1e3:.1f})", flush=True)

    def row_key(r):
        key = device_row_key(f"{r['scenario']}@{r['n_vehicles']}",
                             r["devices"])
        if r.get("fault_dropout") or r.get("fault_upload_loss"):
            key += "+faults"
        return key

    out = {
        "config": {"local_steps": args.local_steps, "batch": args.batch,
                   "rounds": args.rounds, "strategy": args.strategy,
                   "cloud_sync_every": args.sync,
                   "superstep": args.superstep, "schedule": args.schedule,
                   "slot_capacity": args.slot_capacity,
                   "superstep_layout": args.layout,
                   "timeit": args.timeit,
                   "wire": args.wire, "wire_k": args.wire_k,
                   "fault_dropout": args.fault_dropout,
                   "fault_upload_loss": args.fault_upload_loss,
                   "devices": list(DEVICE_COUNTS),
                   "compilation_cache": args.compilation_cache,
                   "backend": jax.default_backend(),
                   "driver": "repro.api.run"},
        "warmup_total_s": float(sum(r["warmup_s"] for r in results)),
        "compile_cache_hit": cache_hit,
        "rounds_per_s": {row_key(r): r["rounds_per_s"] for r in results},
        "api_overhead_s": (api_overhead["api_overhead_s"]
                           if api_overhead else None),
        "api_overhead": api_overhead,
        "results": results,
    }
    if not args.no_write:
        write_bench("BENCH_scenarios", out, "benchmarks/bench_scenarios.py")
        print(f"(warmup_total_s={out['warmup_total_s']:.1f}, "
              f"cache_hit={cache_hit})")

    if args.check_baseline:
        sys.exit(check_baseline(out, args.check_baseline, args.max_regress))


if __name__ == "__main__":
    main()
