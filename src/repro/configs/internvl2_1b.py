"""internvl2-1b — InternViT + InternLM2 VLM backbone [arXiv:2404.16821].

[vlm] 24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151655.
The ViT/SigLIP vision encoder + projector is a STUB frontend: ``input_specs``
provides precomputed patch embeddings of shape (batch, n_patches, d_model)
which are prepended to the text embeddings (the InternVL2 interleave).
Pure full attention -> long_500k skipped (see DESIGN.md §4).
"""
from repro.configs.base import ATTN, ArchConfig

CONFIG = ArchConfig(
    name="internvl2-1b",
    family="vlm",
    source="arXiv:2404.16821",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    head_dim=64,
    d_ff=4864,
    vocab_size=151655,
    pattern=(ATTN,),
    mlp_variant="swiglu",
    rope_theta=1_000_000.0,
    frontend="vision",
    n_patches=256,
    default_cut=4,
    subquadratic=False,
)
