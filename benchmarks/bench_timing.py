"""Shared timing protocol for the ``api_overhead_s`` measurements.

Both benchmark drivers compare an engine built by ``api.build_engine``
(driven exactly as ``api.run`` drives it) against a hand-constructed engine
with the same model/data/config.  The timed re-runs INTERLEAVE (api,
direct, api, direct, ...; min wins per side) so container scheduler drift
hits both sides equally instead of masquerading as front-door overhead.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, Tuple


def interleaved_overhead(api_pair: Tuple[object, Callable],
                         direct_pair: Tuple[object, Callable],
                         repeats: int = 3) -> Dict[str, float]:
    """``(engine, drive)`` pairs for the api-built and direct engines.
    Drives each once to warm (callers AOT-precompile beforehand where
    applicable), then ``repeats`` interleaved timed re-runs with
    ``engine.reset()`` between.  Returns per-round seconds for both sides
    and their difference."""
    sides = {"api": api_pair, "direct": direct_pair}
    for _, drive in sides.values():
        drive()                                # warmup (compiles / staging)
    best: Dict[str, float] = {}
    rounds = 1
    for _ in range(repeats):
        for name, (engine, drive) in sides.items():
            engine.reset()
            t0 = time.perf_counter()
            hist = drive()
            dt = time.perf_counter() - t0
            rounds = len(hist)
            best[name] = min(best.get(name, dt), dt)
    api_s = best["api"] / rounds
    direct_s = best["direct"] / rounds
    return {"rounds": rounds, "timed_repeats": repeats,
            "api_round_s": api_s, "direct_round_s": direct_s,
            "api_overhead_s": api_s - direct_s}
