from repro.optim.optimizers import (  # noqa: F401
    Optimizer, from_name, sgd, momentum, adam, adamw, apply_updates,
    global_norm, clip_by_global_norm)
from repro.optim.schedules import (  # noqa: F401
    constant, cosine_decay, warmup_cosine, linear_warmup)
