"""Chaos benchmark: accuracy vs dropout under the fault plane (DESIGN.md §13).

Sweeps the seeded mid-round dropout rate over ``--dropouts`` (default
0, 0.1, 0.2, 0.4) on the multi-RSU fused super-step engine and reports, per
rate, the accuracy the survivor-weighted merges reach plus the robustness
telemetry the fault plane exposes: effective participation
(``survivor_frac``), the update mass that never merged
(``lost_update_bytes``), and the per-process failure counts.  With
``--straggler-factor > 0`` the staleness bank engages and the row gains the
run's staleness histogram.

Every row is one ``repro.api.run(ExperimentSpec)`` call — same front door,
same engines, same compiled programs as the clean benchmarks; the dropout
rate is the ONLY thing that varies, so the curve isolates what failures
cost the model, not what they cost the harness.  Each row asserts
``compile_fallbacks == 0``: fault churn is carried data, never a program
signature, so the chaos sweep compiles exactly as often as a clean run.

  PYTHONPATH=src python benchmarks/bench_faults.py
  -> BENCH_faults.json (repo root) + benchmarks/out/BENCH_faults.json
"""
from __future__ import annotations

import argparse
import gc
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

from bench_devices import parse_devices_early

# --devices N[,M,...]: per-device-count rows; the host device count must be
# forced BEFORE the first jax import (jax locks it on backend init)
DEVICE_COUNTS = parse_devices_early()

import jax
import numpy as np

from bench_io import write_bench
from repro import api


def _spec(args, dropout: float, devices: int = 1) -> api.ExperimentSpec:
    return api.ExperimentSpec(
        model="mlp9",
        train=api.TrainConfig(scheme="asfl", rounds=args.rounds,
                              local_steps=args.local_steps,
                              batch_size=args.batch, lr=1e-3,
                              eval_every=1,
                              server_schedule=args.schedule),
        faults=api.FaultsConfig(dropout_rate=dropout,
                                upload_loss_rate=args.upload_loss,
                                straggler_factor=args.straggler_factor,
                                rsu_outage_rate=args.rsu_outage,
                                seed=args.fault_seed),
        adaptive=api.AdaptiveConfig(strategy=args.strategy),
        fleet=api.FleetConfig(n_vehicles=args.fleet, scenario=args.scenario,
                              scenario_kwargs={"seed": args.fleet},
                              cloud_sync_every=1, round_interval_s=10.0,
                              per_vehicle_samples=64, data_seed=args.fleet),
        runtime=api.RuntimeConfig(superstep=args.superstep, precompile=True,
                                  mesh_devices=devices))


def bench_one(args, dropout: float, devices: int = 1) -> dict:
    res = api.run(_spec(args, dropout, devices), timeit=args.timeit)
    assert all(np.isfinite(m.loss) for m in res.history)
    assert res.diagnostics["compile_fallbacks"] == 0
    accs = [m.test_acc for m in res.history if np.isfinite(m.test_acc)]
    row = {
        "dropout": dropout, "devices": devices,
        "upload_loss": args.upload_loss,
        "straggler_factor": args.straggler_factor,
        "rsu_outage": args.rsu_outage,
        "final_acc": float(accs[-1]) if accs else float("nan"),
        "final_loss": float(res.history[-1].loss),
        # robustness telemetry (DESIGN.md §13)
        "survivor_frac": res.totals["survivor_frac"],
        "lost_update_bytes": res.totals["lost_update_bytes"],
        "n_dropout": res.totals["n_dropout"],
        "n_upload_lost": res.totals["n_upload_lost"],
        "n_straggler": res.totals["n_straggler"],
        "round_s": res.timing["round_s"],
        "rounds_per_s": res.timing["rounds_per_s"],
    }
    if "staleness_hist" in res.diagnostics:
        row["staleness_hist"] = res.diagnostics["staleness_hist"]
    return row


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dropouts", default="0,0.1,0.2,0.4",
                    help="mid-round dropout rates to sweep")
    ap.add_argument("--upload-loss", type=float, default=0.0,
                    help="P[update lost after full local work], every row")
    ap.add_argument("--straggler-factor", type=float, default=0.0,
                    help=">0 engages the staleness bank (deadline = factor "
                         "x residence)")
    ap.add_argument("--rsu-outage", type=float, default=0.0)
    ap.add_argument("--fault-seed", type=int, default=0)
    ap.add_argument("--fleet", type=int, default=64)
    ap.add_argument("--scenario", default="highway_corridor")
    ap.add_argument("--strategy", default="paper")
    ap.add_argument("--rounds", type=int, default=12)
    ap.add_argument("--local-steps", type=int, default=2)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--schedule", default="sequential",
                    choices=sorted(api.SCHEDULES))
    ap.add_argument("--superstep", type=int, default=4)
    ap.add_argument("--devices", default="1", metavar="N[,M...]",
                    help="device counts to bench (RSU-axis mesh rows; on "
                         "CPU the host device count is forced pre-import "
                         "— parsed by bench_devices before jax loads)")
    ap.add_argument("--timeit", type=int, default=1)
    ap.add_argument("--no-write", action="store_true")
    args = ap.parse_args()

    results = []
    for devices in DEVICE_COUNTS:
        for rate in (float(s) for s in args.dropouts.split(",")):
            gc.collect()
            row = bench_one(args, rate, devices)
            results.append(row)
            print(f"dropout={rate:4.2f} dev={devices} "
                  f"acc={row['final_acc']:.3f} "
                  f"loss={row['final_loss']:.3f} "
                  f"survivor_frac={row['survivor_frac']:.2f} "
                  f"lost={row['lost_update_bytes']/1e6:6.2f} MB "
                  f"dropped={row['n_dropout']:3d} "
                  f"upload_lost={row['n_upload_lost']:3d} "
                  f"({row['rounds_per_s']:.2f} rounds/s)", flush=True)

    clean = next((r for r in results
                  if r["dropout"] == 0.0
                  and r["devices"] == DEVICE_COUNTS[0]), None)
    out = {
        "config": {"fleet": args.fleet, "scenario": args.scenario,
                   "strategy": args.strategy, "rounds": args.rounds,
                   "local_steps": args.local_steps, "batch": args.batch,
                   "schedule": args.schedule, "superstep": args.superstep,
                   "upload_loss": args.upload_loss,
                   "straggler_factor": args.straggler_factor,
                   "rsu_outage": args.rsu_outage,
                   "fault_seed": args.fault_seed,
                   "devices": list(DEVICE_COUNTS),
                   "backend": jax.default_backend(),
                   "driver": "repro.api.run"},
        "accuracy_vs_dropout": {str(r["dropout"]): r["final_acc"]
                                for r in results
                                if r["devices"] == DEVICE_COUNTS[0]},
        # accuracy the failures cost, relative to the clean row
        "acc_drop_vs_clean": ({str(r["dropout"]):
                               float(clean["final_acc"] - r["final_acc"])
                               for r in results
                               if r["devices"] == DEVICE_COUNTS[0]}
                              if clean else None),
        "results": results,
    }
    if not args.no_write:
        write_bench("BENCH_faults", out, "benchmarks/bench_faults.py")


if __name__ == "__main__":
    main()
