"""Smashed-data compression at the cut boundary (beyond-paper optimization).

The paper's point is that SFL trades communication for computation; the
natural next step (its §IV-D 'wireless resource allocation' direction) is to
shrink the uplink itself.  We use per-group symmetric int8 quantisation of
the cut activations (and, optionally, of the returned cut-layer gradients):
4x fewer bytes over the wireless link in the simulator, and 4x fewer
collective bytes at the sharding boundary in the datacenter realisation.

A straight-through estimator keeps the backward path exact w.r.t. the
dequantised values.  ``repro.kernels.quant`` provides the Pallas TPU kernel
with identical semantics (this module is its oracle).
"""
from __future__ import annotations

from typing import Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

GROUP = 128  # quantisation group along the trailing axis


def quantize_int8(x: jnp.ndarray, group: int = GROUP
                  ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-(trailing-)group symmetric int8.  Returns (q int8, scales f32).
    Trailing dim must be divisible by `group` (pad upstream if not)."""
    *lead, d = x.shape
    g = min(group, d)
    if d % g:
        g = d
    xg = x.reshape(*lead, d // g, g).astype(jnp.float32)
    amax = jnp.max(jnp.abs(xg), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(xg / scale), -127, 127).astype(jnp.int8)
    return q.reshape(*lead, d), scale[..., 0]


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray, dtype=jnp.float32
                    ) -> jnp.ndarray:
    *lead, d = q.shape
    ng = scale.shape[-1]
    g = d // ng
    xg = q.reshape(*lead, ng, g).astype(jnp.float32) * scale[..., None]
    return xg.reshape(*lead, d).astype(dtype)


@jax.custom_vjp
def fake_quant(x: jnp.ndarray) -> jnp.ndarray:
    """Quantise-dequantise with a straight-through gradient."""
    q, s = quantize_int8(x)
    return dequantize_int8(q, s, x.dtype)


def _fq_fwd(x):
    return fake_quant(x), None


def _fq_bwd(_, g):
    return (g,)


fake_quant.defvjp(_fq_fwd, _fq_bwd)


def effective_group(trailing_dim, group: int = GROUP):
    """The group size :func:`quantize_int8` actually uses for a trailing dim
    ``d``: min(group, d), falling back to one whole-row group when ``d`` is
    not divisible.  Vectorized over arrays of trailing dims (per-cut smashed
    channel counts)."""
    d = np.asarray(trailing_dim)
    g = np.minimum(group, d)
    return np.where(d % np.maximum(g, 1) != 0, d, g)


def compression_ratio(dtype_bytes: int = 4, group: int = GROUP,
                      trailing_dim: Optional[Union[int, np.ndarray]] = None
                      ) -> Union[float, np.ndarray]:
    """Bytes(fp) / bytes(int8 + f32 scale per group).

    Pass ``trailing_dim`` (scalar or per-cut array) to account with the group
    size :func:`quantize_int8` actually used — e.g. a 64-channel smashed
    tensor quantizes in 64-wide groups, not ``GROUP``-wide ones, so its
    scale overhead is larger and the true ratio smaller."""
    if trailing_dim is None:
        return dtype_bytes * group / (group + 4.0)
    g = effective_group(trailing_dim, group)
    ratio = dtype_bytes * g / (g + 4.0)
    return float(ratio) if np.ndim(ratio) == 0 else ratio
