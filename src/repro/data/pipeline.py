"""Per-client data pipeline for the federation simulator."""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.partition import label_skew_power_law
from repro.data.synthetic import make_cifar_like


@dataclasses.dataclass
class ClientDataset:
    images: np.ndarray   # (n, ...) features
    labels: np.ndarray   # (n,)
    client_id: int

    def __len__(self) -> int:
        return len(self.labels)

    def batches(self, batch_size: int, seed: int,
                drop_remainder: bool = True) -> Iterator[Dict[str, jnp.ndarray]]:
        rng = np.random.default_rng(seed)
        order = rng.permutation(len(self.labels))
        n_full = len(order) // batch_size
        for i in range(n_full):
            sel = order[i * batch_size:(i + 1) * batch_size]
            yield {"images": jnp.asarray(self.images[sel]),
                   "labels": jnp.asarray(self.labels[sel])}
        if not drop_remainder and len(order) % batch_size:
            sel = order[n_full * batch_size:]
            yield {"images": jnp.asarray(self.images[sel]),
                   "labels": jnp.asarray(self.labels[sel])}

    def sample_batch(self, batch_size: int, seed: int) -> Dict[str, jnp.ndarray]:
        rng = np.random.default_rng(seed)
        sel = rng.choice(len(self.labels), size=batch_size,
                         replace=len(self.labels) < batch_size)
        return {"images": jnp.asarray(self.images[sel]),
                "labels": jnp.asarray(self.labels[sel])}


def make_federated_data(seed: int, n_train: int = 4096, n_test: int = 1024,
                        n_clients: int = 4, iid: bool = False,
                        labels_per_client: int = 6):
    """The paper's case-study data: CIFAR-like, 4 vehicles, 6-of-10 labels,
    power-law sizes (non-IID) or uniform (IID)."""
    key = jax.random.PRNGKey(seed)
    k_train, k_test = jax.random.split(key)
    x, y = make_cifar_like(k_train, n_train)
    xt, yt = make_cifar_like(k_test, n_test)
    x, y = np.asarray(x), np.asarray(y)
    if iid:
        rng = np.random.default_rng(seed)
        order = rng.permutation(n_train)
        parts = np.array_split(order, n_clients)
    else:
        parts = label_skew_power_law(seed, y, n_clients,
                                     labels_per_client=labels_per_client)
    clients = [ClientDataset(x[p], y[p], i) for i, p in enumerate(parts)]
    test = {"images": jnp.asarray(np.asarray(xt)), "labels": jnp.asarray(np.asarray(yt))}
    return clients, test
