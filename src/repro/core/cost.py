"""Per-cut communication / computation / energy accounting.

This is the analytic model behind the paper's Fig. 5a (communication overhead
per scheme and cut layer) and Fig. 5b (overall training time), and the input
to the latency-optimal cut selection strategy (beyond-paper, adaptive.py).

A :class:`SplitProfile` abstracts any layer-stack model: per-unit forward
FLOPs, per-unit parameter bytes, and smashed-data bytes at each cut.  Both
ResNet18 (the paper's model) and every assigned ArchConfig provide one.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np

from repro.configs.base import (ATTN, ATTN_LOCAL, ATTN_MOE, MLA_DENSE,
                                MLA_MOE, RGLRU, SSM, ArchConfig)

BYTES_F32 = 4
BWD_FWD_RATIO = 2.0  # backward pass ~ 2x forward FLOPs


def wire_smashed_ratio(profile: "SplitProfile", cuts, wire: str = "none",
                       wire_k: Optional[float] = None, group: int = 128):
    """Dense-fp32 / on-wire bytes for the smashed tensors at each cut.

    ``wire="int8"`` is per-group quant (int8 values + f32 scale per group);
    ``"topk_int8"`` is the packed sparse format (bitmap + scale + int8
    survivors — compression.wire_row_bytes charges every word).  The ratio
    applies to BOTH directions: activations up AND cut-layer gradients down
    ride the same wire (previously the downlink was charged dense fp32 even
    with gradient quantisation on — the effective-bytes helper below routes
    both through this one factor)."""
    from repro.core import compression
    if wire == "none":
        return 1.0
    td = profile.smashed_trailing_dim
    trailing = (None if td is None
                else np.asarray(td)[np.asarray(cuts, dtype=np.int64) - 1])
    if wire_k is None:
        wire_k = compression.WIRE_K
    return compression.wire_compression_ratio(wire, BYTES_F32, group,
                                              trailing, wire_k)


def effective_comm_bytes(profile: "SplitProfile", cuts, steps, batch: int,
                         wire: str = "none", wire_k: Optional[float] = None,
                         include_model_transfer: bool = True,
                         model_upload=True):
    """(up, down) bytes for one round: smashed traffic charged at actual
    on-wire size in both directions, model transfer (aggregation up + fresh
    copy down) always dense fp32 — the wire compresses activations and
    gradients, never parameters.  ``model_upload`` (scalar or bool array
    broadcast over the fleet) drops the aggregation-upload bytes for
    vehicles whose update never made it onto the wire (mid-round dropouts,
    DESIGN.md §13) — the fresh-copy download at round start is still
    charged, as is every smashed exchange in ``steps``."""
    cuts = np.asarray(cuts, dtype=np.int64)
    smashed = (np.asarray(profile.smashed_bytes_per_sample)[cuts - 1] * batch
               / wire_smashed_ratio(profile, cuts, wire, wire_k))
    up = np.asarray(steps) * smashed
    down = np.asarray(steps) * smashed
    if include_model_transfer:
        bytes_cum = np.concatenate([[0], np.cumsum(profile.unit_param_bytes)])
        up = up + bytes_cum[cuts] * np.asarray(model_upload)
        down = down + bytes_cum[cuts]
    return up, down


@dataclasses.dataclass
class SplitProfile:
    name: str
    unit_fwd_flops: List[float]      # per-sample forward FLOPs per unit
    unit_param_bytes: List[int]      # parameter bytes per unit
    smashed_bytes_per_sample: List[float]  # at cut c (index c-1), forward
    head_flops: float = 0.0
    head_param_bytes: int = 0
    # trailing dim of the smashed tensor at cut c (index c-1) — the axis
    # int8 quantisation groups along; None = unknown (assume GROUP-divisible)
    smashed_trailing_dim: Optional[List[int]] = None

    @property
    def n_units(self) -> int:
        return len(self.unit_fwd_flops)

    def client_fwd_flops(self, cut: int) -> float:
        return float(sum(self.unit_fwd_flops[:cut]))

    def server_fwd_flops(self, cut: int) -> float:
        return float(sum(self.unit_fwd_flops[cut:]) + self.head_flops)

    def client_param_bytes(self, cut: int) -> int:
        return int(sum(self.unit_param_bytes[:cut]))

    def full_param_bytes(self) -> int:
        return int(sum(self.unit_param_bytes) + self.head_param_bytes)

    def smashed_bytes(self, cut: int, batch: int) -> float:
        return self.smashed_bytes_per_sample[cut - 1] * batch


def resnet_profile() -> SplitProfile:
    from repro.models import resnet as R
    unit_flops = [float(R.unit_flops(i)) for i in range(R.N_UNITS)]
    unit_bytes = []
    # analytic param bytes per unit
    cin = 3
    # stem
    unit_bytes.append((3 * 3 * 3 * 64 + 2 * 64) * BYTES_F32)
    cin = 64
    for cout, stride in zip(R.STAGE_CHANNELS, R.STAGE_STRIDES):
        n = 3 * 3 * cin * cout + 2 * cout + 3 * 3 * cout * cout + 2 * cout
        if stride != 1 or cin != cout:
            n += cin * cout + 2 * cout
        unit_bytes.append(n * BYTES_F32)
        cin = cout
    smashed = [float(np.prod(R.smashed_shape(c, 1)[1:])) * BYTES_F32
               for c in range(1, R.N_UNITS + 1)]
    return SplitProfile(
        name="resnet18",
        unit_fwd_flops=unit_flops,
        unit_param_bytes=unit_bytes,
        smashed_bytes_per_sample=smashed,
        head_flops=2 * 512 * 10,
        head_param_bytes=(512 * 10 + 10) * BYTES_F32,
        smashed_trailing_dim=[R.smashed_shape(c, 1)[-1]
                              for c in range(1, R.N_UNITS + 1)],
    )


def arch_profile(cfg: ArchConfig, seq: int, param_bytes_per: int = 2
                 ) -> SplitProfile:
    """SplitProfile for an assigned architecture at period granularity.
    smashed data = (seq, d_model) activations at the period boundary."""
    from repro.models import transformer as T
    from repro.models.attention import attn_flops
    from repro.models.mla import mla_flops
    from repro.models.moe import moe_flops
    from repro.models.rglru import rglru_flops
    from repro.models.ssm import ssm_flops
    from repro.models.layers import mlp_flops

    def layer_flops(kind: str) -> float:
        if kind in (ATTN, ATTN_MOE):
            f = attn_flops(cfg, seq)
        elif kind == ATTN_LOCAL:
            f = attn_flops(cfg, seq, cfg.window)
        elif kind in (MLA_DENSE, MLA_MOE):
            f = mla_flops(cfg, seq)
        elif kind == SSM:
            return float(ssm_flops(cfg, seq, "train"))
        elif kind == RGLRU:
            f = rglru_flops(cfg)
        else:
            raise ValueError(kind)
        if kind in (ATTN_MOE, MLA_MOE):
            f += moe_flops(cfg)
        elif kind != SSM:
            f += mlp_flops(cfg.d_model, cfg.d_ff, cfg.mlp_variant)
        return float(f)

    def layer_params(kind: str) -> int:
        # reuse the analytic counter via a 1-layer pseudo-config
        import dataclasses as dc
        one = dc.replace(cfg, n_layers=1, pattern=(kind,), tail=())
        base = T.count_params(one)
        emb = one.padded_vocab * one.d_model * (
            one.n_codebooks if one.frontend == "audio" else 1)
        head = one.d_model * one.padded_vocab * (
            one.n_codebooks if one.frontend == "audio" else 1)
        return (base - emb - head - one.d_model) * param_bytes_per

    types = cfg.layer_types
    segs = T.segments_of(cfg)
    unit_flops, unit_bytes = [], []
    li = 0
    for pat, n in segs:
        for _ in range(n):
            f = sum(layer_flops(k) for k in pat) * seq
            b = sum(layer_params(k) for k in pat)
            unit_flops.append(float(f))
            unit_bytes.append(int(b))
            li += len(pat)
    smashed = [float(seq * cfg.d_model * param_bytes_per)] * len(unit_flops)
    vp = cfg.padded_vocab * (cfg.n_codebooks if cfg.frontend == "audio" else 1)
    return SplitProfile(
        name=cfg.name,
        unit_fwd_flops=unit_flops,
        unit_param_bytes=unit_bytes,
        smashed_bytes_per_sample=smashed,
        head_flops=float(2 * cfg.d_model * vp * seq),
        head_param_bytes=2 * vp * cfg.d_model * param_bytes_per,
        smashed_trailing_dim=[cfg.d_model] * len(unit_flops),
    )


# --------------------------------------------------------------------------
# per-round cost model (Fig 5a / 5b)
# --------------------------------------------------------------------------

@dataclasses.dataclass
class RoundCost:
    comm_bytes_up: float
    comm_bytes_down: float
    t_client_compute: float
    t_server_compute: float
    t_comm: float
    energy_j: float

    @property
    def comm_bytes(self) -> float:
        return self.comm_bytes_up + self.comm_bytes_down

    @property
    def latency(self) -> float:
        return self.t_client_compute + self.t_server_compute + self.t_comm


def sfl_client_round_cost(profile: SplitProfile, cut: int, n_batches: int,
                          batch: int, rate_bps: float, client_flops: float,
                          server_flops: float, local_epochs: int = 1,
                          tx_power_w: float = 0.5, compute_power_w: float = 15.0,
                          include_model_transfer: bool = True,
                          wire: str = "none",
                          wire_k: Optional[float] = None) -> RoundCost:
    """One SFL round for ONE client: K local epochs of (client fwd -> smashed
    up -> server fwd/bwd -> grad down -> client bwd), then client-model
    upload for aggregation (and download of the fresh copy).  ``wire``
    charges smashed traffic (activations up, cut-layer gradients down) at
    its actual on-wire bytes."""
    steps = n_batches * local_epochs
    up, down = effective_comm_bytes(profile, cut, steps, batch, wire, wire_k,
                                    include_model_transfer)
    up, down = float(up), float(down)
    c_fwd = profile.client_fwd_flops(cut) * batch
    s_fwd = profile.server_fwd_flops(cut) * batch
    t_client = steps * c_fwd * (1 + BWD_FWD_RATIO) / client_flops
    t_server = steps * s_fwd * (1 + BWD_FWD_RATIO) / server_flops
    t_comm = (up + down) / max(rate_bps / 8, 1e-9)  # rate in bits/s
    energy = compute_power_w * t_client + tx_power_w * (up * 8 / max(rate_bps, 1e-9))
    return RoundCost(up, down, t_client, t_server, t_comm, energy)


@dataclasses.dataclass
class RoundCostArrays:
    """Vectorized :class:`RoundCost`: every field is an np array broadcast
    over the fleet (and optionally a candidate-cut axis).  This makes round
    accounting and cut selection one vector op for 256+ vehicles."""
    comm_bytes_up: np.ndarray
    comm_bytes_down: np.ndarray
    t_client_compute: np.ndarray
    t_server_compute: np.ndarray
    t_comm: np.ndarray
    energy_j: np.ndarray

    @property
    def comm_bytes(self) -> np.ndarray:
        return self.comm_bytes_up + self.comm_bytes_down

    @property
    def latency(self) -> np.ndarray:
        return self.t_client_compute + self.t_server_compute + self.t_comm


def sfl_round_cost_arrays(profile: SplitProfile, cuts, n_batches, batch: int,
                          rates_bps, client_flops, server_flops: float,
                          local_epochs: int = 1, tx_power_w=0.5,
                          compute_power_w=15.0,
                          include_model_transfer: bool = True,
                          wire: str = "none", wire_k: Optional[float] = None,
                          model_upload=True
                          ) -> RoundCostArrays:
    """Vectorized :func:`sfl_client_round_cost`.  ``cuts``, ``n_batches``,
    ``rates_bps``, ``client_flops``, ``tx_power_w``, ``compute_power_w`` may
    be scalars or arrays; everything broadcasts (e.g. rates (n,1) against
    candidate cuts (k,) yields an (n,k) cost matrix for cut selection).
    Smashed traffic is charged at on-wire bytes in BOTH directions via
    :func:`effective_comm_bytes`; latency and radio energy follow from the
    compressed byte counts (the engines no longer rescale post-hoc).  Under
    fault injection, pass per-vehicle *performed* steps as ``n_batches``
    (with ``local_epochs=1``) and a ``model_upload`` mask so dropouts are
    charged only the work they actually did."""
    cuts = np.asarray(cuts, dtype=np.int64)
    fwd_cum = np.concatenate([[0.0], np.cumsum(profile.unit_fwd_flops)])

    steps = np.asarray(n_batches) * local_epochs
    up, down = effective_comm_bytes(profile, cuts, steps, batch, wire,
                                    wire_k, include_model_transfer,
                                    model_upload)
    c_fwd = fwd_cum[cuts] * batch
    s_fwd = (fwd_cum[-1] - fwd_cum[cuts] + profile.head_flops) * batch
    t_client = steps * c_fwd * (1 + BWD_FWD_RATIO) / np.asarray(client_flops)
    t_server = steps * s_fwd * (1 + BWD_FWD_RATIO) / server_flops
    rate = np.asarray(rates_bps, dtype=np.float64)
    t_comm = (up + down) / np.maximum(rate / 8, 1e-9)
    energy = (np.asarray(compute_power_w) * t_client
              + np.asarray(tx_power_w) * (up * 8 / np.maximum(rate, 1e-9)))
    b = np.broadcast_arrays(up, down, t_client, t_server, t_comm, energy)
    return RoundCostArrays(*[np.asarray(a, dtype=np.float64) for a in b])


def fl_round_cost_arrays(profile: SplitProfile, n_batches, batch: int,
                         rates_bps, client_flops, local_epochs: int = 1,
                         tx_power_w=0.5, compute_power_w=15.0
                         ) -> RoundCostArrays:
    """Vectorized :func:`fl_client_round_cost` over the fleet."""
    steps = np.asarray(n_batches) * local_epochs
    full = float(profile.full_param_bytes())
    fwd = (profile.client_fwd_flops(profile.n_units) + profile.head_flops) * batch
    t_client = steps * fwd * (1 + BWD_FWD_RATIO) / np.asarray(client_flops)
    rate = np.asarray(rates_bps, dtype=np.float64)
    t_comm = 2 * full / np.maximum(rate / 8, 1e-9)
    energy = (np.asarray(compute_power_w) * t_client
              + np.asarray(tx_power_w) * (full * 8 / np.maximum(rate, 1e-9)))
    b = np.broadcast_arrays(np.full_like(t_client, full),
                            np.full_like(t_client, full),
                            t_client, np.zeros_like(t_client), t_comm, energy)
    return RoundCostArrays(*[np.asarray(a, dtype=np.float64) for a in b])


def fl_client_round_cost(profile: SplitProfile, n_batches: int, batch: int,
                         rate_bps: float, client_flops: float,
                         local_epochs: int = 1, tx_power_w: float = 0.5,
                         compute_power_w: float = 15.0) -> RoundCost:
    """FL: full model trained on-vehicle; model up+down once per round."""
    steps = n_batches * local_epochs
    full = profile.full_param_bytes()
    fwd = (profile.client_fwd_flops(profile.n_units) + profile.head_flops) * batch
    t_client = steps * fwd * (1 + BWD_FWD_RATIO) / client_flops
    t_comm = 2 * full / max(rate_bps / 8, 1e-9)
    energy = compute_power_w * t_client + tx_power_w * (full * 8 / max(rate_bps, 1e-9))
    return RoundCost(full, full, t_client, 0.0, t_comm, energy)


def sl_round_cost(profile: SplitProfile, cut: int, n_batches_per_client: Sequence[int],
                  batch: int, rates_bps: Sequence[float], client_flops: Sequence[float],
                  server_flops: float, local_epochs: int = 1) -> RoundCost:
    """Sequential SL: clients served one after another; the client-side model
    additionally hops vehicle -> vehicle (via RSU) between turns."""
    up = down = t_c = t_s = t_comm = energy = 0.0
    for nb, r, cf in zip(n_batches_per_client, rates_bps, client_flops):
        c = sfl_client_round_cost(profile, cut, nb, batch, r, cf, server_flops,
                                  local_epochs, include_model_transfer=True)
        up += c.comm_bytes_up
        down += c.comm_bytes_down
        t_c += c.t_client_compute          # sequential: times add up
        t_s += c.t_server_compute
        t_comm += c.t_comm
        energy += c.energy_j
    return RoundCost(up, down, t_c, t_s, t_comm, energy)


def parallel_round_latency(costs: Sequence[RoundCost],
                           survivors: Optional[Sequence[bool]] = None) -> float:
    """SFL/FL round latency: slowest client (straggler) bounds the round.

    ``survivors`` restricts the bound to clients whose update actually made
    the round (DESIGN.md §13): a dropout's partial work and a deadline
    straggler's late upload do not extend the round — the server closes the
    merge without them.  An empty survivor set costs 0 (nothing merged)."""
    if survivors is None:
        return max(c.latency for c in costs)
    lats = [c.latency for c, s in zip(costs, survivors) if s]
    return max(lats) if lats else 0.0
