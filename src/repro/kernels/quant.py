"""Per-group symmetric int8 quantisation of smashed data (Pallas).

The SFL uplink compressor (DESIGN.md §5): activations at the cut layer are
quantised to int8 with one f32 scale per 128-element group before crossing
the vehicle->RSU boundary — 4x fewer bytes on the wireless link in the
simulator / the `data`-axis collective in the datacenter realisation.

Tiles are (block_rows, group): the group dim matches the quantisation group
so each tile computes its own scales — no cross-tile reductions.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

GROUP = 128


def _quant_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)               # (rows, group)
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127)
    q_ref[...] = q.astype(jnp.int8)
    s_ref[...] = scale


def _dequant_kernel(q_ref, s_ref, x_ref):
    x_ref[...] = (q_ref[...].astype(jnp.float32) * s_ref[...]
                  ).astype(x_ref.dtype)


def quantize_int8(x: jnp.ndarray, group: int = GROUP, block_rows: int = 256,
                  interpret: bool = False):
    """x (..., d) with d % group == 0 -> (q int8 (..., d), scales (..., d/group))."""
    *lead, d = x.shape
    if d % group:
        group = d
    rows = 1
    for s in lead:
        rows *= s
    x2 = x.reshape(rows, d // group, group).reshape(rows * (d // group), group)
    n = x2.shape[0]
    br = min(block_rows, n)
    pad = (-n) % br
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    grid = (x2.shape[0] // br,)
    q, s = pl.pallas_call(
        _quant_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((br, group), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((br, group), lambda i: (i, 0)),
                   pl.BlockSpec((br, 1), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct(x2.shape, jnp.int8),
                   jax.ShapeDtypeStruct((x2.shape[0], 1), jnp.float32)],
        interpret=interpret,
    )(x2)
    if pad:
        q, s = q[:n], s[:n]
    return (q.reshape(*lead, d),
            s.reshape(*lead, d // group))


def dequantize_int8(q: jnp.ndarray, scales: jnp.ndarray, group: int = GROUP,
                    dtype=jnp.float32, block_rows: int = 256,
                    interpret: bool = False) -> jnp.ndarray:
    *lead, d = q.shape
    ng = scales.shape[-1]
    group = d // ng
    rows = 1
    for s in lead:
        rows *= s
    q2 = q.reshape(rows * ng, group)
    s2 = scales.reshape(rows * ng, 1)
    n = q2.shape[0]
    br = min(block_rows, n)
    pad = (-n) % br
    if pad:
        q2 = jnp.pad(q2, ((0, pad), (0, 0)))
        s2 = jnp.pad(s2, ((0, pad), (0, 0)))
    grid = (q2.shape[0] // br,)
    x = pl.pallas_call(
        _dequant_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((br, group), lambda i: (i, 0)),
                  pl.BlockSpec((br, 1), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((br, group), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(q2.shape, dtype),
        interpret=interpret,
    )(q2, s2)
    if pad:
        x = x[:n]
    return x.reshape(*lead, d)
