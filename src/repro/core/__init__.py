"""Core ASFL library: cut-layer splitting, adaptive cut selection, wireless
channel model, FedAvg aggregation, the paper-faithful federation simulator,
datacenter SFL train/serve steps, and smashed-data compression."""
