"""Roofline-term extraction from compiled (SPMD-partitioned) HLO text.

XLA's ``compiled.cost_analysis()`` counts a ``while`` body once, so any
scan-over-layers model is undercounted by ~n_layers.  This module parses the
post-optimization HLO, recursively walks fusion / call / while computations,
multiplies while bodies by their trip count (from the
``known_trip_count`` backend config, falling back to the loop-condition
constant), and accumulates:

  * ``flops``      — 2*M*N*K for every dot (contracting dims resolved via a
                     per-computation symbol table) + conv window FLOPs
  * ``traffic``    — result bytes of materialising top-level ops (HBM-traffic
                     proxy; fusion-internal intermediates excluded)
  * ``collective`` — result bytes per collective kind (all-gather,
                     all-reduce, reduce-scatter, all-to-all, collective-permute)

All values are PER DEVICE: shapes in post-SPMD HLO are per-partition.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional

_DTYPE_BYTES = {"bf16": 2, "f16": 2, "f32": 4, "f64": 8, "s8": 1, "u8": 1,
                "s16": 2, "u16": 2, "s32": 4, "u32": 4, "s64": 8, "u64": 8,
                "pred": 1, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
# name = shape op(args...), attrs
_LINE_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*((?:\([^()]*(?:\([^()]*\)[^()]*)*\))|\S+)"
    r"\s+([\w\-]+)\((.*)$")
_HEADER_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->\s*.*\{\s*$")
_TRIP_RE = re.compile(r'known_trip_count[":{ ]+n["\s:]+"?(\d+)')
_CALL_RE = re.compile(r"(?:calls|to_apply)=%?([\w\.\-]+)")
_WHILE_RE = re.compile(r"(body|condition)=%?([\w\.\-]+)")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")

_SKIP_TRAFFIC_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
                     "bitcast", "after-all", "iota", "partition-id"}


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _first_shape_dims(text: str) -> List[int]:
    m = _SHAPE_RE.search(text)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


def _elems(dims: List[int]) -> int:
    n = 1
    for d in dims:
        n *= d
    return n


@dataclasses.dataclass
class Op:
    name: str
    shape: str
    op: str
    rest: str  # args + attrs tail of the line


@dataclasses.dataclass
class Costs:
    flops: float = 0.0
    traffic: float = 0.0
    collective: Dict[str, float] = dataclasses.field(default_factory=dict)

    def add(self, other: "Costs", mult: float = 1.0):
        self.flops += mult * other.flops
        self.traffic += mult * other.traffic
        for k, v in other.collective.items():
            self.collective[k] = self.collective.get(k, 0.0) + mult * v

    @property
    def collective_bytes(self) -> float:
        return sum(v for k, v in self.collective.items()
                   if not k.startswith("count_"))

    def as_dict(self) -> Dict:
        return {"flops": self.flops, "traffic": self.traffic,
                "collective": dict(self.collective),
                "collective_bytes_total": self.collective_bytes}


class HloModule:
    def __init__(self, text: str):
        self.computations: Dict[str, List[Op]] = {}
        self.symbols: Dict[str, Dict[str, str]] = {}
        self.entry: Optional[str] = None
        self._parse(text)
        self._memo: Dict[str, Costs] = {}

    def _parse(self, text: str):
        cur: Optional[str] = None
        for raw in text.splitlines():
            line = raw.rstrip()
            stripped = line.strip()
            hm = _HEADER_RE.match(stripped)
            if hm and "=" not in stripped.split("(")[0]:
                cur = hm.group(1)
                self.computations[cur] = []
                self.symbols[cur] = {}
                if stripped.startswith("ENTRY"):
                    self.entry = cur
                continue
            if stripped == "}":
                cur = None
                continue
            if cur is None:
                continue
            lm = _LINE_RE.match(stripped)
            if lm:
                op = Op(lm.group(1), lm.group(2), lm.group(3), lm.group(4))
                self.computations[cur].append(op)
                self.symbols[cur][op.name] = op.shape
        if self.entry is None:
            mains = [k for k in self.computations if "main" in k]
            self.entry = mains[0] if mains else next(iter(self.computations), None)

    # ------------------------------------------------------------------
    def _dot_flops(self, comp: str, op: Op) -> float:
        out = _elems(_first_shape_dims(op.shape))
        if op.op == "convolution":
            win = 1
            wm = re.search(r"size=([0-9x]+)", op.rest)
            if wm:
                for d in wm.group(1).split("x"):
                    win *= int(d)
            kin = 1
            ops = _OPERAND_RE.findall(op.rest.split("),")[0])
            if len(ops) > 1:
                kshape = _first_shape_dims(self.symbols[comp].get(ops[1], ""))
                # HWIO kernel: in-features is dim -2
                if len(kshape) >= 2:
                    kin = kshape[-2]
            return 2.0 * out * win * kin
        cm = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.rest)
        args = _OPERAND_RE.findall(op.rest.split(")", 1)[0])
        k = 1
        if cm and args:
            lhs_dims = _first_shape_dims(self.symbols[comp].get(args[0], ""))
            for i in (int(i) for i in cm.group(1).split(",") if i):
                if i < len(lhs_dims):
                    k *= lhs_dims[i]
        return 2.0 * out * k

    def _trip_count(self, op: Op) -> int:
        tm = _TRIP_RE.search(op.rest)
        if tm:
            return int(tm.group(1))
        refs = dict(_WHILE_RE.findall(op.rest))
        cond = refs.get("condition")
        consts = []
        for o in self.computations.get(cond or "", []):
            cm = re.search(r"constant\((\d+)\)", o.rest + o.shape)
            if cm:
                consts.append(int(cm.group(1)))
        return max(consts) if consts else 1

    def cost_of(self, comp: Optional[str]) -> Costs:
        if comp is None or comp not in self.computations:
            return Costs()
        if comp in self._memo:
            return self._memo[comp]
        total = Costs()
        self._memo[comp] = total
        for op in self.computations[comp]:
            base = op.op.replace("-start", "")
            if op.op in ("dot", "convolution"):
                total.flops += self._dot_flops(comp, op)
            elif base in _COLLECTIVES:
                b = _shape_bytes(op.shape)
                total.collective[base] = total.collective.get(base, 0.0) + b
                ck = "count_" + base
                total.collective[ck] = total.collective.get(ck, 0.0) + 1
                total.traffic += 2.0 * b
            if op.op == "while":
                refs = dict(_WHILE_RE.findall(op.rest))
                trip = self._trip_count(op)
                total.add(self.cost_of(refs.get("body")), trip)
                total.add(self.cost_of(refs.get("condition")), trip)
            elif op.op in ("fusion", "call", "custom-call", "map", "reduce",
                           "reduce-window", "sort", "scatter", "select-and-scatter"):
                m = _CALL_RE.search(op.rest)
                if m:
                    sub = self.cost_of(m.group(1))
                    total.add(Costs(flops=sub.flops,
                                    collective=sub.collective))
                if op.op not in ("call",):
                    total.traffic += _shape_bytes(op.shape)
            elif op.op == "conditional":
                names = re.findall(r"%([\w\.\-]+)", op.rest)
                subs = [self.cost_of(n) for n in names
                        if n in self.computations]
                if subs:
                    worst = max(subs, key=lambda c: c.flops)
                    total.add(worst)
            elif op.op not in _SKIP_TRAFFIC_OPS:
                total.traffic += _shape_bytes(op.shape)
        self._memo[comp] = total
        return total

    def analyze(self) -> Costs:
        return self.cost_of(self.entry)


def analyze_hlo(text: str) -> Costs:
    return HloModule(text).analyze()
