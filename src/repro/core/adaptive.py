"""Cut-layer selection strategies — the 'adaptive' in ASFL.

`paper_threshold` is the paper's Eq. 3 (rate bands -> cut in {2,4,6,8}).

NOTE on Eq. 3 vs the paper's text: the printed equation maps the LOWEST rate
band to cut 2, whose smashed data is the LARGEST (Fig. 5a) — contradicting
the surrounding text ("when the vehicle's transmission rate is higher, we can
choose a smaller split layer").  We implement the text-consistent ordering by
default (high rate -> early cut -> more offload) and keep the literal printed
mapping behind ``literal_eq3=True``.  See DESIGN.md.

Beyond-paper strategies:
  * `latency_optimal` — per-vehicle argmin of the analytic round latency
    (cost.py), the multi-objective direction the paper lists as future work.
  * `memory_constrained` — upper-bounds the vehicle-side model bytes first
    (vehicles cannot hold a DBRX layer), then applies another strategy.
  * `energy_aware` — weighted latency+energy objective.
"""
from __future__ import annotations

from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.cost import SplitProfile, sfl_client_round_cost

DEFAULT_CUTS = (2, 4, 6, 8)
# Threshold rates (bps), R1<=R2<=R3<=R4 as in Eq. 3.  The paper leaves the
# R-bar values unspecified; these are calibrated to the quartiles of the
# channel model's rate distribution over a drive-by trace (channel.py), so
# each band is actually populated.
DEFAULT_THRESHOLDS = (60e6, 110e6, 160e6, 260e6)


def paper_threshold(rates_bps: Sequence[float],
                    thresholds: Sequence[float] = DEFAULT_THRESHOLDS,
                    cuts: Sequence[int] = DEFAULT_CUTS,
                    literal_eq3: bool = False) -> List[int]:
    """Eq. 3: banded rate -> cut layer, per vehicle."""
    t1, t2, t3, _ = thresholds
    out = []
    for r in rates_bps:
        if r <= t1:
            band = 0
        elif r <= t2:
            band = 1
        elif r <= t3:
            band = 2
        else:
            band = 3
        if literal_eq3:
            out.append(cuts[band])            # printed Eq. 3: low rate -> cut 2
        else:
            out.append(cuts[len(cuts) - 1 - band])  # text: high rate -> cut 2
    return out


def latency_optimal(profile: SplitProfile, rates_bps: Sequence[float],
                    client_flops: Sequence[float], server_flops: float,
                    n_batches: int, batch: int, local_epochs: int = 1,
                    candidate_cuts: Optional[Sequence[int]] = None) -> List[int]:
    cuts = list(candidate_cuts or range(1, profile.n_units))
    out = []
    for r, cf in zip(rates_bps, client_flops):
        lat = [sfl_client_round_cost(profile, c, n_batches, batch, r, cf,
                                     server_flops, local_epochs).latency
               for c in cuts]
        out.append(cuts[int(np.argmin(lat))])
    return out


def energy_aware(profile: SplitProfile, rates_bps: Sequence[float],
                 client_flops: Sequence[float], server_flops: float,
                 n_batches: int, batch: int, local_epochs: int = 1,
                 latency_weight: float = 0.5,
                 candidate_cuts: Optional[Sequence[int]] = None) -> List[int]:
    cuts = list(candidate_cuts or range(1, profile.n_units))
    out = []
    for r, cf in zip(rates_bps, client_flops):
        costs = [sfl_client_round_cost(profile, c, n_batches, batch, r, cf,
                                       server_flops, local_epochs)
                 for c in cuts]
        lat = np.array([c.latency for c in costs])
        en = np.array([c.energy_j for c in costs])
        score = latency_weight * lat / lat.max() + (1 - latency_weight) * en / en.max()
        out.append(cuts[int(np.argmin(score))])
    return out


def memory_constrained(profile: SplitProfile, budget_bytes: float,
                       inner: Callable[..., List[int]], *args,
                       **kwargs) -> List[int]:
    """Clamp any strategy's cuts so the vehicle-side model fits the budget."""
    cuts = inner(*args, **kwargs)
    max_cut = 0
    for c in range(1, profile.n_units + 1):
        if profile.client_param_bytes(c) <= budget_bytes:
            max_cut = c
        else:
            break
    max_cut = max(max_cut, 1)  # at least the first unit stays on-vehicle
    return [min(c, max_cut) for c in cuts]
