"""Mamba2 block — SSD (state-space duality, arXiv:2405.21060).

Train/prefill uses the chunked SSD decomposition (intra-chunk quadratic block
+ inter-chunk linear state recurrence); decode is the O(1) recurrence over a
constant-size (heads, head_dim, d_state) state — the reason mamba2 is
long_500k-eligible.  The pure-jnp chunk math here is also the oracle for the
Pallas ssd kernel (repro/kernels/ssd.py).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers as L

Params = Dict[str, Any]


def dims(cfg: ArchConfig):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    n_heads = d_inner // s.head_dim
    conv_dim = d_inner + 2 * s.n_groups * s.d_state
    return d_inner, n_heads, conv_dim


def init_ssm(key, cfg: ArchConfig, dtype=jnp.float32) -> Params:
    s = cfg.ssm
    d_inner, n_heads, conv_dim = dims(cfg)
    d_in_proj = 2 * d_inner + 2 * s.n_groups * s.d_state + n_heads
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    if s.fused_proj:
        proj = {"in_proj": L.init_dense(k1, cfg.d_model, d_in_proj, dtype)}
    else:
        # fully stream-split projections: every stream (z/x/B/C/dt) shards
        # cleanly on the model axis — no shard-boundary crossings (§Perf)
        k6, k7 = jax.random.split(k5)
        gn = s.n_groups * s.d_state
        proj = {"in_z": L.init_dense(k1, cfg.d_model, d_inner, dtype),
                "in_x": L.init_dense(k4, cfg.d_model, d_inner, dtype),
                "in_b": L.init_dense(k6, cfg.d_model, gn, dtype),
                "in_c": L.init_dense(k7, cfg.d_model, gn, dtype),
                "in_dt": L.init_dense(k5, cfg.d_model, n_heads, dtype)}
    return {
        **proj,
        "conv_w": L.trunc_normal(k2, (s.d_conv, conv_dim),
                                 1.0 / math.sqrt(s.d_conv), dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, n_heads, dtype=jnp.float32)),
        "D": jnp.ones((n_heads,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.linspace(1e-3, 1e-1, n_heads, dtype=jnp.float32))),
        "norm": L.init_rmsnorm(d_inner, dtype),
        "out_proj": L.init_dense(k3, d_inner, cfg.d_model, dtype),
    }


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv. x (b,s,c), w (width,c)."""
    width = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    y = jnp.zeros_like(x)
    for i in range(width):
        y = y + pad[:, i:i + x.shape[1], :] * w[i].astype(x.dtype)
    return y + b.astype(x.dtype)


def _split_proj(p: Params, cfg: ArchConfig, u: jnp.ndarray):
    """Returns (z, xBC_pre_conv, dt).  In split mode xBC is produced as
    separate shard-aligned streams and only *logically* concatenated; the
    conv is applied per stream (see _conv_xbc) so no op ever crosses the
    x|B|C boundary."""
    s = cfg.ssm
    d_inner, n_heads, conv_dim = dims(cfg)
    if s.fused_proj:
        zxbcdt = L.dense(p["in_proj"], u)
        z = zxbcdt[..., :d_inner]
        xBC = zxbcdt[..., d_inner:d_inner + conv_dim]
        dt = zxbcdt[..., d_inner + conv_dim:]
        return z, xBC, dt
    streams = (L.dense(p["in_x"], u), L.dense(p["in_b"], u),
               L.dense(p["in_c"], u))
    return L.dense(p["in_z"], u), streams, L.dense(p["in_dt"], u)


def _conv_xbc(p: Params, cfg: ArchConfig, xBC):
    """Causal conv + silu over the xBC streams (fused or per-stream)."""
    s = cfg.ssm
    d_inner, _, conv_dim = dims(cfg)
    gn = s.n_groups * s.d_state
    if s.fused_proj:
        return jax.nn.silu(_causal_conv(xBC, p["conv_w"], p["conv_b"]))
    xs, bs, cs = xBC
    w, b = p["conv_w"], p["conv_b"]
    x = jax.nn.silu(_causal_conv(xs, w[:, :d_inner], b[:d_inner]))
    bb = jax.nn.silu(_causal_conv(bs, w[:, d_inner:d_inner + gn],
                                  b[d_inner:d_inner + gn]))
    cc = jax.nn.silu(_causal_conv(cs, w[:, d_inner + gn:],
                                  b[d_inner + gn:]))
    return jnp.concatenate([x, bb, cc], axis=-1)


def _unpack_xbc(cfg: ArchConfig, xBC: jnp.ndarray):
    s = cfg.ssm
    d_inner, n_heads, _ = dims(cfg)
    gn = s.n_groups * s.d_state
    x = xBC[..., :d_inner]
    B = xBC[..., d_inner:d_inner + gn]
    C = xBC[..., d_inner + gn:]
    lead = x.shape[:-1]
    x = x.reshape(*lead, n_heads, s.head_dim)
    B = B.reshape(*lead, s.n_groups, s.d_state)
    C = C.reshape(*lead, s.n_groups, s.d_state)
    return x, B, C


def ssd_chunked(x, dt, A, B, C, chunk: int):
    """SSD reference.  x (b,s,h,p), dt (b,s,h) [post-softplus], A (h,) [<0],
    B,C (b,s,g,n).  Returns y (b,s,h,p) and final state (b,h,n,p)."""
    b, s, h, p_ = x.shape
    g, n = B.shape[2], B.shape[3]
    rep = h // g
    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
    sp = s + pad
    nc = sp // chunk
    xc = x.reshape(b, nc, chunk, h, p_)
    dtc = dt.reshape(b, nc, chunk, h).astype(jnp.float32)
    # broadcast groups -> heads
    Bc = jnp.repeat(B.reshape(b, nc, chunk, g, n), rep, axis=3)
    Cc = jnp.repeat(C.reshape(b, nc, chunk, g, n), rep, axis=3)

    la = dtc * A  # (b,nc,q,h) log-decay per step, <= 0
    cum = jnp.cumsum(la, axis=2)                      # inclusive
    total = cum[:, :, -1]                             # (b,nc,h)

    # intra-chunk (the quadratic "attention-like" block)
    cb = jnp.einsum("bcihn,bcjhn->bchij", Cc.astype(jnp.float32),
                    Bc.astype(jnp.float32))
    # decay[b,c,h,i,j] = exp(cum_i - cum_j)
    ci = jnp.transpose(cum, (0, 1, 3, 2))             # (b,nc,h,q)
    decay = jnp.exp(ci[..., :, None] - ci[..., None, :])
    idx = jnp.arange(chunk)
    mask = idx[:, None] >= idx[None, :]
    scores = cb * jnp.where(mask, decay, 0.0)
    dtj = jnp.transpose(dtc, (0, 1, 3, 2))            # (b,nc,h,q_j)
    y_intra = jnp.einsum("bchij,bcjhp->bcihp",
                         scores * dtj[..., None, :], xc.astype(jnp.float32))

    # per-chunk outgoing state: sum_j exp(total - cum_j) dt_j B_j x_j
    w = jnp.exp(total[:, :, None, :] - cum) * dtc     # (b,nc,q,h)
    S = jnp.einsum("bcjhn,bcjhp->bchnp", Bc.astype(jnp.float32) * w[..., None],
                   xc.astype(jnp.float32))

    # inter-chunk recurrence
    def step(hprev, inp):
        tot_c, s_c = inp
        hnew = jnp.exp(tot_c)[..., None, None] * hprev + s_c
        return hnew, hprev

    h0 = jnp.zeros((b, h, n, p_), jnp.float32)
    final, hprev = jax.lax.scan(
        step, h0, (jnp.moveaxis(total, 1, 0), jnp.moveaxis(S, 1, 0)))
    hprev = jnp.moveaxis(hprev, 0, 1)                  # (b,nc,h,n,p)

    y_inter = jnp.einsum("bcihn,bchnp->bcihp",
                         Cc.astype(jnp.float32) * jnp.exp(cum)[..., None], hprev)
    y = (y_intra + y_inter).reshape(b, sp, h, p_)[:, :s]
    return y.astype(x.dtype), final


def ssm_train(p: Params, cfg: ArchConfig, u: jnp.ndarray) -> jnp.ndarray:
    y, _ = _ssm_full_keep(p, cfg, u)
    return y


def init_ssm_cache(cfg: ArchConfig, batch: int, dtype=jnp.float32) -> Params:
    s = cfg.ssm
    d_inner, n_heads, conv_dim = dims(cfg)
    return {
        "conv": jnp.zeros((batch, s.d_conv - 1, conv_dim), dtype),
        "state": jnp.zeros((batch, n_heads, s.d_state, s.head_dim), jnp.float32),
        "pos": jnp.zeros((), jnp.int32),
    }


def ssm_prefill(p: Params, cfg: ArchConfig, u: jnp.ndarray
                ) -> Tuple[jnp.ndarray, Params]:
    y, (xBC_pre, state) = _ssm_full_keep(p, cfg, u)
    s = cfg.ssm
    cache = init_ssm_cache(cfg, u.shape[0], u.dtype)
    cache["conv"] = xBC_pre[:, -(s.d_conv - 1):, :]
    cache["state"] = state                    # (b, h, n, p) from ssd_chunked
    cache["pos"] = jnp.asarray(u.shape[1], jnp.int32)
    return y, cache


def _ssm_full_keep(p, cfg, u):
    """Like _ssm_full but keeps the *pre-conv* xBC for the conv cache."""
    s = cfg.ssm
    z, xBC_pre, dt = _split_proj(p, cfg, u)
    xBC = _conv_xbc(p, cfg, xBC_pre)
    if isinstance(xBC_pre, tuple):
        xBC_pre = jnp.concatenate(xBC_pre, axis=-1)   # cache keeps fused layout
    x, B, C = _unpack_xbc(cfg, xBC)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    y, state = ssd_chunked(x, dt, A, B, C, s.chunk)
    y = y + x * p["D"][:, None].astype(x.dtype)
    b, sl = u.shape[0], u.shape[1]
    y = y.reshape(b, sl, dims(cfg)[0])
    y = L.rmsnorm(p["norm"], y * jax.nn.silu(z))
    return L.dense(p["out_proj"], y), (xBC_pre, state)


def ssm_decode(p: Params, cfg: ArchConfig, u: jnp.ndarray,
               cache: Params) -> Tuple[jnp.ndarray, Params]:
    """One-step recurrence.  u (b, 1, d)."""
    s = cfg.ssm
    b = u.shape[0]
    z, xBC_new, dt = _split_proj(p, cfg, u)          # (b,1,·)
    if isinstance(xBC_new, tuple):
        xBC_new = jnp.concatenate(xBC_new, axis=-1)
    window = jnp.concatenate([cache["conv"], xBC_new], axis=1)  # (b,d_conv,c)
    conv_out = (jnp.einsum("bwc,wc->bc", window, p["conv_w"].astype(u.dtype))
                + p["conv_b"].astype(u.dtype))[:, None, :]
    xBC = jax.nn.silu(conv_out)
    x, B, C = _unpack_xbc(cfg, xBC)                   # x (b,1,h,p), B/C (b,1,g,n)
    x, B, C = x[:, 0], B[:, 0], C[:, 0]
    dt = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])  # (b,h)
    A = -jnp.exp(p["A_log"])
    a = jnp.exp(dt * A)                               # (b,h)
    rep = dims(cfg)[1] // s.n_groups
    Bh = jnp.repeat(B, rep, axis=1)                   # (b,h,n)
    Ch = jnp.repeat(C, rep, axis=1)
    upd = jnp.einsum("bhn,bhp->bhnp", Bh.astype(jnp.float32) * dt[..., None],
                     x.astype(jnp.float32))
    state = a[..., None, None] * cache["state"] + upd
    y = jnp.einsum("bhn,bhnp->bhp", Ch.astype(jnp.float32), state)
    y = y.astype(u.dtype) + x * p["D"][:, None].astype(u.dtype)
    y = y.reshape(b, 1, dims(cfg)[0])
    y = L.rmsnorm(p["norm"], y * jax.nn.silu(z))
    y = L.dense(p["out_proj"], y)
    new_cache = {"conv": window[:, 1:], "state": state, "pos": cache["pos"] + 1}
    return y, new_cache


def ssm_flops(cfg: ArchConfig, seq: int, kind: str) -> int:
    """Per-token matmul-ish FLOPs for one mamba2 block."""
    s = cfg.ssm
    d_inner, n_heads, conv_dim = dims(cfg)
    d_in_proj = 2 * d_inner + 2 * s.n_groups * s.d_state + n_heads
    proj = 2 * cfg.d_model * d_in_proj + 2 * d_inner * cfg.d_model
    conv = 2 * s.d_conv * conv_dim
    if kind == "decode":
        ssd = 4 * n_heads * s.d_state * s.head_dim
    else:
        q = s.chunk
        ssd = (2 * n_heads * s.d_state * q      # CB^T per token (q cols)
               + 2 * n_heads * q * s.head_dim   # scores @ x
               + 4 * n_heads * s.d_state * s.head_dim)  # state in/out
    return proj + conv + ssd
