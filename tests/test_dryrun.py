"""Dry-run machinery tests.

The full 512-device sweep runs via ``python -m repro.launch.dryrun --all``
(results in dryrun_baseline.json); here we verify the machinery end-to-end in
a subprocess with 16 placeholder devices (XLA device count locks at first
backend init, so isolation requires a fresh interpreter), plus unit-test the
HLO analyzer on modules with known costs.
"""
import json
import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_hlo_analyzer_counts_scan_flops_exactly():
    import jax
    import jax.numpy as jnp
    from repro.launch.hlo_analysis import analyze_hlo

    def f(x, w):
        def body(c, _):
            return c @ w, None
        y, _ = jax.lax.scan(body, x, None, length=4)
        return y

    sds = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    compiled = jax.jit(f).lower(sds, sds).compile()
    costs = analyze_hlo(compiled.as_text())
    assert costs.flops == 4 * 2 * 256 ** 3
    assert costs.traffic > 0


@pytest.mark.slow
@pytest.mark.skipif(not hasattr(__import__("jax").sharding, "AxisType"),
                    reason="the subprocess shim builds meshes with "
                           "jax.sharding.AxisType (jax >= 0.5)")
def test_dryrun_subprocess_small_mesh():
    """dryrun_one must lower+compile a reduced-mesh combo in a fresh
    interpreter (8 fake devices, 2x4 mesh) and report roofline inputs."""
    code = r"""
import os
os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=8'
import json, jax
from repro.launch import mesh as MX
MX.make_production_mesh = lambda multi_pod=False: (
    jax.make_mesh((2,2,2),('pod','data','model'),
                  axis_types=(jax.sharding.AxisType.Auto,)*3) if multi_pod
    else jax.make_mesh((2,4),('data','model'),
                       axis_types=(jax.sharding.AxisType.Auto,)*2))
from repro.launch.dryrun import dryrun_one
rec = dryrun_one('smollm-360m', 'decode_32k', multi_pod=False, verbose=False)
rec2 = dryrun_one('smollm-360m', 'decode_32k', multi_pod=True, verbose=False)
print(json.dumps({'flops': rec['flops_per_device'],
                  'coll': rec['collective_bytes_per_device'],
                  'mp_ok': rec2['flops_per_device'] > 0}))
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=420)
    assert out.returncode == 0, out.stderr[-2000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["flops"] > 0
    assert rec["mp_ok"]


def test_baseline_sweep_artifact_complete():
    """The committed dry-run artifact must cover every eligible combo on
    both meshes (33 x 2 = 66 records, per DESIGN.md long_500k skips)."""
    path = os.path.join(os.path.dirname(__file__), "..",
                        "dryrun_baseline.json")
    if not os.path.exists(path):
        pytest.skip("baseline sweep artifact not present")
    recs = json.load(open(path))
    from repro.configs import ARCH_IDS, INPUT_SHAPES, get_config
    expected = set()
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in INPUT_SHAPES.values():
            if shape.name == "long_500k" and not cfg.subquadratic:
                continue
            expected.add((arch, shape.name, "16x16"))
            expected.add((arch, shape.name, "2x16x16"))
    got = {(r["arch"], r["shape"], r["mesh"]) for r in recs}
    assert expected == got
    for r in recs:
        assert r["flops_per_device"] > 0, (r["arch"], r["shape"])
