"""Pre-jax-import handling of the benchmark ``--devices`` flag.

``--devices N[,M,...]`` asks a benchmark for one row set per device count
(the fleet-sharding scale axis, DESIGN.md §10).  jax locks the host device
count at first backend init, so the flag must be peeked from ``sys.argv``
and folded into ``XLA_FLAGS=--xla_force_host_platform_device_count=max``
BEFORE any jax import — the same trick ``launch/dryrun.py`` uses.  One
process then serves every requested count: a FleetMesh over n <= max
devices just takes the first n.

Honesty note: forcing the host device count splits the host's cores (and
XLA's intra-op threadpools) across ALL rows of the run, including the
``devices=1`` ones — so single-device rows from a ``--devices 1,8`` run
read lower than a pure 1-device process would.  The per-device-count rows
of one run are mutually comparable; the run's ``config.devices`` list and
provenance argv record the split for cross-run comparisons.

Import this module (and call :func:`parse_devices_early`) before jax.
"""
from __future__ import annotations

import os
import sys
from typing import List


def parse_devices_early(argv=None) -> List[int]:
    """Device counts from ``--devices`` (default ``[1]``); forces the host
    platform device count to their max when > 1.  Must run pre-jax-import."""
    argv = list(sys.argv[1:] if argv is None else argv)
    raw = None
    for i, a in enumerate(argv):
        if a == "--devices" and i + 1 < len(argv):
            raw = argv[i + 1]
        elif a.startswith("--devices="):
            raw = a.split("=", 1)[1]
    if not raw:
        return [1]
    counts = sorted({max(int(s), 1) for s in raw.split(",")})
    top = counts[-1]
    if top > 1:
        assert "jax" not in sys.modules, \
            "--devices must be parsed before jax is imported"
        flags = os.environ.get("XLA_FLAGS", "")
        if "xla_force_host_platform_device_count" not in flags:
            os.environ["XLA_FLAGS"] = (
                f"{flags} --xla_force_host_platform_device_count={top}"
            ).strip()
    return counts
