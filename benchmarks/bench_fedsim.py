"""Cohort-engine scaling benchmark: fleet sizes {4, 16, 64, 256}, sfl/asfl.

Compares the vectorized :class:`CohortEngine` federation round — driven
through the declarative front door, ``repro.api.run(ExperimentSpec(...))``
— against the seed per-client Python loop (one jit dispatch + one
``float(loss)`` host sync per client per batch, per-batch host staging,
Python slice/merge optimizer surgery) at EQUAL rounds/local-steps/batches:
both sides consume identical batch streams and make identical cut
decisions, and evaluation is disabled on both, so the measured gap is pure
round-execution overhead.

The default model is the registry's ``mlp9`` (models/mlp_unit.py): small
enough that a local step is milliseconds, which is exactly the regime where
the seed loop's per-dispatch overhead dominates at fleet scale (a
vehicle-side perception model is small; the simulator's job is to scale the
*federation*, not the FLOPs).  ``--model resnet`` runs the paper's ResNet18
instead — on CPU containers that is conv-compute-bound and mostly measures
XLA's conv throughput, not the engine (see DESIGN.md §6).

Timing is post-warmup: ``api.run(spec, timeit=True)`` runs once to compile
every round structure, resets (same seeds => same rate draws => same cuts
=> warm caches), and times only the re-run.  The ``api_overhead_s`` key
measures the front door itself: per-round API time minus a direct
``FederationSim`` call at the same config (fleet 64) — proving the
declarative layer adds no measurable per-round cost.

  PYTHONPATH=src python benchmarks/bench_fedsim.py
  -> BENCH_fedsim.json (repo root) + benchmarks/out/BENCH_fedsim.json
"""
from __future__ import annotations

import argparse
import os
import time
from typing import List, Optional, Tuple

import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

from bench_devices import parse_devices_early

# --devices N[,M,...] runs per-device-count rows; the host device count must
# be forced BEFORE the first jax import (jax locks it on backend init)
DEVICE_COUNTS = parse_devices_early()

import jax
import numpy as np

from bench_io import device_row_key, write_bench
from bench_timing import interleaved_overhead
from repro import api
from repro.core import aggregation
from repro.core.fedsim import FederationSim, SimConfig, _make_opt, \
    make_sfl_batch_step
# re-exported for backward compatibility (promoted to the package in PR 4)
from repro.models.mlp_unit import MLPUnitModel, make_mlp_fleet_data  # noqa: F401


# ------------------------------------------------- seed per-client loop sim
class SeedLoopSim(FederationSim):
    """The seed FederationSim's `_parallel_split_round`, verbatim: a Python
    loop over clients per local step, one jitted dispatch and one
    `float(loss)` host sync per client batch, per-batch `sample_batch`
    staging, Python dict surgery on the shared RSU optimizer state, and
    Python-list unit-wise FedAvg at round end."""

    def __init__(self, *args, **kwargs):
        super().__init__(*args, **kwargs)
        self._sfl_steps = {}

    def _sfl_step(self, cut):
        if cut not in self._sfl_steps:
            self._sfl_steps[cut] = make_sfl_batch_step(self.model, self.cfg,
                                                       cut)
        return self._sfl_steps[cut]

    def _parallel_split_round(self, rnd):
        cfgc = self.cfg
        rates = self._round_rates(rnd)
        participants = set(self._participants(rnd))
        cuts = [max(1, min(c, self.model.n_units - 1))
                for c in self._pick_cuts(rates)]
        opt = _make_opt(cfgc)
        n_units = self.model.n_units

        server_units = [jax.tree.map(lambda a: a, u) for u in self.units]
        head = self.head
        s_opt_full = opt.init({"units": server_units, "head": head})

        def slice_opt(cut):
            out = {}
            for k, v in s_opt_full.items():
                if isinstance(v, dict) and "units" in v:
                    out[k] = {"units": v["units"][cut:], "head": v["head"]}
                else:
                    out[k] = v
            return out

        def merge_opt(new, cut):
            for k, v in new.items():
                if isinstance(v, dict) and "units" in v:
                    s_opt_full[k]["units"] = (
                        list(s_opt_full[k]["units"][:cut]) + list(v["units"]))
                    s_opt_full[k]["head"] = v["head"]
                else:
                    s_opt_full[k] = v

        client_units = [[jax.tree.map(lambda a: a, u)
                         for u in self.units[:cut]] for cut in cuts]
        c_opts = [opt.init(cu) for cu in client_units]

        losses = []
        steps = max(self._local_steps(c) for c in self.clients)
        for s in range(steps):
            for ci, c in enumerate(self.clients):
                if ci not in participants or s >= self._local_steps(c):
                    continue
                cut = cuts[ci]
                step = self._sfl_step(cut)
                batch = c.sample_batch(cfgc.batch_size,
                                       cfgc.seed + rnd * 983 + s * 31 + ci)
                sv = server_units[cut:]
                (client_units[ci], new_sv, head, c_opts[ci], new_s_opt,
                 loss, _) = step(client_units[ci], sv, head, c_opts[ci],
                                 slice_opt(cut), batch)
                server_units[cut:] = list(new_sv)
                merge_opt(new_s_opt, cut)
                losses.append(float(loss))

        unit_replicas = [[] for _ in range(n_units)]
        unit_weights = [[] for _ in range(n_units)]
        for ci, c in enumerate(self.clients):
            if ci not in participants:
                continue
            w = float(len(c))
            for u in range(cuts[ci]):
                unit_replicas[u].append(client_units[ci][u])
                unit_weights[u].append(w)
        for u in range(n_units):
            served = sum(len(c) for ci, c in enumerate(self.clients)
                         if ci in participants and cuts[ci] <= u)
            if served:
                unit_replicas[u].append(server_units[u])
                unit_weights[u].append(float(served))
        self.units = [aggregation.fedavg(unit_replicas[u], unit_weights[u])
                      if unit_replicas[u] else self.units[u]
                      for u in range(n_units)]
        self.head = head
        return self._metrics(rnd, float(np.mean(losses)), cuts, 0.0, 0.0, 0.0)


# ----------------------------------------------------------------- protocol
def _timed_run(sim, repeats: int = 1) -> Tuple[float, float]:
    """Direct-engine twin of ``api.run(..., timeit=repeats)``: warmup run
    (compiles every round structure), then ``repeats`` timed re-runs (reset
    between; min wins — strips scheduler noise).  Returns (warmup seconds,
    seconds per round)."""
    t0 = time.perf_counter()
    sim.run()
    warmup = time.perf_counter() - t0
    best = None
    for _ in range(repeats):
        sim.reset()
        t0 = time.perf_counter()
        hist = sim.run()
        dt = time.perf_counter() - t0
        best = dt if best is None else min(best, dt)
        assert all(np.isfinite(m.loss) for m in hist)
    return warmup, best / len(hist)


def measure_api_overhead(spec, direct, repeats: int = 3) -> dict:
    """Per-round cost of the front door: an engine built by
    ``api.build_engine(spec)`` and driven exactly as ``api.run`` drives it
    (``run(on_round=None)``) vs ``direct``, a hand-constructed engine with
    the same model/data/config (interleaved protocol: bench_timing)."""
    api_eng = api.build_engine(spec)
    out = interleaved_overhead(
        (api_eng, lambda: api_eng.run(on_round=None)),
        (direct, direct.run), repeats)
    return {"fleet": spec.fleet.n_vehicles, **out}


def _spec(model_name: str, scheme: str, n: int, per_client: int,
          local_steps: int, batch: int, rounds: int,
          compilation_cache: Optional[str],
          devices: int = 1) -> api.ExperimentSpec:
    return api.ExperimentSpec(
        model=model_name,
        train=api.TrainConfig(scheme=scheme, rounds=rounds,
                              local_steps=local_steps, batch_size=batch,
                              lr=1e-3, eval_every=0),
        fleet=api.FleetConfig(
            n_vehicles=n, per_vehicle_samples=per_client, test_samples=256,
            data_seed=(n if model_name == "mlp9" else 0)),
        runtime=api.RuntimeConfig(
            compilation_cache_dir=compilation_cache, mesh_devices=devices))


def _row_key(r) -> str:
    return device_row_key(f"{r['scheme']}@{r['n_clients']}", r["devices"])


def bench(sizes: List[int], schemes: List[str], model_kind: str,
          per_client: int, local_steps: int, batch: int, rounds: int,
          seed_loop_max: int,
          compilation_cache: Optional[str] = None,
          device_counts: Tuple[int, ...] = (1,)) -> dict:
    model_name = "mlp9" if model_kind == "mlp" else "resnet18"
    entry = api.model_entry(model_name)
    overhead_fleet = 64 if 64 in sizes else max(sizes)
    results = []
    api_overhead = None
    for devices in device_counts:
        for n in sizes:
            for scheme in schemes:
                spec = _spec(model_name, scheme, n, per_client, local_steps,
                             batch, rounds, compilation_cache, devices)
                res = api.run(spec, timeit=True)
                assert all(np.isfinite(m.loss) for m in res.history)
                t_eng = res.timing["round_s"]
                row = {"scheme": scheme, "n_clients": n, "devices": devices,
                       "mode": res.diagnostics["mode"],
                       "engine_round_s": t_eng,
                       "warmup_s": res.timing["warmup_s"],
                       # fault-plane telemetry (DESIGN.md §13) — trivial
                       # values here (this bench runs clean), kept so the
                       # row schema matches bench_scenarios
                       "survivor_frac": res.totals["survivor_frac"],
                       "lost_update_bytes": res.totals["lost_update_bytes"],
                       "n_dropout": res.totals["n_dropout"],
                       "n_upload_lost": res.totals["n_upload_lost"],
                       "seed_round_s": None, "speedup": None}
                # the seed-loop reference and the api-overhead probe run on
                # the single-device rows only (they measure engine overhead,
                # not the mesh)
                if devices == 1 and scheme in ("sfl", "asfl") \
                        and (n <= seed_loop_max or n == overhead_fleet):
                    clients, test = entry.make_data(
                        n, per_client, spec.fleet.test_samples,
                        spec.fleet.data_seed)
                    cfg = spec.to_sim_config()
                    if n <= seed_loop_max:
                        ref = SeedLoopSim(entry.build(), clients, test, cfg)
                        _, t_ref = _timed_run(ref)
                        row["seed_round_s"] = t_ref
                        row["speedup"] = t_ref / t_eng
                        # both sides consumed identical batch streams & cuts
                        np.testing.assert_allclose(
                            res.history[-1].loss, ref.history[-1].loss,
                            rtol=0.05, atol=0.05)
                    if scheme == "asfl" and n == overhead_fleet:
                        o_rounds = max(rounds, 8)
                        o_spec = _spec(model_name, scheme, n, per_client,
                                       local_steps, batch, o_rounds,
                                       compilation_cache)
                        api_overhead = measure_api_overhead(
                            o_spec, FederationSim(entry.build(), clients,
                                                  test,
                                                  o_spec.to_sim_config()))
                results.append(row)
                print(f"{scheme:5s} n={n:4d} dev={devices} "
                      f"mode={row['mode']:6s} "
                      f"engine={t_eng*1e3:9.1f} ms/round"
                      + (f"  seed={row['seed_round_s']*1e3:9.1f} ms/round"
                         f"  speedup={row['speedup']:.1f}x"
                         if row["speedup"] else ""), flush=True)
    return {
        "config": {"model": model_kind, "per_client": per_client,
                   "local_steps": local_steps, "batch": batch,
                   "rounds": rounds, "backend": jax.default_backend(),
                   "devices": list(device_counts),
                   "compilation_cache": compilation_cache,
                   "driver": "repro.api.run"},
        "warmup_total_s": float(sum(r["warmup_s"] for r in results)),
        # NOTE: cache-hit detection must happen BEFORE the runs populate the
        # cache dir — main() fills this in; None means "caller to decide"
        "compile_cache_hit": None,
        "rounds_per_s": {_row_key(r): 1.0 / r["engine_round_s"]
                         for r in results},
        "api_overhead_s": (api_overhead["api_overhead_s"]
                           if api_overhead else None),
        "api_overhead": api_overhead,
        "results": results,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--sizes", default="4,16,64,256")
    ap.add_argument("--schemes", default="sfl,asfl")
    ap.add_argument("--model", default="mlp", choices=["mlp", "resnet"])
    ap.add_argument("--per-client", type=int, default=64)
    ap.add_argument("--local-steps", type=int, default=2)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--rounds", type=int, default=2)
    ap.add_argument("--seed-loop-max", type=int, default=256,
                    help="largest fleet to also run the seed loop at")
    ap.add_argument("--compilation-cache", default=None, metavar="DIR",
                    help="persistent XLA compilation cache directory")
    ap.add_argument("--devices", default="1", metavar="N[,M...]",
                    help="device counts to bench (mesh_devices rows; on "
                         "CPU the host device count is forced pre-import "
                         "— parsed by bench_devices before jax loads)")
    args = ap.parse_args()
    sizes = [int(s) for s in args.sizes.split(",")]
    schemes = args.schemes.split(",")

    from repro.configs.base import cache_dir_is_warm
    cache_hit_at_start = cache_dir_is_warm(args.compilation_cache)
    out = bench(sizes, schemes, args.model, args.per_client,
                args.local_steps, args.batch, args.rounds,
                args.seed_loop_max, args.compilation_cache,
                device_counts=tuple(DEVICE_COUNTS))
    out["compile_cache_hit"] = cache_hit_at_start

    key = [r for r in out["results"]
           if r["scheme"] == "asfl" and r["n_clients"] == 64 and r["speedup"]]
    if key:
        out["asfl_64_speedup"] = key[0]["speedup"]
        out["asfl_64_speedup_ge_5x"] = key[0]["speedup"] >= 5.0
        print(f"\nasfl @ 64 vehicles: {key[0]['speedup']:.1f}x "
              f"(>=5x: {out['asfl_64_speedup_ge_5x']})")
    if out["api_overhead"]:
        o = out["api_overhead"]
        print(f"api overhead @ fleet {o['fleet']}: "
              f"{o['api_overhead_s']*1e3:+.2f} ms/round "
              f"(api {o['api_round_s']*1e3:.1f} vs direct "
              f"{o['direct_round_s']*1e3:.1f})")

    write_bench("BENCH_fedsim", out, "benchmarks/bench_fedsim.py")


if __name__ == "__main__":
    main()
