"""Paper-faithful federation simulator: CL / FL / SL / SFL(fixed cut) / ASFL.

This engine reproduces the paper's Fig. 5 case study: ResNet18-class models,
4 vehicles, non-IID (6-of-10 labels, power-law sizes), lr 1e-4, batch 16,
local epochs 5.  The SFL message flow is realised explicitly — vehicle-side
forward, smashed-data upload, RSU-side forward/backward, cut-layer-gradient
download, vehicle-side backward — via jax.vjp, NOT one composite jax.grad,
so the implementation is structurally the paper's Fig. 3 workflow (their
mathematical equality is asserted in tests/test_sfl_math.py).

Scaling design (DESIGN.md §6): a federation round is compiled as ONE jitted
program by the :class:`CohortEngine`.  Clients are bucketed by cut layer and
stacked along a leading replica axis; local steps are driven by `lax.scan`
over pre-staged batch-index tensors (batches are gathered from the on-device
:class:`StackedClients` tensors inside the scan); losses are accumulated
on-device and fetched once per round.  Within a bucket the vehicle-side
compute runs either `jax.vmap`-vectorized across replicas (accelerators) or
as a fused `lax.scan` (CPU, where XLA lowers per-replica-filter convolutions
to slow grouped convs) — both schedules compute the same math.  The seed's
4-client Python loop (one jit dispatch + one `float(loss)` host sync per
client per batch) is gone; the 4-vehicle paper case study is just a small
configuration of the same engine.

The engine is generic over a :class:`UnitModel` (any stack of units with a
head); ResNet18 (the paper's model) and the small transformer wrapper both
implement it.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import (Any, Callable, Dict, List, Optional, Protocol, Sequence,
                    Tuple, Union)

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.configs.base import enable_compilation_cache
from repro.core import adaptive, aggregation, channel, compression, cost
from repro.core import faults, fleet_sharding, streaming
from repro.core.fleet_sharding import VEH_AXIS as MESH_AXIS, FLEET_AXES, FleetMesh
from repro.core.superstep import (SERVER_SCHEDULES, SUPERSTEP_LAYOUTS,
                                  SuperStepPrograms)
from repro.data.pipeline import (ClientDataset, DoubleBuffer, StackedClients,
                                 epoch_batch_indices, sample_batch_indices,
                                 stack_clients)
from repro import optim

Params = Any


class UnitModel(Protocol):
    name: str
    n_units: int

    def init(self, key) -> Tuple[List[Params], Params]: ...
    def apply_units(self, units: List[Params], x, start: int): ...
    def head_loss(self, head: Params, feats, labels): ...
    def head_predict(self, head: Params, feats): ...
    def profile(self) -> cost.SplitProfile: ...


class ResNetModel:
    """The paper's ResNet18 over 32x32x3 inputs."""
    name = "resnet18"
    # conv gradients inside lax.scan bodies hit XLA:CPU's slow generic path;
    # the cohort engine unrolls replicas for this model on CPU (DESIGN.md §6)
    scan_friendly = False

    def __init__(self, n_classes: int = 10):
        from repro.models import resnet as R
        self.R = R
        self.n_units = R.N_UNITS
        self.n_classes = n_classes

    def init(self, key):
        p = self.R.init_resnet18(key, self.n_classes)
        return list(p["units"]), p["head"]

    def apply_units(self, units, x, start):
        for j, u in enumerate(units):
            x = self.R._apply_unit(u, x, start + j)
        return x

    def head_loss(self, head, feats, labels):
        logits = jnp.mean(feats, axis=(1, 2)) @ head["w"] + head["b"]
        logz = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
        return jnp.mean(logz - gold), logits

    def head_predict(self, head, feats):
        return jnp.mean(feats, axis=(1, 2)) @ head["w"] + head["b"]

    def profile(self):
        return cost.resnet_profile()


# the valid values of every categorical SimConfig field — construction
# rejects anything else (with the allowed values listed) instead of failing
# deep inside engine dispatch.  The api layer (repro.api) re-validates the
# *combinations* per engine at spec-build time.
SCHEMES = ("cl", "fl", "sl", "sfl", "asfl")
ADAPTIVE_STRATEGIES = ("paper", "paper-literal", "latency", "energy",
                       "memory", "residence")
SLOT_CAPACITIES = ("pow2", "tight8")
COHORT_MODES = ("auto", "vmap", "scan", "unroll")
OPTIMIZERS = ("adam", "sgd", "momentum")
WIRE_SCHEMES = compression.WIRE_SCHEMES  # none | int8 | topk_int8

# which adaptive strategies each engine can execute (the fused scenario
# engine runs cut selection on-device; only the traced strategies are wired)
FEDERATION_STRATEGIES = ("paper", "paper-literal", "latency", "energy",
                         "memory")
SCENARIO_STRATEGIES = ("paper", "paper-literal", "residence")


@dataclasses.dataclass
class SimConfig:
    scheme: str = "asfl"          # cl | fl | sl | sfl | asfl
    cut: int = 4                  # fixed cut for sl/sfl
    n_clients: int = 4
    batch_size: int = 16          # paper: 16
    local_epochs: int = 5         # paper: 5
    local_steps: Optional[int] = None  # overrides epochs if set
    lr: float = 1e-4              # paper: 1e-4
    rounds: int = 10
    seed: int = 0
    optimizer: str = "adam"
    # paper | paper-literal | latency | energy | memory
    adaptive_strategy: str = "paper"
    compress_smashed: bool = False
    # wire scheme at the cut boundary (DESIGN.md §11): "none" ships dense
    # fp32 smashed tensors; "int8" per-group quantisation (both directions);
    # "topk_int8" top-k sparsify + int8 pack with per-vehicle error-feedback
    # residuals in the superstep engine (stateless in the cohort engine).
    # compress_smashed=True is the legacy spelling of wire="int8".
    wire: str = "none"
    # keep-fraction per quantisation group for wire="topk_int8"
    wire_k: float = compression.WIRE_K
    server_flops: float = 2e12    # RSU (GPU-class)
    round_interval_s: float = 5.0
    # mobility: vehicles outside RSU coverage at round start skip the round
    # (the paper's §II-C training-interruption challenge).  Legacy spelling
    # of fault_coverage=True — see fault_config()
    mobility_dropout: bool = False
    # fault plane (core/faults.py, DESIGN.md §13): seeded stochastic failure
    # processes.  All-zero defaults are gated out at Python level, so the
    # compiled programs are byte-identical to a no-fault build
    fault_coverage: bool = False      # deterministic §II-C in-range test
    fault_dropout: float = 0.0        # P[vehicle drops mid-round]
    fault_upload_loss: float = 0.0    # P[update lost after full local work]
    fault_straggler: float = 0.0      # >0: deadline factor x residence
    fault_rsu_outage: float = 0.0     # P[RSU misses a round] (scenario only)
    fault_staleness_discount: float = 0.5  # weight for banked late updates
    fault_seed: int = 0
    # streaming plane (core/streaming.py, DESIGN.md §14): seeded presence
    # churn (any schedule) + the buffered-asynchronous streaming schedule's
    # merge policy.  All-defaults are gated out at Python level, so the
    # compiled programs are byte-identical to a no-streaming build
    stream_buffer_size: int = 4       # B pending deltas per RSU per merge
    stream_churn_rate: float = 0.0    # P[vehicle toggles presence per round]
    stream_kernel: str = "constant"   # staleness discount: constant | poly
    stream_alpha: float = 0.5         # poly kernel exponent 1/(1+s)**alpha
    stream_seed: int = 0
    # intra-bucket schedule: "vmap" vectorizes client replicas across the
    # stacked axis (accelerators), "scan" fuses them sequentially (CPU);
    # "auto" picks by platform.  Same math either way (DESIGN.md §6).
    cohort_parallel: str = "auto"
    # evaluate the global model every k rounds (0 = never; test_acc is NaN
    # for skipped rounds).  Evaluation itself is jitted.
    eval_every: int = 1
    # ScenarioEngine server schedule (DESIGN.md §8): "sequential" keeps the
    # source paper's §III-B semantics (the RSU updates its shared server
    # model on every client batch, in cohort order); "parallel" is the
    # companion ASFL paper's parallel server-side execution
    # (arXiv:2405.18707) — one |D_n|-weighted mean-gradient server step per
    # local step, with every matmul batched over the (RSU, vehicle) axes;
    # "streaming" rides the parallel machinery but commits each round's
    # cohort delta into a capacity-B StreamBuffer and advances the edge
    # model only when the buffer fires, via staleness-weighted survivor
    # FedAvg (core/streaming.py, DESIGN.md §14)
    server_schedule: str = "sequential"
    # per-RSU slot-capacity rounding for the fused programs: "pow2" (the
    # bucket-signature scheme — most stable compile cache) or "tight8"
    # (next multiple of 8 — up to ~40% fewer padded slots at fleet scale,
    # a few more signatures under heavy cohort churn)
    slot_capacity: str = "pow2"
    # super-step execution layout (DESIGN.md §12): "ragged" sizes per-slot
    # client planes / optimizer moments / EF wire residuals to the
    # strategy's static max-cut prefix and (parallel schedule) compacts the
    # slot axis to occupied slots with segment-sum per-RSU aggregation;
    # "dense" keeps full-plane masked replicas over per-RSU padded tables.
    # Bit-for-bit identical for sgd on both schedules (tests/test_ragged.py)
    superstep_layout: str = "ragged"
    # rounds fused per ScenarioEngine super-step (DESIGN.md §8): K rounds of
    # mobility, scheduling, training, handover, and edge/cloud aggregation
    # execute as ONE compiled lax.scan with donated carries; 1 = one
    # dispatch per round (same program, scan length 1)
    superstep: int = 1
    # persistent XLA compilation cache directory (None = leave the process
    # config untouched): second runs of the same programs skip compilation
    # entirely.  NOTE: JAX's cache config is PROCESS-GLOBAL — setting it on
    # any engine latches it on for every compile in the process, and the
    # last configured directory wins (configs.base.enable_compilation_cache)
    compilation_cache_dir: Optional[str] = None
    # device mesh over the fleet (core/fleet_sharding.py, DESIGN.md §10,
    # §15): mesh_devices > 1 runs the compiled round / super-step programs
    # under shard_map across that many devices; 1 (the default) is the
    # unsharded single-device path, bit-identical to the pre-mesh engines;
    # "auto" picks 1 vs every addressable device from an occupied-slots-
    # per-device floor, so small fleets never pay the sharding tax
    mesh_devices: Union[int, str] = 1
    # which fleet dimension the mesh partitions: "vehicle" (cohort-engine
    # slot axis), "rsu" (super-step RSU axis), "grid" (2-D rsu x vehicle —
    # the super-step shards BOTH its axes), or "auto" (the engine's
    # natural axis)
    fleet_axis: str = "auto"
    # 2-D mesh factorization (DESIGN.md §15): "auto" derives (rsu, vehicle)
    # device counts from fleet_axis ("vehicle" -> (1, n), "rsu" -> (n, 1),
    # "grid" -> the balanced power-of-2 split), or an explicit "RxV"
    # string whose product must equal mesh_devices
    mesh_shape: str = "auto"
    # slot-capacity paging (DESIGN.md §15): > 0 caps the per-device
    # CONCURRENT slot window of the ragged parallel/streaming super-step —
    # cohorts beyond it page through the compacted axis in fixed windows
    # on the donated carry (more planned slots never raises, and paging
    # churn is data, not a program signature).  0 = unpaged
    page_slots: int = 0
    # presence-churn source (DESIGN.md §15): "markov" is the seeded toggle
    # chain (stream_churn_rate); "mobility" derives departures from the
    # scenario's coverage state (serving_rsu == -1) — a vehicle leaving
    # coverage departs the stream, a vehicle entering it re-registers
    # (synchronous schedules admit it next round; streaming immediately)
    stream_churn_source: str = "markov"

    def __post_init__(self):
        for field, allowed in (("scheme", SCHEMES),
                               ("adaptive_strategy", ADAPTIVE_STRATEGIES),
                               ("server_schedule", SERVER_SCHEDULES),
                               ("slot_capacity", SLOT_CAPACITIES),
                               ("superstep_layout", SUPERSTEP_LAYOUTS),
                               ("cohort_parallel", COHORT_MODES),
                               ("fleet_axis", FLEET_AXES),
                               ("optimizer", OPTIMIZERS),
                               ("wire", WIRE_SCHEMES)):
            value = getattr(self, field)
            if value not in allowed:
                raise ValueError(
                    f"SimConfig.{field}={value!r} is not valid; allowed "
                    f"values: {' | '.join(allowed)}")
        for field, floor in (("n_clients", 1), ("batch_size", 1),
                             ("local_epochs", 1), ("rounds", 1),
                             ("superstep", 1), ("cut", 1), ("eval_every", 0),
                             ("page_slots", 0)):
            value = getattr(self, field)
            if not isinstance(value, int) or value < floor:
                raise ValueError(
                    f"SimConfig.{field}={value!r} is not valid; expected an "
                    f"int >= {floor}")
        md = self.mesh_devices
        if not (md == "auto" or (isinstance(md, int) and md >= 1)):
            raise ValueError(
                f"SimConfig.mesh_devices={md!r} is not valid; expected an "
                f"int >= 1 or 'auto'")
        if self.stream_churn_source not in streaming.CHURN_SOURCES:
            raise ValueError(
                f"SimConfig.stream_churn_source="
                f"{self.stream_churn_source!r} is not valid; allowed "
                f"values: {' | '.join(streaming.CHURN_SOURCES)}")
        if self.mesh_shape != "auto":
            fleet_sharding.parse_shape_spec(self.mesh_shape)
        if self.local_steps is not None and self.local_steps < 1:
            raise ValueError(
                f"SimConfig.local_steps={self.local_steps!r} is not valid; "
                f"expected None (use local_epochs) or an int >= 1")
        if not 0.0 < self.wire_k <= 1.0:
            raise ValueError(
                f"SimConfig.wire_k={self.wire_k!r} is not valid; expected "
                f"a keep-fraction in (0, 1]")
        if self.compress_smashed and self.wire not in ("none", "int8"):
            raise ValueError(
                f"SimConfig.compress_smashed=True conflicts with "
                f"wire={self.wire!r}: compress_smashed is the legacy "
                f"spelling of wire='int8' — set wire alone")
        if self.mobility_dropout and self.fault_coverage:
            raise ValueError(
                "SimConfig.mobility_dropout=True conflicts with "
                "fault_coverage=True: mobility_dropout is the legacy "
                "spelling of fault_coverage — set fault_coverage alone")
        self.fault_config()  # rate/discount validation (FaultConfig raises)
        self.stream_config()  # kernel/rate validation (StreamConfig raises)

    def wire_scheme(self) -> str:
        """The effective cut-boundary wire: compress_smashed=True is kept as
        a working alias for wire="int8" (pre-wire configs still run, with
        identical numerics and now-honest byte accounting)."""
        if self.wire == "none" and self.compress_smashed:
            return "int8"
        return self.wire

    def fault_config(self) -> faults.FaultConfig:
        """The effective fault plane (core/faults.py, DESIGN.md §13).
        ``mobility_dropout=True`` is kept as a working alias for
        ``fault_coverage=True`` — the same shim pattern as
        ``compress_smashed`` → ``wire="int8"``."""
        return faults.FaultConfig(
            dropout_rate=self.fault_dropout,
            upload_loss_rate=self.fault_upload_loss,
            straggler_factor=self.fault_straggler,
            rsu_outage_rate=self.fault_rsu_outage,
            staleness_discount=self.fault_staleness_discount,
            coverage=self.mobility_dropout or self.fault_coverage,
            seed=self.fault_seed)

    def stream_config(self) -> streaming.StreamConfig:
        """The effective streaming plane (core/streaming.py, DESIGN.md
        §14)."""
        return streaming.StreamConfig(
            buffer_size=self.stream_buffer_size,
            churn_rate=self.stream_churn_rate,
            kernel=self.stream_kernel,
            alpha=self.stream_alpha,
            seed=self.stream_seed,
            churn_source=self.stream_churn_source)


@dataclasses.dataclass
class RoundMetrics:
    round: int
    loss: float
    test_acc: float
    comm_bytes: float
    sim_time_s: float
    energy_j: float
    cuts: List[int]
    # fault-plane telemetry (DESIGN.md §13); the defaults are the no-fault
    # values, so pre-fault code paths need no changes
    n_dropout: int = 0
    n_upload_lost: int = 0
    survivor_frac: float = 1.0
    lost_update_bytes: float = 0.0


def _make_opt(cfg: SimConfig):
    return optim.from_name(cfg.optimizer, cfg.lr)


def _wire_transform(cfg: SimConfig, x):
    """The cohort-engine wire site: what a smashed activation (or cut-layer
    gradient) looks like after one trip over the configured wire.  The
    cohort engine is stateless per batch, so topk_int8 runs WITHOUT error
    feedback here; the superstep engine carries the per-vehicle residual
    plane (core/superstep.py).  wire="none" is the identity — no ops are
    added, so pre-wire jaxprs are unchanged."""
    wire = cfg.wire_scheme()
    if wire == "int8":
        return compression.fake_quant(x)
    if wire == "topk_int8":
        return compression.wire_fake(x, cfg.wire_k)
    return x


# --------------------------------------------------------------------------
# jitted single-client batch step (kept as the oracle: tests/test_sfl_math.py
# asserts it computes composite-loss gradients; the parity suite and the
# benchmark replay the seed per-client loop with it against the cohort engine)
# --------------------------------------------------------------------------

def make_sfl_batch_step(model: UnitModel, cfg: SimConfig, cut: int):
    """One SFL batch for one client at a given cut (static).  Returns the
    explicit message-flow step (client fwd -> server fwd/bwd -> client bwd)."""
    opt = _make_opt(cfg)

    @jax.jit
    def step(client_units, server_units, head, c_opt, s_opt, batch):
        x, y = batch["images"], batch["labels"]

        def client_fwd(cu):
            return model.apply_units(cu, x, 0)

        smashed, client_vjp = jax.vjp(client_fwd, client_units)
        sm_in = _wire_transform(cfg, smashed)

        def server_loss(sv, sm):
            feats = model.apply_units(sv["units"], sm, cut)
            loss, logits = model.head_loss(sv["head"], feats, y)
            return loss, logits

        sv_tree = {"units": server_units, "head": head}
        (loss, logits), grads = jax.value_and_grad(
            server_loss, argnums=(0, 1), has_aux=True)(sv_tree, sm_in)
        g_server, g_smashed = grads
        g_smashed = _wire_transform(cfg, g_smashed)  # downlink wire, too
        (g_client,) = client_vjp(g_smashed)

        upd_c, c_opt = opt.update(g_client, c_opt, client_units)
        client_units = optim.apply_updates(client_units, upd_c)
        upd_s, s_opt = opt.update(g_server, s_opt, sv_tree)
        sv_tree = optim.apply_updates(sv_tree, upd_s)
        acc = jnp.mean((jnp.argmax(logits, -1) == y).astype(jnp.float32))
        return client_units, sv_tree["units"], sv_tree["head"], c_opt, s_opt, loss, acc

    return step


# --------------------------------------------------------------------------
# evaluation (jitted; one compiled program per slice shape, cached per model)
# --------------------------------------------------------------------------

def make_eval_fn(model: UnitModel):
    # cached on the model instance (a WeakKeyDictionary would never evict:
    # the jitted fn closes over `model`, pinning its own key; the attribute
    # cycle model -> fn -> model is ordinary gc-collectable garbage)
    fn = getattr(model, "_eval_fn", None)
    if fn is None:
        @jax.jit
        def fn(units, head, x, y):
            feats = model.apply_units(units, x, 0)
            logits = model.head_predict(head, feats)
            return jnp.sum(jnp.argmax(logits, -1) == y)
        model._eval_fn = fn
    return fn


def evaluate(model: UnitModel, units, head, test: Dict[str, jnp.ndarray],
             batch: int = 256) -> float:
    fn = make_eval_fn(model)
    n = test["labels"].shape[0]
    correct = []
    total = 0
    for i in range(0, n, batch):
        x = test["images"][i:i + batch]
        y = test["labels"][i:i + batch]
        correct.append(fn(units, head, x, y))
        total += int(np.prod(y.shape))
    return int(sum(correct)) / max(total, 1)


# --------------------------------------------------------------------------
# cohort engine internals
# --------------------------------------------------------------------------

def _pow2(n: int) -> int:
    return 1 << max(n - 1, 0).bit_length()


def _select(mask, new, old):
    """tree_map(where): pick `new` where mask else `old`.  mask broadcasts
    from the left (a (n,) mask over stacked (n, ...) leaves; a scalar mask
    over whole trees)."""
    mask = jnp.asarray(mask)

    def f(a, b):
        m = mask.reshape(mask.shape + (1,) * (a.ndim - mask.ndim))
        return jnp.where(m, a, b)

    return jax.tree.map(f, new, old)


def _gather_batch(data, idx):
    """data (n, L, ...), idx (n, B) -> (n, B, ...): per-replica batch gather
    inside the scanned round (no host staging per batch)."""
    return jax.vmap(lambda d, i: d[i])(data, idx)


def _suffix_state(state, cut):
    """Slice the RSU optimizer state (whose leaves mirror the full
    {"units": [...], "head": ...} tree) down to the units after `cut`.
    This is static pytree surgery at trace time — the stacked-state
    replacement for the seed's per-batch Python slice_opt/merge_opt."""
    out = {}
    for k, v in state.items():
        if isinstance(v, dict) and "units" in v:
            out[k] = {"units": list(v["units"][cut:]), "head": v["head"]}
        else:
            out[k] = v
    return out


def _merge_state(full, suffix, cut):
    out = {}
    for k, v in full.items():
        if isinstance(v, dict) and "units" in v:
            out[k] = {"units": list(v["units"][:cut]) + list(suffix[k]["units"]),
                      "head": suffix[k]["head"]}
        else:
            out[k] = suffix[k]
    return out


@dataclasses.dataclass
class RoundPlan:
    """Host-side staging of one federation round.  Static fields key the
    compile cache; array fields are the per-round inputs of the compiled
    program (so rounds with the same structure never retrace)."""
    cuts_sig: Tuple[Tuple[int, int], ...]      # ((cut, n_padded), ...) static
    steps: int                                 # static
    bucket_rows: List[np.ndarray]              # (n_pad,) client row per slot
    bucket_idx: List[np.ndarray]               # (steps, n_pad, B)
    bucket_mask: List[np.ndarray]              # (steps, n_pad) bool
    bucket_w: List[np.ndarray]                 # (n_pad,) aggregation weights
    server_unit_w: np.ndarray                  # (n_units,) RSU copy weights


class CohortEngine:
    """Compiles and runs whole federation rounds with one (or a few) jitted
    dispatches instead of a Python loop per client per batch.

    One instance per simulation: it owns the stacked client data (device
    resident, staged once) and a compile cache keyed by round structure
    (bucket cuts/sizes, local steps, batch).  See DESIGN.md §6 for the
    equivalence argument with the seed per-client loop.

    Intra-bucket schedules (same math, different compilation):
      * "vmap"   — vehicle-side compute vectorized across the stacked replica
                   axis, local steps scanned.  The accelerator schedule.
      * "scan"   — replicas AND steps fused into nested lax.scans: one
                   dispatch per round.  The CPU schedule for matmul-dominated
                   models (transformer units, MLPs).
      * "unroll" — replicas unrolled inside a per-step program, Python loop
                   over steps.  XLA:CPU lowers convolution *gradients* inside
                   while-loop bodies (and per-replica-filter convs, i.e.
                   vmapped client backward passes) to a slow generic path —
                   ~20-45x slower than straight-line code — so conv models on
                   CPU keep convs out of while bodies entirely.  Still one
                   dispatch per step (not per client-batch) and zero host
                   syncs inside the round.

    "auto" picks vmap on accelerators; on CPU, scan when the model declares
    ``scan_friendly`` else unroll.

    With a vehicle-axis :class:`~repro.core.fleet_sharding.FleetMesh`
    (``cfg.mesh_devices > 1``, or an explicit ``mesh=``), the split and FL
    round programs run under ``shard_map``: bucket slots are padded to
    device multiples and sharded, client-side compute and optimizer state
    stay shard-local, the shared RSU state is replicated (it consumes the
    all-gathered smashed batches in canonical slot order, preserving paper
    §III-B sequential semantics), and the unit-wise FedAvg is a psum'd
    weighted all-reduce (DESIGN.md §10).  The sharded cohort schedule IS
    the vmap schedule — ``scan``/``unroll`` serialize the very axis the
    mesh partitions and are rejected."""

    def __init__(self, model: UnitModel, cfg: SimConfig,
                 clients: Sequence[ClientDataset],
                 mesh: Optional[FleetMesh] = None):
        self.model = model
        self.cfg = cfg
        self.opt = _make_opt(cfg)
        self.fleet_mesh = mesh if mesh is not None \
            else fleet_sharding.from_config(cfg, "federation",
                                            fleet_size=len(clients))
        if self.fleet_mesh is not None and self.fleet_mesh.axis != "vehicle":
            raise ValueError(
                f"CohortEngine shards the vehicle axis; got a FleetMesh "
                f"over {self.fleet_mesh.axis!r} (fleet_axis='vehicle' or "
                f"'auto')")
        self.stacked: StackedClients = stack_clients(clients)
        if self.fleet_mesh is not None:
            self.stacked = self.fleet_mesh.place_stacked(self.stacked)
        self._programs: Dict[Any, Callable] = {}
        mode = cfg.cohort_parallel
        if self.fleet_mesh is not None:
            if mode in ("scan", "unroll"):
                raise ValueError(
                    f"cohort_parallel={mode!r} serializes the replica axis "
                    f"the mesh shards; with mesh_devices > 1 use 'vmap' "
                    f"(or 'auto')")
            mode = "vmap"
        elif mode == "auto":
            if jax.default_backend() == "cpu":
                mode = "scan" if getattr(model, "scan_friendly", False) \
                    else "unroll"
            else:
                mode = "vmap"
        assert mode in ("vmap", "scan", "unroll"), mode
        self.mode = mode

    def slot_pad(self, n: int) -> int:
        """Bucket slot-count padding: pow2 (the compile-cache signature
        scheme) then up to a device multiple so every shard holds the same
        number of slots.  Padded slots carry zero weight — inert."""
        p = _pow2(n)
        return self.fleet_mesh.pad(p) if self.fleet_mesh is not None else p

    # ---- the shared SFL message-flow math (one client batch) ---------
    def _sfl_client_batch(self, cut, sv, so, cu_i, co_i, x_i, y_i):
        """Explicit message flow for one client batch against the shared
        RSU state: client fwd -> smashed -> server fwd/bwd -> cut-gradient
        -> client bwd.  Returns updated (sv, so, cu, co, loss)."""
        model, opt, cfg = self.model, self.opt, self.cfg

        def client_fwd(c):
            return model.apply_units(c, x_i, 0)

        smashed, cvjp = jax.vjp(client_fwd, cu_i)
        sm_in = _wire_transform(cfg, smashed)

        def server_loss(svt, sm):
            feats = model.apply_units(svt["units"], sm, cut)
            loss, logits = model.head_loss(svt["head"], feats, y_i)
            return loss, logits

        (loss, _), grads = jax.value_and_grad(
            server_loss, argnums=(0, 1), has_aux=True)(sv, sm_in)
        g_sv, g_sm = grads
        g_sm = _wire_transform(cfg, g_sm)
        (g_cu,) = cvjp(g_sm)
        upd_c, co2 = opt.update(g_cu, co_i, cu_i)
        cu2 = optim.apply_updates(cu_i, upd_c)
        upd_s, so2 = opt.update(g_sv, so, sv)
        sv2 = optim.apply_updates(sv, upd_s)
        return sv2, so2, cu2, co2, loss

    def _full_batch(self, tree, ost, x_i, y_i):
        """One full-model (CL / FL local) batch step."""
        model, opt = self.model, self.opt

        def loss_fn(t):
            feats = model.apply_units(t["units"], x_i, 0)
            loss, logits = model.head_loss(t["head"], feats, y_i)
            return loss, logits

        (loss, _), g = jax.value_and_grad(loss_fn, has_aux=True)(tree)
        upd, ost2 = opt.update(g, ost, tree)
        return optim.apply_updates(tree, upd), ost2, loss

    # ---- intra-bucket schedules --------------------------------------
    def _bucket_scan(self, cut, sv, so, cu, co, x, y, msk):
        """Fused sequential schedule: one lax.scan over the bucket's client
        axis; the body is the full message flow.  Exactly the seed loop's
        update order for this bucket."""
        def body(carry, inp):
            sv, so = carry
            cu_i, co_i, x_i, y_i, act = inp
            sv2, so2, cu2, co2, loss = self._sfl_client_batch(
                cut, sv, so, cu_i, co_i, x_i, y_i)
            sv = _select(act, sv2, sv)
            so = _select(act, so2, so)
            cu2 = _select(act, cu2, cu_i)
            co2 = _select(act, co2, co_i)
            return (sv, so), (cu2, co2, jnp.where(act, loss, 0.0))

        (sv, so), (cu, co, losses) = lax.scan(body, (sv, so),
                                              (cu, co, x, y, msk))
        return cu, co, sv, so, losses

    def _bucket_unroll(self, cut, sv, so, cu, co, x, y, msk):
        """Unrolled schedule: same client order and math as _bucket_scan,
        emitted as straight-line code (fast conv grads on XLA:CPU)."""
        n_pad = msk.shape[0]
        cus, cos, losses = [], [], []
        for i in range(n_pad):
            cu_i = jax.tree.map(lambda a: a[i], cu)
            co_i = jax.tree.map(lambda a: a[i], co)
            sv2, so2, cu2, co2, loss = self._sfl_client_batch(
                cut, sv, so, cu_i, co_i, x[i], y[i])
            act = msk[i]
            sv = _select(act, sv2, sv)
            so = _select(act, so2, so)
            cus.append(_select(act, cu2, cu_i))
            cos.append(_select(act, co2, co_i))
            losses.append(jnp.where(act, loss, 0.0))
        cu = jax.tree.map(lambda *a: jnp.stack(a), *cus)
        co = jax.tree.map(lambda *a: jnp.stack(a), *cos)
        return cu, co, sv, so, jnp.stack(losses)

    def _server_scan_body(self, cut):
        """The shared-RSU consume step of the vmap schedule: one smashed
        batch against the shared server state, emitting the cut-layer
        gradient (shared by the sharded and unsharded vmap schedules — the
        sequence of ops must stay identical between them)."""
        model, opt, cfg = self.model, self.opt, self.cfg

        def body(carry, inp):
            sv, so = carry
            sm, y_i, act = inp

            def server_loss(svt, s):
                feats = model.apply_units(svt["units"], s, cut)
                loss, logits = model.head_loss(svt["head"], feats, y_i)
                return loss, logits

            (loss, _), grads = jax.value_and_grad(
                server_loss, argnums=(0, 1), has_aux=True)(sv, sm)
            g_sv, g_sm = grads
            g_sm = _wire_transform(cfg, g_sm)
            upd_s, so2 = opt.update(g_sv, so, sv)
            sv2 = optim.apply_updates(sv, upd_s)
            sv = _select(act, sv2, sv)
            so = _select(act, so2, so)
            g_sm = jnp.where(act, g_sm, jnp.zeros_like(g_sm))
            return (sv, so), (g_sm, jnp.where(act, loss, 0.0))

        return body

    def _bucket_vmap(self, cut, sv, so, cu, co, x, y, msk):
        """Vectorized schedule: vehicle-side fwd/bwd vmapped across the
        stacked replica axis; the shared RSU state still consumes the
        smashed batches sequentially (paper §III-B semantics), via scan."""
        model, cfg = self.model, self.cfg

        def client_fwd(cu_all):
            return jax.vmap(lambda c, xb: model.apply_units(c, xb, 0))(cu_all, x)

        smashed, cvjp = jax.vjp(client_fwd, cu)
        sm_in = _wire_transform(cfg, smashed)

        (sv, so), (g_sm, losses) = lax.scan(self._server_scan_body(cut),
                                            (sv, so), (sm_in, y, msk))
        (g_cu,) = cvjp(g_sm)
        upd, co2 = jax.vmap(self.opt.update)(g_cu, co, cu)
        cu2 = optim.apply_updates(cu, upd)
        cu = _select(msk, cu2, cu)
        co = _select(msk, co2, co)
        return cu, co, sv, so, losses

    def _bucket_vmap_sharded(self, cut, sv, so, cu, co, x, y, msk):
        """The vmap schedule inside a vehicle-axis ``shard_map`` shard:
        client-side fwd/bwd and optimizer updates run on this shard's
        slots only; the smashed batches (and labels/masks) are all-gathered
        so every shard replays the IDENTICAL shared-RSU scan over the full
        cohort in canonical slot order — the server state stays replicated
        by construction, paper §III-B update order survives sharding, and
        each shard slices back exactly its slots' cut-layer gradients.
        Returns full-cohort losses (replicated)."""
        model, cfg = self.model, self.cfg
        n_loc = msk.shape[0]

        def client_fwd(cu_all):
            return jax.vmap(lambda c, xb: model.apply_units(c, xb, 0))(cu_all, x)

        smashed, cvjp = jax.vjp(client_fwd, cu)
        sm_in = _wire_transform(cfg, smashed)
        sm_all = lax.all_gather(sm_in, MESH_AXIS, tiled=True)
        y_all = lax.all_gather(y, MESH_AXIS, tiled=True)
        msk_all = lax.all_gather(msk, MESH_AXIS, tiled=True)

        (sv, so), (g_sm_all, losses) = lax.scan(self._server_scan_body(cut),
                                                (sv, so),
                                                (sm_all, y_all, msk_all))
        g_sm = fleet_sharding.local_slice(g_sm_all, n_loc)
        (g_cu,) = cvjp(g_sm)
        upd, co2 = jax.vmap(self.opt.update)(g_cu, co, cu)
        cu2 = optim.apply_updates(cu, upd)
        cu = _select(msk, cu2, cu)
        co = _select(msk, co2, co)
        return cu, co, sv, so, losses

    def _bucket_fn(self):
        if self.fleet_mesh is not None:
            return self._bucket_vmap_sharded
        return {"scan": self._bucket_scan, "vmap": self._bucket_vmap,
                "unroll": self._bucket_unroll}[self.mode]

    # ---- shared round pieces -----------------------------------------
    def _split_step_body(self, cuts_sig, carry, xs, bdata):
        """One local step across every bucket: client fwd/bwd on all
        (active) replicas, shared RSU state threaded through bucket after
        bucket in ascending-cut order."""
        bucket_fn = self._bucket_fn()
        server, s_opt, bstates = carry
        loss_sum = jnp.zeros((), jnp.float32)
        cnt = jnp.zeros((), jnp.float32)
        new_bstates = []
        for bi, (cut, n_pad) in enumerate(cuts_sig):
            cu, co = bstates[bi]
            idx, msk = xs[bi]
            x = _gather_batch(bdata[bi][0], idx)
            y = _gather_batch(bdata[bi][1], idx)
            sv = {"units": list(server["units"][cut:]),
                  "head": server["head"]}
            so = _suffix_state(s_opt, cut)
            cu, co, sv, so, losses = bucket_fn(cut, sv, so, cu, co, x, y, msk)
            server = {"units": list(server["units"][:cut])
                      + list(sv["units"]), "head": sv["head"]}
            s_opt = _merge_state(s_opt, so, cut)
            new_bstates.append((cu, co))
            loss_sum = loss_sum + jnp.sum(losses)
            c = jnp.sum(msk.astype(jnp.float32))
            if self.fleet_mesh is not None:
                # sharded bucket fns return full-cohort losses (replicated)
                # but the mask here is this shard's slice — complete it
                c = lax.psum(c, MESH_AXIS)
            cnt = cnt + c
        return (server, s_opt, new_bstates), loss_sum, cnt

    def _split_agg(self, cuts_sig, server, bstates, ws, server_unit_w):
        """Unit-wise FedAvg over the stacked axis: vehicle replicas of every
        unit before their cut + the RSU copy of units it served, reduced
        on-device (aggregation.stacked_weighted_sum).  Under a mesh the
        replica axis is sharded, so the bucket reductions become psum'd
        weighted all-reduces (aggregation.sharded_weighted_sum); the RSU
        copy is replicated and contributes locally."""
        n_units = self.model.n_units
        sharded = self.fleet_mesh is not None
        merged = []
        for u in range(n_units):
            swu = server_unit_w[u]
            num = jax.tree.map(
                lambda a: swu * a.astype(jnp.float32), server["units"][u])
            den = swu
            for bi, (cut, n_pad) in enumerate(cuts_sig):
                if cut > u:
                    if sharded:
                        part = aggregation.sharded_weighted_sum(
                            bstates[bi][0][u], ws[bi], MESH_AXIS)
                        den = den + lax.psum(jnp.sum(ws[bi]), MESH_AXIS)
                    else:
                        part = aggregation.stacked_weighted_sum(
                            bstates[bi][0][u], ws[bi])
                        den = den + jnp.sum(ws[bi])
                    num = jax.tree.map(jnp.add, num, part)
            merged.append(jax.tree.map(
                lambda nm, ref: (nm / den).astype(ref.dtype),
                num, server["units"][u]))
        return merged, server["head"]

    def _split_init(self, units, head, rows_list, cuts_sig, data_images,
                    data_labels):
        """Fresh per-round state: shared RSU tree + opt, broadcast client
        replicas + stacked opt states, per-bucket data rows."""
        opt = self.opt
        server = {"units": list(units), "head": head}
        s_opt = opt.init(server)
        bstates, bdata = [], []
        for (cut, n_pad), r in zip(cuts_sig, rows_list):
            cu = jax.tree.map(
                lambda a: jnp.broadcast_to(a, (n_pad,) + a.shape),
                list(units[:cut]))
            co = jax.vmap(opt.init)(cu)
            bstates.append((cu, co))
            bdata.append((data_images[r], data_labels[r]))
        return server, s_opt, bstates, bdata

    # ---- compiled programs -------------------------------------------
    def _split_round_program(self, cuts_sig, steps: int, batch: int):
        """scan/vmap modes: the whole round (init, every local step, the
        aggregation) is ONE jitted program; losses come back as two scalars.
        Under a mesh the same program body runs inside ``shard_map`` with
        every bucket's slot axis sharded (``cuts_sig`` carries the GLOBAL
        padded sizes; each shard traces its 1/D slice)."""
        key = ("split", cuts_sig, steps, batch, self.mode)
        if key in self._programs:
            return self._programs[key]
        fm = self.fleet_mesh
        local_sig = cuts_sig if fm is None else tuple(
            (cut, n_pad // fm.n_devices) for cut, n_pad in cuts_sig)

        def round_fn(units, head, data_images, data_labels, rows, idxs,
                     masks, ws, server_unit_w):
            server, s_opt, bstates, bdata = self._split_init(
                units, head, rows, local_sig, data_images, data_labels)

            def body(carry, xs):
                carry, ls, cs = self._split_step_body(local_sig, carry, xs,
                                                      bdata)
                return carry, (ls, cs)

            (server, s_opt, bstates), (ls, cs) = lax.scan(
                body, (server, s_opt, bstates), tuple(zip(idxs, masks)))
            merged, head2 = self._split_agg(local_sig, server, bstates, ws,
                                            server_unit_w)
            return merged, head2, jnp.sum(ls), jnp.sum(cs)

        if fm is None:
            fn = jax.jit(round_fn)
        else:
            # params/data replicated; slot axes sharded; outputs replicated
            slot = P(MESH_AXIS)
            slab = P(None, MESH_AXIS)        # (steps, n_pad, ...) tensors
            fn = jax.jit(shard_map(
                round_fn, mesh=fm.mesh,
                in_specs=(P(), P(), P(), P(), slot, slab, slab, slot, P()),
                out_specs=(P(), P(), P(), P()), check_rep=False))
        self._programs[key] = fn
        return fn

    def _split_step_program(self, cuts_sig, batch: int):
        """unroll mode: one jitted program per local step (all buckets, all
        replicas, straight-line).  The carry is donated: step s+1 reuses
        step s's buffers."""
        key = ("splitstep", cuts_sig, batch, self.mode)
        if key in self._programs:
            return self._programs[key]

        @functools.partial(jax.jit, donate_argnums=(0,))
        def step_fn(carry, xs, bdata):
            return self._split_step_body(cuts_sig, carry, xs, bdata)

        self._programs[key] = step_fn
        return step_fn

    def _split_agg_program(self, cuts_sig):
        key = ("splitagg", cuts_sig)
        if key in self._programs:
            return self._programs[key]

        @jax.jit
        def agg_fn(server, bstates, ws, server_unit_w):
            return self._split_agg(cuts_sig, server, bstates, ws,
                                   server_unit_w)

        self._programs[key] = agg_fn
        return agg_fn

    def _fl_step_body(self, n_pad, carry, idx_s, msk, bimgs, blabs):
        st, ost = carry
        x = _gather_batch(bimgs, idx_s)
        y = _gather_batch(blabs, idx_s)
        if self.mode == "vmap":
            st2, ost2, losses = jax.vmap(self._full_batch)(st, ost, x, y)
        elif self.mode == "scan":
            def body(_, inp):
                t_i, o_i, x_i, y_i = inp
                t2, o2, loss = self._full_batch(t_i, o_i, x_i, y_i)
                return (), (t2, o2, loss)
            _, (st2, ost2, losses) = lax.scan(body, (), (st, ost, x, y))
        else:
            ts, os_, ls = [], [], []
            for i in range(n_pad):
                t_i = jax.tree.map(lambda a: a[i], st)
                o_i = jax.tree.map(lambda a: a[i], ost)
                t2, o2, loss = self._full_batch(t_i, o_i, x[i], y[i])
                ts.append(t2)
                os_.append(o2)
                ls.append(loss)
            st2 = jax.tree.map(lambda *a: jnp.stack(a), *ts)
            ost2 = jax.tree.map(lambda *a: jnp.stack(a), *os_)
            losses = jnp.stack(ls)
        st = _select(msk, st2, st)
        ost = _select(msk, ost2, ost)
        return (st, ost), (jnp.sum(jnp.where(msk, losses, 0.0)),
                           jnp.sum(msk.astype(jnp.float32)))

    def _fl_round_program(self, n_pad: int, steps: int, batch: int):
        """FL is embarrassingly parallel across clients: under a mesh every
        slot's local steps (model replica, optimizer state, batch gathers)
        are shard-local end to end, and only the closing FedAvg (plus the
        loss/count totals) all-reduce."""
        key = ("fl", n_pad, steps, batch, self.mode)
        if key in self._programs:
            return self._programs[key]
        opt = self.opt
        fm = self.fleet_mesh
        n_loc = n_pad if fm is None else n_pad // fm.n_devices

        def round_fn(units, head, data_images, data_labels, rows, idx,
                     mask, w):
            tree = {"units": list(units), "head": head}
            st = jax.tree.map(
                lambda a: jnp.broadcast_to(a, (n_loc,) + a.shape), tree)
            ost = jax.vmap(opt.init)(st)
            bimgs, blabs = data_images[rows], data_labels[rows]

            def body(carry, xs):
                idx_s, msk = xs
                carry, out = self._fl_step_body(n_loc, carry, idx_s, msk,
                                                bimgs, blabs)
                return carry, out

            (st, ost), (ls, cs) = lax.scan(body, (st, ost), (idx, mask))
            if fm is None:
                avg = aggregation.stacked_fedavg(st, w)
                return avg["units"], avg["head"], jnp.sum(ls), jnp.sum(cs)
            avg = aggregation.sharded_fedavg(st, w, MESH_AXIS)
            return (avg["units"], avg["head"],
                    lax.psum(jnp.sum(ls), MESH_AXIS),
                    lax.psum(jnp.sum(cs), MESH_AXIS))

        if fm is None:
            fn = jax.jit(round_fn)
        else:
            slot, slab = P(MESH_AXIS), P(None, MESH_AXIS)
            fn = jax.jit(shard_map(
                round_fn, mesh=fm.mesh,
                in_specs=(P(), P(), P(), P(), slot, slab, slab, slot),
                out_specs=(P(), P(), P(), P()), check_rep=False))
        self._programs[key] = fn
        return fn

    def _fl_step_program(self, n_pad: int, batch: int):
        key = ("flstep", n_pad, batch, self.mode)
        if key in self._programs:
            return self._programs[key]

        @functools.partial(jax.jit, donate_argnums=(0,))
        def step_fn(carry, idx_s, msk, bimgs, blabs):
            return self._fl_step_body(n_pad, carry, idx_s, msk, bimgs, blabs)

        self._programs[key] = step_fn
        return step_fn

    def _chain_step(self, kind, cut, carry, x_i, y_i):
        if kind == "sl":
            cu, sv, co, so = carry
            sv, so, cu, co, loss = self._sfl_client_batch(
                cut, sv, so, cu, co, x_i, y_i)
            return (cu, sv, co, so), loss
        tree, ost = carry
        tree, ost, loss = self._full_batch(tree, ost, x_i, y_i)
        return (tree, ost), loss

    def _chain_round_program(self, kind: str, cut: int, total_steps: int,
                             batch: int):
        """SL (one traveling vehicle-side model) and CL (centralized) are
        inherently sequential chains; scan/vmap modes fuse the whole chain
        into one scan."""
        key = (kind, cut, total_steps, batch)
        if key in self._programs:
            return self._programs[key]

        @jax.jit
        def round_fn(carry, data_images, data_labels, rows, idx):
            imgs = data_images[rows[:, None], idx]
            labs = data_labels[rows[:, None], idx]

            def body(carry, inp):
                return self._chain_step(kind, cut, carry, *inp)

            carry, losses = lax.scan(body, carry, (imgs, labs))
            return carry, jnp.sum(losses)

        self._programs[key] = round_fn
        return round_fn

    def _chain_step_program(self, kind: str, cut: int, batch: int):
        key = (kind + "step", cut, batch)
        if key in self._programs:
            return self._programs[key]

        @functools.partial(jax.jit, donate_argnums=(0,))
        def step_fn(carry, x_i, y_i):
            return self._chain_step(kind, cut, carry, x_i, y_i)

        self._programs[key] = step_fn
        return step_fn

    # ---- public entry points -----------------------------------------
    def split_round(self, units, head, plan: RoundPlan, batch: int):
        rows = [jnp.asarray(r) for r in plan.bucket_rows]
        ws = tuple(jnp.asarray(w, jnp.float32) for w in plan.bucket_w)
        suw = jnp.asarray(plan.server_unit_w, jnp.float32)
        if self.mode != "unroll":
            fn = self._split_round_program(plan.cuts_sig, plan.steps, batch)
            idxs = tuple(jnp.asarray(i) for i in plan.bucket_idx)
            masks = tuple(jnp.asarray(m) for m in plan.bucket_mask)
            units, head, ls, cnt = fn(units, head, self.stacked.images,
                                      self.stacked.labels, rows, idxs,
                                      masks, ws, suw)
            return list(units), head, ls, cnt
        step_fn = self._split_step_program(plan.cuts_sig, batch)
        agg_fn = self._split_agg_program(plan.cuts_sig)
        server, s_opt, bstates, bdata = self._split_init(
            units, head, rows, plan.cuts_sig, self.stacked.images,
            self.stacked.labels)
        carry = (server, s_opt, bstates)
        ls = cnt = None
        for s in range(plan.steps):
            xs = tuple((jnp.asarray(i[s]), jnp.asarray(m[s]))
                       for i, m in zip(plan.bucket_idx, plan.bucket_mask))
            carry, ls_s, cs_s = step_fn(carry, xs, bdata)
            ls = ls_s if ls is None else ls + ls_s
            cnt = cs_s if cnt is None else cnt + cs_s
        server, s_opt, bstates = carry
        units, head = agg_fn(server, bstates, ws, suw)
        return list(units), head, ls, cnt

    def fl_round(self, units, head, rows, idx, mask, w, batch: int):
        n_pad = len(rows)
        rows = jnp.asarray(rows)
        w = jnp.asarray(w, jnp.float32)
        if self.mode != "unroll":
            fn = self._fl_round_program(n_pad, idx.shape[0], batch)
            units, head, ls, cnt = fn(units, head, self.stacked.images,
                                      self.stacked.labels, rows,
                                      jnp.asarray(idx), jnp.asarray(mask), w)
            return list(units), head, ls, cnt
        step_fn = self._fl_step_program(n_pad, batch)
        tree = {"units": list(units), "head": head}
        st = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (n_pad,) + a.shape), tree)
        ost = jax.vmap(self.opt.init)(st)
        bimgs = self.stacked.images[rows]
        blabs = self.stacked.labels[rows]
        carry, ls, cnt = (st, ost), None, None
        for s in range(idx.shape[0]):
            carry, (ls_s, cs_s) = step_fn(carry, jnp.asarray(idx[s]),
                                          jnp.asarray(mask[s]), bimgs, blabs)
            ls = ls_s if ls is None else ls + ls_s
            cnt = cs_s if cnt is None else cnt + cs_s
        avg = aggregation.stacked_fedavg(carry[0], w)
        return list(avg["units"]), avg["head"], ls, cnt

    def _chain_round(self, kind, cut, carry, rows, idx, batch):
        if self.fleet_mesh is not None:
            raise ValueError(
                f"scheme {kind!r} is an inherently sequential chain (one "
                f"traveling model); the vehicle-axis mesh has nothing to "
                f"shard — run it with mesh_devices=1")
        rows = jnp.asarray(rows)
        idx = jnp.asarray(idx)
        if self.mode == "scan" or self.mode == "vmap":
            fn = self._chain_round_program(kind, cut, idx.shape[0], batch)
            carry, ls = fn(carry, self.stacked.images, self.stacked.labels,
                           rows, idx)
            return carry, ls
        step_fn = self._chain_step_program(kind, cut, batch)
        imgs = self.stacked.images[rows[:, None], idx]
        labs = self.stacked.labels[rows[:, None], idx]
        ls = None
        for s in range(idx.shape[0]):
            carry, loss = step_fn(carry, imgs[s], labs[s])
            ls = loss if ls is None else ls + loss
        return carry, ls

    def sl_round(self, units, head, cut, rows, idx, batch: int):
        carry = (list(units[:cut]),
                 {"units": list(units[cut:]), "head": head},
                 self.opt.init(list(units[:cut])),
                 self.opt.init({"units": list(units[cut:]), "head": head}))
        (cu, sv, co, so), ls = self._chain_round("sl", cut, carry, rows,
                                                 idx, batch)
        return list(cu) + list(sv["units"]), sv["head"], ls

    def cl_round(self, units, head, cl_opt, rows, idx, batch: int):
        carry = ({"units": list(units), "head": head}, cl_opt)
        (tree, cl_opt), ls = self._chain_round("cl", 0, carry, rows, idx,
                                               batch)
        return list(tree["units"]), tree["head"], cl_opt, ls


# --------------------------------------------------------------------------
# the simulator
# --------------------------------------------------------------------------

class FederationSim:
    def __init__(self, model: UnitModel, clients: Sequence[ClientDataset],
                 test: Dict[str, jnp.ndarray], cfg: SimConfig,
                 fleet: Optional[List[channel.VehicleProfile]] = None,
                 ch_cfg: Optional[channel.ChannelConfig] = None,
                 mesh: Optional[FleetMesh] = None):
        if cfg.compilation_cache_dir:
            enable_compilation_cache(cfg.compilation_cache_dir)
        self.model = model
        self.clients = list(clients)
        self.test = test
        self.cfg = cfg
        self.fleet = fleet or channel.make_fleet(len(clients), cfg.seed)
        self.fleet_arr = channel.fleet_arrays(self.fleet)
        self.ch = ch_cfg or channel.ChannelConfig()
        self.profile = model.profile()
        self.engine = CohortEngine(model, cfg, self.clients, mesh=mesh)
        if self.engine.fleet_mesh is not None and cfg.scheme in ("cl", "sl"):
            raise ValueError(
                f"scheme {cfg.scheme!r} is an inherently sequential chain; "
                f"the vehicle-axis mesh shards parallel cohorts only "
                f"(fl | sfl | asfl) — set mesh_devices=1")
        self.faults = cfg.fault_config()
        if (self.faults.straggler_factor > 0.0
                or self.faults.rsu_outage_rate > 0.0):
            raise ValueError(
                "FederationSim is the single-RSU engine: fault_straggler "
                "and fault_rsu_outage need the multi-RSU ScenarioEngine "
                "(residence deadlines and RSU outages are scenario "
                "concepts)")
        if self.faults.stochastic and cfg.scheme not in ("sfl", "asfl"):
            raise ValueError(
                f"fault injection is wired into the split-federation round "
                f"(sfl | asfl); scheme {cfg.scheme!r} does not support it")
        if cfg.server_schedule == "streaming":
            raise ValueError(
                "server_schedule='streaming' needs the multi-RSU "
                "ScenarioEngine (the StreamBuffer is per-RSU super-step "
                "carry state); FederationSim runs the single-RSU "
                "synchronous round loop")
        if cfg.stream_config().churning:
            raise ValueError(
                "presence churn (stream_churn_rate > 0 or "
                "stream_churn_source='mobility') needs the multi-RSU "
                "ScenarioEngine (churn is traced super-step carry state; "
                "the single-RSU engine models coverage via fault_coverage)")
        self.reset()

    def reset(self):
        """Re-initialise parameters and history (compiled round programs and
        staged data are kept — benchmarks time warm re-runs with this)."""
        key = jax.random.PRNGKey(self.cfg.seed)
        self.units, self.head = self.model.init(key)
        self._cl_opt = None
        self.history: List[RoundMetrics] = []

    # ---- helpers -----------------------------------------------------
    def _local_steps(self, client: ClientDataset) -> int:
        if self.cfg.local_steps is not None:
            return self.cfg.local_steps
        nb = max(len(client) // self.cfg.batch_size, 1)
        return nb * self.cfg.local_epochs

    def _round_rates(self, rnd: int) -> np.ndarray:
        t = rnd * self.cfg.round_interval_s
        return channel.sample_round_rates(self.ch, self.fleet_arr, t,
                                          self.cfg.seed * 1000 + rnd)

    def _participants(self, rnd: int) -> List[int]:
        """Vehicle indices in RSU coverage this round (all, unless the
        coverage fault — legacy mobility_dropout — is enabled).  At least
        one vehicle always participates."""
        if not self.faults.coverage:
            return list(range(len(self.clients)))
        t = rnd * self.cfg.round_interval_s
        inr = np.nonzero(channel.in_range_mask(self.ch, self.fleet_arr, t))[0]
        return list(map(int, inr)) or [0]

    def _pick_cuts(self, rates: np.ndarray) -> List[int]:
        c = self.cfg
        if c.scheme == "sfl" or c.scheme == "sl":
            return [c.cut] * len(self.clients)
        strat = c.adaptive_strategy
        if strat not in FEDERATION_STRATEGIES:
            raise ValueError(
                f"adaptive_strategy {strat!r} needs the multi-RSU "
                f"ScenarioEngine; FederationSim supports: "
                f"{' | '.join(FEDERATION_STRATEGIES)}")
        if strat == "paper":
            return adaptive.paper_threshold(rates)
        if strat == "paper-literal":
            return adaptive.paper_threshold(rates, literal_eq3=True)
        if strat == "memory":
            return adaptive.memory_constrained(
                self.profile, self.fleet_arr["memory_budget_bytes"],
                adaptive.paper_threshold, rates)
        flops = self.fleet_arr["compute_flops"]
        nb = max(len(self.clients[0]) // c.batch_size, 1)
        if strat == "latency":
            return adaptive.latency_optimal(self.profile, rates, flops,
                                            c.server_flops, nb, c.batch_size,
                                            c.local_epochs)
        return adaptive.energy_aware(self.profile, rates, flops,
                                     c.server_flops, nb, c.batch_size,
                                     c.local_epochs)

    # ---- schemes -----------------------------------------------------
    def run(self, on_round: Optional[Callable[[RoundMetrics], None]] = None
            ) -> List[RoundMetrics]:
        """Run ``cfg.rounds`` federation rounds.  ``on_round`` (the api
        layer's streaming hook) is invoked with each round's metrics as it
        completes."""
        for rnd in range(self.cfg.rounds):
            fn = getattr(self, f"_round_{self.cfg.scheme}")
            metrics = fn(rnd)
            self.history.append(metrics)
            if on_round is not None:
                on_round(metrics)
        return self.history

    def _metrics(self, rnd, loss, cuts, comm, time_s, energy) -> RoundMetrics:
        ev = self.cfg.eval_every
        if ev and rnd % ev == 0:
            acc = evaluate(self.model, self.units, self.head, self.test)
        else:
            acc = float("nan")
        return RoundMetrics(rnd, float(loss), acc, comm, time_s, energy, cuts)

    def _round_cl(self, rnd: int) -> RoundMetrics:
        # centralized: pool every client's raw data at the RSU (the upper
        # bound the paper argues against — raw-data upload included in comm)
        cfgc = self.cfg
        if self._cl_opt is None:
            self._cl_opt = self.engine.opt.init(
                {"units": self.units, "head": self.head})
        rows_l, idx_l = [], []
        for ci, c in enumerate(self.clients):
            eidx = epoch_batch_indices(len(c), cfgc.batch_size,
                                       cfgc.seed + rnd)
            rows_l += [ci] * len(eidx)
            idx_l.append(eidx)
        rows = np.asarray(rows_l, np.int32)
        idx = np.concatenate(idx_l).astype(np.int32)
        self.units, self.head, self._cl_opt, ls = self.engine.cl_round(
            self.units, self.head, self._cl_opt, rows, idx, cfgc.batch_size)
        comm = sum(c.images.nbytes for c in self.clients) if rnd == 0 else 0.0
        return self._metrics(rnd, float(ls) / max(len(rows), 1), [], comm,
                             0.0, 0.0)

    def _round_fl(self, rnd: int) -> RoundMetrics:
        cfgc = self.cfg
        rates = self._round_rates(rnd)
        part = self._participants(rnd)
        n_pad = self.engine.slot_pad(len(part))
        steps_i = [self._local_steps(self.clients[ci]) for ci in part]
        steps = max(steps_i)
        rows = np.zeros(n_pad, np.int32)
        rows[:len(part)] = part
        idx = np.zeros((steps, n_pad, cfgc.batch_size), np.int32)
        mask = np.zeros((steps, n_pad), bool)
        w = np.zeros(n_pad, np.float64)
        for j, ci in enumerate(part):
            ln = len(self.clients[ci])
            w[j] = ln
            for s in range(steps_i[j]):
                idx[s, j] = sample_batch_indices(ln, cfgc.batch_size,
                                                 cfgc.seed + rnd * 997 + s)
                mask[s, j] = True
        self.units, self.head, ls, cnt = self.engine.fl_round(
            self.units, self.head, rows, idx, mask, w, cfgc.batch_size)

        nb = np.array([max(len(self.clients[ci]) // cfgc.batch_size, 1)
                       for ci in part])
        rc = cost.fl_round_cost_arrays(
            self.profile, nb, cfgc.batch_size, rates[part],
            self.fleet_arr["compute_flops"][part], cfgc.local_epochs,
            self.fleet_arr["tx_power_w"][part],
            self.fleet_arr["compute_power_w"][part])
        return self._metrics(rnd, float(ls) / max(float(cnt), 1.0), [],
                             float(rc.comm_bytes.sum()),
                             float(rc.latency.max()),
                             float(rc.energy_j.sum()))

    def _round_sl(self, rnd: int) -> RoundMetrics:
        """Vanilla sequential SL: the vehicle-side model travels from vehicle
        to vehicle; the RSU-side model trains continuously."""
        cfgc = self.cfg
        cut = cfgc.cut
        rates = self._round_rates(rnd)
        rows_l, idx_l = [], []
        for ci, c in enumerate(self.clients):
            for s in range(self._local_steps(c)):
                rows_l.append(ci)
                idx_l.append(sample_batch_indices(
                    len(c), cfgc.batch_size, cfgc.seed + rnd * 991 + s))
        rows = np.asarray(rows_l, np.int32)
        idx = np.stack(idx_l).astype(np.int32)
        self.units, self.head, ls = self.engine.sl_round(
            self.units, self.head, cut, rows, idx, cfgc.batch_size)
        rc = cost.sl_round_cost(
            self.profile, cut,
            [max(len(c) // cfgc.batch_size, 1) for c in self.clients],
            cfgc.batch_size, rates, self.fleet_arr["compute_flops"],
            cfgc.server_flops, cfgc.local_epochs)
        return self._metrics(rnd, float(ls) / max(len(rows), 1),
                             [cut] * len(self.clients), rc.comm_bytes,
                             rc.latency, rc.energy_j)

    def _round_sfl(self, rnd: int) -> RoundMetrics:
        return self._parallel_split_round(rnd)

    def _round_asfl(self, rnd: int) -> RoundMetrics:
        return self._parallel_split_round(rnd)

    def _plan_split_round(self, rnd: int, cuts: List[int],
                          participants: List[int],
                          performed: Optional[Dict[int, int]] = None,
                          survivors: Optional[Dict[int, bool]] = None
                          ) -> RoundPlan:
        """Stage one SFL/ASFL round: bucket participants by cut (ascending,
        stable by client index), pad buckets to powers of two (bounds the
        compile cache under per-round adaptive cut churn), and pre-draw every
        client's batch-index stream for the whole round.

        Fault plane (DESIGN.md §13): ``performed[ci]`` truncates a mid-round
        dropout's step mask to the steps it actually ran; ``survivors[ci]``
        zeroes the merge weight of any failed vehicle so its client-side
        update folds into the aggregation as an exact ``+0``.  Both are
        *data* (mask and weight tensors) — the compiled round program and
        its signature are untouched, so fault churn never retraces."""
        cfgc = self.cfg
        n_units = self.model.n_units
        buckets: Dict[int, List[int]] = {}
        for ci in participants:
            buckets.setdefault(cuts[ci], []).append(ci)
        steps = max(self._local_steps(self.clients[ci])
                    for ci in participants)
        cuts_sig, rows_l, idx_l, mask_l, w_l = [], [], [], [], []
        for cut in sorted(buckets):
            members = sorted(buckets[cut])
            n_pad = self.engine.slot_pad(len(members))
            rows = np.zeros(n_pad, np.int32)
            rows[:len(members)] = members
            idx = np.zeros((steps, n_pad, cfgc.batch_size), np.int32)
            mask = np.zeros((steps, n_pad), bool)
            w = np.zeros(n_pad, np.float64)
            for j, ci in enumerate(members):
                ln = len(self.clients[ci])
                w[j] = ln if survivors is None or survivors[ci] else 0.0
                n_s = (self._local_steps(self.clients[ci])
                       if performed is None else performed[ci])
                for s in range(n_s):
                    idx[s, j] = sample_batch_indices(
                        ln, cfgc.batch_size,
                        cfgc.seed + rnd * 983 + s * 31 + ci)
                    mask[s, j] = True
            cuts_sig.append((cut, n_pad))
            rows_l.append(rows)
            idx_l.append(idx)
            mask_l.append(mask)
            w_l.append(w)
        server_unit_w = np.array(
            [sum(len(self.clients[ci]) for ci in participants
                 if cuts[ci] <= u) for u in range(n_units)], np.float64)
        return RoundPlan(tuple(cuts_sig), steps, rows_l, idx_l, mask_l, w_l,
                         server_unit_w)

    def _parallel_split_round(self, rnd: int) -> RoundMetrics:
        """SFL/ASFL with SplitFed-V1 semantics: vehicle-side replicas train
        in parallel at (possibly heterogeneous) cuts while the RSU keeps ONE
        shared server-side model that is updated on every client batch (the
        RSU 'sequentially performs forward propagation ... with the received
        smashed data' — paper §III-B).  Round end: vehicle-side units are
        FedAvg'd (|D_n|-weighted) with the RSU copy of any unit it trained.
        The whole round — every bucket, every local step, the aggregation —
        is one compiled CohortEngine program."""
        cfgc = self.cfg
        fc = self.faults
        rates = self._round_rates(rnd)
        participants = self._participants(rnd)
        cuts = [max(1, min(c, self.model.n_units - 1))
                for c in self._pick_cuts(rates)]
        performed = survivors = uploads = None
        if fc.stochastic:
            # host twin of the traced fault plane (DESIGN.md §13): dropouts
            # truncate the step mask, upload losses zero the merge weight —
            # both data, so the compiled round program never retraces
            drop, dfrac, lost = faults.sample_faults_host(
                fc, rnd, len(self.clients))
            lost = lost & ~drop          # dropout precedence (never uploads)
            if all(drop[ci] or lost[ci] for ci in participants):
                # at-least-one-participant guarantee: clear the first
                # scheduled vehicle's failure bits (faults.rescue_mask twin)
                drop[participants[0]] = lost[participants[0]] = False
            performed = {ci: (int(dfrac[ci] * self._local_steps(
                                  self.clients[ci])) if drop[ci]
                              else self._local_steps(self.clients[ci]))
                         for ci in participants}
            survivors = {ci: not (drop[ci] or lost[ci])
                         for ci in participants}
            uploads = {ci: not drop[ci] for ci in participants}
        plan = self._plan_split_round(rnd, cuts, participants, performed,
                                      survivors)
        self.units, self.head, ls, cnt = self.engine.split_round(
            self.units, self.head, plan, cfgc.batch_size)

        part = np.asarray(participants)
        if fc.stochastic:
            # charge only the work performed: a dropout pays its partial
            # smashed traffic and compute but no aggregation upload; an
            # upload loss pays everything (the upload went out and was
            # lost); the straggler latency bound is over merge survivors
            rc = cost.sfl_round_cost_arrays(
                self.profile, np.asarray(cuts)[part],
                np.array([performed[ci] for ci in participants]),
                cfgc.batch_size, rates[part],
                self.fleet_arr["compute_flops"][part], cfgc.server_flops,
                1, self.fleet_arr["tx_power_w"][part],
                self.fleet_arr["compute_power_w"][part],
                wire=cfgc.wire_scheme(), wire_k=cfgc.wire_k,
                model_upload=np.array([uploads[ci]
                                       for ci in participants]))
            surv_arr = np.array([survivors[ci] for ci in participants])
            latency = float(np.max(rc.latency[surv_arr], initial=0.0))
        else:
            rc = cost.sfl_round_cost_arrays(
                self.profile, np.asarray(cuts)[part],
                np.array([max(len(self.clients[ci]) // cfgc.batch_size, 1)
                          for ci in participants]),
                cfgc.batch_size, rates[part],
                self.fleet_arr["compute_flops"][part], cfgc.server_flops,
                cfgc.local_epochs, self.fleet_arr["tx_power_w"][part],
                self.fleet_arr["compute_power_w"][part],
                wire=cfgc.wire_scheme(), wire_k=cfgc.wire_k)
            latency = float(rc.latency.max())
        # cost.effective_comm_bytes charges the wire inside the model: the
        # smashed bytes (both directions) shrink by the per-cut packed-byte
        # ratio while model-transfer bytes stay dense, and latency/energy
        # follow the compressed counts (previously a post-hoc division here
        # wrongly discounted the model bytes and left energy uncompressed)
        m = self._metrics(rnd, float(ls) / max(float(cnt), 1.0), cuts,
                          float(rc.comm_bytes.sum()), latency,
                          float(rc.energy_j.sum()))
        if fc.stochastic:
            bytes_cum = np.concatenate(
                [[0.0], np.cumsum(self.profile.unit_param_bytes)])
            failed = [ci for ci in participants if not survivors[ci]]
            m.n_dropout = int(sum(drop[ci] for ci in participants))
            m.n_upload_lost = int(sum(lost[ci] for ci in participants))
            m.survivor_frac = (float(sum(survivors.values()))
                               / max(len(participants), 1))
            m.lost_update_bytes = float(
                sum(bytes_cum[cuts[ci]] for ci in failed))
        return m


# --------------------------------------------------------------------------
# multi-RSU scenario orchestration (DESIGN.md §7)
# --------------------------------------------------------------------------

@dataclasses.dataclass
class ScenarioRoundMetrics:
    round: int
    loss: float
    test_acc: float          # NaN on rounds without a cloud sync / eval
    comm_bytes: float
    sim_time_s: float        # straggler-bounded round latency
    energy_j: float
    n_scheduled: int         # vehicles that trained this round
    n_skipped: int           # in coverage but residence-infeasible (cut=SKIP)
    n_handover: int          # vehicles that re-associated since last round
    rsu_loads: List[int]     # participants per RSU
    cuts: List[int]          # fleet-wide cuts; 0 = sat the round out
    # fault-plane telemetry (DESIGN.md §13); defaults = no faults
    n_dropout: int = 0       # scheduled vehicles that dropped mid-round
    n_upload_lost: int = 0   # full work done, update lost on the uplink
    n_straggler: int = 0     # deadline-exceeded; update banked, not lost
    n_rsu_down: int = 0      # RSUs that sat the round out
    survivor_frac: float = 1.0   # merged / scheduled (effective participation)
    lost_update_bytes: float = 0.0  # client-side params that never merged
    stale_merged: float = 0.0    # banked straggler weight merged this round
    # streaming-plane telemetry (DESIGN.md §14); defaults = no streaming
    n_present: int = -1          # fleet presence after churn (-1 = no churn)
    n_arrived: int = 0           # vehicles that arrived this round
    absorbed_samples: float = 0.0  # sample weight MERGED into an edge model
    stream_merges: int = 0       # StreamBuffer fires this round
    buffer_occupancy: float = 0.0  # pending deltas across RSUs, post-round
    stream_stale: float = 0.0    # summed slot ages of the merged deltas


class ScenarioEngine:
    """Multi-RSU federation orchestrator over a pluggable mobility
    :class:`~repro.core.scenario.Scenario`, with handover and hierarchical
    edge→cloud aggregation — executed as **fused super-steps**
    (:mod:`repro.core.superstep`, DESIGN.md §8).

    Per round, inside the compiled program:

    1. Fleet state — positions, serving RSU, Shannon rates, residence —
       from the scenario's traced-step path (or staged per super-step for
       scenarios without one, e.g. ``urban_grid``).
    2. Cuts, fleet-wide and on-device: ``paper`` Eq. 3 banding or
       ``residence``-aware deadline feasibility with SKIP.
    3. On-device segment grouping (one sort of (serving, cut, vehicle)
       keys) stacks every RSU's cohort on a leading RSU axis; all RSUs
       train inside the same program with the cut as *data* (per-unit
       client/server parameter masking), then unit-wise FedAvg at the edge.
    4. Every ``cloud_sync_every`` rounds a sample-weighted cloud merge
       across the RSU axis re-seeds every edge model from the global.

    ``cfg.superstep = K`` fuses K such rounds into one ``lax.scan`` with the
    carry (edge stack, sample counters, previous serving, global model)
    donated between dispatches; K = 1 is the per-round dispatch path — the
    *same* program at scan length 1, which is why fused and sequential
    execution agree bit-for-bit (tests/test_superstep.py).  On CPU the
    cut-as-data formulation makes the K=1 path ~2x slower per round than
    PR 2's static-bucket engine; K >= 4 (with ``slot_capacity="tight8"``)
    recovers to at-or-above its throughput — set ``superstep`` accordingly
    when round rate matters (DESIGN.md §8 has the floor analysis).  Dynamic
    membership never retraces: programs are keyed by the rounded per-RSU
    slot capacity (``slot_capacity``: pow2, or tight8 = next multiple of
    8), so join/leave/handover only reshuffles which rows of the
    device-resident :class:`StackedClients` tensors each round gathers.

    Handover semantics: a vehicle's data shard and identity travel with it
    (its rows in the stacked tensors are RSU-agnostic); server-side model
    and optimizer state stay at the RSU.  The handover cost charges the
    vehicle-side sub-model re-download at the new cell.

    What stays in Python: metrics assembly, analytic comm/latency/energy
    accounting, and evaluation — all fed from per-round scan outputs pulled
    once per super-step.
    """

    def __init__(self, model: UnitModel, clients: Sequence[ClientDataset],
                 test: Dict[str, jnp.ndarray], cfg: SimConfig, scenario,
                 cloud_sync_every: int = 1,
                 mesh: Optional[FleetMesh] = None):
        assert len(clients) == scenario.n_vehicles, \
            (len(clients), scenario.n_vehicles)
        if cfg.adaptive_strategy not in SCENARIO_STRATEGIES:
            raise ValueError(
                f"ScenarioEngine supports adaptive_strategy "
                f"{' | '.join(SCENARIO_STRATEGIES)}, got "
                f"{cfg.adaptive_strategy!r} (the single-RSU FederationSim "
                f"strategies latency/energy/memory are not wired here)")
        if cfg.compilation_cache_dir:
            enable_compilation_cache(cfg.compilation_cache_dir)
        self.model = model
        self.clients = list(clients)
        self.test = test
        self.cfg = cfg
        self.scenario = scenario
        self.n_rsus = len(scenario.rsu_positions)
        self.fa = scenario.fleet_arrays
        self.profile = model.profile()
        self.lengths = np.array([len(c) for c in clients], dtype=np.int64)
        self.cloud_sync_every = max(int(cloud_sync_every), 1)
        self.fleet_mesh = mesh if mesh is not None \
            else fleet_sharding.from_config(cfg, "scenario",
                                            fleet_size=scenario.n_vehicles)
        if self.fleet_mesh is not None and \
                self.fleet_mesh.axis not in ("rsu", "grid"):
            raise ValueError(
                f"ScenarioEngine shards the RSU axis (optionally x the "
                f"vehicle slot axis); got a FleetMesh over "
                f"{self.fleet_mesh.axis!r} (fleet_axis='rsu', 'grid' or "
                f"'auto')")
        nb, ep = self._nb_ep()
        self.programs = SuperStepPrograms(
            model, cfg, stack_clients(self.clients), self.lengths, scenario,
            self.n_rsus, self.cloud_sync_every, self.profile, nb, ep,
            mesh=self.fleet_mesh)
        self.mode = ("fused-traced" if self.programs.traced_mobility
                     else "fused-staged")
        self._cohort_counts: Dict[int, int] = {}
        self._covered_totals: Dict[int, int] = {}
        self._state_cache: Dict[int, Any] = {}
        # double-buffered window staging (DESIGN.md §14): the next window's
        # batch/mobility arrays are built while the current one trains
        self._xs_stage = DoubleBuffer()
        self.reset()

    def reset(self):
        """Fresh parameters/history; compiled programs and staged data are
        kept (benchmarks time warm re-runs with this)."""
        units, head = self.model.init(jax.random.PRNGKey(self.cfg.seed))
        self.units, self.head = list(units), head
        # the carry holds its own buffers: the whole carry is DONATED to the
        # next super-step, while self.units/self.head stay valid for
        # callers between (and after) runs
        self._carry = self.programs.make_carry(units, head,
                                               len(self.clients))
        self._sync_count = 0
        self.history: List[ScenarioRoundMetrics] = []

    # ---- staging ------------------------------------------------------
    def _nb_ep(self) -> Tuple[int, int]:
        """(n_batches, epochs) — uniform across the fleet: the scenario
        engine runs every scheduled vehicle for the same number of local
        steps (deadline feasibility is folded into cut selection)."""
        c = self.cfg
        if c.local_steps is not None:
            return c.local_steps, 1
        return max(int(self.lengths.max()) // c.batch_size, 1), c.local_epochs

    def _steps(self) -> int:
        nb, ep = self._nb_ep()
        return nb * ep

    def _host_state(self, rnd: int):
        """Cached host fleet state for round ``rnd`` (fleet_state is a pure
        function of (t, seed), so capacity planning and staged-mobility
        windows share one evaluation per round)."""
        st = self._state_cache.get(rnd)
        if st is None:
            st = self.scenario.fleet_state(rnd * self.cfg.round_interval_s,
                                           self.cfg.seed * 1000 + rnd)
            self._state_cache[rnd] = st
        return st

    def _capacity(self, horizon: int) -> int:
        """pow2 per-RSU slot capacity over rounds [0, horizon): the max
        *covered*-vehicle count of any cell — coverage is deterministic
        geometry, so this upper-bounds every scheduled cohort the traced
        scheduler can form, and the pow2 bucketing keeps the compile-cache
        signature stable under membership churn."""
        for rnd in range(horizon):
            if rnd not in self._cohort_counts:
                s = self._host_state(rnd).serving_rsu
                c = int(np.bincount(s[s >= 0],
                                    minlength=self.n_rsus).max()) \
                    if (s >= 0).any() else 0
                self._cohort_counts[rnd] = c
        mx = max([self._cohort_counts[r] for r in range(horizon)] + [1])
        cap = ((mx + 7) // 8) * 8 \
            if self.cfg.slot_capacity == "tight8" else _pow2(mx)
        if self.fleet_mesh is not None:
            # dense 2-D: each RSU's slot row splits into vehicle-axis
            # column blocks, so the capacity must be a dv multiple
            cap = self.fleet_mesh.pad_slots(cap)
        return cap

    def _total_slots(self, horizon: int) -> int:
        """Capacity of the ragged layout's compacted global slot axis over
        rounds [0, horizon): the max TOTAL covered count of any round,
        rounded like ``slot_capacity`` for compile-cache stability and
        padded to a device multiple under a mesh
        (:meth:`~repro.core.fleet_sharding.FleetMesh.balanced_slots`).
        0 when the engine's layout/schedule has no compacted axis."""
        if not (self.cfg.server_schedule in ("parallel", "streaming")
                and self.programs.layout == "ragged"):
            return 0
        for rnd in range(horizon):
            if rnd not in self._covered_totals:
                s = self._host_state(rnd).serving_rsu
                self._covered_totals[rnd] = int((s >= 0).sum())
        mx = max([self._covered_totals[r] for r in range(horizon)] + [1])
        slots = ((mx + 7) // 8) * 8 \
            if self.cfg.slot_capacity == "tight8" else _pow2(mx)
        if self.fleet_mesh is not None:
            slots = self.fleet_mesh.balanced_slots(slots)
        return slots

    def occupancy_stats(self) -> Dict[str, Any]:
        """Occupancy accounting for bench rows (DESIGN.md §12): how much of
        the compiled layout's slot and plane budget the run actually used.
        ``executed_slots`` is per-round slot-compute the program runs
        (padded grid for dense/sequential, compacted capacity for
        ragged+parallel); ``mean_occupied_slots`` averages the scheduled
        counts over the recorded history; ``owned_plane_frac`` is the
        client-plane prefix fraction (1.0 dense); the effective-FLOPs
        utilization is the occupied share of executed slot fwd/bwd work."""
        pg = self.programs
        horizon = max(int(self.cfg.rounds), 1)
        cap = self._capacity(horizon)
        if (self.cfg.server_schedule in ("parallel", "streaming")
                and pg.layout == "ragged"):
            executed = self._total_slots(horizon)
        else:
            executed = pg.n_rsus_padded * cap
        occ = [float(m.n_scheduled) for m in self.history]
        mean_occ = float(np.mean(occ)) if occ else 0.0
        util = (mean_occ / executed) if executed else 0.0
        return {
            "layout": pg.layout,
            "slot_capacity": int(cap),
            "executed_slots": int(executed),
            "mean_occupied_slots": mean_occ,
            "padded_slot_frac": float(1.0 - util),
            "owned_plane_frac": float(pg.plane_width
                                      / max(pg.n_params, 1)),
            "effective_flops_utilization": float(util),
        }

    def _window_xs(self, rnd0: int, k: int):
        """Host staging of one super-step window: the round indices, plus —
        only for scenarios without a traced-step path — the per-round fleet
        state arrays, stacked over the window."""
        xs = {"rnd": jnp.arange(rnd0, rnd0 + k, dtype=jnp.int32)}
        if not self.programs.traced_mobility:
            states = [self._host_state(rnd) for rnd in range(rnd0, rnd0 + k)]
            xs["serving"] = jnp.asarray(
                np.stack([s.serving_rsu for s in states]), jnp.int32)
            xs["rates"] = jnp.asarray(
                np.stack([s.rates_bps for s in states]), jnp.float32)
            xs["residence"] = jnp.asarray(
                np.stack([s.residence_s for s in states]), jnp.float32)
        return xs

    def _windows(self, rounds: int):
        k = max(int(self.cfg.superstep or 1), 1)
        rnd = 0
        while rnd < rounds:
            kk = min(k, rounds - rnd)
            yield rnd, kk
            rnd += kk

    # ---- warmup -------------------------------------------------------
    def precompile(self, rounds: Optional[int] = None) -> List[Any]:
        """AOT-lower and compile (``.lower().compile()``) every super-step
        signature the run plan for ``rounds`` (default ``cfg.rounds``) will
        request, plus the evaluation program — so the run itself never
        compiles (asserted via ``programs.compile_fallbacks`` in
        tests/test_superstep.py).  With ``cfg.compilation_cache_dir`` set,
        repeat processes deserialize these binaries instead of re-invoking
        XLA.  Returns the compiled signatures."""
        total = int(rounds if rounds is not None else self.cfg.rounds)
        cap = self._capacity(max(total, 1))
        slots = self._total_slots(max(total, 1))
        sigs = []
        for rnd0, kk in self._windows(total):
            sig = self.programs.signature(kk, cap, slots)
            if sig in sigs:
                continue
            # derive the abstract xs from the real staging path so the
            # precompiled pytree spec can never drift from what
            # run_superstep passes (host states are cached, so this is
            # cheap even for staged-mobility scenarios)
            xs = jax.tree.map(
                lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype),
                self._window_xs(rnd0, kk))
            self.programs.precompile(sig, self._carry, xs)
            sigs.append(sig)
        ev = self.cfg.eval_every
        if ev and any((r + 1) % self.cloud_sync_every == 0
                      for r in range(total)):
            # compile the eval program through its real call path
            evaluate(self.model, self.units, self.head, self.test)
        return sigs

    # ---- the rounds ---------------------------------------------------
    def run_superstep(self, rnd0: int, k: int) -> List[ScenarioRoundMetrics]:
        """Execute rounds [rnd0, rnd0+k) as ONE compiled program and return
        their metrics.  The previous carry is donated; per-round arrays come
        back as scan outputs and are pulled to the host once."""
        horizon = max(self.cfg.rounds, rnd0 + k)
        cap = self._capacity(horizon)
        sig = self.programs.signature(k, cap, self._total_slots(horizon))
        fn = self.programs.get(sig)
        xs = self._xs_stage.take((rnd0, k),
                                 lambda: self._window_xs(rnd0, k))
        carry, ys = fn(self._carry, xs)            # async dispatch
        # double-buffered staging (DESIGN.md §14): while the dispatched
        # window trains on device, build the NEXT window's batch/mobility
        # arrays and start their transfers — newly arrived vehicles' shards
        # are resident before their first round forms, and the blocking
        # host pull below overlaps the staging instead of serializing it
        nxt = rnd0 + k
        if nxt < self.cfg.rounds:
            kk = min(max(int(self.cfg.superstep or 1), 1),
                     self.cfg.rounds - nxt)
            self._xs_stage.stage((nxt, kk),
                                 lambda: self._window_xs(nxt, kk))
        ys = jax.tree.map(np.asarray, ys)          # ONE host sync per window
        if int(ys["counts"].max(initial=0)) > cap:
            # raise BEFORE committing the window: the window silently
            # dropped overflow vehicles, so its carry must not become
            # engine state (the donated previous carry is gone — the engine
            # needs reset() — but nothing masquerades as valid training)
            raise RuntimeError(
                f"per-RSU cohort exceeded slot capacity {cap}; traced vs "
                f"host association disagree — raise the capacity margin "
                f"and reset() the engine")
        if sig.slots and int(ys["counts"].sum(axis=-1).max(initial=0)) \
                > sig.slots:
            # the ragged layout's compacted axis silently truncates the
            # sorted slot order past its capacity — same contract as the
            # per-RSU check above
            raise RuntimeError(
                f"fleet-wide occupied slots exceeded the compacted "
                f"capacity {sig.slots}; traced vs host association "
                f"disagree — raise the capacity margin and reset() the "
                f"engine")
        self._carry = carry
        self.units, self.head = self.programs.global_model(carry)
        out = []
        eval_due, last_synced = False, None
        for i in range(k):
            out.append(self._round_metrics(rnd0 + i, i, ys))
            if (rnd0 + i + 1) % self.cloud_sync_every == 0:
                # evaluate every eval_every-th cloud sync (the global model
                # only changes at syncs) — counted here on the host, since
                # the fused window keeps no per-round model snapshots
                ev = self.cfg.eval_every
                if ev and self._sync_count % ev == 0:
                    eval_due = True
                self._sync_count += 1
                last_synced = i
        if eval_due and last_synced is not None:
            # the current global IS the last synced round's model (later
            # rounds trained edges but did not merge), so attaching the
            # score there is exact; K=1 reproduces the per-round schedule
            out[last_synced].test_acc = evaluate(
                self.model, self.units, self.head, self.test)
        return out

    def _round_metrics(self, rnd: int, i: int, ys) -> ScenarioRoundMetrics:
        cuts = ys["cuts"][i].astype(np.int64)
        serving = ys["serving"][i]
        sched = cuts > 0
        active = serving >= 0
        handover = np.asarray(ys["handover"][i], bool)
        fault = None
        if self.programs.fz:
            # drop/lost/strag come out of the program already scheduled-
            # masked, precedence-ordered, and rescue-cleared
            fault = (np.asarray(ys["dstep"][i], np.int64),
                     np.asarray(ys["drop"][i], bool),
                     np.asarray(ys["lost"][i], bool),
                     np.asarray(ys["strag"][i], bool))
        comm, lat, energy = self._accounting(ys["rates"][i], cuts, sched,
                                             handover, fault)
        loss = float(ys["loss"][i]) / max(float(ys["cnt"][i]), 1.0)
        m = ScenarioRoundMetrics(
            rnd, loss, float("nan"), comm, lat, energy,
            n_scheduled=int(sched.sum()),
            n_skipped=int((active & ~sched).sum()),
            n_handover=int(handover.sum()),
            # the program may pad the RSU axis to a device multiple; padded
            # cells never receive members — report the real cells only
            rsu_loads=[int(c) for c in ys["counts"][i][:self.n_rsus]],
            cuts=[int(c) for c in cuts])
        if fault is not None:
            _, drop, lost, strag = fault
            bytes_cum = np.concatenate(
                [[0.0], np.cumsum(self.profile.unit_param_bytes)])
            surv = sched & ~drop & ~lost & ~strag
            m.n_dropout = int(drop.sum())
            m.n_upload_lost = int(lost.sum())
            m.n_straggler = int(strag.sum())
            m.n_rsu_down = int(
                np.asarray(ys["rsu_down"][i], bool)[:self.n_rsus].sum())
            m.survivor_frac = float(surv.sum()) / max(int(sched.sum()), 1)
            # stragglers are banked, not lost — only drop/lost updates die
            m.lost_update_bytes = float(bytes_cum[cuts[drop | lost]].sum())
            m.stale_merged = float(ys["stale_w"][i])
        if self.programs.cz:
            m.n_present = int(ys["present"][i])
            m.n_arrived = int(ys["arrived"][i])
        if self.programs.sz:
            # streaming: absorption happens at buffer fires, measured
            # in-program (DESIGN.md §14)
            m.absorbed_samples = float(ys["absorbed"][i])
            m.stream_merges = int(ys["stream_fires"][i])
            m.buffer_occupancy = float(ys["buf_occ"][i])
            m.stream_stale = float(ys["stream_stale"][i])
        else:
            # synchronous schedules absorb every merge-surviving update the
            # round it trained — the goodput baseline streaming is compared
            # against (host arithmetic over the same scan outputs)
            if fault is not None:
                _, drop, lost, strag = fault
                merged = sched & ~drop & ~lost & ~strag
            else:
                merged = sched
            m.absorbed_samples = float(self.lengths[merged].sum())
        return m

    def run_round(self, rnd: int) -> ScenarioRoundMetrics:
        return self.run_superstep(rnd, 1)[0]

    def run(self,
            on_round: Optional[Callable[[ScenarioRoundMetrics],
                                        None]] = None,
            on_cloud_merge: Optional[Callable[[int, "ScenarioEngine"],
                                              None]] = None,
            on_stream_merge: Optional[Callable[[ScenarioRoundMetrics,
                                                "ScenarioEngine"],
                                               None]] = None
            ) -> List[ScenarioRoundMetrics]:
        """Run ``cfg.rounds`` rounds as fused super-step windows.

        Streaming hooks (the api layer's callbacks): ``on_round(metrics)``
        fires for every completed round, ``on_cloud_merge(rnd, engine)``
        after every cloud sync, and ``on_stream_merge(metrics, engine)``
        after every round in which at least one StreamBuffer fired
        (``metrics.stream_merges > 0`` — streaming schedule only) — all
        AFTER each fused window completes, fed from the window's single
        host pull, so none adds a host sync to the fused path.  Consequence
        for ``superstep`` K > 1: the fused window keeps no per-round model
        snapshots, so every ``on_cloud_merge`` / ``on_stream_merge`` in a
        window observes ``engine.units/head`` as of the window end (exactly
        the eval semantics above); run with K = 1 if a callback needs the
        global model at each individual sync."""
        for rnd0, kk in self._windows(self.cfg.rounds):
            window = self.run_superstep(rnd0, kk)
            self.history.extend(window)
            for m in window:
                if on_round is not None:
                    on_round(m)
                if (on_cloud_merge is not None
                        and (m.round + 1) % self.cloud_sync_every == 0):
                    on_cloud_merge(m.round, self)
                if on_stream_merge is not None and m.stream_merges > 0:
                    on_stream_merge(m, self)
        return self.history

    def _accounting(self, rates, cuts, sched, handover, fault=None):
        """Analytic per-round comm/latency/energy over the scheduled set +
        the handover model-migration bytes (vehicle-side sub-model
        re-download at the new cell).  Pure numpy over arrays the super-step
        emitted — part of the Python accounting tier by design.

        With ``fault = (dstep, drop, lost, strag)`` (DESIGN.md §13) each
        vehicle is charged the work it performed: dropouts pay their partial
        smashed traffic and compute but no aggregation upload; upload losses
        pay in full (the upload went out and was lost); the straggler bound
        on round latency is over merge survivors — a dropout's partial work
        and a deadline straggler's banked upload do not extend the round."""
        cfgc = self.cfg
        act = np.nonzero(sched)[0]
        bytes_cum = np.concatenate(
            [[0.0], np.cumsum(self.profile.unit_param_bytes)])
        ho_bytes = float(bytes_cum[cuts[handover]].sum())
        if not len(act):
            return ho_bytes, 0.0, 0.0
        nb, ep = self._nb_ep()
        if fault is None:
            rc = cost.sfl_round_cost_arrays(
                self.profile, cuts[act], nb, cfgc.batch_size,
                np.maximum(np.asarray(rates, np.float64)[act], 1.0),
                self.fa["compute_flops"][act], cfgc.server_flops, ep,
                self.fa["tx_power_w"][act], self.fa["compute_power_w"][act],
                wire=cfgc.wire_scheme(), wire_k=cfgc.wire_k)
            lat = float(rc.latency.max())
        else:
            dstep, drop, lost, strag = fault
            rc = cost.sfl_round_cost_arrays(
                self.profile, cuts[act], dstep[act], cfgc.batch_size,
                np.maximum(np.asarray(rates, np.float64)[act], 1.0),
                self.fa["compute_flops"][act], cfgc.server_flops, 1,
                self.fa["tx_power_w"][act], self.fa["compute_power_w"][act],
                wire=cfgc.wire_scheme(), wire_k=cfgc.wire_k,
                model_upload=~drop[act])
            surv = ~(drop | lost | strag)[act]
            lat = float(np.max(rc.latency[surv], initial=0.0))
        # wire bytes charged inside the cost model (smashed both directions;
        # model transfer and handover migration stay dense) — see cost.py
        return (float(rc.comm_bytes.sum()) + ho_bytes, lat,
                float(rc.energy_j.sum()))
