"""Mamba2 SSD chunk scan (Pallas) — the server-side hot spot for the SSM
architecture (DESIGN.md §5).

Grid = (batch, heads, num_chunks) with chunks innermost: TPU grids iterate
sequentially, so the inter-chunk recurrent state (d_state x head_dim, f32)
lives in VMEM scratch and carries across chunk steps — the TPU-native
replacement for the paper's GPU chunk-parallel + cross-chunk scan.  Per
grid step the kernel computes the intra-chunk quadratic block (the
"attention-like" dual form, MXU matmuls over (chunk x chunk)) and folds the
incoming state in, then updates the state for the next chunk.

Inputs are pre-activation: dt already softplus'ed, A negative.  Oracle:
repro.kernels.ref.ssd_naive (the literal recurrence) and models.ssm's
chunked jnp reference.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, state_scr):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0, 0].astype(jnp.float32)        # (q, p)
    dt = dt_ref[0, 0].astype(jnp.float32)      # (q, 1) -- padded lane dim
    a = a_ref[0]                               # scalar A for this head
    bb = b_ref[0, 0].astype(jnp.float32)       # (q, n)
    cc = c_ref[0, 0].astype(jnp.float32)       # (q, n)

    la = dt[:, 0] * a                          # (q,) log-decay, <= 0
    cum = jnp.cumsum(la)                       # inclusive
    total = cum[-1]

    # intra-chunk: scores[i,j] = (C_i . B_j) * exp(cum_i - cum_j) * dt_j, j<=i
    cb = jax.lax.dot_general(cc, bb, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (q, q)
    decay = jnp.exp(cum[:, None] - cum[None, :])
    q = cb.shape[0]
    ii = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    scores = jnp.where(ii >= jj, cb * decay, 0.0) * dt[:, 0][None, :]
    y = jax.lax.dot_general(scores, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)   # (q, p)

    # contribution of the incoming state: C_i @ H_prev * exp(cum_i)
    h_prev = state_scr[...]                    # (n, p)
    y += jnp.exp(cum)[:, None] * jax.lax.dot_general(
        cc, h_prev, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    # state update: H = exp(total) H_prev + sum_j exp(total - cum_j) dt_j B_j x_j
    w = jnp.exp(total - cum) * dt[:, 0]        # (q,)
    s_new = jax.lax.dot_general(bb * w[:, None], x, (((0,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (n, p)
    state_scr[...] = jnp.exp(total) * h_prev + s_new
    y_ref[0, 0] = y.astype(y_ref.dtype)


def ssd_chunk_scan(x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
                   B: jnp.ndarray, C: jnp.ndarray, *, chunk: int = 128,
                   interpret: bool = False) -> jnp.ndarray:
    """x (b,s,h,p), dt (b,s,h) [post-softplus], A (h,) [<0], B/C (b,s,g,n).
    Returns y (b,s,h,p).  s is padded to a chunk multiple (dt=0 on pads)."""
    b, s, h, p_ = x.shape
    g, n = B.shape[2], B.shape[3]
    rep = h // g
    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
    sp = s + pad
    nc = sp // chunk

    xh = x.swapaxes(1, 2)                       # (b, h, s, p)
    dth = dt.swapaxes(1, 2)[..., None]          # (b, h, s, 1)
    Bh = B.swapaxes(1, 2)                       # (b, g, s, n)
    Ch = C.swapaxes(1, 2)

    grid = (b, h, nc)
    y = pl.pallas_call(
        _ssd_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, chunk, p_), lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((1, 1, chunk, 1), lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((1,), lambda bi, hi, ci: (hi,)),
            pl.BlockSpec((1, 1, chunk, n),
                         lambda bi, hi, ci, r=rep: (bi, hi // r, ci, 0)),
            pl.BlockSpec((1, 1, chunk, n),
                         lambda bi, hi, ci, r=rep: (bi, hi // r, ci, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, chunk, p_),
                               lambda bi, hi, ci: (bi, hi, ci, 0)),
        out_shape=jax.ShapeDtypeStruct(xh.shape, x.dtype),
        scratch_shapes=[pltpu.VMEM((n, p_), jnp.float32)],
        interpret=interpret,
    )(xh, dth, A.astype(jnp.float32), Bh, Ch)
    y = y.swapaxes(1, 2)
    if pad:
        y = y[:, :s]
    return y
