"""Wireless channel model (VEI radio layer).

Shannon-capacity rates with log-distance path loss.  This supplies the
per-vehicle, per-round transmission rates `r_n^t` that drive the paper's
cut-layer selection rule (Eq. 3) and the latency / energy accounting of
Fig. 5b.

Mobility lives one layer up, in ``core/scenario.py`` (multi-RSU corridors,
urban grids, trace replay); this module keeps only the radio math
(:func:`rates_from_distance`) plus the seed's single-RSU drive-by trace
helpers, which the paper-faithful 4-vehicle case study (`FederationSim`)
still uses — they are the `n_rsus=1` special case of the scenario layer.
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class VehicleProfile:
    """Static per-vehicle characteristics."""
    compute_flops: float = 20e9     # sustained vehicle-side FLOP/s (CPU-class)
    tx_power_w: float = 0.5         # uplink transmit power
    compute_power_w: float = 15.0   # power draw while computing
    x0_m: float = -200.0            # initial position along the road
    speed_mps: float = 15.0         # vehicle speed (m/s)
    # on-vehicle parameter budget for the client-side sub-model; inf = the
    # vehicle can hold the whole stack (adaptive_strategy="memory" clamps
    # cuts so client_param_bytes(cut) fits this budget)
    memory_budget_bytes: float = float("inf")


@dataclasses.dataclass
class ChannelConfig:
    bandwidth_hz: float = 10e6      # per-vehicle allocated bandwidth
    noise_dbm_hz: float = -174.0    # thermal noise density
    path_loss_exp: float = 3.0
    ref_gain_db: float = -30.0      # gain at 1 m
    rsu_range_m: float = 400.0
    fading_std_db: float = 4.0      # shadow fading (log-normal)


RSU_HEIGHT_M = 10.0


def _shannon_rate(cfg: ChannelConfig, d, tx_power_w, fading_db):
    """B log2(1 + SNR) with log-distance path loss — the one place the
    channel math lives; scalars and fleet arrays broadcast alike."""
    pl_db = (-cfg.ref_gain_db
             + 10 * cfg.path_loss_exp * np.log10(np.maximum(d, 1.0))
             + fading_db)
    p_rx_dbm = 10 * np.log10(np.asarray(tx_power_w) * 1e3) - pl_db
    noise_dbm = cfg.noise_dbm_hz + 10 * np.log10(cfg.bandwidth_hz)
    snr = 10 ** ((p_rx_dbm - noise_dbm) / 10)
    return cfg.bandwidth_hz * np.log2(1.0 + snr)


def rates_from_distance(cfg: ChannelConfig, d_m, tx_power_w,
                        seed: int | None = None) -> np.ndarray:
    """Vectorized Shannon rates at given vehicle->RSU distances (the scenario
    layer's entry point: mobility hands in distances, radio hands back
    rates).  ``seed`` draws one shadow-fading sample per vehicle."""
    d = np.asarray(d_m, dtype=np.float64)
    if seed is not None and cfg.fading_std_db > 0:
        fading = np.random.default_rng(seed).normal(0.0, cfg.fading_std_db,
                                                    size=d.shape)
    else:
        fading = 0.0
    return _shannon_rate(cfg, d, tx_power_w, fading)


def shannon_rate_traced(cfg: ChannelConfig, d, tx_power_w, fading_db=0.0):
    """jit-traceable twin of :func:`_shannon_rate` (same formula in jnp), the
    radio entry point of the fused super-step path: distances and fading may
    be tracers, the ChannelConfig stays a static closure constant."""
    d = jnp.maximum(jnp.asarray(d, jnp.float32), 1.0)
    pl_db = (-cfg.ref_gain_db
             + 10.0 * cfg.path_loss_exp * jnp.log10(d)
             + fading_db)
    p_rx_dbm = 10.0 * jnp.log10(jnp.asarray(tx_power_w, jnp.float32) * 1e3) \
        - pl_db
    noise_dbm = cfg.noise_dbm_hz + 10.0 * np.log10(cfg.bandwidth_hz)
    snr = 10.0 ** ((p_rx_dbm - noise_dbm) / 10.0)
    return cfg.bandwidth_hz * jnp.log2(1.0 + snr)


def distance_at(v: VehicleProfile, t: float) -> float:
    """Distance to the RSU (at x=0, height folded in) at time t."""
    x = v.x0_m + v.speed_mps * t
    return float(np.sqrt(x * x + RSU_HEIGHT_M ** 2))


def rate_bps(cfg: ChannelConfig, v: VehicleProfile, t: float,
             rng: np.random.Generator | None = None) -> float:
    """Shannon rate for one vehicle + optional shadow fading."""
    fading = (rng.normal(0.0, cfg.fading_std_db)
              if rng is not None and cfg.fading_std_db > 0 else 0.0)
    return float(_shannon_rate(cfg, distance_at(v, t), v.tx_power_w, fading))


def in_range(cfg: ChannelConfig, v: VehicleProfile, t: float) -> bool:
    return abs(v.x0_m + v.speed_mps * t) <= cfg.rsu_range_m


def residence_time(cfg: ChannelConfig, v: VehicleProfile, t: float) -> float:
    """Remaining time within RSU coverage (the training-completion deadline)."""
    x = v.x0_m + v.speed_mps * t
    if abs(x) > cfg.rsu_range_m:
        return 0.0
    return (cfg.rsu_range_m - x) / max(v.speed_mps, 1e-9)


def make_fleet(n: int, seed: int = 0,
               memory_budget_bytes: float | Tuple[float, float] | None = None
               ) -> List[VehicleProfile]:
    """Heterogeneous fleet: compute speeds and mobility vary per vehicle.
    ``memory_budget_bytes``: None = unconstrained; a scalar applies to every
    vehicle; a (lo, hi) pair samples per-vehicle budgets uniformly."""
    rng = np.random.default_rng(seed)
    fleet = []
    for i in range(n):
        fleet.append(VehicleProfile(
            compute_flops=float(rng.uniform(5e9, 50e9)),
            tx_power_w=float(rng.uniform(0.2, 1.0)),
            compute_power_w=float(rng.uniform(8.0, 25.0)),
            x0_m=float(rng.uniform(-350.0, -50.0)),
            speed_mps=float(rng.uniform(8.0, 30.0)),
        ))
    if memory_budget_bytes is not None:
        if isinstance(memory_budget_bytes, tuple):
            lo, hi = memory_budget_bytes
            budgets = rng.uniform(lo, hi, size=n)
        else:
            budgets = np.full(n, float(memory_budget_bytes))
        for v, b in zip(fleet, budgets):
            v.memory_budget_bytes = float(b)
    return fleet


def fleet_arrays(fleet: Sequence[VehicleProfile]) -> dict:
    """Column-major view of a fleet: one np array per attribute, so per-round
    channel sampling and cut selection cost one vector op for 256+ vehicles
    instead of a Python loop per vehicle."""
    return {
        "compute_flops": np.array([v.compute_flops for v in fleet]),
        "tx_power_w": np.array([v.tx_power_w for v in fleet]),
        "compute_power_w": np.array([v.compute_power_w for v in fleet]),
        "x0_m": np.array([v.x0_m for v in fleet]),
        "speed_mps": np.array([v.speed_mps for v in fleet]),
        "memory_budget_bytes": np.array([v.memory_budget_bytes
                                         for v in fleet]),
    }


def sample_round_rates(cfg: ChannelConfig, fleet: Sequence[VehicleProfile],
                       t: float, seed: int) -> np.ndarray:
    """Per-vehicle Shannon rates at time t, vectorized over the fleet
    (:func:`_shannon_rate` with one rng draw per vehicle, fleet-wide)."""
    fa = fleet if isinstance(fleet, dict) else fleet_arrays(fleet)
    x = fa["x0_m"] + fa["speed_mps"] * t
    d = np.sqrt(x * x + RSU_HEIGHT_M ** 2)
    return rates_from_distance(cfg, d, fa["tx_power_w"], seed)


def in_range_mask(cfg: ChannelConfig, fleet: Sequence[VehicleProfile],
                  t: float) -> np.ndarray:
    """Vectorized :func:`in_range` over the fleet -> bool (n,)."""
    fa = fleet if isinstance(fleet, dict) else fleet_arrays(fleet)
    return np.abs(fa["x0_m"] + fa["speed_mps"] * t) <= cfg.rsu_range_m
