"""Vehicular mobility simulation: watch the adaptive cut-layer rule react as
vehicles drive past the RSU (the paper's core 'adaptive' story).

Eight vehicles approach, pass, and leave the RSU's coverage; at each round
the channel model yields per-vehicle Shannon rates, and the three cut
strategies (paper Eq. 3, latency-optimal, energy-aware) pick cut layers.
Also demonstrates the memory-constrained clamp (a vehicle-side budget the
DBRX-scale architectures force — DESIGN.md §4).

  PYTHONPATH=src python examples/vehicular_sim.py
"""
import sys

sys.path.insert(0, "src")

import numpy as np

from repro.core import adaptive, channel
from repro.core.cost import resnet_profile, sfl_client_round_cost


def main():
    prof = resnet_profile()
    fleet = channel.make_fleet(8, seed=7)
    ch = channel.ChannelConfig()
    flops = [v.compute_flops for v in fleet]
    n_batches, batch, sf = 32, 16, 2e12

    print("t(s) | vehicle rates (Mbit/s) -> cuts [paper Eq.3] "
          "[latency-opt] [energy-aware]")
    for t in np.linspace(0, 30, 7):
        rates = channel.sample_round_rates(ch, fleet, float(t), seed=int(t))
        in_rng = [channel.in_range(ch, v, float(t)) for v in fleet]
        cuts_p = adaptive.paper_threshold(rates)
        cuts_l = adaptive.latency_optimal(prof, rates, flops, sf, n_batches,
                                          batch, candidate_cuts=(2, 4, 6, 8))
        cuts_e = adaptive.energy_aware(prof, rates, flops, sf, n_batches,
                                       batch, candidate_cuts=(2, 4, 6, 8))
        rstr = " ".join(f"{r/1e6:5.1f}{'' if ok else '!'}"
                        for r, ok in zip(rates, in_rng))
        print(f"{t:4.0f} | {rstr} -> {cuts_p} {cuts_l} {cuts_e}")
    print("('!' marks vehicles outside RSU coverage: they skip the round —")
    print(" the mobility interruption problem the paper highlights)")

    # round latency comparison at t=15
    rates = channel.sample_round_rates(ch, fleet, 15.0, seed=15)
    for name, cuts in [
        ("fixed cut 4 (SFL)", [4] * 8),
        ("paper Eq.3 (ASFL)", adaptive.paper_threshold(rates)),
        ("latency-optimal  ", adaptive.latency_optimal(
            prof, rates, flops, sf, n_batches, batch,
            candidate_cuts=(2, 4, 6, 8))),
    ]:
        lat = max(sfl_client_round_cost(prof, c, n_batches, batch, r, f, sf,
                                        local_epochs=5).latency
                  for c, r, f in zip(cuts, rates, flops))
        print(f"round latency {name}: {lat:7.1f}s  cuts={cuts}")

    # vehicle-side memory budget (the DBRX argument)
    budget = 64 * 1024 * 1024  # 64 MiB on-vehicle budget
    cuts = adaptive.memory_constrained(prof, budget, adaptive.paper_threshold,
                                       rates)
    print(f"with a {budget>>20} MiB vehicle budget the cuts clamp to {cuts}")


if __name__ == "__main__":
    main()
