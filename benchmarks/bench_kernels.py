"""Wire-kernel benchmark: fused sparsify+quant+pack vs separate XLA stages,
plus the end-to-end compression/accuracy contract (DESIGN.md §11).

Three row families land in ``BENCH_kernels.json`` (bench_io provenance):

* ``kernels``: per (rows, d, k_frac) — analytic bytes moved (dense fp32 vs
  packed wire words) and wall times for (a) the separate-stage XLA baseline
  (topk/quant -> pack as independently jitted, materialised stages), (b)
  the one-jit fused oracle (XLA fuses what it can), and (c) the Pallas
  kernel under ``interpret=True``.  Honesty note: on CPU Pallas interpret
  mode is a *correctness harness*, not a perf path — its times are reported
  so nobody mistakes them for kernel speed; the XLA-fused oracle is what
  CPU training executes, and the packed-bytes column is what the cost model
  charges on any backend.
* ``matmul``: RSU-side consumption — unpack-then-matmul (dense smashed
  tensor materialised) vs the fused group-loop consuming the packed buffer.
* ``model``: the acceptance contract — ``repro.api.run`` on the tier-1
  parity model (mlp9) at ``wire="none"`` vs ``wire="topk_int8"``: asserts
  >=4x smashed-traffic reduction (packed bytes, charged by the cost model)
  at <1% final-accuracy delta.

``--check-baseline BENCH_kernels.json [--max-regress 0.5]`` gates the
XLA-fused oracle times against the committed baseline (the CI perf smoke;
interpret-mode rows are never gated — they measure the interpreter).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

import jax
import jax.numpy as jnp
import numpy as np

from bench_io import write_bench
from repro import api
from repro.core import compression as C
from repro.core import cost
from repro.kernels import wire as W

KEY = jax.random.PRNGKey(0)


def _time(fn, *args, repeats: int = 5) -> float:
    jax.block_until_ready(fn(*args))          # compile + warm
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best


def bench_pack(rows: int, d: int, k_frac: float, repeats: int) -> dict:
    x = jax.random.normal(KEY, (rows, d)) * 3
    g, ng, k, wpg = C.wire_layout(d, k_frac)

    # (a) separate XLA stages: each jitted alone, intermediates materialise
    sparsify = jax.jit(lambda x: C.sparsify_topk_int8(x, k_frac))
    pack = jax.jit(lambda q, s, m: C._pack_groups(
        C._grouped(q, g)[0].astype(jnp.int32), s, C._grouped(m, g)[0], k))

    def separate(x):
        q, s, m = sparsify(x)
        return pack(q, s, m)

    fused_xla = jax.jit(lambda x: C.sparsify_quant_pack_ref(x, k_frac))
    pallas = jax.jit(lambda x: W.sparsify_quant_pack(x, k_frac,
                                                     interpret=True))
    return {
        "rows": rows, "d": d, "k_frac": k_frac,
        "dense_bytes": 4.0 * rows * d,
        "wire_bytes": 4.0 * rows * ng * wpg,
        "reduction": d / float(ng * wpg),
        "t_xla_separate_s": _time(separate, x, repeats=repeats),
        "t_xla_fused_s": _time(fused_xla, x, repeats=repeats),
        "t_pallas_interpret_s": _time(pallas, x, repeats=repeats),
    }


def bench_matmul(rows: int, d: int, n: int, repeats: int) -> dict:
    ks = jax.random.split(KEY, 2)
    x = jax.random.normal(ks[0], (rows, d)) * 3
    w = jax.random.normal(ks[1], (d, n))
    buf = C.sparsify_quant_pack_ref(x)

    # dense path: unpack to the full fp32 smashed tensor, then matmul
    dense_path = jax.jit(lambda b, w: C.wire_dequant_ref(b, d) @ w)
    fused_path = jax.jit(lambda b, w: C.wire_dequant_matmul_ref(b, w))
    pallas = jax.jit(lambda b, w: W.unpack_dequant_matmul(b, w,
                                                          interpret=True))
    return {
        "rows": rows, "d": d, "n": n,
        "smashed_dense_bytes": 4.0 * rows * d,
        "smashed_wire_bytes": float(C.wire_row_bytes(d) * rows),
        "t_unpack_then_matmul_s": _time(dense_path, buf, w,
                                        repeats=repeats),
        "t_fused_matmul_s": _time(fused_path, buf, w, repeats=repeats),
        "t_pallas_interpret_s": _time(pallas, buf, w, repeats=repeats),
    }


def bench_model(rounds: int, vehicles: int) -> dict:
    """The acceptance contract on the tier-1 parity model: >=4x smashed
    traffic reduction at <1% final-accuracy delta, both charged/scored the
    way the repo reports them (cost model bytes, test accuracy)."""
    entry = api.model_entry("mlp9")
    prof = entry.build().profile()
    out = {}
    for wire in ("none", "topk_int8"):
        spec = api.ExperimentSpec(
            model="mlp9",
            train=api.TrainConfig(scheme="asfl", rounds=rounds,
                                  local_steps=2, batch_size=8, lr=2e-3,
                                  eval_every=1, wire=wire),
            adaptive=api.AdaptiveConfig(strategy="paper"),
            fleet=api.FleetConfig(n_vehicles=vehicles,
                                  scenario="single_rsu",
                                  per_vehicle_samples=64, data_seed=0),
        )
        res = api.run(spec)
        accs = [m.test_acc for m in res.history if np.isfinite(m.test_acc)]
        smashed = 0.0
        for m in res.history:
            up, down = cost.effective_comm_bytes(
                prof, np.asarray(m.cuts), 2, 8, wire=wire,
                include_model_transfer=False)
            smashed += float(np.sum(up + down))
        out[wire] = {"final_acc": float(accs[-1]),
                     "smashed_bytes": smashed,
                     "total_comm_bytes": float(sum(m.comm_bytes
                                                   for m in res.history))}
    reduction = out["none"]["smashed_bytes"] \
        / max(out["topk_int8"]["smashed_bytes"], 1.0)
    acc_delta = abs(out["none"]["final_acc"]
                    - out["topk_int8"]["final_acc"])
    row = {"rounds": rounds, "vehicles": vehicles, "model": "mlp9",
           "smashed_reduction": reduction, "acc_delta": acc_delta, **out}
    assert reduction >= 4.0, \
        f"smashed-traffic reduction {reduction:.2f}x < 4x floor"
    assert acc_delta < 0.01, \
        f"final-accuracy delta {acc_delta:.4f} >= 1% ceiling"
    return row


def check_baseline(out: dict, baseline_path: str, max_regress: float) -> int:
    """CI perf gate over the XLA-fused oracle times (the CPU training
    path); interpret-mode rows are informational only."""
    if not os.path.exists(baseline_path):
        print(f"baseline {baseline_path} missing; skipping perf check")
        return 0
    with open(baseline_path) as f:
        base = json.load(f)
    base_rows = {(r["rows"], r["d"], r["k_frac"]): r
                 for r in base.get("kernels", [])}
    failures = []
    for row in out["kernels"]:
        key = (row["rows"], row["d"], row["k_frac"])
        if key not in base_rows:
            print(f"no baseline row for {key}; skipping")
            continue
        b = base_rows[key]
        # packed-size accounting is analytic: any drift is a bug, not noise
        if row["wire_bytes"] != b["wire_bytes"]:
            print(f"wire_bytes drift at {key}: {row['wire_bytes']} vs "
                  f"baseline {b['wire_bytes']}")
            failures.append(key)
            continue
        ceil = b["t_xla_fused_s"] * (1.0 + max_regress)
        status = "OK" if row["t_xla_fused_s"] <= ceil else "REGRESSION"
        print(f"perf {key}: fused {row['t_xla_fused_s']*1e3:.2f} ms vs "
              f"baseline {b['t_xla_fused_s']*1e3:.2f} "
              f"(ceil {ceil*1e3:.2f}) {status}")
        if row["t_xla_fused_s"] > ceil:
            failures.append(key)
    if failures:
        print(f"kernel perf regression >{max_regress:.0%}: {failures}")
        return 1
    return 0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--repeats", type=int, default=5)
    ap.add_argument("--model-rounds", type=int, default=16)
    ap.add_argument("--model-vehicles", type=int, default=8)
    ap.add_argument("--skip-model", action="store_true",
                    help="skip the end-to-end accuracy/traffic contract")
    ap.add_argument("--check-baseline", default=None, metavar="JSON")
    ap.add_argument("--max-regress", type=float, default=0.50,
                    help="micro-kernel times are noisier than engine "
                         "rounds/s; the gate margin is wider to match")
    ap.add_argument("--no-write", action="store_true")
    args = ap.parse_args()

    kernels = []
    for rows, d, k_frac in [(256, 64, 0.25), (256, 128, 0.25),
                            (1024, 128, 0.25), (1024, 128, 0.1),
                            (1024, 384, 0.25)]:
        row = bench_pack(rows, d, k_frac, args.repeats)
        kernels.append(row)
        print(f"pack ({rows:5d},{d:4d}) k={k_frac:.2f} "
              f"{row['reduction']:5.2f}x bytes  "
              f"separate={row['t_xla_separate_s']*1e3:7.2f} ms  "
              f"fused-xla={row['t_xla_fused_s']*1e3:7.2f} ms  "
              f"pallas-interp={row['t_pallas_interpret_s']*1e3:8.2f} ms",
              flush=True)

    matmuls = []
    for rows, d, n in [(256, 64, 64), (1024, 128, 64)]:
        row = bench_matmul(rows, d, n, args.repeats)
        matmuls.append(row)
        print(f"matmul ({rows:5d},{d:4d})x({d},{n:3d})  "
              f"unpack+mm={row['t_unpack_then_matmul_s']*1e3:7.2f} ms  "
              f"fused={row['t_fused_matmul_s']*1e3:7.2f} ms  "
              f"pallas-interp={row['t_pallas_interpret_s']*1e3:8.2f} ms",
              flush=True)

    model = None
    if not args.skip_model:
        model = bench_model(args.model_rounds, args.model_vehicles)
        print(f"model mlp9: smashed reduction "
              f"{model['smashed_reduction']:.2f}x, acc delta "
              f"{model['acc_delta']:.4f} "
              f"(none {model['none']['final_acc']:.4f} vs topk_int8 "
              f"{model['topk_int8']['final_acc']:.4f})", flush=True)

    out = {
        "config": {"repeats": args.repeats, "group": C.GROUP,
                   "backend": jax.default_backend(),
                   "interpret_note": "Pallas rows run interpret=True on "
                   "CPU — correctness-harness timings, not kernel speed"},
        "kernels": kernels, "matmul": matmuls, "model": model,
    }
    if not args.no_write:
        write_bench("BENCH_kernels", out, "benchmarks/bench_kernels.py")
    if args.check_baseline:
        sys.exit(check_baseline(out, args.check_baseline,
                                args.max_regress))


if __name__ == "__main__":
    main()
