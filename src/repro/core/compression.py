"""Smashed-data compression at the cut boundary (beyond-paper optimization).

The paper's point is that SFL trades communication for computation; the
natural next step (its §IV-D 'wireless resource allocation' direction) is to
shrink the uplink itself.  We use per-group symmetric int8 quantisation of
the cut activations (and, optionally, of the returned cut-layer gradients):
4x fewer bytes over the wireless link in the simulator, and 4x fewer
collective bytes at the sharding boundary in the datacenter realisation.

A straight-through estimator keeps the backward path exact w.r.t. the
dequantised values.  ``repro.kernels.quant`` provides the Pallas TPU kernel
with identical semantics (this module is its oracle).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

GROUP = 128  # quantisation group along the trailing axis

# scale = amax * (1/127), written as a multiply: XLA's algebraic simplifier
# rewrites division-by-constant into this form under jit but not in eager
# dispatch — using the multiply everywhere keeps eager, jit and Pallas
# interpret mode bit-identical (the kernel parity tests assert exact equality)
INV127 = 1.0 / 127.0

# wire schemes at the cut boundary (DESIGN.md §11): "none" ships dense fp32,
# "int8" the per-group quant above, "topk_int8" adds per-group top-k
# sparsification with error feedback and a packed int32 wire buffer
WIRE_SCHEMES = ("none", "int8", "topk_int8")
WIRE_K = 0.25  # default keep-fraction per group for topk_int8


def _group_shape(d: int, group: int) -> Tuple[int, int]:
    """(group size, group count) for a trailing dim: g = min(group, d)
    groups, the last one zero-padded when d is not a multiple of g."""
    g = min(group, max(d, 1))
    return g, -(-d // g)                       # ceil(d / g)


def quantize_int8(x: jnp.ndarray, group: int = GROUP
                  ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-(trailing-)group symmetric int8.  Returns (q int8 (..., d),
    scales f32 (..., ceil(d/g))).

    A trailing dim that is not a multiple of the group size is padded with
    zeros INTERNALLY to the next group boundary — the pad never changes any
    group's amax/scale and is sliced off the returned q, so callers get
    ``group``-granular quantisation for every d (previously the whole row
    silently collapsed into one group — coarser scales with no warning)."""
    *lead, d = x.shape
    g, ng = _group_shape(d, group)
    pad = ng * g - d
    if pad:
        x = jnp.concatenate(
            [x, jnp.zeros((*lead, pad), x.dtype)], axis=-1)
    xg = x.reshape(*lead, ng, g).astype(jnp.float32)
    amax = jnp.max(jnp.abs(xg), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-8) * INV127
    q = jnp.clip(jnp.round(xg / scale), -127, 127).astype(jnp.int8)
    return q.reshape(*lead, ng * g)[..., :d], scale[..., 0]


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray, dtype=jnp.float32,
                    group: int = GROUP) -> jnp.ndarray:
    """Inverse of :func:`quantize_int8` (pass the same ``group``).  The
    group size is re-derived as min(group, d); when the scale count says
    the producer used a different (exactly dividing) group, that wins —
    so custom divisible groups round-trip without threading ``group``.
    A custom group on a NON-divisible dim is the one ambiguous case (the
    scale count alone cannot recover it): there you must pass the same
    ``group`` you quantized with, or the groups are mis-sliced."""
    *lead, d = q.shape
    ng = scale.shape[-1]
    g, ng_default = _group_shape(d, group)
    if ng != ng_default:
        g = d // ng                            # custom exactly-dividing group
    pad = ng * g - d
    if pad:
        q = jnp.concatenate(
            [q, jnp.zeros((*lead, pad), q.dtype)], axis=-1)
    xg = q.reshape(*lead, ng, g).astype(jnp.float32) * scale[..., None]
    return xg.reshape(*lead, ng * g)[..., :d].astype(dtype)


@jax.custom_vjp
def fake_quant(x: jnp.ndarray) -> jnp.ndarray:
    """Quantise-dequantise with a straight-through gradient."""
    q, s = quantize_int8(x)
    return dequantize_int8(q, s, x.dtype)


def _fq_fwd(x):
    return fake_quant(x), None


def _fq_bwd(_, g):
    return (g,)


fake_quant.defvjp(_fq_fwd, _fq_bwd)


def effective_group(trailing_dim, group: int = GROUP):
    """The group size :func:`quantize_int8` actually uses for a trailing dim
    ``d``: min(group, d) — non-divisible dims are padded internally to the
    next group boundary, so the granularity never coarsens.  Vectorized over
    arrays of trailing dims (per-cut smashed channel counts)."""
    d = np.asarray(trailing_dim)
    return np.minimum(group, np.maximum(d, 1))


def compression_ratio(dtype_bytes: int = 4, group: int = GROUP,
                      trailing_dim: Optional[Union[int, np.ndarray]] = None
                      ) -> Union[float, np.ndarray]:
    """Bytes(fp) / bytes(int8 + f32 scale per group).

    Pass ``trailing_dim`` (scalar or per-cut array) to account with the
    groups :func:`quantize_int8` actually emits — ceil(d/g) scales with
    g = min(group, d): a 64-channel smashed tensor quantizes in 64-wide
    groups (more scale overhead than the nominal GROUP-wide assumption),
    and a 200-channel one pays a second scale for its padded tail group."""
    if trailing_dim is None:
        return dtype_bytes * group / (group + 4.0)
    d = np.asarray(trailing_dim)
    g = effective_group(d, group)
    ng = -(-d // g)                            # ceil: padded tail group
    ratio = dtype_bytes * d / (d + 4.0 * ng)
    return float(ratio) if np.ndim(ratio) == 0 else ratio


# --------------------------------------------------------------------------
# topk_int8 wire format (DESIGN.md §11)
# --------------------------------------------------------------------------
# Per quantisation group of g values, exactly k = clip(round(k_frac*g), 1, g)
# survivors (largest |x|, ties to the lower index) are int8-quantised with the
# group's amax/127 scale and packed into ceil(g/32) + 1 + ceil(k/4) int32
# words:
#
#   [ bitmap: ceil(g/32) words | scale: 1 word (f32 bitcast) |
#     values: ceil(k/4) words, 4 int8 lanes each, survivor order ]
#
# The exactly-k rule keeps every shape static (no data-dependent packing), so
# the format composes with jit / scan / shard_map with zero retraces.  These
# jnp functions are the oracles for the fused Pallas kernels in
# repro.kernels.wire (bit-exact in interpret mode).

def wire_layout(d: int, k_frac: float = WIRE_K, group: int = GROUP
                ) -> Tuple[int, int, int, int]:
    """(g, ng, k, words_per_group) for trailing dim ``d``.  k_frac <= 0
    degenerates to k=1 (at least one survivor per group keeps the format
    non-empty); k_frac >= 1 keeps the whole group (quant-only)."""
    g, ng = _group_shape(d, group)
    k = int(min(max(int(round(float(k_frac) * g)), 1), g))
    wpg = -(-g // 32) + 1 + -(-k // 4)
    return g, ng, k, wpg


def _topk_mask(absx: jnp.ndarray, k: int) -> jnp.ndarray:
    """Per-group top-k mask over the trailing axis.  Rank by pairwise
    comparison with ties broken toward the lower index — a total order, so
    exactly k elements win and the oracle/kernel agree bit-for-bit (no
    reliance on a sort primitive's tie behavior)."""
    g = absx.shape[-1]
    ii = jax.lax.broadcasted_iota(jnp.int32, (g, g), 0)   # candidate
    jj = jax.lax.broadcasted_iota(jnp.int32, (g, g), 1)   # competitor
    beats = ((absx[..., None, :] > absx[..., :, None])
             | ((absx[..., None, :] == absx[..., :, None]) & (jj < ii)))
    rank = jnp.sum(beats.astype(jnp.int32), axis=-1)
    return rank < k


def _grouped(x: jnp.ndarray, group: int):
    """Zero-pad the trailing dim to the group boundary and reshape to
    (..., ng, g); returns (xg, g, ng, d)."""
    *lead, d = x.shape
    g, ng = _group_shape(d, group)
    pad = ng * g - d
    if pad:
        x = jnp.concatenate([x, jnp.zeros((*lead, pad), x.dtype)], axis=-1)
    return x.reshape(*lead, ng, g), g, ng, d


def sparsify_topk_int8(x: jnp.ndarray, k_frac: float = WIRE_K,
                       group: int = GROUP):
    """Top-k sparsify + int8 quantise.  Returns (q int8 (..., d) with zeros
    off-mask, scales f32 (..., ng), mask bool (..., d)).  The scale is the
    full group's amax/127 — identical to :func:`quantize_int8`, since the
    group maximum always survives top-k."""
    xg, g, ng, d = _grouped(x, group)
    k = wire_layout(d, k_frac, group)[2]
    xg = xg.astype(jnp.float32)
    absx = jnp.abs(xg)
    amax = jnp.max(absx, axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-8) * INV127
    mask = _topk_mask(absx, k)
    q = jnp.where(mask, jnp.clip(jnp.round(xg / scale), -127, 127), 0)
    lead = x.shape[:-1]
    return (q.astype(jnp.int8).reshape(*lead, ng * g)[..., :d],
            scale[..., 0],
            mask.reshape(*lead, ng * g)[..., :d])


def _pack_groups(q: jnp.ndarray, scale: jnp.ndarray, mask: jnp.ndarray,
                 k: int) -> jnp.ndarray:
    """(..., ng, g) int32 q / (..., ng) scale / (..., ng, g) mask ->
    (..., ng, wpg) int32 words.  Disjoint-bit adds are exact ORs."""
    *lead, ng, g = q.shape
    bw, vw = -(-g // 32), -(-k // 4)
    m32 = mask.astype(jnp.int32)
    # bitmap: bit (i % 32) of word (i // 32) = mask[i]
    pad_b = bw * 32 - g
    mb = jnp.concatenate(
        [m32, jnp.zeros((*lead, ng, pad_b), jnp.int32)], axis=-1
    ) if pad_b else m32
    shifts = jax.lax.broadcasted_iota(jnp.int32, (bw, 32), 1)
    bitmap = jnp.sum(jnp.left_shift(mb.reshape(*lead, ng, bw, 32), shifts),
                     axis=-1)
    # survivor compaction via one-hot matmul: exact (one survivor per slot)
    pos = jnp.cumsum(m32, axis=-1) - 1                       # (..., ng, g)
    slot = jax.lax.broadcasted_iota(jnp.int32, (g, k), 1)
    onehot = ((pos[..., None] == slot) & mask[..., None]).astype(jnp.int32)
    vals = jnp.sum(q[..., None] * onehot, axis=-2)           # (..., ng, k)
    pad_v = vw * 4 - k
    vb = jnp.concatenate(
        [vals, jnp.zeros((*lead, ng, pad_v), jnp.int32)], axis=-1
    ) if pad_v else vals
    lanes = jax.lax.broadcasted_iota(jnp.int32, (vw, 4), 1)
    words = jnp.sum(jnp.left_shift(
        jnp.bitwise_and(vb.reshape(*lead, ng, vw, 4), 0xFF), 8 * lanes),
        axis=-1)
    sword = jax.lax.bitcast_convert_type(scale.astype(jnp.float32),
                                         jnp.int32)[..., None]
    return jnp.concatenate([bitmap, sword, words], axis=-1)


def _unpack_groups(buf: jnp.ndarray, g: int, k: int):
    """(..., ng, wpg) int32 -> (q int32 (..., ng, g), scale (..., ng),
    mask bool (..., ng, g)).  Exact inverse of :func:`_pack_groups`."""
    *lead, ng, _ = buf.shape
    bw, vw = -(-g // 32), -(-k // 4)
    bitmap = buf[..., :bw]
    scale = jax.lax.bitcast_convert_type(buf[..., bw], jnp.float32)
    words = buf[..., bw + 1:]
    shifts = jax.lax.broadcasted_iota(jnp.int32, (bw, 32), 1)
    mask = jnp.bitwise_and(
        jnp.right_shift(bitmap[..., None], shifts), 1
    ).reshape(*lead, ng, bw * 32)[..., :g].astype(bool)
    lanes = jax.lax.broadcasted_iota(jnp.int32, (vw, 4), 1)
    bytes_ = jnp.bitwise_and(
        jnp.right_shift(words[..., None], 8 * lanes), 0xFF)
    vals = bytes_.reshape(*lead, ng, vw * 4)[..., :k]
    vals = vals - 256 * (vals > 127)                         # sign-extend
    # scatter survivors back: transpose of the pack-side one-hot
    pos = jnp.cumsum(mask.astype(jnp.int32), axis=-1) - 1
    slot = jax.lax.broadcasted_iota(jnp.int32, (g, k), 1)
    onehot = ((pos[..., None] == slot) & mask[..., None]).astype(jnp.int32)
    q = jnp.sum(vals[..., None, :] * onehot, axis=-1)        # (..., ng, g)
    return q, scale, mask


def sparsify_quant_pack_ref(x: jnp.ndarray, k_frac: float = WIRE_K,
                            group: int = GROUP) -> jnp.ndarray:
    """Fused-oracle: x (..., d) -> packed wire buffer int32 (..., ng*wpg).
    Oracle for ``repro.kernels.wire.sparsify_quant_pack`` (bit-exact)."""
    xg, g, ng, d = _grouped(x, group)
    k, wpg = wire_layout(d, k_frac, group)[2:]
    xg = xg.astype(jnp.float32)
    absx = jnp.abs(xg)
    amax = jnp.max(absx, axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-8) * INV127
    mask = _topk_mask(absx, k)
    q = jnp.where(mask, jnp.clip(jnp.round(xg / scale), -127, 127),
                  0).astype(jnp.int32)
    buf = _pack_groups(q, scale[..., 0], mask, k)
    return buf.reshape(*x.shape[:-1], ng * wpg)


def unpack_wire(buf: jnp.ndarray, d: int, k_frac: float = WIRE_K,
                group: int = GROUP):
    """Packed buffer (..., ng*wpg) -> (q int8 (..., d), scales (..., ng),
    mask bool (..., d)).  Round-trip identity with
    :func:`sparsify_quant_pack_ref` / :func:`sparsify_topk_int8`."""
    g, ng, k, wpg = wire_layout(d, k_frac, group)
    *lead, _ = buf.shape
    q, scale, mask = _unpack_groups(buf.reshape(*lead, ng, wpg), g, k)
    return (q.astype(jnp.int8).reshape(*lead, ng * g)[..., :d],
            scale,
            mask.reshape(*lead, ng * g)[..., :d])


def wire_dequant_ref(buf: jnp.ndarray, d: int, k_frac: float = WIRE_K,
                     group: int = GROUP, dtype=jnp.float32) -> jnp.ndarray:
    """Packed buffer -> dense (..., d): unpack + dequantise."""
    q, scale, _ = unpack_wire(buf, d, k_frac, group)
    return dequantize_int8(q, scale, dtype, group)


def wire_dequant_matmul_ref(buf: jnp.ndarray, w: jnp.ndarray,
                            k_frac: float = WIRE_K, group: int = GROUP
                            ) -> jnp.ndarray:
    """Packed buffer (rows, ng*wpg) @ w (d, n) -> (rows, n) f32 without ever
    materialising the dense smashed tensor at full width: accumulate one
    g-wide slab per group, mirroring the Pallas kernel's loop order so the
    f32 accumulation is bit-exact against it."""
    d, n = w.shape
    g, ng, k, wpg = wire_layout(d, k_frac, group)
    rows = buf.shape[0]
    q, scale, _ = _unpack_groups(buf.reshape(rows, ng, wpg), g, k)
    pad = ng * g - d
    wp = jnp.concatenate([w, jnp.zeros((pad, n), w.dtype)]) if pad else w
    wg = wp.reshape(ng, g, n).astype(jnp.float32)
    acc = jnp.zeros((rows, n), jnp.float32)
    for j in range(ng):                        # static ng: unrolled, ordered
        dense = q[:, j].astype(jnp.float32) * scale[:, j, None]
        acc = acc + jnp.dot(dense, wg[j])
    return acc


def wire_topk_dense(x: jnp.ndarray, k_frac: float = WIRE_K,
                    group: int = GROUP) -> jnp.ndarray:
    """Dense equivalent of one wire trip: sparsify -> quantise -> dequantise.
    What the receiver reconstructs from the packed buffer."""
    q, s, _ = sparsify_topk_int8(x, k_frac, group)
    return dequantize_int8(q, s, x.dtype, group)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def wire_fake(x: jnp.ndarray, k_frac: float = WIRE_K,
              group: int = GROUP) -> jnp.ndarray:
    """Straight-through top-k+int8 (stateless: no error feedback).  The
    cohort engine's wire site — the superstep engine uses
    :func:`wire_boundary`, which carries residuals."""
    return wire_topk_dense(x, k_frac, group)


def _wf_fwd(x, k_frac, group):
    return wire_fake(x, k_frac, group), None


def _wf_bwd(k_frac, group, _, g):
    # symmetric downlink: the cut-layer gradient rides the same wire
    return (wire_topk_dense(g, k_frac, group),)


wire_fake.defvjp(_wf_fwd, _wf_bwd)


@jax.custom_vjp
def quant_boundary(x: jnp.ndarray) -> jnp.ndarray:
    """wire="int8" cut boundary: quantise-dequantise forward, and the
    incoming cut-layer gradient is quantised too (the symmetric downlink
    path) — one site expressing both directions of the int8 wire."""
    q, s = quantize_int8(x)
    return dequantize_int8(q, s, x.dtype)


def _qb_fwd(x):
    return quant_boundary(x), None


def _qb_bwd(_, g):
    q, s = quantize_int8(g)
    return (dequantize_int8(q, s, g.dtype),)


quant_boundary.defvjp(_qb_fwd, _qb_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def wire_boundary(x: jnp.ndarray, res: jnp.ndarray, k_frac: float = WIRE_K,
                  group: int = GROUP):
    """Error-feedback wire boundary (topk_int8): compress x + res, return
    (received value, new residual).  The residual is the part the wire
    dropped; the caller persists it per vehicle and feeds it back on that
    vehicle's next step, so the compression error telescopes instead of
    accumulating (EF-SGD).  Backward: the cut-layer gradient rides the same
    stateless compressed path; the residual gets no cotangent."""
    xc = x + res.astype(x.dtype)
    y = wire_topk_dense(xc, k_frac, group)
    return y, (xc - y).astype(res.dtype)


def _wb_fwd(x, res, k_frac, group):
    return wire_boundary(x, res, k_frac, group), None


def _wb_bwd(k_frac, group, _, cts):
    g_y, g_res = cts
    return (wire_topk_dense(g_y, k_frac, group), jnp.zeros_like(g_res))


wire_boundary.defvjp(_wb_fwd, _wb_bwd)


# ------------------------------------------------------- byte accounting

def wire_row_bytes(trailing_dim, k_frac: float = WIRE_K, group: int = GROUP):
    """Packed topk_int8 bytes for one row of trailing dim d (vectorized over
    arrays of per-cut dims): 4 bytes per int32 word, ng*wpg words."""
    d = np.asarray(trailing_dim)
    g = effective_group(d, group)
    ng = -(-d // g)
    k = np.clip(np.round(k_frac * g).astype(np.int64), 1, g)
    wpg = -(-g // 32) + 1 + -(-k // 4)
    out = 4.0 * ng * wpg
    return float(out) if np.ndim(out) == 0 else out


def wire_compression_ratio(wire: str = "topk_int8", dtype_bytes: int = 4,
                           group: int = GROUP, trailing_dim=None,
                           k_frac: float = WIRE_K):
    """Dense-fp bytes / wire bytes for a scheme — the factor the cost model
    divides smashed traffic by (both directions; see cost.py)."""
    if wire not in WIRE_SCHEMES:
        raise ValueError(f"unknown wire scheme {wire!r}; one of "
                         f"{WIRE_SCHEMES}")
    if wire == "none":
        return 1.0
    if wire == "int8":
        return compression_ratio(dtype_bytes, group, trailing_dim)
    d = np.asarray(group if trailing_dim is None else trailing_dim)
    ratio = dtype_bytes * d / wire_row_bytes(d, k_frac, group)
    return float(ratio) if np.ndim(ratio) == 0 else ratio
