"""Cohort-engine scaling benchmark: fleet sizes {4, 16, 64, 256}, sfl/asfl.

Compares the vectorized :class:`CohortEngine` federation round against the
seed per-client Python loop (one jit dispatch + one ``float(loss)`` host sync
per client per batch, per-batch host staging, Python slice/merge optimizer
surgery) at EQUAL rounds/local-steps/batches — both sides consume identical
batch streams and make identical cut decisions, and evaluation is disabled on
both, so the measured gap is pure round-execution overhead.

The default model is a 9-unit split MLP: small enough that a local step is
milliseconds, which is exactly the regime where the seed loop's per-dispatch
overhead dominates at fleet scale (a vehicle-side perception model is small;
the simulator's job is to scale the *federation*, not the FLOPs).  ``--model
resnet`` runs the paper's ResNet18 instead — on CPU containers that is
conv-compute-bound and mostly measures XLA's conv throughput, not the
engine (see DESIGN.md §6).

Timing is post-warmup: each simulator runs once to compile every round
structure, is reset (same seeds => same rate draws => same cuts => warm
caches), and only the re-run is timed.

  PYTHONPATH=src python benchmarks/bench_fedsim.py
  -> BENCH_fedsim.json (repo root) + benchmarks/out/BENCH_fedsim.json
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import math
import os
import time
from typing import List, Optional, Tuple

import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import aggregation, cost
from repro.core.fedsim import (FederationSim, ResNetModel, SimConfig,
                               _make_opt, make_sfl_batch_step)
from repro.data.pipeline import ClientDataset
from repro import optim

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
OUT_DIR = os.path.join(os.path.dirname(__file__), "out")


# --------------------------------------------------------------- bench model
class MLPUnitModel:
    """9-unit split MLP over feature vectors — the dispatch-bound bench model
    (mirrors the ResNet's 9 split points; every cut in {2,4,6,8} is valid)."""
    name = "mlp-split"
    scan_friendly = True

    def __init__(self, dim: int = 48, width: int = 64, n_units: int = 9,
                 n_classes: int = 10):
        self.dim, self.width, self.n_units = dim, width, n_units
        self.n_classes = n_classes

    def init(self, key):
        ks = jax.random.split(key, self.n_units + 1)
        units = []
        d_in = self.dim
        for i in range(self.n_units):
            units.append({
                "w": jax.random.normal(ks[i], (d_in, self.width))
                * math.sqrt(2.0 / d_in),
                "b": jnp.zeros((self.width,)),
            })
            d_in = self.width
        head = {"w": jax.random.normal(ks[-1], (self.width, self.n_classes))
                * math.sqrt(1.0 / self.width),
                "b": jnp.zeros((self.n_classes,))}
        return units, head

    def apply_units(self, units, x, start):
        for u in units:
            x = jax.nn.relu(x @ u["w"] + u["b"])
        return x

    def head_loss(self, head, feats, labels):
        logits = feats @ head["w"] + head["b"]
        logz = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
        return jnp.mean(logz - gold), logits

    def head_predict(self, head, feats):
        return feats @ head["w"] + head["b"]

    def profile(self):
        w, d = self.width, self.dim
        flops = [2.0 * d * w] + [2.0 * w * w] * (self.n_units - 1)
        pbytes = [(d * w + w) * 4] + [(w * w + w) * 4] * (self.n_units - 1)
        return cost.SplitProfile(
            name=self.name, unit_fwd_flops=flops, unit_param_bytes=pbytes,
            smashed_bytes_per_sample=[w * 4.0] * self.n_units,
            head_flops=2.0 * w * self.n_classes,
            head_param_bytes=(w * self.n_classes + self.n_classes) * 4,
            smashed_trailing_dim=[w] * self.n_units)


def make_mlp_fleet_data(n_clients: int, per_client: int, dim: int, seed: int):
    """Class-structured feature vectors, one shard per vehicle."""
    rng = np.random.default_rng(seed)
    templates = rng.normal(size=(10, dim)).astype(np.float32)
    clients = []
    for i in range(n_clients):
        y = rng.integers(0, 10, size=per_client)
        x = templates[y] + 0.5 * rng.normal(size=(per_client, dim))
        clients.append(ClientDataset(x.astype(np.float32),
                                     y.astype(np.int32), i))
    yt = rng.integers(0, 10, size=256)
    xt = templates[yt] + 0.5 * rng.normal(size=(256, dim))
    test = {"images": jnp.asarray(xt.astype(np.float32)),
            "labels": jnp.asarray(yt.astype(np.int32))}
    return clients, test


# ------------------------------------------------- seed per-client loop sim
class SeedLoopSim(FederationSim):
    """The seed FederationSim's `_parallel_split_round`, verbatim: a Python
    loop over clients per local step, one jitted dispatch and one
    `float(loss)` host sync per client batch, per-batch `sample_batch`
    staging, Python dict surgery on the shared RSU optimizer state, and
    Python-list unit-wise FedAvg at round end."""

    def __init__(self, *args, **kwargs):
        super().__init__(*args, **kwargs)
        self._sfl_steps = {}

    def _sfl_step(self, cut):
        if cut not in self._sfl_steps:
            self._sfl_steps[cut] = make_sfl_batch_step(self.model, self.cfg,
                                                       cut)
        return self._sfl_steps[cut]

    def _parallel_split_round(self, rnd):
        from repro.core.fedsim import RoundMetrics
        cfgc = self.cfg
        rates = self._round_rates(rnd)
        participants = set(self._participants(rnd))
        cuts = [max(1, min(c, self.model.n_units - 1))
                for c in self._pick_cuts(rates)]
        opt = _make_opt(cfgc)
        n_units = self.model.n_units

        server_units = [jax.tree.map(lambda a: a, u) for u in self.units]
        head = self.head
        s_opt_full = opt.init({"units": server_units, "head": head})

        def slice_opt(cut):
            out = {}
            for k, v in s_opt_full.items():
                if isinstance(v, dict) and "units" in v:
                    out[k] = {"units": v["units"][cut:], "head": v["head"]}
                else:
                    out[k] = v
            return out

        def merge_opt(new, cut):
            for k, v in new.items():
                if isinstance(v, dict) and "units" in v:
                    s_opt_full[k]["units"] = (
                        list(s_opt_full[k]["units"][:cut]) + list(v["units"]))
                    s_opt_full[k]["head"] = v["head"]
                else:
                    s_opt_full[k] = v

        client_units = [[jax.tree.map(lambda a: a, u)
                         for u in self.units[:cut]] for cut in cuts]
        c_opts = [opt.init(cu) for cu in client_units]

        losses = []
        steps = max(self._local_steps(c) for c in self.clients)
        for s in range(steps):
            for ci, c in enumerate(self.clients):
                if ci not in participants or s >= self._local_steps(c):
                    continue
                cut = cuts[ci]
                step = self._sfl_step(cut)
                batch = c.sample_batch(cfgc.batch_size,
                                       cfgc.seed + rnd * 983 + s * 31 + ci)
                sv = server_units[cut:]
                (client_units[ci], new_sv, head, c_opts[ci], new_s_opt,
                 loss, _) = step(client_units[ci], sv, head, c_opts[ci],
                                 slice_opt(cut), batch)
                server_units[cut:] = list(new_sv)
                merge_opt(new_s_opt, cut)
                losses.append(float(loss))

        unit_replicas = [[] for _ in range(n_units)]
        unit_weights = [[] for _ in range(n_units)]
        for ci, c in enumerate(self.clients):
            if ci not in participants:
                continue
            w = float(len(c))
            for u in range(cuts[ci]):
                unit_replicas[u].append(client_units[ci][u])
                unit_weights[u].append(w)
        for u in range(n_units):
            served = sum(len(c) for ci, c in enumerate(self.clients)
                         if ci in participants and cuts[ci] <= u)
            if served:
                unit_replicas[u].append(server_units[u])
                unit_weights[u].append(float(served))
        self.units = [aggregation.fedavg(unit_replicas[u], unit_weights[u])
                      if unit_replicas[u] else self.units[u]
                      for u in range(n_units)]
        self.head = head
        return self._metrics(rnd, float(np.mean(losses)), cuts, 0.0, 0.0, 0.0)


# ----------------------------------------------------------------- protocol
def _timed_run(sim) -> Tuple[float, float]:
    """Warmup run (compiles every round structure), reset, timed re-run.
    Returns (warmup seconds, seconds per round)."""
    t0 = time.perf_counter()
    sim.run()
    warmup = time.perf_counter() - t0
    sim.reset()
    t0 = time.perf_counter()
    hist = sim.run()
    dt = time.perf_counter() - t0
    assert all(np.isfinite(m.loss) for m in hist)
    return warmup, dt / len(hist)


def bench(sizes: List[int], schemes: List[str], model_kind: str,
          per_client: int, local_steps: int, batch: int, rounds: int,
          seed_loop_max: int,
          compilation_cache: Optional[str] = None) -> dict:
    results = []
    for n in sizes:
        if model_kind == "mlp":
            model_f = lambda: MLPUnitModel()
            clients, test = make_mlp_fleet_data(n, per_client, 48, seed=n)
        else:
            from repro.data.pipeline import make_federated_data
            model_f = lambda: ResNetModel()
            clients, test = make_federated_data(0, n_train=per_client * n,
                                                n_test=256, n_clients=n)
        for scheme in schemes:
            cfg = SimConfig(scheme=scheme, rounds=rounds,
                            local_steps=local_steps, batch_size=batch,
                            lr=1e-3, eval_every=0,
                            compilation_cache_dir=compilation_cache)
            eng = FederationSim(model_f(), clients, test, cfg)
            t_warm, t_eng = _timed_run(eng)
            row = {"scheme": scheme, "n_clients": n, "mode": eng.engine.mode,
                   "engine_round_s": t_eng, "warmup_s": t_warm,
                   "seed_round_s": None, "speedup": None}
            if n <= seed_loop_max and scheme in ("sfl", "asfl"):
                ref = SeedLoopSim(model_f(), clients, test, cfg)
                _, t_ref = _timed_run(ref)
                row["seed_round_s"] = t_ref
                row["speedup"] = t_ref / t_eng
                # both sides consumed identical batch streams & cuts
                np.testing.assert_allclose(
                    eng.history[-1].loss, ref.history[-1].loss,
                    rtol=0.05, atol=0.05)
            results.append(row)
            print(f"{scheme:5s} n={n:4d} mode={row['mode']:6s} "
                  f"engine={t_eng*1e3:9.1f} ms/round"
                  + (f"  seed={row['seed_round_s']*1e3:9.1f} ms/round"
                     f"  speedup={row['speedup']:.1f}x"
                     if row["speedup"] else ""), flush=True)
    return {
        "config": {"model": model_kind, "per_client": per_client,
                   "local_steps": local_steps, "batch": batch,
                   "rounds": rounds, "backend": jax.default_backend(),
                   "compilation_cache": compilation_cache},
        "warmup_total_s": float(sum(r["warmup_s"] for r in results)),
        # NOTE: cache-hit detection must happen BEFORE the runs populate the
        # cache dir — main() fills this in; None means "caller to decide"
        "compile_cache_hit": None,
        "rounds_per_s": {f"{r['scheme']}@{r['n_clients']}":
                         1.0 / r["engine_round_s"] for r in results},
        "results": results,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--sizes", default="4,16,64,256")
    ap.add_argument("--schemes", default="sfl,asfl")
    ap.add_argument("--model", default="mlp", choices=["mlp", "resnet"])
    ap.add_argument("--per-client", type=int, default=64)
    ap.add_argument("--local-steps", type=int, default=2)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--rounds", type=int, default=2)
    ap.add_argument("--seed-loop-max", type=int, default=256,
                    help="largest fleet to also run the seed loop at")
    ap.add_argument("--compilation-cache", default=None, metavar="DIR",
                    help="persistent XLA compilation cache directory")
    args = ap.parse_args()
    sizes = [int(s) for s in args.sizes.split(",")]
    schemes = args.schemes.split(",")

    from repro.configs.base import cache_dir_is_warm
    cache_hit_at_start = cache_dir_is_warm(args.compilation_cache)
    out = bench(sizes, schemes, args.model, args.per_client,
                args.local_steps, args.batch, args.rounds,
                args.seed_loop_max, args.compilation_cache)
    out["compile_cache_hit"] = cache_hit_at_start

    key = [r for r in out["results"]
           if r["scheme"] == "asfl" and r["n_clients"] == 64 and r["speedup"]]
    if key:
        out["asfl_64_speedup"] = key[0]["speedup"]
        out["asfl_64_speedup_ge_5x"] = key[0]["speedup"] >= 5.0
        print(f"\nasfl @ 64 vehicles: {key[0]['speedup']:.1f}x "
              f"(>=5x: {out['asfl_64_speedup_ge_5x']})")

    os.makedirs(OUT_DIR, exist_ok=True)
    for path in (os.path.join(ROOT, "BENCH_fedsim.json"),
                 os.path.join(OUT_DIR, "BENCH_fedsim.json")):
        with open(path, "w") as f:
            json.dump(out, f, indent=1, default=float)
    print(f"wrote {os.path.join(ROOT, 'BENCH_fedsim.json')}")


if __name__ == "__main__":
    main()
