"""End-to-end SFL training driver.

On TPU this trains the selected architecture at the selected input shape on
the production mesh; on this CPU container use ``--smoke`` (reduced config,
1-device mesh) — that path is exercised by examples/quickstart.py and CI.

Example:
  PYTHONPATH=src python -m repro.launch.train --arch smollm-360m --smoke \
      --steps 50 --cut 2
"""
from __future__ import annotations

import argparse
import time
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import INPUT_SHAPES, get_config
from repro.core import distributed as D
from repro.core import split as SP
from repro.data.synthetic import make_bigram_lm
from repro.launch import mesh as MX
from repro.ckpt import save_checkpoint


def synth_batch(cfg, key, batch: int, seq: int, n_clients: int) -> Dict:
    """Synthetic federated LM batch: per-client bigram streams with
    heterogeneous |D_n| weights (power law, as in the paper's case study)."""
    ks = jax.random.split(key, 3)
    if cfg.frontend == "vision":
        s_text = seq - cfg.n_patches
        toks = jax.random.randint(ks[0], (batch, s_text + 1), 0, cfg.vocab_size)
        out = {"tokens": toks[:, :-1], "labels": toks[:, 1:],
               "patch_embeds": 0.02 * jax.random.normal(
                   ks[1], (batch, cfg.n_patches, cfg.d_model))}
    elif cfg.frontend == "audio":
        out = {"codes": jax.random.randint(
            ks[0], (batch, cfg.n_codebooks, seq), 0, cfg.vocab_size)}
    else:
        toks = jax.random.randint(ks[0], (batch, seq + 1), 0, cfg.vocab_size)
        out = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    sizes = (np.arange(1, n_clients + 1, dtype=np.float32)) ** -1.5
    w = np.repeat(sizes / sizes.sum(), batch // n_clients)
    out["weights"] = jnp.asarray(w[:batch])
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config on the local device mesh")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--cut", type=int, default=None)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--n-clients", type=int, default=4)
    ap.add_argument("--compress", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--log-every", type=int, default=5)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.reduced()
        batch, seq = args.batch, args.seq
        mesh = None
    else:
        shape = INPUT_SHAPES[args.shape]
        batch, seq = shape.global_batch, shape.seq_len
        mesh = MX.make_production_mesh(multi_pod=args.multi_pod)

    opts = D.DistOptions(
        cut=args.cut if args.cut is not None else cfg.default_cut,
        compress_smashed=args.compress, learning_rate=args.lr,
        smashed_sharding=(jax.sharding.NamedSharding(mesh, MX.smashed_spec(mesh))
                          if mesh is not None else None))
    key = jax.random.PRNGKey(0)
    state = D.init_state(key, cfg, opts)
    step_fn = D.make_train_step(cfg, opts)
    if mesh is not None:
        state_shape = jax.eval_shape(lambda: state)
        sspec = MX.named(mesh, MX.state_specs(cfg, state_shape, mesh))
        state = jax.device_put(state, sspec)
        step_fn = jax.jit(step_fn, in_shardings=(sspec, None),
                          out_shardings=(sspec, None))
    else:
        step_fn = jax.jit(step_fn)

    print(f"[train] arch={cfg.name} cut={opts.cut} params="
          f"{cfg.param_count()/1e6:.1f}M batch={batch} seq={seq}")
    t0 = time.time()
    for i in range(args.steps):
        bkey = jax.random.fold_in(key, i)
        b = synth_batch(cfg, bkey, batch, seq, args.n_clients)
        state, metrics = step_fn(state, b)
        if i % args.log_every == 0 or i == args.steps - 1:
            print(f"  step {i:4d} loss={float(metrics['loss']):.4f} "
                  f"ce={float(metrics['ce']):.4f} "
                  f"({(time.time()-t0)/(i+1):.2f}s/step)")
    if args.ckpt_dir:
        path = save_checkpoint(args.ckpt_dir, args.steps, state["params"])
        print(f"[train] checkpoint -> {path}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
