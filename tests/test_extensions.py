"""Extensions beyond the paper's case study: SFL over transformer stacks in
the simulator, mobility dropout, optimized-sharding model variants."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import channel
from repro.core.fedsim import FederationSim, ResNetModel, SimConfig
from repro.core.lm_unit import TransformerUnitModel
from repro.data.pipeline import ClientDataset, make_federated_data
from repro.data.synthetic import make_bigram_lm


def _lm_clients(cfg, n_clients=3, seq=32):
    clients = []
    for i in range(n_clients):
        s = np.asarray(make_bigram_lm(jax.random.PRNGKey(i), cfg.vocab_size,
                                      1500))
        n = (len(s) - 1) // seq
        x = np.stack([s[j * seq:(j + 1) * seq] for j in range(n)])
        y = np.stack([s[j * seq + 1:(j + 1) * seq + 1] for j in range(n)])
        clients.append(ClientDataset(x, y, i))
    t = np.asarray(make_bigram_lm(jax.random.PRNGKey(99), cfg.vocab_size, 700))
    test = {"images": jnp.asarray(np.stack([t[j * seq:(j + 1) * seq]
                                            for j in range(10)])),
            "labels": jnp.asarray(np.stack([t[j * seq + 1:(j + 1) * seq + 1]
                                            for j in range(10)]))}
    return clients, test


def test_transformer_unit_model_multi_cut_sfl():
    """ASFL over a 4-period smollm stack: every cut splits/learns."""
    base = get_config("smollm-360m").reduced()
    cfg = dataclasses.replace(base, n_layers=4)   # 4 periods -> 5 units
    model = TransformerUnitModel(cfg)
    assert model.n_units == 5
    clients, test = _lm_clients(cfg)
    sim = FederationSim(model, clients, test,
                        SimConfig(scheme="sfl", cut=2, rounds=2,
                                  local_steps=3, lr=3e-3, batch_size=4))
    hist = sim.run()
    assert hist[-1].loss < hist[0].loss + 1e-6
    assert np.isfinite(hist[-1].loss)


def test_transformer_unit_model_matches_whole_model():
    """Unit-stacked forward == monolithic transformer forward."""
    from repro.models import transformer as T
    cfg = dataclasses.replace(get_config("smollm-360m").reduced(), n_layers=3)
    model = TransformerUnitModel(cfg)
    key = jax.random.PRNGKey(0)
    units, head = model.init(key)
    toks = jax.random.randint(key, (2, 16), 0, cfg.vocab_size)
    feats = model.apply_units(units, toks, 0)
    logits_units = model.head_predict(head, feats)

    params = T.init_params(key, cfg)   # same key -> same weights
    logits_full, _, _ = T.forward(params, cfg, {"tokens": toks}, "train")
    np.testing.assert_allclose(np.asarray(logits_units),
                               np.asarray(logits_full), rtol=2e-4, atol=2e-4)


def test_mobility_dropout_skips_out_of_range_vehicles():
    clients, test = make_federated_data(0, n_train=256, n_test=64,
                                        n_clients=4)
    # fleet engineered so vehicles 2,3 are out of range at t=0
    fleet = [channel.VehicleProfile(x0_m=-100.0, speed_mps=0.0),
             channel.VehicleProfile(x0_m=-200.0, speed_mps=0.0),
             channel.VehicleProfile(x0_m=-900.0, speed_mps=0.0),
             channel.VehicleProfile(x0_m=-900.0, speed_mps=0.0)]
    cfg = SimConfig(scheme="sfl", cut=2, rounds=1, local_steps=1,
                    batch_size=8, mobility_dropout=True)
    sim = FederationSim(ResNetModel(), clients, test, cfg, fleet=fleet)
    assert sim._participants(0) == [0, 1]
    hist = sim.run()
    assert np.isfinite(hist[0].loss)


def test_ssm_split_proj_variant_param_count_unchanged():
    cfg = get_config("mamba2-780m")
    split = dataclasses.replace(cfg, ssm=dataclasses.replace(
        cfg.ssm, fused_proj=False))
    assert cfg.param_count() == split.param_count()


@pytest.mark.skipif(not hasattr(jax.sharding, "AxisType"),
                    reason="jax.sharding.AxisType requires jax >= 0.5")
def test_megatron_specs_shard_experts():
    """EP preference: expert weights shard the expert dim over `model`."""
    import os
    from repro.launch import mesh as MX
    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    # fake 16-way model axis via a mesh-like shim is overkill; check the
    # rule function directly with a synthetic path
    class Leaf:
        shape = (27, 64, 2048, 1408)   # (periods, experts, d, ff)
    path = (jax.tree_util.DictKey("segments"), jax.tree_util.DictKey("wi_gate"))
    mesh16 = jax.make_mesh((1, 1), ("data", "model"),
                           axis_types=(jax.sharding.AxisType.Auto,) * 2)
    spec = MX._megatron_spec(path, Leaf(), mesh16, fsdp=False)
    # model axis size 1 divides everything; expert dim (-3) must be chosen
    assert spec == jax.sharding.PartitionSpec(None, "model", None, None)


def test_paper_threshold_literal_vs_text_ordering():
    """DESIGN.md §2: the printed Eq. 3 maps the LOWEST rate band to cut 2
    (largest smashed data); the text-consistent default maps the HIGHEST
    rate band to cut 2 (more offload when the link is fast).  The two
    orderings are exact mirrors over the cut table."""
    from repro.core import adaptive
    th = adaptive.DEFAULT_THRESHOLDS
    # one rate per band: below R1, R1..R2, R2..R3, above R3
    rates = [th[0] * 0.5, (th[0] + th[1]) / 2, (th[1] + th[2]) / 2,
             th[2] * 2.0]
    text = adaptive.paper_threshold(rates)
    literal = adaptive.paper_threshold(rates, literal_eq3=True)
    assert literal == list(adaptive.DEFAULT_CUTS)          # low rate -> cut 2
    assert text == list(reversed(adaptive.DEFAULT_CUTS))   # high rate -> cut 2
    assert text == literal[::-1]
    # band edges are right-inclusive (np.digitize(right=True))
    assert adaptive.paper_threshold([th[0]], literal_eq3=True) == [2]


def test_mobility_dropout_participation_over_time():
    """The engine's participation mask must follow coverage round by round:
    a vehicle drives INTO range and joins; with everyone out of range the
    fallback keeps vehicle 0 so the round still runs."""
    clients, test = make_federated_data(1, n_train=128, n_test=64,
                                        n_clients=3)
    # v0 parked in range; v1 enters range at t=5 (x: -420 -> -395);
    # v2 parked far outside for good
    fleet = [channel.VehicleProfile(x0_m=-100.0, speed_mps=0.0),
             channel.VehicleProfile(x0_m=-420.0, speed_mps=5.0),
             channel.VehicleProfile(x0_m=-2000.0, speed_mps=0.0)]
    cfg = SimConfig(scheme="asfl", rounds=2, local_steps=1, batch_size=8,
                    lr=1e-3, mobility_dropout=True, eval_every=0)
    sim = FederationSim(ResNetModel(), clients, test, cfg, fleet=fleet)
    assert sim._participants(0) == [0]
    assert sim._participants(1) == [0, 1]
    hist = sim.run()
    assert all(np.isfinite(m.loss) for m in hist)

    # all-out-of-coverage fallback: vehicle 0 still participates
    far = [channel.VehicleProfile(x0_m=-2000.0, speed_mps=0.0)
           for _ in range(3)]
    sim2 = FederationSim(ResNetModel(), clients, test, cfg, fleet=far)
    assert sim2._participants(0) == [0]


def test_compression_ratio_matches_actual_bytes():
    """compression_ratio(trailing_dim=...) must equal the measured bytes of
    quantize_int8's output (int8 payload + f32 scale per ACTUAL group),
    including the internally padded tail group for non-divisible dims."""
    from repro.core import compression as C
    for d in (64, 128, 200, 384, 512):
        x = jnp.asarray(np.random.default_rng(d).normal(size=(16, d)),
                        jnp.float32)
        q, s = C.quantize_int8(x)
        measured = x.size * 4 / (q.size * 1 + s.size * 4)
        np.testing.assert_allclose(C.compression_ratio(trailing_dim=d),
                                   measured, rtol=1e-12)
    # the nominal ratio is wrong off the GROUP grid: small dims pay more
    # scale overhead (64-wide groups), non-divisible dims pay an extra
    # scale for the padded tail group — both land BELOW the nominal ratio
    assert C.compression_ratio(trailing_dim=64) < C.compression_ratio()
    assert C.compression_ratio(trailing_dim=200) < C.compression_ratio()
    # vectorized over per-cut dims (the fedsim accounting path)
    dims = np.array([64, 128, 200])
    np.testing.assert_allclose(
        C.compression_ratio(trailing_dim=dims),
        [C.compression_ratio(trailing_dim=int(d)) for d in dims])


def test_quantize_int8_divisible_and_padded_branches():
    """quantize_int8 covers both trailing-dim branches: divisible (no pad)
    and non-divisible (internal zero-pad to the next group boundary) —
    GROUP-granular scales either way, pad sliced off, roundtrip within one
    quantisation step of each group's scale, straight-through gradient."""
    from repro.core import compression as C
    rng = np.random.default_rng(7)
    for d, exp_groups in ((256, 2), (200, 2), (130, 2), (16, 1), (5, 1)):
        x = jnp.asarray(rng.normal(size=(4, d)), jnp.float32)
        q, s = C.quantize_int8(x)
        assert q.shape == x.shape and q.dtype == jnp.int8
        assert s.shape == (4, exp_groups), (d, s.shape)
        xd = C.dequantize_int8(q, s)
        assert xd.shape == x.shape
        # per-element error bounded by half a step of its OWN group's scale
        g = C.effective_group(d)
        reps = np.repeat(np.asarray(s), g, axis=-1)[:, :d]
        assert np.all(np.abs(np.asarray(xd) - np.asarray(x))
                      <= 0.5 * reps + 1e-7), d
        # the padded tail never leaks: quantizing the zero-padded twin of x
        # in one divisible call gives identical q/s on the real columns
        if d % int(g):
            dpad = int(-(-d // g) * g)
            xp = jnp.zeros((4, dpad), jnp.float32).at[:, :d].set(x)
            qp, sp = C.quantize_int8(xp)
            np.testing.assert_array_equal(np.asarray(qp)[:, :d],
                                          np.asarray(q))
            np.testing.assert_array_equal(np.asarray(sp), np.asarray(s))
        # straight-through estimator survives both branches
        gx = jax.grad(lambda t: jnp.sum(C.fake_quant(t) * 2.0))(x)
        np.testing.assert_array_equal(np.asarray(gx), 2.0)


def test_resnet_profile_has_smashed_trailing_dims():
    from repro.core.cost import resnet_profile
    from repro.models import resnet as R
    prof = resnet_profile()
    assert prof.smashed_trailing_dim is not None
    assert len(prof.smashed_trailing_dim) == prof.n_units
    assert prof.smashed_trailing_dim == [R.smashed_shape(c, 1)[-1]
                                         for c in range(1, R.N_UNITS + 1)]
