"""Multi-RSU scenario demo: mobility, handover, hierarchical aggregation.

A fleet drives a 4-RSU highway corridor (core/scenario.py).  Each round the
scenario layer yields vectorized fleet state — positions, serving cell,
Shannon rates, remaining residence time; the ScenarioEngine groups vehicles
into one CohortEngine cohort per RSU, trains them against that RSU's edge
model, and merges the edge models at a cloud tier every ``--sync`` rounds
(hierarchical FedAvg == flat FedAvg under matching weights, DESIGN.md §7).
Vehicles crossing cell borders hand over: their data shard and identity move
with them; server-side state stays at the RSU.

  PYTHONPATH=src python examples/multi_rsu_sim.py                 # highway
  PYTHONPATH=src python examples/multi_rsu_sim.py --scenario urban_grid
  PYTHONPATH=src python examples/multi_rsu_sim.py --rounds 8 --sync 2
"""
import argparse
import os
import sys
import time

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(_ROOT, "src"))
sys.path.insert(0, os.path.join(_ROOT, "benchmarks"))

import numpy as np

# the 9-unit split MLP bench model stands in for a vehicle perception model
# (the federation dynamics, not the FLOPs, are the point of this demo)
from bench_fedsim import MLPUnitModel, make_mlp_fleet_data
from repro.core import adaptive, cost, scenario
from repro.core.fedsim import ScenarioEngine, SimConfig


def show_residence_rule(sc, rounds, interval):
    """What the residence_aware rule would decide for the paper's ResNet18
    cost profile on this scenario (SKIP = vehicle leaves its cell before any
    cut's round latency fits)."""
    prof = cost.resnet_profile()
    print("\nresidence_aware on the ResNet18 profile "
          "(cut 0 = skip the round):")
    for rnd in range(min(rounds, 4)):
        st = sc.fleet_state(rnd * interval, seed=rnd)
        cuts = np.asarray(adaptive.residence_aware(
            prof, np.maximum(st.rates_bps, 1.0), 2e10, 2e12, 4, 16, 1,
            st.residence_s))
        cuts = np.where(st.active, cuts, -1)
        n_skip = int(((cuts == 0) & st.active).sum())
        print(f"  t={rnd*interval:5.1f}s  cuts={cuts[:12]}...  "
              f"skips={n_skip}  uncovered={int((~st.active).sum())}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scenario", default="highway_corridor",
                    choices=sorted(scenario.SCENARIOS))
    ap.add_argument("--vehicles", type=int, default=24)
    ap.add_argument("--rounds", type=int, default=6)
    ap.add_argument("--sync", type=int, default=2,
                    help="cloud merge every k rounds")
    ap.add_argument("--superstep", type=int, default=2,
                    help="rounds fused into one compiled super-step "
                         "(DESIGN.md §8; 1 = one dispatch per round)")
    ap.add_argument("--schedule", default="sequential",
                    choices=["sequential", "parallel"],
                    help="RSU server schedule: paper §III-B sequential or "
                         "the parallel scheme of arXiv:2405.18707")
    ap.add_argument("--compilation-cache", default=None, metavar="DIR",
                    help="persistent XLA cache: re-runs skip compilation")
    args = ap.parse_args()

    sc = scenario.make_scenario(args.scenario, args.vehicles, seed=7)
    print(f"scenario={args.scenario}: {args.vehicles} vehicles, "
          f"{len(sc.rsu_positions)} RSUs")

    clients, test = make_mlp_fleet_data(args.vehicles, 64, 48, seed=0)
    cfg = SimConfig(scheme="asfl", adaptive_strategy="paper",
                    rounds=args.rounds, local_steps=2, batch_size=8,
                    lr=1e-3, round_interval_s=10.0,
                    superstep=args.superstep,
                    server_schedule=args.schedule,
                    compilation_cache_dir=args.compilation_cache)
    eng = ScenarioEngine(MLPUnitModel(), clients, test, cfg, sc,
                         cloud_sync_every=args.sync)
    t0 = time.time()
    eng.precompile()               # AOT: the run below never compiles
    print(f"engine mode={eng.mode}, schedule={args.schedule}, "
          f"K={args.superstep}, cloud sync every {args.sync} round(s); "
          f"precompiled in {time.time()-t0:.1f}s\n")
    t0 = time.time()
    for m in eng.run():
        acc = f"{m.test_acc:.3f}" if np.isfinite(m.test_acc) else "  -  "
        print(f"round {m.round}: loss={m.loss:.3f} acc={acc} "
              f"sched={m.n_scheduled:3d} handover={m.n_handover:2d} "
              f"rsu_loads={m.rsu_loads} comm={m.comm_bytes/1e6:6.1f}MB")
    print(f"({time.time()-t0:.1f}s wall, compile-free)")

    show_residence_rule(sc, args.rounds, cfg.round_interval_s)


if __name__ == "__main__":
    main()
