"""Multi-pod dry-run: prove every (arch x input-shape x mesh) combination
lowers + compiles on the production mesh, and extract roofline inputs.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch smollm-360m --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--out out.json]

The first two lines below MUST run before any other import: jax locks the
device count on first backend init, and the dry-run needs 512 placeholder
host devices to build the 2x16x16 production mesh.
"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("DRYRUN_XLA_FLAGS")
                           or "--xla_force_host_platform_device_count=512")

import argparse      # noqa: E402
import json          # noqa: E402
import re            # noqa: E402
import sys           # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402
from typing import Any, Dict, Optional  # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import ARCH_IDS, INPUT_SHAPES, get_config  # noqa: E402
from repro.core import distributed as D  # noqa: E402
from repro.launch import mesh as MX  # noqa: E402
from repro.launch.hlo_analysis import analyze_hlo  # noqa: E402

# long_500k runs only for sub-quadratic architectures (DESIGN.md §4)
def combos():
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in INPUT_SHAPES.values():
            if shape.name == "long_500k" and not cfg.subquadratic:
                continue
            yield arch, shape.name


def dryrun_one(arch: str, shape_name: str, multi_pod: bool = False,
               cut: Optional[int] = None, compress: bool = False,
               verbose: bool = True, megatron: bool = False,
               sdpa_spread: bool = False, remat_policy=None,
               ssm_split_proj: bool = False,
               no_fsdp: bool = False) -> Dict[str, Any]:
    import dataclasses as _dc
    from repro.models import attention as _ATT
    from repro.models import transformer as _T
    _T.set_remat_policy(remat_policy)
    old_thresh = MX.FSDP_PARAM_THRESHOLD
    if no_fsdp:
        MX.FSDP_PARAM_THRESHOLD = float("inf")

    cfg = get_config(arch)
    if ssm_split_proj and cfg.ssm is not None:
        cfg = _dc.replace(cfg, ssm=_dc.replace(cfg.ssm, fused_proj=False))
    shape = INPUT_SHAPES[shape_name]
    mesh = MX.make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.size
    opts = D.DistOptions(
        cut=cut if cut is not None else cfg.default_cut,
        compress_smashed=compress,
        param_dtype=jnp.bfloat16,
        smashed_sharding=jax.sharding.NamedSharding(
            mesh, MX.smashed_spec(mesh)),
    )

    from jax.sharding import NamedSharding, PartitionSpec as P
    dp = MX.dp_axes(mesh)
    spread_axes = None
    if sdpa_spread:
        if shape.global_batch % mesh.size == 0:
            spread_axes = tuple(dp) + ("model",)
        elif shape.global_batch % (mesh.shape["data"]
                                   * mesh.shape["model"]) == 0:
            spread_axes = ("data", "model")   # pod axis stays pure DP
    if spread_axes:
        spread = NamedSharding(mesh, P(spread_axes, None, None, None))
        restore = None
        if sdpa_spread != "norestore":
            restore = NamedSharding(mesh, P(dp if len(dp) > 1 else dp[0],
                                            None, None, None))
        _ATT.set_sdpa_spread((spread, restore))
    else:
        _ATT.set_sdpa_spread(None)

    t0 = time.time()
    key_spec = jax.ShapeDtypeStruct((2,), jnp.uint32)
    state_shape = jax.eval_shape(
        lambda k: D.init_state(k, cfg, opts), key_spec)
    batch_shape = D.input_specs(cfg, shape)

    state_spec = MX.named(mesh, MX.state_specs(cfg, state_shape, mesh,
                                               megatron=megatron))
    batch_spec = MX.named(mesh, MX.batch_specs(shape, batch_shape, mesh))
    param_spec = state_spec["params"]

    if shape.kind == "train":
        step = D.make_train_step(cfg, opts)
        jitted = jax.jit(step, in_shardings=(state_spec, batch_spec))
        lowered = jitted.lower(state_shape, batch_shape)
    elif shape.kind == "prefill":
        step = D.make_prefill_step(cfg, opts, capacity=shape.seq_len)
        jitted = jax.jit(step, in_shardings=(param_spec, batch_spec))
        lowered = jitted.lower(state_shape["params"], batch_shape)
    else:  # decode
        step = D.make_decode_step(cfg, opts, capacity=shape.seq_len)
        cache_shape = D.cache_specs(cfg, shape, opts.cut)
        cache_spec = MX.named(mesh, MX.cache_specs_tree(cache_shape, mesh))
        pos_spec = jax.ShapeDtypeStruct((), jnp.int32)
        jitted = jax.jit(step, in_shardings=(
            param_spec, batch_spec, cache_spec,
            jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())))
        lowered = jitted.lower(state_shape["params"], batch_shape,
                               cache_shape, pos_spec)
    t_lower = time.time() - t0
    _ATT.set_sdpa_spread(None)   # trace-time switches; reset after lowering
    _T.set_remat_policy(None)
    MX.FSDP_PARAM_THRESHOLD = old_thresh

    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    cost = compiled.cost_analysis() or {}
    try:
        mem = compiled.memory_analysis()
        mem_info = {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
        }
    except Exception:   # pragma: no cover - backend-dependent
        mem_info = {}
    # scan-aware per-device costs from the partitioned HLO (hlo_analysis.py);
    # cost_analysis() is kept for reference but undercounts while bodies.
    hc = analyze_hlo(compiled.as_text())

    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": n_chips,
        "cut": opts.cut,
        "compress": compress,
        "kind": shape.kind,
        "variant": {"megatron": megatron, "sdpa_spread": sdpa_spread,
                    "ssm_split_proj": ssm_split_proj,
                    "remat_policy": remat_policy, "no_fsdp": no_fsdp},
        "flops_per_device": hc.flops,
        "traffic_per_device": hc.traffic,
        "collectives": dict(hc.collective),
        "collective_bytes_per_device": hc.collective_bytes,
        "xla_cost_analysis_flops": float(cost.get("flops", 0.0)),
        "memory": mem_info,
        "t_lower_s": round(t_lower, 2),
        "t_compile_s": round(t_compile, 2),
        "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
    }
    if verbose:
        print(f"[dryrun] {arch} x {shape_name} x {rec['mesh']}: "
              f"flops/dev={hc.flops:.3e} traffic/dev={hc.traffic:.3e}B "
              f"coll/dev={hc.collective_bytes:.3e}B "
              f"(lower {t_lower:.1f}s compile {t_compile:.1f}s)")
        if mem_info.get("temp_bytes") is not None:
            print(f"  memory_analysis: {mem_info}")
    return rec


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--cut", type=int, default=None)
    ap.add_argument("--compress", action="store_true")
    ap.add_argument("--megatron", action="store_true",
                    help="name-aware column/row/expert-parallel TP rules")
    ap.add_argument("--sdpa-spread", action="store_true",
                    help="respread batch over (data x model) for SDPA")
    ap.add_argument("--ssm-split-proj", action="store_true",
                    help="shard-aligned z/x/B/C/dt stream split (mamba2)")
    ap.add_argument("--remat-policy", default=None, choices=[None, "dots"])
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    targets = []
    if args.all:
        targets = list(combos())
    else:
        assert args.arch and args.shape, "--arch and --shape (or --all)"
        targets = [(args.arch, args.shape)]

    meshes = [args.multi_pod]
    if args.both_meshes:
        meshes = [False, True]

    records, failures = [], []
    for arch, shape in targets:
        for mp in meshes:
            try:
                records.append(dryrun_one(
                    arch, shape, mp, args.cut, args.compress,
                    megatron=args.megatron,
                    sdpa_spread="norestore" if args.sdpa_spread else False,
                    ssm_split_proj=args.ssm_split_proj,
                    remat_policy=args.remat_policy))
            except Exception as e:  # noqa: BLE001
                traceback.print_exc()
                failures.append((arch, shape, mp, repr(e)))

    if args.out:
        with open(args.out, "w") as f:
            json.dump(records, f, indent=1)
        print(f"wrote {len(records)} records to {args.out}")
    if failures:
        print(f"FAILURES ({len(failures)}):")
        for f in failures:
            print("  ", f)
        return 1
    print(f"dry-run OK: {len(records)} combination(s) lowered + compiled")
    return 0


if __name__ == "__main__":
    sys.exit(main())
