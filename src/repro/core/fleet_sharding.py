"""Device-sharded fleets: one mesh axis over the federation's scale axes.

The paper's ASFL scheme targets fleets far beyond what one accelerator can
hold; this module is the partitioning layer that lets the compiled
federation programs (the CohortEngine's round programs and the fused
multi-RSU super-steps, DESIGN.md §6/§8) execute across a device mesh while
staying *the same programs* — ``mesh_devices=1`` (the default) bypasses
every collective and reproduces today's single-device executables exactly.

One 1-D mesh, one axis name (:data:`AXIS`), two partitionings:

* ``axis="vehicle"`` — the single-RSU cohort engine shards the stacked
  client-replica (slot) axis of each cut bucket: per-vehicle forward/
  backward passes and optimizer updates are shard-local, the shared RSU
  server state is **replicated** (every shard consumes the all-gathered
  smashed batches in the same canonical order, so paper §III-B sequential
  semantics survive sharding), and the unit-wise FedAvg becomes a
  ``psum``-weighted all-reduce (:func:`repro.core.aggregation.
  sharded_weighted_sum`).
* ``axis="rsu"`` — the scenario engine shards the RSU axis of the fused
  super-step: each device trains ``n_rsus / n_devices`` whole RSU cohorts
  (per-RSU rounds are independent between cloud syncs, so this axis is
  embarrassingly parallel), and the edge→cloud merge all-gathers the edge
  stack so the weighted reduction runs in the *identical order* on every
  shard — which is what makes the sharded K-fused sgd path bit-for-bit
  equal to the single-device one (tests/test_fleet_sharding.py).

Ragged slot sharding (DESIGN.md §12): with ``superstep_layout="ragged"``
and the parallel server schedule, the super-step's unit of work is no
longer an RSU row but a slot of the globally compacted occupied-slot axis.
The same ``axis="rsu"`` mesh then splits THAT axis into equal contiguous
blocks (:meth:`FleetMesh.balanced_slots` pads the compacted capacity to a
device multiple): every device carries the same number of *occupied* slots
regardless of how skewed the per-RSU load is, which removes the 256-fleet
sharding inversions where one device trained a crowded cell's whole padded
table while its neighbors trained phantoms.  The per-RSU segment-sums
become psum'd partials and the edge stack replicates — tolerance-level
(not bit-for-bit) parity with the single-device program, asserted in
tests/test_fleet_sharding.py.

Padding rules (DESIGN.md §10): bucket slot counts are padded pow2-first,
then up to the next multiple of the device count; the RSU axis is padded to
a device multiple with phantom cells no vehicle can be served by.  Both
paddings are inert — padded slots carry zero aggregation weight and padded
RSUs never accumulate samples — asserted by the padding-inertness tests.

Data placement: the master :class:`~repro.data.pipeline.StackedClients`
tensors stay **replicated** on the mesh.  Handover moves a vehicle (and the
slot that gathers its rows) between RSUs — and therefore between shards —
every round, so the per-round gathers must be able to reach any vehicle's
shard from any device; what is sharded is everything derived per round
(replica stacks, optimizer moments, batch index slabs), which is where the
O(fleet x params) memory actually lives.

CPU note: ``--xla_force_host_platform_device_count=N`` (the same trick
``launch/dryrun.py`` uses) splits the host into N XLA devices for testing
and CI; on a 2-core container this demonstrates partitioning, not speed —
the benchmarks record per-device-count rounds/s honestly.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.data.pipeline import StackedClients

AXIS = "fleet"                      # the one mesh axis name
FLEET_AXES = ("auto", "vehicle", "rsu")   # SimConfig.fleet_axis values


@dataclasses.dataclass(frozen=True)
class FleetMesh:
    """A 1-D device mesh plus which fleet dimension it partitions.

    ``axis`` is ``"vehicle"`` (cohort-engine slot axis) or ``"rsu"``
    (super-step RSU axis); the mesh axis name is always :data:`AXIS`.
    """
    mesh: Mesh
    axis: str

    @property
    def n_devices(self) -> int:
        return self.mesh.size

    # ---- padding ------------------------------------------------------
    def pad(self, n: int) -> int:
        """Smallest multiple of the device count >= max(n, 1)."""
        d = self.n_devices
        return ((max(int(n), 1) + d - 1) // d) * d

    def balanced_slots(self, n_slots: int) -> int:
        """Occupancy-balanced capacity of the ragged super-step's compacted
        slot axis (module docstring; DESIGN.md §12): the axis counts
        OCCUPIED slots fleet-wide, so padding it to a device multiple and
        splitting contiguously gives every device an equal share of real
        work even under fully skewed per-RSU load — unlike padded per-RSU
        tables, whose shards inherit the load imbalance."""
        return self.pad(n_slots)

    # ---- shardings ----------------------------------------------------
    def leading_sharding(self) -> NamedSharding:
        """Leading axis split over the mesh, everything else replicated."""
        return NamedSharding(self.mesh, P(AXIS))

    def replicated_sharding(self) -> NamedSharding:
        return NamedSharding(self.mesh, P())

    # ---- placement ----------------------------------------------------
    def shard_leading(self, tree: Any) -> Any:
        """device_put every leaf with its leading axis split over the mesh
        (leaf leading dims must be device-count multiples — use
        :meth:`pad` upstream)."""
        s = self.leading_sharding()
        return jax.tree.map(lambda a: jax.device_put(a, s), tree)

    def replicate(self, tree: Any) -> Any:
        """device_put every leaf fully replicated on the mesh."""
        s = self.replicated_sharding()
        return jax.tree.map(lambda a: jax.device_put(a, s), tree)

    def place_stacked(self, stacked: StackedClients) -> StackedClients:
        """The master client tensors, replicated on the mesh (see module
        docstring for why they cannot shard by vehicle: handover makes the
        per-round gather pattern cross-shard by design)."""
        return StackedClients(
            images=jax.device_put(stacked.images, self.replicated_sharding()),
            labels=jax.device_put(stacked.labels, self.replicated_sharding()),
            lengths=stacked.lengths)


def resolve_axis(fleet_axis: str, engine_kind: str) -> str:
    """``"auto"`` -> the engine's natural partitioning: RSU axis for the
    multi-RSU scenario engine, vehicle axis for the single-RSU cohort
    engine."""
    if fleet_axis == "auto":
        return "rsu" if engine_kind == "scenario" else "vehicle"
    return fleet_axis


def build_fleet_mesh(n_devices: int, axis: str,
                     devices: Optional[list] = None) -> FleetMesh:
    """A :class:`FleetMesh` over the first ``n_devices`` local devices.

    Raises with the ``--xla_force_host_platform_device_count`` recipe when
    the process has fewer devices than requested (on CPU the flag must be
    set *before* jax initialises its backend — benchmarks set it from the
    ``--devices`` flag before importing jax)."""
    if axis not in ("vehicle", "rsu"):
        raise ValueError(f"fleet mesh axis must be 'vehicle' or 'rsu', "
                         f"got {axis!r}")
    devs = list(devices if devices is not None else jax.devices())
    if n_devices < 1:
        raise ValueError(f"mesh_devices={n_devices!r} must be >= 1")
    if n_devices > len(devs):
        raise RuntimeError(
            f"mesh_devices={n_devices} but only {len(devs)} device(s) are "
            f"visible; on CPU set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={n_devices} "
            f"before the first jax import (launch/dryrun.py and the "
            f"benchmark --devices flag do exactly this)")
    mesh = Mesh(np.asarray(devs[:n_devices]), (AXIS,))
    return FleetMesh(mesh, axis)


def from_config(cfg, engine_kind: str) -> Optional[FleetMesh]:
    """The mesh a :class:`~repro.core.fedsim.SimConfig` asks for — ``None``
    when ``mesh_devices == 1`` (the default single-device path, which must
    stay bit-identical to the pre-mesh engines and therefore never wraps
    anything in ``shard_map``)."""
    n = int(getattr(cfg, "mesh_devices", 1) or 1)
    if n <= 1:
        return None
    return build_fleet_mesh(n, resolve_axis(cfg.fleet_axis, engine_kind))


def host_fetch(tree: Any) -> Any:
    """Pull a (possibly mesh-sharded) pytree to host numpy arrays — the
    runner calls this on ``RunResult.final_params`` so results survive the
    mesh (and serialize) regardless of where training ran."""
    return jax.tree.map(np.asarray, tree)


def local_slice(x: jnp.ndarray, n_local: int, axis: int = 0) -> jnp.ndarray:
    """Inside ``shard_map``: this shard's contiguous block of a replicated
    array whose logical leading axis is split ``n_local`` per device."""
    start = jax.lax.axis_index(AXIS) * n_local
    return jax.lax.dynamic_slice_in_dim(x, start, n_local, axis=axis)


def scalar_allsum(x: jnp.ndarray) -> jnp.ndarray:
    """Inside ``shard_map``: sum a shard-local scalar (a telemetry total
    reduced from sharded per-RSU state — staleness-bank weight, stream-
    buffer occupancy/absorption) home across the mesh.  Scalars carry no
    reduction-order contract, so a plain psum is the right tool here — the
    bit-for-bit gather-then-reduce discipline applies to model planes, not
    counters."""
    return jax.lax.psum(x, AXIS)
