"""End-to-end behaviour tests: every federation scheme runs (through the
declarative front door, ``repro.api.run`` — DESIGN.md §9); the compiled
datacenter SFL step trains; split inference decodes consistently."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.configs import get_config
from repro.core import distributed as D
from repro.launch import mesh as MX
from repro.models import transformer as T


def _resnet_spec(scheme, rounds=1, local_steps=2, strategy="paper", **kw):
    """The paper case study, declaratively: 4 vehicles, ResNet18, CIFAR-like
    non-IID shards (the same data make_federated_data(0, 256, 128, 4)
    produced for the pre-api version of these tests)."""
    return api.ExperimentSpec(
        model="resnet18",
        train=api.TrainConfig(scheme=scheme, rounds=rounds,
                              local_steps=local_steps, lr=1e-3, batch_size=8,
                              compress_smashed=kw.pop("compress_smashed",
                                                      False)),
        adaptive=api.AdaptiveConfig(strategy=strategy),
        fleet=api.FleetConfig(n_vehicles=4, per_vehicle_samples=64,
                              test_samples=128, **kw))


@pytest.mark.parametrize("scheme", ["cl", "fl", "sl", "sfl", "asfl"])
def test_all_schemes_run_one_round(scheme):
    res = api.run(_resnet_spec(scheme))
    assert len(res.history) == 1
    m = res.history[0]
    assert np.isfinite(m.loss)
    assert 0.0 <= m.test_acc <= 1.0
    if scheme not in ("cl",):
        assert m.comm_bytes > 0
        assert m.sim_time_s > 0


def test_asfl_adapts_cuts_to_rates():
    res = api.run(_resnet_spec("asfl", rounds=2, local_steps=1))
    for m in res.history:
        assert all(c in (2, 4, 6, 8) for c in m.cuts)


def test_memory_constrained_strategy_clamps_cuts():
    """adaptive_strategy='memory': vehicle memory budgets upper-bound the
    vehicle-side sub-model (then the paper rule applies underneath)."""
    from repro.core import adaptive
    from repro.core.cost import resnet_profile
    budget = 4e5
    res = api.run(_resnet_spec("asfl", local_steps=1, strategy="memory",
                               memory_budget_bytes=budget))
    max_cut = int(adaptive.max_cut_for_budget(resnet_profile(), budget)[0])
    cuts = res.history[0].cuts
    assert max_cut < 8                       # the budget actually binds
    assert all(c <= max_cut for c in cuts)
    assert np.isfinite(res.history[0].loss)


def test_compressed_sfl_reduces_comm():
    h0 = api.run(_resnet_spec("sfl", local_steps=1)).history
    h1 = api.run(_resnet_spec("sfl", local_steps=1,
                              compress_smashed=True)).history
    assert h1[0].comm_bytes < h0[0].comm_bytes
    assert np.isfinite(h1[0].loss)


def test_datacenter_train_step_learns():
    """The compiled sync-SFL step must overfit a fixed batch."""
    cfg = get_config("smollm-360m").reduced()
    opts = D.DistOptions(cut=1, learning_rate=1e-2, optimizer="adam")
    key = jax.random.PRNGKey(0)
    state = D.init_state(key, cfg, opts)
    step = jax.jit(D.make_train_step(cfg, opts))
    b, s = 4, 32
    toks = jax.random.randint(key, (b, s + 1), 0, cfg.vocab_size)
    batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:],
             "weights": jnp.asarray([4.0, 2.0, 1.0, 1.0])}
    state, m0 = step(state, batch)
    for _ in range(15):
        state, m = step(state, batch)
    assert float(m["loss"]) < float(m0["loss"])


def test_datacenter_compressed_step_runs():
    cfg = get_config("smollm-360m").reduced()
    opts = D.DistOptions(cut=1, compress_smashed=True)
    key = jax.random.PRNGKey(0)
    state = D.init_state(key, cfg, opts)
    step = jax.jit(D.make_train_step(cfg, opts))
    toks = jax.random.randint(key, (2, 17), 0, cfg.vocab_size)
    batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:],
             "weights": jnp.ones((2,))}
    state, m = step(state, batch)
    assert np.isfinite(float(m["loss"]))


def test_split_inference_prefill_decode_consistency():
    """Split-inference serving (prefill + decode at a cut) must reproduce the
    unsplit teacher-forced logits."""
    cfg = get_config("gemma3-4b").reduced()
    opts = D.DistOptions(cut=2)
    key = jax.random.PRNGKey(1)
    params = T.init_params(key, cfg)
    s, cap = 24, 32
    toks = jax.random.randint(key, (2, s), 0, cfg.vocab_size)
    full, _, _ = T.forward(params, cfg, {"tokens": toks}, "train")
    prefill = jax.jit(D.make_prefill_step(cfg, opts, cap))
    decode = jax.jit(D.make_decode_step(cfg, opts, cap))
    last, caches = prefill(params, {"tokens": toks[:, :s - 1]})
    np.testing.assert_allclose(np.asarray(last[:, 0]), np.asarray(full[:, -2]),
                               rtol=2e-4, atol=2e-4)
    logits, caches = decode(params, {"tokens": toks[:, s - 1:]}, caches,
                            jnp.asarray(s - 1))
    np.testing.assert_allclose(np.asarray(logits[:, 0]),
                               np.asarray(full[:, -1]), rtol=2e-4, atol=2e-4)


@pytest.mark.skipif(not hasattr(jax.sharding, "AxisType"),
                    reason="jax.sharding.AxisType requires jax >= 0.5")
def test_mesh_spec_rules():
    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    spec = MX.spec_for((256, 512), mesh, fsdp=False)
    assert spec is not None
    # tiny leaves replicate
    assert MX.spec_for((8,), mesh) == jax.sharding.PartitionSpec(None)
