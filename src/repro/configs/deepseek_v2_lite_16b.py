"""deepseek-v2-lite-16b — MLA + fine-grained MoE [arXiv:2405.04434].

[moe] 27L d_model=2048 16H (MLA kv_lora=512) vocab=102400,
MoE: 64 routed experts top-6 + 2 shared, expert d_ff=1408.
Layer 0 uses a dense FFN (d_ff=10944) per the model card; the assignment line
lists d_ff=1408 which is the *expert* hidden dim — both are kept.
Pure full attention (MLA) -> long_500k skipped.
"""
from repro.configs.base import MLA_DENSE, MLA_MOE, ArchConfig, MLAConfig, MoEConfig

CONFIG = ArchConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    source="arXiv:2405.04434",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,       # MLA: cache is the 512-dim latent, not per-head KV
    head_dim=128,
    d_ff=10944,          # dense FFN hidden (layer 0)
    vocab_size=102400,
    pattern=(MLA_MOE,),
    tail=(MLA_DENSE,),   # note: model card puts the dense layer first; the
                         # stack here is period-tiled so the dense layer is
                         # placed as the tail — same cost, see DESIGN.md
    mla=MLAConfig(kv_lora_rank=512, qk_nope_dim=128, qk_rope_dim=64,
                  v_head_dim=128),
    moe=MoEConfig(n_experts=64, top_k=6, n_shared=2, d_ff_expert=1408,
                  capacity_factor=1.25),
    default_cut=2,
    subquadratic=False,
)
