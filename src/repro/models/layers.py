"""Shared neural-net building blocks (pure JAX, functional params-as-pytrees)."""
from __future__ import annotations

import math
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

Params = Dict[str, Any]


# --------------------------------------------------------------------------
# initialisers
# --------------------------------------------------------------------------

def trunc_normal(key, shape, stddev, dtype=jnp.float32):
    return stddev * jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32).astype(dtype)


def init_dense(key, d_in: int, d_out: int, dtype=jnp.float32) -> Params:
    """Fan-in scaled dense kernel, no bias (all assigned archs are no-bias)."""
    return {"w": trunc_normal(key, (d_in, d_out), 1.0 / math.sqrt(d_in), dtype)}


def dense(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    return x @ p["w"].astype(x.dtype)


def init_rmsnorm(d: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p: Params, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps) * p["scale"].astype(jnp.float32)).astype(dt)


def rms_head_norm(scale: jnp.ndarray, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    """qk-norm: RMSNorm over the trailing head_dim of (..., head_dim)."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(dt)


# --------------------------------------------------------------------------
# MLPs
# --------------------------------------------------------------------------

def init_mlp(key, d_model: int, d_ff: int, variant: str, dtype=jnp.float32) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    if variant in ("swiglu", "geglu"):
        return {
            "wi_gate": init_dense(k1, d_model, d_ff, dtype),
            "wi_up": init_dense(k2, d_model, d_ff, dtype),
            "wo": init_dense(k3, d_ff, d_model, dtype),
        }
    if variant == "gelu":
        return {
            "wi": init_dense(k1, d_model, d_ff, dtype),
            "wo": init_dense(k2, d_ff, d_model, dtype),
        }
    raise ValueError(variant)


def mlp(p: Params, x: jnp.ndarray, variant: str) -> jnp.ndarray:
    if variant == "swiglu":
        h = jax.nn.silu(dense(p["wi_gate"], x)) * dense(p["wi_up"], x)
        return dense(p["wo"], h)
    if variant == "geglu":
        h = jax.nn.gelu(dense(p["wi_gate"], x), approximate=True) * dense(p["wi_up"], x)
        return dense(p["wo"], h)
    if variant == "gelu":
        return dense(p["wo"], jax.nn.gelu(dense(p["wi"], x), approximate=True))
    raise ValueError(variant)


def mlp_flops(d_model: int, d_ff: int, variant: str) -> int:
    """matmul FLOPs per token (multiply-accumulate counted as 2)."""
    n_mats = 3 if variant in ("swiglu", "geglu") else 2
    return 2 * n_mats * d_model * d_ff


# --------------------------------------------------------------------------
# positions
# --------------------------------------------------------------------------

def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., seq, n_heads, head_dim) or (..., seq, head_dim);
    positions: broadcastable to (..., seq)."""
    head_dim = x.shape[-1]
    half = head_dim // 2
    freqs = jnp.exp(-math.log(theta) * jnp.arange(half, dtype=jnp.float32) / half)
    angles = positions.astype(jnp.float32)[..., None] * freqs  # (..., seq, half)
    if x.ndim == angles.ndim + 2:  # x has a head axis between seq and dim
        angles = angles[..., None, :]
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_pos(positions: jnp.ndarray, d_model: int) -> jnp.ndarray:
    half = d_model // 2
    freqs = jnp.exp(-math.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def softcap(x: jnp.ndarray, cap: float) -> jnp.ndarray:
    return cap * jnp.tanh(x / cap) if cap > 0 else x


# --------------------------------------------------------------------------
# losses
# --------------------------------------------------------------------------

def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                  true_vocab: Optional[int] = None) -> jnp.ndarray:
    """Mean token cross-entropy. logits (..., V_pad), labels (...) int32.
    Padded vocab entries (>= true_vocab) are masked to -inf."""
    logits = logits.astype(jnp.float32)
    if true_vocab is not None and true_vocab < logits.shape[-1]:
        pad = logits.shape[-1] - true_vocab
        mask = jnp.concatenate([
            jnp.zeros((true_vocab,), jnp.float32),
            jnp.full((pad,), -1e9, jnp.float32)])
        logits = logits + mask
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)
