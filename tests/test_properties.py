"""Hypothesis property tests on the system's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="optional dependency: property "
                    "tests run only where hypothesis is installed")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import adaptive, aggregation, channel
from repro.core.compression import dequantize_int8, quantize_int8
from repro.core.cost import resnet_profile, sfl_client_round_cost
from repro.data.partition import label_skew_power_law

SET = settings(max_examples=25, deadline=None)


# ---------------------------------------------------------------- fedavg
@SET
@given(st.integers(1, 5), st.integers(0, 2 ** 31 - 1))
def test_fedavg_of_identical_trees_is_identity(n, seed):
    key = jax.random.PRNGKey(seed)
    tree = {"w": jax.random.normal(key, (3, 4)), "b": jnp.ones((2,))}
    avg = aggregation.fedavg([tree] * n)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a), np.asarray(b), rtol=1e-6), avg, tree)


@SET
@given(st.lists(st.floats(0.1, 100.0), min_size=2, max_size=6),
       st.integers(0, 2 ** 31 - 1))
def test_fedavg_convexity(weights, seed):
    """Weighted average stays inside the convex hull of the leaves."""
    key = jax.random.PRNGKey(seed)
    trees = [{"w": jax.random.normal(k, (4,))}
             for k in jax.random.split(key, len(weights))]
    avg = aggregation.fedavg(trees, weights)
    stack = np.stack([np.asarray(t["w"]) for t in trees])
    assert (np.asarray(avg["w"]) <= stack.max(0) + 1e-5).all()
    assert (np.asarray(avg["w"]) >= stack.min(0) - 1e-5).all()


# ------------------------------------------------------------- quantisation
@SET
@given(st.integers(1, 8), st.integers(1, 4), st.floats(0.01, 50.0),
       st.integers(0, 2 ** 31 - 1))
def test_quant_error_bounded_by_half_scale(rows, groups, amp, seed):
    key = jax.random.PRNGKey(seed)
    x = amp * jax.random.normal(key, (rows, groups * 128))
    q, s = quantize_int8(x)
    xd = dequantize_int8(q, s)
    err = np.abs(np.asarray(x) - np.asarray(xd))
    bound = np.repeat(np.asarray(s), 128, axis=-1) * 0.5 + 1e-6
    assert (err <= bound).all()
    assert (np.asarray(s) > 0).all()
    assert np.abs(np.asarray(q, np.int32)).max() <= 127


# ------------------------------------------------------------ partitioner
@SET
@given(st.integers(2, 8), st.integers(1, 6), st.integers(0, 10_000))
def test_label_skew_partition_invariants(n_clients, labels_per_client, seed):
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, 10, size=800)
    parts = label_skew_power_law(seed, labels, n_clients,
                                 labels_per_client=labels_per_client)
    assert len(parts) == n_clients
    for p in parts:
        assert len(p) > 0
        # each client sees at most `labels_per_client` distinct labels
        assert len(set(labels[p].tolist())) <= labels_per_client
        assert (p >= 0).all() and (p < len(labels)).all()


# ------------------------------------------------------------------ channel
@SET
@given(st.floats(1.0, 500.0), st.floats(1.0, 500.0),
       st.floats(0.1, 1.0))
def test_rate_monotonically_decreases_with_distance(d1, d2, power):
    cfg = channel.ChannelConfig(fading_std_db=0.0)
    v1 = channel.VehicleProfile(x0_m=-min(d1, d2), speed_mps=0.0,
                                tx_power_w=power)
    v2 = channel.VehicleProfile(x0_m=-max(d1, d2), speed_mps=0.0,
                                tx_power_w=power)
    r_near = channel.rate_bps(cfg, v1, 0.0)
    r_far = channel.rate_bps(cfg, v2, 0.0)
    assert r_near >= r_far > 0


# ----------------------------------------------------------------- adaptive
@SET
@given(st.lists(st.floats(1e5, 1e9), min_size=1, max_size=8))
def test_paper_threshold_in_valid_set_and_monotone(rates):
    cuts = adaptive.paper_threshold(rates)
    assert all(c in adaptive.DEFAULT_CUTS for c in cuts)
    # text-consistent rule: higher rate -> earlier (smaller) cut
    pairs = sorted(zip(rates, cuts))
    for (r1, c1), (r2, c2) in zip(pairs, pairs[1:]):
        assert c2 <= c1 or r1 == r2


@SET
@given(st.floats(1e5, 1e9), st.floats(1e9, 1e11))
def test_latency_optimal_never_worse_than_fixed_cuts(rate, cflops):
    prof = resnet_profile()
    cuts = adaptive.latency_optimal(prof, [rate], [cflops], 2e12, 4, 16)
    best = sfl_client_round_cost(prof, cuts[0], 4, 16, rate, cflops, 2e12).latency
    for c in range(1, prof.n_units):
        lat = sfl_client_round_cost(prof, c, 4, 16, rate, cflops, 2e12).latency
        assert best <= lat + 1e-9


@SET
@given(st.floats(1e4, 1e8))
def test_memory_constraint_respected(budget):
    prof = resnet_profile()
    cuts = adaptive.memory_constrained(
        prof, budget, adaptive.paper_threshold, [1e6, 5e7, 2e8])
    for c in cuts:
        assert c >= 1
        if c > 1:
            assert prof.client_param_bytes(c) <= budget


# -------------------------------------------------------------- cost model
@SET
@given(st.integers(1, 8), st.floats(1e5, 1e9))
def test_smashed_comm_decreases_with_later_cut(batch, rate):
    """Paper Fig. 5a: communication overhead falls as the cut moves later."""
    prof = resnet_profile()
    comm = [sfl_client_round_cost(prof, c, 4, batch, rate, 1e10, 1e12,
                                  include_model_transfer=False).comm_bytes
            for c in (2, 4, 6, 8)]
    assert comm == sorted(comm, reverse=True)


# -------------------------------------------------------------------- wire
@SET
@given(st.integers(1, 6), st.integers(1, 400), st.floats(0.0, 1.0),
       st.floats(0.01, 50.0), st.integers(0, 2 ** 31 - 1))
def test_wire_pack_unpack_roundtrip_identity(rows, d, k_frac, amp, seed):
    """pack -> unpack is the identity on (q, scale, mask) for ANY trailing
    dim (incl. non-group-divisible and sub-group) and any keep fraction
    (k_frac=0 clamps to one survivor per group, 1.0 keeps all)."""
    from repro.core import compression as C
    key = jax.random.PRNGKey(seed)
    x = amp * jax.random.normal(key, (rows, d))
    q, s, mask = C.sparsify_topk_int8(x, k_frac)
    buf = C.sparsify_quant_pack_ref(x, k_frac)
    q2, s2, mask2 = C.unpack_wire(buf, d, k_frac)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(q2))
    np.testing.assert_array_equal(np.asarray(s), np.asarray(s2))
    np.testing.assert_array_equal(np.asarray(mask), np.asarray(mask2))
    g, ng, k, _ = C.wire_layout(d, k_frac)
    assert 1 <= k <= g
    # exactly k survivors per group keeps every shape static
    m = np.asarray(mask).reshape(rows, ng, g) if ng * g == d else None
    if m is not None:
        assert (m.sum(-1) == k).all()


@SET
@given(st.floats(0.05, 0.9), st.floats(0.1, 20.0),
       st.integers(0, 2 ** 31 - 1))
def test_wire_error_feedback_residual_bounded(k_frac, amp, seed):
    """Compressing a FIXED tensor with error feedback must not diverge:
    the residual norm stays bounded (by ~the tensor norm) across repeated
    rounds instead of accumulating."""
    from repro.core import compression as C
    key = jax.random.PRNGKey(seed)
    x = amp * jax.random.normal(key, (4, 256))
    res = jnp.zeros_like(x)
    x_norm = float(jnp.linalg.norm(x))
    norms = []
    for _ in range(30):
        y = C.wire_topk_dense(x + res, k_frac)
        res = (x + res) - y
        norms.append(float(jnp.linalg.norm(res)))
    assert np.isfinite(norms).all()
    # bounded: no blow-up — the tail plateaus within a small multiple of
    # the input norm (EF contraction; the multiple grows as k_frac -> 0,
    # ~5x at k_frac=0.08 empirically — DESIGN.md §11)
    assert max(norms[15:]) <= 8.0 * x_norm + 1e-3
    # and the plateau is flat, not climbing
    assert max(norms[25:]) <= 1.25 * max(norms[10:20]) + 1e-3


# ------------------------------------------------------ cut-prefix planes
@SET
@given(st.integers(2, 12),                       # n_units
       st.lists(st.integers(1, 40), min_size=1, max_size=12),  # unit sizes
       st.integers(1, 30),                       # head size
       st.lists(st.integers(0, 12), min_size=1, max_size=64),  # cut vector
       st.integers(0, 2 ** 31 - 1))
def test_prefix_plane_covers_every_cut(n_units, sizes, head, cuts, seed):
    """DESIGN.md §12 invariant, for ARBITRARY cut vectors and unit sizes:
    the signature's max-cut bucket is a pow2 (or n_units-1) upper bound on
    every reachable cut, and the owned prefix window is exactly the
    contiguous run of parameters whose unit id falls below the bucket —
    so a plane sized to the window can hold any scheduled client's owned
    units, and nothing more."""
    from repro.core.superstep import cut_prefix_bucket, owned_window
    sizes = (sizes * n_units)[:n_units]
    cuts = [min(c, n_units - 1) for c in cuts]
    bucket = cut_prefix_bucket(max(cuts), n_units)
    # upper bound on every cut, pow2-bucketed (retrace-free under churn)
    assert bucket >= max(cuts)
    assert bucket <= max(n_units - 1, 1)
    assert bucket == n_units - 1 or (bucket & (bucket - 1)) == 0
    # the engine's plane layout: head serializes first (ids = n_units),
    # then units ascending — mirrored here without building a model
    ids = np.concatenate([np.full(head, n_units, np.int32)]
                         + [np.full(sizes[u], u, np.int32)
                            for u in range(n_units)])
    off, width = owned_window(ids, bucket)
    assert width == int((ids < bucket).sum())
    assert width == sum(sizes[:bucket])
    owned = np.flatnonzero(ids < bucket)
    if width:
        np.testing.assert_array_equal(owned, np.arange(off, off + width))
    # every parameter a scheduled cut can own lies inside the window
    for c in set(cuts):
        assert (np.flatnonzero(ids < c) >= off).all()
        assert (np.flatnonzero(ids < c) < off + width).all()


# -------------------------------------------------------------- fault plane
@SET
@given(st.integers(1, 8), st.integers(0, 2 ** 31 - 1))
def test_survivor_fedavg_all_true_equals_plain_fedavg(n, seed):
    """With every replica surviving, the partial merge IS stacked_fedavg —
    same reduction, same floats (DESIGN.md §13 zero-fault invariant at the
    aggregation level)."""
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    stack = {"w": jax.random.normal(k1, (n, 3, 4)),
             "b": jax.random.normal(k2, (n, 2))}
    w = jnp.arange(1, n + 1, dtype=jnp.float32)
    surv = jnp.ones((n,), bool)
    full = aggregation.stacked_fedavg(stack, w)
    part = aggregation.survivor_fedavg(stack, w, surv, fallback=full)
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), part, full)


@SET
@given(st.integers(2, 8),
       st.lists(st.booleans(), min_size=2, max_size=8),
       st.floats(0.05, 1.0), st.integers(0, 2 ** 31 - 1))
def test_survivor_fedavg_renormalizes_over_any_nonempty_mask(
        n, mask, scale, seed):
    """The survivor weights renormalize to exactly 1 over ANY non-empty
    mask — including fractional weights < 1 (staleness discounts), which
    is why the denominator must be where(total>0, total, 1), not
    maximum(total, 1).  Identical replicas come back unchanged iff anyone
    survives; the fallback comes back when nobody does."""
    mask = (mask * n)[:n]
    key = jax.random.PRNGKey(seed)
    leaf = jax.random.normal(key, (3,))
    stack = {"w": jnp.broadcast_to(leaf, (n, 3))}
    # fractional weights: surviving total can sit anywhere in (0, n]
    w = jnp.full((n,), scale, jnp.float32)
    surv = jnp.asarray(mask, bool)
    fb = {"w": jnp.full((3,), 123.0)}
    out = aggregation.survivor_fedavg(stack, w, surv, fallback=fb)
    if any(mask):
        np.testing.assert_allclose(np.asarray(out["w"]), np.asarray(leaf),
                                   rtol=1e-6)
    else:
        np.testing.assert_array_equal(np.asarray(out["w"]),
                                      np.asarray(fb["w"]))


@SET
@given(st.lists(st.booleans(), min_size=1, max_size=32),
       st.lists(st.booleans(), min_size=1, max_size=32))
def test_rescue_mask_guarantees_a_participant(sched, failed):
    """For ARBITRARY scheduled/failed masks: clearing the rescue bits
    always leaves >= 1 surviving scheduled vehicle (when anything is
    scheduled), and the rescue is inert whenever a survivor already
    exists."""
    from repro.core import faults
    n = max(len(sched), len(failed))
    sched = np.array((sched * n)[:n])
    failed = np.array((failed * n)[:n]) & sched
    rescue = np.asarray(faults.rescue_mask(jnp.asarray(sched),
                                           jnp.asarray(failed)))
    surv_before = sched & ~failed
    if surv_before.any() or not sched.any():
        assert not rescue.any()          # inert
    else:
        assert rescue.sum() == 1
        assert sched[np.argmax(rescue)]  # rescues a scheduled vehicle
    surv_after = sched & ~(failed & ~rescue)
    assert surv_after.any() == sched.any()


@SET
@given(st.lists(st.booleans(), min_size=1, max_size=16),
       st.integers(1, 64), st.integers(0, 2 ** 31 - 1))
def test_drop_steps_bounds(drop, steps, seed):
    """Performed steps land in [0, steps]; a dropped vehicle performs a
    strict prefix (< steps), a surviving one the full schedule."""
    from repro.core import faults
    rng = np.random.default_rng(seed)
    drop = np.array(drop)
    frac = rng.random(len(drop)).astype(np.float32)
    out = np.asarray(faults.drop_steps(jnp.asarray(drop),
                                       jnp.asarray(frac), steps))
    assert (out >= 0).all() and (out <= steps).all()
    assert (out[drop] < steps).all()
    assert (out[~drop] == steps).all()


# ---------------------------------------------------------- streaming plane
@SET
@given(st.integers(1, 8), st.lists(st.booleans(), min_size=1, max_size=8),
       st.integers(0, 2 ** 31 - 1))
def test_constant_discount_is_bitwise_survivor_fedavg(n, mask, seed):
    """The streaming merge with staleness 0 IS plain survivor FedAvg, bit
    for bit: the constant kernel multiplies every weight by exactly 1.0,
    an IEEE identity, so the buffered-async path cannot perturb a
    fresh-only merge (DESIGN.md §14 zero-staleness invariant)."""
    from repro.core import streaming
    key = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    stack = {"w": jax.random.normal(k1, (n, 3, 4)),
             "b": jax.random.normal(k2, (n, 2))}
    w = jax.random.uniform(k3, (n,), minval=0.1, maxval=10.0)
    surv = jnp.asarray((mask * n)[:n], bool)
    disc = streaming.staleness_kernel("constant", 0.5, jnp.zeros((n,)))
    fb = {"w": jnp.zeros((3, 4)), "b": jnp.zeros((2,))}
    plain = aggregation.survivor_fedavg(stack, w, surv, fallback=fb)
    disco = aggregation.discounted_survivor_fedavg(stack, w, surv, disc,
                                                   fallback=fb)
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), disco, plain)


@SET
@given(st.sampled_from(["constant", "poly"]),
       st.floats(0.0, 4.0),
       st.lists(st.integers(0, 64), min_size=2, max_size=16))
def test_staleness_kernel_monotone_non_increasing(kernel, alpha, ages):
    """A staler delta never earns MORE merge weight: both kernels are
    monotone non-increasing in staleness (and land in (0, 1])."""
    from repro.core import streaming
    s = jnp.asarray(sorted(ages), jnp.float32)
    k = np.asarray(streaming.staleness_kernel(kernel, alpha, s))
    assert (np.diff(k) <= 0).all()
    assert (k > 0.0).all() and (k <= 1.0).all()
    # and the discount propagates monotonically into the merge weight
    w = k * 3.5
    assert (np.diff(w) <= 0).all()


# ------------------------------------------------- dirichlet partitioner
@SET
@given(st.integers(2, 8), st.floats(0.05, 5.0), st.integers(0, 10_000))
def test_dirichlet_partition_invariants(n_clients, alpha, seed):
    """Dirichlet(alpha) shards form an exact partition: disjoint, in
    range, and together covering every sample once."""
    from repro.data.partition import dirichlet_partition
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, 10, size=600)
    parts = dirichlet_partition(seed, labels, n_clients, alpha=alpha)
    assert len(parts) == n_clients
    allidx = np.concatenate([p for p in parts]) if parts else np.array([])
    assert len(allidx) == len(labels)
    assert len(np.unique(allidx)) == len(labels)


@pytest.mark.parametrize("alpha", [0.1, 0.3])
def test_dirichlet_label_distribution_skews_with_alpha(alpha):
    """The label-distribution test at the paper-standard alphas: a small
    concentration parameter puts most of each class on few clients
    (measured by the mean max per-class share), strictly more skewed than
    the near-IID alpha=100 reference — and lower alpha skews harder."""
    from repro.data.partition import dirichlet_partition, partition_stats
    rng = np.random.default_rng(0)
    labels = rng.integers(0, 10, size=4000)
    n_clients = 8

    def mean_max_share(a):
        parts = dirichlet_partition(7, labels, n_clients, alpha=a)
        shares = np.zeros((10, n_clients))
        for i, p in enumerate(parts):
            for c in range(10):
                shares[c, i] = (labels[p] == c).sum()
        shares /= np.maximum(shares.sum(axis=1, keepdims=True), 1)
        return shares.max(axis=1).mean(), parts

    skewed, parts = mean_max_share(alpha)
    iid, _ = mean_max_share(100.0)
    assert skewed > iid + 0.1
    assert iid < 0.25            # alpha=100 spreads classes near-uniformly
    if alpha == 0.1:
        assert skewed > 0.5      # most of a class concentrates on 1 client
    # partition_stats reports the induced label footprints
    stats = partition_stats(parts, labels)
    assert sum(s["n"] for s in stats) == len(labels)
    assert all(set(s["classes"]) <= set(range(10)) for s in stats)


# ------------------------------------------------------------- fleet mesh
@SET
@given(st.integers(0, 5000),
       st.sampled_from(["vehicle", "rsu", "grid"]),
       st.integers(0, 2 ** 31 - 1))
def test_mesh_padding_is_minimal_device_multiple(s, axis, _seed):
    """Every FleetMesh pad rule returns the SMALLEST multiple of its device
    divisor that holds the payload (ISSUE 10): ``pad`` over the primary
    axis, ``pad_slots`` over the vehicle sub-axis, ``balanced_slots`` over
    the whole 2-D mesh — and a 1-device mesh pads nothing."""
    from repro.core import fleet_sharding as fs
    for n in sorted({1, jax.device_count()}):
        mesh = fs.build_fleet_mesh(n, axis)
        for fn, d in ((mesh.pad, mesh.primary_devices),
                      (mesh.pad_slots, mesh.veh_devices),
                      (mesh.balanced_slots, mesh.n_devices)):
            b = fn(s)
            assert b % d == 0            # shardable across the divisor
            assert b >= max(s, 1)        # holds the payload (never empty)
            assert b - max(s, 1) < d     # and not one row more than needed


@SET
@given(st.integers(1, 4096))
def test_grid_shape_factorization(n):
    """grid_shape splits n devices into (rsu, vehicle) with the vehicle
    sub-axis a power of two at most sqrt(n), so both factors multiply back
    to n and the slot axis always gets the smaller side."""
    from repro.core import fleet_sharding as fs
    dr, dv = fs.grid_shape(n)
    assert dr * dv == n
    assert dv >= 1 and (dv & (dv - 1)) == 0      # power of two
    assert dv * dv <= n                          # vehicle side <= sqrt(n)
