"""Continuous-fleet streaming benchmark (DESIGN.md §14).

Two curve families over the multi-RSU fused super-step engine on the
continuous highway scenario:

* **goodput vs churn** — sweeps the presence-toggle rate over ``--churns``
  (default 0, 0.1, 0.2, 0.4) under both the synchronous ``sequential``
  schedule and the buffered-asynchronous ``streaming`` schedule, reporting
  ``goodput_samples_per_s``: the sample mass the global model absorbed per
  steady-state second.  Sync schedules make every arrival sit out its
  arrival round (registration/model download), so their goodput decays as
  churn rises; the streaming schedule admits arrivals immediately (ingest
  is double-buffered behind device compute) and holds its goodput flat.
* **staleness vs accuracy** — at fixed churn, sweeps the StreamBuffer
  capacity over ``--buffers`` (default 2, 4, 8): a bigger buffer merges
  less often, so the mean slot age at merge time grows and the
  staleness-discounted model pays for it in accuracy.

Every row is one ``repro.api.run(ExperimentSpec)`` call and asserts
``compile_fallbacks == 0``: presence churn is carried data and the buffer
is donated carry, so the streaming sweep compiles exactly as often as a
static-fleet run.

  PYTHONPATH=src python benchmarks/bench_streaming.py
  -> BENCH_streaming.json (repo root) + benchmarks/out/BENCH_streaming.json
"""
from __future__ import annotations

import argparse
import gc
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

from bench_devices import parse_devices_early

# --devices N[,M,...]: per-device-count rows; the host device count must be
# forced BEFORE the first jax import (jax locks it on backend init)
DEVICE_COUNTS = parse_devices_early()

import jax
import numpy as np

from bench_io import device_row_key, write_bench
from repro import api


def _spec(args, schedule: str, churn: float, buffer_size: int,
          kernel: str, devices: int = 1) -> api.ExperimentSpec:
    return api.ExperimentSpec(
        model="mlp9",
        train=api.TrainConfig(scheme="asfl", rounds=args.rounds,
                              local_steps=args.local_steps,
                              batch_size=args.batch, lr=1e-3,
                              eval_every=1, server_schedule=schedule),
        stream=api.StreamConfig(buffer_size=buffer_size, churn_rate=churn,
                                kernel=kernel, alpha=args.alpha,
                                seed=args.stream_seed),
        adaptive=api.AdaptiveConfig(strategy=args.strategy),
        fleet=api.FleetConfig(n_vehicles=args.fleet, scenario=args.scenario,
                              scenario_kwargs={"seed": args.fleet},
                              cloud_sync_every=args.sync,
                              round_interval_s=10.0,
                              per_vehicle_samples=64, data_seed=args.fleet),
        runtime=api.RuntimeConfig(superstep=args.superstep, precompile=True,
                                  mesh_devices=devices))


def bench_one(args, schedule: str, churn: float, buffer_size: int,
              kernel: str, devices: int = 1) -> dict:
    res = api.run(_spec(args, schedule, churn, buffer_size, kernel, devices),
                  timeit=args.timeit)
    assert all(np.isfinite(m.loss) for m in res.history)
    assert res.diagnostics["compile_fallbacks"] == 0
    accs = [m.test_acc for m in res.history if np.isfinite(m.test_acc)]
    merges = res.totals["stream_merges"]
    stale_total = float(sum(getattr(m, "stream_stale", 0.0)
                            for m in res.history))
    row = {
        "schedule": schedule, "churn": churn, "devices": devices,
        "buffer_size": buffer_size, "kernel": kernel,
        "final_acc": float(accs[-1]) if accs else float("nan"),
        "final_loss": float(res.history[-1].loss),
        # goodput (the headline): sample mass absorbed per second
        "goodput_samples_per_s": res.totals["goodput_samples_per_s"],
        "absorbed_samples": res.totals["absorbed_samples"],
        "stream_merges": merges,
        "n_arrived": res.totals["n_arrived"],
        # mean slot age discharged per merge (each fire empties exactly
        # buffer_size slots), the x-axis of the staleness/accuracy curve
        "mean_slot_staleness": (stale_total / (merges * buffer_size)
                                if merges else 0.0),
        "round_s": res.timing["round_s"],
        "rounds_per_s": res.timing["rounds_per_s"],
    }
    if "staleness_hist" in res.diagnostics:
        row["staleness_hist"] = res.diagnostics["staleness_hist"]
    return row


def check_baseline(out: dict, baseline_path: str, max_regress: float) -> int:
    """Exit status for the CI perf smoke: 1 if any matching row's goodput
    dropped more than ``max_regress`` below the committed baseline."""
    if not os.path.exists(baseline_path):
        print(f"baseline {baseline_path} missing; skipping perf check")
        return 0
    with open(baseline_path) as f:
        base = json.load(f)
    keys = ("fleet", "scenario", "strategy", "rounds", "local_steps",
            "batch", "superstep", "sync", "kernel", "alpha", "stream_seed")
    mismatch = {k: (base.get("config", {}).get(k), out["config"].get(k))
                for k in keys
                if base.get("config", {}).get(k) != out["config"].get(k)}
    if mismatch:
        print(f"baseline config mismatch {mismatch}; skipping perf check "
              f"(regenerate {baseline_path})")
        return 0

    def _perf_key(r):
        return device_row_key(
            f"{r['schedule']}@{r['churn']}x{r['buffer_size']}",
            r.get("devices", 1))

    base_rows = {_perf_key(r): r["goodput_samples_per_s"]
                 for r in base.get("results", [])}
    failures = []
    for row in out["results"]:
        key = _perf_key(row)
        if key not in base_rows or not base_rows[key]:
            print(f"no baseline goodput for {key}; skipping")
            continue
        floor = base_rows[key] * (1.0 - max_regress)
        gp = row["goodput_samples_per_s"]
        status = "OK" if gp >= floor else "REGRESSION"
        print(f"goodput {key}: {gp:.0f} samples/s vs baseline "
              f"{base_rows[key]:.0f} (floor {floor:.0f}) {status}")
        if gp < floor:
            failures.append(key)
    if failures:
        print(f"goodput regression >{max_regress:.0%} in rows: {failures}")
        return 1
    return 0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--churns", default="0,0.1,0.2,0.4",
                    help="presence-toggle rates for the goodput sweep")
    ap.add_argument("--buffers", default="2,4,8",
                    help="StreamBuffer capacities for the staleness sweep")
    ap.add_argument("--staleness-churn", type=float, default=0.2,
                    help="fixed churn for the staleness/accuracy sweep")
    ap.add_argument("--kernel", default="poly",
                    choices=["constant", "poly"])
    ap.add_argument("--alpha", type=float, default=0.5)
    ap.add_argument("--stream-seed", type=int, default=0)
    ap.add_argument("--fleet", type=int, default=64)
    ap.add_argument("--scenario", default="highway_corridor")
    ap.add_argument("--strategy", default="paper")
    ap.add_argument("--rounds", type=int, default=12)
    ap.add_argument("--local-steps", type=int, default=2)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--sync", type=int, default=4)
    ap.add_argument("--superstep", type=int, default=4)
    ap.add_argument("--devices", default="1", metavar="N[,M...]",
                    help="device counts to bench (RSU-axis mesh rows; on "
                         "CPU the host device count is forced pre-import "
                         "— parsed by bench_devices before jax loads)")
    ap.add_argument("--timeit", type=int, default=1)
    ap.add_argument("--no-write", action="store_true")
    ap.add_argument("--skip-staleness", action="store_true",
                    help="goodput sweep only (the CI smoke)")
    ap.add_argument("--check-baseline", metavar="PATH",
                    help="compare goodput against a committed "
                         "BENCH_streaming.json; missing baseline skips")
    ap.add_argument("--max-regress", type=float, default=0.30)
    args = ap.parse_args()

    results = []
    churns = [float(s) for s in args.churns.split(",")]
    for devices in DEVICE_COUNTS:
        for schedule in ("sequential", "streaming"):
            for churn in churns:
                gc.collect()
                row = bench_one(args, schedule, churn,
                                buffer_size=4, kernel=args.kernel,
                                devices=devices)
                results.append(row)
                print(f"{schedule:10s} churn={churn:4.2f} dev={devices} "
                      f"goodput={row['goodput_samples_per_s']:8.0f} samples/s "
                      f"acc={row['final_acc']:.3f} "
                      f"merges={row['stream_merges']:3d} "
                      f"arrived={row['n_arrived']:3d} "
                      f"({row['rounds_per_s']:.2f} rounds/s)", flush=True)

    if not args.skip_staleness:
        for buf in (int(s) for s in args.buffers.split(",")):
            gc.collect()
            row = bench_one(args, "streaming", args.staleness_churn,
                            buffer_size=buf, kernel=args.kernel,
                            devices=DEVICE_COUNTS[0])
            results.append(row)
            print(f"buffer={buf:2d} churn={args.staleness_churn:4.2f} "
                  f"stale={row['mean_slot_staleness']:5.2f} "
                  f"acc={row['final_acc']:.3f} "
                  f"goodput={row['goodput_samples_per_s']:8.0f}", flush=True)

    def _curve(schedule):
        # the headline curves come from the first device count; extra
        # --devices rows live in results keyed by their device suffix
        return {str(r["churn"]): r["goodput_samples_per_s"]
                for r in results
                if r["schedule"] == schedule and r["buffer_size"] == 4
                and r["devices"] == DEVICE_COUNTS[0]}

    seq, strm = _curve("sequential"), _curve("streaming")
    out = {
        "config": {"fleet": args.fleet, "scenario": args.scenario,
                   "strategy": args.strategy, "rounds": args.rounds,
                   "local_steps": args.local_steps, "batch": args.batch,
                   "sync": args.sync, "superstep": args.superstep,
                   "kernel": args.kernel, "alpha": args.alpha,
                   "stream_seed": args.stream_seed,
                   "staleness_churn": args.staleness_churn,
                   "devices": list(DEVICE_COUNTS),
                   "backend": jax.default_backend(),
                   "driver": "repro.api.run"},
        "goodput_vs_churn": {"sequential": seq, "streaming": strm},
        # the headline ratio: how much absorbed throughput the
        # buffered-async plane keeps as the fleet churns
        "goodput_ratio_streaming_vs_sequential": {
            c: (strm[c] / seq[c] if seq.get(c) else None)
            for c in strm if c in seq},
        "staleness_vs_accuracy": [
            {"buffer_size": r["buffer_size"],
             "mean_slot_staleness": r["mean_slot_staleness"],
             "final_acc": r["final_acc"]}
            for r in results
            if r["schedule"] == "streaming"
            and r["churn"] == args.staleness_churn],
        "results": results,
    }
    if not args.no_write:
        write_bench("BENCH_streaming", out, "benchmarks/bench_streaming.py")
    if args.check_baseline:
        sys.exit(check_baseline(out, args.check_baseline, args.max_regress))


if __name__ == "__main__":
    main()
