"""Fused super-step engine (ISSUE 3 acceptance tests, DESIGN.md §8):
K-fused == K-sequential equivalence (with a handover and a cloud merge
inside the fused window), donation safety, precompile coverage (no silent
mid-run recompiles), capacity-padding invariance, and traced-twin parity
for the on-device schedulers."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import adaptive, channel, cost
from repro.core import scenario as S
from repro.core.fedsim import ScenarioEngine, SimConfig
from repro.data.pipeline import fleet_batch_indices_traced

from test_scenario import TinyMLP, _two_cell_trace, _vector_clients

ROUNDS, INTERVAL = 4, 5.0


def _cfg(**kw):
    base = dict(scheme="asfl", adaptive_strategy="paper", rounds=ROUNDS,
                local_steps=2, batch_size=8, lr=1e-2, optimizer="sgd",
                round_interval_s=INTERVAL, eval_every=0, superstep=1)
    base.update(kw)
    return SimConfig(**base)


def _engines(cfg1, sync=2):
    """(K=1 engine, K=4 engine) over the canonical two-cell handover trace:
    the 4-round window contains vehicle 0's handover AND (sync=2) a cloud
    merge strictly inside the fused window."""
    sc = _two_cell_trace(ROUNDS, INTERVAL)
    clients, test = _vector_clients(2)
    cfgK = dataclasses.replace(cfg1, superstep=ROUNDS)
    e1 = ScenarioEngine(TinyMLP(), clients, test, cfg1, sc,
                        cloud_sync_every=sync)
    eK = ScenarioEngine(TinyMLP(), clients, test, cfgK, sc,
                        cloud_sync_every=sync)
    return e1, eK


def _params(eng):
    return jax.tree.map(np.asarray, {"units": eng.units, "head": eng.head})


# ---------------------------------------------------- fused == sequential
@pytest.mark.parametrize("schedule", ["sequential", "parallel"])
@pytest.mark.parametrize("optimizer,exact", [("sgd", True), ("adam", False)])
def test_superstep_matches_sequential_rounds(schedule, optimizer, exact):
    """K fused rounds == K per-round dispatches: same program body, so sgd
    is bit-for-bit; adam stays within the engine-parity fp tolerance.  The
    window covers a handover and a mid-window cloud merge."""
    e1, eK = _engines(_cfg(optimizer=optimizer, server_schedule=schedule))
    h1, hK = e1.run(), eK.run()
    # the fused window really contained the interesting events
    assert sum(m.n_handover for m in h1) >= 1
    assert [m.n_handover for m in h1] == [m.n_handover for m in hK]
    assert [m.n_scheduled for m in h1] == [m.n_scheduled for m in hK]
    assert [m.cuts for m in h1] == [m.cuts for m in hK]
    p1, pK = _params(e1), _params(eK)
    if exact:
        jax.tree.map(np.testing.assert_array_equal, p1, pK)
        np.testing.assert_array_equal([m.loss for m in h1],
                                      [m.loss for m in hK])
    else:
        jax.tree.map(lambda a, b: np.testing.assert_allclose(
            a, b, atol=1e-5, rtol=1e-5), p1, pK)
        np.testing.assert_allclose([m.loss for m in h1],
                                   [m.loss for m in hK],
                                   rtol=1e-5, atol=1e-5)
    # training progressed across the handover in both paths
    assert h1[-1].loss < h1[0].loss
    assert hK[-1].loss < hK[0].loss


def test_superstep_tail_window():
    """rounds not divisible by K: the tail window (smaller K) matches the
    per-round path bit-for-bit too."""
    e1, eK = _engines(_cfg())
    eK.cfg.superstep = 3                       # windows of 3 + tail of 1
    h1, hK = e1.run(), eK.run()
    jax.tree.map(np.testing.assert_array_equal, _params(e1), _params(eK))
    np.testing.assert_array_equal([m.loss for m in h1],
                                  [m.loss for m in hK])


def test_capacity_padding_is_inert():
    """pow2 vs tight8 slot capacity: padded slots are exact no-ops, so the
    trained model is bit-identical."""
    ea, eb = (_engines(_cfg(slot_capacity=cap))[1]
              for cap in ("pow2", "tight8"))
    ha, hb = ea.run(), eb.run()
    jax.tree.map(np.testing.assert_array_equal, _params(ea), _params(eb))
    np.testing.assert_array_equal([m.loss for m in ha],
                                  [m.loss for m in hb])


def test_rsu_loads_follow_the_trace():
    """On-device segment grouping reproduces the known two-cell membership:
    both vehicles start in cell 0; after the crossing, one per cell."""
    _, eK = _engines(_cfg())
    hist = eK.run()
    assert hist[0].rsu_loads == [2, 0]
    assert hist[-1].rsu_loads == [1, 1]
    assert all(sum(m.rsu_loads) == m.n_scheduled for m in hist)


# ------------------------------------------------------- donation safety
def test_donated_carries_never_reused():
    """The super-step donates its carry: old carry buffers must be deleted,
    the engine must keep working across windows/resets, and the public
    units/head handed to callers must survive later (donating)
    dispatches."""
    e1, eK = _engines(_cfg(superstep=2))
    carry0_leaves = jax.tree.leaves(eK._carry)
    hist = eK.run()
    assert len(hist) == ROUNDS
    # the initial carry was consumed by donation...
    assert all(leaf.is_deleted() for leaf in carry0_leaves)
    # ...but caller-facing views are fresh buffers: still readable after
    # further donating dispatches and a reset
    held = jax.tree.map(lambda a: a, {"units": eK.units, "head": eK.head})
    eK.reset()
    eK.run()
    first = jax.tree.leaves(held)[0]
    assert not first.is_deleted()
    _ = jax.tree.map(np.asarray, held)         # materializes without error
    assert np.isfinite(hist[-1].loss)


# ----------------------------------------------- precompile / warm start
def test_precompile_covers_every_signature():
    """After precompile(), a full run must not build (or XLA-compile)
    anything: the engine's fallback counter stays at zero and no backend
    compile events fire during the run (jax.monitoring)."""
    sc = _two_cell_trace(ROUNDS, INTERVAL)
    clients, test = _vector_clients(2)
    cfg = _cfg(superstep=3, eval_every=1)      # windows 3 + 1, plus eval
    eng = ScenarioEngine(TinyMLP(), clients, test, cfg, sc,
                         cloud_sync_every=2)
    sigs = eng.precompile()
    assert len(sigs) == 2                      # K=3 and the K=1 tail
    assert eng.programs.compile_fallbacks == 0

    events = []
    jax.monitoring.register_event_duration_secs_listener(
        lambda name, *a, **kw: events.append(name))
    baseline = len([e for e in events if "compile" in e])
    hist = eng.run()
    compiles = [e for e in events[baseline:] if "compile" in e]
    assert eng.programs.compile_fallbacks == 0, \
        "run requested a signature precompile() did not cover"
    assert not compiles, f"silent mid-run recompiles: {compiles}"
    assert len(hist) == ROUNDS
    # eval ran through its precompiled path too (sync rounds 2 and 4)
    assert np.isfinite(hist[-1].test_acc)


def test_fused_eval_cadence_fires():
    """eval_every must keep firing in fused mode even when the due sync
    never lands on a window-end round (K=2, sync=1, eval_every=2: syncs 0
    and 2 are due, both mid/at-window — regression test)."""
    sc = _two_cell_trace(ROUNDS, INTERVAL)
    clients, test = _vector_clients(2)
    cfg = _cfg(superstep=2, eval_every=2)
    eng = ScenarioEngine(TinyMLP(), clients, test, cfg, sc,
                         cloud_sync_every=1)
    hist = eng.run()
    accs = [m.test_acc for m in hist]
    assert any(np.isfinite(a) for a in accs), \
        f"eval never fired in fused mode: {accs}"
    # the score lands on the last synced round of an eval-due window, whose
    # global model is exactly the one evaluated
    assert all(0.0 <= a <= 1.0 for a in accs if np.isfinite(a))


def test_compilation_cache_dir_is_wired(tmp_path):
    """SimConfig.compilation_cache_dir turns on JAX's persistent cache:
    compiled super-step programs land on disk."""
    cache = tmp_path / "xla-cache"
    sc = _two_cell_trace(2, INTERVAL)
    clients, test = _vector_clients(2)
    cfg = _cfg(rounds=2, superstep=2, compilation_cache_dir=str(cache))
    eng = ScenarioEngine(TinyMLP(), clients, test, cfg, sc)
    eng.run()
    entries = list(cache.iterdir())
    assert entries, "persistent compilation cache wrote nothing"


# ------------------------------------------------- traced-twin schedulers
def test_paper_threshold_traced_matches_numpy():
    rng = np.random.default_rng(0)
    rates = rng.uniform(1e6, 4e8, 256)
    # keep clear of the band edges (fp32 vs fp64 digitize)
    for thr in adaptive.DEFAULT_THRESHOLDS:
        rates = np.where(np.abs(rates - thr) < 0.01 * thr, rates * 1.05,
                         rates)
    for literal in (False, True):
        ref = adaptive.paper_threshold(rates, literal_eq3=literal)
        got = np.asarray(adaptive.paper_threshold_traced(
            jnp.asarray(rates, jnp.float32), literal_eq3=literal))
        np.testing.assert_array_equal(ref, got)


def test_residence_aware_traced_matches_numpy():
    rng = np.random.default_rng(1)
    prof = cost.resnet_profile()
    n = 64
    rates = rng.uniform(2e6, 3e8, n)
    flops = rng.uniform(5e9, 5e10, n)
    residence = rng.uniform(0.05, 60.0, n)
    ref = np.asarray(adaptive.residence_aware(prof, rates, flops, 2e12, 4,
                                              16, 1, residence))
    got = np.asarray(adaptive.residence_aware_traced(
        prof, jnp.asarray(rates, jnp.float32),
        jnp.asarray(flops, jnp.float32), 2e12, 4, 16, 1,
        jnp.asarray(residence, jnp.float32)))
    # fp32 cost evaluation may flip knife-edge vehicles; decisions must
    # agree almost everywhere and SKIPs must agree exactly on clear cases
    assert (ref == got).mean() > 0.95
    clear = np.abs(residence - 1.0) > 0.5      # away from typical latencies
    assert ((ref == 0) == (got == 0))[clear].mean() > 0.95


def test_traced_fleet_state_matches_host_for_traces():
    """TraceReplay's traced-step path indexes the same precomputed tables
    the host path serves (fading-free: exactly)."""
    sc = S.crossing_trace(8, n_rsus=3, seed=5)
    for t in (0.0, 30.0, 77.5):
        host = sc.fleet_state(t, seed=0)
        traced = jax.jit(lambda tt: sc.traced_fleet_state(tt, None))(
            jnp.float32(t))
        np.testing.assert_array_equal(host.serving_rsu,
                                      np.asarray(traced.serving_rsu))
        np.testing.assert_allclose(host.residence_s,
                                   np.asarray(traced.residence_s),
                                   rtol=1e-5, atol=1e-4)
        np.testing.assert_allclose(host.rates_bps,
                                   np.asarray(traced.rates_bps),
                                   rtol=2e-5)


def test_traced_highway_state_consistent():
    """Highway's traced-step path reproduces the host kinematics and cell
    association (rates differ only by the fading stream)."""
    sc = S.highway_corridor(16, seed=3,
                            ch=channel.ChannelConfig(fading_std_db=0.0))
    for t in (0.0, 12.5, 60.0):
        host = sc.fleet_state(t, seed=0)
        traced = jax.jit(lambda tt: sc.traced_fleet_state(tt, None))(
            jnp.float32(t))
        np.testing.assert_array_equal(host.serving_rsu,
                                      np.asarray(traced.serving_rsu))
        np.testing.assert_allclose(host.positions,
                                   np.asarray(traced.positions),
                                   rtol=1e-5, atol=1e-2)
        np.testing.assert_allclose(host.rates_bps,
                                   np.asarray(traced.rates_bps), rtol=2e-5)


def test_fleet_batch_indices_traced_bounds():
    lengths = np.array([5, 64, 17, 1])
    idx = np.asarray(fleet_batch_indices_traced(
        jax.random.PRNGKey(0), lengths, steps=3, batch_size=8))
    assert idx.shape == (3, 4, 8)
    assert (idx >= 0).all()
    assert (idx < lengths[None, :, None]).all()


# ------------------------------------------------------- wire boundaries
@pytest.mark.parametrize("schedule", ["sequential", "parallel"])
@pytest.mark.parametrize("wire", ["int8", "topk_int8"])
def test_superstep_wire_fused_matches_sequential(schedule, wire):
    """K-fused == K per-round dispatches stays bit-for-bit with a wire
    boundary in the forward — including the error-feedback carry planes
    for topk_int8 (same program body, sgd)."""
    e1, eK = _engines(_cfg(server_schedule=schedule, wire=wire))
    h1, hK = e1.run(), eK.run()
    jax.tree.map(np.testing.assert_array_equal, _params(e1), _params(eK))
    np.testing.assert_array_equal([m.loss for m in h1],
                                  [m.loss for m in hK])
    assert all(np.isfinite(m.loss) for m in h1)


def test_wire_precompile_covers_across_cut_churn():
    """With wire="topk_int8" the EF planes are part of the carry signature:
    precompile must still cover the whole run (zero fallbacks, zero
    backend compiles) across the trace's handover/cut churn."""
    sc = _two_cell_trace(ROUNDS, INTERVAL)
    clients, test = _vector_clients(2)
    cfg = _cfg(superstep=2, wire="topk_int8")
    eng = ScenarioEngine(TinyMLP(), clients, test, cfg, sc,
                         cloud_sync_every=2)
    eng.precompile()
    events = []
    jax.monitoring.register_event_duration_secs_listener(
        lambda name, *a, **kw: events.append(name))
    baseline = len([e for e in events if "compile" in e])
    hist = eng.run()
    assert eng.programs.compile_fallbacks == 0
    assert not [e for e in events[baseline:] if "compile" in e]
    assert len(hist) == ROUNDS


def test_wire_residual_plane_persists_and_tracks_cuts():
    """The EF residual is a real carry plane: nonzero after training,
    sized to the largest boundary, and wire_cut records the cut each
    vehicle's buffer was accumulated at (it migrates with the vehicle on
    handover — the plane is fleet-indexed, not RSU-indexed)."""
    sc = _two_cell_trace(ROUNDS, INTERVAL)
    clients, test = _vector_clients(2)
    cfg = _cfg(wire="topk_int8")
    eng = ScenarioEngine(TinyMLP(), clients, test, cfg, sc,
                         cloud_sync_every=2)
    w = TinyMLP().width
    assert eng.programs.res_size == cfg.batch_size * w
    hist = eng.run()
    res = np.asarray(eng._carry["wire_res"])
    wcut = np.asarray(eng._carry["wire_cut"])
    assert res.shape == (2, eng.programs.res_size)
    # both vehicles trained (incl. vehicle 0 after its handover), so both
    # rows hold live residuals and their last cut
    assert (np.abs(res).sum(axis=1) > 0).all()
    assert (wcut == np.asarray(hist[-1].cuts)).all()
    # reset() rebuilds zeroed planes
    eng.reset()
    assert not np.asarray(eng._carry["wire_res"]).any()
    assert (np.asarray(eng._carry["wire_cut"]) == -1).all()


def test_wire_reduces_scenario_comm():
    """The accounting charges packed wire bytes: topk_int8 rounds move
    strictly fewer bytes than the dense fp32 baseline, which moves fewer
    than nothing changes elsewhere (identical schedule/cuts)."""
    hists = {}
    for wire in ("none", "topk_int8"):
        e1, _ = _engines(_cfg(wire=wire))
        hists[wire] = e1.run()
    assert [m.cuts for m in hists["none"]] == \
        [m.cuts for m in hists["topk_int8"]]
    for mn, mt in zip(hists["none"], hists["topk_int8"]):
        assert mt.comm_bytes < mn.comm_bytes


def test_staged_mobility_scenarios_run_fused():
    """urban_grid has no traced-step path: the engine stages its fleet
    state per window and still fuses K rounds into one program."""
    n = 8
    sc = S.urban_grid(n, seed=2, grid_size=4, block_m=120.0)
    clients, test = _vector_clients(n)
    cfg = _cfg(rounds=3, superstep=3)
    eng = ScenarioEngine(TinyMLP(), clients, test, cfg, sc)
    assert eng.mode == "fused-staged"
    hist = eng.run()
    assert len(hist) == 3
    assert all(np.isfinite(m.loss) for m in hist)
