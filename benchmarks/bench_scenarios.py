"""Scenario-layer benchmark: rounds/s per mobility scenario at fleet scale.

Runs the multi-RSU :class:`ScenarioEngine` — since ISSUE 3 a fused
super-step engine (DESIGN.md §8): every round executes all RSUs inside one
jitted program (on-device segment grouping, cut-as-data), ``--superstep K``
fuses K rounds into one ``lax.scan`` dispatch with donated carries, and
warmup is an AOT ``precompile()`` of every signature the run plan needs.
``--compilation-cache DIR`` wires JAX's persistent compilation cache so a
second invocation skips XLA entirely (the ``compile_cache_hit`` key records
whether this run started warm).

  PYTHONPATH=src python benchmarks/bench_scenarios.py
  -> BENCH_scenarios.json (repo root) + benchmarks/out/BENCH_scenarios.json

``--check-baseline BASELINE.json [--max-regress 0.30]`` compares this run's
rounds/s against a committed baseline and exits non-zero on a >30%
regression (the CI perf smoke); rows missing from the baseline are skipped
gracefully.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

import jax
import numpy as np

from bench_fedsim import MLPUnitModel, make_mlp_fleet_data
from repro.configs.base import cache_dir_is_warm
from repro.core import scenario
from repro.core.fedsim import ScenarioEngine, SimConfig

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
OUT_DIR = os.path.join(os.path.dirname(__file__), "out")


def bench_one(name: str, n: int, args) -> dict:
    sc = scenario.make_scenario(name, n, seed=n)
    clients, test = make_mlp_fleet_data(n, 64, 48, seed=n)
    cfg = SimConfig(scheme="asfl", adaptive_strategy=args.strategy,
                    rounds=args.rounds, local_steps=args.local_steps,
                    batch_size=args.batch, lr=1e-3, eval_every=0,
                    round_interval_s=10.0, superstep=args.superstep,
                    server_schedule=args.schedule,
                    slot_capacity=args.slot_capacity,
                    compilation_cache_dir=args.compilation_cache)
    eng = ScenarioEngine(MLPUnitModel(), clients, test, cfg, sc,
                         cloud_sync_every=args.sync)
    t0 = time.perf_counter()
    eng.precompile()               # AOT: every signature the run will use
    t_warm = time.perf_counter() - t0
    eng.run()                      # staging warm-up (no compiles)
    eng.reset()
    t0 = time.perf_counter()
    hist = eng.run()
    dt = time.perf_counter() - t0
    assert all(np.isfinite(m.loss) for m in hist)
    assert eng.programs.compile_fallbacks == 0
    return {
        "scenario": name, "n_vehicles": n, "n_rsus": len(sc.rsu_positions),
        "mode": eng.mode, "schedule": args.schedule,
        "superstep": args.superstep, "rounds": args.rounds,
        "round_s": dt / args.rounds, "rounds_per_s": args.rounds / dt,
        "warmup_s": t_warm,
        "scheduled_per_round": [m.n_scheduled for m in hist],
        "handovers": int(sum(m.n_handover for m in hist)),
        "final_loss": float(hist[-1].loss),
    }


def check_baseline(out: dict, baseline_path: str, max_regress: float) -> int:
    """Exit status for the CI perf smoke: 1 if any matching row's rounds/s
    dropped more than ``max_regress`` below the baseline."""
    if not os.path.exists(baseline_path):
        print(f"baseline {baseline_path} missing; skipping perf check")
        return 0
    with open(baseline_path) as f:
        base = json.load(f)
    # rounds/s is only comparable when the per-round work matches: skip
    # (don't spuriously fail) if the bench config drifted from the
    # committed baseline's — that means the baseline needs regenerating
    keys = ("local_steps", "batch", "strategy", "cloud_sync_every",
            "superstep", "schedule", "slot_capacity")
    mismatch = {k: (base.get("config", {}).get(k), out["config"].get(k))
                for k in keys
                if base.get("config", {}).get(k) != out["config"].get(k)}
    if mismatch:
        print(f"baseline config mismatch {mismatch}; skipping perf check "
              f"(regenerate {baseline_path})")
        return 0
    base_rows = {(r["scenario"], r["n_vehicles"]): r["rounds_per_s"]
                 for r in base.get("results", [])}
    failures = []
    for row in out["results"]:
        key = (row["scenario"], row["n_vehicles"])
        if key not in base_rows:
            print(f"no baseline row for {key}; skipping")
            continue
        floor = base_rows[key] * (1.0 - max_regress)
        status = "OK" if row["rounds_per_s"] >= floor else "REGRESSION"
        print(f"perf {key}: {row['rounds_per_s']:.2f} r/s vs baseline "
              f"{base_rows[key]:.2f} (floor {floor:.2f}) {status}")
        if row["rounds_per_s"] < floor:
            failures.append(key)
    if failures:
        print(f"perf regression >{max_regress:.0%} in rows: {failures}")
        return 1
    return 0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--sizes", default="64,256")
    ap.add_argument("--scenarios", default=",".join(sorted(scenario.SCENARIOS)))
    ap.add_argument("--rounds", type=int, default=4)
    ap.add_argument("--local-steps", type=int, default=2)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--strategy", default="paper",
                    help="cut strategy (paper | residence | ...)")
    ap.add_argument("--sync", type=int, default=1)
    ap.add_argument("--superstep", type=int, default=8,
                    help="rounds fused per dispatch (1 = per-round); the "
                         "default benchmarks the engine's recommended "
                         "fused operating point")
    ap.add_argument("--schedule", default="sequential",
                    choices=["sequential", "parallel"])
    ap.add_argument("--slot-capacity", default="tight8",
                    choices=["pow2", "tight8"])
    ap.add_argument("--compilation-cache", default=None, metavar="DIR",
                    help="persistent XLA compilation cache directory")
    ap.add_argument("--check-baseline", default=None, metavar="JSON",
                    help="compare rounds/s against a committed baseline")
    ap.add_argument("--max-regress", type=float, default=0.30)
    ap.add_argument("--no-write", action="store_true",
                    help="don't overwrite BENCH_scenarios.json")
    args = ap.parse_args()

    cache_hit = cache_dir_is_warm(args.compilation_cache)
    results = []
    for name in args.scenarios.split(","):
        for n in (int(s) for s in args.sizes.split(",")):
            row = bench_one(name, n, args)
            results.append(row)
            print(f"{name:17s} n={n:4d} rsus={row['n_rsus']} "
                  f"mode={row['mode']:12s} K={args.superstep} "
                  f"warmup={row['warmup_s']:6.1f}s "
                  f"round={row['round_s']*1e3:9.1f} ms "
                  f"({row['rounds_per_s']:.2f} rounds/s) "
                  f"handovers={row['handovers']}", flush=True)

    out = {
        "config": {"local_steps": args.local_steps, "batch": args.batch,
                   "rounds": args.rounds, "strategy": args.strategy,
                   "cloud_sync_every": args.sync,
                   "superstep": args.superstep, "schedule": args.schedule,
                   "slot_capacity": args.slot_capacity,
                   "compilation_cache": args.compilation_cache,
                   "backend": jax.default_backend()},
        "warmup_total_s": float(sum(r["warmup_s"] for r in results)),
        "compile_cache_hit": cache_hit,
        "rounds_per_s": {f"{r['scenario']}@{r['n_vehicles']}":
                         r["rounds_per_s"] for r in results},
        "results": results,
    }
    if not args.no_write:
        os.makedirs(OUT_DIR, exist_ok=True)
        for path in (os.path.join(ROOT, "BENCH_scenarios.json"),
                     os.path.join(OUT_DIR, "BENCH_scenarios.json")):
            with open(path, "w") as f:
                json.dump(out, f, indent=1, default=float)
        print(f"wrote {os.path.join(ROOT, 'BENCH_scenarios.json')} "
              f"(warmup_total_s={out['warmup_total_s']:.1f}, "
              f"cache_hit={cache_hit})")

    if args.check_baseline:
        sys.exit(check_baseline(out, args.check_baseline, args.max_regress))


if __name__ == "__main__":
    main()
