"""qwen3-14b — dense GQA with qk_norm [hf:Qwen/Qwen3-8B family].

[dense] 40L d_model=5120 40H (GQA kv=8) d_ff=17408 vocab=151936.
40 heads are not divisible by the 16-way model axis; the sharding rules
(launch/mesh.py) therefore shard attention weights on the d_model dim.
Pure full attention -> long_500k skipped.
"""
from repro.configs.base import ATTN, ArchConfig

CONFIG = ArchConfig(
    name="qwen3-14b",
    family="dense",
    source="hf:Qwen/Qwen3-8B",
    n_layers=40,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=17408,
    vocab_size=151936,
    pattern=(ATTN,),
    qk_norm=True,
    mlp_variant="swiglu",
    rope_theta=1_000_000.0,
    default_cut=2,
    param_dtype="bfloat16",
    subquadratic=False,
)
