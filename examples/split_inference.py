"""Split inference (paper §IV-C): serve a decoder with the model cut between
'vehicle' and 'RSU', batched requests, prefill + decode with KV caches.

Uses the reduced smollm-360m config on CPU; the same code path serves the
full architectures on the production mesh via launch/serve.py.  Also shows
int8 smashed-data compression on the uplink and compares the logits drift.

  PYTHONPATH=src python examples/split_inference.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import distributed as D
from repro.models import transformer as T


def main():
    cfg = get_config("smollm-360m").reduced()
    key = jax.random.PRNGKey(0)
    params = T.init_params(key, cfg)
    b, prompt, steps = 4, 48, 12
    capacity = prompt + steps
    toks = jax.random.randint(key, (b, prompt), 0, cfg.vocab_size)

    for compress in (False, True):
        opts = D.DistOptions(cut=2, compress_smashed=compress)
        prefill = jax.jit(D.make_prefill_step(cfg, opts, capacity))
        decode = jax.jit(D.make_decode_step(cfg, opts, capacity))

        t0 = time.time()
        logits, caches = prefill(params, {"tokens": toks})
        out_ids = []
        pos = prompt
        for i in range(steps):
            nxt = jnp.argmax(logits[:, -1, :cfg.vocab_size], axis=-1)
            out_ids.append(np.asarray(nxt))
            logits, caches = decode(params, {"tokens": nxt[:, None]}, caches,
                                    jnp.asarray(pos))
            pos += 1
        dt = time.time() - t0
        tag = "int8-compressed uplink" if compress else "fp32 uplink        "
        print(f"[{tag}] {steps} tokens x {b} reqs in {dt:.2f}s "
              f"-> ids[0]={np.stack(out_ids)[:, 0].tolist()}")

    # uplink bytes comparison at this cut (one decode step)
    smashed_elems = b * 1 * cfg.d_model
    print(f"uplink per decode step: fp32 {smashed_elems*4}B vs "
          f"int8 {smashed_elems + smashed_elems//128*4}B "
          f"({4/(1+4/128):.1f}x reduction)")


if __name__ == "__main__":
    main()
