"""Paper-faithful federation simulator: CL / FL / SL / SFL(fixed cut) / ASFL.

This engine reproduces the paper's Fig. 5 case study: ResNet18-class models,
4 vehicles, non-IID (6-of-10 labels, power-law sizes), lr 1e-4, batch 16,
local epochs 5.  The SFL message flow is realised explicitly — vehicle-side
forward, smashed-data upload, RSU-side forward/backward, cut-layer-gradient
download, vehicle-side backward — via jax.vjp, NOT one composite jax.grad,
so the implementation is structurally the paper's Fig. 3 workflow (their
mathematical equality is asserted in tests/test_sfl_math.py).

The engine is generic over a :class:`UnitModel` (any stack of units with a
head); ResNet18 (the paper's model) and the small transformer wrapper both
implement it.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, List, Optional, Protocol, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import adaptive, aggregation, channel, compression, cost
from repro.data.pipeline import ClientDataset
from repro import optim

Params = Any


class UnitModel(Protocol):
    name: str
    n_units: int

    def init(self, key) -> Tuple[List[Params], Params]: ...
    def apply_units(self, units: List[Params], x, start: int): ...
    def head_loss(self, head: Params, feats, labels): ...
    def head_predict(self, head: Params, feats): ...
    def profile(self) -> cost.SplitProfile: ...


class ResNetModel:
    """The paper's ResNet18 over 32x32x3 inputs."""
    name = "resnet18"

    def __init__(self, n_classes: int = 10):
        from repro.models import resnet as R
        self.R = R
        self.n_units = R.N_UNITS
        self.n_classes = n_classes

    def init(self, key):
        p = self.R.init_resnet18(key, self.n_classes)
        return list(p["units"]), p["head"]

    def apply_units(self, units, x, start):
        for j, u in enumerate(units):
            x = self.R._apply_unit(u, x, start + j)
        return x

    def head_loss(self, head, feats, labels):
        logits = jnp.mean(feats, axis=(1, 2)) @ head["w"] + head["b"]
        logz = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
        return jnp.mean(logz - gold), logits

    def head_predict(self, head, feats):
        return jnp.mean(feats, axis=(1, 2)) @ head["w"] + head["b"]

    def profile(self):
        return cost.resnet_profile()


@dataclasses.dataclass
class SimConfig:
    scheme: str = "asfl"          # cl | fl | sl | sfl | asfl
    cut: int = 4                  # fixed cut for sl/sfl
    n_clients: int = 4
    batch_size: int = 16          # paper: 16
    local_epochs: int = 5         # paper: 5
    local_steps: Optional[int] = None  # overrides epochs if set
    lr: float = 1e-4              # paper: 1e-4
    rounds: int = 10
    seed: int = 0
    optimizer: str = "adam"
    adaptive_strategy: str = "paper"   # paper | paper-literal | latency | energy
    compress_smashed: bool = False
    server_flops: float = 2e12    # RSU (GPU-class)
    round_interval_s: float = 5.0
    # mobility: vehicles outside RSU coverage at round start skip the round
    # (the paper's §II-C training-interruption challenge)
    mobility_dropout: bool = False


@dataclasses.dataclass
class RoundMetrics:
    round: int
    loss: float
    test_acc: float
    comm_bytes: float
    sim_time_s: float
    energy_j: float
    cuts: List[int]


def _make_opt(cfg: SimConfig):
    if cfg.optimizer == "adam":
        return optim.adam(cfg.lr)
    if cfg.optimizer == "sgd":
        return optim.sgd(cfg.lr)
    return optim.momentum(cfg.lr)


# --------------------------------------------------------------------------
# jitted batch steps
# --------------------------------------------------------------------------

def make_sfl_batch_step(model: UnitModel, cfg: SimConfig, cut: int):
    """One SFL batch for one client at a given cut (static).  Returns the
    explicit message-flow step (client fwd -> server fwd/bwd -> client bwd)."""
    opt = _make_opt(cfg)

    @jax.jit
    def step(client_units, server_units, head, c_opt, s_opt, batch):
        x, y = batch["images"], batch["labels"]

        def client_fwd(cu):
            return model.apply_units(cu, x, 0)

        smashed, client_vjp = jax.vjp(client_fwd, client_units)
        sm_in = compression.fake_quant(smashed) if cfg.compress_smashed else smashed

        def server_loss(sv, sm):
            feats = model.apply_units(sv["units"], sm, cut)
            loss, logits = model.head_loss(sv["head"], feats, y)
            return loss, logits

        sv_tree = {"units": server_units, "head": head}
        (loss, logits), grads = jax.value_and_grad(
            server_loss, argnums=(0, 1), has_aux=True)(sv_tree, sm_in)
        g_server, g_smashed = grads
        if cfg.compress_smashed:                    # downlink gradient, too
            g_smashed = compression.fake_quant(g_smashed)
        (g_client,) = client_vjp(g_smashed)

        upd_c, c_opt = opt.update(g_client, c_opt, client_units)
        client_units = optim.apply_updates(client_units, upd_c)
        upd_s, s_opt = opt.update(g_server, s_opt, sv_tree)
        sv_tree = optim.apply_updates(sv_tree, upd_s)
        acc = jnp.mean((jnp.argmax(logits, -1) == y).astype(jnp.float32))
        return client_units, sv_tree["units"], sv_tree["head"], c_opt, s_opt, loss, acc

    return step


def make_full_batch_step(model: UnitModel, cfg: SimConfig):
    """Full-model step (CL and FL local training)."""
    opt = _make_opt(cfg)

    @jax.jit
    def step(units, head, opt_state, batch):
        x, y = batch["images"], batch["labels"]

        def loss_fn(tree):
            feats = model.apply_units(tree["units"], x, 0)
            loss, logits = model.head_loss(tree["head"], feats, y)
            return loss, logits

        tree = {"units": units, "head": head}
        (loss, logits), g = jax.value_and_grad(loss_fn, has_aux=True)(tree)
        upd, opt_state = opt.update(g, opt_state, tree)
        tree = optim.apply_updates(tree, upd)
        acc = jnp.mean((jnp.argmax(logits, -1) == y).astype(jnp.float32))
        return tree["units"], tree["head"], opt_state, loss, acc

    return step


# --------------------------------------------------------------------------
# evaluation
# --------------------------------------------------------------------------

def evaluate(model: UnitModel, units, head, test: Dict[str, jnp.ndarray],
             batch: int = 256) -> float:
    n = test["labels"].shape[0]
    correct = total = 0
    for i in range(0, n, batch):
        x = test["images"][i:i + batch]
        y = test["labels"][i:i + batch]
        feats = model.apply_units(units, x, 0)
        logits = model.head_predict(head, feats)
        correct += int(jnp.sum(jnp.argmax(logits, -1) == y))
        total += int(y.size)
    return correct / max(total, 1)


# --------------------------------------------------------------------------
# the simulator
# --------------------------------------------------------------------------

class FederationSim:
    def __init__(self, model: UnitModel, clients: Sequence[ClientDataset],
                 test: Dict[str, jnp.ndarray], cfg: SimConfig,
                 fleet: Optional[List[channel.VehicleProfile]] = None,
                 ch_cfg: Optional[channel.ChannelConfig] = None):
        self.model = model
        self.clients = list(clients)
        self.test = test
        self.cfg = cfg
        self.fleet = fleet or channel.make_fleet(len(clients), cfg.seed)
        self.ch = ch_cfg or channel.ChannelConfig()
        self.profile = model.profile()
        key = jax.random.PRNGKey(cfg.seed)
        self.units, self.head = model.init(key)
        self._sfl_steps: Dict[int, Callable] = {}
        self._full_step = make_full_batch_step(model, cfg)
        self.history: List[RoundMetrics] = []

    # ---- helpers -----------------------------------------------------
    def _sfl_step(self, cut: int):
        if cut not in self._sfl_steps:
            self._sfl_steps[cut] = make_sfl_batch_step(self.model, self.cfg, cut)
        return self._sfl_steps[cut]

    def _local_steps(self, client: ClientDataset) -> int:
        if self.cfg.local_steps is not None:
            return self.cfg.local_steps
        nb = max(len(client) // self.cfg.batch_size, 1)
        return nb * self.cfg.local_epochs

    def _round_rates(self, rnd: int) -> np.ndarray:
        t = rnd * self.cfg.round_interval_s
        return channel.sample_round_rates(self.ch, self.fleet, t,
                                          self.cfg.seed * 1000 + rnd)

    def _participants(self, rnd: int) -> List[int]:
        """Vehicle indices in RSU coverage this round (all, if mobility
        dropout is disabled).  At least one vehicle always participates."""
        if not self.cfg.mobility_dropout:
            return list(range(len(self.clients)))
        t = rnd * self.cfg.round_interval_s
        inr = [ci for ci, v in enumerate(self.fleet)
               if channel.in_range(self.ch, v, t)]
        return inr or [0]

    def _pick_cuts(self, rates: np.ndarray) -> List[int]:
        c = self.cfg
        if c.scheme == "sfl" or c.scheme == "sl":
            return [c.cut] * len(self.clients)
        strat = c.adaptive_strategy
        if strat == "paper":
            return adaptive.paper_threshold(rates)
        if strat == "paper-literal":
            return adaptive.paper_threshold(rates, literal_eq3=True)
        flops = [v.compute_flops for v in self.fleet]
        nb = max(len(self.clients[0]) // c.batch_size, 1)
        if strat == "latency":
            return adaptive.latency_optimal(self.profile, rates, flops,
                                            c.server_flops, nb, c.batch_size,
                                            c.local_epochs)
        return adaptive.energy_aware(self.profile, rates, flops,
                                     c.server_flops, nb, c.batch_size,
                                     c.local_epochs)

    # ---- schemes -----------------------------------------------------
    def run(self) -> List[RoundMetrics]:
        for rnd in range(self.cfg.rounds):
            fn = getattr(self, f"_round_{self.cfg.scheme}")
            metrics = fn(rnd)
            self.history.append(metrics)
        return self.history

    def _metrics(self, rnd, losses, cuts, comm, time_s, energy) -> RoundMetrics:
        acc = evaluate(self.model, self.units, self.head, self.test)
        return RoundMetrics(rnd, float(np.mean(losses)), acc, comm, time_s,
                            energy, cuts)

    def _round_cl(self, rnd: int) -> RoundMetrics:
        # centralized: pool every client's raw data at the RSU (the upper
        # bound the paper argues against — raw-data upload included in comm)
        opt = _make_opt(self.cfg)
        if not hasattr(self, "_cl_opt"):
            self._cl_opt = opt.init({"units": self.units, "head": self.head})
        losses = []
        comm = 0.0
        for c in self.clients:
            for batch in c.batches(self.cfg.batch_size, self.cfg.seed + rnd):
                self.units, self.head, self._cl_opt, loss, _ = self._full_step(
                    self.units, self.head, self._cl_opt, batch)
                losses.append(float(loss))
            if rnd == 0:
                comm += c.images.nbytes
        return self._metrics(rnd, losses, [], comm, 0.0, 0.0)

    def _round_fl(self, rnd: int) -> RoundMetrics:
        cfgc = self.cfg
        opt = _make_opt(cfgc)
        rates = self._round_rates(rnd)
        participants = set(self._participants(rnd))
        client_trees, weights, losses = [], [], []
        comm = energy = 0.0
        latencies = []
        for ci, c in enumerate(self.clients):
            if ci not in participants:
                continue
            units, head = jax.tree.map(lambda a: a, (self.units, self.head))
            ostate = opt.init({"units": units, "head": head})
            steps = self._local_steps(c)
            for s in range(steps):
                batch = c.sample_batch(cfgc.batch_size, cfgc.seed + rnd * 997 + s)
                units, head, ostate, loss, _ = self._full_step(units, head,
                                                               ostate, batch)
                losses.append(float(loss))
            client_trees.append({"units": units, "head": head})
            weights.append(len(c))
            rc = cost.fl_client_round_cost(
                self.profile, max(len(c) // cfgc.batch_size, 1),
                cfgc.batch_size, rates[ci], self.fleet[ci].compute_flops,
                cfgc.local_epochs, self.fleet[ci].tx_power_w,
                self.fleet[ci].compute_power_w)
            comm += rc.comm_bytes
            energy += rc.energy_j
            latencies.append(rc.latency)
        avg = aggregation.fedavg(client_trees, weights)
        self.units, self.head = avg["units"], avg["head"]
        return self._metrics(rnd, losses, [], comm, max(latencies), energy)

    def _round_sl(self, rnd: int) -> RoundMetrics:
        """Vanilla sequential SL: the vehicle-side model travels from vehicle
        to vehicle; the RSU-side model trains continuously."""
        cfgc = self.cfg
        cut = cfgc.cut
        step = self._sfl_step(cut)
        opt = _make_opt(cfgc)
        client_units = self.units[:cut]
        server_units = self.units[cut:]
        head = self.head
        c_opt = opt.init(client_units)
        s_opt = opt.init({"units": server_units, "head": head})
        losses = []
        rates = self._round_rates(rnd)
        for ci, c in enumerate(self.clients):
            for s in range(self._local_steps(c)):
                batch = c.sample_batch(cfgc.batch_size, cfgc.seed + rnd * 991 + s)
                client_units, server_units, head, c_opt, s_opt, loss, _ = step(
                    client_units, server_units, head, c_opt, s_opt, batch)
                losses.append(float(loss))
        self.units = list(client_units) + list(server_units)
        self.head = head
        rc = cost.sl_round_cost(
            self.profile, cut,
            [max(len(c) // cfgc.batch_size, 1) for c in self.clients],
            cfgc.batch_size, rates, [v.compute_flops for v in self.fleet],
            cfgc.server_flops, cfgc.local_epochs)
        return self._metrics(rnd, losses, [cut] * len(self.clients),
                             rc.comm_bytes, rc.latency, rc.energy_j)

    def _round_sfl(self, rnd: int) -> RoundMetrics:
        return self._parallel_split_round(rnd)

    def _round_asfl(self, rnd: int) -> RoundMetrics:
        return self._parallel_split_round(rnd)

    def _parallel_split_round(self, rnd: int) -> RoundMetrics:
        """SFL/ASFL with SplitFed-V1 semantics: vehicle-side replicas train
        in parallel at (possibly heterogeneous) cuts while the RSU keeps ONE
        shared server-side model that is updated on every client batch (the
        RSU 'sequentially performs forward propagation ... with the received
        smashed data' — paper §III-B).  Round end: vehicle-side units are
        FedAvg'd (|D_n|-weighted) with the RSU copy of any unit it trained."""
        cfgc = self.cfg
        rates = self._round_rates(rnd)
        participants = set(self._participants(rnd))
        cuts = [max(1, min(c, self.model.n_units - 1))
                for c in self._pick_cuts(rates)]
        opt = _make_opt(cfgc)
        n_units = self.model.n_units

        # shared RSU-side state over the FULL stack (per-cut slices train).
        # Optimizer-state leaves mirror the {"units": [...], "head": ...}
        # params tree, so slicing at a cut = slicing the unit lists.
        server_units = [jax.tree.map(lambda a: a, u) for u in self.units]
        head = self.head
        s_opt_full = opt.init({"units": server_units, "head": head})

        def slice_opt(cut):
            out = {}
            for k, v in s_opt_full.items():
                if isinstance(v, dict) and "units" in v:
                    out[k] = {"units": v["units"][cut:], "head": v["head"]}
                else:
                    out[k] = v
            return out

        def merge_opt(new, cut):
            for k, v in new.items():
                if isinstance(v, dict) and "units" in v:
                    s_opt_full[k]["units"] = (
                        list(s_opt_full[k]["units"][:cut]) + list(v["units"]))
                    s_opt_full[k]["head"] = v["head"]
                else:
                    s_opt_full[k] = v
        # per-vehicle client-side replicas
        client_units = [[jax.tree.map(lambda a: a, u)
                         for u in self.units[:cut]] for cut in cuts]
        c_opts = [opt.init(cu) for cu in client_units]

        losses = []
        comm = energy = 0.0
        latencies = []
        steps = max(self._local_steps(c) for c in self.clients)
        for s in range(steps):
            for ci, c in enumerate(self.clients):
                if ci not in participants or s >= self._local_steps(c):
                    continue
                cut = cuts[ci]
                step = self._sfl_step(cut)
                batch = c.sample_batch(cfgc.batch_size,
                                       cfgc.seed + rnd * 983 + s * 31 + ci)
                sv = server_units[cut:]
                (client_units[ci], new_sv, head, c_opts[ci], new_s_opt,
                 loss, _) = step(client_units[ci], sv, head, c_opts[ci],
                                 slice_opt(cut), batch)
                server_units[cut:] = list(new_sv)
                merge_opt(new_s_opt, cut)
                losses.append(float(loss))

        # unit-wise FedAvg: vehicle replicas + the shared RSU copy
        unit_replicas: List[List[Params]] = [[] for _ in range(n_units)]
        unit_weights: List[List[float]] = [[] for _ in range(n_units)]
        for ci, c in enumerate(self.clients):
            if ci not in participants:
                continue
            w = float(len(c))
            for u in range(cuts[ci]):
                unit_replicas[u].append(client_units[ci][u])
                unit_weights[u].append(w)
        for u in range(n_units):
            served = sum(len(c) for ci, c in enumerate(self.clients)
                         if ci in participants and cuts[ci] <= u)
            if served:
                unit_replicas[u].append(server_units[u])
                unit_weights[u].append(float(served))
        merged = []
        for u in range(n_units):
            if unit_replicas[u]:
                merged.append(aggregation.fedavg(unit_replicas[u],
                                                 unit_weights[u]))
            else:
                merged.append(self.units[u])
        self.units = merged
        self.head = head

        for ci, c in enumerate(self.clients):
            if ci not in participants:
                continue
            rc = cost.sfl_client_round_cost(
                self.profile, cuts[ci], max(len(c) // cfgc.batch_size, 1),
                cfgc.batch_size, rates[ci], self.fleet[ci].compute_flops,
                cfgc.server_flops, cfgc.local_epochs,
                self.fleet[ci].tx_power_w, self.fleet[ci].compute_power_w)
            if cfgc.compress_smashed:
                ratio = compression.compression_ratio()
                rc = dataclasses.replace(
                    rc, comm_bytes_up=rc.comm_bytes_up / ratio,
                    comm_bytes_down=rc.comm_bytes_down / ratio,
                    t_comm=rc.t_comm / ratio)
            comm += rc.comm_bytes
            energy += rc.energy_j
            latencies.append(rc.latency)
        return self._metrics(rnd, losses, cuts, comm, max(latencies), energy)
