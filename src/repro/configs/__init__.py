"""Config registry: ``get_config("<arch-id>")`` for every assigned arch."""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import (  # noqa: F401  (re-exported)
    ArchConfig, MoEConfig, MLAConfig, SSMConfig, RGLRUConfig, ShapeConfig,
    INPUT_SHAPES, pad_vocab,
)

_MODULES = {
    "internvl2-1b": "repro.configs.internvl2_1b",
    "mamba2-780m": "repro.configs.mamba2_780m",
    "deepseek-v2-lite-16b": "repro.configs.deepseek_v2_lite_16b",
    "dbrx-132b": "repro.configs.dbrx_132b",
    "command-r-35b": "repro.configs.command_r_35b",
    "qwen3-14b": "repro.configs.qwen3_14b",
    "smollm-360m": "repro.configs.smollm_360m",
    "musicgen-large": "repro.configs.musicgen_large",
    "gemma3-4b": "repro.configs.gemma3_4b",
    "recurrentgemma-2b": "repro.configs.recurrentgemma_2b",
}

ARCH_IDS: List[str] = list(_MODULES)


def get_config(name: str) -> ArchConfig:
    if name.endswith("-smoke"):
        return get_config(name[: -len("-smoke")]).reduced()
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {ARCH_IDS}")
    return importlib.import_module(_MODULES[name]).CONFIG


def all_configs() -> Dict[str, ArchConfig]:
    return {k: get_config(k) for k in ARCH_IDS}
