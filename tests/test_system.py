"""End-to-end behaviour tests: every federation scheme runs; the compiled
datacenter SFL step trains; split inference decodes consistently."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import distributed as D
from repro.core.fedsim import FederationSim, ResNetModel, SimConfig
from repro.data.pipeline import make_federated_data
from repro.launch import mesh as MX
from repro.models import transformer as T


@pytest.fixture(scope="module")
def fed_data():
    return make_federated_data(0, n_train=256, n_test=128, n_clients=4)


@pytest.mark.parametrize("scheme", ["cl", "fl", "sl", "sfl", "asfl"])
def test_all_schemes_run_one_round(fed_data, scheme):
    clients, test = fed_data
    cfg = SimConfig(scheme=scheme, rounds=1, local_steps=2, lr=1e-3,
                    batch_size=8)
    sim = FederationSim(ResNetModel(), clients, test, cfg)
    hist = sim.run()
    assert len(hist) == 1
    m = hist[0]
    assert np.isfinite(m.loss)
    assert 0.0 <= m.test_acc <= 1.0
    if scheme not in ("cl",):
        assert m.comm_bytes > 0
        assert m.sim_time_s > 0


def test_asfl_adapts_cuts_to_rates(fed_data):
    clients, test = fed_data
    cfg = SimConfig(scheme="asfl", rounds=2, local_steps=1, batch_size=8)
    sim = FederationSim(ResNetModel(), clients, test, cfg)
    hist = sim.run()
    for m in hist:
        assert all(c in (2, 4, 6, 8) for c in m.cuts)


def test_memory_constrained_strategy_clamps_cuts(fed_data):
    """adaptive_strategy='memory': per-vehicle memory budgets upper-bound
    the vehicle-side sub-model (then the paper rule applies underneath)."""
    from repro.core import adaptive, channel
    from repro.core.cost import resnet_profile
    clients, test = fed_data
    budgets = [1e4, 4e5, float("inf"), float("inf")]
    fleet = channel.make_fleet(4, seed=0)
    for v, b in zip(fleet, budgets):
        v.memory_budget_bytes = b
    cfg = SimConfig(scheme="asfl", adaptive_strategy="memory", rounds=1,
                    local_steps=1, batch_size=8)
    sim = FederationSim(ResNetModel(), clients, test, cfg, fleet=fleet)
    hist = sim.run()
    max_cuts = adaptive.max_cut_for_budget(resnet_profile(), budgets)
    cuts = hist[0].cuts
    assert all(c <= m for c, m in zip(cuts, max_cuts))
    assert cuts[0] == 1                      # 10 KB: only the stem fits
    assert np.isfinite(hist[0].loss)


def test_compressed_sfl_reduces_comm(fed_data):
    clients, test = fed_data
    base = SimConfig(scheme="sfl", rounds=1, local_steps=1, batch_size=8)
    comp = SimConfig(scheme="sfl", rounds=1, local_steps=1, batch_size=8,
                     compress_smashed=True)
    h0 = FederationSim(ResNetModel(), clients, test, base).run()
    h1 = FederationSim(ResNetModel(), clients, test, comp).run()
    assert h1[0].comm_bytes < h0[0].comm_bytes
    assert np.isfinite(h1[0].loss)


def test_datacenter_train_step_learns():
    """The compiled sync-SFL step must overfit a fixed batch."""
    cfg = get_config("smollm-360m").reduced()
    opts = D.DistOptions(cut=1, learning_rate=1e-2, optimizer="adam")
    key = jax.random.PRNGKey(0)
    state = D.init_state(key, cfg, opts)
    step = jax.jit(D.make_train_step(cfg, opts))
    b, s = 4, 32
    toks = jax.random.randint(key, (b, s + 1), 0, cfg.vocab_size)
    batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:],
             "weights": jnp.asarray([4.0, 2.0, 1.0, 1.0])}
    state, m0 = step(state, batch)
    for _ in range(15):
        state, m = step(state, batch)
    assert float(m["loss"]) < float(m0["loss"])


def test_datacenter_compressed_step_runs():
    cfg = get_config("smollm-360m").reduced()
    opts = D.DistOptions(cut=1, compress_smashed=True)
    key = jax.random.PRNGKey(0)
    state = D.init_state(key, cfg, opts)
    step = jax.jit(D.make_train_step(cfg, opts))
    toks = jax.random.randint(key, (2, 17), 0, cfg.vocab_size)
    batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:],
             "weights": jnp.ones((2,))}
    state, m = step(state, batch)
    assert np.isfinite(float(m["loss"]))


def test_split_inference_prefill_decode_consistency():
    """Split-inference serving (prefill + decode at a cut) must reproduce the
    unsplit teacher-forced logits."""
    cfg = get_config("gemma3-4b").reduced()
    opts = D.DistOptions(cut=2)
    key = jax.random.PRNGKey(1)
    params = T.init_params(key, cfg)
    s, cap = 24, 32
    toks = jax.random.randint(key, (2, s), 0, cfg.vocab_size)
    full, _, _ = T.forward(params, cfg, {"tokens": toks}, "train")
    prefill = jax.jit(D.make_prefill_step(cfg, opts, cap))
    decode = jax.jit(D.make_decode_step(cfg, opts, cap))
    last, caches = prefill(params, {"tokens": toks[:, :s - 1]})
    np.testing.assert_allclose(np.asarray(last[:, 0]), np.asarray(full[:, -2]),
                               rtol=2e-4, atol=2e-4)
    logits, caches = decode(params, {"tokens": toks[:, s - 1:]}, caches,
                            jnp.asarray(s - 1))
    np.testing.assert_allclose(np.asarray(logits[:, 0]),
                               np.asarray(full[:, -1]), rtol=2e-4, atol=2e-4)


@pytest.mark.skipif(not hasattr(jax.sharding, "AxisType"),
                    reason="jax.sharding.AxisType requires jax >= 0.5")
def test_mesh_spec_rules():
    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    spec = MX.spec_for((256, 512), mesh, fsdp=False)
    assert spec is not None
    # tiny leaves replicate
    assert MX.spec_for((8,), mesh) == jax.sharding.PartitionSpec(None)
