"""Cohort-engine parity: the vectorized round (CohortEngine) must reproduce
the seed per-client Python loop's loss/accuracy trajectory.

The reference below is the seed's `_parallel_split_round` verbatim (per-client
jit dispatch, `float(loss)` host sync per batch, slice/merge optimizer-state
surgery, Python-list unit-wise FedAvg), with one defined difference: clients
are visited in the engine's bucket order (ascending cut, then client index)
instead of raw client order.  For fixed-cut SFL the two orders coincide, so
that case is parity against the literal seed.  See DESIGN.md §6.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.core import adaptive, aggregation, channel
from repro.core.fedsim import (FederationSim, ResNetModel, SimConfig,
                               _make_opt, evaluate, make_sfl_batch_step)
from repro.data.pipeline import make_federated_data
from repro import optim


# ------------------------------------------------------------------ reference
def _seed_loop_split_round(model, cfg, clients, fleet, ch, units, head, rnd,
                           sfl_steps):
    """The seed FederationSim._parallel_split_round, bucket-ordered."""
    t = rnd * cfg.round_interval_s
    rates = channel.sample_round_rates(ch, fleet, t, cfg.seed * 1000 + rnd)
    if cfg.scheme in ("sfl", "sl"):
        cuts = [cfg.cut] * len(clients)
    else:
        cuts = adaptive.paper_threshold(rates)
    cuts = [max(1, min(c, model.n_units - 1)) for c in cuts]
    participants = set(range(len(clients)))
    opt = _make_opt(cfg)
    n_units = model.n_units

    server_units = [jax.tree.map(lambda a: a, u) for u in units]
    s_head = head
    s_opt_full = opt.init({"units": server_units, "head": s_head})

    def slice_opt(cut):
        out = {}
        for k, v in s_opt_full.items():
            if isinstance(v, dict) and "units" in v:
                out[k] = {"units": v["units"][cut:], "head": v["head"]}
            else:
                out[k] = v
        return out

    def merge_opt(new, cut):
        for k, v in new.items():
            if isinstance(v, dict) and "units" in v:
                s_opt_full[k]["units"] = (
                    list(s_opt_full[k]["units"][:cut]) + list(v["units"]))
                s_opt_full[k]["head"] = v["head"]
            else:
                s_opt_full[k] = v

    client_units = [[jax.tree.map(lambda a: a, u)
                     for u in units[:cut]] for cut in cuts]
    c_opts = [opt.init(cu) for cu in client_units]

    def local_steps(c):
        if cfg.local_steps is not None:
            return cfg.local_steps
        return max(len(c) // cfg.batch_size, 1) * cfg.local_epochs

    # engine visit order: buckets ascending by cut, clients ascending inside
    order = sorted(participants, key=lambda ci: (cuts[ci], ci))
    losses = []
    steps = max(local_steps(c) for c in clients)
    for s in range(steps):
        for ci in order:
            c = clients[ci]
            if s >= local_steps(c):
                continue
            cut = cuts[ci]
            if cut not in sfl_steps:
                sfl_steps[cut] = make_sfl_batch_step(model, cfg, cut)
            step = sfl_steps[cut]
            batch = c.sample_batch(cfg.batch_size,
                                   cfg.seed + rnd * 983 + s * 31 + ci)
            sv = server_units[cut:]
            (client_units[ci], new_sv, s_head, c_opts[ci], new_s_opt,
             loss, _) = step(client_units[ci], sv, s_head, c_opts[ci],
                             slice_opt(cut), batch)
            server_units[cut:] = list(new_sv)
            merge_opt(new_s_opt, cut)
            losses.append(float(loss))

    unit_replicas = [[] for _ in range(n_units)]
    unit_weights = [[] for _ in range(n_units)]
    for ci, c in enumerate(clients):
        w = float(len(c))
        for u in range(cuts[ci]):
            unit_replicas[u].append(client_units[ci][u])
            unit_weights[u].append(w)
    for u in range(n_units):
        served = sum(len(c) for ci, c in enumerate(clients) if cuts[ci] <= u)
        if served:
            unit_replicas[u].append(server_units[u])
            unit_weights[u].append(float(served))
    merged = [aggregation.fedavg(unit_replicas[u], unit_weights[u])
              if unit_replicas[u] else units[u] for u in range(n_units)]
    return merged, s_head, losses, cuts


def _run_reference(model, cfg, clients, fleet, ch, rounds):
    units, head = model.init(jax.random.PRNGKey(cfg.seed))
    sfl_steps = {}
    round_losses, all_cuts = [], []
    for rnd in range(rounds):
        units, head, losses, cuts = _seed_loop_split_round(
            model, cfg, clients, fleet, ch, units, head, rnd, sfl_steps)
        round_losses.append(float(np.mean(losses)))
        all_cuts.append(cuts)
    return units, head, round_losses, all_cuts


def _tree_allclose(a, b, atol):
    ok = []
    jax.tree.map(lambda x, y: ok.append(
        np.allclose(np.asarray(x), np.asarray(y), atol=atol, rtol=1e-3)),
        a, b)
    return all(ok)


@pytest.fixture(scope="module")
def small_fed():
    return make_federated_data(0, n_train=128, n_test=96, n_clients=4)


# SGD parity is exact up to fp reassociation (~1e-7 on params after a full
# round).  Adam's eps=1e-8 amplifies 1e-6-level XLA-fusion noise into
# lr-sized update flips wherever |grad| ~ 0, so its trajectory tolerance is
# necessarily looser — the drift is fp chaos, not an engine/seed semantic
# difference (verified by the sgd rows of this very test).
@pytest.mark.parametrize("scheme,optimizer,loss_tol,param_atol,acc_tol", [
    ("sfl", "sgd", 1e-4, 1e-5, 0.02),
    # param_atol=None: adam's chaotic per-parameter drift makes elementwise
    # comparison meaningless at round 2; trajectory+accuracy carry the check
    ("sfl", "adam", 3e-2, None, 0.05),
    ("asfl", "adam", 3e-2, None, 0.05),
])
def test_engine_matches_seed_loop(small_fed, scheme, optimizer, loss_tol,
                                  param_atol, acc_tol):
    clients, test = small_fed
    cfg = SimConfig(scheme=scheme, cut=4, rounds=2, local_steps=2,
                    lr=1e-3, batch_size=8, optimizer=optimizer)
    sim = FederationSim(ResNetModel(), clients, test, cfg)
    hist = sim.run()

    ref_units, ref_head, ref_losses, ref_cuts = _run_reference(
        sim.model, cfg, clients, sim.fleet, sim.ch, cfg.rounds)

    # same cut decisions, same loss trajectory, same final model
    assert [m.cuts for m in hist] == ref_cuts
    eng_losses = [m.loss for m in hist]
    np.testing.assert_allclose(eng_losses, ref_losses, rtol=loss_tol,
                               atol=loss_tol)
    if param_atol is not None:
        assert _tree_allclose(sim.units, ref_units, atol=param_atol)
        assert _tree_allclose(sim.head, ref_head, atol=param_atol)

    ref_acc = evaluate(sim.model, ref_units, ref_head, test)
    assert abs(hist[-1].test_acc - ref_acc) <= acc_tol


@pytest.mark.parametrize("mode", ["scan", "vmap"])
def test_schedules_agree_with_unroll(small_fed, mode):
    """The three intra-bucket schedules compute the same round (fp tol)."""
    clients, test = small_fed
    base = SimConfig(scheme="sfl", cut=5, rounds=1, local_steps=1,
                     lr=1e-3, batch_size=4, eval_every=0, optimizer="sgd",
                     cohort_parallel="unroll")
    ref = FederationSim(ResNetModel(), clients, test, base)
    ref.run()
    alt = FederationSim(ResNetModel(), clients, test,
                        dataclasses.replace(base, cohort_parallel=mode))
    alt.run()
    np.testing.assert_allclose(alt.history[0].loss, ref.history[0].loss,
                               rtol=1e-4, atol=1e-4)
    assert _tree_allclose(alt.units, ref.units, atol=1e-4)
