"""City-scale scale-out benchmark: rounds/s on the ``city`` scenario vs
device count (DESIGN.md §15).

The ``city`` scenario is the scale-out fixture: a ``grid_x x grid_y`` RSU
lattice (hundreds of cells) with a Zipf cell-popularity fleet in the
thousands, eccentric-orbit mobility, and geometric coverage gaps.  Every row
is one ``repro.api.run(ExperimentSpec)`` on the ragged super-step layout and
asserts ``compile_fallbacks == 0`` — across mobility churn, slot paging, and
every mesh shape, nothing recompiles mid-run.

Row families:

* **device sweep** — each ``--devices`` count (forced host-platform devices
  on CPU, parsed pre-jax-import by ``bench_devices``) runs each ``--sizes``
  fleet on the 2-D ``(rsu, vehicle)`` mesh (``fleet_axis="grid"`` by
  default), reporting rounds/s for the scaling curve.  Honesty note: forced
  host devices SPLIT the host's cores — on a 1-2 core container the
  multi-device rows measure sharding overhead, not speedup; near-linear
  scaling is only observable when real cores/accelerators back the devices.
  The per-device-count rows of one run remain mutually comparable and the
  provenance block records the split.
* **paged row** — the largest fleet re-runs with ``--page-slots`` bounding
  the per-device *concurrent* slot window; the row asserts the planned slot
  block genuinely exceeds one window (``slot_windows > 1``) so the paging
  carry loop is actually exercised, and that its loss trajectory matches
  the unpaged twin bit-for-bit (paging changes peak footprint, not math).

  PYTHONPATH=src python benchmarks/bench_city.py --devices 1,8
  -> BENCH_city.json (repo root) + benchmarks/out/BENCH_city.json

``--check-baseline BASELINE.json [--max-regress 0.30]`` compares rounds/s
rows against a committed baseline (the CI perf smoke); rows missing from
the baseline are skipped gracefully.
"""
from __future__ import annotations

import argparse
import gc
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

from bench_devices import parse_devices_early

# --devices N[,M,...]: forced host device count must precede any jax import
DEVICE_COUNTS = parse_devices_early()

import jax
import numpy as np

from bench_io import device_row_key, write_bench
from repro import api
from repro.configs.base import cache_dir_is_warm


def _spec(args, n: int, devices: int, page: int) -> api.ExperimentSpec:
    gx, gy = (int(s) for s in args.grid.split("x"))
    stream = (api.StreamConfig(churn_source="mobility")
              if args.churn == "mobility" else api.StreamConfig())
    return api.ExperimentSpec(
        model="mlp9",
        train=api.TrainConfig(scheme="asfl", rounds=args.rounds,
                              local_steps=args.local_steps,
                              batch_size=args.batch, lr=1e-3, eval_every=0,
                              optimizer="sgd",
                              server_schedule=args.schedule),
        adaptive=api.AdaptiveConfig(strategy=args.strategy),
        stream=stream,
        fleet=api.FleetConfig(n_vehicles=n, scenario="city",
                              scenario_kwargs={"seed": n, "grid_x": gx,
                                               "grid_y": gy},
                              cloud_sync_every=args.sync,
                              round_interval_s=10.0,
                              per_vehicle_samples=args.samples,
                              data_seed=n),
        runtime=api.RuntimeConfig(superstep=args.superstep,
                                  superstep_layout="ragged",
                                  precompile=True,
                                  mesh_devices=devices,
                                  fleet_axis=args.fleet_axis,
                                  page_slots=page,
                                  compilation_cache_dir=args.compilation_cache))


def bench_one(args, n: int, devices: int, page: int = 0) -> dict:
    res = api.run(_spec(args, n, devices, page), timeit=args.timeit)
    assert all(np.isfinite(m.loss) for m in res.history)
    # zero retraces across mobility churn, paging windows, and mesh shapes:
    # presence and page position are carried data, never a signature
    assert res.diagnostics["compile_fallbacks"] == 0
    occ = res.diagnostics["occupancy"]
    # concurrent slot windows per device the paged sweep walks (1 = the
    # whole block fits one window, i.e. paging is off or trivial)
    per_dev = -(-occ["executed_slots"] // max(devices, 1))
    windows = -(-per_dev // page) if page > 0 else 1
    return {
        "scenario": "city", "n_vehicles": n, "devices": devices,
        "grid": args.grid, "n_rsus": res.diagnostics["n_rsus"],
        "schedule": args.schedule, "superstep": args.superstep,
        "rounds": args.rounds, "churn_source": args.churn,
        "mesh_shape": res.diagnostics["mesh_shape"],
        "page_slots": page, "slot_windows": int(windows),
        "executed_slots": occ["executed_slots"],
        "mean_occupied_slots": occ["mean_occupied_slots"],
        "padded_slot_frac": occ["padded_slot_frac"],
        "round_s": res.timing["round_s"],
        "rounds_per_s": res.timing["rounds_per_s"],
        "warmup_s": res.timing["warmup_s"],
        "scheduled_per_round": [m.n_scheduled for m in res.history],
        "final_loss": float(res.history[-1].loss),
        "losses": [float(m.loss) for m in res.history],
    }


def check_baseline(out: dict, baseline_path: str, max_regress: float) -> int:
    """Exit status for the CI perf smoke: 1 if any matching row's rounds/s
    dropped more than ``max_regress`` below the baseline."""
    if not os.path.exists(baseline_path):
        print(f"baseline {baseline_path} missing; skipping perf check")
        return 0
    with open(baseline_path) as f:
        base = json.load(f)
    keys = ("local_steps", "batch", "rounds", "strategy", "superstep",
            "schedule", "grid", "churn", "samples", "fleet_axis")
    mismatch = {k: (base.get("config", {}).get(k), out["config"].get(k))
                for k in keys
                if base.get("config", {}).get(k) != out["config"].get(k)}
    if mismatch:
        print(f"baseline config mismatch {mismatch}; skipping perf check "
              f"(regenerate {baseline_path})")
        return 0

    def _perf_key(r):
        key = device_row_key(f"city@{r['n_vehicles']}", r["devices"])
        if r.get("page_slots"):
            key += f"+page{r['page_slots']}"
        return key

    base_rows = {_perf_key(r): r["rounds_per_s"]
                 for r in base.get("results", [])}
    failures = []
    for row in out["results"]:
        key = _perf_key(row)
        if key not in base_rows:
            print(f"no baseline row for {key}; skipping")
            continue
        floor = base_rows[key] * (1.0 - max_regress)
        status = "OK" if row["rounds_per_s"] >= floor else "REGRESSION"
        print(f"perf {key}: {row['rounds_per_s']:.2f} r/s vs baseline "
              f"{base_rows[key]:.2f} (floor {floor:.2f}) {status}")
        if row["rounds_per_s"] < floor:
            failures.append(key)
    if failures:
        print(f"perf regression >{max_regress:.0%} in rows: {failures}")
        return 1
    return 0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--sizes", default="4096",
                    help="fleet sizes per device count (city is built for "
                         "4k-100k vehicles)")
    ap.add_argument("--grid", default="16x16",
                    help="RSU lattice as GXxGY (256 cells default)")
    ap.add_argument("--rounds", type=int, default=4)
    ap.add_argument("--local-steps", type=int, default=1)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--samples", type=int, default=16,
                    help="training samples per vehicle (kept small so the "
                         "staged data for a 4k+ fleet fits the container)")
    ap.add_argument("--strategy", default="paper")
    ap.add_argument("--sync", type=int, default=1)
    ap.add_argument("--superstep", type=int, default=4)
    ap.add_argument("--schedule", default="parallel",
                    choices=["parallel", "streaming"],
                    help="paging targets the ragged compacted layouts")
    ap.add_argument("--churn", default="mobility",
                    choices=["markov", "mobility"],
                    help="mobility: presence follows the scenario's "
                         "coverage gaps (stream_churn_source)")
    ap.add_argument("--fleet-axis", default="grid",
                    choices=["auto", "rsu", "grid", "vehicle"])
    ap.add_argument("--page-slots", type=int, default=128,
                    help="per-device concurrent slot window for the paged "
                         "row (0 skips it)")
    ap.add_argument("--devices", default="1", metavar="N[,M...]",
                    help="device counts to bench (2-D mesh rows; on CPU "
                         "the host device count is forced pre-import — "
                         "parsed by bench_devices before jax loads)")
    ap.add_argument("--compilation-cache", default=None, metavar="DIR")
    ap.add_argument("--timeit", type=int, default=2,
                    help="timed compile-free re-runs per row (min wins)")
    ap.add_argument("--check-baseline", default=None, metavar="JSON")
    ap.add_argument("--max-regress", type=float, default=0.30)
    ap.add_argument("--no-write", action="store_true")
    args = ap.parse_args()

    cache_hit = cache_dir_is_warm(args.compilation_cache)
    sizes = [int(s) for s in args.sizes.split(",")]
    results = []
    for devices in DEVICE_COUNTS:
        for n in sizes:
            gc.collect()
            row = bench_one(args, n, devices)
            results.append(row)
            print(f"city n={n:6d} dev={devices} mesh={row['mesh_shape']} "
                  f"rsus={row['n_rsus']} slots={row['executed_slots']} "
                  f"warmup={row['warmup_s']:6.1f}s "
                  f"round={row['round_s']*1e3:9.1f} ms "
                  f"({row['rounds_per_s']:.2f} rounds/s)", flush=True)

    if args.page_slots > 0:
        # paged twin of the largest fleet at the top device count: the
        # planned per-device slot block must exceed one window (the paging
        # loop actually runs) and the math must not move
        n, devices = max(sizes), DEVICE_COUNTS[-1]
        gc.collect()
        row = bench_one(args, n, devices, page=args.page_slots)
        results.append(row)
        assert row["slot_windows"] > 1, (
            f"page_slots={args.page_slots} does not page: the per-device "
            f"block ({row['executed_slots']} / {devices} slots) fits one "
            f"window — lower --page-slots or raise the fleet")
        twin = next(r for r in results
                    if r["n_vehicles"] == n and r["devices"] == devices
                    and not r["page_slots"])
        assert row["losses"] == twin["losses"], (
            "paged run diverged from its unpaged twin")
        print(f"city n={n:6d} dev={devices} PAGED window={args.page_slots} "
              f"({row['slot_windows']} windows/device) "
              f"round={row['round_s']*1e3:9.1f} ms "
              f"({row['rounds_per_s']:.2f} rounds/s) "
              f"losses match unpaged twin", flush=True)

    out = {
        "config": {"sizes": sizes, "grid": args.grid, "rounds": args.rounds,
                   "local_steps": args.local_steps, "batch": args.batch,
                   "samples": args.samples, "strategy": args.strategy,
                   "cloud_sync_every": args.sync,
                   "superstep": args.superstep, "schedule": args.schedule,
                   "churn": args.churn, "fleet_axis": args.fleet_axis,
                   "page_slots": args.page_slots,
                   "timeit": args.timeit,
                   "devices": list(DEVICE_COUNTS),
                   "compilation_cache": args.compilation_cache,
                   "backend": jax.default_backend(),
                   # forced host devices SPLIT these cores: scaling rows
                   # are honest only when host_cpus >= devices
                   "host_cpus": len(os.sched_getaffinity(0)),
                   "driver": "repro.api.run"},
        "warmup_total_s": float(sum(r["warmup_s"] for r in results)),
        "compile_cache_hit": cache_hit,
        "rounds_per_s": {
            device_row_key(f"city@{r['n_vehicles']}", r["devices"])
            + (f"+page{r['page_slots']}" if r["page_slots"] else ""):
            r["rounds_per_s"] for r in results},
        "results": results,
    }
    if not args.no_write:
        write_bench("BENCH_city", out, "benchmarks/bench_city.py")
        print(f"(warmup_total_s={out['warmup_total_s']:.1f}, "
              f"cache_hit={cache_hit})")

    if args.check_baseline:
        sys.exit(check_baseline(out, args.check_baseline, args.max_regress))


if __name__ == "__main__":
    main()
