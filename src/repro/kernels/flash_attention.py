"""Flash attention for TPU (Pallas): blocked online-softmax, causal +
sliding-window masks, GQA via kv-head index mapping.

Grid = (batch, q_heads, num_q_blocks, num_k_blocks) with the k dimension
innermost: TPU grids iterate sequentially, so the (m, l, o) accumulators live
in VMEM scratch and carry across k steps — the canonical TPU flash schedule.
Fully-masked k blocks are skipped with ``pl.when`` (no compute, no VMEM
traffic beyond the prefetched tiles).

Block shapes are MXU-aligned: block_q x head_dim and block_k x head_dim tiles
with head_dim in {64, 128, 256} (all assigned architectures).  Validated on
CPU in interpret mode against ref.py (tests/test_kernels.py sweeps shapes,
dtypes, causal/window).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1.0e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
               block_q: int, block_k: int, seq_k: int, causal: bool,
               window: int, scale: float):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * block_q
    k_start = ki * block_k

    # visibility: does this k block intersect the allowed span?
    run = True
    if causal:
        run = k_start <= q_start + block_q - 1
    if window > 0:
        # earliest visible k for the last q row is q_end - window + 1
        pass  # handled in-mask; block-level skip for causal only

    def body():
        q = q_ref[0, 0].astype(jnp.float32)       # (block_q, d)
        k = k_ref[0, 0].astype(jnp.float32)       # (block_k, d)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (bq, bk)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = kpos < seq_k
        if causal:
            mask &= kpos <= qpos
        if window > 0:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_cur = jnp.max(s, axis=-1, keepdims=True)  # (bq, 1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new
        l_scr[...] = l_new

    if causal:
        pl.when(run)(body)
    else:
        body()

    @pl.when(ki == nk - 1)
    def _finish():
        l = l_scr[...]
        safe = jnp.where(l > 0.0, l, 1.0)
        o_ref[0, 0] = (acc_scr[...] / safe).astype(o_ref.dtype)


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    scale: float | None = None,
                    interpret: bool = False) -> jnp.ndarray:
    """q (b, sq, h, d); k/v (b, sk, kv, d); GQA when h > kv.  Returns
    (b, sq, h, d).  sq/sk are padded to block multiples internally."""
    b, sq, h, d = q.shape
    _, sk, kv, _ = k.shape
    assert h % kv == 0
    group = h // kv
    scale = scale if scale is not None else 1.0 / math.sqrt(d)

    block_q = min(block_q, max(sq, 16))
    block_k = min(block_k, max(sk, 16))
    pq = (-sq) % block_q
    pk = (-sk) % block_k
    qp = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0))) if pq else q
    kp = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0))) if pk else k
    vp = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0))) if pk else v
    # layout: (b, heads, seq, d) blocks
    qp = qp.swapaxes(1, 2)
    kp = kp.swapaxes(1, 2)
    vp = vp.swapaxes(1, 2)
    nq = qp.shape[2] // block_q
    nk = kp.shape[2] // block_k

    grid = (b, h, nq, nk)
    out = pl.pallas_call(
        functools.partial(_fa_kernel, block_q=block_q, block_k=block_k,
                          seq_k=sk, causal=causal, window=window, scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda bi, hi, qi, ki, g=group: (bi, hi // g, ki, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda bi, hi, qi, ki, g=group: (bi, hi // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct(qp.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(qp, kp, vp)
    out = out.swapaxes(1, 2)
    if pq:
        out = out[:, :sq]
    return out
