"""String-keyed registries behind the declarative experiment layer.

Four registries unify what the three federation engines can execute, so an
:class:`~repro.api.spec.ExperimentSpec` is pure data (strings + numbers) and
every capability a future PR lands plugs in by registering an entry instead
of growing a fourth bespoke loop:

* :data:`MODELS` — ``UnitModel`` builders paired with a matching fleet-data
  builder: the paper's ``resnet18``, the dispatch-bound ``mlp9`` split MLP,
  and every ``TransformerUnitModel``-eligible architecture config (text
  archs, ``frontend == "none"``).  Arch entries build the **reduced** config
  by default (vehicle-side perception scale — the federation simulator's
  regime; pass ``model_kwargs={"reduced": False}`` for the full stack, which
  is datacenter-sized).
* :data:`SCENARIOS` — reuses :data:`repro.core.scenario.SCENARIOS` and adds
  ``"single_rsu"`` (the :class:`~repro.core.fedsim.FederationSim` drive-by
  channel, equivalent to ``fleet.scenario=None``).
* :data:`STRATEGIES` — every ``adaptive.*`` cut strategy, tagged with the
  engines that can execute it (the fused multi-RSU engine runs cut selection
  on-device, so only traced strategies carry the ``"scenario"`` tag).
* :data:`SCHEDULES` — RSU server schedules (paper §III-B ``sequential``,
  arXiv:2405.18707 ``parallel``).

Spec construction validates against these registries and raises actionable
errors (allowed values listed) instead of failing deep inside engine
dispatch.  Model/scenario *builders* are lazy: registering is metadata-only,
heavy imports happen when :func:`build_model`/:func:`build_scenario` run.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

from repro.core import scenario as _scenario
from repro.core.fedsim import (FEDERATION_STRATEGIES, SCENARIO_STRATEGIES,
                               SERVER_SCHEDULES, WIRE_SCHEMES)

# engine kinds an entry can be executed by
FEDERATION = "federation"   # single-RSU FederationSim / CohortEngine
SCENARIO = "scenario"       # multi-RSU ScenarioEngine (fused super-steps)

SINGLE_RSU = "single_rsu"   # the scenario key that routes to FederationSim


# --------------------------------------------------------------------------
# models
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ModelEntry:
    """A federated model: lazy ``UnitModel`` builder + the fleet-data
    builder that produces compatible client shards.

    ``make_data(n_vehicles, per_vehicle, n_test, seed)`` must be a pure
    function of its arguments (benchmark warm re-runs and the api-vs-direct
    parity tests rely on identical shards)."""
    name: str
    build: Callable[..., Any]
    make_data: Callable[[int, int, int, int], Tuple[list, dict]]
    n_units: int
    description: str = ""


MODELS: Dict[str, ModelEntry] = {}


def register_model(entry: ModelEntry) -> ModelEntry:
    MODELS[entry.name] = entry
    return entry


def model_entry(name: str) -> ModelEntry:
    if name not in MODELS:
        raise ValueError(f"unknown model {name!r}; registered models: "
                         f"{' | '.join(sorted(MODELS))}")
    return MODELS[name]


def build_model(name: str, **kwargs):
    return model_entry(name).build(**kwargs)


def _build_resnet(**kw):
    from repro.core.fedsim import ResNetModel
    return ResNetModel(**kw)


def _resnet_data(n_vehicles, per_vehicle, n_test, seed):
    from repro.data.pipeline import make_federated_data
    return make_federated_data(seed, n_train=per_vehicle * n_vehicles,
                               n_test=n_test, n_clients=n_vehicles)


def _build_mlp9(**kw):
    from repro.models.mlp_unit import MLPUnitModel
    return MLPUnitModel(**kw)


def _mlp9_data(n_vehicles, per_vehicle, n_test, seed):
    from repro.models.mlp_unit import make_mlp_fleet_data
    return make_mlp_fleet_data(n_vehicles, per_vehicle, seed=seed,
                               n_test=n_test)


def make_lm_fleet_data(n_vehicles: int, per_vehicle: int, n_test: int,
                       seed: int, vocab_size: int, seq_len: int = 8):
    """Synthetic next-token shards for the LM UnitModels: ``images`` are
    token ids (n, seq), ``labels`` the shifted next tokens — the fedsim
    batch convention (core/lm_unit.py)."""
    import jax.numpy as jnp
    import numpy as np

    from repro.data.pipeline import ClientDataset

    rng = np.random.default_rng(seed)

    def shard(n):
        toks = rng.integers(0, vocab_size, size=(n, seq_len + 1))
        return (toks[:, :-1].astype(np.int32), toks[:, 1:].astype(np.int32))

    clients = []
    for i in range(n_vehicles):
        x, y = shard(per_vehicle)
        clients.append(ClientDataset(x, y, i))
    xt, yt = shard(n_test)
    return clients, {"images": jnp.asarray(xt), "labels": jnp.asarray(yt)}


def _arch_model_entry(arch_id: str) -> ModelEntry:
    from repro.configs import get_config
    cfg = get_config(arch_id)
    reduced = cfg.reduced()
    # unit granularity (core/lm_unit.py): embedding + one unit per period
    n_units = 1 + reduced.n_periods + (1 if reduced.tail else 0)

    def build(reduced: bool = True):
        from repro.configs import get_config
        from repro.core.lm_unit import TransformerUnitModel
        c = get_config(arch_id)
        return TransformerUnitModel(c.reduced() if reduced else c)

    def make_data(n_vehicles, per_vehicle, n_test, seed):
        return make_lm_fleet_data(n_vehicles, per_vehicle, n_test, seed,
                                  vocab_size=reduced.vocab_size)

    return ModelEntry(
        name=arch_id, build=build, make_data=make_data, n_units=n_units,
        description=f"{cfg.family} LM ({cfg.source}); reduced config by "
                    f"default, model_kwargs={{'reduced': False}} for full")


def _register_builtin_models():
    from repro.configs import ARCH_IDS, get_config

    register_model(ModelEntry(
        "resnet18", _build_resnet, _resnet_data, n_units=9,
        description="the paper's ResNet18 over 32x32x3 (9 split points)"))
    register_model(ModelEntry(
        "mlp9", _build_mlp9, _mlp9_data, n_units=9,
        description="9-unit split MLP — the dispatch-bound federation "
                    "model (models/mlp_unit.py)"))
    for arch_id in ARCH_IDS:
        if get_config(arch_id).frontend == "none":   # text archs only
            register_model(_arch_model_entry(arch_id))


# --------------------------------------------------------------------------
# scenarios
# --------------------------------------------------------------------------

# name -> builder(n_vehicles, seed=..., **kw) -> Scenario; the SINGLE_RSU
# entry is None: the router dispatches it to FederationSim instead.
# Includes the city scale-out fixture (DESIGN.md §15): an RSU lattice with
# Zipf cell popularity sized for the 2-D mesh + slot-paging paths
SCENARIOS: Dict[str, Optional[Callable[..., Any]]] = {
    SINGLE_RSU: None,
    **_scenario.SCENARIOS,
}


def register_scenario(name: str, builder: Callable[..., Any]) -> None:
    SCENARIOS[name] = builder


def scenario_names() -> str:
    return " | ".join(sorted(SCENARIOS))


def build_scenario(name: str, n_vehicles: int, seed: int = 0, **kw):
    if name not in SCENARIOS or SCENARIOS[name] is None:
        raise ValueError(f"{name!r} is not a multi-RSU scenario; "
                        f"registered: {scenario_names()}")
    return SCENARIOS[name](n_vehicles, seed=seed, **kw)


# --------------------------------------------------------------------------
# cut strategies and server schedules
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class StrategyEntry:
    name: str
    engines: Tuple[str, ...]      # subset of (FEDERATION, SCENARIO)
    description: str = ""


STRATEGIES: Dict[str, StrategyEntry] = {}


def register_strategy(entry: StrategyEntry) -> StrategyEntry:
    STRATEGIES[entry.name] = entry
    return entry


@dataclasses.dataclass(frozen=True)
class ScheduleEntry:
    name: str
    engines: Tuple[str, ...]
    description: str = ""


SCHEDULES: Dict[str, ScheduleEntry] = {}


def register_schedule(entry: ScheduleEntry) -> ScheduleEntry:
    SCHEDULES[entry.name] = entry
    return entry


@dataclasses.dataclass(frozen=True)
class WireEntry:
    """A cut-boundary wire scheme (DESIGN.md §11): how smashed activations
    (up) and cut-layer gradients (down) cross the vehicle<->RSU link, and
    what the cost model charges for them."""
    name: str
    engines: Tuple[str, ...]
    description: str = ""


WIRES: Dict[str, WireEntry] = {}


def register_wire(entry: WireEntry) -> WireEntry:
    WIRES[entry.name] = entry
    return entry


def wire_names() -> str:
    return " | ".join(sorted(WIRES))


def _register_builtin_strategies():
    descr = {
        "paper": "Eq. 3 rate banding (text-consistent ordering)",
        "paper-literal": "Eq. 3 as printed (low rate -> cut 2)",
        "latency": "per-vehicle argmin of analytic round latency",
        "energy": "weighted latency+energy objective",
        "memory": "vehicle-side byte budget clamp over the paper rule",
        "residence": "deadline-aware largest-offload cut, SKIP when none "
                     "fits the remaining cell residence",
    }
    for name in sorted(set(FEDERATION_STRATEGIES) | set(SCENARIO_STRATEGIES)):
        engines = tuple(
            kind for kind, names in ((FEDERATION, FEDERATION_STRATEGIES),
                                     (SCENARIO, SCENARIO_STRATEGIES))
            if name in names)
        register_strategy(StrategyEntry(name, engines, descr.get(name, "")))

    register_schedule(ScheduleEntry(
        "sequential", (FEDERATION, SCENARIO),
        "paper §III-B: the RSU consumes the cohort's smashed batches one "
        "at a time, in cohort order"))
    register_schedule(ScheduleEntry(
        "parallel", (SCENARIO,),
        "arXiv:2405.18707: one |D_n|-weighted mean-gradient server step "
        "per local step, batched over the whole cohort"))
    register_schedule(ScheduleEntry(
        "streaming", (SCENARIO,),
        "buffered-asynchronous (FedBuff-style): per-RSU StreamBuffer of "
        "pending deltas, staleness-weighted merge whenever it reaches "
        "stream.buffer_size (core/streaming.py, DESIGN.md §14)"))
    assert set(SCHEDULES) == set(SERVER_SCHEDULES)

    register_wire(WireEntry(
        "none", (FEDERATION, SCENARIO),
        "dense fp32 smashed tensors, uncompressed both directions"))
    register_wire(WireEntry(
        "int8", (FEDERATION, SCENARIO),
        "per-128-group symmetric int8 quant of activations and cut-layer "
        "gradients (~4x fewer bytes; kernels/quant.py)"))
    register_wire(WireEntry(
        "topk_int8", (FEDERATION, SCENARIO),
        "per-group top-k sparsify + int8 pack with per-vehicle error "
        "feedback in the superstep engine (>=4x on top of quant; "
        "kernels/wire.py)"))
    assert set(WIRES) == set(WIRE_SCHEMES)


_register_builtin_models()
_register_builtin_strategies()
