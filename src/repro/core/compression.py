"""Smashed-data compression at the cut boundary (beyond-paper optimization).

The paper's point is that SFL trades communication for computation; the
natural next step (its §IV-D 'wireless resource allocation' direction) is to
shrink the uplink itself.  We use per-group symmetric int8 quantisation of
the cut activations (and, optionally, of the returned cut-layer gradients):
4x fewer bytes over the wireless link in the simulator, and 4x fewer
collective bytes at the sharding boundary in the datacenter realisation.

A straight-through estimator keeps the backward path exact w.r.t. the
dequantised values.  ``repro.kernels.quant`` provides the Pallas TPU kernel
with identical semantics (this module is its oracle).
"""
from __future__ import annotations

from typing import Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

GROUP = 128  # quantisation group along the trailing axis


def _group_shape(d: int, group: int) -> Tuple[int, int]:
    """(group size, group count) for a trailing dim: g = min(group, d)
    groups, the last one zero-padded when d is not a multiple of g."""
    g = min(group, max(d, 1))
    return g, -(-d // g)                       # ceil(d / g)


def quantize_int8(x: jnp.ndarray, group: int = GROUP
                  ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-(trailing-)group symmetric int8.  Returns (q int8 (..., d),
    scales f32 (..., ceil(d/g))).

    A trailing dim that is not a multiple of the group size is padded with
    zeros INTERNALLY to the next group boundary — the pad never changes any
    group's amax/scale and is sliced off the returned q, so callers get
    ``group``-granular quantisation for every d (previously the whole row
    silently collapsed into one group — coarser scales with no warning)."""
    *lead, d = x.shape
    g, ng = _group_shape(d, group)
    pad = ng * g - d
    if pad:
        x = jnp.concatenate(
            [x, jnp.zeros((*lead, pad), x.dtype)], axis=-1)
    xg = x.reshape(*lead, ng, g).astype(jnp.float32)
    amax = jnp.max(jnp.abs(xg), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(xg / scale), -127, 127).astype(jnp.int8)
    return q.reshape(*lead, ng * g)[..., :d], scale[..., 0]


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray, dtype=jnp.float32,
                    group: int = GROUP) -> jnp.ndarray:
    """Inverse of :func:`quantize_int8` (pass the same ``group``).  The
    group size is re-derived as min(group, d); when the scale count says
    the producer used a different (exactly dividing) group, that wins —
    so custom divisible groups round-trip without threading ``group``.
    A custom group on a NON-divisible dim is the one ambiguous case (the
    scale count alone cannot recover it): there you must pass the same
    ``group`` you quantized with, or the groups are mis-sliced."""
    *lead, d = q.shape
    ng = scale.shape[-1]
    g, ng_default = _group_shape(d, group)
    if ng != ng_default:
        g = d // ng                            # custom exactly-dividing group
    pad = ng * g - d
    if pad:
        q = jnp.concatenate(
            [q, jnp.zeros((*lead, pad), q.dtype)], axis=-1)
    xg = q.reshape(*lead, ng, g).astype(jnp.float32) * scale[..., None]
    return xg.reshape(*lead, ng * g)[..., :d].astype(dtype)


@jax.custom_vjp
def fake_quant(x: jnp.ndarray) -> jnp.ndarray:
    """Quantise-dequantise with a straight-through gradient."""
    q, s = quantize_int8(x)
    return dequantize_int8(q, s, x.dtype)


def _fq_fwd(x):
    return fake_quant(x), None


def _fq_bwd(_, g):
    return (g,)


fake_quant.defvjp(_fq_fwd, _fq_bwd)


def effective_group(trailing_dim, group: int = GROUP):
    """The group size :func:`quantize_int8` actually uses for a trailing dim
    ``d``: min(group, d) — non-divisible dims are padded internally to the
    next group boundary, so the granularity never coarsens.  Vectorized over
    arrays of trailing dims (per-cut smashed channel counts)."""
    d = np.asarray(trailing_dim)
    return np.minimum(group, np.maximum(d, 1))


def compression_ratio(dtype_bytes: int = 4, group: int = GROUP,
                      trailing_dim: Optional[Union[int, np.ndarray]] = None
                      ) -> Union[float, np.ndarray]:
    """Bytes(fp) / bytes(int8 + f32 scale per group).

    Pass ``trailing_dim`` (scalar or per-cut array) to account with the
    groups :func:`quantize_int8` actually emits — ceil(d/g) scales with
    g = min(group, d): a 64-channel smashed tensor quantizes in 64-wide
    groups (more scale overhead than the nominal GROUP-wide assumption),
    and a 200-channel one pays a second scale for its padded tail group."""
    if trailing_dim is None:
        return dtype_bytes * group / (group + 4.0)
    d = np.asarray(trailing_dim)
    g = effective_group(d, group)
    ng = -(-d // g)                            # ceil: padded tail group
    ratio = dtype_bytes * d / (d + 4.0 * ng)
    return float(ratio) if np.ndim(ratio) == 0 else ratio
