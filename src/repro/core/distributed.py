"""Datacenter-scale SFL: jit-compilable train / prefill / decode steps.

Mapping (DESIGN.md §3): vehicles <-> the `data` mesh axis (one cohort per
column), RSU-side model tensor-parallel over `model`, the smashed-data
boundary an explicit sharding constraint, FedAvg the |D_n|-weighted gradient
mean over the client axis (visible as the data-axis all-reduce in the HLO).
The compiled step is sync-SFL (aggregation every step, K=1) — see DESIGN.md
for the equivalence argument; K>1 divergent-replica SFL runs in fedsim.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig
from repro.core import split as SP
from repro.core.compression import fake_quant
from repro.models import layers as L
from repro.models import transformer as T
from repro import optim

Params = Any


@dataclasses.dataclass
class DistOptions:
    cut: int = 2
    compress_smashed: bool = False
    remat: bool = True
    learning_rate: float = 3e-4
    optimizer: str = "adamw"
    grad_clip: float = 1.0
    smashed_sharding: Optional[jax.sharding.NamedSharding] = None
    param_dtype: Any = None       # None -> cfg.param_dtype


def make_optimizer(opts: DistOptions) -> optim.Optimizer:
    if opts.optimizer == "adamw":
        return optim.adamw(opts.learning_rate, weight_decay=0.01)
    if opts.optimizer == "adam":
        return optim.adam(opts.learning_rate)
    return optim.sgd(opts.learning_rate)


def init_state(key, cfg: ArchConfig, opts: DistOptions) -> Dict[str, Any]:
    params = T.init_params(key, cfg, opts.param_dtype)
    opt = make_optimizer(opts)
    return {"params": params, "opt": opt.init(params),
            "step": jnp.zeros((), jnp.int32)}


def weighted_ce(logits, labels, weights, true_vocab: int) -> jnp.ndarray:
    """Per-sample-weighted token cross-entropy — realises the |D_n|-weighted
    FedAvg objective (paper Eq. 1) inside one lowered step."""
    logits = logits.astype(jnp.float32)
    vpad = logits.shape[-1]
    if true_vocab < vpad:
        mask = jnp.concatenate([jnp.zeros((true_vocab,), jnp.float32),
                                jnp.full((vpad - true_vocab,), -1e9)])
        logits = logits + mask
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    per_tok = logz - gold                       # (b, s) or (b, s, k)
    while per_tok.ndim > 1:
        per_tok = jnp.mean(per_tok, axis=-1)
    w = weights / jnp.maximum(jnp.sum(weights), 1e-9)
    return jnp.sum(per_tok * w)


def _labels_of(cfg: ArchConfig, batch):
    if cfg.frontend == "audio":
        return batch["codes"].swapaxes(1, 2)     # (b, s, K)
    return batch["labels"]


def make_train_step(cfg: ArchConfig, opts: DistOptions) -> Callable:
    """SFL round step: client fwd -> smashed boundary -> server fwd/bwd ->
    client bwd -> weighted FedAvg (the data-axis mean inside jax.grad)."""
    opt = make_optimizer(opts)
    cut = SP.clamp_cut(cfg, opts.cut)

    def train_step(state, batch):
        def loss_fn(params):
            client, server = SP.split_params(params, cfg, cut)
            smashed, positions, aux_c, _ = SP.client_forward(
                client, cfg, batch, cut, "train")
            if opts.smashed_sharding is not None:
                smashed = jax.lax.with_sharding_constraint(
                    smashed, opts.smashed_sharding)
            if opts.compress_smashed:
                smashed = fake_quant(smashed)     # int8 uplink (beyond-paper)
            logits, aux_s, _ = SP.server_forward(
                server, cfg, smashed, positions, cut, "train")
            labels = _labels_of(cfg, batch)
            if cfg.frontend == "vision":
                logits = logits[:, cfg.n_patches:]
            ce = weighted_ce(logits, labels, batch["weights"], cfg.vocab_size)
            return ce + aux_c + aux_s, {"ce": ce, "aux": aux_c + aux_s}

        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(state["params"])
        if opts.grad_clip > 0:
            grads, gnorm = optim.clip_by_global_norm(grads, opts.grad_clip)
            metrics["grad_norm"] = gnorm
        updates, opt_state = opt.update(grads, state["opt"], state["params"])
        params = optim.apply_updates(state["params"], updates)
        new_state = {"params": params, "opt": opt_state,
                     "step": state["step"] + 1}
        metrics["loss"] = loss
        return new_state, metrics

    return train_step


def make_prefill_step(cfg: ArchConfig, opts: DistOptions,
                      capacity: int) -> Callable:
    """Split inference (paper §IV-C), prefill phase: vehicle-side layers run
    on the cohort, one smashed upload, RSU-side layers fill their caches."""
    cut = SP.clamp_cut(cfg, opts.cut)

    def prefill_step(params, batch):
        client, server = SP.split_params(params, cfg, cut)
        smashed, positions, _, c_caches = SP.client_forward(
            client, cfg, batch, cut, "prefill", capacity=capacity)
        if opts.smashed_sharding is not None:
            smashed = jax.lax.with_sharding_constraint(
                smashed, opts.smashed_sharding)
        if opts.compress_smashed:
            smashed = fake_quant(smashed)
        logits, _, s_caches = SP.server_forward(
            server, cfg, smashed, positions, cut, "prefill", capacity=capacity)
        return logits[:, -1:], (c_caches, s_caches)

    return prefill_step


def make_decode_step(cfg: ArchConfig, opts: DistOptions,
                     capacity: int) -> Callable:
    """Split inference, decode: ONE new token against seq_len of cache."""
    cut = SP.clamp_cut(cfg, opts.cut)

    def decode_step(params, batch, caches, pos):
        client, server = SP.split_params(params, cfg, cut)
        c_caches, s_caches = caches
        smashed, positions, _, c_caches = SP.client_forward(
            client, cfg, batch, cut, "decode", caches=c_caches,
            capacity=capacity, pos_offset=pos)
        if opts.smashed_sharding is not None:
            smashed = jax.lax.with_sharding_constraint(
                smashed, opts.smashed_sharding)
        if opts.compress_smashed:
            smashed = fake_quant(smashed)
        logits, _, s_caches = SP.server_forward(
            server, cfg, smashed, positions, cut, "decode", caches=s_caches,
            capacity=capacity)
        return logits, (c_caches, s_caches)

    return decode_step


# --------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins; no allocation — dry-run contract)
# --------------------------------------------------------------------------

def input_specs(cfg: ArchConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """Model inputs for one step at the given input shape."""
    b = shape.global_batch
    sds = jax.ShapeDtypeStruct
    if shape.kind == "decode":
        s = 1
    else:
        s = shape.seq_len
    if cfg.frontend == "vision":
        s_text = max(s - cfg.n_patches, 1) if shape.kind != "decode" else 1
        batch = {"tokens": sds((b, s_text), jnp.int32)}
        if shape.kind != "decode":
            batch["patch_embeds"] = sds((b, cfg.n_patches, cfg.d_model), jnp.float32)
        if shape.kind == "train":
            batch["labels"] = sds((b, s_text), jnp.int32)
    elif cfg.frontend == "audio":
        batch = {"codes": sds((b, cfg.n_codebooks, s), jnp.int32)}
    else:
        batch = {"tokens": sds((b, s), jnp.int32)}
        if shape.kind == "train":
            batch["labels"] = sds((b, s), jnp.int32)
    if shape.kind == "train":
        batch["weights"] = sds((b,), jnp.float32)
    return batch


def cache_specs(cfg: ArchConfig, shape: ShapeConfig, cut: int,
                dtype=jnp.bfloat16):
    """Shape-only KV/state cache stand-ins for the decode dry-run."""
    def build():
        return SP.init_split_caches(cfg, shape.global_batch, shape.seq_len,
                                    cut, dtype)
    return jax.eval_shape(build)
