"""Multi-RSU scenario demo: mobility, handover, hierarchical aggregation —
driven through the declarative front door, ``repro.api.run`` (DESIGN.md §9).

A fleet drives a 4-RSU highway corridor (core/scenario.py).  Each round the
scenario layer yields vectorized fleet state — positions, serving cell,
Shannon rates, remaining residence time; the fused super-step engine groups
vehicles into one cohort per RSU inside a single compiled program, trains
them against that RSU's edge model, and merges the edge models at a cloud
tier every ``--sync`` rounds (hierarchical FedAvg == flat FedAvg under
matching weights, DESIGN.md §7).  Vehicles crossing cell borders hand over:
their data shard and identity move with them; server-side state stays at
the RSU.  The per-round lines below stream from the ``on_round`` callback —
fired after each fused K-round window, so streaming adds no host syncs to
the compiled path.

  PYTHONPATH=src python examples/multi_rsu_sim.py                 # highway
  PYTHONPATH=src python examples/multi_rsu_sim.py --scenario urban_grid
  PYTHONPATH=src python examples/multi_rsu_sim.py --rounds 8 --sync 2
"""
import argparse
import time

import numpy as np

from repro import api
from repro.core import adaptive, cost


def show_residence_rule(sc, rounds, interval):
    """What the residence_aware rule would decide for the paper's ResNet18
    cost profile on this scenario (SKIP = vehicle leaves its cell before any
    cut's round latency fits)."""
    prof = cost.resnet_profile()
    print("\nresidence_aware on the ResNet18 profile "
          "(cut 0 = skip the round):")
    for rnd in range(min(rounds, 4)):
        st = sc.fleet_state(rnd * interval, seed=rnd)
        cuts = np.asarray(adaptive.residence_aware(
            prof, np.maximum(st.rates_bps, 1.0), 2e10, 2e12, 4, 16, 1,
            st.residence_s))
        cuts = np.where(st.active, cuts, -1)
        n_skip = int(((cuts == 0) & st.active).sum())
        print(f"  t={rnd*interval:5.1f}s  cuts={cuts[:12]}...  "
              f"skips={n_skip}  uncovered={int((~st.active).sum())}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scenario", default="highway_corridor",
                    choices=sorted(n for n, b in api.SCENARIOS.items()
                                   if b is not None))
    ap.add_argument("--vehicles", type=int, default=24)
    ap.add_argument("--rounds", type=int, default=6)
    ap.add_argument("--sync", type=int, default=2,
                    help="cloud merge every k rounds")
    ap.add_argument("--superstep", type=int, default=2,
                    help="rounds fused into one compiled super-step "
                         "(DESIGN.md §8; 1 = one dispatch per round)")
    ap.add_argument("--schedule", default="sequential",
                    choices=sorted(api.SCHEDULES),
                    help="RSU server schedule: paper §III-B sequential or "
                         "the parallel scheme of arXiv:2405.18707")
    ap.add_argument("--compilation-cache", default=None, metavar="DIR",
                    help="persistent XLA cache: re-runs skip compilation")
    args = ap.parse_args()

    # the registry's mlp9 split model stands in for a vehicle perception
    # model (the federation dynamics, not the FLOPs, are this demo's point)
    spec = api.ExperimentSpec(
        model="mlp9",
        train=api.TrainConfig(scheme="asfl", rounds=args.rounds,
                              local_steps=2, batch_size=8, lr=1e-3,
                              server_schedule=args.schedule),
        adaptive=api.AdaptiveConfig(strategy="paper"),
        fleet=api.FleetConfig(n_vehicles=args.vehicles,
                              scenario=args.scenario,
                              scenario_kwargs={"seed": 7},
                              cloud_sync_every=args.sync,
                              round_interval_s=10.0,
                              per_vehicle_samples=64),
        runtime=api.RuntimeConfig(superstep=args.superstep,
                                  precompile=True,
                                  compilation_cache_dir=args.compilation_cache),
    )
    sc = api.build_scenario(args.scenario, args.vehicles,
                            **spec.fleet.scenario_kwargs)
    print(f"scenario={args.scenario}: {args.vehicles} vehicles, "
          f"{len(sc.rsu_positions)} RSUs; schedule={args.schedule}, "
          f"K={args.superstep}, cloud sync every {args.sync} round(s)")

    def on_round(m):
        acc = f"{m.test_acc:.3f}" if np.isfinite(m.test_acc) else "  -  "
        print(f"round {m.round}: loss={m.loss:.3f} acc={acc} "
              f"sched={m.n_scheduled:3d} handover={m.n_handover:2d} "
              f"rsu_loads={m.rsu_loads} comm={m.comm_bytes/1e6:6.1f}MB")

    t0 = time.time()
    result = api.run(spec, on_round=on_round,
                     on_cloud_merge=lambda rnd, eng: print(
                         f"  cloud merge after round {rnd}"))
    print(f"({time.time()-t0:.1f}s wall; engine mode="
          f"{result.diagnostics['mode']}, precompile+compile warmup "
          f"{result.timing['warmup_s']:.1f}s, run "
          f"{result.timing['run_s']:.1f}s compile-free)")

    show_residence_rule(sc, args.rounds, spec.fleet.round_interval_s)


if __name__ == "__main__":
    main()
