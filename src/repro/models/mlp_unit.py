"""Split MLP UnitModel + synthetic fleet data (promoted from the benchmark).

The 9-unit split MLP over feature vectors is the dispatch-bound federation
model: small enough that a local step is milliseconds, which is exactly the
regime where engine overhead (not FLOPs) dominates at fleet scale — a
vehicle-side perception model is small; the simulator's job is to scale the
*federation*.  It mirrors the paper ResNet18's 9 split points, so every cut
in {2, 4, 6, 8} is valid.  Registered as ``"mlp9"`` in
:mod:`repro.api.registry`; the benchmarks and the multi-RSU example import
it from here.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cost
from repro.data.pipeline import ClientDataset


class MLPUnitModel:
    """9-unit split MLP over feature vectors (every cut in {2,4,6,8} valid)."""
    name = "mlp-split"
    scan_friendly = True

    def __init__(self, dim: int = 48, width: int = 64, n_units: int = 9,
                 n_classes: int = 10):
        self.dim, self.width, self.n_units = dim, width, n_units
        self.n_classes = n_classes

    def init(self, key):
        ks = jax.random.split(key, self.n_units + 1)
        units = []
        d_in = self.dim
        for i in range(self.n_units):
            units.append({
                "w": jax.random.normal(ks[i], (d_in, self.width))
                * math.sqrt(2.0 / d_in),
                "b": jnp.zeros((self.width,)),
            })
            d_in = self.width
        head = {"w": jax.random.normal(ks[-1], (self.width, self.n_classes))
                * math.sqrt(1.0 / self.width),
                "b": jnp.zeros((self.n_classes,))}
        return units, head

    def apply_units(self, units, x, start):
        for u in units:
            x = jax.nn.relu(x @ u["w"] + u["b"])
        return x

    def head_loss(self, head, feats, labels):
        logits = feats @ head["w"] + head["b"]
        logz = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
        return jnp.mean(logz - gold), logits

    def head_predict(self, head, feats):
        return feats @ head["w"] + head["b"]

    def profile(self):
        w, d = self.width, self.dim
        flops = [2.0 * d * w] + [2.0 * w * w] * (self.n_units - 1)
        pbytes = [(d * w + w) * 4] + [(w * w + w) * 4] * (self.n_units - 1)
        return cost.SplitProfile(
            name=self.name, unit_fwd_flops=flops, unit_param_bytes=pbytes,
            smashed_bytes_per_sample=[w * 4.0] * self.n_units,
            head_flops=2.0 * w * self.n_classes,
            head_param_bytes=(w * self.n_classes + self.n_classes) * 4,
            smashed_trailing_dim=[w] * self.n_units)


def make_mlp_fleet_data(n_clients: int, per_client: int, dim: int = 48,
                        seed: int = 0, n_test: int = 256,
                        n_classes: int = 10):
    """Class-structured feature vectors, one shard per vehicle."""
    rng = np.random.default_rng(seed)
    templates = rng.normal(size=(n_classes, dim)).astype(np.float32)
    clients = []
    for i in range(n_clients):
        y = rng.integers(0, n_classes, size=per_client)
        x = templates[y] + 0.5 * rng.normal(size=(per_client, dim))
        clients.append(ClientDataset(x.astype(np.float32),
                                     y.astype(np.int32), i))
    yt = rng.integers(0, n_classes, size=n_test)
    xt = templates[yt] + 0.5 * rng.normal(size=(n_test, dim))
    test = {"images": jnp.asarray(xt.astype(np.float32)),
            "labels": jnp.asarray(yt.astype(np.int32))}
    return clients, test
