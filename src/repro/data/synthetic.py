"""Synthetic datasets (offline container: no real CIFAR-10 download).

``make_cifar_like`` builds a 10-class image problem with class-conditional
structure (per-class frequency+spatial templates plus noise) so accuracy
curves behave like a real vision task: learnable, non-trivial, and sensitive
to non-IID partitioning — which is what the paper's Fig. 5c/5d compare.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np


TEMPLATE_SEED = 20240911  # class templates are a fixed property of the task


def make_cifar_like(key, n: int, n_classes: int = 10, noise: float = 0.5
                    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (images (n,32,32,3) float32 in [-1,1]-ish, labels (n,) int32).

    The per-class templates come from a FIXED seed so that independently
    generated splits (train/test, different clients) share the same class
    structure — generalisation is measurable."""
    k1, k3 = jax.random.split(key, 2)
    labels = jax.random.randint(k1, (n,), 0, n_classes)
    templates = jax.random.normal(
        jax.random.PRNGKey(TEMPLATE_SEED), (n_classes, 32, 32, 3)) * 0.7
    # low-frequency structure: smooth the templates with a separable blur
    kernel = jnp.array([0.25, 0.5, 0.25])
    t = templates
    for axis in (1, 2):
        t = (0.25 * jnp.roll(t, 1, axis) + 0.5 * t + 0.25 * jnp.roll(t, -1, axis))
    images = t[labels] + noise * jax.random.normal(k3, (n, 32, 32, 3))
    return images.astype(jnp.float32), labels.astype(jnp.int32)


def make_bigram_lm(key, vocab: int, n_tokens: int, temperature: float = 1.0
                   ) -> jnp.ndarray:
    """Token stream from a fixed random bigram table — learnable LM task."""
    k1, k2 = jax.random.split(key)
    logits = jax.random.normal(k1, (vocab, vocab)) * 2.0 / temperature

    def step(tok, k):
        nxt = jax.random.categorical(k, logits[tok])
        return nxt, nxt

    keys = jax.random.split(k2, n_tokens)
    _, toks = jax.lax.scan(step, jnp.zeros((), jnp.int32), keys)
    return toks.astype(jnp.int32)


def lm_batch_from_stream(stream: jnp.ndarray, batch: int, seq: int,
                         step: int) -> Dict[str, jnp.ndarray]:
    """Deterministic sliding batches from a token stream (wraps around)."""
    n = stream.shape[0]
    starts = (np.arange(batch) * seq + step * batch * seq) % max(n - seq - 1, 1)
    toks = np.stack([np.asarray(stream[s:s + seq]) for s in starts])
    labels = np.stack([np.asarray(stream[s + 1:s + seq + 1]) for s in starts])
    return {"tokens": jnp.asarray(toks), "labels": jnp.asarray(labels)}
