"""Cut-layer selection strategies — the 'adaptive' in ASFL.

`paper_threshold` is the paper's Eq. 3 (rate bands -> cut in {2,4,6,8}).

NOTE on Eq. 3 vs the paper's text: the printed equation maps the LOWEST rate
band to cut 2, whose smashed data is the LARGEST (Fig. 5a) — contradicting
the surrounding text ("when the vehicle's transmission rate is higher, we can
choose a smaller split layer").  We implement the text-consistent ordering by
default (high rate -> early cut -> more offload) and keep the literal printed
mapping behind ``literal_eq3=True``.  See DESIGN.md §2.

Every strategy is vectorized over the fleet: selection for 256 vehicles is a
handful of numpy vector ops, not a Python loop of per-vehicle cost-model
evaluations (DESIGN.md §6).  All strategies return a plain list of ints so
results stay JSON-serializable and usable as static jit keys.

Beyond-paper strategies:
  * `latency_optimal` — per-vehicle argmin of the analytic round latency
    (cost.py), the multi-objective direction the paper lists as future work.
  * `memory_constrained` — upper-bounds the vehicle-side model bytes first
    (vehicles cannot hold a DBRX layer), then applies another strategy.
    Accepts a scalar budget or per-vehicle budgets (VehicleProfile.
    memory_budget_bytes, wired as ``SimConfig.adaptive_strategy="memory"``).
  * `energy_aware` — weighted latency+energy objective.
  * `residence_aware` — deadline-aware: the largest-offload cut whose
    analytic round latency fits the vehicle's remaining residence time in
    its serving cell (the ASFL direction of arXiv:2405.18707), falling back
    to SKIP when no cut fits.
"""
from __future__ import annotations

from typing import Callable, List, Optional, Sequence, Tuple, Union

import jax.numpy as jnp
import numpy as np

from repro.core.cost import BWD_FWD_RATIO, SplitProfile, sfl_round_cost_arrays

DEFAULT_CUTS = (2, 4, 6, 8)
# Threshold rates (bps), R1<=R2<=R3<=R4 as in Eq. 3.  The paper leaves the
# R-bar values unspecified; these are calibrated to the quartiles of the
# channel model's rate distribution over a drive-by trace (channel.py), so
# each band is actually populated.
DEFAULT_THRESHOLDS = (60e6, 110e6, 160e6, 260e6)


def paper_threshold(rates_bps: Sequence[float],
                    thresholds: Sequence[float] = DEFAULT_THRESHOLDS,
                    cuts: Sequence[int] = DEFAULT_CUTS,
                    literal_eq3: bool = False) -> List[int]:
    """Eq. 3: banded rate -> cut layer, per vehicle (one digitize call)."""
    rates = np.asarray(rates_bps, dtype=np.float64)
    band = np.digitize(rates, np.asarray(thresholds[:3]), right=True)
    cuts_arr = np.asarray(cuts)
    if literal_eq3:
        out = cuts_arr[band]                  # printed Eq. 3: low rate -> cut 2
    else:
        out = cuts_arr[len(cuts) - 1 - band]  # text: high rate -> cut 2
    return [int(c) for c in out]


def _cost_matrix(profile: SplitProfile, rates_bps, client_flops,
                 server_flops: float, n_batches: int, batch: int,
                 local_epochs: int, candidate_cuts):
    """(n_vehicles, n_cuts) RoundCostArrays via one broadcast evaluation."""
    cuts = np.asarray(list(candidate_cuts), dtype=np.int64)
    rates = np.atleast_1d(np.asarray(rates_bps, dtype=np.float64))[:, None]
    flops = np.atleast_1d(np.asarray(client_flops,
                                     dtype=np.float64))[:, None]
    return cuts, sfl_round_cost_arrays(profile, cuts[None, :], n_batches,
                                       batch, rates, flops, server_flops,
                                       local_epochs)


def latency_optimal(profile: SplitProfile, rates_bps: Sequence[float],
                    client_flops: Sequence[float], server_flops: float,
                    n_batches: int, batch: int, local_epochs: int = 1,
                    candidate_cuts: Optional[Sequence[int]] = None) -> List[int]:
    cuts, costs = _cost_matrix(profile, rates_bps, client_flops, server_flops,
                               n_batches, batch, local_epochs,
                               candidate_cuts or range(1, profile.n_units))
    return [int(c) for c in cuts[np.argmin(costs.latency, axis=1)]]


def energy_aware(profile: SplitProfile, rates_bps: Sequence[float],
                 client_flops: Sequence[float], server_flops: float,
                 n_batches: int, batch: int, local_epochs: int = 1,
                 latency_weight: float = 0.5,
                 candidate_cuts: Optional[Sequence[int]] = None) -> List[int]:
    cuts, costs = _cost_matrix(profile, rates_bps, client_flops, server_flops,
                               n_batches, batch, local_epochs,
                               candidate_cuts or range(1, profile.n_units))
    lat, en = costs.latency, costs.energy_j
    score = (latency_weight * lat / lat.max(axis=1, keepdims=True)
             + (1 - latency_weight) * en / en.max(axis=1, keepdims=True))
    return [int(c) for c in cuts[np.argmin(score, axis=1)]]


SKIP = 0  # sentinel cut: the vehicle sits this round out


def residence_aware(profile: SplitProfile, rates_bps: Sequence[float],
                    client_flops: Sequence[float], server_flops: float,
                    n_batches: int, batch: int, local_epochs: int,
                    residence_s: Sequence[float],
                    candidate_cuts: Optional[Sequence[int]] = None
                    ) -> List[int]:
    """Deadline-aware selection: among candidate cuts (ascending), pick the
    LARGEST-OFFLOAD cut — the smallest vehicle-side prefix, i.e. the most
    work pushed to the RSU — whose analytic round latency (cost.py) fits the
    vehicle's remaining residence time; :data:`SKIP` (0) when no cut fits
    (the vehicle would leave coverage mid-round, the §II-C interruption the
    scenario layer models).  One broadcast cost-matrix evaluation for the
    whole fleet."""
    cand = sorted(candidate_cuts or range(1, profile.n_units))
    cuts, costs = _cost_matrix(profile, rates_bps, client_flops, server_flops,
                               n_batches, batch, local_epochs, cand)
    res = np.asarray(residence_s, dtype=np.float64)[:, None]
    feasible = costs.latency <= res
    first = np.argmax(feasible, axis=1)          # smallest feasible cut
    out = np.where(feasible.any(axis=1), cuts[first], SKIP)
    return [int(c) for c in out]


# --------------------------------------------------------------------------
# traced strategies (the fused super-step path, DESIGN.md §8): same decisions
# as the numpy strategies above, computed on-device so K rounds of cut
# selection run inside one compiled program with no host round-trip.
# --------------------------------------------------------------------------

def paper_threshold_traced(rates_bps,
                           thresholds: Sequence[float] = DEFAULT_THRESHOLDS,
                           cuts: Sequence[int] = DEFAULT_CUTS,
                           literal_eq3: bool = False):
    """jit-traceable :func:`paper_threshold`: (n,) traced rates -> (n,) int32
    cuts.  Thresholds/cuts are static closure constants."""
    rates = jnp.asarray(rates_bps, jnp.float32)
    bins = jnp.asarray(thresholds[:3], jnp.float32)
    band = jnp.sum(rates[:, None] > bins[None, :], axis=1)  # digitize(right)
    cuts_arr = jnp.asarray(cuts, jnp.int32)
    return cuts_arr[band] if literal_eq3 else cuts_arr[len(cuts) - 1 - band]


def latency_matrix_traced(profile: SplitProfile, rates_bps, client_flops,
                          server_flops: float, n_batches: int, batch: int,
                          local_epochs: int, candidate_cuts):
    """(n, k) analytic round latency per candidate cut — the traced core of
    :func:`sfl_round_cost_arrays` (latency field only), used by the fused
    residence-aware scheduler."""
    cuts = np.asarray(list(candidate_cuts), dtype=np.int64)
    fwd_cum = np.concatenate([[0.0], np.cumsum(profile.unit_fwd_flops)])
    bytes_cum = np.concatenate([[0.0], np.cumsum(profile.unit_param_bytes)])
    smashed = np.asarray(profile.smashed_bytes_per_sample)[cuts - 1] * batch
    steps = n_batches * local_epochs
    updown = 2.0 * (steps * smashed + bytes_cum[cuts])          # (k,) static
    c_fwd = fwd_cum[cuts] * batch
    s_fwd = (fwd_cum[-1] - fwd_cum[cuts] + profile.head_flops) * batch
    rates = jnp.asarray(rates_bps, jnp.float32)[:, None]
    flops = jnp.asarray(client_flops, jnp.float32)[:, None]
    t_client = steps * (1 + BWD_FWD_RATIO) * jnp.asarray(
        c_fwd, jnp.float32)[None, :] / flops
    t_server = steps * (1 + BWD_FWD_RATIO) * np.asarray(
        s_fwd
        / server_flops, np.float32)[None, :]
    t_comm = jnp.asarray(updown, jnp.float32)[None, :] \
        / jnp.maximum(rates / 8.0, 1e-9)
    return t_client + t_server + t_comm


def residence_aware_traced(profile: SplitProfile, rates_bps, client_flops,
                           server_flops: float, n_batches: int, batch: int,
                           local_epochs: int, residence_s,
                           candidate_cuts: Optional[Sequence[int]] = None):
    """jit-traceable :func:`residence_aware`: (n,) traced rates/residence ->
    (n,) int32 cuts with :data:`SKIP` where no candidate fits."""
    cand = sorted(candidate_cuts or range(1, profile.n_units))
    lat = latency_matrix_traced(profile, rates_bps, client_flops,
                                server_flops, n_batches, batch, local_epochs,
                                cand)
    res = jnp.asarray(residence_s, jnp.float32)[:, None]
    feasible = lat <= res
    first = jnp.argmax(feasible, axis=1)
    cand_arr = jnp.asarray(cand, jnp.int32)
    return jnp.where(feasible.any(axis=1), cand_arr[first], SKIP)


def strategy_max_cut(strategy: str, n_units: int,
                     candidate_cuts: Optional[Sequence[int]] = None) -> int:
    """Static upper bound on the cut any traced scenario strategy can emit —
    the prefix-plane sizing bound of the ragged super-step layout
    (DESIGN.md §12).  ``paper``/``paper-literal`` pick from
    :data:`DEFAULT_CUTS` (the traced scheduler clips to U-1); every other
    strategy searches ``candidate_cuts`` (default ``range(1, n_units)``).
    This must remain a true upper bound of the matching ``*_traced``
    strategy: the ragged engine sizes client planes to this prefix, and the
    parity tests assert every emitted cut stays under it."""
    top = max(n_units - 1, 1)
    if strategy in ("paper", "paper-literal"):
        return min(max(DEFAULT_CUTS), top)
    cand = sorted(candidate_cuts or range(1, n_units))
    return min(max(cand), top) if cand else top


def max_cut_for_budget(profile: SplitProfile,
                       budget_bytes: Union[float, Sequence[float]]
                       ) -> np.ndarray:
    """Largest cut whose vehicle-side params fit each budget (>= 1: the
    first unit always stays on-vehicle — the paper's privacy floor)."""
    cum = np.cumsum(np.asarray(profile.unit_param_bytes, dtype=np.float64))
    budgets = np.atleast_1d(np.asarray(budget_bytes, dtype=np.float64))
    max_cuts = np.searchsorted(cum, budgets, side="right")
    return np.maximum(max_cuts, 1)


def memory_constrained(profile: SplitProfile,
                       budget_bytes: Union[float, Sequence[float]],
                       inner: Callable[..., List[int]], *args,
                       **kwargs) -> List[int]:
    """Clamp any strategy's cuts so the vehicle-side model fits the budget.
    ``budget_bytes`` is a scalar (fleet-wide) or per-vehicle array."""
    cuts = np.asarray(inner(*args, **kwargs))
    max_cuts = max_cut_for_budget(profile, budget_bytes)
    return [int(c) for c in np.minimum(cuts, max_cuts)]
