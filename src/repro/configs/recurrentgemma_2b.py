"""recurrentgemma-2b — Griffin: RG-LRU + local attention, 1:2 [arXiv:2402.19427].

[hybrid] 26L d_model=2560 10H (MQA kv=1) d_ff=7680 vocab=256000.
Pattern: (recurrent, recurrent, local-attn) x 8 periods + (R, R) tail = 26.
Constant-size RG-LRU state + window-2048 local attention -> long_500k eligible.
"""
from repro.configs.base import ATTN_LOCAL, RGLRU, ArchConfig, RGLRUConfig

R = RGLRU
A = ATTN_LOCAL

CONFIG = ArchConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    source="arXiv:2402.19427",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab_size=256000,
    pattern=(R, R, A),
    tail=(R, R),
    window=2048,
    mlp_variant="geglu",
    rglru=RGLRUConfig(d_rnn=2560, d_conv=4, c_exponent=8.0),
    default_cut=2,
    subquadratic=True,
)
