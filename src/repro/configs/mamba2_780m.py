"""mamba2-780m — SSD state-space duality [arXiv:2405.21060].

[ssm] 48L d_model=1536 (attention-free) vocab=50280, ssm_state=128.
d_inner = expand * d_model = 3072, n_heads = d_inner / head_dim = 48.
Sub-quadratic -> long_500k eligible (constant-size recurrent state decode).
"""
from repro.configs.base import SSM, ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="mamba2-780m",
    family="ssm",
    source="arXiv:2405.21060",
    n_layers=48,
    d_model=1536,
    n_heads=48,          # d_inner / ssm.head_dim
    n_kv_heads=48,
    head_dim=64,
    d_ff=0,              # attention-free: no separate FFN
    vocab_size=50280,
    pattern=(SSM,),
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, d_conv=4, n_groups=1,
                  chunk=256),
    default_cut=8,
    subquadratic=True,
)
