"""Multi-head Latent Attention (DeepSeek-V2).  The KV cache stores only the
compressed latent c_kv (kv_lora_rank) + the shared rotary key (qk_rope_dim);
decode uses the absorbed formulation (q_nope absorbed through W_uk so scores
are taken directly against the latent cache) — the actual MLA serving trick.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models.attention import NEG_INF

Params = Dict[str, Any]


def init_mla(key, cfg: ArchConfig, dtype=jnp.float32) -> Params:
    m = cfg.mla
    d, h = cfg.d_model, cfg.n_heads
    qk = m.qk_nope_dim + m.qk_rope_dim
    ks = jax.random.split(key, 6)
    s = 1.0 / math.sqrt(d)
    return {
        "wq": L.trunc_normal(ks[0], (d, h, qk), s, dtype),
        "w_dkv": L.trunc_normal(ks[1], (d, m.kv_lora_rank), s, dtype),
        "w_kr": L.trunc_normal(ks[2], (d, m.qk_rope_dim), s, dtype),
        "kv_norm": L.init_rmsnorm(m.kv_lora_rank, dtype),
        "w_uk": L.trunc_normal(ks[3], (m.kv_lora_rank, h, m.qk_nope_dim),
                               1.0 / math.sqrt(m.kv_lora_rank), dtype),
        "w_uv": L.trunc_normal(ks[4], (m.kv_lora_rank, h, m.v_head_dim),
                               1.0 / math.sqrt(m.kv_lora_rank), dtype),
        "wo": L.trunc_normal(ks[5], (h, m.v_head_dim, d),
                             1.0 / math.sqrt(h * m.v_head_dim), dtype),
    }


def _latents(p: Params, cfg: ArchConfig, x: jnp.ndarray, positions):
    c_kv = L.rmsnorm(p["kv_norm"], jnp.einsum("bsd,dr->bsr", x, p["w_dkv"].astype(x.dtype)))
    k_rope = jnp.einsum("bsd,dr->bsr", x, p["w_kr"].astype(x.dtype))
    k_rope = L.apply_rope(k_rope, positions, cfg.rope_theta)
    return c_kv, k_rope


def _queries(p: Params, cfg: ArchConfig, x: jnp.ndarray, positions):
    m = cfg.mla
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    q_nope, q_rope = q[..., :m.qk_nope_dim], q[..., m.qk_nope_dim:]
    q_rope = L.apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def mla_train(p: Params, cfg: ArchConfig, x: jnp.ndarray,
              positions: jnp.ndarray) -> jnp.ndarray:
    """Naive (materialised K/V) path for train/prefill."""
    m = cfg.mla
    b, s, _ = x.shape
    c_kv, k_rope = _latents(p, cfg, x, positions)
    q_nope, q_rope = _queries(p, cfg, x, positions)
    k_nope = jnp.einsum("bsr,rhk->bshk", c_kv, p["w_uk"].astype(x.dtype))
    v = jnp.einsum("bsr,rhk->bshk", c_kv, p["w_uv"].astype(x.dtype))
    scale = 1.0 / math.sqrt(m.qk_nope_dim + m.qk_rope_dim)
    scores = (jnp.einsum("bshk,bthk->bhst", q_nope, k_nope)
              + jnp.einsum("bshk,btk->bhst", q_rope, k_rope)).astype(jnp.float32) * scale
    mask = positions[None, :] <= positions[:, None]      # (s, t)
    scores = jnp.where(mask[None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    o = jnp.einsum("bhst,bthk->bshk", probs, v)
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(x.dtype))


def init_mla_cache(cfg: ArchConfig, batch: int, capacity: int,
                   dtype=jnp.float32) -> Params:
    m = cfg.mla
    return {
        "c_kv": jnp.zeros((batch, capacity, m.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, capacity, m.qk_rope_dim), dtype),
        "pos": jnp.zeros((), jnp.int32),
    }


def mla_prefill(p: Params, cfg: ArchConfig, x: jnp.ndarray,
                positions: jnp.ndarray, capacity: int
                ) -> Tuple[jnp.ndarray, Params]:
    b, s, _ = x.shape
    y = mla_train(p, cfg, x, positions)
    c_kv, k_rope = _latents(p, cfg, x, positions)
    cache = init_mla_cache(cfg, b, capacity, c_kv.dtype)
    n = min(s, capacity)
    cache["c_kv"] = jax.lax.dynamic_update_slice_in_dim(cache["c_kv"], c_kv[:, :n], 0, axis=1)
    cache["k_rope"] = jax.lax.dynamic_update_slice_in_dim(cache["k_rope"], k_rope[:, :n], 0, axis=1)
    cache["pos"] = jnp.asarray(s, jnp.int32)
    return y, cache


def mla_decode(p: Params, cfg: ArchConfig, x: jnp.ndarray,
               cache: Params) -> Tuple[jnp.ndarray, Params]:
    """Absorbed decode: scores against the latent cache, O(S * (r + rope))."""
    m = cfg.mla
    b = x.shape[0]
    pos = cache["pos"]
    positions = pos[None].astype(jnp.int32)  # (1,)
    c_new, kr_new = _latents(p, cfg, x, positions)
    size = cache["c_kv"].shape[1]
    slot = jnp.minimum(pos, size - 1)
    c_all = jax.lax.dynamic_update_slice_in_dim(cache["c_kv"], c_new, slot, axis=1)
    kr_all = jax.lax.dynamic_update_slice_in_dim(cache["k_rope"], kr_new, slot, axis=1)

    q_nope, q_rope = _queries(p, cfg, x, positions)
    # absorb: q' = q_nope @ W_uk  -> (b, 1, h, r); scores vs latent directly
    q_abs = jnp.einsum("bshk,rhk->bshr", q_nope, p["w_uk"].astype(x.dtype))
    scale = 1.0 / math.sqrt(m.qk_nope_dim + m.qk_rope_dim)
    scores = (jnp.einsum("bshr,btr->bhst", q_abs, c_all)
              + jnp.einsum("bshk,btk->bhst", q_rope, kr_all)).astype(jnp.float32) * scale
    kpos = jnp.arange(size, dtype=jnp.int32)
    scores = jnp.where((kpos <= pos)[None, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    o_lat = jnp.einsum("bhst,btr->bshr", probs, c_all)       # attend over latents
    o = jnp.einsum("bshr,rhk->bshk", o_lat, p["w_uv"].astype(x.dtype))
    y = jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(x.dtype))
    return y, {"c_kv": c_all, "k_rope": kr_all, "pos": pos + 1}


def mla_flops(cfg: ArchConfig, seq: int) -> int:
    m, d, h = cfg.mla, cfg.d_model, cfg.n_heads
    qk = m.qk_nope_dim + m.qk_rope_dim
    proj = 2 * d * (h * qk + m.kv_lora_rank + m.qk_rope_dim) \
        + 2 * m.kv_lora_rank * h * (m.qk_nope_dim + m.v_head_dim) \
        + 2 * h * m.v_head_dim * d
    sdpa = 2 * 2 * h * qk * seq
    return proj + sdpa
