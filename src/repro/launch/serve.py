"""Split-inference serving driver (paper §IV-C).

Prefill + batched decode with the model split at the cut layer: vehicle-side
layers produce the one-token smashed activation, the RSU-side layers decode
against the KV cache.  ``--smoke`` serves a reduced config on CPU.

Example:
  PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m --smoke \
      --prompt-len 32 --decode-steps 16 --batch 4
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import distributed as D
from repro.models import transformer as T


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--decode-steps", type=int, default=16)
    ap.add_argument("--cut", type=int, default=None)
    ap.add_argument("--temperature", type=float, default=1.0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.reduced()
    capacity = args.prompt_len + args.decode_steps
    opts = D.DistOptions(
        cut=args.cut if args.cut is not None else cfg.default_cut)

    key = jax.random.PRNGKey(0)
    params = T.init_params(key, cfg)
    prefill = jax.jit(D.make_prefill_step(cfg, opts, capacity))
    decode = jax.jit(D.make_decode_step(cfg, opts, capacity))

    b = args.batch
    if cfg.frontend == "audio":
        batch = {"codes": jax.random.randint(
            key, (b, cfg.n_codebooks, args.prompt_len), 0, cfg.vocab_size)}
    elif cfg.frontend == "vision":
        s_text = max(args.prompt_len - cfg.n_patches, 1)
        batch = {"tokens": jax.random.randint(key, (b, s_text), 0,
                                              cfg.vocab_size),
                 "patch_embeds": 0.02 * jax.random.normal(
                     key, (b, cfg.n_patches, cfg.d_model))}
    else:
        batch = {"tokens": jax.random.randint(key, (b, args.prompt_len), 0,
                                              cfg.vocab_size)}

    t0 = time.time()
    logits, caches = prefill(params, batch)
    print(f"[serve] {cfg.name} prefill({args.prompt_len}) "
          f"-> logits {logits.shape} in {time.time()-t0:.2f}s")

    tokens = []
    pos = args.prompt_len
    t0 = time.time()
    for i in range(args.decode_steps):
        key, sk = jax.random.split(key)
        if cfg.frontend == "audio":
            nxt = jax.random.categorical(
                sk, logits[:, -1] / args.temperature, axis=-1)  # (b, K)
            step_batch = {"codes": nxt[..., None].swapaxes(1, 2).reshape(
                b, cfg.n_codebooks, 1)}
        else:
            nxt = jax.random.categorical(
                sk, logits[:, -1] / args.temperature, axis=-1)  # (b,)
            # padded-vocab safety: clamp into the true vocab
            nxt = jnp.minimum(nxt, cfg.vocab_size - 1)
            step_batch = {"tokens": nxt[:, None]}
        tokens.append(nxt)
        logits, caches = decode(params, step_batch, caches, jnp.asarray(pos))
        pos += 1
    dt = time.time() - t0
    print(f"[serve] decoded {args.decode_steps} steps x batch {b} "
          f"in {dt:.2f}s ({dt/args.decode_steps*1e3:.1f} ms/step)")
    first = tokens[0]
    print(f"[serve] first sampled ids: {jnp.ravel(first)[:8].tolist()}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
