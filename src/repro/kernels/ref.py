"""Pure-jnp oracles for every Pallas kernel (tests assert_allclose vs these)."""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True, window: int = 0,
                  scale: Optional[float] = None) -> jnp.ndarray:
    """q (b,sq,h,d), k/v (b,sk,kv,d) -> (b,sq,h,d).  GQA by head grouping."""
    b, sq, h, d = q.shape
    _, sk, kv, _ = k.shape
    g = h // kv
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    qh = q.reshape(b, sq, kv, g, d).astype(jnp.float32)
    s = jnp.einsum("bsngd,btnd->bngst", qh, k.astype(jnp.float32)) * scale
    qpos = jnp.arange(sq)[:, None]
    kpos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.any(mask, -1)[..., None], p, 0.0)
    o = jnp.einsum("bngst,btnd->bsngd", p, v.astype(jnp.float32))
    return o.reshape(b, sq, h, d).astype(q.dtype)


def quantize_ref(x: jnp.ndarray, group: int = 128
                 ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    from repro.core.compression import quantize_int8
    return quantize_int8(x, group)


def dequantize_ref(q: jnp.ndarray, scale: jnp.ndarray, dtype=jnp.float32):
    from repro.core.compression import dequantize_int8
    return dequantize_int8(q, scale, dtype)


def rmsnorm_ref(x: jnp.ndarray, scale: jnp.ndarray,
                eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
            ).astype(x.dtype)


def ssd_ref(x, dt, A, B, C, chunk: int = 64):
    """Mamba2 SSD oracle — delegates to the model's chunked reference,
    which is itself validated against the naive recurrence in tests."""
    from repro.models.ssm import ssd_chunked
    return ssd_chunked(x, dt, A, B, C, chunk)


def ssd_naive(x, dt, A, B, C):
    """O(s * n * p) literal recurrence: the ground truth for both the model
    reference and the Pallas kernel.  x (b,s,h,p), dt (b,s,h), A (h,),
    B/C (b,s,g,n)."""
    b, s, h, p_ = x.shape
    g, n = B.shape[2], B.shape[3]
    rep = h // g
    Bh = jnp.repeat(B, rep, axis=2).astype(jnp.float32)
    Ch = jnp.repeat(C, rep, axis=2).astype(jnp.float32)
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)

    def step(hstate, inp):
        xt, dtt, Bt, Ct = inp
        a = jnp.exp(dtt * A)[..., None, None]          # (b,h,1,1)
        upd = jnp.einsum("bhn,bhp->bhnp", Bt * dtt[..., None], xt)
        hstate = a * hstate + upd
        y = jnp.einsum("bhn,bhnp->bhp", Ct, hstate)
        return hstate, y

    h0 = jnp.zeros((b, h, n, p_), jnp.float32)
    xs = (jnp.moveaxis(xf, 1, 0), jnp.moveaxis(dtf, 1, 0),
          jnp.moveaxis(Bh, 1, 0), jnp.moveaxis(Ch, 1, 0))
    final, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), final
