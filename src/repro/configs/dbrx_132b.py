"""dbrx-132b — fine-grained MoE [hf:databricks/dbrx-base].

[moe] 40L d_model=6144 48H (GQA kv=8) d_ff=10752 vocab=100352,
16 experts top-4.  Pure full attention -> long_500k skipped.
The memory-constrained adaptive cut strategy (core/adaptive.py) forces an
early cut here: one DBRX MoE layer is ~3.3B params, far beyond any
vehicle-side budget — exactly the paper's resource argument.
"""
from repro.configs.base import ATTN_MOE, ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="dbrx-132b",
    family="moe",
    source="hf:databricks/dbrx-base",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=10752,
    vocab_size=100352,
    pattern=(ATTN_MOE,),
    moe=MoEConfig(n_experts=16, top_k=4, n_shared=0, d_ff_expert=10752,
                  capacity_factor=1.25),
    rope_theta=500_000.0,
    default_cut=1,
    param_dtype="bfloat16",
    subquadratic=False,
)
