"""Production mesh + sharding rules.

Mesh: single-pod (data=16, model=16) = 256 chips; multi-pod adds a leading
pod=2 axis (512 chips).  SFL mapping: `data` hosts the vehicle cohorts (the
FedAvg/client axis), `model` is RSU-side tensor parallelism.

Sharding is decided by one divisibility heuristic (``spec_for``): per tensor,
the largest dim divisible by the model-axis size is sharded over `model`
(preferring trailing dims — output features / head_dim); for FSDP-eligible
architectures (>1.5B params) the largest remaining dim divisible by the data
axis is sharded over (`pod`,`data`).  Small leaves (<64 KiB elements) stay
replicated.  KV caches shard batch over the data axes and head_dim/latent
dims over `model` (all assigned head_dims are multiples of 16), so the
decode-time dynamic-update-slice stays shard-local — no cache regather.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeConfig

FSDP_PARAM_THRESHOLD = 1.5e9   # params; above this, shard params over data
REPLICATE_BELOW = 65536        # leaves smaller than this stay replicated


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def _axis_size(mesh: Mesh, axes) -> int:
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def spec_for(shape: Sequence[int], mesh: Mesh, *, skip_dims: Tuple[int, ...] = (),
             batch_dim: Optional[int] = None, fsdp: bool = False,
             size_threshold: int = REPLICATE_BELOW) -> P:
    """The generic divisibility heuristic described in the module docstring."""
    ndim = len(shape)
    entries: list = [None] * ndim
    total = 1
    for d in shape:
        total *= d
    if total < size_threshold:
        return P(*entries)

    used = set(skip_dims)
    dp = dp_axes(mesh)
    # batch dim -> data axes (if divisible)
    if batch_dim is not None and batch_dim not in used:
        if shape[batch_dim] % _axis_size(mesh, dp) == 0:
            entries[batch_dim] = dp if len(dp) > 1 else dp[0]
            used.add(batch_dim)
        elif shape[batch_dim] % mesh.shape["data"] == 0:
            entries[batch_dim] = "data"
            used.add(batch_dim)

    mdl = mesh.shape["model"]
    # model axis: largest divisible dim, preferring trailing dims
    cands = [i for i in range(ndim)
             if i not in used and shape[i] % mdl == 0 and shape[i] >= mdl]
    if cands:
        best = max(cands, key=lambda i: (shape[i], i))
        entries[best] = "model"
        used.add(best)

    if fsdp:
        dn = _axis_size(mesh, dp)
        cands = [i for i in range(ndim)
                 if i not in used and shape[i] % dn == 0 and shape[i] >= dn]
        if cands:
            best = max(cands, key=lambda i: (shape[i], i))
            entries[best] = dp if len(dp) > 1 else dp[0]
        else:
            # fall back to the data axis alone (pod replicates)
            dn = mesh.shape["data"]
            cands = [i for i in range(ndim)
                     if i not in used and shape[i] % dn == 0 and shape[i] >= dn]
            if cands:
                best = max(cands, key=lambda i: (shape[i], i))
                entries[best] = "data"
    return P(*entries)


def _is_segment_path(path) -> bool:
    return any(getattr(p, "key", None) == "segments" or
               str(getattr(p, "key", "")) == "segments" for p in path)


def _leaf_name(path) -> str:
    for p in reversed(path):
        k = getattr(p, "key", None)
        if isinstance(k, str):
            return k
    return ""


# Megatron-style name-aware tensor-parallel rules (§Perf knob): shard OUTPUT
# feature dims (heads / latent heads / d_ff) for column-parallel weights and
# the CONTRACTION dim for the closing row-parallel weight, so each block
# incurs exactly one activation all-reduce instead of one per matmul.
# Maps leaf name -> preferred shard dim counted FROM THE END of the shape
# (period-stack leading axes make absolute indices ambiguous).
_MEGATRON_PREF = {
    # attention: q/k/v column-parallel on heads; wo row-parallel on heads
    "wq": -2, "wk": -2, "wv": -2, "wo": -3,
    # MLA: absorbers column-parallel on heads
    "w_uk": -2, "w_uv": -2, "w_dkv": -1, "w_kr": -1,
    # MLPs: wi column-parallel on d_ff; (mlp) wo handled above (ff at -2)
    "wi_gate": -1, "wi_up": -1, "wi": -1,
    # rglru
    "w_gate": -1, "w_x": -1, "w_a": -1, "w_i": -1, "w_out": -2,
    # ssm
    "in_proj": -1, "in_z": -1, "in_x": -1, "in_b": -1, "in_c": -1,
    "in_dt": -1, "out_proj": -2,
}


def _megatron_spec(path, leaf, mesh: Mesh, fsdp: bool) -> Optional[P]:
    name = _leaf_name(path)
    pref = _MEGATRON_PREF.get(name)
    if pref is None:
        return None
    shape = leaf.shape
    # expert-parallel preference: MoE expert tensors carry a leading expert
    # dim ((n_periods,) e, d, ff) — shard experts over `model` so dispatch/
    # combine lower to the canonical EP all-to-all.
    if name in ("wi_gate", "wi_up", "wo"):
        nd = len(shape) - (1 if _is_segment_path(path) else 0)
        if nd == 4 or (nd == 3 and name != "wo"):
            pref = -3
    if name == "wo" and len(shape) - (1 if _is_segment_path(path) else 0) == 2:
        pref = -2  # plain MLP row-parallel: contract d_ff
    total = 1
    for d in shape:
        total *= d
    if total < REPLICATE_BELOW:
        return P(*([None] * len(shape)))
    i = len(shape) + pref
    if i < 0 or i >= len(shape):
        return None
    mdl = mesh.shape["model"]
    if shape[i] % mdl or shape[i] < mdl:
        return None          # fall back to the generic heuristic
    entries: list = [None] * len(shape)
    entries[i] = "model"
    if fsdp:
        dp = dp_axes(mesh)
        dn = _axis_size(mesh, dp)
        skip0 = 1 if _is_segment_path(path) else 0
        cands = [j for j in range(skip0, len(shape))
                 if j != i and shape[j] % dn == 0 and shape[j] >= dn]
        if cands:
            best = max(cands, key=lambda j: (shape[j], j))
            entries[best] = dp if len(dp) > 1 else dp[0]
    return P(*entries)


def param_specs(cfg: ArchConfig, params_shape: Any, mesh: Mesh,
                megatron: bool = False) -> Any:
    """PartitionSpec pytree mirroring the params tree (works on either real
    params or eval_shape output).  ``megatron=True`` applies the name-aware
    column/row-parallel rules before the generic divisibility heuristic."""
    fsdp = cfg.param_count() > FSDP_PARAM_THRESHOLD

    def rule(path, leaf):
        if megatron:
            spec = _megatron_spec(path, leaf, mesh, fsdp)
            if spec is not None:
                return spec
        skip = (0,) if _is_segment_path(path) else ()
        return spec_for(leaf.shape, mesh, skip_dims=skip, fsdp=fsdp)

    return jax.tree_util.tree_map_with_path(rule, params_shape)


def state_specs(cfg: ArchConfig, state_shape: Any, mesh: Mesh,
                megatron: bool = False) -> Any:
    """Optimizer state mirrors params; scalars replicate."""
    fsdp = cfg.param_count() > FSDP_PARAM_THRESHOLD

    def rule(path, leaf):
        if leaf.ndim == 0:
            return P()
        if megatron:
            spec = _megatron_spec(path, leaf, mesh, fsdp)
            if spec is not None:
                return spec
        skip = (0,) if _is_segment_path(path) else ()
        return spec_for(leaf.shape, mesh, skip_dims=skip, fsdp=fsdp)

    return jax.tree_util.tree_map_with_path(rule, state_shape)


def batch_specs(shape_cfg: ShapeConfig, batch_shape: Any, mesh: Mesh) -> Any:
    def rule(path, leaf):
        if leaf.ndim == 0:
            return P()
        return spec_for(leaf.shape, mesh, batch_dim=0, size_threshold=2)

    return jax.tree_util.tree_map_with_path(rule, batch_shape)


def cache_specs_tree(cache_shape: Any, mesh: Mesh) -> Any:
    """KV/state caches: batch over data axes, trailing feature dims over
    model (head_dim / latent rank / conv channels / d_state)."""
    def rule(path, leaf):
        if leaf.ndim == 0:
            return P()
        if leaf.ndim == 1:        # k_pos vectors etc.
            return P()
        # skip the stacked-period leading axis: caches come stacked like
        # params (n_periods, batch, ...) inside segment scans
        return spec_for(leaf.shape, mesh, skip_dims=(0,), batch_dim=1,
                        size_threshold=2 ** 14)

    return jax.tree_util.tree_map_with_path(rule, cache_shape)


def named(mesh: Mesh, spec_tree: Any) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def smashed_spec(mesh: Mesh, ndim: int = 3) -> P:
    """Smashed data (b, s, d): clients over the data axes — the explicit
    SFL uplink boundary."""
    dp = dp_axes(mesh)
    entries = [dp if len(dp) > 1 else dp[0]] + [None] * (ndim - 1)
    return P(*entries)
