"""Minimal optax-like optimizers (pure JAX; optax is not available offline).

Each optimizer is a pair of pure functions ``init(params) -> state`` and
``update(grads, state, params) -> (updates, state)``; ``apply_updates`` adds
the updates.  States are pytrees mirroring the params, so any sharding rule
that applies to params applies verbatim to optimizer state (ZeRO-style
sharding falls out of the dry-run in_shardings).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Union

import jax
import jax.numpy as jnp

Schedule = Union[float, Callable[[jnp.ndarray], jnp.ndarray]]


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[..., Any]  # (grads, state, params) -> (updates, state)


def _lr_at(lr: Schedule, count: jnp.ndarray) -> jnp.ndarray:
    return lr(count) if callable(lr) else jnp.asarray(lr, jnp.float32)


def _treecast(tree, dtype):
    return jax.tree.map(lambda a: jnp.asarray(a, dtype), tree)


def from_name(name: str, lr: Schedule) -> Optimizer:
    """Optimizer by config name — the single dispatch shared by the
    federation engines (fedsim's per-client oracle, the cohort engine, and
    the fused super-step engine), so a new optimizer wired here reaches all
    of them at once."""
    if name == "adam":
        return adam(lr)
    if name == "sgd":
        return sgd(lr)
    if name == "momentum":
        return momentum(lr)
    raise ValueError(f"unknown optimizer {name!r} "
                     f"(expected adam | sgd | momentum)")


def sgd(lr: Schedule) -> Optimizer:
    def init(params):
        return {"count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params=None):
        step = _lr_at(lr, state["count"])
        upd = jax.tree.map(lambda g: -step * g.astype(jnp.float32), grads)
        return upd, {"count": state["count"] + 1}

    return Optimizer(init, update)


def momentum(lr: Schedule, beta: float = 0.9, nesterov: bool = False) -> Optimizer:
    def init(params):
        return {"count": jnp.zeros((), jnp.int32),
                "mu": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)}

    def update(grads, state, params=None):
        step = _lr_at(lr, state["count"])
        mu = jax.tree.map(lambda m, g: beta * m + g.astype(jnp.float32),
                          state["mu"], grads)
        if nesterov:
            upd = jax.tree.map(lambda m, g: -step * (beta * m + g.astype(jnp.float32)),
                               mu, grads)
        else:
            upd = jax.tree.map(lambda m: -step * m, mu)
        return upd, {"count": state["count"] + 1, "mu": mu}

    return Optimizer(init, update)


def adam(lr: Schedule, b1: float = 0.9, b2: float = 0.999,
         eps: float = 1e-8) -> Optimizer:
    return adamw(lr, b1, b2, eps, weight_decay=0.0)


def adamw(lr: Schedule, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
          weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"count": jnp.zeros((), jnp.int32),
                "m": jax.tree.map(zeros, params),
                "v": jax.tree.map(zeros, params)}

    def update(grads, state, params=None):
        c = state["count"] + 1
        step = _lr_at(lr, state["count"])
        m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g.astype(jnp.float32),
                         state["m"], grads)
        v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * jnp.square(g.astype(jnp.float32)),
                         state["v"], grads)
        bc1 = 1 - b1 ** c.astype(jnp.float32)
        bc2 = 1 - b2 ** c.astype(jnp.float32)

        def upd(m_, v_, p):
            u = -step * (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps)
            if weight_decay:
                u = u - step * weight_decay * p.astype(jnp.float32)
            return u

        updates = jax.tree.map(upd, m, v,
                               params if params is not None else m)
        return updates, {"count": c, "m": m, "v": v}

    return Optimizer(init, update)


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p.astype(jnp.float32) + u).astype(p.dtype),
                        params, updates)


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm
