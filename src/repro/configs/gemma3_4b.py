"""gemma3-4b — 5:1 local:global attention, 128k context [hf:google/gemma-3-1b-pt family].

[dense] 34L d_model=2560 8H (GQA kv=4) d_ff=10240 vocab=262144.
Pattern: (5 sliding-window local + 1 global) x 5 periods + 4 local tail = 34.
Sliding-window local layers (window=1024) make this arch long_500k-eligible:
local KV caches are ring buffers of size 1024; only the 5 global layers hold
the full 512k cache (sharded over the mesh, linear per decoded token).
"""
from repro.configs.base import ATTN, ATTN_LOCAL, ArchConfig

L = ATTN_LOCAL
G = ATTN

CONFIG = ArchConfig(
    name="gemma3-4b",
    family="dense",
    source="hf:google/gemma-3-1b-pt",
    n_layers=34,
    d_model=2560,
    n_heads=8,
    n_kv_heads=4,
    head_dim=256,
    d_ff=10240,
    vocab_size=262144,
    pattern=(L, L, L, L, L, G),
    tail=(L, L, L, L),
    qk_norm=True,
    window=1024,
    mlp_variant="geglu",
    rope_theta=1_000_000.0,
    default_cut=1,
    subquadratic=True,
)
