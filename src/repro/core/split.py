"""Cut-layer splitting of model parameters (paper §III-A: ω = {ω^V; ω^S}).

For the assigned transformer architectures the cut is at *period*
granularity (see models/transformer.py); for ResNet18 it is the paper's 9
unit boundaries.  ``split_params``/``join_params`` are exact inverses —
property-tested in tests/test_split.py.
"""
from __future__ import annotations

from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import transformer as T

Params = Dict[str, Any]


def valid_cuts(cfg: ArchConfig) -> List[int]:
    """Period boundaries 1..P-1 (both sides keep at least one period)."""
    return list(range(1, T.total_periods(cfg)))


def clamp_cut(cfg: ArchConfig, cut: int) -> int:
    return max(1, min(cut, T.total_periods(cfg) - 1))


def split_params(params: Params, cfg: ArchConfig, cut: int
                 ) -> Tuple[Params, Params]:
    """Vehicle side: embed + periods [0, cut).  RSU side: periods [cut, P) +
    final norm + head."""
    cut = clamp_cut(cfg, cut)
    client: Params = {"embed": params["embed"], "segments": []}
    server: Params = {"final_norm": params["final_norm"],
                      "head": params["head"], "segments": []}
    off = 0
    for si, (pat, n) in enumerate(T.segments_of(cfg)):
        lo, hi = max(cut - off, 0), n
        seg = params["segments"][si]
        client["segments"].append(
            jax.tree.map(lambda a: a[:lo], seg) if lo > 0 else None)
        server["segments"].append(
            jax.tree.map(lambda a: a[lo:], seg) if lo < n else None)
        off += n
    client["segments"] = tuple(client["segments"])
    server["segments"] = tuple(server["segments"])
    return client, server


def join_params(client: Params, server: Params, cfg: ArchConfig) -> Params:
    segs = []
    for c_seg, s_seg in zip(client["segments"], server["segments"]):
        if c_seg is None:
            segs.append(s_seg)
        elif s_seg is None:
            segs.append(c_seg)
        else:
            segs.append(jax.tree.map(
                lambda a, b: jnp.concatenate([a, b], axis=0), c_seg, s_seg))
    return {"embed": client["embed"], "segments": tuple(segs),
            "final_norm": server["final_norm"], "head": server["head"]}


def client_forward(client: Params, cfg: ArchConfig, batch, cut: int,
                   mode: str = "train", caches=None, capacity: int = 0,
                   pos_offset: int = 0):
    """Vehicle-side forward: embed + periods [0, cut) -> smashed data."""
    cut = clamp_cut(cfg, cut)
    full_like = {"embed": client["embed"], "segments": client["segments"],
                 "final_norm": None, "head": None}
    if mode == "decode":
        positions = jnp.asarray([pos_offset], jnp.int32)
    else:
        if cfg.frontend == "vision":
            s = batch["tokens"].shape[1] + cfg.n_patches
        elif cfg.frontend == "audio":
            s = batch["codes"].shape[2]
        else:
            s = batch["tokens"].shape[1]
        positions = jnp.arange(s, dtype=jnp.int32)
    x = T.embed_inputs(client, cfg, batch, positions)
    # client segments are the [0, cut) slice: run them fully (start=0)
    x, aux, new_caches = _run_sliced(client["segments"], cfg, x, mode,
                                     positions, caches, capacity)
    return x, positions, aux, new_caches


def server_forward(server: Params, cfg: ArchConfig, smashed, positions,
                   cut: int, mode: str = "train", caches=None,
                   capacity: int = 0):
    """RSU-side forward: periods [cut, P) + head -> logits."""
    x, aux, new_caches = _run_sliced(server["segments"], cfg, smashed, mode,
                                     positions, caches, capacity)
    logits = T.unembed(server, cfg, x)
    return logits, aux, new_caches


def _run_sliced(sliced_segments, cfg: ArchConfig, x, mode, positions,
                caches, capacity):
    """Run pre-sliced stacked segments (client or server part)."""
    aux = jnp.zeros((), jnp.float32)
    out_caches = []
    for si, (pat, _) in enumerate(T.segments_of(cfg)):
        seg = sliced_segments[si]
        if seg is None:
            out_caches.append(None)
            continue
        seg_c = caches[si] if caches is not None else None
        x, a, nc = T._scan_segment(seg, cfg, pat, x, mode, positions, seg_c,
                                   capacity, remat=(mode == "train"))
        aux = aux + a
        out_caches.append(nc)
    return x, aux, tuple(out_caches)


def init_split_caches(cfg: ArchConfig, batch: int, capacity: int, cut: int,
                      dtype=jnp.float32):
    """(client_caches, server_caches) for decode at the given cut."""
    cut = clamp_cut(cfg, cut)
    total = T.total_periods(cfg)
    return (T.init_caches(cfg, batch, capacity, dtype, 0, cut),
            T.init_caches(cfg, batch, capacity, dtype, cut, total))
