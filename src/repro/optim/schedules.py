"""Learning-rate schedules (count -> lr)."""
from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda count: jnp.asarray(lr, jnp.float32)


def linear_warmup(lr: float, warmup_steps: int):
    def f(count):
        frac = jnp.minimum(count.astype(jnp.float32) / max(warmup_steps, 1), 1.0)
        return lr * frac
    return f


def cosine_decay(lr: float, decay_steps: int, alpha: float = 0.0):
    def f(count):
        frac = jnp.clip(count.astype(jnp.float32) / max(decay_steps, 1), 0.0, 1.0)
        cos = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
        return lr * ((1 - alpha) * cos + alpha)
    return f


def warmup_cosine(lr: float, warmup_steps: int, decay_steps: int,
                  alpha: float = 0.0):
    def f(count):
        c = count.astype(jnp.float32)
        warm = lr * c / max(warmup_steps, 1)
        frac = jnp.clip((c - warmup_steps) / max(decay_steps - warmup_steps, 1),
                        0.0, 1.0)
        cos = lr * ((1 - alpha) * 0.5 * (1.0 + jnp.cos(jnp.pi * frac)) + alpha)
        return jnp.where(c < warmup_steps, warm, cos)
    return f
