"""Scenario layer: multi-RSU mobility, handover, hierarchical aggregation,
and residence-aware cut selection (ISSUE 2 acceptance tests + invariants)."""
import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import adaptive, aggregation, channel, cost
from repro.core import scenario as S
from repro.core.fedsim import ScenarioEngine, SimConfig
from repro.data.pipeline import ClientDataset


# ----------------------------------------------------------------- fixtures
class TinyMLP:
    """5-unit split MLP over 16-d vectors — a fast, scan-friendly UnitModel
    for scenario-engine tests (the cohort engine is generic over models)."""
    name = "tiny-mlp"
    scan_friendly = True
    n_units = 5

    def __init__(self, dim=16, width=16, n_classes=4):
        self.dim, self.width, self.n_classes = dim, width, n_classes

    def init(self, key):
        ks = jax.random.split(key, self.n_units + 1)
        units, d_in = [], self.dim
        for i in range(self.n_units):
            units.append({"w": jax.random.normal(ks[i], (d_in, self.width))
                          * math.sqrt(2.0 / d_in),
                          "b": jnp.zeros((self.width,))})
            d_in = self.width
        head = {"w": jax.random.normal(ks[-1], (self.width, self.n_classes))
                * math.sqrt(1.0 / self.width),
                "b": jnp.zeros((self.n_classes,))}
        return units, head

    def apply_units(self, units, x, start):
        for u in units:
            x = jax.nn.relu(x @ u["w"] + u["b"])
        return x

    def head_loss(self, head, feats, labels):
        logits = feats @ head["w"] + head["b"]
        logz = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
        return jnp.mean(logz - gold), logits

    def head_predict(self, head, feats):
        return feats @ head["w"] + head["b"]

    def profile(self):
        w, d = self.width, self.dim
        return cost.SplitProfile(
            name=self.name,
            unit_fwd_flops=[2.0 * d * w] + [2.0 * w * w] * (self.n_units - 1),
            unit_param_bytes=[(d * w + w) * 4]
            + [(w * w + w) * 4] * (self.n_units - 1),
            smashed_bytes_per_sample=[w * 4.0] * self.n_units,
            head_flops=2.0 * w * self.n_classes,
            head_param_bytes=(w * self.n_classes + self.n_classes) * 4,
            smashed_trailing_dim=[w] * self.n_units)


def _vector_clients(n_clients, per_client=24, dim=16, n_classes=4, seed=0):
    rng = np.random.default_rng(seed)
    templates = rng.normal(size=(n_classes, dim)).astype(np.float32)
    clients = []
    for i in range(n_clients):
        y = rng.integers(0, n_classes, size=per_client)
        x = templates[y] + 0.4 * rng.normal(size=(per_client, dim))
        clients.append(ClientDataset(x.astype(np.float32),
                                     y.astype(np.int32), i))
    yt = rng.integers(0, n_classes, size=64)
    xt = templates[yt] + 0.4 * rng.normal(size=(64, dim))
    test = {"images": jnp.asarray(xt.astype(np.float32)),
            "labels": jnp.asarray(yt.astype(np.int32))}
    return clients, test


# ------------------------------------------------------- scenario invariants
@pytest.mark.parametrize("name", sorted(S.SCENARIOS))
def test_scenario_state_invariants(name):
    sc = S.make_scenario(name, 12, seed=3)
    assert len(sc.rsu_positions) >= 2           # genuinely multi-RSU
    for t in (0.0, 7.5, 40.0):
        st = sc.fleet_state(t, seed=11)
        assert st.positions.shape == (12, 2)
        assert st.velocities.shape == (12, 2)
        assert st.serving_rsu.shape == (12,)
        assert st.serving_rsu.max() < len(sc.rsu_positions)
        # covered vehicles: positive rate, finite residence, serving in range
        act = st.active
        assert (st.rates_bps[act] > 0).all()
        assert (st.residence_s[act] >= 0).all()
        # uncovered vehicles are fully inert
        assert (st.rates_bps[~act] == 0).all()
        assert (st.residence_s[~act] == 0).all()
        # pure function of (t, seed)
        st2 = sc.fleet_state(t, seed=11)
        np.testing.assert_array_equal(st.positions, st2.positions)
        np.testing.assert_array_equal(st.rates_bps, st2.rates_bps)


def test_highway_serving_cells_progress():
    """A corridor vehicle is handed cell to cell in road order."""
    sc = S.highway_corridor(1, seed=0, n_rsus=4)
    seen = []
    for t in np.linspace(0, 80, 81):
        r = int(sc.fleet_state(float(t), 0).serving_rsu[0])
        if r >= 0 and (not seen or seen[-1] != r):
            seen.append(r)
    assert len(seen) >= 2                       # crossed at least one border
    # cells are visited in road order (modulo the corridor wrap)
    assert all(b == (a + 1) % sc.n_rsus for a, b in zip(seen, seen[1:]))


def test_urban_grid_stays_on_grid_and_dwells():
    sc = S.urban_grid(16, seed=5, grid_size=4, block_m=100.0, dwell_s=3.0)
    extent = (sc.grid_size - 1) * sc.block_m
    moving_seen = dwelling_seen = False
    for t in np.linspace(0, 120, 49):
        st = sc.fleet_state(float(t), 0)
        assert (st.positions >= -1e-6).all()
        assert (st.positions <= extent + 1e-6).all()
        speed = np.linalg.norm(st.velocities, axis=-1)
        moving_seen |= bool((speed > 0).any())
        dwelling_seen |= bool((speed == 0).any())
    assert moving_seen and dwelling_seen


def test_coverage_exit_time_analytic():
    # vehicle at x=-100 moving +x at 10 m/s inside a 400 m cell centred at 0:
    # exits at x=+400 -> 50 s
    res = S.coverage_exit_time(np.array([[-100.0, 0.0]]),
                               np.array([[10.0, 0.0]]),
                               np.array([[0.0, 0.0]]), 400.0)
    np.testing.assert_allclose(res, [50.0])
    # parked vehicle never exits -> capped
    res = S.coverage_exit_time(np.array([[0.0, 0.0]]),
                               np.array([[0.0, 0.0]]),
                               np.array([[0.0, 0.0]]), 400.0)
    assert res[0] == S.RESIDENCE_CAP_S


# ------------------------------------------- hierarchical aggregation (a)
def test_hierarchical_equals_flat_fedavg():
    """Edge->cloud two-tier FedAvg == flat weighted FedAvg for any grouping
    when cloud weights are the per-edge sample sums."""
    key = jax.random.PRNGKey(0)
    trees = []
    for i in range(7):
        key, k1, k2 = jax.random.split(key, 3)
        trees.append({"w": jax.random.normal(k1, (4, 3)),
                      "b": jax.random.normal(k2, (3,))})
    weights = np.array([3.0, 1.0, 4.0, 1.0, 5.0, 9.0, 2.0])
    for groups in ([0, 0, 1, 1, 2, 2, 2], [2, 0, 1, 0, 2, 1, 0],
                   [0, 0, 0, 0, 0, 0, 0]):
        flat = aggregation.fedavg(trees, weights)
        hier = aggregation.hierarchical_fedavg(trees, weights, groups)
        jax.tree.map(lambda a, b: np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6), flat, hier)


def test_edge_aggregate_weights_are_sample_sums():
    trees = [{"x": jnp.ones(2) * i} for i in range(4)]
    gids, etrees, ew = aggregation.edge_aggregate(
        trees, [1.0, 2.0, 3.0, 4.0], [1, 0, 1, 0])
    assert gids == [0, 1]
    assert ew == [6.0, 4.0]
    np.testing.assert_allclose(np.asarray(etrees[0]["x"]),
                               (2.0 * 1 + 4.0 * 3) / 6.0 * np.ones(2))


# --------------------------------------------- residence-aware cuts (c)
def test_residence_aware_never_exceeds_residence():
    rng = np.random.default_rng(0)
    prof = cost.resnet_profile()
    n = 64
    rates = rng.uniform(2e6, 3e8, n)
    flops = rng.uniform(5e9, 5e10, n)
    residence = rng.uniform(0.05, 60.0, n)
    cuts = adaptive.residence_aware(prof, rates, flops, 2e12, 4, 16, 1,
                                    residence)
    assert len(cuts) == n
    chosen = [i for i, c in enumerate(cuts) if c != adaptive.SKIP]
    assert chosen                                  # some vehicles feasible
    assert len(chosen) < n                         # and some must skip
    for i in chosen:
        rc = cost.sfl_round_cost_arrays(prof, np.array([cuts[i]]), 4, 16,
                                        np.array([rates[i]]),
                                        np.array([flops[i]]), 2e12, 1)
        assert float(rc.latency[0]) <= residence[i] + 1e-9
    # skipped vehicles truly had NO feasible cut
    skipped = [i for i, c in enumerate(cuts) if c == adaptive.SKIP]
    for i in skipped[:8]:
        rc = cost.sfl_round_cost_arrays(
            prof, np.arange(1, prof.n_units), 4, 16, np.array([[rates[i]]]),
            np.array([[flops[i]]]), 2e12, 1)
        assert (rc.latency[0] > residence[i]).all()


def test_residence_aware_prefers_largest_offload():
    """With a generous deadline the smallest (most-offloaded) cut wins."""
    prof = cost.resnet_profile()
    cuts = adaptive.residence_aware(prof, [1e9], [1e11], 2e12, 2, 16, 1,
                                    [1e5])
    assert cuts == [1]


# --------------------------------------------------- handover replay (b)
def _two_cell_trace(rounds, interval):
    """Vehicle 0 drives RSU0 -> RSU1; vehicle 1 parks inside RSU0."""
    times = np.arange(rounds + 1, dtype=np.float64) * interval
    n_steps = len(times)
    x0 = np.linspace(300.0, 900.0, n_steps)      # crosses the 600 m border
    x1 = np.full(n_steps, 250.0)
    x = np.stack([x0, x1], axis=-1)
    pos = np.stack([x, np.zeros_like(x)], axis=-1)
    rsus = np.array([[300.0, 0.0], [900.0, 0.0]])
    ch = channel.ChannelConfig(fading_std_db=0.0, rsu_range_m=320.0)
    return S.TraceReplay(times, pos, rsus, ch=ch, seed=0)


def test_trace_replay_handover_continues_training():
    """A vehicle handing over between RSUs keeps training and its data shard
    keeps contributing to the global model."""
    rounds, interval = 4, 5.0
    sc = _two_cell_trace(rounds, interval)
    clients, test = _vector_clients(2)
    cfg = SimConfig(scheme="asfl", adaptive_strategy="paper", rounds=rounds,
                    local_steps=2, batch_size=8, lr=1e-2, optimizer="sgd",
                    round_interval_s=interval, eval_every=1)
    eng = ScenarioEngine(TinyMLP(), clients, test, cfg, sc,
                         cloud_sync_every=1)

    serving0 = [int(sc.fleet_state(r * interval, 0).serving_rsu[0])
                for r in range(rounds)]
    assert serving0[0] == 0 and serving0[-1] == 1    # the trace crosses

    globals_before = jax.tree.map(lambda a: np.asarray(a).copy(),
                                  {"units": eng.units, "head": eng.head})
    hist = eng.run()
    ho_round = next(r for r in range(1, rounds)
                    if serving0[r] != serving0[r - 1])
    assert hist[ho_round].n_handover >= 1
    # vehicle 0 trained in every round, including after the handover
    assert all(m.n_scheduled == 2 for m in hist)
    # after handover, vehicle 0 is RSU1's ONLY client; RSU1's cohort ran
    assert hist[ho_round].rsu_loads[1] == 1
    # and its shard moved the global model (cloud sync every round)
    l2 = aggregation.tree_l2(aggregation.tree_sub(
        {"units": eng.units, "head": eng.head}, globals_before))
    assert l2 > 0
    assert all(np.isfinite(m.loss) for m in hist)
    # training progressed across the handover, not around it
    assert hist[-1].loss < hist[0].loss


def test_scenario_engine_dynamic_membership_no_crash():
    """Vehicles leaving coverage entirely (empty RSUs, varying cohort sizes)
    must not break the engine or the compile cache."""
    rounds, interval = 3, 5.0
    times = np.arange(rounds + 1, dtype=np.float64) * interval
    # vehicle 0 in cell 0 always; vehicle 1 leaves all coverage at t>=5
    x = np.stack([np.full(len(times), 300.0),
                  300.0 + np.array([0.0, 5000.0, 5000.0, 5000.0])], axis=-1)
    pos = np.stack([x, np.zeros_like(x)], axis=-1)
    rsus = np.array([[300.0, 0.0], [900.0, 0.0]])
    sc = S.TraceReplay(times, pos, rsus,
                       ch=channel.ChannelConfig(fading_std_db=0.0,
                                                rsu_range_m=320.0))
    clients, test = _vector_clients(2)
    cfg = SimConfig(scheme="asfl", adaptive_strategy="paper", rounds=rounds,
                    local_steps=1, batch_size=8, lr=1e-2, optimizer="sgd",
                    round_interval_s=interval, eval_every=0)
    eng = ScenarioEngine(TinyMLP(), clients, test, cfg, sc)
    hist = eng.run()
    assert hist[0].n_scheduled == 2
    assert hist[1].n_scheduled == 1          # vehicle 1 left all coverage
    assert all(np.isfinite(m.loss) for m in hist)


def test_residence_aware_skip_path_in_engine():
    """An in-coverage vehicle whose residence fits no cut sits the round out
    (n_skipped) rather than training."""
    rounds, interval = 1, 5.0
    times = np.array([0.0, 5.0])
    # both vehicles in coverage, but vehicle 1 sits exactly on its cell
    # border moving outward: zero remaining residence, every cut infeasible
    x = np.stack([[300.0, 300.0], [1220.0, 1900.0]], axis=-1)
    pos = np.stack([x, np.zeros_like(x)], axis=-1)
    rsus = np.array([[300.0, 0.0], [900.0, 0.0]])
    sc = S.TraceReplay(times, pos, rsus,
                       ch=channel.ChannelConfig(fading_std_db=0.0,
                                                rsu_range_m=320.0))
    st = sc.fleet_state(0.0, 0)
    assert st.active.all()
    assert st.residence_s[1] < 0.2           # about to leave its cell
    clients, test = _vector_clients(2)
    cfg = SimConfig(scheme="asfl", adaptive_strategy="residence",
                    rounds=rounds, local_steps=2, batch_size=8, lr=1e-2,
                    optimizer="sgd", round_interval_s=interval, eval_every=0)
    eng = ScenarioEngine(TinyMLP(), clients, test, cfg, sc)
    hist = eng.run()
    assert hist[0].cuts[1] == adaptive.SKIP
    assert hist[0].n_skipped >= 1
    assert np.isfinite(hist[0].loss)


# ---------------------------------------------------- city scale-out fixture
# (ISSUE 10: Zipf cell popularity, geometric coverage gaps, O(n) lattice
# association — the scenario the 2-D mesh and slot paging are sized for)

def test_city_zipf_skew_and_coverage_gaps():
    sc = S.city(512, seed=1, grid_x=4, grid_y=4)
    assert sc.n_rsus == 16
    assert sc.rsu_positions.shape == (16, 2)
    st = sc.fleet_state(0.0, seed=0)
    assert st.serving_rsu.min() >= -1 and st.serving_rsu.max() < 16
    # the lattice pitch (900 m) exceeds 2x coverage (400 m): gaps exist,
    # but the orbit radii keep most of the fleet in coverage
    covered = float(st.active.mean())
    assert 0.5 < covered < 0.98
    # Zipf home cells: the hottest cell carries far more than the median
    counts = np.bincount(st.serving_rsu[st.active], minlength=16)
    assert counts.max() > 3 * max(np.median(counts), 1)
    # eccentric orbits breathe across the coverage edge: presence flips
    st2 = sc.fleet_state(30.0, seed=0)
    assert (st.active != st2.active).sum() > 0
    # deterministic in (seed, t)
    twin = S.city(512, seed=1, grid_x=4, grid_y=4)
    np.testing.assert_array_equal(st.serving_rsu,
                                  twin.fleet_state(0.0, seed=0).serving_rsu)
    with pytest.raises(ValueError, match="load_skew"):
        S.city(8, seed=0, grid_x=2, grid_y=2, load_skew="bogus")


def test_city_lattice_association_matches_brute_force():
    """The O(n) floor+clip lattice lookup must agree with the O(n*n_rsus)
    nearest-RSU search: on a square lattice the enclosing cell IS the
    Voronoi cell, and coverage (400 m) never reaches a neighbour's centre."""
    sc = S.city(256, seed=3, grid_x=3, grid_y=5)
    for t in (0.0, 17.0, 123.0):
        st = sc.fleet_state(t, seed=0)
        ref, _ = S.nearest_rsu(st.positions, sc.rsu_positions,
                               sc.ch.rsu_range_m)
        np.testing.assert_array_equal(st.serving_rsu, ref)


def test_city_residence_and_rates_consistent():
    sc = S.city(128, seed=2, grid_x=2, grid_y=2)
    st = sc.fleet_state(5.0, seed=0)
    assert (st.rates_bps[st.active] > 0).all()
    assert (st.rates_bps[~st.active] == 0).all()
    assert (st.residence_s[st.active] > 0).all()
    assert (st.residence_s[~st.active] == 0).all()
    # uniform load (load_skew=None) spreads homes across all cells
    flat = S.city(128, seed=2, grid_x=2, grid_y=2, load_skew=None)
    st_f = flat.fleet_state(5.0, seed=0)
    assert len(np.unique(st_f.serving_rsu[st_f.active])) == 4
