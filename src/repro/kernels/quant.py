"""Per-group symmetric int8 quantisation of smashed data (Pallas).

The SFL uplink compressor (DESIGN.md §5): activations at the cut layer are
quantised to int8 with one f32 scale per 128-element group before crossing
the vehicle->RSU boundary — 4x fewer bytes on the wireless link in the
simulator / the `data`-axis collective in the datacenter realisation.

Tiles are (block_rows, group): the group dim matches the quantisation group
so each tile computes its own scales — no cross-tile reductions.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

GROUP = 128
INV127 = 1.0 / 127.0  # multiply form: bit-identical under eager/jit/interpret


def _quant_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)               # (rows, group)
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-8) * INV127
    q = jnp.clip(jnp.round(x / scale), -127, 127)
    q_ref[...] = q.astype(jnp.int8)
    s_ref[...] = scale


def _dequant_kernel(q_ref, s_ref, x_ref):
    x_ref[...] = (q_ref[...].astype(jnp.float32) * s_ref[...]
                  ).astype(x_ref.dtype)


def quantize_int8(x: jnp.ndarray, group: int = GROUP, block_rows: int = 256,
                  interpret: bool = False):
    """x (..., d) -> (q int8 (..., d), scales (..., ceil(d/g))) with
    g = min(group, d).  Matches core/compression.quantize_int8 (its oracle)
    exactly, including the internal zero-pad of non-divisible trailing dims
    to the next group boundary (the pad never raises a group's amax and is
    sliced off the returned q)."""
    *lead, d = x.shape
    g = min(group, max(d, 1))
    pad_d = (-d) % g
    if pad_d:
        x = jnp.pad(x, [(0, 0)] * len(lead) + [(0, pad_d)])
    dp = d + pad_d
    rows = 1
    for s in lead:
        rows *= s
    x2 = x.reshape(rows, dp // g, g).reshape(rows * (dp // g), g)
    n = x2.shape[0]
    br = min(block_rows, n)
    pad = (-n) % br
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    grid = (x2.shape[0] // br,)
    q, s = pl.pallas_call(
        _quant_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((br, g), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((br, g), lambda i: (i, 0)),
                   pl.BlockSpec((br, 1), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct(x2.shape, jnp.int8),
                   jax.ShapeDtypeStruct((x2.shape[0], 1), jnp.float32)],
        interpret=interpret,
    )(x2)
    if pad:
        q, s = q[:n], s[:n]
    return (q.reshape(*lead, dp)[..., :d],
            s.reshape(*lead, dp // g))


def dequantize_int8(q: jnp.ndarray, scales: jnp.ndarray, group: int = GROUP,
                    dtype=jnp.float32, block_rows: int = 256,
                    interpret: bool = False) -> jnp.ndarray:
    *lead, d = q.shape
    ng = scales.shape[-1]
    g = min(group, max(d, 1))
    if -(-d // g) != ng:
        g = d // ng                    # custom exactly-dividing group
    pad_d = ng * g - d
    if pad_d:
        q = jnp.pad(q, [(0, 0)] * len(lead) + [(0, pad_d)])
    rows = 1
    for s in lead:
        rows *= s
    q2 = q.reshape(rows * ng, g)
    s2 = scales.reshape(rows * ng, 1)
    n = q2.shape[0]
    br = min(block_rows, n)
    pad = (-n) % br
    if pad:
        q2 = jnp.pad(q2, ((0, pad), (0, 0)))
        s2 = jnp.pad(s2, ((0, pad), (0, 0)))
    grid = (q2.shape[0] // br,)
    x = pl.pallas_call(
        _dequant_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((br, g), lambda i: (i, 0)),
                  pl.BlockSpec((br, 1), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((br, g), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(q2.shape, dtype),
        interpret=interpret,
    )(q2, s2)
    if pad:
        x = x[:n]
    return x.reshape(*lead, ng * g)[..., :d]
