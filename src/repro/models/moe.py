"""Mixture-of-Experts FFN — two TPU-friendly formulations:

* **Grouped GShard dispatch/combine** (train / prefill): tokens are tiled
  into groups of ~1024, each group builds a (tpg, E, capacity) one-hot
  dispatch.  Capacity is per-group, so the dispatch tensor is linear in total
  tokens (not quadratic).  With experts sharded over the `model` mesh axis
  this lowers to the canonical expert-parallel all-to-all.
* **Dense-gather** (decode / tiny batches): every expert runs on every token
  and the router gates the sum.  Exact (no capacity drops), cheap when
  T * E * d_ff is small — the right trade at one-token decode.

DBRX: 16 routed top-4.  DeepSeek-V2-Lite: 64 routed top-6 + 2 shared.
Aux load-balance loss follows Switch/GShard.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers as L

Params = Dict[str, Any]

TARGET_TOKENS_PER_GROUP = 1024
DENSE_PATH_MAX_ELEMENTS = 2 ** 27   # T*E*d_ff budget for the dense path


def _expert_ff(cfg: ArchConfig) -> int:
    return cfg.moe.d_ff_expert or cfg.d_ff


def init_moe(key, cfg: ArchConfig, dtype=jnp.float32) -> Params:
    m = cfg.moe
    d, ff, e = cfg.d_model, _expert_ff(cfg), m.n_experts
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    std_in, std_out = 1.0 / math.sqrt(d), 1.0 / math.sqrt(ff)
    p = {
        "router": L.trunc_normal(k1, (d, e), std_in, jnp.float32),
        "wi_gate": L.trunc_normal(k2, (e, d, ff), std_in, dtype),
        "wi_up": L.trunc_normal(k3, (e, d, ff), std_in, dtype),
        "wo": L.trunc_normal(k4, (e, ff, d), std_out, dtype),
    }
    if m.n_shared:
        p["shared"] = L.init_mlp(k5, d, m.n_shared * ff, "swiglu", dtype)
    return p


def _route(p: Params, cfg: ArchConfig, xt: jnp.ndarray):
    """(t,d) -> (probs (t,E), gate_vals (t,k), expert_idx (t,k), aux)."""
    m = cfg.moe
    logits = xt.astype(jnp.float32) @ p["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, m.top_k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)
    onehot = jax.nn.one_hot(expert_idx, m.n_experts, dtype=jnp.float32)
    frac_tokens = jnp.mean(jnp.sum(onehot, axis=1), axis=0)
    frac_probs = jnp.mean(probs, axis=0)
    aux = (m.n_experts * jnp.sum(frac_tokens / m.top_k * frac_probs)
           * m.aux_loss_weight)
    return probs, gate_vals, expert_idx, onehot, aux


def _experts_dense(p: Params, cfg: ArchConfig, xt, gate_vals, expert_idx):
    """All-experts compute, router-gated sum (decode path)."""
    m = cfg.moe
    w = jax.nn.one_hot(expert_idx, m.n_experts, dtype=jnp.float32)
    w = jnp.sum(w * gate_vals[..., None], axis=1)            # (t, E)
    h = jax.nn.silu(jnp.einsum("td,edf->tef", xt, p["wi_gate"].astype(xt.dtype)))
    h = h * jnp.einsum("td,edf->tef", xt, p["wi_up"].astype(xt.dtype))
    out = jnp.einsum("tef,efd->ted", h, p["wo"].astype(xt.dtype))
    return jnp.einsum("te,ted->td", w.astype(xt.dtype), out)


def _pick_groups(t: int) -> int:
    g = max(t // TARGET_TOKENS_PER_GROUP, 1)
    while g > 1 and t % g:
        g -= 1
    return g


def _experts_grouped(p: Params, cfg: ArchConfig, xt, gate_vals, expert_idx,
                     n_groups: Optional[int]):
    """GShard grouped dispatch/combine (train/prefill path)."""
    m = cfg.moe
    t, d = xt.shape
    e, k = m.n_experts, m.top_k
    g = n_groups or _pick_groups(t)
    tpg = t // g
    cap = max(4, min(int(math.ceil(tpg * k / e * m.capacity_factor)), tpg))

    xg = xt.reshape(g, tpg, d)
    idx = expert_idx.reshape(g, tpg, k)
    gates = gate_vals.reshape(g, tpg, k)

    onehot = jax.nn.one_hot(idx, e, dtype=jnp.int32)          # (g,tpg,k,e)
    flat = onehot.reshape(g, tpg * k, e)
    pos = (jnp.cumsum(flat, axis=1) - flat).reshape(g, tpg, k, e)
    pos = jnp.sum(pos * onehot, axis=-1)                      # (g,tpg,k)
    keep = pos < cap
    gates = jnp.where(keep, gates, 0.0)

    combine = jnp.einsum(
        "gtke,gtkc->gtec",
        (onehot * keep[..., None]).astype(jnp.float32),
        jax.nn.one_hot(pos, cap, dtype=jnp.float32) * gates[..., None])
    dispatch = (combine > 0).astype(xt.dtype)                 # (g,tpg,e,cap)

    expert_in = jnp.einsum("gtec,gtd->gecd", dispatch, xg)    # all-to-all here
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", expert_in,
                               p["wi_gate"].astype(xt.dtype)))
    h = h * jnp.einsum("gecd,edf->gecf", expert_in, p["wi_up"].astype(xt.dtype))
    expert_out = jnp.einsum("gecf,efd->gecd", h, p["wo"].astype(xt.dtype))
    y = jnp.einsum("gtec,gecd->gtd", combine.astype(xt.dtype), expert_out)
    return y.reshape(t, d)


def moe_forward(p: Params, cfg: ArchConfig, x: jnp.ndarray,
                n_groups: Optional[int] = None
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x (..., d) -> (y, aux_loss)."""
    m = cfg.moe
    orig_shape = x.shape
    d = orig_shape[-1]
    xt = x.reshape(-1, d)
    t = xt.shape[0]

    _, gate_vals, expert_idx, _, aux = _route(p, cfg, xt)
    if t * m.n_experts * _expert_ff(cfg) <= DENSE_PATH_MAX_ELEMENTS:
        y = _experts_dense(p, cfg, xt, gate_vals, expert_idx)
    else:
        y = _experts_grouped(p, cfg, xt, gate_vals, expert_idx, n_groups)

    if m.n_shared:
        y = y + L.mlp(p["shared"], xt, "swiglu")
    return y.reshape(orig_shape), aux


def capacity(n_tokens: int, cfg: ArchConfig) -> int:
    m = cfg.moe
    g = _pick_groups(n_tokens)
    tpg = n_tokens // g
    return max(4, min(int(math.ceil(tpg * m.top_k / m.n_experts
                                    * m.capacity_factor)), tpg))


def moe_flops(cfg: ArchConfig) -> int:
    """Active matmul FLOPs per token (routed top-k + shared)."""
    m, d, ff = cfg.moe, cfg.d_model, _expert_ff(cfg)
    per_expert = 2 * 3 * d * ff
    return m.top_k * per_expert + m.n_shared * per_expert + 2 * d * m.n_experts
