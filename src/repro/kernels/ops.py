"""Jit'd public wrappers for the Pallas kernels.

On this CPU container the kernels run in interpret mode (set
``REPRO_PALLAS_INTERPRET=1``, the default off-TPU); on TPU they compile to
Mosaic.  Each wrapper falls back to the jnp reference when
``use_kernel=False`` — the models use the reference path by default so CPU
tests stay fast, and the launch scripts flip them to kernels on TPU.
"""
from __future__ import annotations

import functools
import os
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ref as REF
from repro.kernels.flash_attention import flash_attention as _fa
from repro.kernels.quant import dequantize_int8 as _dq, quantize_int8 as _q
from repro.kernels.rmsnorm import rmsnorm as _rms
from repro.kernels.ssd import ssd_chunk_scan as _ssd


def _interpret() -> bool:
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env not in ("0", "false", "")
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "use_kernel",
                                             "block_q", "block_k"))
def attention(q, k, v, *, causal: bool = True, window: int = 0,
              use_kernel: bool = True, block_q: int = 128,
              block_k: int = 128) -> jnp.ndarray:
    if not use_kernel:
        return REF.attention_ref(q, k, v, causal=causal, window=window)
    return _fa(q, k, v, causal=causal, window=window, block_q=block_q,
               block_k=block_k, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("group", "use_kernel"))
def quantize(x, *, group: int = 128, use_kernel: bool = True):
    if not use_kernel:
        return REF.quantize_ref(x, group)
    return _q(x, group, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("use_kernel",))
def dequantize(q, scales, *, use_kernel: bool = True):
    if not use_kernel:
        return REF.dequantize_ref(q, scales)
    return _dq(q, scales, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("eps", "use_kernel"))
def rmsnorm(x, scale, *, eps: float = 1e-6, use_kernel: bool = True):
    if not use_kernel:
        return REF.rmsnorm_ref(x, scale, eps)
    return _rms(x, scale, eps, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("chunk", "use_kernel"))
def ssd(x, dt, A, B, C, *, chunk: int = 128, use_kernel: bool = True):
    if not use_kernel:
        y, _ = REF.ssd_ref(x, dt, A, B, C, chunk)
        return y
    return _ssd(x, dt, A, B, C, chunk=chunk, interpret=_interpret())
