"""Ragged super-steps (ISSUE 7 acceptance tests, DESIGN.md §12):
cut-prefix parameter planes + occupancy-compacted slot scheduling.

The load-bearing claims:

* ragged == dense bit-for-bit for sgd on BOTH server schedules, through a
  window containing a handover, a cloud merge, and a cut change (the
  two-cell trace) — with and without the EF wire carry planes;
* zero compile fallbacks / zero backend compiles across cut churn (the
  prefix bucket and compacted slot count are part of the static program
  signature, so retracing would be a bug, not a slowdown);
* the compacted layout's compiled program needs less temp memory than the
  dense one (the peak-device-memory smoke CI runs via ``-k memory``);
* occupancy accounting is honest: the bench columns derive from
  ``ScenarioEngine.occupancy_stats()`` asserted here.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import scenario as S
from repro.core.fedsim import ScenarioEngine, SimConfig
from repro.core.superstep import (SUPERSTEP_LAYOUTS, cut_prefix_bucket,
                                  owned_window)

from test_scenario import TinyMLP, _two_cell_trace, _vector_clients

ROUNDS, INTERVAL = 6, 5.0


def _cfg(layout, **kw):
    base = dict(scheme="asfl", adaptive_strategy="paper", rounds=ROUNDS,
                local_steps=2, batch_size=8, lr=1e-2, optimizer="sgd",
                round_interval_s=INTERVAL, eval_every=0, superstep=3,
                superstep_layout=layout)
    base.update(kw)
    return SimConfig(**base)


def _engine(layout, **kw):
    sc = _two_cell_trace(ROUNDS, INTERVAL)
    clients, test = _vector_clients(2)
    return ScenarioEngine(TinyMLP(), clients, test, _cfg(layout, **kw), sc,
                          cloud_sync_every=2)


def _params(eng):
    return jax.tree.map(np.asarray, {"units": eng.units, "head": eng.head})


# ------------------------------------------------- ragged == dense, sgd
@pytest.mark.parametrize("wire", ["none", "topk_int8"])
@pytest.mark.parametrize("schedule", ["sequential", "parallel"])
def test_ragged_matches_dense_bitforbit(schedule, wire):
    """The compacted layout is a pure re-layout: sgd training through a
    handover, a mid-window cloud merge, and the trace's cut churn is
    bit-identical to the dense masked path — including the EF residual
    planes (their prefix sizing covers every reachable boundary)."""
    er = _engine("ragged", server_schedule=schedule, wire=wire)
    ed = _engine("dense", server_schedule=schedule, wire=wire)
    hr, hd = er.run(), ed.run()
    assert sum(m.n_handover for m in hr) >= 1
    assert [m.cuts for m in hr] == [m.cuts for m in hd]
    assert [m.rsu_loads for m in hr] == [m.rsu_loads for m in hd]
    np.testing.assert_array_equal([m.loss for m in hr],
                                  [m.loss for m in hd])
    jax.tree.map(np.testing.assert_array_equal, _params(er), _params(ed))
    if wire == "topk_int8":
        np.testing.assert_array_equal(np.asarray(er._carry["wire_res"]),
                                      np.asarray(ed._carry["wire_res"]))
        np.testing.assert_array_equal(np.asarray(er._carry["wire_cut"]),
                                      np.asarray(ed._carry["wire_cut"]))


@pytest.mark.parametrize("schedule", ["sequential", "parallel"])
def test_ragged_matches_dense_adam_tolerance(schedule):
    """adam re-associates nothing extra in the ragged layout, but moment
    planes live on the prefix window; parity within the engine-parity fp
    tolerance (acceptance wording)."""
    er = _engine("ragged", server_schedule=schedule, optimizer="adam")
    ed = _engine("dense", server_schedule=schedule, optimizer="adam")
    hr, hd = er.run(), ed.run()
    np.testing.assert_allclose([m.loss for m in hr], [m.loss for m in hd],
                               rtol=1e-5, atol=1e-5)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        a, b, atol=1e-5, rtol=1e-5), _params(er), _params(ed))


# ------------------------------------------- static signatures, no churn
def test_zero_fallbacks_across_cut_churn():
    """The prefix bucket / compacted slot count are pow2-bucketed STATICS:
    precompile covers the whole run and no backend compile fires mid-run
    even as cuts and membership churn (jax.monitoring listener — the same
    harness as tests/test_superstep.py)."""
    for schedule in ("sequential", "parallel"):
        eng = _engine("ragged", server_schedule=schedule, wire="topk_int8")
        eng.precompile()
        events = []
        jax.monitoring.register_event_duration_secs_listener(
            lambda name, *a, **kw: events.append(name))
        baseline = len([e for e in events if "compile" in e])
        hist = eng.run()
        assert eng.programs.compile_fallbacks == 0
        assert not [e for e in events[baseline:] if "compile" in e]
        assert len(hist) == ROUNDS


def test_signature_carries_slots_and_max_cut():
    """The compile-cache key: ragged+parallel signatures carry the planned
    compacted slot capacity; everything else keys slots=0.  max_cut is the
    strategy's pow2 prefix bucket (TinyMLP, paper thresholds: 4 of 5
    units)."""
    ep = _engine("ragged", server_schedule="parallel")
    sig = ep.programs.signature(3, 2, 8)
    assert sig.slots == 8 and sig.max_cut == 4
    # unplanned callers fall back to the uncompacted R*capacity bound
    assert ep.programs.signature(3, 2).slots == \
        ep.programs.n_rsus_padded * 2
    es = _engine("ragged", server_schedule="sequential")
    assert es.programs.signature(3, 2, 8).slots == 0
    ed = _engine("dense", server_schedule="parallel")
    assert ed.programs.signature(3, 2, 8).slots == 0
    assert ed.programs.signature(3, 2, 8).max_cut == 0


def test_prefix_plane_window():
    """TinyMLP under paper thresholds: bucket 4 of 5 units, so the client
    plane window owns units 0..3 (head + unit 4 excluded) and the EF wire
    sizing still covers every reachable boundary (== dense here: unit ids
    below the bucket include every candidate cut)."""
    er = _engine("ragged", wire="topk_int8")
    ed = _engine("dense", wire="topk_int8")
    pg = er.programs
    assert pg.max_cut_bucket == 4
    ids = pg.unit_ids_np
    assert pg.plane_width == int((ids < 4).sum())
    o, w = pg.plane_offset, pg.plane_width
    assert (np.sort(np.flatnonzero(ids < 4)) == np.arange(o, o + w)).all()
    assert pg.wire_units == min(pg.model.n_units - 1, pg.max_cut_bucket)
    assert pg.res_size == ed.programs.res_size


def test_layout_validation_and_spec_wiring():
    with pytest.raises(ValueError, match="superstep_layout"):
        SimConfig(superstep_layout="diagonal")
    assert set(SUPERSTEP_LAYOUTS) == {"ragged", "dense"}
    from repro import api
    spec = api.ExperimentSpec(
        fleet=api.FleetConfig(n_vehicles=4, scenario="trace_replay"),
        runtime=api.RuntimeConfig(superstep_layout="dense"))
    assert spec.to_sim_config().superstep_layout == "dense"
    assert api.ExperimentSpec().to_sim_config().superstep_layout == "ragged"


# -------------------------------------------------- occupancy accounting
def test_occupancy_stats_are_honest():
    """The bench columns' source of truth: executed slots, padded fraction,
    prefix plane fraction.  On the two-cell trace the dense layout pads
    2 RSUs x capacity while the compacted one executes the bucketed total
    covered count."""
    er = _engine("ragged", server_schedule="parallel")
    ed = _engine("dense", server_schedule="parallel")
    hr, hd = er.run(), ed.run()
    occ_r, occ_d = er.occupancy_stats(), ed.occupancy_stats()
    assert occ_r["layout"] == "ragged" and occ_d["layout"] == "dense"
    mean = float(np.mean([m.n_scheduled for m in hr]))
    assert occ_r["mean_occupied_slots"] == mean
    for occ in (occ_r, occ_d):
        assert 0.0 <= occ["padded_slot_frac"] <= 1.0
        assert 0.0 < occ["effective_flops_utilization"] <= 1.0
        assert abs(occ["padded_slot_frac"]
                   + occ["effective_flops_utilization"] - 1.0) < 1e-9
    assert occ_r["executed_slots"] <= occ_d["executed_slots"]
    assert occ_r["owned_plane_frac"] < 1.0 == occ_d["owned_plane_frac"]


def test_compacted_overflow_raises():
    """A signature planned for fewer slots than the fleet occupies must
    fail loudly (truncated cohorts would train silently wrong)."""
    eng = _engine("ragged", server_schedule="parallel")
    eng._covered_totals = {r: 0 for r in range(ROUNDS)}

    def fake(horizon):
        return 1                                # plan 1 slot, serve 2
    eng._total_slots = fake
    with pytest.raises(RuntimeError, match="compacted"):
        eng.run_superstep(0, 3)


# -------------------------------------------------- zipf skewed arrivals
def test_zipf_load_skew_biases_initial_cells():
    """load_skew="zipf" piles initial arrivals onto the low-index cells;
    kinematics are untouched (same speeds as the uniform twin)."""
    uni = S.make_scenario("highway_corridor", 64, seed=7)
    zip_ = S.make_scenario("highway_zipf", 64, seed=7)
    assert zip_.name == "highway_zipf"
    np.testing.assert_array_equal(uni._speed, zip_._speed)
    s = zip_.fleet_state(0.0, seed=0).serving_rsu
    loads = np.bincount(s[s >= 0], minlength=zip_.n_rsus)
    # zipf mass ~ 1/(k+1): cell 0 clearly dominates the tail cell
    assert loads[0] > 2 * max(loads[-1], 1)
    with pytest.raises(ValueError, match="load_skew"):
        S.HighwayCorridor(n_vehicles=4, load_skew="bogus")


def test_zipf_runs_ragged_parallel():
    """The skewed scenario trains end-to-end on the compacted layout with
    zero fallbacks — and compaction beats the dense grid where it matters:
    fewer executed slots than n_rsus_padded * capacity."""
    n = 16
    sc = S.make_scenario("highway_zipf", n, seed=3)
    clients, test = _vector_clients(n)
    cfg = _cfg("ragged", server_schedule="parallel", rounds=4, superstep=4)
    eng = ScenarioEngine(TinyMLP(), clients, test, cfg, sc,
                         cloud_sync_every=2)
    eng.precompile()
    hist = eng.run()
    assert eng.programs.compile_fallbacks == 0
    assert all(np.isfinite(m.loss) for m in hist)
    occ = eng.occupancy_stats()
    cap = eng._capacity(4)
    assert occ["executed_slots"] < eng.programs.n_rsus_padded * cap


# --------------------------------------------- peak-device-memory smoke
def test_memory_compacted_below_dense():
    """The CI peak-device-memory smoke (``-k memory``): on the skewed
    fleet the ragged+parallel executable's temp allocation stays below the
    dense one's — the compacted slot axis and prefix planes are where the
    O(n_rsus * capacity * P) dense working set goes."""
    n = 32
    engines = {}
    for layout in ("ragged", "dense"):
        sc = S.make_scenario("highway_zipf", n, seed=5)
        clients, test = _vector_clients(n)
        cfg = _cfg(layout, server_schedule="parallel", rounds=4,
                   superstep=4)
        eng = ScenarioEngine(TinyMLP(), clients, test, cfg, sc,
                             cloud_sync_every=2)
        eng.precompile()
        engines[layout] = eng

    def temp_bytes(eng):
        tots = []
        for prog in eng.programs._programs.values():
            ma = getattr(prog, "memory_analysis", None)
            if ma is None:
                pytest.skip("compiled memory_analysis unavailable "
                            "on this backend")
            tots.append(int(ma().temp_size_in_bytes))
        return max(tots)

    ragged, dense = temp_bytes(engines["ragged"]), \
        temp_bytes(engines["dense"])
    assert ragged < dense, (ragged, dense)
