"""Fused wire pipeline for smashed data (Pallas): top-k sparsify + int8
group-quantise + pack in ONE kernel, and dequant fused into the consuming
matmul (DESIGN.md §11).

Wire format per quantisation group (g values, exactly k survivors):

    [ bitmap: ceil(g/32) int32 words | scale: 1 word (f32 bitcast) |
      values: ceil(k/4) int32 words, 4 int8 lanes each, survivor order ]

``core/compression.py`` holds the jnp oracles; every kernel here is
bit-exact against them in interpret mode (asserted in tier-1 CI on CPU).
Tiles are (block_rows, group) like kernels/quant.py: the group dim matches
the quantisation group so a tile packs its own groups with no cross-tile
traffic — the dense fp32 tensor never leaves the tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.compression import (GROUP, INV127, WIRE_K,
                                    wire_layout)


def _pack_tile(x, k, bw, vw):
    """(br, g) f32 -> (br, bw+1+vw) int32 packed words.  Pure jnp so the
    same code serves the pack kernel and the fused-consumption kernels."""
    br, g = x.shape
    absx = jnp.abs(x)
    amax = jnp.max(absx, axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-8) * INV127
    ii = jax.lax.broadcasted_iota(jnp.int32, (g, g), 0)   # candidate
    jj = jax.lax.broadcasted_iota(jnp.int32, (g, g), 1)   # competitor
    beats = ((absx[:, None, :] > absx[:, :, None])
             | ((absx[:, None, :] == absx[:, :, None]) & (jj < ii)))
    mask = jnp.sum(beats.astype(jnp.int32), axis=-1) < k  # rank < k
    q = jnp.where(mask, jnp.clip(jnp.round(x / scale), -127, 127),
                  0).astype(jnp.int32)
    m32 = mask.astype(jnp.int32)
    pad_b = bw * 32 - g
    mb = jnp.concatenate([m32, jnp.zeros((br, pad_b), jnp.int32)],
                         axis=-1) if pad_b else m32
    shifts = jax.lax.broadcasted_iota(jnp.int32, (bw, 32), 1)
    bitmap = jnp.sum(jnp.left_shift(mb.reshape(br, bw, 32), shifts), axis=-1)
    pos = jnp.cumsum(m32, axis=-1) - 1
    slot = jax.lax.broadcasted_iota(jnp.int32, (g, k), 1)
    onehot = ((pos[..., None] == slot) & mask[..., None]).astype(jnp.int32)
    vals = jnp.sum(q[..., None] * onehot, axis=-2)         # (br, k)
    pad_v = vw * 4 - k
    vb = jnp.concatenate([vals, jnp.zeros((br, pad_v), jnp.int32)],
                         axis=-1) if pad_v else vals
    lanes = jax.lax.broadcasted_iota(jnp.int32, (vw, 4), 1)
    words = jnp.sum(jnp.left_shift(
        jnp.bitwise_and(vb.reshape(br, vw, 4), 0xFF), 8 * lanes), axis=-1)
    sword = jax.lax.bitcast_convert_type(scale, jnp.int32)  # (br, 1)
    return jnp.concatenate([bitmap, sword, words], axis=-1)


def _unpack_tile(buf, g, k, bw, vw):
    """(br, bw+1+vw) int32 -> (q int32 (br, g), scale f32 (br,))."""
    br = buf.shape[0]
    bitmap = buf[:, :bw]
    scale = jax.lax.bitcast_convert_type(buf[:, bw], jnp.float32)
    words = buf[:, bw + 1:]
    shifts = jax.lax.broadcasted_iota(jnp.int32, (bw, 32), 1)
    mask = jnp.bitwise_and(jnp.right_shift(bitmap[..., None], shifts), 1
                           ).reshape(br, bw * 32)[:, :g].astype(bool)
    lanes = jax.lax.broadcasted_iota(jnp.int32, (vw, 4), 1)
    bytes_ = jnp.bitwise_and(jnp.right_shift(words[..., None], 8 * lanes),
                             0xFF)
    vals = bytes_.reshape(br, vw * 4)[:, :k]
    vals = vals - 256 * (vals > 127)                       # sign-extend int8
    pos = jnp.cumsum(mask.astype(jnp.int32), axis=-1) - 1
    slot = jax.lax.broadcasted_iota(jnp.int32, (g, k), 1)
    onehot = ((pos[..., None] == slot) & mask[..., None]).astype(jnp.int32)
    q = jnp.sum(vals[:, None, :] * onehot, axis=-1)        # (br, g)
    return q, scale


def _pack_kernel(x_ref, o_ref, *, k, bw, vw):
    o_ref[...] = _pack_tile(x_ref[...].astype(jnp.float32), k, bw, vw)


def _unpack_dequant_kernel(b_ref, x_ref, *, g, k, bw, vw):
    q, scale = _unpack_tile(b_ref[...], g, k, bw, vw)
    x_ref[...] = (q.astype(jnp.float32) * scale[:, None]).astype(x_ref.dtype)


def _unpack_matmul_kernel(b_ref, w_ref, o_ref, *, g, k, bw, vw, ng, wpg):
    buf = b_ref[...]                                       # (br, ng*wpg)
    w = w_ref[...].astype(jnp.float32)                     # (ng*g, n)
    acc = jnp.zeros((buf.shape[0], w.shape[-1]), jnp.float32)
    for j in range(ng):                                    # static: unrolled
        q, scale = _unpack_tile(buf[:, j * wpg:(j + 1) * wpg], g, k, bw, vw)
        dense = q.astype(jnp.float32) * scale[:, None]
        acc = acc + jnp.dot(dense, w[j * g:(j + 1) * g])
    o_ref[...] = acc


def _rows(lead):
    rows = 1
    for s in lead:
        rows *= s
    return rows


def sparsify_quant_pack(x: jnp.ndarray, k_frac: float = WIRE_K,
                        group: int = GROUP, block_rows: int = 256,
                        interpret: bool = False) -> jnp.ndarray:
    """x (..., d) -> packed int32 wire buffer (..., ng*wpg), one fused pass:
    top-k select, int8 quantise, bitmap/scale/value pack.  Bit-exact oracle:
    ``core.compression.sparsify_quant_pack_ref``."""
    *lead, d = x.shape
    g, ng, k, wpg = wire_layout(d, k_frac, group)
    bw, vw = -(-g // 32), -(-k // 4)
    pad_d = ng * g - d
    if pad_d:
        x = jnp.pad(x, [(0, 0)] * len(lead) + [(0, pad_d)])
    x2 = x.reshape(_rows(lead) * ng, g)
    n = x2.shape[0]
    br = min(block_rows, n)
    pad = (-n) % br
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    buf = pl.pallas_call(
        functools.partial(_pack_kernel, k=k, bw=bw, vw=vw),
        grid=(x2.shape[0] // br,),
        in_specs=[pl.BlockSpec((br, g), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((br, wpg), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((x2.shape[0], wpg), jnp.int32),
        interpret=interpret,
    )(x2)
    if pad:
        buf = buf[:n]
    return buf.reshape(*lead, ng * wpg)


def unpack_dequant(buf: jnp.ndarray, d: int, k_frac: float = WIRE_K,
                   group: int = GROUP, dtype=jnp.float32,
                   block_rows: int = 256, interpret: bool = False
                   ) -> jnp.ndarray:
    """Packed buffer (..., ng*wpg) -> dense (..., d).  The symmetric
    downlink consumer (cut-layer gradients).  Oracle:
    ``core.compression.wire_dequant_ref``."""
    *lead, _ = buf.shape
    g, ng, k, wpg = wire_layout(d, k_frac, group)
    bw, vw = -(-g // 32), -(-k // 4)
    b2 = buf.reshape(_rows(lead) * ng, wpg)
    n = b2.shape[0]
    br = min(block_rows, n)
    pad = (-n) % br
    if pad:
        b2 = jnp.pad(b2, ((0, pad), (0, 0)))
    x = pl.pallas_call(
        functools.partial(_unpack_dequant_kernel, g=g, k=k, bw=bw, vw=vw),
        grid=(b2.shape[0] // br,),
        in_specs=[pl.BlockSpec((br, wpg), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((br, g), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b2.shape[0], g), dtype),
        interpret=interpret,
    )(b2)
    if pad:
        x = x[:n]
    return x.reshape(*lead, ng * g)[..., :d]


def unpack_dequant_matmul(buf: jnp.ndarray, w: jnp.ndarray,
                          k_frac: float = WIRE_K, group: int = GROUP,
                          block_rows: int = 128, interpret: bool = False
                          ) -> jnp.ndarray:
    """buf (rows, ng*wpg) @ w (d, n) -> (rows, n) f32 with dequant fused
    into the matmul epilogue: each row tile unpacks one g-wide slab at a
    time and accumulates, so the dense fp32 smashed tensor is never
    materialised server-side.  Oracle (same accumulation order):
    ``core.compression.wire_dequant_matmul_ref``."""
    rows, _ = buf.shape
    d, n = w.shape
    g, ng, k, wpg = wire_layout(d, k_frac, group)
    bw, vw = -(-g // 32), -(-k // 4)
    pad_d = ng * g - d
    wp = jnp.pad(w, ((0, pad_d), (0, 0))) if pad_d else w
    br = min(block_rows, rows)
    pad = (-rows) % br
    b2 = jnp.pad(buf, ((0, pad), (0, 0))) if pad else buf
    out = pl.pallas_call(
        functools.partial(_unpack_matmul_kernel, g=g, k=k, bw=bw, vw=vw,
                          ng=ng, wpg=wpg),
        grid=(b2.shape[0] // br,),
        in_specs=[pl.BlockSpec((br, ng * wpg), lambda i: (i, 0)),
                  pl.BlockSpec((ng * g, n), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((br, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b2.shape[0], n), jnp.float32),
        interpret=interpret,
    )(b2, wp)
    if pad:
        out = out[:rows]
    return out
