"""One canonical write path for every benchmark artifact.

Before this module each driver open-coded its own ``json.dump`` loop, so
root ``BENCH_*.json`` and ``benchmarks/out/*.json`` were written separately
(and could drift), and nothing inside a JSON recorded which driver — with
which flags — produced it (the ``fig5*.json`` files were fully orphaned).

:func:`write_bench` writes the canonical copy under ``benchmarks/out/`` and
byte-copies it to the repo root (the committed-baseline location the CI
perf gate reads) when ``mirror_root=True``; every artifact gets a
``provenance`` block: the driver path, its argv, and where the canonical /
mirror copies live, so any JSON found in the tree is reproducible from its
own contents.
"""
from __future__ import annotations

import json
import os
import shutil
import sys
from typing import Any, List, Optional

OUT_DIR = os.path.join(os.path.dirname(os.path.abspath(__file__)), "out")
ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def device_row_key(base: str, devices: int) -> str:
    """The shared ``rounds_per_s`` key format for per-device-count rows
    (baseline matching in bench_scenarios/bench_superstep keys off it, so
    it must not drift between drivers)."""
    return base if devices == 1 else f"{base}x{devices}dev"


def write_bench(name: str, out: Any, driver: str, *,
                mirror_root: bool = True,
                argv: Optional[List[str]] = None) -> List[str]:
    """Write ``benchmarks/out/<name>.json`` (canonical) and, for the
    committed baselines, copy it to ``<repo root>/<name>.json``.  ``out``
    gains a ``provenance`` block (non-dict payloads are wrapped as
    ``{"rows": ...}`` first).  Returns the paths written."""
    if not isinstance(out, dict):
        out = {"rows": out}
    else:
        out = dict(out)
    out["provenance"] = {
        "driver": driver,
        "argv": list(sys.argv[1:] if argv is None else argv),
        "canonical": f"benchmarks/out/{name}.json",
        "root_mirror": f"{name}.json" if mirror_root else None,
    }
    os.makedirs(OUT_DIR, exist_ok=True)
    canonical = os.path.join(OUT_DIR, f"{name}.json")
    with open(canonical, "w") as f:
        json.dump(out, f, indent=1, default=float)
    paths = [canonical]
    if mirror_root:
        mirror = os.path.join(ROOT, f"{name}.json")
        shutil.copyfile(canonical, mirror)
        paths.append(mirror)
    print(f"wrote {' + '.join(paths)}")
    return paths
