"""Declarative experiment specs: nested config groups over one flat engine
config, validated against the registries at construction time.

An :class:`ExperimentSpec` is pure data — strings, numbers, and six nested
groups — that fully determines a federation experiment:

* :class:`TrainConfig` — the learning loop: scheme, batches, epochs/steps,
  optimizer, lr, rounds, eval cadence, smashed-data compression, and the
  RSU server schedule.
* :class:`AdaptiveConfig` — cut selection: the strategy (registry-validated
  per engine) and the fixed cut for ``sl``/``sfl``.
* :class:`FleetConfig` — who trains where: fleet size, the mobility
  scenario (``"single_rsu"``/None routes to the single-RSU engine), cloud
  sync cadence, data sizing, and the analytic-cost knobs.
* :class:`RuntimeConfig` — XLA execution: seed, intra-bucket schedule,
  super-step fusion K, slot capacity, AOT precompile, compilation cache.
* :class:`FaultsConfig` — the fault plane (core/faults.py, DESIGN.md §13):
  seeded dropout / upload-loss / straggler / RSU-outage processes plus the
  legacy coverage test.  All-defaults = no faults, byte-identical programs.
* :class:`StreamConfig` — the streaming plane (core/streaming.py,
  DESIGN.md §14): seeded continuous arrival/departure churn plus the
  buffered-asynchronous merge knobs consumed by
  ``train.server_schedule="streaming"``.  All-defaults = no streaming,
  byte-identical programs (the fault-plane contract).

Validation happens in ``__post_init__``: unknown registry keys, field
values outside the allowed sets, and combinations the selected engine
cannot execute (e.g. ``strategy="latency"`` on the multi-RSU engine, whose
cut selection runs on-device) all raise ``ValueError`` with the allowed
values listed — at spec-build time, not rounds-deep inside engine dispatch.

``to_json``/``from_json`` round-trip every spec; ``to_sim_config`` /
``from_sim_config`` are the deprecation shim onto the flat
:class:`~repro.core.fedsim.SimConfig` the engines still consume
(field-for-field, asserted in tests/test_api.py).
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, Optional, Tuple, Union

from repro.api import registry
from repro.core.fedsim import SimConfig

__all__ = [
    "TrainConfig", "AdaptiveConfig", "FleetConfig", "RuntimeConfig",
    "FaultsConfig", "StreamConfig", "ExperimentSpec",
    "SIM_CONFIG_FIELD_MAP",
]


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    """The learning loop (paper defaults: batch 16, 5 local epochs,
    lr 1e-4)."""
    scheme: str = "asfl"              # cl | fl | sl | sfl | asfl
    batch_size: int = 16
    local_epochs: int = 5
    local_steps: Optional[int] = None  # overrides epochs if set
    lr: float = 1e-4
    rounds: int = 10
    optimizer: str = "adam"           # adam | sgd | momentum
    eval_every: int = 1               # 0 = never
    compress_smashed: bool = False    # legacy alias for wire="int8"
    server_schedule: str = "sequential"  # sequential | parallel | streaming
    # cut-boundary wire scheme (registry.WIRES): none | int8 | topk_int8
    wire: str = "none"
    wire_k: float = 0.25              # topk_int8 keep-fraction per group


@dataclasses.dataclass(frozen=True)
class AdaptiveConfig:
    """Cut-layer selection — the 'adaptive' in ASFL."""
    strategy: str = "paper"           # registry.STRATEGIES key
    cut: int = 4                      # fixed cut for sl / sfl


@dataclasses.dataclass(frozen=True)
class FleetConfig:
    """The fleet and where it drives.  ``scenario`` routes the experiment:
    ``"single_rsu"`` (or None) -> FederationSim; a registry scenario name ->
    the multi-RSU ScenarioEngine."""
    n_vehicles: int = 4
    scenario: Optional[str] = registry.SINGLE_RSU
    scenario_kwargs: Dict[str, Any] = dataclasses.field(default_factory=dict)
    cloud_sync_every: int = 1         # multi-RSU: cloud merge every k rounds
    round_interval_s: float = 5.0     # wall-clock round spacing (mobility)
    mobility_dropout: bool = False    # single-RSU §II-C interruption model
    server_flops: float = 2e12        # RSU compute, analytic cost model
    # fleet data sizing (every registry model's make_data consumes these)
    per_vehicle_samples: int = 64
    test_samples: int = 256
    data_seed: int = 0
    # single-RSU fleet memory budgets (adaptive strategy "memory"):
    # None = unconstrained, scalar = fleet-wide, (lo, hi) = per-vehicle draw
    memory_budget_bytes: Optional[Union[float, Tuple[float, float]]] = None


@dataclasses.dataclass(frozen=True)
class RuntimeConfig:
    """XLA execution knobs (DESIGN.md §6/§8/§10)."""
    seed: int = 0
    cohort_parallel: str = "auto"     # auto | vmap | scan | unroll
    superstep: int = 1                # rounds fused per scenario dispatch
    slot_capacity: str = "pow2"       # pow2 | tight8
    # super-step layout (DESIGN.md §12): "ragged" = cut-prefix client
    # planes + occupancy-compacted slot scheduling (the default);
    # "dense" = full-plane masked replicas over per-RSU padded tables
    superstep_layout: str = "ragged"
    precompile: bool = True           # scenario engine: AOT-compile the plan
    compilation_cache_dir: Optional[str] = None
    # device mesh over the fleet (core/fleet_sharding.py, DESIGN.md §10,
    # §15): > 1 runs the compiled programs under shard_map across that many
    # devices (on CPU: XLA_FLAGS=--xla_force_host_platform_device_count=N
    # before the first jax import); 1 is the unsharded single-device path;
    # "auto" picks 1 vs every visible device from an occupied-slots-per-
    # device floor (the decision lands in RunResult.diagnostics)
    mesh_devices: Union[int, str] = 1
    # auto | vehicle | rsu | grid — which fleet dimension(s) the mesh
    # partitions (auto = the engine's natural axis: RSU for multi-RSU
    # scenarios, vehicle for the single-RSU cohort engine; grid = the
    # 2-D rsu x vehicle mesh, scenario engine only)
    fleet_axis: str = "auto"
    # 2-D mesh factorization: "auto" derives (rsu, vehicle) counts from
    # fleet_axis, or an explicit "RxV" string (e.g. "4x2") whose product
    # must equal the resolved mesh_devices
    mesh_shape: str = "auto"
    # slot-capacity paging (DESIGN.md §15): > 0 caps the per-device
    # concurrent slot window of the ragged parallel/streaming super-step;
    # larger cohorts page through the compacted axis in fixed windows on
    # the donated carry.  0 = unpaged
    page_slots: int = 0
    # multi-host execution (DESIGN.md §15): when num_processes > 1 and a
    # coordinator address is set, the runner calls
    # jax.distributed.initialize BEFORE the first backend touch, the mesh
    # spans every process's devices, and RunResult.final_params gathers
    # home to every host's numpy.  These never reach SimConfig — process
    # topology is runner state, not engine math
    coordinator_address: Optional[str] = None
    num_processes: int = 1
    process_id: int = 0


@dataclasses.dataclass(frozen=True)
class FaultsConfig:
    """The fault plane (core/faults.py, DESIGN.md §13).  All-defaults is
    the no-fault spec: the engines gate every fault hook at Python level,
    so the compiled programs are byte-identical to a pre-fault build.
    ``fleet.mobility_dropout`` is the legacy spelling of ``coverage``."""
    coverage: bool = False            # deterministic §II-C in-range test
    dropout_rate: float = 0.0         # P[vehicle drops mid-round]
    upload_loss_rate: float = 0.0     # P[update lost after full local work]
    straggler_factor: float = 0.0     # >0: deadline factor x residence
    rsu_outage_rate: float = 0.0      # P[RSU misses a round] (multi-RSU)
    staleness_discount: float = 0.5   # weight for banked straggler updates
    seed: int = 0                     # dedicated fault PRNG stream


@dataclasses.dataclass(frozen=True)
class StreamConfig:
    """The streaming plane (core/streaming.py, DESIGN.md §14).
    All-defaults is the no-streaming spec: zero churn and a buffer that
    only exists under ``train.server_schedule="streaming"``, with every
    hook gated at Python level so default programs stay byte-identical."""
    buffer_size: int = 4        # B: buffered deltas per RSU before a merge
    churn_rate: float = 0.0     # P[vehicle toggles presence each round]
    kernel: str = "constant"    # staleness discount: constant | poly
    alpha: float = 0.5          # poly kernel exponent: 1/(1+s)**alpha
    seed: int = 0               # dedicated streaming PRNG stream
    # presence-departure source (DESIGN.md §15): "markov" samples the
    # toggle chain at churn_rate; "mobility" derives departures from the
    # scenario's coverage state (serving_rsu == -1) — churn_rate stays 0
    churn_source: str = "markov"


# SimConfig field -> (spec group, group field): the deprecation shim's
# field-for-field mapping, used by both converters below (and asserted
# exhaustive over SimConfig's fields in tests/test_api.py)
SIM_CONFIG_FIELD_MAP: Dict[str, Tuple[str, str]] = {
    "scheme": ("train", "scheme"),
    "batch_size": ("train", "batch_size"),
    "local_epochs": ("train", "local_epochs"),
    "local_steps": ("train", "local_steps"),
    "lr": ("train", "lr"),
    "rounds": ("train", "rounds"),
    "optimizer": ("train", "optimizer"),
    "eval_every": ("train", "eval_every"),
    "compress_smashed": ("train", "compress_smashed"),
    "server_schedule": ("train", "server_schedule"),
    "wire": ("train", "wire"),
    "wire_k": ("train", "wire_k"),
    "adaptive_strategy": ("adaptive", "strategy"),
    "cut": ("adaptive", "cut"),
    "n_clients": ("fleet", "n_vehicles"),
    "round_interval_s": ("fleet", "round_interval_s"),
    "mobility_dropout": ("fleet", "mobility_dropout"),
    "server_flops": ("fleet", "server_flops"),
    "fault_coverage": ("faults", "coverage"),
    "fault_dropout": ("faults", "dropout_rate"),
    "fault_upload_loss": ("faults", "upload_loss_rate"),
    "fault_straggler": ("faults", "straggler_factor"),
    "fault_rsu_outage": ("faults", "rsu_outage_rate"),
    "fault_staleness_discount": ("faults", "staleness_discount"),
    "fault_seed": ("faults", "seed"),
    "stream_buffer_size": ("stream", "buffer_size"),
    "stream_churn_rate": ("stream", "churn_rate"),
    "stream_kernel": ("stream", "kernel"),
    "stream_alpha": ("stream", "alpha"),
    "stream_seed": ("stream", "seed"),
    "stream_churn_source": ("stream", "churn_source"),
    "seed": ("runtime", "seed"),
    "cohort_parallel": ("runtime", "cohort_parallel"),
    "superstep": ("runtime", "superstep"),
    "slot_capacity": ("runtime", "slot_capacity"),
    "superstep_layout": ("runtime", "superstep_layout"),
    "compilation_cache_dir": ("runtime", "compilation_cache_dir"),
    "mesh_devices": ("runtime", "mesh_devices"),
    "fleet_axis": ("runtime", "fleet_axis"),
    "mesh_shape": ("runtime", "mesh_shape"),
    "page_slots": ("runtime", "page_slots"),
}

_GROUP_TYPES = {"train": TrainConfig, "adaptive": AdaptiveConfig,
                "fleet": FleetConfig, "runtime": RuntimeConfig,
                "faults": FaultsConfig, "stream": StreamConfig}


@dataclasses.dataclass(frozen=True)
class ExperimentSpec:
    """One experiment, declaratively: model x scenario x strategy x schedule
    plus the nested config groups.  Construction validates everything the
    registries know about; ``repro.api.run(spec)`` routes it to the right
    engine."""
    model: str = "resnet18"
    train: TrainConfig = dataclasses.field(default_factory=TrainConfig)
    adaptive: AdaptiveConfig = dataclasses.field(
        default_factory=AdaptiveConfig)
    fleet: FleetConfig = dataclasses.field(default_factory=FleetConfig)
    runtime: RuntimeConfig = dataclasses.field(default_factory=RuntimeConfig)
    faults: FaultsConfig = dataclasses.field(default_factory=FaultsConfig)
    stream: StreamConfig = dataclasses.field(default_factory=StreamConfig)
    model_kwargs: Dict[str, Any] = dataclasses.field(default_factory=dict)

    # ---- engine routing ------------------------------------------------
    @property
    def engine_kind(self) -> str:
        """Which engine ``run`` dispatches to: ``"federation"`` (single-RSU
        FederationSim) or ``"scenario"`` (multi-RSU ScenarioEngine)."""
        sc = self.fleet.scenario
        return (registry.FEDERATION
                if sc in (None, registry.SINGLE_RSU) else registry.SCENARIO)

    # ---- validation ----------------------------------------------------
    def __post_init__(self):
        # field-level validity (allowed values listed) via the engine
        # config's own construction-time checks
        self.to_sim_config()
        entry = registry.model_entry(self.model)

        sc = self.fleet.scenario
        if sc is not None and sc not in registry.SCENARIOS:
            raise ValueError(
                f"unknown scenario {sc!r}; registered: "
                f"{registry.scenario_names()} (None == single_rsu)")
        engine = self.engine_kind

        strat = registry.STRATEGIES.get(self.adaptive.strategy)
        if strat is None:
            raise ValueError(
                f"unknown adaptive strategy {self.adaptive.strategy!r}; "
                f"registered: {' | '.join(sorted(registry.STRATEGIES))}")
        # the strategy is consumed whenever cuts are adaptive (asfl on the
        # single-RSU engine; always on the scenario engine)
        consumed = engine == registry.SCENARIO or self.train.scheme == "asfl"
        if consumed and engine not in strat.engines:
            ok = sorted(n for n, s in registry.STRATEGIES.items()
                        if engine in s.engines)
            raise ValueError(
                f"adaptive strategy {strat.name!r} is not executable by the "
                f"{engine} engine (fleet.scenario={sc!r}); strategies this "
                f"engine supports: {' | '.join(ok)}")

        sched = registry.SCHEDULES.get(self.train.server_schedule)
        if sched is None:
            raise ValueError(
                f"unknown server schedule {self.train.server_schedule!r}; "
                f"registered: {' | '.join(sorted(registry.SCHEDULES))}")
        if engine not in sched.engines:
            ok = sorted(n for n, s in registry.SCHEDULES.items()
                        if engine in s.engines)
            raise ValueError(
                f"server schedule {sched.name!r} is not executable by the "
                f"{engine} engine (fleet.scenario={sc!r}); schedules this "
                f"engine supports: {' | '.join(ok)} (the parallel and "
                f"streaming schedules need a multi-RSU scenario)")

        wire = registry.WIRES.get(self.train.wire)
        if wire is None:
            raise ValueError(
                f"unknown wire scheme {self.train.wire!r}; registered: "
                f"{' | '.join(registry.wire_names())}")
        if engine not in wire.engines:
            ok = sorted(n for n, w in registry.WIRES.items()
                        if engine in w.engines)
            raise ValueError(
                f"wire scheme {wire.name!r} is not executable by the "
                f"{engine} engine (fleet.scenario={sc!r}); wires this "
                f"engine supports: {' | '.join(ok)}")

        if engine == registry.SCENARIO:
            if self.train.scheme != "asfl":
                raise ValueError(
                    f"scheme {self.train.scheme!r} is not executable by the "
                    f"multi-RSU scenario engine (fleet.scenario={sc!r}); it "
                    f"runs the adaptive split flow only: scheme='asfl'. "
                    f"Use fleet.scenario='single_rsu' for cl | fl | sl | "
                    f"sfl")
            if self.fleet.mobility_dropout:
                raise ValueError(
                    "fleet.mobility_dropout is the single-RSU interruption "
                    "model; multi-RSU scenarios model coverage through the "
                    "scenario itself (serving_rsu == -1)")
            if self.fleet.memory_budget_bytes is not None:
                raise ValueError(
                    "fleet.memory_budget_bytes feeds the single-RSU "
                    "'memory' strategy; the scenario engine's on-device "
                    "strategies are: "
                    f"{' | '.join(sorted(n for n, s in registry.STRATEGIES.items() if registry.SCENARIO in s.engines))}")
            if self.faults.coverage:
                raise ValueError(
                    "faults.coverage is the single-RSU §II-C in-range "
                    "test; multi-RSU scenarios model coverage through the "
                    "scenario itself (serving_rsu == -1)")
        else:
            if self.runtime.superstep > 1:
                raise ValueError(
                    f"runtime.superstep={self.runtime.superstep} fuses "
                    f"multi-RSU rounds; the single-RSU engine dispatches "
                    f"per round — set a fleet.scenario "
                    f"({registry.scenario_names()}) or superstep=1")
            if self.fleet.cloud_sync_every != 1:
                raise ValueError(
                    "fleet.cloud_sync_every is the multi-RSU edge->cloud "
                    "cadence; the single-RSU engine aggregates at its one "
                    "RSU every round (leave it at 1 or set a scenario)")
            fl = self.faults
            if fl.straggler_factor > 0.0 or fl.rsu_outage_rate > 0.0:
                raise ValueError(
                    "faults.straggler_factor / faults.rsu_outage_rate need "
                    "a multi-RSU scenario (residence deadlines and RSU "
                    "outages are scenario concepts); the single-RSU engine "
                    "supports dropout_rate / upload_loss_rate / coverage")
            if ((fl.dropout_rate > 0.0 or fl.upload_loss_rate > 0.0)
                    and self.train.scheme not in ("sfl", "asfl")):
                raise ValueError(
                    f"stochastic fault injection is wired into the "
                    f"split-federation round (sfl | asfl); scheme "
                    f"{self.train.scheme!r} does not support it")
            if self.stream.churn_rate > 0.0 \
                    or self.stream.churn_source == "mobility":
                raise ValueError(
                    "presence churn (stream.churn_rate > 0 or "
                    "stream.churn_source='mobility') needs a multi-RSU "
                    "scenario (continuous arrivals/departures live on the "
                    "scenario engine's presence plane); the single-RSU "
                    "engine models interruption via fleet.mobility_dropout")
            if self.runtime.page_slots > 0:
                raise ValueError(
                    "runtime.page_slots pages the multi-RSU super-step's "
                    "compacted slot axis; set a fleet.scenario (and "
                    "superstep_layout='ragged' with a parallel or "
                    "streaming schedule), or leave it at 0")

        rt = self.runtime
        if rt.page_slots < 0 or not isinstance(rt.page_slots, int):
            raise ValueError(
                f"runtime.page_slots={rt.page_slots!r} must be an int >= 0")
        if rt.page_slots > 0 and engine == registry.SCENARIO \
                and (rt.superstep_layout != "ragged"
                     or self.train.server_schedule == "sequential"):
            raise ValueError(
                "runtime.page_slots pages the RAGGED layout's compacted "
                "slot axis under the parallel/streaming schedules; the "
                "dense layout and the sequential chain have no compacted "
                "axis to page — set superstep_layout='ragged' and a "
                "non-sequential train.server_schedule, or page_slots=0")
        meshy = rt.mesh_devices == "auto" \
            or (isinstance(rt.mesh_devices, int) and rt.mesh_devices > 1)
        if meshy:
            # mesh/engine combinations that cannot execute — rejected here,
            # at spec-build time, with the axis the engine does shard named
            if engine == registry.SCENARIO:
                if rt.fleet_axis == "vehicle":
                    raise ValueError(
                        f"runtime.fleet_axis='vehicle' cannot partition the "
                        f"multi-RSU engine (fleet.scenario={sc!r}): it "
                        f"shards the RSU axis — use fleet_axis='rsu', "
                        f"'grid' or 'auto'")
            else:
                if rt.fleet_axis in ("rsu", "grid"):
                    raise ValueError(
                        f"runtime.fleet_axis={rt.fleet_axis!r} needs a "
                        "multi-RSU scenario; the single-RSU engine shards "
                        "the vehicle axis — use fleet_axis='vehicle' or "
                        "'auto', or set a fleet.scenario")
                if self.train.scheme in ("cl", "sl"):
                    raise ValueError(
                        f"scheme {self.train.scheme!r} is an inherently "
                        f"sequential chain (one traveling model); "
                        f"runtime.mesh_devices={rt.mesh_devices} has "
                        f"nothing to shard — use fl | sfl | asfl or "
                        f"mesh_devices=1")
                if rt.cohort_parallel in ("scan", "unroll"):
                    raise ValueError(
                        f"runtime.cohort_parallel={rt.cohort_parallel!r} "
                        f"serializes the replica axis the mesh shards; "
                        f"with mesh_devices > 1 use 'vmap' (or 'auto')")

        if rt.num_processes < 1 or not (0 <= rt.process_id
                                        < rt.num_processes):
            raise ValueError(
                f"runtime.num_processes={rt.num_processes!r} / "
                f"process_id={rt.process_id!r} is not a valid process "
                f"topology: need num_processes >= 1 and 0 <= process_id < "
                f"num_processes")
        if rt.num_processes > 1 and not rt.coordinator_address:
            raise ValueError(
                "runtime.num_processes > 1 needs "
                "runtime.coordinator_address (host:port of process 0) so "
                "jax.distributed.initialize can rendezvous the hosts")

        if self.train.scheme in ("sl", "sfl"):
            if not (1 <= self.adaptive.cut <= entry.n_units - 1):
                raise ValueError(
                    f"adaptive.cut={self.adaptive.cut} is out of range for "
                    f"model {self.model!r} ({entry.n_units} units): fixed "
                    f"cuts must be in [1, {entry.n_units - 1}]")
        if self.fleet.cloud_sync_every < 1:
            raise ValueError(
                f"fleet.cloud_sync_every={self.fleet.cloud_sync_every!r} "
                f"must be an int >= 1")
        for field in ("per_vehicle_samples", "test_samples"):
            if getattr(self.fleet, field) < 1:
                raise ValueError(
                    f"fleet.{field}={getattr(self.fleet, field)!r} must be "
                    f">= 1")
        if self.fleet.per_vehicle_samples < self.train.batch_size \
                and self.train.local_steps is None:
            raise ValueError(
                f"fleet.per_vehicle_samples={self.fleet.per_vehicle_samples}"
                f" < train.batch_size={self.train.batch_size} with "
                f"epoch-driven local steps; raise per_vehicle_samples or "
                f"set train.local_steps")

    # ---- the SimConfig deprecation shim ---------------------------------
    def to_sim_config(self) -> SimConfig:
        """The flat engine config (``repro.core.fedsim.SimConfig``) this
        spec maps onto — the deprecation shim for pre-api callers; the
        engines keep consuming SimConfig internally."""
        kw = {}
        for sim_field, (group, field) in SIM_CONFIG_FIELD_MAP.items():
            kw[sim_field] = getattr(getattr(self, group), field)
        return SimConfig(**kw)

    @classmethod
    def from_sim_config(cls, cfg: SimConfig, *, model: str = "resnet18",
                        scenario: Optional[str] = registry.SINGLE_RSU,
                        **extras) -> "ExperimentSpec":
        """Lift a legacy flat ``SimConfig`` (plus the model/scenario that
        used to be picked by constructing an engine class by hand) into a
        spec, field-for-field.  ``extras`` override any nested field as
        ``"group.field"`` keys (e.g. ``{"fleet.cloud_sync_every": 2}``)."""
        groups: Dict[str, Dict[str, Any]] = {g: {} for g in _GROUP_TYPES}
        for sim_field, (group, field) in SIM_CONFIG_FIELD_MAP.items():
            groups[group][field] = getattr(cfg, sim_field)
        groups["fleet"]["scenario"] = scenario
        for key, value in extras.items():
            group, _, field = key.partition(".")
            if group not in groups or not field:
                raise ValueError(
                    f"override key {key!r} must look like 'group.field' "
                    f"with group in {sorted(groups)}")
            groups[group][field] = value
        return cls(model=model,
                   **{g: _GROUP_TYPES[g](**kw) for g, kw in groups.items()})

    # ---- serialization ---------------------------------------------------
    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    def to_json(self, **dumps_kw) -> str:
        return json.dumps(self.to_dict(), **dumps_kw)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "ExperimentSpec":
        kw = dict(d)
        for group, typ in _GROUP_TYPES.items():
            if group in kw and isinstance(kw[group], dict):
                kw[group] = typ(**kw[group])
        # JSON has no tuples: restore the (lo, hi) budget pair
        fleet = kw.get("fleet")
        if isinstance(fleet, FleetConfig) \
                and isinstance(fleet.memory_budget_bytes, list):
            kw["fleet"] = dataclasses.replace(
                fleet, memory_budget_bytes=tuple(fleet.memory_budget_bytes))
        return cls(**kw)

    @classmethod
    def from_json(cls, s: str) -> "ExperimentSpec":
        return cls.from_dict(json.loads(s))
