"""Config-driven assembly of every assigned architecture.

The stack is a list of *segments*; a segment is a repeating pattern of layer
types scanned over ``n_periods`` (stacked params, ``jax.lax.scan``) so compile
time is O(pattern), not O(n_layers).  Cut-layer splitting (repro.core.split)
addresses the stack at *period* granularity via the ``start``/``end``
arguments of :func:`forward_core`.

Modes: ``train`` (full seq, no cache) · ``prefill`` (full seq, returns cache)
· ``decode`` (one token, consumes+returns cache).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import (ATTN, ATTN_LOCAL, ATTN_MOE, MLA_DENSE,
                                MLA_MOE, RGLRU, SSM, ArchConfig)
from repro.models import attention as A
from repro.models import layers as L
from repro.models import mla as M
from repro.models import moe as E
from repro.models import rglru as R
from repro.models import ssm as S

Params = Dict[str, Any]

_ATTN_KINDS = (ATTN, ATTN_LOCAL, ATTN_MOE)
_MLA_KINDS = (MLA_DENSE, MLA_MOE)
_MOE_KINDS = (ATTN_MOE, MLA_MOE)


# --------------------------------------------------------------------------
# per-layer init / apply
# --------------------------------------------------------------------------

def init_layer(key, cfg: ArchConfig, kind: str, dtype) -> Params:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p: Params = {"norm1": L.init_rmsnorm(cfg.d_model, dtype)}
    if kind in _ATTN_KINDS:
        p["mixer"] = A.init_attn(k1, cfg, dtype)
    elif kind in _MLA_KINDS:
        p["mixer"] = M.init_mla(k1, cfg, dtype)
    elif kind == SSM:
        p["mixer"] = S.init_ssm(k1, cfg, dtype)
        return p  # mamba block has no separate FFN
    elif kind == RGLRU:
        p["mixer"] = R.init_rglru(k1, cfg, dtype)
    else:
        raise ValueError(kind)
    p["norm2"] = L.init_rmsnorm(cfg.d_model, dtype)
    if kind in _MOE_KINDS:
        p["ffn"] = E.init_moe(k2, cfg, dtype)
    else:
        p["ffn"] = L.init_mlp(k2, cfg.d_model, cfg.d_ff, cfg.mlp_variant, dtype)
    return p


def _window(cfg: ArchConfig, kind: str) -> int:
    return cfg.window if kind == ATTN_LOCAL else 0


def apply_layer(p: Params, cfg: ArchConfig, kind: str, x: jnp.ndarray,
                mode: str, positions, cache, capacity: int
                ) -> Tuple[jnp.ndarray, jnp.ndarray, Any]:
    """Returns (x, aux_loss, new_cache)."""
    aux = jnp.zeros((), jnp.float32)
    h = L.rmsnorm(p["norm1"], x)
    new_cache = cache
    if kind in _ATTN_KINDS:
        w = _window(cfg, kind)
        if mode == "train":
            h = A.attn_train(p["mixer"], cfg, h, positions, w)
        elif mode == "prefill":
            h, new_cache = A.attn_prefill(p["mixer"], cfg, h, positions, capacity, w)
        else:
            h, new_cache = A.attn_decode(p["mixer"], cfg, h, cache, w)
    elif kind in _MLA_KINDS:
        if mode == "train":
            h = M.mla_train(p["mixer"], cfg, h, positions)
        elif mode == "prefill":
            h, new_cache = M.mla_prefill(p["mixer"], cfg, h, positions, capacity)
        else:
            h, new_cache = M.mla_decode(p["mixer"], cfg, h, cache)
    elif kind == SSM:
        if mode in ("train",):
            h = S.ssm_train(p["mixer"], cfg, h)
        elif mode == "prefill":
            h, new_cache = S.ssm_prefill(p["mixer"], cfg, h)
        else:
            h, new_cache = S.ssm_decode(p["mixer"], cfg, h, cache)
        return x + h, aux, new_cache
    elif kind == RGLRU:
        if mode == "train":
            h = R.rglru_train(p["mixer"], cfg, h)
        elif mode == "prefill":
            h, new_cache = R.rglru_prefill(p["mixer"], cfg, h)
        else:
            h, new_cache = R.rglru_decode(p["mixer"], cfg, h, cache)
    x = x + h
    h = L.rmsnorm(p["norm2"], x)
    if kind in _MOE_KINDS:
        h, aux = E.moe_forward(p["ffn"], cfg, h)
    else:
        h = L.mlp(p["ffn"], h, cfg.mlp_variant)
    return x + h, aux, new_cache


def init_layer_cache(cfg: ArchConfig, kind: str, batch: int, capacity: int,
                     dtype) -> Any:
    if kind in _ATTN_KINDS:
        return A.init_cache(cfg, batch, capacity, _window(cfg, kind), dtype)
    if kind in _MLA_KINDS:
        return M.init_mla_cache(cfg, batch, capacity, dtype)
    if kind == SSM:
        return S.init_ssm_cache(cfg, batch, dtype)
    if kind == RGLRU:
        return R.init_rglru_cache(cfg, batch, dtype)
    raise ValueError(kind)


# --------------------------------------------------------------------------
# segments
# --------------------------------------------------------------------------

def segments_of(cfg: ArchConfig) -> List[Tuple[Tuple[str, ...], int]]:
    segs = [(tuple(cfg.pattern), cfg.n_periods)]
    if cfg.tail:
        segs.append((tuple(cfg.tail), 1))
    return segs


def total_periods(cfg: ArchConfig) -> int:
    return sum(n for _, n in segments_of(cfg))


def init_segment(key, cfg: ArchConfig, pattern, n_periods: int, dtype):
    def one(k):
        ks = jax.random.split(k, len(pattern))
        return tuple(init_layer(ks[i], cfg, t, dtype) for i, t in enumerate(pattern))
    return jax.vmap(one)(jax.random.split(key, n_periods))


def _slice_leaves(tree, lo: int, hi: int):
    return jax.tree.map(lambda a: a[lo:hi], tree)


# Remat policy for the layer-scan body (perf knob, trace-time switch):
# None = full recompute; "dots" = save dot outputs (cuts the recomputed
# matmuls AND their collectives in the backward pass at the cost of
# activation memory).
REMAT_POLICY = None


def set_remat_policy(name):
    global REMAT_POLICY
    REMAT_POLICY = name


def _scan_segment(seg_params, cfg: ArchConfig, pattern, x, mode: str,
                  positions, caches, capacity: int, remat: bool):
    """Scan the period body over the (already sliced) stacked params."""
    def body(carry, xs):
        xc, auxc = carry
        if mode == "decode":
            pp, cc = xs
        else:
            pp, cc = xs, None
        new_caches = []
        for i, kind in enumerate(pattern):
            layer_cache = cc[i] if cc is not None else None
            xc, aux, ncache = apply_layer(pp[i], cfg, kind, xc, mode,
                                          positions, layer_cache, capacity)
            auxc = auxc + aux
            new_caches.append(ncache)
        return (xc, auxc), tuple(new_caches)

    if remat and mode == "train":
        policy = None
        if REMAT_POLICY == "dots":
            policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        body = jax.checkpoint(body, prevent_cse=False, policy=policy)
    xs = (seg_params, caches) if mode == "decode" else seg_params
    (x, aux), out_caches = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), xs)
    return x, aux, out_caches


# --------------------------------------------------------------------------
# model-level params
# --------------------------------------------------------------------------

def init_params(key, cfg: ArchConfig, dtype=None) -> Params:
    dtype = dtype or jnp.dtype(cfg.param_dtype)
    keys = jax.random.split(key, 4 + len(segments_of(cfg)))
    vp, d = cfg.padded_vocab, cfg.d_model
    p: Params = {}
    if cfg.frontend == "audio":
        p["embed"] = L.trunc_normal(keys[0], (cfg.n_codebooks, vp, d), d ** -0.5, dtype)
        p["head"] = L.trunc_normal(keys[1], (d, cfg.n_codebooks, vp), d ** -0.5, dtype)
    else:
        p["embed"] = L.trunc_normal(keys[0], (vp, d), d ** -0.5, dtype)
        p["head"] = L.trunc_normal(keys[1], (d, vp), d ** -0.5, dtype)
    p["final_norm"] = L.init_rmsnorm(d, dtype)
    p["segments"] = tuple(
        init_segment(keys[4 + i], cfg, pat, n, dtype)
        for i, (pat, n) in enumerate(segments_of(cfg)))
    return p


def embed_inputs(p: Params, cfg: ArchConfig, batch: Dict[str, jnp.ndarray],
                 positions: jnp.ndarray) -> jnp.ndarray:
    """batch -> (b, s, d) activations (the vehicle-side input boundary)."""
    if cfg.frontend == "vision" and "patch_embeds" in batch:
        tok = p["embed"][batch["tokens"]]
        x = jnp.concatenate([batch["patch_embeds"].astype(tok.dtype), tok], axis=1)
    elif cfg.frontend == "audio":
        # sum over codebook embeddings (MusicGen interleave collapse)
        codes = batch["codes"]                      # (b, K, s)
        x = jnp.zeros((codes.shape[0], codes.shape[2], cfg.d_model),
                      p["embed"].dtype)
        for k in range(cfg.n_codebooks):
            x = x + p["embed"][k][codes[:, k]]
    else:
        x = p["embed"][batch["tokens"]]
    if cfg.pos == "sinusoidal":
        x = x + L.sinusoidal_pos(positions, cfg.d_model).astype(x.dtype)
    return x


def unembed(p: Params, cfg: ArchConfig, x: jnp.ndarray) -> jnp.ndarray:
    x = L.rmsnorm(p["final_norm"], x)
    if cfg.frontend == "audio":
        logits = jnp.einsum("bsd,dkv->bskv", x, p["head"].astype(x.dtype))
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, p["head"].astype(x.dtype))
    return L.softcap(logits, cfg.logit_softcap)


def forward_core(p: Params, cfg: ArchConfig, x: jnp.ndarray, mode: str,
                 positions=None, caches=None, capacity: int = 0,
                 start: int = 0, end: Optional[int] = None,
                 remat: bool = True):
    """Run periods [start, end) of the stack.  Returns (x, aux, caches)."""
    end = total_periods(cfg) if end is None else end
    aux = jnp.zeros((), jnp.float32)
    out_caches = []
    off = 0
    for si, (pat, n) in enumerate(segments_of(cfg)):
        lo, hi = max(start - off, 0), min(end - off, n)
        if lo < hi:
            seg_p = _slice_leaves(p["segments"][si], lo, hi)
            seg_c = None
            if caches is not None:
                seg_c = _slice_leaves(caches[si], lo, hi)
            x, a, nc = _scan_segment(seg_p, cfg, pat, x, mode, positions,
                                     seg_c, capacity, remat)
            aux = aux + a
            out_caches.append(nc)
        else:
            out_caches.append(None)
        off += n
    return x, aux, tuple(out_caches)


def init_caches(cfg: ArchConfig, batch: int, capacity: int, dtype=jnp.float32,
                start: int = 0, end: Optional[int] = None):
    """Stacked per-segment caches for periods [start, end)."""
    end = total_periods(cfg) if end is None else end
    caches = []
    off = 0
    for pat, n in segments_of(cfg):
        lo, hi = max(start - off, 0), min(end - off, n)
        if lo < hi:
            def one(_):
                return tuple(init_layer_cache(cfg, t, batch, capacity, dtype)
                             for t in pat)
            stacked = jax.tree.map(
                lambda *xs: jnp.stack(xs), *[one(i) for i in range(hi - lo)])
            caches.append(stacked)
        else:
            caches.append(None)
        off += n
    return tuple(caches)


# --------------------------------------------------------------------------
# whole-model convenience (used by fedsim / examples / smoke tests)
# --------------------------------------------------------------------------

def forward(p: Params, cfg: ArchConfig, batch: Dict[str, jnp.ndarray],
            mode: str = "train", caches=None, capacity: int = 0,
            pos_offset=0, remat: bool = False):
    """Full model: embed -> stack -> head.  Returns (logits, aux, caches)."""
    if mode == "decode":
        positions = jnp.asarray([pos_offset], jnp.int32)
        x = embed_inputs(p, cfg, batch, positions)
    else:
        if cfg.frontend == "vision":
            s = batch["tokens"].shape[1] + cfg.n_patches
        elif cfg.frontend == "audio":
            s = batch["codes"].shape[2]
        else:
            s = batch["tokens"].shape[1]
        positions = jnp.arange(s, dtype=jnp.int32)
        x = embed_inputs(p, cfg, batch, positions)
    x, aux, caches = forward_core(p, cfg, x, mode, positions, caches,
                                  capacity, remat=remat)
    return unembed(p, cfg, x), aux, caches


def loss_fn(p: Params, cfg: ArchConfig, batch: Dict[str, jnp.ndarray],
            remat: bool = False) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    logits, aux, _ = forward(p, cfg, batch, "train", remat=remat)
    if cfg.frontend == "audio":
        # next-frame prediction over the K codebooks
        ce = L.cross_entropy(logits, batch["codes"].swapaxes(1, 2), cfg.vocab_size)
    else:
        if cfg.frontend == "vision":
            logits = logits[:, cfg.n_patches:]      # loss on text positions
        ce = L.cross_entropy(logits, batch["labels"], cfg.vocab_size)
    return ce + aux, {"ce": ce, "aux": aux}


# --------------------------------------------------------------------------
# analytic parameter count (roofline MODEL_FLOPS = 6 N D)
# --------------------------------------------------------------------------

def count_params(cfg: ArchConfig, active_only: bool = False) -> int:
    d, ff, vp = cfg.d_model, cfg.d_ff, cfg.padded_vocab
    total = 0
    for kind in cfg.layer_types:
        n = 2 * d  # norms
        if kind in _ATTN_KINDS:
            hd = cfg.head_dim_
            n += d * hd * (cfg.n_heads * 2 + cfg.n_kv_heads * 2)
        elif kind in _MLA_KINDS:
            m = cfg.mla
            qk = m.qk_nope_dim + m.qk_rope_dim
            n += d * (cfg.n_heads * qk + m.kv_lora_rank + m.qk_rope_dim)
            n += m.kv_lora_rank * cfg.n_heads * (m.qk_nope_dim + m.v_head_dim)
            n += cfg.n_heads * m.v_head_dim * d + m.kv_lora_rank
        elif kind == SSM:
            d_inner, n_heads, conv_dim = S.dims(cfg)
            n = d + d * (2 * d_inner + 2 * cfg.ssm.n_groups * cfg.ssm.d_state
                         + n_heads)
            n += cfg.ssm.d_conv * conv_dim + conv_dim + 3 * n_heads
            n += d_inner + d_inner * d
            total += n
            continue
        elif kind == RGLRU:
            dr = cfg.rglru.d_rnn or d
            n += d * dr * 2 + dr * d + 2 * dr * dr + 3 * dr + cfg.rglru.d_conv * dr
        # FFN
        if kind in _MOE_KINDS:
            m = cfg.moe
            eff = m.d_ff_expert or ff
            n_e = m.top_k if active_only else m.n_experts
            n += d * m.n_experts  # router
            n += (n_e + m.n_shared) * 3 * d * eff
        elif kind != SSM:
            mats = 3 if cfg.mlp_variant in ("swiglu", "geglu") else 2
            n += mats * d * ff
        total += n
    emb = vp * d * (cfg.n_codebooks if cfg.frontend == "audio" else 1)
    head = d * vp * (cfg.n_codebooks if cfg.frontend == "audio" else 1)
    return total + emb + head + d
