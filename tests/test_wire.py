"""Wire compression pipeline (DESIGN.md §11): oracle semantics of the
packed format, error-feedback boundary gradients, byte-honest cost
accounting, and the engine-level compression/accuracy contract."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import compression as C
from repro.core import cost
from repro.core.fedsim import FederationSim, SimConfig

KEY = jax.random.PRNGKey(0)


# -------------------------------------------------------------- wire format
def test_wire_layout_geometry():
    # d=64: one group of 64 -> bitmap 2 words, 1 scale, k=16 -> 4 value
    # words: 7 words = 28 B vs 256 B dense = 9.14x
    g, ng, k, wpg = C.wire_layout(64, 0.25)
    assert (g, ng, k, wpg) == (64, 1, 16, 7)
    # d=128: k=32 -> 4+1+8 = 13 words per group
    g, ng, k, wpg = C.wire_layout(128, 0.25)
    assert (g, ng, k, wpg) == (128, 1, 32, 13)
    # k clamps to [1, g]
    assert C.wire_layout(128, 0.0)[2] == 1
    assert C.wire_layout(128, 1.0)[2] == 128


def test_wire_exactly_k_survivors_with_ties():
    """The pairwise-rank top-k breaks ties by index, so EXACTLY k values
    survive even on constant inputs — shapes stay static."""
    x = jnp.ones((3, 128))
    q, s, mask = C.sparsify_topk_int8(x, 0.25)
    assert (np.asarray(mask).sum(-1) == 32).all()
    # ties resolve to the lowest indices
    assert np.asarray(mask)[:, :32].all()


def test_wire_topk_keeps_largest_magnitudes():
    x = jnp.asarray(np.random.default_rng(0).normal(size=(4, 128)),
                    jnp.float32)
    q, s, mask = C.sparsify_topk_int8(x, 0.25)
    ax = np.abs(np.asarray(x))
    m = np.asarray(mask)
    for r in range(4):
        kept = np.sort(ax[r][m[r]])
        dropped = np.sort(ax[r][~m[r]])
        assert kept.min() >= dropped.max() - 1e-7


def test_wire_row_bytes_and_ratio():
    # 7 int32 words = 28 B for a 64-wide row
    assert C.wire_row_bytes(64) == 28.0
    assert C.wire_compression_ratio("topk_int8", trailing_dim=64) \
        == pytest.approx(256.0 / 28.0)
    assert C.wire_compression_ratio("none") == 1.0
    assert C.wire_compression_ratio("int8") == C.compression_ratio()
    with pytest.raises(ValueError):
        C.wire_compression_ratio("gzip")
    # the >=4x acceptance floor holds for every profile trailing dim used
    # by the tier-1 parity models (mlp9 width 64, TinyMLP width 16)
    for d in (16, 64, 128):
        assert C.wire_compression_ratio("topk_int8", trailing_dim=d) >= 4.0


def test_wire_dequant_matches_sparse_values():
    """Unpacked dequant reproduces dequantize_int8 restricted to the
    survivor mask, zeros elsewhere."""
    x = jax.random.normal(KEY, (4, 200)) * 3
    buf = C.sparsify_quant_pack_ref(x)
    dense = C.wire_dequant_ref(buf, 200)
    q, s, mask = C.sparsify_topk_int8(x)
    ref = np.asarray(C.dequantize_int8(q, s)) * np.asarray(mask)
    np.testing.assert_array_equal(np.asarray(dense), ref)


# -------------------------------------------------------- boundary autodiff
def test_wire_boundary_error_feedback_semantics():
    """fwd: y = compress(x + res), new_res = (x + res) - y — what was not
    sent is exactly what is remembered."""
    x = jax.random.normal(KEY, (8, 64))
    res = jax.random.normal(jax.random.PRNGKey(1), (8, 64)) * 0.1
    y, res2 = C.wire_boundary(x, res)
    np.testing.assert_allclose(np.asarray(y + res2), np.asarray(x + res),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_array_equal(np.asarray(y),
                                  np.asarray(C.wire_topk_dense(x + res)))


def test_wire_boundary_gradient_is_compressed_downlink():
    """The bwd rule routes the cut-layer gradient through the SAME topk
    compressor (symmetric wire) and gives the residual a zero cotangent."""
    x = jax.random.normal(KEY, (4, 128))
    res = jnp.zeros_like(x)

    def f(x, res):
        y, _ = C.wire_boundary(x, res)
        return jnp.sum(y * jnp.arange(128, dtype=jnp.float32))

    gx, gres = jax.grad(f, argnums=(0, 1))(x, res)
    up = jnp.broadcast_to(jnp.arange(128, dtype=jnp.float32), (4, 128))
    np.testing.assert_array_equal(np.asarray(gx),
                                  np.asarray(C.wire_topk_dense(up)))
    assert not np.asarray(gres).any()


def test_quant_boundary_gradient_quantised():
    x = jax.random.normal(KEY, (4, 128))

    def f(x):
        return jnp.sum(C.quant_boundary(x) ** 2)

    g = jax.grad(f)(x)
    assert np.isfinite(np.asarray(g)).all()
    # bwd is fake-quantised: values land on the int8 grid of 2x
    q, s = C.quantize_int8(2.0 * x)
    np.testing.assert_allclose(np.asarray(g),
                               np.asarray(C.dequantize_int8(q, s)),
                               rtol=1e-6, atol=1e-6)


# ------------------------------------------------------------ cost honesty
def test_cost_charges_wire_bytes_both_directions():
    """Satellite fix: the downlink (cut-layer gradients) is charged at the
    same on-wire bytes as the uplink — never dense fp32 while the uplink is
    compressed."""
    prof = cost.resnet_profile()
    dense_up, dense_down = cost.effective_comm_bytes(
        prof, 4, steps=4, batch=16, include_model_transfer=False)
    assert dense_up == dense_down == prof.smashed_bytes(4, 16) * 4
    for wire in ("int8", "topk_int8"):
        up, down = cost.effective_comm_bytes(
            prof, 4, steps=4, batch=16, wire=wire,
            include_model_transfer=False)
        ratio = cost.wire_smashed_ratio(prof, 4, wire)
        assert up == down == pytest.approx(dense_up / ratio)
        assert ratio > 1.0
    # topk_int8 at the default keep fraction beats plain int8
    assert cost.wire_smashed_ratio(prof, 4, "topk_int8") \
        > cost.wire_smashed_ratio(prof, 4, "int8")


def test_cost_model_transfer_stays_dense():
    """Only the smashed traffic rides the wire: parameter upload/download
    is charged dense regardless of scheme."""
    prof = cost.resnet_profile()
    rc_none = cost.sfl_client_round_cost(prof, 4, 4, 16, 1e7, 1e10, 1e12)
    rc_topk = cost.sfl_client_round_cost(prof, 4, 4, 16, 1e7, 1e10, 1e12,
                                         wire="topk_int8")
    model_bytes = 2 * prof.client_param_bytes(4)
    smashed_none = rc_none.comm_bytes - model_bytes
    smashed_topk = rc_topk.comm_bytes - model_bytes
    ratio = cost.wire_smashed_ratio(prof, 4, "topk_int8")
    assert smashed_topk == pytest.approx(smashed_none / ratio)
    # latency/energy follow the compressed byte counts
    assert rc_topk.latency < rc_none.latency
    assert rc_topk.energy_j < rc_none.energy_j


def test_cost_arrays_wire_matches_scalar_path():
    prof = cost.resnet_profile()
    cuts = np.array([2, 4, 6])
    rc = cost.sfl_round_cost_arrays(prof, cuts, 4, 16,
                                    np.full(3, 1e7), np.full(3, 1e10), 1e12,
                                    wire="topk_int8")
    for i, c in enumerate(cuts):
        one = cost.sfl_client_round_cost(prof, int(c), 4, 16, 1e7, 1e10,
                                         1e12, wire="topk_int8")
        assert rc.comm_bytes[i] == pytest.approx(one.comm_bytes)
        assert rc.latency[i] == pytest.approx(one.latency)


def test_legacy_compress_smashed_aliases_int8():
    cfg = SimConfig(rounds=1, compress_smashed=True)
    assert cfg.wire_scheme() == "int8"
    assert SimConfig(rounds=1).wire_scheme() == "none"
    assert SimConfig(rounds=1, wire="topk_int8").wire_scheme() == "topk_int8"
    with pytest.raises(ValueError):
        SimConfig(rounds=1, compress_smashed=True, wire="topk_int8")
    with pytest.raises(ValueError):
        SimConfig(rounds=1, wire="gzip")
    with pytest.raises(ValueError):
        SimConfig(rounds=1, wire_k=0.0)


# -------------------------------------------------- engine-level contract
def _sim(wire, **kw):
    from repro.models.mlp_unit import MLPUnitModel, make_mlp_fleet_data
    model = MLPUnitModel()
    clients, test = make_mlp_fleet_data(4, 32, seed=0, n_test=64)
    cfg = SimConfig(rounds=3, local_steps=2, batch_size=8, lr=5e-3,
                    adaptive_strategy="paper", eval_every=0, wire=wire, **kw)
    return FederationSim(model, clients, test, cfg)


def test_federation_sim_wire_reduces_comm_and_trains():
    hist = {w: _sim(w).run() for w in ("none", "topk_int8")}
    for w, h in hist.items():
        assert all(np.isfinite(m.loss) for m in h)
    assert hist["topk_int8"][-1].comm_bytes < hist["none"][-1].comm_bytes
