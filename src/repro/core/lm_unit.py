"""Transformer-as-UnitModel adapter: run the federation simulator (fedsim)
over any assigned architecture's reduced config — SFL/ASFL with the paper's
message flow on LM stacks, not just the paper's ResNet18.

Unit granularity: unit 0 = token embedding (always vehicle-side — the raw
tokens never leave the vehicle, the paper's privacy argument); units 1..P =
the stack's periods; the head (final norm + LM head) lives with the RSU.
Batches use the fedsim convention: ``images`` = token ids (b, s),
``labels`` = next-token ids (b, s).
"""
from __future__ import annotations

from typing import List, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core import cost
from repro.models import layers as L
from repro.models import transformer as T


class TransformerUnitModel:
    # matmul-dominated: gradients scan fine on every backend, so the cohort
    # engine may fuse replicas and steps into nested lax.scans on CPU too
    scan_friendly = True

    def __init__(self, cfg: ArchConfig):
        assert cfg.frontend == "none", "fedsim LM adapter: text archs only"
        self.cfg = cfg
        self.name = cfg.name
        # (segment index, pattern) per period, in stack order
        self._period_seg: List[Tuple[int, Tuple[str, ...]]] = []
        for si, (pat, n) in enumerate(T.segments_of(cfg)):
            self._period_seg += [(si, pat)] * n
        self.n_units = 1 + len(self._period_seg)

    def init(self, key):
        params = T.init_params(key, self.cfg)
        units: List = [{"embed": params["embed"]}]
        seg_start = {}
        for pi, (si, _) in enumerate(self._period_seg):
            seg_start.setdefault(si, pi)
        for pi, (si, pat) in enumerate(self._period_seg):
            local = pi - seg_start[si]       # period index within its segment
            seg = params["segments"][si]
            units.append(jax.tree.map(lambda a: a[local:local + 1], seg))
        head = {"final_norm": params["final_norm"], "head": params["head"]}
        return units, head

    def apply_units(self, units, x, start: int):
        cfg = self.cfg
        i = start
        for u in units:
            if i == 0:
                positions = jnp.arange(x.shape[1], dtype=jnp.int32)
                x = T.embed_inputs(u, cfg, {"tokens": x}, positions)
                self._positions = positions
            else:
                si, pat = self._period_seg[i - 1]
                positions = jnp.arange(x.shape[1], dtype=jnp.int32)
                x, _, _ = T._scan_segment(u, cfg, pat, x, "train", positions,
                                          None, 0, remat=False)
            i += 1
        return x

    def head_loss(self, head, feats, labels):
        logits = T.unembed(head, self.cfg, feats)
        ce = L.cross_entropy(logits, labels, self.cfg.vocab_size)
        return ce, logits

    def head_predict(self, head, feats):
        return T.unembed(head, self.cfg, feats)

    def profile(self) -> cost.SplitProfile:
        prof = cost.arch_profile(self.cfg, seq=64, param_bytes_per=4)
        # prepend the embedding unit
        emb_bytes = self.cfg.padded_vocab * self.cfg.d_model * 4
        prof.unit_fwd_flops.insert(0, 0.0)
        prof.unit_param_bytes.insert(0, emb_bytes)
        prof.smashed_bytes_per_sample.insert(
            0, prof.smashed_bytes_per_sample[0])
        return prof
