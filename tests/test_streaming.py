"""Streaming plane (ISSUE 9, DESIGN.md §14): zero-streaming byte-identity,
seeded arrival/departure churn, the StreamBuffer's buffered-asynchronous
merges, and goodput/staleness telemetry — across schedules, super-step
layouts, and the device mesh.

The CI ``streaming`` job re-runs this file plus the zero-streaming
invariants; the hard contract mirrors the fault plane's: a default
:class:`~repro.core.streaming.StreamConfig` must compile the exact program
a pre-streaming build compiled.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.core import scenario, streaming
from repro.core.fedsim import FederationSim, ScenarioEngine, SimConfig

from test_scenario import TinyMLP, _two_cell_trace, _vector_clients

ROUNDS, INTERVAL = 4, 5.0
# the canonical streaming knob set: buffered-async schedule, 30% presence
# churn, a small buffer so merges fire inside the short test window
STREAM = dict(server_schedule="streaming", stream_churn_rate=0.3,
              stream_buffer_size=2)
CHAOS = dict(fault_dropout=0.2, fault_upload_loss=0.1, fault_straggler=1e-7)


def _cfg(**kw):
    base = dict(scheme="asfl", adaptive_strategy="paper", rounds=ROUNDS,
                local_steps=2, batch_size=8, lr=1e-2, optimizer="sgd",
                round_interval_s=INTERVAL, eval_every=0, superstep=1)
    base.update(kw)
    return SimConfig(**base)


def _engine(cfg, sync=2):
    sc = _two_cell_trace(ROUNDS, INTERVAL)
    clients, test = _vector_clients(2)
    return ScenarioEngine(TinyMLP(), clients, test, cfg, sc,
                          cloud_sync_every=sync)


def _params(eng):
    return jax.tree.map(np.asarray, {"units": eng.units, "head": eng.head})


# ----------------------------------------------------------- StreamConfig
def test_stream_config_validation():
    for bad in ({"kernel": "exp"}, {"churn_rate": 1.0},
                {"churn_rate": -0.1}, {"buffer_size": 0}, {"alpha": -1.0}):
        with pytest.raises(ValueError):
            streaming.StreamConfig(**bad)


def test_stream_config_flags():
    assert not streaming.StreamConfig().churning
    assert streaming.StreamConfig(churn_rate=0.1).churning
    # schedule validation rides SimConfig's allowed-values check
    with pytest.raises(ValueError, match="server_schedule"):
        SimConfig(server_schedule="fedbuff")
    assert SimConfig(**STREAM).stream_config().churning


def test_staleness_kernel_values():
    s = np.array([0.0, 1.0, 3.0], np.float32)
    np.testing.assert_array_equal(
        np.asarray(streaming.staleness_kernel("constant", 0.5, s)),
        np.ones(3, np.float32))
    poly = np.asarray(streaming.staleness_kernel("poly", 1.0, s))
    np.testing.assert_allclose(poly, [1.0, 0.5, 0.25], rtol=1e-6)
    assert (np.diff(poly) <= 0).all()
    with pytest.raises(ValueError, match="kernel"):
        streaming.staleness_kernel("exp", 0.5, s)


def test_gate_presence_matches_apply_presence():
    """The traced gate and the FleetState-level twin agree: a non-admitted
    vehicle is exactly an out-of-coverage one."""
    serving = np.array([0, 1, -1, 2], np.int32)
    rates = np.array([1e6, 2e6, 0.0, 3e6], np.float32)
    res = np.array([4.0, 5.0, 0.0, 6.0], np.float32)
    admit = np.array([True, False, True, False])
    s2, r2, d2 = streaming.gate_presence(serving, rates, res, admit)
    assert np.asarray(s2).tolist() == [0, -1, -1, -1]
    assert np.asarray(r2).tolist() == [1e6, 0.0, 0.0, 0.0]
    st = scenario.FleetState(t=0.0, positions=np.zeros((4, 2)),
                             velocities=np.zeros((4, 2)),
                             serving_rsu=serving, rates_bps=rates,
                             residence_s=res)
    st2 = scenario.apply_presence(st, admit)
    np.testing.assert_array_equal(np.asarray(s2), st2.serving_rsu)
    np.testing.assert_array_equal(np.asarray(r2), st2.rates_bps)
    np.testing.assert_array_equal(np.asarray(d2), st2.residence_s)


# ------------------------------------------------- zero-streaming identity
def test_zero_stream_carry_has_no_stream_planes():
    eng = _engine(_cfg())
    assert not eng.programs.cz and not eng.programs.sz
    for key in ("present", "sbuf", "sbuf_w", "sbuf_age", "sbuf_cnt"):
        assert key not in eng._carry


def test_zero_stream_never_samples(monkeypatch):
    """The Python-level gate: a default StreamConfig must never reach the
    presence sampler, so the traced program cannot contain streaming ops."""
    def boom(*a, **kw):                      # pragma: no cover
        raise AssertionError("presence sampler invoked on zero-churn config")
    monkeypatch.setattr(streaming, "sample_toggles_traced", boom)
    eng = _engine(_cfg(superstep=ROUNDS))
    hist = eng.run()
    assert len(hist) == ROUNDS
    assert all(np.isfinite(m.loss) for m in hist)


@pytest.mark.parametrize("schedule", ["sequential", "parallel"])
def test_zero_stream_lowering_byte_identical_across_stream_seed(schedule):
    """Byte-identity, provable in-repo: with zero churn and a sync
    schedule, nothing of the stream group may leak into the lowered
    program — two configs that differ only in stream_seed (and buffer
    shape knobs) lower to the identical text."""
    txts = []
    for seed, buf in ((0, 4), (99, 7)):
        eng = _engine(_cfg(server_schedule=schedule, superstep=ROUNDS,
                           stream_seed=seed, stream_buffer_size=buf))
        cap = eng._capacity(ROUNDS)
        sig = eng.programs.signature(ROUNDS, cap, eng._total_slots(ROUNDS))
        fn = eng.programs.get(sig)
        txts.append(fn.lower(eng._carry,
                             eng._window_xs(0, ROUNDS)).as_text())
    assert txts[0] == txts[1]


# ----------------------------------------------- streaming: fused engines
@pytest.mark.parametrize("kernel", ["constant", "poly"])
def test_fused_matches_per_round_under_streaming(kernel):
    """K fused rounds == K per-round dispatches stays bit-for-bit under
    churn + buffered merges: the presence stream is round-indexed
    (fold_in(key, rnd)) and the buffer lives on the donated carry."""
    cfg1 = _cfg(stream_kernel=kernel, **STREAM)
    cfgK = dataclasses.replace(cfg1, superstep=ROUNDS)
    e1, eK = _engine(cfg1), _engine(cfgK)
    h1, hK = e1.run(), eK.run()
    jax.tree.map(np.testing.assert_array_equal, _params(e1), _params(eK))
    np.testing.assert_array_equal([m.loss for m in h1],
                                  [m.loss for m in hK])
    assert [m.stream_merges for m in h1] == [m.stream_merges for m in hK]
    assert [m.absorbed_samples for m in h1] == \
        [m.absorbed_samples for m in hK]
    assert [m.n_present for m in h1] == [m.n_present for m in hK]
    assert sum(m.stream_merges for m in h1) > 0


def test_layouts_agree_under_streaming():
    """ragged == dense stays bit-for-bit with the StreamBuffer in play."""
    engs = [_engine(_cfg(superstep=ROUNDS, superstep_layout=lay, **STREAM))
            for lay in ("ragged", "dense")]
    hists = [e.run() for e in engs]
    jax.tree.map(np.testing.assert_array_equal,
                 _params(engs[0]), _params(engs[1]))
    np.testing.assert_array_equal([m.loss for m in hists[0]],
                                  [m.loss for m in hists[1]])
    assert [m.stream_merges for m in hists[0]] == \
        [m.stream_merges for m in hists[1]]


@pytest.mark.parametrize("layout", ["ragged", "dense"])
@pytest.mark.skipif(jax.device_count() < 8,
                    reason="needs XLA_FLAGS=--xla_force_host_platform_"
                           "device_count=8")
def test_mesh_agrees_under_streaming(layout):
    """FleetMesh(8) == single device, bit-for-bit: the buffer planes shard
    (dense) or replicate (ragged) with the edge stack, and the goodput
    telemetry psums back to a replicated scalar."""
    ref = _engine(_cfg(superstep=ROUNDS, superstep_layout=layout, **STREAM))
    msh = _engine(_cfg(superstep=ROUNDS, superstep_layout=layout,
                       mesh_devices=8, **STREAM))
    hr, hm = ref.run(), msh.run()
    jax.tree.map(np.testing.assert_array_equal, _params(ref), _params(msh))
    np.testing.assert_array_equal([m.loss for m in hr],
                                  [m.loss for m in hm])
    assert [m.stream_merges for m in hr] == [m.stream_merges for m in hm]
    assert [m.absorbed_samples for m in hr] == \
        [m.absorbed_samples for m in hm]


def test_stream_churn_precompiled_zero_fallbacks():
    """Churn is retrace-free: after precompile(), a streaming run builds
    and XLA-compiles nothing (presence is data, the buffer is carry)."""
    eng = _engine(_cfg(superstep=2, **STREAM))
    eng.precompile()
    events = []
    jax.monitoring.register_event_duration_secs_listener(
        lambda name, *a, **kw: events.append(name))
    baseline = len([e for e in events if "compile" in e])
    hist = eng.run()
    assert eng.programs.compile_fallbacks == 0
    assert not [e for e in events[baseline:] if "compile" in e]
    assert len(hist) == ROUNDS
    assert all(np.isfinite(m.loss) for m in hist)


# ------------------------------------------------ StreamBuffer semantics
def test_buffer_fires_at_capacity():
    """With zero churn every served RSU pushes every round, so a size-B
    buffer fires exactly every B pushes — and absorbs sample mass only on
    fire rounds."""
    eng = _engine(_cfg(server_schedule="streaming", stream_buffer_size=2,
                       superstep=ROUNDS), sync=ROUNDS)
    hist = eng.run()
    assert sum(m.stream_merges for m in hist) > 0
    for m in hist:
        assert (m.absorbed_samples > 0.0) == (m.stream_merges > 0)
        assert m.buffer_occupancy >= 0.0
    # an RSU that pushed every round fires on every second round
    merges = [m.stream_merges for m in hist]
    assert merges[0] == 0 and merges[1] > 0


def test_buffer_size_one_tracks_parallel_schedule():
    """B=1 with the constant kernel is the degenerate buffered-async case:
    every push fires immediately, so the trajectory tracks the plain
    parallel schedule (same updates modulo the (w*d)/w renormalization
    rounding)."""
    cfg = dict(superstep=ROUNDS, stream_buffer_size=1)
    es = _engine(_cfg(server_schedule="streaming", **cfg))
    ep = _engine(_cfg(server_schedule="parallel", superstep=ROUNDS))
    hs, hp = es.run(), ep.run()
    jax.tree.map(
        lambda a, b: np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-7),
        _params(es), _params(ep))
    np.testing.assert_allclose([m.loss for m in hs], [m.loss for m in hp],
                               rtol=1e-5)
    # every fire merges age-0 slots only
    assert all(m.stream_stale == 0.0 for m in hs)


def test_stream_schedule_is_seeded():
    """Same stream_seed -> identical presence trace; different seed ->
    (this trace) a different one.  The stream is dedicated: it cannot
    collide with the batch-index, fading, or fault streams."""
    h1 = _engine(_cfg(**STREAM)).run()
    h2 = _engine(_cfg(**STREAM)).run()
    assert [m.n_present for m in h1] == [m.n_present for m in h2]
    assert [m.n_arrived for m in h1] == [m.n_arrived for m in h2]
    h3 = _engine(_cfg(stream_seed=123, **STREAM)).run()
    assert ([m.n_present for m in h1] != [m.n_present for m in h3]
            or [m.n_arrived for m in h1] != [m.n_arrived for m in h3])
    # the host twin reproduces too (independent stream, same seeding rule)
    sc = streaming.StreamConfig(churn_rate=0.3, seed=7)
    np.testing.assert_array_equal(streaming.sample_toggles_host(sc, 3, 64),
                                  streaming.sample_toggles_host(sc, 3, 64))


def test_churn_on_sync_schedules_defers_arrivals():
    """Presence churn composes with the sync schedules: arrivals sit out
    their arrival round (registration/model download), telemetry reports
    the presence/arrival counts, and sample absorption tracks the merged
    survivor set."""
    for schedule in ("sequential", "parallel"):
        eng = _engine(_cfg(server_schedule=schedule, stream_churn_rate=0.3,
                           superstep=ROUNDS))
        hist = eng.run()
        assert all(np.isfinite(m.loss) for m in hist)
        assert all(0 <= m.n_present <= 2 for m in hist)
        for m in hist:
            # an arrival round absorbs nothing from the arrivers: with a
            # 2-vehicle fleet, all-arrived rounds absorb zero
            if m.n_arrived == m.n_present and m.n_arrived > 0:
                assert m.absorbed_samples == 0.0
        assert sum(m.stream_merges for m in hist) == 0


def test_chaos_and_streaming_compose():
    """The fault and streaming planes are orthogonal carry planes: seeded
    chaos over a churning buffered-async run stays finite, fused ==
    per-round, and both telemetry families report."""
    cfg1 = _cfg(**STREAM, **CHAOS)
    cfgK = dataclasses.replace(cfg1, superstep=ROUNDS)
    e1, eK = _engine(cfg1), _engine(cfgK)
    h1, hK = e1.run(), eK.run()
    jax.tree.map(np.testing.assert_array_equal, _params(e1), _params(eK))
    np.testing.assert_array_equal([m.loss for m in h1],
                                  [m.loss for m in hK])
    assert [m.stream_merges for m in h1] == [m.stream_merges for m in hK]
    assert [m.n_dropout for m in h1] == [m.n_dropout for m in hK]
    assert all(np.isfinite(m.loss) for m in h1)


# ----------------------------------------------- host engine (single RSU)
def test_federation_rejects_streaming():
    clients, test = _vector_clients(2)
    with pytest.raises(ValueError, match="multi-RSU"):
        FederationSim(TinyMLP(), clients, test,
                      _cfg(server_schedule="streaming"))
    with pytest.raises(ValueError, match="multi-RSU"):
        FederationSim(TinyMLP(), clients, test,
                      _cfg(stream_churn_rate=0.2))


# ------------------------------------------- mobility-coupled churn source
# (ISSUE 10: stream_churn_source="mobility" — presence follows coverage)

def _gap_trace(rounds, interval):
    """Vehicle 0: covered (RSU0) -> coverage gap -> covered again; vehicle
    1 parks inside RSU0.  The gap is geometric (serving == -1), exactly
    what the mobility churn source turns into a departure + re-arrival."""
    times = np.arange(rounds + 1, dtype=np.float64) * interval
    n_steps = len(times)
    x0 = np.array([300.0, 600.0] + [300.0] * (n_steps - 2))
    x1 = np.full(n_steps, 310.0)
    x = np.stack([x0, x1], axis=-1)
    pos = np.stack([x, np.zeros_like(x)], axis=-1)
    rsus = np.array([[300.0, 0.0], [900.0, 0.0]])
    from repro.core import channel
    ch = channel.ChannelConfig(fading_std_db=0.0, rsu_range_m=200.0)
    return scenario.TraceReplay(times, pos, rsus, ch=ch, seed=0)


def test_mobility_churn_config_validation():
    with pytest.raises(ValueError, match="churn_source"):
        streaming.StreamConfig(churn_source="gps")
    with pytest.raises(ValueError, match="churn_rate must stay 0"):
        streaming.StreamConfig(churn_source="mobility", churn_rate=0.2)
    assert streaming.StreamConfig(churn_source="mobility").churning
    with pytest.raises(ValueError, match="stream_churn_source"):
        SimConfig(stream_churn_source="gps")


def test_mobility_churn_defers_reentry_on_sync_schedules():
    """With churn_source="mobility" a vehicle leaving coverage DEPARTS the
    stream; on a synchronous schedule its re-entry is an arrival that sits
    out the arrival round (registration/model download), one round behind
    the no-churn engine, which re-schedules it the moment it is covered."""
    sc = _gap_trace(ROUNDS, INTERVAL)
    clients, test = _vector_clients(2)
    base = ScenarioEngine(TinyMLP(), clients, test, _cfg(), sc,
                          cloud_sync_every=2)
    mob = ScenarioEngine(TinyMLP(), clients, test,
                         _cfg(stream_churn_source="mobility"), sc,
                         cloud_sync_every=2)
    hb, hm = base.run(), mob.run()
    assert [m.n_scheduled for m in hb] == [2, 1, 2, 2]
    assert [m.n_scheduled for m in hm] == [2, 1, 1, 2]
    # round 2 is the re-arrival: present again, not yet admitted
    assert [m.n_arrived for m in hm] == [0, 0, 1, 0]
    assert [m.n_present for m in hm] == [2, 1, 2, 2]


def test_mobility_churn_fused_matches_per_round():
    """The mobility presence plane lives on the donated carry: K-fused
    super-steps see the same presence sequence as per-round dispatch, bit
    for bit, and the fused signature precompiles (zero fallbacks)."""
    sc = _gap_trace(ROUNDS, INTERVAL)
    clients, test = _vector_clients(2)
    cfg1 = _cfg(stream_churn_source="mobility")
    cfgK = dataclasses.replace(cfg1, superstep=ROUNDS)
    e1 = ScenarioEngine(TinyMLP(), clients, test, cfg1, sc,
                        cloud_sync_every=2)
    eK = ScenarioEngine(TinyMLP(), clients, test, cfgK, sc,
                        cloud_sync_every=2)
    eK.precompile()
    h1, hK = e1.run(), eK.run()
    assert eK.programs.compile_fallbacks == 0
    np.testing.assert_array_equal([m.loss for m in h1],
                                  [m.loss for m in hK])
    assert [m.n_arrived for m in h1] == [m.n_arrived for m in hK]
    jax.tree.map(np.testing.assert_array_equal, _params(e1), _params(eK))


def test_mobility_churn_streaming_admits_immediately():
    """The buffered-async schedule registers re-entering vehicles the round
    they re-appear (no sit-out round): the re-arrival round schedules the
    full covered set."""
    sc = _gap_trace(ROUNDS, INTERVAL)
    clients, test = _vector_clients(2)
    eng = ScenarioEngine(TinyMLP(), clients, test,
                         _cfg(server_schedule="streaming",
                              stream_churn_source="mobility",
                              stream_buffer_size=2), sc,
                         cloud_sync_every=2)
    hist = eng.run()
    assert all(np.isfinite(m.loss) for m in hist)
    assert [m.n_scheduled for m in hist] == [2, 1, 2, 2]
    assert [m.n_arrived for m in hist] == [0, 0, 1, 0]
