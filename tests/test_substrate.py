"""Optimizer / checkpoint / data / compression-STE substrate tests."""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro import optim
from repro.ckpt import latest_step, restore_checkpoint, save_checkpoint
from repro.core.compression import fake_quant
from repro.data.synthetic import make_bigram_lm, make_cifar_like
from repro.data.pipeline import make_federated_data


def test_adam_converges_on_quadratic():
    opt = optim.adam(0.1)
    params = {"x": jnp.asarray([5.0, -3.0])}
    state = opt.init(params)
    for _ in range(200):
        g = jax.grad(lambda p: jnp.sum((p["x"] - 1.0) ** 2))(params)
        upd, state = opt.update(g, state, params)
        params = optim.apply_updates(params, upd)
    np.testing.assert_allclose(np.asarray(params["x"]), [1.0, 1.0], atol=1e-2)


def test_sgd_and_momentum_step_direction():
    for opt in (optim.sgd(0.5), optim.momentum(0.5)):
        params = {"x": jnp.asarray(2.0)}
        state = opt.init(params)
        g = {"x": jnp.asarray(1.0)}
        upd, state = opt.update(g, state, params)
        assert float(upd["x"]) < 0  # descent


def test_grad_clip():
    g = {"a": jnp.full((10,), 10.0)}
    clipped, norm = optim.clip_by_global_norm(g, 1.0)
    assert float(optim.global_norm(clipped)) <= 1.0 + 1e-5
    assert float(norm) > 1.0


def test_warmup_cosine_schedule():
    sch = optim.warmup_cosine(1.0, 10, 100)
    assert float(sch(jnp.asarray(0))) == 0.0
    assert abs(float(sch(jnp.asarray(10))) - 1.0) < 1e-6
    assert float(sch(jnp.asarray(100))) < 1e-3


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "nested": {"b": jnp.ones((4,), jnp.bfloat16)},
            "t": (jnp.zeros((2,)), jnp.asarray(3, jnp.int32))}
    d = str(tmp_path)
    save_checkpoint(d, 7, tree)
    assert latest_step(d) == 7
    back = restore_checkpoint(d, 7, jax.tree.map(lambda x: x, tree))
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a, np.float32), np.asarray(b, np.float32)), tree, back)
    assert back["nested"]["b"].dtype == jnp.bfloat16


def test_fake_quant_straight_through_gradient():
    x = jnp.linspace(-2, 2, 256).reshape(2, 128)
    g = jax.grad(lambda v: jnp.sum(fake_quant(v) * 3.0))(x)
    np.testing.assert_allclose(np.asarray(g), 3.0, rtol=1e-6)


def test_bigram_lm_learnable_structure():
    stream = make_bigram_lm(jax.random.PRNGKey(0), vocab=32, n_tokens=5000)
    toks = np.asarray(stream)
    assert toks.min() >= 0 and toks.max() < 32
    # bigram entropy must be far below uniform (structure present)
    joint = np.zeros((32, 32))
    np.add.at(joint, (toks[:-1], toks[1:]), 1)
    cond = joint / np.maximum(joint.sum(1, keepdims=True), 1)
    ent = -np.nansum(cond * np.log(np.maximum(cond, 1e-12)), axis=1).mean()
    assert ent < 0.8 * np.log(32)


def test_federated_data_shapes_and_noniid():
    clients, test = make_federated_data(0, n_train=512, n_test=128,
                                        n_clients=4)
    assert len(clients) == 4
    for c in clients:
        assert c.images.shape[1:] == (32, 32, 3)
        assert len(set(c.labels.tolist())) <= 6
    assert test["images"].shape[0] == 128
    # IID variant covers (almost) all classes per client
    clients_iid, _ = make_federated_data(0, n_train=512, n_test=128,
                                         n_clients=4, iid=True)
    assert all(len(set(c.labels.tolist())) >= 7 for c in clients_iid)
