"""Fused super-step benchmark (ISSUE 3 acceptance): highway_corridor fleet
rounds with K-round fusion, both server schedules, AOT precompile, and the
persistent compilation cache — compared against the per-round dispatch
baseline committed in BENCH_scenarios.json.

Three questions, three measurements per fleet size:

* steady-state rounds/s — fused K-round ``lax.scan`` dispatches (both the
  paper-faithful ``sequential`` server schedule and the companion paper's
  ``parallel`` schedule, arXiv:2405.18707) vs the engine's K=1 per-round
  dispatch path (the BENCH_scenarios.json configuration);
* warmup — AOT ``precompile()`` cold, then again on a **warm persistent
  compilation cache** (a fresh engine whose ``.lower().compile()`` calls
  deserialize from disk instead of invoking XLA);
* effective rounds/s — rounds / (warmup + run), the metric the issue's
  motivation frames ("the warmup costs the equivalent of ~150 simulated
  rounds"): short fleet simulations are warmup-dominated, and the super-step
  engine's collapsed signature set + persistent cache is what moves it.

  PYTHONPATH=src python benchmarks/bench_superstep.py
  -> BENCH_superstep.json (repo root) + benchmarks/out/BENCH_superstep.json
"""
from __future__ import annotations

import argparse
import json
import os
import shutil
import sys
import tempfile
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

import jax
import numpy as np

from bench_io import write_bench
from repro.core import scenario
from repro.core.fedsim import ScenarioEngine, SimConfig
from repro.models.mlp_unit import MLPUnitModel, make_mlp_fleet_data

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
SCENARIO = "highway_corridor"


def _engine(n, args, superstep, schedule, slot_capacity, cache_dir,
            name=SCENARIO):
    sc = scenario.make_scenario(name, n, seed=n)
    clients, test = make_mlp_fleet_data(n, 64, 48, seed=n)
    cfg = SimConfig(scheme="asfl", adaptive_strategy="paper",
                    rounds=args.rounds, local_steps=args.local_steps,
                    batch_size=args.batch, lr=1e-3, eval_every=0,
                    round_interval_s=10.0, superstep=superstep,
                    server_schedule=schedule, slot_capacity=slot_capacity,
                    superstep_layout=args.layout,
                    compilation_cache_dir=cache_dir)
    return ScenarioEngine(MLPUnitModel(), clients, test, cfg, sc,
                          cloud_sync_every=1)


def bench_variant(n, args, superstep, schedule, slot_capacity,
                  cache_dir, name=SCENARIO) -> dict:
    """Cold precompile, warm-cache precompile (fresh engine, same disk
    cache), then a timed steady-state run with zero compile fallbacks."""
    # time precompile() alone (not engine construction / data staging) so
    # the warmup numbers are commensurable with bench_scenarios' warmup_s
    eng = _engine(n, args, superstep, schedule, slot_capacity, cache_dir,
                  name)
    t0 = time.perf_counter()
    eng.precompile()
    warmup_cold = time.perf_counter() - t0
    # a fresh engine AOT-compiles the same programs; with the persistent
    # cache populated, .lower().compile() deserializes instead of compiling
    eng = _engine(n, args, superstep, schedule, slot_capacity, cache_dir,
                  name)
    t0 = time.perf_counter()
    eng.precompile()
    warmup_warm = time.perf_counter() - t0
    eng.run()                               # staging warm-up (no compiles)
    dt = None
    for _ in range(max(args.timeit, 1)):    # min of N strips CPU noise
        eng.reset()
        t0 = time.perf_counter()
        hist = eng.run()
        rep = time.perf_counter() - t0
        dt = rep if dt is None else min(dt, rep)
    assert all(np.isfinite(m.loss) for m in hist)
    assert eng.programs.compile_fallbacks == 0
    occ = eng.occupancy_stats()
    return {
        "scenario": name, "n_vehicles": n, "superstep": superstep,
        "schedule": schedule, "slot_capacity": slot_capacity,
        "superstep_layout": occ["layout"],
        "rounds": args.rounds,
        "round_s": dt / args.rounds,
        "rounds_per_s": args.rounds / dt,
        "warmup_cold_s": warmup_cold,
        "warmup_warm_cache_s": warmup_warm,
        "effective_rounds_per_s_cold": args.rounds / (warmup_cold + dt),
        "effective_rounds_per_s_warm": args.rounds / (warmup_warm + dt),
        # occupancy accounting (DESIGN.md §12)
        "padded_slot_frac": occ["padded_slot_frac"],
        "owned_plane_frac": occ["owned_plane_frac"],
        "effective_flops_utilization": occ["effective_flops_utilization"],
        "handovers": int(sum(m.n_handover for m in hist)),
        "final_loss": float(hist[-1].loss),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--sizes", default="64,256")
    ap.add_argument("--rounds", type=int, default=8)
    ap.add_argument("--superstep", type=int, default=8)
    ap.add_argument("--local-steps", type=int, default=2)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--schedules", default="sequential,parallel")
    ap.add_argument("--slot-capacity", default="tight8",
                    choices=["pow2", "tight8"])
    ap.add_argument("--layout", default="ragged",
                    choices=["ragged", "dense"],
                    help="super-step slot layout (DESIGN.md §12): ragged "
                         "compacts occupied slots + cut-prefix planes")
    ap.add_argument("--timeit", type=int, default=3,
                    help="timed steady-state runs per row (min wins)")
    ap.add_argument("--compilation-cache", default=None, metavar="DIR",
                    help="persistent cache dir (default: fresh temp dir)")
    ap.add_argument("--baseline", default=os.path.join(
        ROOT, "BENCH_scenarios.json"))
    args = ap.parse_args()
    assert args.superstep >= 4, "acceptance asks for super-step K>=4"

    cache_dir = args.compilation_cache or tempfile.mkdtemp(
        prefix="superstep-xla-cache-")
    baseline, baseline_cfg = {}, {}
    if os.path.exists(args.baseline):
        with open(args.baseline) as f:
            b = json.load(f)
        baseline = {(r["scenario"], r["n_vehicles"]): r
                    for r in b.get("results", [])
                    if r.get("devices", 1) == 1}    # single-device reference
        baseline_cfg = b.get("config", {})

    results = []
    for n in (int(s) for s in args.sizes.split(",")):
        # the K=1 per-round dispatch reference (BENCH_scenarios.json config)
        rows = [bench_variant(n, args, 1, "sequential", "pow2", cache_dir)]
        for sched in args.schedules.split(","):
            rows.append(bench_variant(n, args, args.superstep, sched,
                                      args.slot_capacity, cache_dir))
        # the skewed-load stress row (one crowded cell, sparse tail): where
        # occupancy compaction pays most — a dense table pads every RSU to
        # the crowded cell's cohort
        rows.append(bench_variant(n, args, args.superstep, "parallel",
                                  args.slot_capacity, cache_dir,
                                  name="highway_zipf"))
        dispatch = rows[0]                     # the K=1 per-round reference
        for row in rows:
            base = baseline.get((row["scenario"], n))
            row["speedup_vs_per_round_dispatch"] = \
                row["rounds_per_s"] / dispatch["rounds_per_s"]
            if base:
                row["baseline_rounds_per_s"] = base["rounds_per_s"]
                row["baseline_warmup_s"] = base["warmup_s"]
                row["speedup_rounds_per_s_vs_baseline"] = \
                    row["rounds_per_s"] / base["rounds_per_s"]
                row["warmup_reduction_vs_baseline"] = \
                    base["warmup_s"] / row["warmup_warm_cache_s"]
                row["effective_speedup_vs_baseline"] = (
                    row["effective_rounds_per_s_warm"]
                    / (base["rounds"] / (base["warmup_s"]
                                         + base["rounds"] * base["round_s"])))
            results.append(row)
            print(f"{row['scenario']} n={n:4d} K={row['superstep']} "
                  f"{row['schedule']:10s}: {row['rounds_per_s']:6.2f} r/s "
                  f"({row['speedup_vs_per_round_dispatch']:.2f}x vs K=1)  "
                  f"warmup cold {row['warmup_cold_s']:5.1f}s / warm "
                  f"{row['warmup_warm_cache_s']:5.1f}s"
                  + (f"  [{row['speedup_rounds_per_s_vs_baseline']:.2f}x r/s,"
                     f" {row['warmup_reduction_vs_baseline']:.1f}x warmup,"
                     f" {row['effective_speedup_vs_baseline']:.1f}x "
                     f"effective vs baseline]" if base else ""), flush=True)

    # acceptance summary at the largest fleet.  The committed
    # BENCH_scenarios.json baseline is itself the fused recommended
    # operating point (its config block records the superstep), so the
    # K-fusion benefit is measured against this bench's own K=1 per-round
    # dispatch row; ratios vs the baseline file are reported alongside,
    # unmasked.
    n_max = max(int(s) for s in args.sizes.split(","))
    fused = [r for r in results
             if r["n_vehicles"] == n_max and r["superstep"] >= 4]
    acceptance = {}
    if fused:
        best_tp = max(fused, key=lambda r: r["rounds_per_s"])
        acceptance = {
            "fleet": n_max,
            "rounds_per_s_ratio_vs_per_round_dispatch": {
                "value": best_tp["speedup_vs_per_round_dispatch"],
                "schedule": best_tp["schedule"], "target": 3.0},
        }
        with_base = [r for r in fused
                     if "speedup_rounds_per_s_vs_baseline" in r]
        if with_base:
            best_fb = max(with_base,
                          key=lambda r:
                          r["speedup_rounds_per_s_vs_baseline"])
            best_wu = max(with_base,
                          key=lambda r: r["warmup_reduction_vs_baseline"])
            best_ef = max(with_base,
                          key=lambda r: r["effective_speedup_vs_baseline"])
            acceptance.update({
                "rounds_per_s_ratio_vs_baseline_file": {
                    "value": best_fb["speedup_rounds_per_s_vs_baseline"],
                    "schedule": best_fb["schedule"], "target": 3.0,
                    "note": "baseline file already runs fused superstep="
                            f"{baseline_cfg.get('superstep')}"},
                "warm_warmup_reduction_vs_baseline": {
                    "value": best_wu["warmup_reduction_vs_baseline"],
                    "schedule": best_wu["schedule"], "target": 5.0},
                # rounds/(warmup+run): the amortized metric the issue's
                # motivation frames warmup in ("~150 simulated rounds")
                "effective_rounds_per_s_ratio_vs_baseline": {
                    "value": best_ef["effective_speedup_vs_baseline"],
                    "schedule": best_ef["schedule"], "target": 3.0},
            })
    def row_key(r):
        return (f"{r['scenario']}@{r['n_vehicles']}/K{r['superstep']}/"
                f"{r['schedule']}")

    out = {
        "config": {"local_steps": args.local_steps, "batch": args.batch,
                   "rounds": args.rounds, "superstep": args.superstep,
                   "slot_capacity": args.slot_capacity,
                   "superstep_layout": args.layout,
                   "timeit": args.timeit,
                   "strategy": "paper", "cloud_sync_every": 1,
                   "baseline_file": os.path.basename(args.baseline),
                   "backend": jax.default_backend()},
        # top-level summary keys, schema-aligned with BENCH_scenarios.json
        # (tooling reads the same two keys off either file)
        "warmup_total_s": float(sum(r["warmup_cold_s"] for r in results)),
        "rounds_per_s": {row_key(r): r["rounds_per_s"] for r in results},
        "acceptance": acceptance,
        "results": results,
    }
    write_bench("BENCH_superstep", out, "benchmarks/bench_superstep.py")
    if not args.compilation_cache:
        shutil.rmtree(cache_dir, ignore_errors=True)


if __name__ == "__main__":
    main()
