"""Device-sharded fleets (ISSUE 5 acceptance tests, DESIGN.md §10).

Two tiers:

* Always-on (any device count): FleetMesh construction/padding rules, spec
  validation of mesh combinations, and — the load-bearing ones — engines
  driven through the FULL ``shard_map`` path on an explicit ONE-device mesh
  asserted bit-identical to the default unsharded engines.  Every
  collective (all_gather, psum) degenerates to identity on one device, so
  these run in plain tier-1 and keep the sharded code from rotting.

* 8-device (skipped unless ``XLA_FLAGS=--xla_force_host_platform_
  device_count=8`` — the CI multi-device job sets it): K-fused sgd
  bit-for-bit across the mesh with a handover AND a cloud merge inside the
  fused window, adam within the engine-parity tolerance, cohort-engine
  parity, and padding inertness for fleets/RSU counts that do not divide
  the device count.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import fleet_sharding
from repro.core.fedsim import FederationSim, ScenarioEngine, SimConfig
from repro.core.fleet_sharding import FleetMesh, build_fleet_mesh

from test_scenario import TinyMLP, _two_cell_trace, _vector_clients

DEV = jax.device_count()
ROUNDS, INTERVAL = 4, 5.0

need8 = pytest.mark.skipif(
    DEV < 8, reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")


def _cfg(**kw):
    base = dict(scheme="asfl", adaptive_strategy="paper", rounds=ROUNDS,
                local_steps=2, batch_size=8, lr=1e-2, optimizer="sgd",
                round_interval_s=INTERVAL, eval_every=0, superstep=ROUNDS)
    base.update(kw)
    return SimConfig(**base)


def _params(eng):
    return jax.tree.map(np.asarray, {"units": eng.units, "head": eng.head})


def _assert_histories_equal(h1, h2, exact=True):
    assert [m.cuts for m in h1] == [m.cuts for m in h2]
    if hasattr(h1[0], "rsu_loads"):
        assert [m.rsu_loads for m in h1] == [m.rsu_loads for m in h2]
        assert [m.n_handover for m in h1] == [m.n_handover for m in h2]
    l1, l2 = [m.loss for m in h1], [m.loss for m in h2]
    if exact:
        np.testing.assert_array_equal(l1, l2)
    else:
        np.testing.assert_allclose(l1, l2, rtol=1e-5, atol=1e-5)


def _scenario_engines(n_devices, **cfg_kw):
    """(reference engine, mesh engine) over the canonical two-cell handover
    trace with a cloud merge strictly inside the fused window."""
    sc = _two_cell_trace(ROUNDS, INTERVAL)
    clients, test = _vector_clients(2)
    cfg = _cfg(**cfg_kw)
    ref = ScenarioEngine(TinyMLP(), clients, test, cfg, sc,
                         cloud_sync_every=2)
    mesh = build_fleet_mesh(n_devices, "rsu")
    eng = ScenarioEngine(TinyMLP(), clients, test, cfg, sc,
                         cloud_sync_every=2, mesh=mesh)
    return ref, eng


# ----------------------------------------------------------- mesh plumbing
def test_fleet_mesh_padding_rules():
    mesh = build_fleet_mesh(1, "vehicle")
    assert mesh.n_devices == 1
    assert [mesh.pad(n) for n in (0, 1, 3, 8)] == [1, 1, 3, 8]
    if DEV >= 2:
        m2 = build_fleet_mesh(2, "rsu")
        assert [m2.pad(n) for n in (1, 2, 3, 8)] == [2, 2, 4, 8]


def test_fleet_mesh_build_errors():
    with pytest.raises(ValueError, match="vehicle|rsu"):
        build_fleet_mesh(1, "bogus")
    with pytest.raises(RuntimeError,
                       match="xla_force_host_platform_device_count"):
        build_fleet_mesh(DEV + 1, "rsu")
    with pytest.raises(ValueError, match=">= 1"):
        build_fleet_mesh(0, "vehicle")


def test_from_config_default_is_unsharded():
    assert fleet_sharding.from_config(_cfg(), "scenario") is None
    assert fleet_sharding.from_config(_cfg(), "federation") is None


def test_engines_reject_wrong_axis_mesh():
    sc = _two_cell_trace(ROUNDS, INTERVAL)
    clients, test = _vector_clients(2)
    with pytest.raises(ValueError, match="RSU axis"):
        ScenarioEngine(TinyMLP(), clients, test, _cfg(), sc,
                       mesh=build_fleet_mesh(1, "vehicle"))
    with pytest.raises(ValueError, match="vehicle axis"):
        FederationSim(TinyMLP(), clients, test,
                      _cfg(superstep=1, cohort_parallel="vmap"),
                      mesh=build_fleet_mesh(1, "rsu"))


def test_spec_validates_mesh_combinations():
    from repro import api
    rt = lambda **kw: api.RuntimeConfig(mesh_devices=2, **kw)
    # single-RSU engine: rsu axis / sequential chains / serial schedules
    with pytest.raises(ValueError, match="vehicle axis"):
        api.ExperimentSpec(runtime=rt(fleet_axis="rsu"))
    with pytest.raises(ValueError, match="sequential chain"):
        api.ExperimentSpec(train=api.TrainConfig(scheme="sl"), runtime=rt())
    with pytest.raises(ValueError, match="cohort_parallel"):
        api.ExperimentSpec(runtime=rt(cohort_parallel="scan"))
    # multi-RSU engine: vehicle axis cannot partition it
    with pytest.raises(ValueError, match="RSU axis"):
        api.ExperimentSpec(
            fleet=api.FleetConfig(n_vehicles=8, scenario="highway_corridor"),
            runtime=rt(fleet_axis="vehicle"))
    # valid combos build
    api.ExperimentSpec(runtime=rt())
    api.ExperimentSpec(
        fleet=api.FleetConfig(n_vehicles=8, scenario="highway_corridor"),
        runtime=rt(fleet_axis="rsu"))
    # field-level validation still lives in SimConfig
    with pytest.raises(ValueError, match="fleet_axis"):
        SimConfig(fleet_axis="diagonal")
    with pytest.raises(ValueError, match="mesh_devices"):
        SimConfig(mesh_devices=0)


# ----------------------- one-device mesh == default engine, bit for bit
# (the full shard_map/all_gather/psum path with every collective degenerate
# — keeps the sharded code exercised by plain single-device tier-1 runs)

def test_one_device_mesh_superstep_bitforbit():
    ref, eng = _scenario_engines(1)
    assert eng.programs.mesh is not None
    h1, h2 = ref.run(), eng.run()
    assert sum(m.n_handover for m in h1) >= 1
    _assert_histories_equal(h1, h2)
    jax.tree.map(np.testing.assert_array_equal, _params(ref), _params(eng))


def test_one_device_mesh_ragged_parallel_bitforbit():
    """The ragged+parallel mesh path (replicated edge, psum'd segment-sum
    partials — DESIGN.md §12) on ONE device: every collective degenerates,
    so the compacted sharded program must equal the unsharded one bit for
    bit — keeps the slot-sharded code exercised in plain tier-1."""
    ref, eng = _scenario_engines(1, server_schedule="parallel",
                                 superstep_layout="ragged")
    assert eng.programs.mesh is not None
    h1, h2 = ref.run(), eng.run()
    _assert_histories_equal(h1, h2)
    jax.tree.map(np.testing.assert_array_equal, _params(ref), _params(eng))


def test_one_device_mesh_cohort_matches_default():
    """The sharded cohort path on one device: losses are bit-identical
    (every collective is an identity), params agree to ~1 ulp — inserting
    the (identity) psum into the FedAvg moves an XLA fusion boundary, so
    the merge divide rounds once differently; anything beyond that is a
    real bug."""
    clients, test = _vector_clients(5)      # odd fleet: padded slots in play
    cfg = _cfg(superstep=1, cohort_parallel="vmap", n_clients=5)
    ref = FederationSim(TinyMLP(), clients, test, cfg)
    eng = FederationSim(TinyMLP(), clients, test, cfg,
                        mesh=build_fleet_mesh(1, "vehicle"))
    assert eng.engine.fleet_mesh is not None
    h1, h2 = ref.run(), eng.run()
    _assert_histories_equal(h1, h2)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        a, b, rtol=1e-6, atol=1e-7), _params(ref), _params(eng))


# ------------------------------------------------ 8-device parity suite
@need8
@pytest.mark.parametrize("schedule", ["sequential", "parallel"])
def test_superstep_sharded_sgd_bitforbit(schedule):
    """K-fused sgd across an 8-device RSU mesh == the single-device engine
    bit for bit; the fused window contains vehicle 0's handover AND a cloud
    merge (cloud_sync_every=2 inside a K=4 window).  The 2-RSU trace pads
    to 8 phantom cells — padding inertness on the RSU axis included.

    The parallel schedule pins ``superstep_layout="dense"``: only the
    RSU-aligned slot-block sharding is bit-exact across the mesh; the
    ragged compacted axis psums segment-sum partials and is covered by the
    tolerance test below (DESIGN.md §12)."""
    layout = "dense" if schedule == "parallel" else "ragged"
    ref, eng = _scenario_engines(8, server_schedule=schedule,
                                 superstep_layout=layout)
    assert eng.programs.n_rsus_padded == 8
    h1, h2 = ref.run(), eng.run()
    assert sum(m.n_handover for m in h1) >= 1
    _assert_histories_equal(h1, h2)
    jax.tree.map(np.testing.assert_array_equal, _params(ref), _params(eng))


@need8
def test_superstep_sharded_adam_within_parity_tolerance():
    ref, eng = _scenario_engines(8, optimizer="adam")
    h1, h2 = ref.run(), eng.run()
    _assert_histories_equal(h1, h2, exact=False)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        a, b, atol=1e-5, rtol=1e-5), _params(ref), _params(eng))


@need8
def test_superstep_sharded_ragged_parallel_tolerance():
    """Occupancy-balanced slot sharding (DESIGN.md §12): the compacted
    slot axis splits into equal contiguous blocks per device and the
    per-RSU segment sums become psum'd partials — the psum reassociates
    float additions, so parity with the single-device compacted program is
    tolerance-level, not bit-exact (sgd)."""
    ref, eng = _scenario_engines(8, server_schedule="parallel",
                                 superstep_layout="ragged")
    assert eng.programs.layout == "ragged"
    h1, h2 = ref.run(), eng.run()
    _assert_histories_equal(h1, h2, exact=False)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        a, b, atol=1e-5, rtol=1e-5), _params(ref), _params(eng))


@need8
def test_superstep_sharded_precompile_covers():
    """AOT precompile covers the sharded signatures: a full run builds
    nothing mid-flight (fallback counter stays zero) and the donated
    sharded carry survives windowing."""
    sc = _two_cell_trace(ROUNDS, INTERVAL)
    clients, test = _vector_clients(2)
    eng = ScenarioEngine(TinyMLP(), clients, test, _cfg(superstep=3), sc,
                         cloud_sync_every=2, mesh=build_fleet_mesh(8, "rsu"))
    sigs = eng.precompile()
    assert len(sigs) == 2                      # K=3 and the K=1 tail
    hist = eng.run()
    assert eng.programs.compile_fallbacks == 0
    assert len(hist) == ROUNDS


@need8
@pytest.mark.parametrize("optimizer", ["sgd", "adam"])
def test_cohort_sharded_parity_nondivisible_fleet(optimizer):
    """Vehicle-axis sharding of the cohort engine: a 6-vehicle fleet pads
    its cut buckets to device multiples (padding inertness for
    non-divisible fleets) and matches the single-device vmap engine within
    the engine-parity fp tolerance (the FedAvg psum reassociates float
    additions, so sgd is near- but not bit-exact — DESIGN.md §10)."""
    clients, test = _vector_clients(6)
    cfg = _cfg(superstep=1, cohort_parallel="vmap", n_clients=6,
               optimizer=optimizer)
    ref = FederationSim(TinyMLP(), clients, test, cfg)
    eng = FederationSim(TinyMLP(), clients, test,
                        dataclasses.replace(cfg, mesh_devices=8))
    assert eng.engine.slot_pad(6) == 8
    h1, h2 = ref.run(), eng.run()
    _assert_histories_equal(h1, h2, exact=False)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        a, b, atol=1e-5, rtol=1e-5), _params(ref), _params(eng))


@need8
def test_fl_sharded_parity():
    clients, test = _vector_clients(6)
    cfg = _cfg(scheme="fl", superstep=1, cohort_parallel="vmap", n_clients=6)
    ref = FederationSim(TinyMLP(), clients, test, cfg)
    eng = FederationSim(TinyMLP(), clients, test,
                        dataclasses.replace(cfg, mesh_devices=8))
    h1, h2 = ref.run(), eng.run()
    np.testing.assert_allclose([m.loss for m in h1], [m.loss for m in h2],
                               rtol=1e-5, atol=1e-5)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        a, b, atol=1e-5, rtol=1e-5), _params(ref), _params(eng))


@need8
def test_api_run_on_mesh_gathers_final_params():
    """The front door builds the mesh from RuntimeConfig and returns
    host-numpy final params regardless of where training ran."""
    from repro import api
    spec = api.ExperimentSpec(
        model="mlp9",
        train=api.TrainConfig(scheme="asfl", rounds=2, local_steps=1,
                              batch_size=8, lr=1e-3, eval_every=0,
                              optimizer="sgd"),
        fleet=api.FleetConfig(n_vehicles=8, scenario="trace_replay",
                              per_vehicle_samples=16),
        runtime=api.RuntimeConfig(superstep=2, mesh_devices=8))
    res = api.run(spec)
    assert res.diagnostics["mesh_devices"] == 8
    assert res.diagnostics["fleet_axis"] == "rsu"
    units, head = res.final_params
    assert all(isinstance(leaf, np.ndarray)
               for leaf in jax.tree.leaves((units, head)))
    ref = api.run(dataclasses.replace(
        spec, runtime=dataclasses.replace(spec.runtime, mesh_devices=1)))
    # the trained model is bit-identical; the scalar loss METRIC may move
    # one ulp (XLA fuses the per-round loss sum differently at different
    # vmap widths — a reporting reduction, not training state)
    np.testing.assert_allclose([m.loss for m in ref.history],
                               [m.loss for m in res.history],
                               rtol=1e-6, atol=0)
    jax.tree.map(np.testing.assert_array_equal,
                 res.final_params, ref.final_params)
