"""Attention: GQA, qk-norm, causal + sliding-window, KV-cache decode.

Training/prefill attention is computed in q-chunks (a jnp blockwise
formulation, scan over query blocks) so the materialised score block is
bounded — the same tiling the Pallas flash_attention kernel uses on TPU.
The kernel (repro.kernels.flash_attention) is injectable via ``use_kernel``.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers as L

Params = Dict[str, Any]
NEG_INF = -2.0e38

# q-chunk length for the blockwise softmax (static; clipped to seq len)
Q_CHUNK = 512

# Optional SDPA batch-spread (perf knob, set at trace time by the launcher):
# when the per-layer activations can only shard batch over the data axes
# (head counts not divisible by the model axis), resharding the batch over
# (data x model) for the SDPA inner block removes the model-axis replication
# of the score tensors.  Holds a pair (spread_sharding, restore_sharding) of
# NamedShardings for (b, s, heads, head_dim) activations, or None.
SDPA_SPREAD = None


def set_sdpa_spread(spread_restore):
    """Install (spread, restore) NamedShardings for 4-D attention
    activations, or None to disable.  Trace-time switch."""
    global SDPA_SPREAD
    SDPA_SPREAD = spread_restore


def init_attn(key, cfg: ArchConfig, dtype=jnp.float32) -> Params:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {
        "wq": L.trunc_normal(k1, (d, h, hd), 1.0 / math.sqrt(d), dtype),
        "wk": L.trunc_normal(k2, (d, kv, hd), 1.0 / math.sqrt(d), dtype),
        "wv": L.trunc_normal(k3, (d, kv, hd), 1.0 / math.sqrt(d), dtype),
        "wo": L.trunc_normal(k4, (h, hd, d), 1.0 / math.sqrt(h * hd), dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dtype)
        p["k_norm"] = jnp.ones((hd,), dtype)
    return p


def _qkv(p: Params, cfg: ArchConfig, x: jnp.ndarray, positions: jnp.ndarray):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dnk->bsnk", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dnk->bsnk", x, p["wv"].astype(x.dtype))
    if cfg.qk_norm:
        q = L.rms_head_norm(p["q_norm"], q)
        k = L.rms_head_norm(p["k_norm"], k)
    if cfg.pos == "rope":
        q = L.apply_rope(q, positions, cfg.rope_theta)
        k = L.apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _sdpa(q, k, v, q_pos, k_pos, window: int, scale: float):
    """One score block.  q (b,sq,n,g,hd), k/v (b,sk,n,hd),
    q_pos (sq,), k_pos (sk,) — k_pos < 0 marks invalid slots."""
    s = jnp.einsum("bsngh,btnh->bngst", q, k).astype(jnp.float32) * scale
    mask = k_pos[None, :] <= q_pos[:, None]
    mask &= k_pos[None, :] >= 0
    if window > 0:
        mask &= k_pos[None, :] > q_pos[:, None] - window
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    # rows with no valid key (fully masked) produce uniform junk; zero them
    any_valid = jnp.any(mask, axis=-1)
    p = jnp.where(any_valid[..., None], p, 0.0).astype(v.dtype)
    return jnp.einsum("bngst,btnh->bsngh", p, v)


def _full_attention(cfg: ArchConfig, q, k, v, q_pos, k_pos, window: int):
    """Blockwise over q-chunks; k optionally sliced to the window span."""
    b, sq, h, hd = q.shape
    kvh = k.shape[2]
    g = h // kvh
    spread = SDPA_SPREAD
    if spread is not None:
        sp, _ = spread
        q = jax.lax.with_sharding_constraint(q, sp)
        k = jax.lax.with_sharding_constraint(k, sp)
        v = jax.lax.with_sharding_constraint(v, sp)
    q = q.reshape(b, sq, kvh, g, hd)
    scale = 1.0 / math.sqrt(hd)
    sk = k.shape[1]
    cq = min(Q_CHUNK, sq)
    if sq % cq:
        cq = sq  # ragged seq (smoke tests): single block
    n_chunks = sq // cq
    if n_chunks == 1:
        o = _sdpa(q, k, v, q_pos, k_pos, window, scale)
        o = o.reshape(b, sq, h, hd)
        if spread is not None and spread[1] is not None:
            o = jax.lax.with_sharding_constraint(o, spread[1])
        return o

    slice_k = window > 0 and sk > 2 * window and (window + cq) < sk
    span = min(sk, window + cq) if slice_k else sk

    def body(_, idx):
        q0 = idx * cq
        qc = jax.lax.dynamic_slice_in_dim(q, q0, cq, axis=1)
        qp = jax.lax.dynamic_slice_in_dim(q_pos, q0, cq, axis=0)
        if slice_k:
            start = jnp.clip(q0 - window, 0, sk - span)
            kc = jax.lax.dynamic_slice_in_dim(k, start, span, axis=1)
            vc = jax.lax.dynamic_slice_in_dim(v, start, span, axis=1)
            kp = jax.lax.dynamic_slice_in_dim(k_pos, start, span, axis=0)
        else:
            kc, vc, kp = k, v, k_pos
        return None, _sdpa(qc, kc, vc, qp, kp, window, scale)

    _, o = jax.lax.scan(body, None, jnp.arange(n_chunks))
    o = jnp.moveaxis(o, 0, 1).reshape(b, n_chunks * cq, kvh, g, hd)
    o = o.reshape(b, sq, h, hd)
    if spread is not None and spread[1] is not None:
        o = jax.lax.with_sharding_constraint(o, spread[1])
    return o


# --------------------------------------------------------------------------
# public entry points
# --------------------------------------------------------------------------

def attn_train(p: Params, cfg: ArchConfig, x: jnp.ndarray,
               positions: jnp.ndarray, window: int = 0) -> jnp.ndarray:
    """Causal self-attention over the full sequence (no cache)."""
    q, k, v = _qkv(p, cfg, x, positions)
    o = _full_attention(cfg, q, k, v, positions, positions, window)
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(x.dtype))


def init_cache(cfg: ArchConfig, batch: int, capacity: int, window: int,
               dtype=jnp.float32) -> Params:
    kv, hd = cfg.n_kv_heads, cfg.head_dim_
    size = min(window, capacity) if window > 0 else capacity
    return {
        "k": jnp.zeros((batch, size, kv, hd), dtype),
        "v": jnp.zeros((batch, size, kv, hd), dtype),
        "k_pos": jnp.full((size,), -1, jnp.int32),
        "pos": jnp.zeros((), jnp.int32),
    }


def attn_prefill(p: Params, cfg: ArchConfig, x: jnp.ndarray,
                 positions: jnp.ndarray, capacity: int,
                 window: int = 0) -> Tuple[jnp.ndarray, Params]:
    """Full-sequence attention that also returns a filled KV cache."""
    b, s, _ = x.shape
    q, k, v = _qkv(p, cfg, x, positions)
    o = _full_attention(cfg, q, k, v, positions, positions, window)
    y = jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(x.dtype))
    cache = init_cache(cfg, b, capacity, window, k.dtype)
    size = cache["k"].shape[1]
    if window > 0 and s >= size:
        # ring buffer: slot of position p is p % size
        k_last = k[:, s - size:, :, :]
        v_last = v[:, s - size:, :, :]
        shift = s % size
        cache["k"] = jnp.roll(k_last, shift, axis=1)
        cache["v"] = jnp.roll(v_last, shift, axis=1)
        kp = jnp.arange(s - size, s, dtype=jnp.int32)
        cache["k_pos"] = jnp.roll(kp, shift, axis=0)
    else:
        n = min(s, size)
        cache["k"] = jax.lax.dynamic_update_slice_in_dim(cache["k"], k[:, :n], 0, axis=1)
        cache["v"] = jax.lax.dynamic_update_slice_in_dim(cache["v"], v[:, :n], 0, axis=1)
        cache["k_pos"] = cache["k_pos"].at[:n].set(jnp.arange(n, dtype=jnp.int32))
    cache["pos"] = jnp.asarray(s, jnp.int32)
    return y, cache


def attn_decode(p: Params, cfg: ArchConfig, x: jnp.ndarray,
                cache: Params, window: int = 0) -> Tuple[jnp.ndarray, Params]:
    """One-token decode.  x (b, 1, d)."""
    b = x.shape[0]
    pos = cache["pos"]
    positions = pos[None].astype(jnp.int32)  # (1,)
    q, k, v = _qkv(p, cfg, x, positions)
    size = cache["k"].shape[1]
    slot = jnp.mod(pos, size) if window > 0 else jnp.minimum(pos, size - 1)
    ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, slot, axis=1)
    cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, slot, axis=1)
    kp = jax.lax.dynamic_update_slice_in_dim(
        cache["k_pos"], pos[None], slot, axis=0)
    h, kvh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    qh = q.reshape(b, 1, kvh, h // kvh, hd)
    o = _sdpa(qh, ck, cv, positions, kp, window, 1.0 / math.sqrt(hd))
    o = o.reshape(b, 1, h, hd)
    y = jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(x.dtype))
    new_cache = {"k": ck, "v": cv, "k_pos": kp, "pos": pos + 1}
    return y, new_cache


def attn_flops(cfg: ArchConfig, seq: int, window: int = 0) -> int:
    """Per-token matmul FLOPs for one attention layer at context `seq`."""
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    proj = 2 * d * hd * (2 * h + 2 * kv)
    ctx = min(seq, window) if window > 0 else seq
    sdpa = 2 * 2 * h * hd * ctx  # qk + pv
    return proj + sdpa
