"""Vehicular mobility simulation: watch the adaptive cut-layer rule react as
vehicles drive past the RSU (the paper's core 'adaptive' story).

Eight vehicles approach, pass, and leave the RSU's coverage; at each round
the channel model yields per-vehicle Shannon rates (one vectorized draw for
the whole fleet), and the three cut strategies (paper Eq. 3, latency-optimal,
energy-aware) pick cut layers.  Also demonstrates the memory-constrained
clamp (a vehicle-side budget the DBRX-scale architectures force — DESIGN.md
§4), and finishes by training the fleet for two ASFL rounds through the
cohort engine (DESIGN.md §6) with per-vehicle memory budgets.

  PYTHONPATH=src python examples/vehicular_sim.py          # strategy trace
  PYTHONPATH=src python examples/vehicular_sim.py --train  # + engine rounds
"""
import sys
import time

sys.path.insert(0, "src")

import numpy as np

from repro.core import adaptive, channel
from repro.core.cost import resnet_profile, sfl_client_round_cost


def main():
    prof = resnet_profile()
    fleet = channel.make_fleet(8, seed=7)
    ch = channel.ChannelConfig()
    flops = [v.compute_flops for v in fleet]
    n_batches, batch, sf = 32, 16, 2e12

    print("t(s) | vehicle rates (Mbit/s) -> cuts [paper Eq.3] "
          "[latency-opt] [energy-aware]")
    for t in np.linspace(0, 30, 7):
        rates = channel.sample_round_rates(ch, fleet, float(t), seed=int(t))
        in_rng = [channel.in_range(ch, v, float(t)) for v in fleet]
        cuts_p = adaptive.paper_threshold(rates)
        cuts_l = adaptive.latency_optimal(prof, rates, flops, sf, n_batches,
                                          batch, candidate_cuts=(2, 4, 6, 8))
        cuts_e = adaptive.energy_aware(prof, rates, flops, sf, n_batches,
                                       batch, candidate_cuts=(2, 4, 6, 8))
        rstr = " ".join(f"{r/1e6:5.1f}{'' if ok else '!'}"
                        for r, ok in zip(rates, in_rng))
        print(f"{t:4.0f} | {rstr} -> {cuts_p} {cuts_l} {cuts_e}")
    print("('!' marks vehicles outside RSU coverage: they skip the round —")
    print(" the mobility interruption problem the paper highlights)")

    # round latency comparison at t=15
    rates = channel.sample_round_rates(ch, fleet, 15.0, seed=15)
    for name, cuts in [
        ("fixed cut 4 (SFL)", [4] * 8),
        ("paper Eq.3 (ASFL)", adaptive.paper_threshold(rates)),
        ("latency-optimal  ", adaptive.latency_optimal(
            prof, rates, flops, sf, n_batches, batch,
            candidate_cuts=(2, 4, 6, 8))),
    ]:
        lat = max(sfl_client_round_cost(prof, c, n_batches, batch, r, f, sf,
                                        local_epochs=5).latency
                  for c, r, f in zip(cuts, rates, flops))
        print(f"round latency {name}: {lat:7.1f}s  cuts={cuts}")

    # vehicle-side memory budget (the DBRX argument): fleet-wide scalar ...
    budget = 64 * 1024 * 1024  # 64 MiB on-vehicle budget
    cuts = adaptive.memory_constrained(prof, budget, adaptive.paper_threshold,
                                       rates)
    print(f"with a {budget>>20} MiB vehicle budget the cuts clamp to {cuts}")
    # ... or per-vehicle (VehicleProfile.memory_budget_bytes)
    het = channel.make_fleet(8, seed=7, memory_budget_bytes=(1e5, 8e6))
    cuts = adaptive.memory_constrained(
        prof, channel.fleet_arrays(het)["memory_budget_bytes"],
        adaptive.paper_threshold, rates)
    print(f"with per-vehicle budgets (0.1-8 MB) they clamp to    {cuts}")


def train(n_vehicles: int = 8, rounds: int = 2):
    """Two ASFL rounds over the fleet through the cohort engine: the whole
    round (all buckets, all local steps, the unit-wise FedAvg) runs as one
    or a few compiled programs with per-vehicle memory-clamped cuts.

    Pass ``--compilation-cache DIR`` (after ``--train``) to point JAX's
    persistent compilation cache at DIR: a second invocation deserializes
    the compiled round programs instead of re-running XLA (README
    quickstart / DESIGN.md §8)."""
    from repro.core.fedsim import FederationSim, ResNetModel, SimConfig
    from repro.data.pipeline import make_federated_data

    cache = None
    if "--compilation-cache" in sys.argv:
        i = sys.argv.index("--compilation-cache") + 1
        if i >= len(sys.argv) or sys.argv[i].startswith("--"):
            sys.exit("--compilation-cache requires a directory argument")
        cache = sys.argv[i]
    clients, test = make_federated_data(0, n_train=32 * n_vehicles,
                                        n_test=128, n_clients=n_vehicles)
    fleet = channel.make_fleet(n_vehicles, seed=7,
                               memory_budget_bytes=(5e5, 5e7))
    cfg = SimConfig(scheme="asfl", adaptive_strategy="memory", rounds=rounds,
                    local_steps=2, batch_size=8, lr=1e-3,
                    compilation_cache_dir=cache)
    sim = FederationSim(ResNetModel(), clients, test, cfg, fleet=fleet)
    print(f"\ntraining {n_vehicles} vehicles, scheme=asfl(memory), "
          f"engine mode={sim.engine.mode}")
    t0 = time.time()
    for m in sim.run():
        print(f"round {m.round}: loss={m.loss:.3f} acc={m.test_acc:.3f} "
              f"cuts={m.cuts}")
    print(f"({time.time()-t0:.1f}s wall incl. compile)")


if __name__ == "__main__":
    main()
    if "--train" in sys.argv:
        train()
