"""Non-IID client partitioners.

``label_skew_power_law`` is the paper's setting: each vehicle keeps only
``labels_per_client`` of the ``n_classes`` labels (6 of 10 in the paper) and
sample counts follow a power law as in Li et al., "Federated Optimization in
Heterogeneous Networks" (paper ref [14]).
"""
from __future__ import annotations

from typing import List

import numpy as np


def label_skew_power_law(seed: int, labels: np.ndarray, n_clients: int,
                         labels_per_client: int = 6, n_classes: int = 10,
                         power: float = 1.5) -> List[np.ndarray]:
    rng = np.random.default_rng(seed)
    labels = np.asarray(labels)
    # which labels each client may hold
    client_labels = [rng.choice(n_classes, size=labels_per_client, replace=False)
                     for _ in range(n_clients)]
    # power-law share per client
    raw = (np.arange(1, n_clients + 1, dtype=np.float64)) ** (-power)
    rng.shuffle(raw)
    shares = raw / raw.sum()

    by_class = {c: rng.permutation(np.where(labels == c)[0])
                for c in range(n_classes)}
    cursor = {c: 0 for c in range(n_classes)}
    out: List[np.ndarray] = []
    total = len(labels)
    for i in range(n_clients):
        want = max(int(shares[i] * total), labels_per_client)
        per_label = max(want // labels_per_client, 1)
        idx = []
        for c in client_labels[i]:
            pool = by_class[int(c)]
            take = pool[cursor[int(c)]: cursor[int(c)] + per_label]
            # wrap around if a class is exhausted (clients may share samples
            # at the tail — matches the "power law" sim in ref [14])
            if len(take) < per_label:
                take = np.concatenate([take, pool[:per_label - len(take)]])
                cursor[int(c)] = per_label - len(take)
            else:
                cursor[int(c)] += per_label
            idx.append(take)
        out.append(np.concatenate(idx))
    return out


def dirichlet_partition(seed: int, labels: np.ndarray, n_clients: int,
                        alpha: float = 0.5, n_classes: int = 10
                        ) -> List[np.ndarray]:
    """Standard Dirichlet(alpha) label-skew partitioner (extra baseline)."""
    rng = np.random.default_rng(seed)
    labels = np.asarray(labels)
    out = [[] for _ in range(n_clients)]
    for c in range(n_classes):
        idx = rng.permutation(np.where(labels == c)[0])
        props = rng.dirichlet([alpha] * n_clients)
        cuts = (np.cumsum(props) * len(idx)).astype(int)[:-1]
        for i, part in enumerate(np.split(idx, cuts)):
            out[i].extend(part.tolist())
    return [np.asarray(sorted(x), dtype=np.int64) for x in out]


def partition_stats(parts: List[np.ndarray], labels: np.ndarray,
                    n_classes: int = 10):
    labels = np.asarray(labels)
    return [{
        "n": len(p),
        "classes": sorted(set(labels[p].tolist())),
    } for p in parts]
