"""Architecture + shape configuration for the ASFL framework.

Every assigned architecture is described by one :class:`ArchConfig`. The model
substrate (``repro.models.transformer``) consumes this config to assemble the
layer stack; ``repro.core.split`` consumes it to enumerate valid cut points.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

# Layer-type ids understood by models/transformer.py
ATTN = "attn"            # global attention + dense MLP
ATTN_LOCAL = "attn_local"  # sliding-window attention + dense MLP
ATTN_MOE = "attn_moe"    # global attention + MoE FFN
MLA_DENSE = "mla_dense"  # multi-head latent attention + dense MLP
MLA_MOE = "mla_moe"      # multi-head latent attention + MoE FFN
SSM = "ssm"              # Mamba2 SSD block (no separate FFN)
RGLRU = "rglru"          # RG-LRU recurrent block + dense MLP

VOCAB_PAD = 2048  # Megatron-style: pad embedding tables to a multiple of this


def pad_vocab(v: int) -> int:
    return ((v + VOCAB_PAD - 1) // VOCAB_PAD) * VOCAB_PAD


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int            # routed experts
    top_k: int
    n_shared: int = 0         # shared (always-on) experts
    d_ff_expert: int = 0      # expert hidden dim (0 -> use arch d_ff)
    capacity_factor: float = 1.25
    aux_loss_weight: float = 0.01


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    d_conv: int = 4
    n_groups: int = 1
    chunk: int = 256
    # perf knob (§Perf): split the fused in_proj into per-stream projections
    # (z / xBC / dt) so each output shards cleanly on the model axis instead
    # of crossing shard boundaries at the split offsets.
    fused_proj: bool = True


@dataclasses.dataclass(frozen=True)
class RGLRUConfig:
    d_rnn: int = 0          # 0 -> d_model
    d_conv: int = 4
    c_exponent: float = 8.0


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str               # dense | moe | ssm | hybrid | vlm | audio
    source: str               # citation (paper / model card)
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0         # 0 -> d_model // n_heads
    # Layer pattern: tuple of layer-type ids forming one repeating period.
    # The stack = pattern * n_periods + tail.  n_layers must equal
    # len(pattern) * n_periods + len(tail).
    pattern: Tuple[str, ...] = (ATTN,)
    tail: Tuple[str, ...] = ()
    # Attention details
    qk_norm: bool = False
    window: int = 0           # sliding window size for ATTN_LOCAL layers
    rope_theta: float = 10000.0
    pos: str = "rope"         # rope | sinusoidal
    mlp_variant: str = "swiglu"  # swiglu | geglu | gelu
    logit_softcap: float = 0.0
    # Sub-configs
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    rglru: Optional[RGLRUConfig] = None
    # Modality frontend stub ("none" | "vision" | "audio")
    frontend: str = "none"
    n_patches: int = 256      # vision: patch embeddings prepended to text
    n_codebooks: int = 4      # audio: EnCodec codebooks summed at the input
    # SFL defaults
    default_cut: int = 2      # default cut layer (in *period* units; see split.py)
    # Long-context eligibility: sub-quadratic (SSM/hybrid/sliding-window) only
    subquadratic: bool = False
    # dtypes
    param_dtype: str = "float32"

    # ---- derived ----
    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        return pad_vocab(self.vocab_size)

    @property
    def layer_types(self) -> Tuple[str, ...]:
        n_periods = self.n_periods
        return tuple(self.pattern) * n_periods + tuple(self.tail)

    @property
    def n_periods(self) -> int:
        body = self.n_layers - len(self.tail)
        assert body % len(self.pattern) == 0, (
            f"{self.name}: {self.n_layers} layers, pattern {self.pattern}, "
            f"tail {self.tail} do not tile")
        return body // len(self.pattern)

    def param_count(self) -> int:
        """Analytic parameter count (for 6ND model-FLOPs in the roofline)."""
        from repro.models.transformer import count_params  # lazy, avoids cycle
        return count_params(self)

    def active_param_count(self) -> int:
        from repro.models.transformer import count_params
        return count_params(self, active_only=True)

    def reduced(self) -> "ArchConfig":
        """Smoke-test variant: <=2 periods, d_model<=256, <=4 experts."""
        d = min(self.d_model, 256)
        n_heads = max(1, min(self.n_heads, 4))
        n_kv = max(1, min(self.n_kv_heads, n_heads))
        while n_heads % n_kv:
            n_kv -= 1
        hd = 32
        moe = None
        if self.moe is not None:
            moe = dataclasses.replace(
                self.moe, n_experts=min(self.moe.n_experts, 4),
                top_k=min(self.moe.top_k, 2),
                n_shared=min(self.moe.n_shared, 1),
                d_ff_expert=min(self.moe.d_ff_expert or 128, 128))
        mla = None
        if self.mla is not None:
            mla = MLAConfig(kv_lora_rank=64, qk_nope_dim=32, qk_rope_dim=16,
                            v_head_dim=32)
        ssm = None
        if self.ssm is not None:
            ssm = dataclasses.replace(self.ssm, d_state=16, head_dim=16,
                                      chunk=32)
        rglru = None
        if self.rglru is not None:
            rglru = dataclasses.replace(self.rglru, d_rnn=0)
        n_tail = len(self.tail)
        # keep 1-2 periods so every layer type in the pattern is exercised
        n_layers = len(self.pattern) + n_tail
        return dataclasses.replace(
            self, name=self.name + "-smoke", n_layers=n_layers,
            d_model=d, n_heads=n_heads, n_kv_heads=n_kv, head_dim=hd,
            d_ff=min(self.d_ff, 512) or 0, vocab_size=min(self.vocab_size, 512),
            window=min(self.window, 16) if self.window else 0,
            moe=moe, mla=mla, ssm=ssm, rglru=rglru,
            n_patches=min(self.n_patches, 8), default_cut=1)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


INPUT_SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


# --------------------------------------------------------------------------
# runtime / XLA configuration
# --------------------------------------------------------------------------

def enable_compilation_cache(cache_dir: str,
                             min_compile_time_secs: float = 0.0) -> str:
    """Point JAX's persistent compilation cache at ``cache_dir`` so repeat
    runs of the same programs (fedsim round programs, fused super-steps,
    kernels) deserialize compiled binaries instead of re-invoking XLA.

    Wired through ``SimConfig.compilation_cache_dir``, the benchmarks'
    ``--compilation-cache`` flag, and the examples.  ``min_compile_time_secs
    = 0`` caches everything — the federation engines compile few, large
    programs, exactly the shape the cache is built for.  Returns the
    directory (created if missing) so callers can log it.

    JAX's cache configuration is **process-global**: this latches the cache
    on for every subsequent compile in the process, and calling it again
    with a different directory repoints everything (last call wins)."""
    import os

    import jax

    os.makedirs(cache_dir, exist_ok=True)
    jax.config.update("jax_compilation_cache_dir", str(cache_dir))
    jax.config.update("jax_persistent_cache_min_compile_time_secs",
                      float(min_compile_time_secs))
    try:  # cache small entries too (knob absent on some jax versions)
        jax.config.update("jax_persistent_cache_min_entry_size_bytes", -1)
    except Exception:
        pass
    try:
        # the cache singleton latches its directory on first use: reset so
        # a dir configured mid-process (engine __init__, bench flags) takes
        # effect for everything compiled afterwards
        from jax.experimental.compilation_cache import compilation_cache
        compilation_cache.reset_cache()
    except Exception:
        pass
    return str(cache_dir)


def cache_dir_is_warm(cache_dir) -> bool:
    """True if ``cache_dir`` already holds persistent-cache entries.  Call
    BEFORE running anything that compiles — the run itself populates the
    directory, so probing afterwards always reads warm (the benchmarks'
    ``compile_cache_hit`` key uses this at startup)."""
    import os

    return bool(cache_dir and os.path.isdir(cache_dir)
                and os.listdir(cache_dir))
