"""Cut-layer splitting: split/join inverse + split forward == full forward,
for every assigned architecture (reduced configs, all valid cuts)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.core import split as SP
from repro.models import transformer as T


def _batch(cfg, key, b=2, s=32):
    if cfg.frontend == "vision":
        return {"tokens": jax.random.randint(key, (b, s - cfg.n_patches), 0,
                                             cfg.vocab_size),
                "patch_embeds": jax.random.normal(
                    key, (b, cfg.n_patches, cfg.d_model))}
    if cfg.frontend == "audio":
        return {"codes": jax.random.randint(key, (b, cfg.n_codebooks, s), 0,
                                            cfg.vocab_size)}
    return {"tokens": jax.random.randint(key, (b, s), 0, cfg.vocab_size)}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_split_join_inverse(arch):
    cfg = get_config(arch).reduced()
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    for cut in SP.valid_cuts(cfg):
        client, server = SP.split_params(params, cfg, cut)
        joined = SP.join_params(client, server, cfg)
        jax.tree.map(lambda a, b: np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b)), params, joined)


@pytest.mark.parametrize("arch", ["smollm-360m", "gemma3-4b", "mamba2-780m",
                                  "recurrentgemma-2b", "deepseek-v2-lite-16b",
                                  "dbrx-132b", "internvl2-1b", "musicgen-large"])
def test_split_forward_equals_full_forward(arch):
    cfg = get_config(arch).reduced()
    key = jax.random.PRNGKey(1)
    params = T.init_params(key, cfg)
    batch = _batch(cfg, key)
    full_logits, _, _ = T.forward(params, cfg, batch, "train")
    for cut in SP.valid_cuts(cfg):
        client, server = SP.split_params(params, cfg, cut)
        smashed, positions, _, _ = SP.client_forward(client, cfg, batch, cut,
                                                     "train")
        logits, _, _ = SP.server_forward(server, cfg, smashed, positions, cut,
                                         "train")
        np.testing.assert_allclose(np.asarray(logits),
                                   np.asarray(full_logits),
                                   rtol=2e-4, atol=2e-4)


def test_valid_cuts_and_clamp():
    cfg = get_config("gemma3-4b").reduced()
    cuts = SP.valid_cuts(cfg)
    total = T.total_periods(cfg)
    assert cuts == list(range(1, total))
    assert SP.clamp_cut(cfg, 0) == 1
    assert SP.clamp_cut(cfg, 999) == total - 1
