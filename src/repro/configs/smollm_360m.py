"""smollm-360m — llama-arch small [hf:HuggingFaceTB/SmolLM-360M].

[dense] 32L d_model=960 15H (GQA kv=5) d_ff=2560 vocab=49152.
Small enough for CPU-runnable end-to-end SFL examples.
Pure full attention -> long_500k skipped.
"""
from repro.configs.base import ATTN, ArchConfig

CONFIG = ArchConfig(
    name="smollm-360m",
    family="dense",
    source="hf:HuggingFaceTB/SmolLM-135M",
    n_layers=32,
    d_model=960,
    n_heads=15,
    n_kv_heads=5,
    head_dim=64,
    d_ff=2560,
    vocab_size=49152,
    pattern=(ATTN,),
    mlp_variant="swiglu",
    default_cut=4,
    subquadratic=False,
)
