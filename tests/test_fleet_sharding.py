"""Device-sharded fleets (ISSUE 5 acceptance tests, DESIGN.md §10).

Two tiers:

* Always-on (any device count): FleetMesh construction/padding rules, spec
  validation of mesh combinations, and — the load-bearing ones — engines
  driven through the FULL ``shard_map`` path on an explicit ONE-device mesh
  asserted bit-identical to the default unsharded engines.  Every
  collective (all_gather, psum) degenerates to identity on one device, so
  these run in plain tier-1 and keep the sharded code from rotting.

* 8-device (skipped unless ``XLA_FLAGS=--xla_force_host_platform_
  device_count=8`` — the CI multi-device job sets it): K-fused sgd
  bit-for-bit across the mesh with a handover AND a cloud merge inside the
  fused window, adam within the engine-parity tolerance, cohort-engine
  parity, and padding inertness for fleets/RSU counts that do not divide
  the device count.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import fleet_sharding
from repro.core.fedsim import FederationSim, ScenarioEngine, SimConfig
from repro.core.fleet_sharding import FleetMesh, build_fleet_mesh

from test_scenario import TinyMLP, _two_cell_trace, _vector_clients

DEV = jax.device_count()
ROUNDS, INTERVAL = 4, 5.0

need8 = pytest.mark.skipif(
    DEV < 8, reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8")


def _cfg(**kw):
    base = dict(scheme="asfl", adaptive_strategy="paper", rounds=ROUNDS,
                local_steps=2, batch_size=8, lr=1e-2, optimizer="sgd",
                round_interval_s=INTERVAL, eval_every=0, superstep=ROUNDS)
    base.update(kw)
    return SimConfig(**base)


def _params(eng):
    return jax.tree.map(np.asarray, {"units": eng.units, "head": eng.head})


def _assert_histories_equal(h1, h2, exact=True):
    assert [m.cuts for m in h1] == [m.cuts for m in h2]
    if hasattr(h1[0], "rsu_loads"):
        assert [m.rsu_loads for m in h1] == [m.rsu_loads for m in h2]
        assert [m.n_handover for m in h1] == [m.n_handover for m in h2]
    l1, l2 = [m.loss for m in h1], [m.loss for m in h2]
    if exact:
        np.testing.assert_array_equal(l1, l2)
    else:
        np.testing.assert_allclose(l1, l2, rtol=1e-5, atol=1e-5)


def _scenario_engines(n_devices, **cfg_kw):
    """(reference engine, mesh engine) over the canonical two-cell handover
    trace with a cloud merge strictly inside the fused window."""
    sc = _two_cell_trace(ROUNDS, INTERVAL)
    clients, test = _vector_clients(2)
    cfg = _cfg(**cfg_kw)
    ref = ScenarioEngine(TinyMLP(), clients, test, cfg, sc,
                         cloud_sync_every=2)
    mesh = build_fleet_mesh(n_devices, "rsu")
    eng = ScenarioEngine(TinyMLP(), clients, test, cfg, sc,
                         cloud_sync_every=2, mesh=mesh)
    return ref, eng


# ----------------------------------------------------------- mesh plumbing
def test_fleet_mesh_padding_rules():
    mesh = build_fleet_mesh(1, "vehicle")
    assert mesh.n_devices == 1
    assert [mesh.pad(n) for n in (0, 1, 3, 8)] == [1, 1, 3, 8]
    if DEV >= 2:
        m2 = build_fleet_mesh(2, "rsu")
        assert [m2.pad(n) for n in (1, 2, 3, 8)] == [2, 2, 4, 8]


def test_fleet_mesh_build_errors():
    with pytest.raises(ValueError, match="vehicle|rsu"):
        build_fleet_mesh(1, "bogus")
    with pytest.raises(RuntimeError,
                       match="xla_force_host_platform_device_count"):
        build_fleet_mesh(DEV + 1, "rsu")
    with pytest.raises(ValueError, match=">= 1"):
        build_fleet_mesh(0, "vehicle")


def test_from_config_default_is_unsharded():
    assert fleet_sharding.from_config(_cfg(), "scenario") is None
    assert fleet_sharding.from_config(_cfg(), "federation") is None


def test_engines_reject_wrong_axis_mesh():
    sc = _two_cell_trace(ROUNDS, INTERVAL)
    clients, test = _vector_clients(2)
    with pytest.raises(ValueError, match="RSU axis"):
        ScenarioEngine(TinyMLP(), clients, test, _cfg(), sc,
                       mesh=build_fleet_mesh(1, "vehicle"))
    with pytest.raises(ValueError, match="vehicle axis"):
        FederationSim(TinyMLP(), clients, test,
                      _cfg(superstep=1, cohort_parallel="vmap"),
                      mesh=build_fleet_mesh(1, "rsu"))


def test_spec_validates_mesh_combinations():
    from repro import api
    rt = lambda **kw: api.RuntimeConfig(mesh_devices=2, **kw)
    # single-RSU engine: rsu axis / sequential chains / serial schedules
    with pytest.raises(ValueError, match="vehicle axis"):
        api.ExperimentSpec(runtime=rt(fleet_axis="rsu"))
    with pytest.raises(ValueError, match="sequential chain"):
        api.ExperimentSpec(train=api.TrainConfig(scheme="sl"), runtime=rt())
    with pytest.raises(ValueError, match="cohort_parallel"):
        api.ExperimentSpec(runtime=rt(cohort_parallel="scan"))
    # multi-RSU engine: vehicle axis cannot partition it
    with pytest.raises(ValueError, match="RSU axis"):
        api.ExperimentSpec(
            fleet=api.FleetConfig(n_vehicles=8, scenario="highway_corridor"),
            runtime=rt(fleet_axis="vehicle"))
    # valid combos build
    api.ExperimentSpec(runtime=rt())
    api.ExperimentSpec(
        fleet=api.FleetConfig(n_vehicles=8, scenario="highway_corridor"),
        runtime=rt(fleet_axis="rsu"))
    # field-level validation still lives in SimConfig
    with pytest.raises(ValueError, match="fleet_axis"):
        SimConfig(fleet_axis="diagonal")
    with pytest.raises(ValueError, match="mesh_devices"):
        SimConfig(mesh_devices=0)


# ----------------------- one-device mesh == default engine, bit for bit
# (the full shard_map/all_gather/psum path with every collective degenerate
# — keeps the sharded code exercised by plain single-device tier-1 runs)

def test_one_device_mesh_superstep_bitforbit():
    ref, eng = _scenario_engines(1)
    assert eng.programs.mesh is not None
    h1, h2 = ref.run(), eng.run()
    assert sum(m.n_handover for m in h1) >= 1
    _assert_histories_equal(h1, h2)
    jax.tree.map(np.testing.assert_array_equal, _params(ref), _params(eng))


def test_one_device_mesh_ragged_parallel_bitforbit():
    """The ragged+parallel mesh path (replicated edge, psum'd segment-sum
    partials — DESIGN.md §12) on ONE device: every collective degenerates,
    so the compacted sharded program must equal the unsharded one bit for
    bit — keeps the slot-sharded code exercised in plain tier-1."""
    ref, eng = _scenario_engines(1, server_schedule="parallel",
                                 superstep_layout="ragged")
    assert eng.programs.mesh is not None
    h1, h2 = ref.run(), eng.run()
    _assert_histories_equal(h1, h2)
    jax.tree.map(np.testing.assert_array_equal, _params(ref), _params(eng))


def test_one_device_mesh_cohort_matches_default():
    """The sharded cohort path on one device: losses are bit-identical
    (every collective is an identity), params agree to ~1 ulp — inserting
    the (identity) psum into the FedAvg moves an XLA fusion boundary, so
    the merge divide rounds once differently; anything beyond that is a
    real bug."""
    clients, test = _vector_clients(5)      # odd fleet: padded slots in play
    cfg = _cfg(superstep=1, cohort_parallel="vmap", n_clients=5)
    ref = FederationSim(TinyMLP(), clients, test, cfg)
    eng = FederationSim(TinyMLP(), clients, test, cfg,
                        mesh=build_fleet_mesh(1, "vehicle"))
    assert eng.engine.fleet_mesh is not None
    h1, h2 = ref.run(), eng.run()
    _assert_histories_equal(h1, h2)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        a, b, rtol=1e-6, atol=1e-7), _params(ref), _params(eng))


# ------------------------------------------------ 8-device parity suite
@need8
@pytest.mark.parametrize("schedule", ["sequential", "parallel"])
def test_superstep_sharded_sgd_bitforbit(schedule):
    """K-fused sgd across an 8-device RSU mesh == the single-device engine
    bit for bit; the fused window contains vehicle 0's handover AND a cloud
    merge (cloud_sync_every=2 inside a K=4 window).  The 2-RSU trace pads
    to 8 phantom cells — padding inertness on the RSU axis included.

    The parallel schedule pins ``superstep_layout="dense"``: only the
    RSU-aligned slot-block sharding is bit-exact across the mesh; the
    ragged compacted axis psums segment-sum partials and is covered by the
    tolerance test below (DESIGN.md §12)."""
    layout = "dense" if schedule == "parallel" else "ragged"
    ref, eng = _scenario_engines(8, server_schedule=schedule,
                                 superstep_layout=layout)
    assert eng.programs.n_rsus_padded == 8
    h1, h2 = ref.run(), eng.run()
    assert sum(m.n_handover for m in h1) >= 1
    _assert_histories_equal(h1, h2)
    jax.tree.map(np.testing.assert_array_equal, _params(ref), _params(eng))


@need8
def test_superstep_sharded_adam_within_parity_tolerance():
    ref, eng = _scenario_engines(8, optimizer="adam")
    h1, h2 = ref.run(), eng.run()
    _assert_histories_equal(h1, h2, exact=False)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        a, b, atol=1e-5, rtol=1e-5), _params(ref), _params(eng))


@need8
def test_superstep_sharded_ragged_parallel_tolerance():
    """Occupancy-balanced slot sharding (DESIGN.md §12): the compacted
    slot axis splits into equal contiguous blocks per device and the
    per-RSU segment sums become psum'd partials — the psum reassociates
    float additions, so parity with the single-device compacted program is
    tolerance-level, not bit-exact (sgd)."""
    ref, eng = _scenario_engines(8, server_schedule="parallel",
                                 superstep_layout="ragged")
    assert eng.programs.layout == "ragged"
    h1, h2 = ref.run(), eng.run()
    _assert_histories_equal(h1, h2, exact=False)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        a, b, atol=1e-5, rtol=1e-5), _params(ref), _params(eng))


@need8
def test_superstep_sharded_precompile_covers():
    """AOT precompile covers the sharded signatures: a full run builds
    nothing mid-flight (fallback counter stays zero) and the donated
    sharded carry survives windowing."""
    sc = _two_cell_trace(ROUNDS, INTERVAL)
    clients, test = _vector_clients(2)
    eng = ScenarioEngine(TinyMLP(), clients, test, _cfg(superstep=3), sc,
                         cloud_sync_every=2, mesh=build_fleet_mesh(8, "rsu"))
    sigs = eng.precompile()
    assert len(sigs) == 2                      # K=3 and the K=1 tail
    hist = eng.run()
    assert eng.programs.compile_fallbacks == 0
    assert len(hist) == ROUNDS


@need8
@pytest.mark.parametrize("optimizer", ["sgd", "adam"])
def test_cohort_sharded_parity_nondivisible_fleet(optimizer):
    """Vehicle-axis sharding of the cohort engine: a 6-vehicle fleet pads
    its cut buckets to device multiples (padding inertness for
    non-divisible fleets) and matches the single-device vmap engine within
    the engine-parity fp tolerance (the FedAvg psum reassociates float
    additions, so sgd is near- but not bit-exact — DESIGN.md §10)."""
    clients, test = _vector_clients(6)
    cfg = _cfg(superstep=1, cohort_parallel="vmap", n_clients=6,
               optimizer=optimizer)
    ref = FederationSim(TinyMLP(), clients, test, cfg)
    eng = FederationSim(TinyMLP(), clients, test,
                        dataclasses.replace(cfg, mesh_devices=8))
    assert eng.engine.slot_pad(6) == 8
    h1, h2 = ref.run(), eng.run()
    _assert_histories_equal(h1, h2, exact=False)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        a, b, atol=1e-5, rtol=1e-5), _params(ref), _params(eng))


@need8
def test_fl_sharded_parity():
    clients, test = _vector_clients(6)
    cfg = _cfg(scheme="fl", superstep=1, cohort_parallel="vmap", n_clients=6)
    ref = FederationSim(TinyMLP(), clients, test, cfg)
    eng = FederationSim(TinyMLP(), clients, test,
                        dataclasses.replace(cfg, mesh_devices=8))
    h1, h2 = ref.run(), eng.run()
    np.testing.assert_allclose([m.loss for m in h1], [m.loss for m in h2],
                               rtol=1e-5, atol=1e-5)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        a, b, atol=1e-5, rtol=1e-5), _params(ref), _params(eng))


@need8
def test_api_run_on_mesh_gathers_final_params():
    """The front door builds the mesh from RuntimeConfig and returns
    host-numpy final params regardless of where training ran."""
    from repro import api
    spec = api.ExperimentSpec(
        model="mlp9",
        train=api.TrainConfig(scheme="asfl", rounds=2, local_steps=1,
                              batch_size=8, lr=1e-3, eval_every=0,
                              optimizer="sgd"),
        fleet=api.FleetConfig(n_vehicles=8, scenario="trace_replay",
                              per_vehicle_samples=16),
        runtime=api.RuntimeConfig(superstep=2, mesh_devices=8))
    res = api.run(spec)
    assert res.diagnostics["mesh_devices"] == 8
    assert res.diagnostics["fleet_axis"] == "rsu"
    units, head = res.final_params
    assert all(isinstance(leaf, np.ndarray)
               for leaf in jax.tree.leaves((units, head)))
    ref = api.run(dataclasses.replace(
        spec, runtime=dataclasses.replace(spec.runtime, mesh_devices=1)))
    # the trained model is bit-identical; the scalar loss METRIC may move
    # one ulp (XLA fuses the per-round loss sum differently at different
    # vmap widths — a reporting reduction, not training state)
    np.testing.assert_allclose([m.loss for m in ref.history],
                               [m.loss for m in res.history],
                               rtol=1e-6, atol=0)
    jax.tree.map(np.testing.assert_array_equal,
                 res.final_params, ref.final_params)


# --------------------------------------- 2-D grid mesh, paging, auto sizing
# (ISSUE 10, DESIGN.md §15)

def test_grid_shape_and_shape_spec():
    assert [fleet_sharding.grid_shape(n) for n in (1, 2, 4, 8, 16)] == \
        [(1, 1), (2, 1), (2, 2), (4, 2), (4, 4)]
    assert fleet_sharding.parse_shape_spec("auto") is None
    assert fleet_sharding.parse_shape_spec("4x2") == (4, 2)
    with pytest.raises(ValueError, match="mesh_shape"):
        fleet_sharding.parse_shape_spec("4by2")
    with pytest.raises(ValueError, match=">= 1"):
        fleet_sharding.parse_shape_spec("0x2")
    # device-count consistency is a BUILD-time check, not config syntax
    with pytest.raises(ValueError, match="mesh_devices"):
        fleet_sharding.parse_mesh_shape("4x2", 4, "grid")
    with pytest.raises(ValueError, match="mesh_shape"):
        SimConfig(mesh_shape="x")
    SimConfig(mesh_shape="64x2")    # syntax-valid on any device count


def test_balanced_and_padded_slot_rules():
    m1 = build_fleet_mesh(1, "grid")
    assert (m1.rsu_devices, m1.veh_devices) == (1, 1)
    assert [m1.balanced_slots(s) for s in (0, 1, 5)] == [1, 1, 5]
    assert m1.pad_slots(3) == 3
    if DEV >= 8:
        m = build_fleet_mesh(8, "grid")
        assert (m.rsu_devices, m.veh_devices) == (4, 2)
        assert m.pad(3) == 4            # RSU rows pad to the rsu sub-axis
        assert m.pad_slots(3) == 4      # dense capacity pads to the veh axis
        for s in (1, 3, 7, 8, 9, 64):   # compacted axis: whole device grid
            b = m.balanced_slots(s)
            assert b % m.n_devices == 0 and b >= s and b - s < m.n_devices
        # explicit shapes must factor the device count; 1-D axes stay 1-D
        with pytest.raises(ValueError, match="requires"):
            build_fleet_mesh(8, "rsu", shape=(4, 2))
        m42 = build_fleet_mesh(8, "grid", shape=(2, 4))
        assert (m42.rsu_devices, m42.veh_devices) == (2, 4)


def test_mesh_devices_auto_resolution():
    n, info = fleet_sharding.resolve_mesh_devices("auto", fleet_size=32,
                                                  available=8)
    assert n == 1 and info["chosen"] == 1
    n, _ = fleet_sharding.resolve_mesh_devices("auto", fleet_size=4096,
                                               available=8)
    assert n == 8
    # 200 vehicles: 2 devices keep >= 64 slots each, 4 would not
    n, info = fleet_sharding.resolve_mesh_devices("auto", fleet_size=200,
                                                  available=8)
    assert n == 2 and info["floor"] == fleet_sharding.AUTO_SLOTS_PER_DEVICE
    n, info = fleet_sharding.resolve_mesh_devices(4, fleet_size=None)
    assert n == 4 and info is None
    SimConfig(mesh_devices="auto")      # config accepts the sentinel
    with pytest.raises(ValueError, match="mesh_devices"):
        SimConfig(mesh_devices="many")


def test_api_auto_mesh_decision_in_diagnostics():
    """mesh_devices="auto" on a tiny fleet chooses one device (below the
    slots-per-device floor) and records the decision."""
    from repro import api
    spec = api.ExperimentSpec(
        model="mlp9",
        train=api.TrainConfig(scheme="asfl", rounds=1, local_steps=1,
                              batch_size=4, lr=1e-3, eval_every=0),
        fleet=api.FleetConfig(n_vehicles=4, scenario="trace_replay",
                              per_vehicle_samples=8, test_samples=8),
        runtime=api.RuntimeConfig(mesh_devices="auto", precompile=False))
    res = api.run(spec)
    assert res.diagnostics["mesh_devices"] == 1
    auto = res.diagnostics["mesh_auto"]
    assert auto["requested"] == "auto" and auto["chosen"] == 1
    assert auto["floor"] == fleet_sharding.AUTO_SLOTS_PER_DEVICE


def _city_engines(page, mesh=None, n=24):
    """(unpaged reference, paged engine) on a small city lattice — enough
    occupied slots that ``page_slots`` genuinely splits the per-device
    block into multiple windows."""
    from repro.core import scenario as S
    sc = S.make_scenario("city", n, seed=1, grid_x=2, grid_y=2)
    clients, test = _vector_clients(n)
    base = _cfg(server_schedule="parallel", superstep_layout="ragged",
                n_clients=n)
    ref = ScenarioEngine(TinyMLP(), clients, test, base, sc,
                         cloud_sync_every=2, mesh=mesh)
    eng = ScenarioEngine(TinyMLP(), clients, test,
                         dataclasses.replace(base, page_slots=page), sc,
                         cloud_sync_every=2, mesh=mesh)
    sigs = eng.precompile()
    # the paged program must actually page: > 1 window per device block
    nd = mesh.n_devices if mesh is not None else 1
    assert all(s.slots // nd > page for s in sigs), (page, sigs)
    return ref, eng


def test_paged_ragged_parallel_bitexact():
    """Slot paging (page_slots) bounds the CONCURRENT slot window of the
    ragged compacted axis: the paged lax.scan walks fixed windows over the
    same slots in the same order, so it is bit-identical to the unpaged
    vmap — paging changes peak footprint, never math — and the paged
    signature precompiles (page position is loop state, not a signature)."""
    ref, eng = _city_engines(page=4)
    h1, h2 = ref.run(), eng.run()
    assert eng.programs.compile_fallbacks == 0
    _assert_histories_equal(h1, h2)
    jax.tree.map(np.testing.assert_array_equal, _params(ref), _params(eng))


def test_page_slots_validation():
    with pytest.raises(ValueError, match="page_slots"):
        SimConfig(page_slots=-1)
    from repro import api
    with pytest.raises(ValueError, match="page_slots"):
        api.ExperimentSpec(
            fleet=api.FleetConfig(n_vehicles=8, scenario="highway_corridor"),
            runtime=api.RuntimeConfig(page_slots=4,
                                      superstep_layout="dense"))
    with pytest.raises(ValueError, match="page_slots"):
        api.ExperimentSpec(runtime=api.RuntimeConfig(page_slots=4))


def test_process_topology_validation():
    from repro import api
    with pytest.raises(ValueError, match="process_id"):
        api.ExperimentSpec(runtime=api.RuntimeConfig(num_processes=2,
                                                     process_id=2,
                                                     coordinator_address="localhost:1"))
    with pytest.raises(ValueError, match="coordinator_address"):
        api.ExperimentSpec(runtime=api.RuntimeConfig(num_processes=2))
    api.ExperimentSpec(runtime=api.RuntimeConfig(
        num_processes=2, process_id=1, coordinator_address="localhost:1"))


@need8
@pytest.mark.parametrize("schedule,layout,exact", [
    ("sequential", "ragged", True),
    ("parallel", "dense", True),
    ("parallel", "ragged", False),
])
def test_grid_mesh_superstep_parity(schedule, layout, exact):
    """The 2-D (rsu, vehicle) mesh shards RSU rows AND slot columns at
    once (4x2 over 8 devices).  Sequential chains replicate the vehicle
    sub-axis (bit-exact); the dense parallel schedule splits each RSU's
    slot columns and regroups gathers into single-device order (bit-exact
    — this is also the 2-D padding-inertness case: the 2-RSU trace pads to
    4 phantom RSU rows x phantom slot columns, all folding out as exact
    +0s); the ragged compacted axis psums segment partials (tolerance)."""
    sc = _two_cell_trace(ROUNDS, INTERVAL)
    clients, test = _vector_clients(2)
    cfg = _cfg(server_schedule=schedule, superstep_layout=layout)
    ref = ScenarioEngine(TinyMLP(), clients, test, cfg, sc,
                         cloud_sync_every=2)
    mesh = build_fleet_mesh(8, "grid")
    assert (mesh.rsu_devices, mesh.veh_devices) == (4, 2)
    eng = ScenarioEngine(TinyMLP(), clients, test, cfg, sc,
                         cloud_sync_every=2, mesh=mesh)
    assert eng.programs.n_rsus_padded == 4      # phantom RSU rows in play
    h1, h2 = ref.run(), eng.run()
    assert sum(m.n_handover for m in h1) >= 1
    _assert_histories_equal(h1, h2, exact=exact)
    if exact:
        jax.tree.map(np.testing.assert_array_equal,
                     _params(ref), _params(eng))
    else:
        jax.tree.map(lambda a, b: np.testing.assert_allclose(
            a, b, atol=1e-5, rtol=1e-5), _params(ref), _params(eng))


@need8
def test_paged_grid_mesh_matches_unpaged():
    """Paging composes with the 2-D mesh: each device pages its own
    compacted block through fixed windows; parity with the same-mesh
    unpaged program is exact (same slots, same order, same psums)."""
    ref, eng = _city_engines(page=2, mesh=build_fleet_mesh(8, "grid"),
                             n=64)
    h1, h2 = ref.run(), eng.run()
    assert eng.programs.compile_fallbacks == 0
    _assert_histories_equal(h1, h2)
    jax.tree.map(np.testing.assert_array_equal, _params(ref), _params(eng))
