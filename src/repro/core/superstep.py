"""Fused multi-RSU super-steps (DESIGN.md §8).

PR 2's :class:`~repro.core.fedsim.ScenarioEngine` ran one compiled
CohortEngine cohort **per RSU per round** from a Python loop: an
``np.unique(serving[sched])`` host sync, per-RSU boolean indexing and numpy
staging, one jit dispatch plus a blocking ``float(loss)`` pull per RSU, and
a host-side Python FedAvg at every cloud sync.  At 256 vehicles that Python
orbit bounded round throughput, and warmup compiled one program per (bucket
signature, RSU cohort structure) pair: ~53-58 s before the first round
(BENCH_scenarios.json).

This module restructures the hot path around four ideas:

* **All RSUs execute inside one jitted program.**  Per-RSU cohorts are
  stacked on a leading RSU axis and ``vmap``-ed; membership grouping is one
  on-device segment sort of (serving, cut, vehicle) keys — replacing
  ``np.unique`` + per-RSU boolean indexing while preserving the engine's
  canonical server-update order (ascending cut, then vehicle index, per
  RSU).  The pow2 per-RSU slot capacity plays the role of PR 1's pow2
  bucket signatures: membership churn from mobility/handover only
  reshuffles gather indices, never the compiled program.

* **The cut layer is data, on a flat parameter plane.**  The whole
  ``{units, head}`` pytree is ravelled once into a single (P,) vector with
  a static ``unit_ids`` position→unit map
  (``jax.flatten_util.ravel_pytree``).  A vehicle at cut c owns the
  positions with ``unit_ids < c``; the RSU owns the rest.  Heterogeneous
  cuts, gradient routing, masked optimizer updates, and the unit-wise
  FedAvg become a few fused vector ops, so dynamic cut churn (residence-
  aware SKIP, rate banding) never retraces anything.

* **Two server schedules, one engine.**  ``sequential`` keeps the source
  paper's §III-B semantics — the RSU updates its shared server-side model
  on every client batch, in cohort order — as a ``lax.scan`` over slots
  (client-replica optimizer updates are deferred out of that scan and
  applied vmapped per local step, which is the identical math since each
  replica is touched once per step).  ``parallel`` implements the
  companion paper's parallel server-side execution (arXiv:2405.18707,
  "Adaptive and Parallel Split Federated Learning in Vehicular Edge
  Computing"): the RSU consumes the whole cohort's smashed batches at once
  and takes one |D_n|-weighted mean-gradient step per local step.  The
  parallel schedule has no sequential inner loop at all — every matmul in
  the round batches across the (RSU, slot) axes, which is what lets fleet-
  scale rounds run at the hardware's batched-matmul throughput instead of
  the tiny-matmul scan throughput (~10x apart on CPU; see DESIGN.md §8).

* **K rounds fuse into one super-step** via ``lax.scan`` over rounds:
  mobility (scenario traced-step path), rate sampling, cut selection,
  batch staging, training, handover tracking, edge aggregation, and the
  periodic cloud merge all live in the scanned round body, with the carry
  (edge-model stack, edge sample counters, previous serving cells, global
  model) donated between super-steps.  The per-round dispatch path is the
  K=1 special case of the same program, which is why K-fused and
  K-sequential execution agree bit-for-bit (tests/test_superstep.py).

Warmup collapses with it: :meth:`SuperStepPrograms.precompile` AOT-lowers
(``.lower().compile()``) every signature a run plan will request, and the
engine wires JAX's persistent compilation cache so warm starts skip XLA
entirely.

What stays in Python, by design: logging, round-metrics assembly, and the
analytic comm/latency/energy accounting — all consume the per-round arrays
the super-step emits as scan outputs, pulled to the host **once per
super-step** instead of several times per round.

**Ragged layout** (DESIGN.md §12, the default): the dense formulation
above pays as if every vehicle held the whole model — full (P,) replicas
plus moments per slot, all client math masked by ``keep``, and pow2/tight8
capacity padding burning full-plane FLOPs on phantom slots.  Because the
plane serializes the head first and then units in ascending order, every
position a vehicle can own at any cut ``c <= c_max`` lives in ONE static
contiguous window of the plane (:func:`owned_window`), where ``c_max`` is
the strategy's static cut bound (:func:`repro.core.adaptive.
strategy_max_cut`) pow2-bucketed into the program signature
(:func:`cut_prefix_bucket` — cut churn stays retrace-free).  With
``superstep_layout="ragged"`` client replicas, client moments, and EF wire
residuals shrink to that prefix window; the sequential schedule truncates
its per-unit replica lists to the bucket; and the parallel schedule
replaces the per-RSU (R, C) padded slot table with a globally compacted
(segment-id, slot) layout from the same on-device sort — client fwd/bwd
vmaps over *occupied* slots only and per-RSU aggregation becomes
segment-sums (scatter-adds into an R+1-row table whose overflow row drops
phantom work).  Segment scatter-adds are left-folds, so a padded slot
contributes an exact ±0 in any position: compacted and dense execution
stay bit-for-bit for sgd on both schedules (tests/test_ragged.py).
``superstep_layout="dense"`` keeps the full-plane masked path.

Caveats: the flat plane requires a uniform parameter dtype (the current
UnitModels are float32 throughout), and a replica is materialized per slot
— the price of making the cut a runtime value.  Memory is
``O(n_rsus * capacity * P)`` for the dense layout, and
``O(occupied_slots * P_prefix)`` for the ragged one.

Wire schemes (DESIGN.md §11): ``cfg.wire`` inserts a compression boundary
at the runtime cut inside the fused forward — ``"int8"`` is the stateless
fake-quant round trip, ``"topk_int8"`` adds per-vehicle error-feedback
residuals carried as two extra slot-table planes (``wire_res``,
``wire_cut``) in the donated scan carry.  Residuals follow the vehicle
(the planes are fleet-indexed and replicated under a mesh), so they
migrate on handover exactly like the data shards; a residual is zeroed
only when the vehicle's cut changes, because the buffer's layout is the
smashed-tensor shape at that cut.  Because the cut is a runtime value,
every unit boundary computes its compressed candidate and a ``where``
selects the one at the cut — under the RSU/slot vmaps a ``lax.cond``
would execute both branches anyway, so the select form is the honest
spelling of that cost (see DESIGN.md §11 for the CPU-interpret numbers).
``wire="none"`` stays byte-identical to the pre-wire engine: every hook
below is gated at Python level, so the traced program is unchanged.

Fault plane (DESIGN.md §13): ``cfg.fault_*`` turns on seeded, fully traced
failure processes from :mod:`repro.core.faults` — mid-round dropout, upload
loss, deadline stragglers (analytic latency at the chosen cut vs
``straggler_factor x residence``), and whole-RSU outages.  Consequences are
computed in-round: outages zero the cohort's cuts before slot grouping;
per-step activity masks stop a dropout's batches after its drop step
(server-side gradients it contributed before dropping stand — they already
landed on the RSU); the unit-wise FedAvg renormalizes over *survivors*
(``aggregation.survivor_weighted_sum`` — failed slots fold in as exact +0);
and straggler client updates land in a staleness bank on the donated carry
(``stale_num``/``stale_den``) that merges next round at a
``staleness_discount``.  Every hook is gated at Python level on
``FaultConfig.stochastic`` (the ``wire="none"`` precedent), so the
zero-fault program is byte-identical and trains bit-for-bit vs a build
without the fault plane — on both schedules, both layouts, and under a
mesh (tests/test_faults.py).

Streaming plane (DESIGN.md §14): ``cfg.stream_*`` adds a seeded presence
process (a per-vehicle Markov toggle chain on the donated carry — see
:mod:`repro.core.streaming`) that gates cut selection on any schedule, and
a third server schedule ``"streaming"`` that rides the parallel machinery
but commits its round update through a ``StreamBuffer`` carry plane: each
RSU's survivor-aggregated cohort delta is pushed into a capacity-B slot
ring (``sbuf``/``sbuf_w``/``sbuf_age``/``sbuf_cnt``), and the edge model
advances only when the buffer reaches B pending deltas, via a
staleness-weighted survivor FedAvg (``streaming.staleness_kernel`` over
slot ages — the FedBuff policy).  Both planes are gated at Python level on
``StreamConfig.churning`` / the schedule flag, so the zero-streaming
program is byte-identical, and all state is carry — presence/buffer churn
is data, never a program signature (tests/test_streaming.py).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.experimental.shard_map import shard_map
from jax.flatten_util import ravel_pytree
from jax.sharding import PartitionSpec as PSpec

from repro.core import (adaptive, aggregation, compression, faults,
                        fleet_sharding, streaming)
from repro.core.fleet_sharding import (ALL_AXES, RSU_AXIS, VEH_AXIS,
                                       FleetMesh)
from repro.data.pipeline import StackedClients, fleet_batch_indices_traced
from repro import optim

SERVER_SCHEDULES = ("sequential", "parallel", "streaming")
SUPERSTEP_LAYOUTS = ("ragged", "dense")


def cut_prefix_bucket(c_max: int, n_units: int) -> int:
    """pow2-bucket the strategy's static max cut into the program-signature
    dimension that sizes prefix planes: the smallest power of two >= c_max,
    clipped to U-1 (no vehicle can own the last unit).  Bucketing keeps the
    signature — and therefore the compile cache — stable when a strategy's
    candidate set changes without crossing a power of two."""
    c = max(int(c_max), 1)
    b = 1
    while b < c:
        b *= 2
    return min(b, max(int(n_units) - 1, 1))


def owned_window(unit_ids: np.ndarray, bucket: int):
    """(offset, width) of the contiguous flat-plane window holding every
    position with ``unit_ids < bucket`` — all positions a vehicle can own
    at any cut <= bucket.  Contiguity is a property of the ravel order
    (``ravel_pytree`` sorts dict keys: "head" serializes before "units",
    units ascend), asserted here rather than assumed."""
    ids = np.asarray(unit_ids)
    owned = np.nonzero(ids < int(bucket))[0]
    if owned.size == 0:
        return 0, 0
    off, width = int(owned[0]), int(owned.size)
    if not np.array_equal(owned, np.arange(off, off + width)):
        raise AssertionError(
            "owned plane positions are not contiguous; the ragged layout "
            "requires the ravel order to keep units < bucket adjacent")
    return off, width


def tree_copy(tree):
    """Deep copy device buffers (public views of donated carries must not
    alias buffers a later super-step will consume)."""
    return jax.tree.map(lambda a: jnp.array(a, copy=True), tree)


def _select(mask, new, old):
    """tree_map(where): pick ``new`` where mask else ``old``; the mask
    broadcasts from the left (scalar masks select whole trees)."""
    mask = jnp.asarray(mask)

    def f(a, b):
        m = mask.reshape(mask.shape + (1,) * (a.ndim - mask.ndim))
        return jnp.where(m, a, b)

    return jax.tree.map(f, new, old)


def _sel_list_state(new: Dict, old: Dict, keep_units, act):
    """Per-unit select over an optimizer state whose array collections are
    *lists* mirroring a client replica's unit list (bookkeeping leaves —
    step counts — follow the per-replica ``act`` mask)."""
    out = {}
    for k, v in new.items():
        if isinstance(v, list):
            out[k] = [_select(keep_units[u], v[u], old[k][u])
                      for u in range(len(v))]
        else:
            out[k] = _select(act, v, old[k])
    return out


def _sel_server_state(new: Dict, old: Dict, keep_units, act):
    """Per-unit select over the server optimizer state (leaves mirror the
    ``{"units": [...], "head": ...}`` tree)."""
    out = {}
    for k, v in new.items():
        if isinstance(v, dict) and "units" in v:
            out[k] = {"units": [_select(keep_units[u], v["units"][u],
                                        old[k]["units"][u])
                                for u in range(len(v["units"]))],
                      "head": _select(act, v["head"], old[k]["head"])}
        else:
            out[k] = _select(act, v, old[k])
    return out


def _sel_flat_state(keep, act, new, old, params_shape):
    """Select a flat-plane optimizer state: leaves shaped like the (flat)
    parameters follow the per-position ``keep`` mask, bookkeeping leaves
    (step counts) follow ``act``."""
    def f(a, b):
        if a.shape == tuple(params_shape):
            return jnp.where(keep, a, b)
        return jnp.where(act, a, b)

    return jax.tree.map(f, new, old)


@dataclasses.dataclass(frozen=True)
class SuperStepSignature:
    """Static compile-cache key of one fused program."""
    k: int            # rounds fused into the scan
    capacity: int     # pow2 per-RSU slot capacity
    staged: bool      # True: mobility staged per-window on the host
    # compacted global slot capacity (ragged layout + parallel schedule:
    # bucketed max TOTAL covered count; 0 = dense per-RSU padded tables)
    slots: int = 0
    # pow2-bucketed static max cut sizing the prefix planes (0 = dense
    # layout, full plane)
    max_cut: int = 0


class SuperStepPrograms:
    """Builds, caches, and AOT-precompiles fused super-step programs for one
    (model, config, fleet, scenario) tuple.  ``ScenarioEngine`` owns one.

    ``compile_fallbacks`` counts programs that had to be built outside
    :meth:`precompile` — zero after a covering precompile means no silent
    mid-run recompiles (asserted in tests/test_superstep.py)."""

    def __init__(self, model, cfg, stacked: StackedClients,
                 lengths: np.ndarray, scenario, n_rsus: int,
                 cloud_sync_every: int, profile, nb: int, ep: int,
                 mesh: Optional[FleetMesh] = None):
        self.model = model
        self.cfg = cfg
        self.opt = optim.from_name(cfg.optimizer, cfg.lr)
        self.schedule = getattr(cfg, "server_schedule", "sequential")
        if self.schedule not in SERVER_SCHEDULES:
            raise ValueError(f"server_schedule must be one of "
                             f"{SERVER_SCHEDULES}, got {self.schedule!r}")
        # RSU-axis mesh (core/fleet_sharding.py, DESIGN.md §10): the RSU
        # axis is padded to a device multiple (phantom cells no vehicle is
        # served by — inert, they never accumulate samples) and sharded;
        # the master client tensors replicate (handover makes per-round
        # gathers cross-shard by design); everything fleet-wide (mobility,
        # cuts, the slot table, the global model) is computed replicated
        self.mesh = mesh
        self.n_rsus_padded = mesh.pad(n_rsus) if mesh is not None else n_rsus
        self.stacked = stacked if mesh is None else mesh.place_stacked(stacked)
        self.lengths = np.asarray(lengths, np.int64)
        self.scenario = scenario
        self.n_rsus = n_rsus
        self.n_vehicles = int(len(lengths))
        self.sync_every = cloud_sync_every
        self.profile = profile
        self.nb, self.ep = nb, ep
        self.steps = nb * ep
        self.fa = scenario.fleet_arrays
        self._programs: Dict[SuperStepSignature, Callable] = {}
        self.compile_fallbacks = 0
        self.traced_mobility = hasattr(scenario, "traced_fleet_state")
        # the flat parameter plane: one (P,) vector for {units, head}, plus
        # the static position->unit map that makes the cut a runtime value
        units, head = model.init(jax.random.PRNGKey(cfg.seed))
        template = {"units": list(units), "head": head}
        flat, self.unravel = ravel_pytree(template)
        if flat.dtype != jnp.float32:
            raise TypeError(
                f"superstep engine requires uniform float32 params, got "
                f"{flat.dtype} after ravel")
        self.n_params = int(flat.size)
        ids = {"units": [jax.tree.map(
            lambda a, _u=u: np.full(np.shape(a), _u, np.int32), ut)
            for u, ut in enumerate(units)],
            "head": jax.tree.map(
                lambda a: np.full(np.shape(a), model.n_units, np.int32),
                head)}
        self.unit_ids = ravel_pytree(ids)[0].astype(jnp.int32)
        self.unit_ids_np = np.asarray(self.unit_ids)
        # ragged layout (DESIGN.md §12): client planes/moments/EF residuals
        # are sized to the static max-cut prefix — the pow2 bucket of the
        # strategy's cut bound — which is one contiguous window of the
        # plane (head serializes first, then units ascending)
        self.layout = getattr(cfg, "superstep_layout", "ragged")
        if self.layout not in SUPERSTEP_LAYOUTS:
            raise ValueError(f"superstep_layout must be one of "
                             f"{SUPERSTEP_LAYOUTS}, got {self.layout!r}")
        if self.layout == "ragged":
            c_max = adaptive.strategy_max_cut(cfg.adaptive_strategy,
                                              model.n_units)
            self.max_cut_bucket = cut_prefix_bucket(c_max, model.n_units)
            self.plane_offset, self.plane_width = owned_window(
                self.unit_ids_np, self.max_cut_bucket)
            self.client_units = self.max_cut_bucket
        else:
            self.max_cut_bucket = 0
            self.plane_offset, self.plane_width = 0, self.n_params
            self.client_units = model.n_units
        # wire boundary geometry: the smashed-tensor shape at every cut
        # (1..U-1), from one eval_shape of the per-unit forward.  The EF
        # residual plane holds the LARGEST boundary flattened — one slot
        # per vehicle, reinterpreted in the shape of its current cut.
        # Ragged layout: cuts never exceed the bucket, so only boundaries
        # below it ever carry a residual — the plane shrinks accordingly
        self.wire = getattr(cfg, "wire", "none")
        self.wire_k = float(getattr(cfg, "wire_k", compression.WIRE_K))
        self.ef = self.wire == "topk_int8"
        if self.wire != "none":
            x_sds = jax.ShapeDtypeStruct(
                (cfg.batch_size,) + tuple(self.stacked.images.shape[2:]),
                self.stacked.images.dtype)

            def _stack_shapes(x):
                h, outs = x, []
                for u in range(model.n_units - 1):
                    h = model.apply_units([units[u]], h, u)
                    outs.append(h)
                return outs

            sds = jax.eval_shape(_stack_shapes, x_sds)
            self.boundary_shapes = [tuple(s.shape) for s in sds]
            self.wire_units = (min(model.n_units - 1, self.max_cut_bucket)
                               if self.layout == "ragged"
                               else model.n_units - 1)
            self.res_size = max(int(np.prod(s))
                                for s in self.boundary_shapes
                                [:self.wire_units])
        else:
            self.boundary_shapes, self.res_size = None, 0
            self.wire_units = 0
        # fault plane (DESIGN.md §13): every hook below is gated at Python
        # level on `fz`, so a zero-fault config traces the identical program
        self.faults = (cfg.fault_config() if hasattr(cfg, "fault_config")
                       else faults.FaultConfig())
        if self.faults.coverage:
            raise ValueError(
                "fault coverage (the legacy single-RSU mobility_dropout "
                "in-range test) does not apply to the multi-RSU super-step "
                "engine: scenarios model coverage through serving_rsu == -1")
        self.fz = self.faults.stochastic
        # streaming plane (DESIGN.md §14): presence churn (`cz`) gates any
        # schedule; the StreamBuffer (`sz`) belongs to schedule="streaming"
        self.stream = (cfg.stream_config() if hasattr(cfg, "stream_config")
                       else streaming.StreamConfig())
        self.cz = self.stream.churning
        self.sz = self.schedule == "streaming"

    def flatten(self, units, head) -> jnp.ndarray:
        return ravel_pytree({"units": list(units), "head": head})[0]

    def make_carry(self, units, head, n_vehicles: int):
        """Fresh super-step carry for the engine's schedule.  Every buffer
        belongs to the carry alone (the whole carry is donated to each
        dispatch); the sequential schedule keeps pytree edges, the parallel
        schedule keeps the flat plane.  Under a mesh the edge stack is
        placed sharded over the RSU axis and the rest replicated, matching
        the ``shard_map`` specs so donation reuses the sharded buffers."""
        R = self.n_rsus_padded
        if self.schedule == "sequential":
            stackR = lambda t: jax.tree.map(
                lambda a: jnp.broadcast_to(a, (R,) + a.shape), t)
            edge = {"units": [stackR(u) for u in units],
                    "head": stackR(head)}
            glob = tree_copy({"units": list(units), "head": head})
        else:
            flat = self.flatten(units, head)
            edge = jnp.broadcast_to(flat, (R, self.n_params))
            glob = jnp.array(flat, copy=True)
        carry = {"edge": edge,
                 "samples": jnp.zeros((R,), jnp.float32),
                 "prev": jnp.full((n_vehicles,), -1, jnp.int32),
                 "global": glob}
        if self.ef:
            # error-feedback planes (wire="topk_int8"): per-vehicle
            # residual buffer + the cut it was accumulated at (-1 = never
            # trained; a cut change invalidates the buffer's layout)
            carry["wire_res"] = jnp.zeros((n_vehicles, self.res_size),
                                          jnp.float32)
            carry["wire_cut"] = jnp.full((n_vehicles,), -1, jnp.int32)
        if self.fz:
            # staleness bank (DESIGN.md §13): last round's deadline-
            # straggler client updates, banked per RSU as a weighted
            # numerator (sequential: per-unit trees; parallel: the owned
            # prefix window of the flat plane) plus the per-unit banked
            # weight, merged next round at the staleness discount
            CU = self.client_units
            if self.schedule == "sequential":
                carry["stale_num"] = [
                    jax.tree.map(
                        lambda a: jnp.zeros((R,) + a.shape, jnp.float32),
                        units[u])
                    for u in range(CU)]
            else:
                carry["stale_num"] = jnp.zeros((R, self.plane_width),
                                               jnp.float32)
            carry["stale_den"] = jnp.zeros((R, CU), jnp.float32)
        if self.cz:
            # presence plane (DESIGN.md §14): the Markov toggle chain's
            # state — all vehicles start present; churn flips bits in-round
            carry["present"] = jnp.ones((n_vehicles,), bool)
        if self.sz:
            # StreamBuffer (DESIGN.md §14): per-RSU ring of B pending
            # cohort deltas on the flat plane, their merge weights, their
            # ages in rounds, and the fill count.  Per-RSU state: it shards
            # with the edge stack (and replicates when the edge does)
            B = int(self.stream.buffer_size)
            carry["sbuf"] = jnp.zeros((R, B, self.n_params), jnp.float32)
            carry["sbuf_w"] = jnp.zeros((R, B), jnp.float32)
            carry["sbuf_age"] = jnp.zeros((R, B), jnp.int32)
            carry["sbuf_cnt"] = jnp.zeros((R,), jnp.int32)
        if self.mesh is not None:
            if self.schedule != "sequential" and self.layout == "ragged":
                # ragged + parallel/streaming shards the compacted SLOT
                # axis, not the RSU axis: every device owns a block of
                # occupied slots of arbitrary RSUs, so the edge stack must
                # be replicated (the per-RSU segment-sums come home via
                # psum)
                carry = {k: self.mesh.replicate(v) for k, v in carry.items()}
            else:
                # the staleness bank and stream buffer are per-RSU state
                # and shard with the edge stack
                for k in carry:
                    if k in ("edge", "stale_num", "stale_den", "sbuf",
                             "sbuf_w", "sbuf_age", "sbuf_cnt"):
                        carry[k] = self.mesh.shard_leading(carry[k])
                    else:
                        carry[k] = self.mesh.replicate(carry[k])
        return carry

    def global_model(self, carry):
        """(units, head) view of the carry's global model, in fresh buffers
        callers may hold across the next (donating) dispatch."""
        if self.schedule == "sequential":
            g = tree_copy(carry["global"])
        else:
            g = self.unravel(carry["global"])
        return list(g["units"]), g["head"]

    # ---- program construction ----------------------------------------
    def _build(self, sig: SuperStepSignature):
        model, cfg, opt = self.model, self.cfg, self.opt
        U = model.n_units
        R, C, n = self.n_rsus_padded, sig.capacity, self.n_vehicles
        fm = self.mesh
        R_loc = R if fm is None else R // fm.rsu_devices
        dv = 1 if fm is None else fm.veh_devices
        P = self.n_params
        steps, batch = self.steps, cfg.batch_size
        interval = float(cfg.round_interval_s)
        sync_every = self.sync_every
        nb, ep = self.nb, self.ep
        sc = self.scenario
        unravel = self.unravel
        unit_ids = self.unit_ids
        images, labels = self.stacked.images, self.stacked.labels
        lengths_dev = jnp.asarray(self.lengths, jnp.int32)
        lengths_f = jnp.asarray(self.lengths, jnp.float32)
        flops = jnp.asarray(self.fa["compute_flops"], jnp.float32)
        base_key = jax.random.PRNGKey(cfg.seed)
        fading_key = jax.random.PRNGKey(cfg.seed ^ 0x5EED5EED)
        strategy = cfg.adaptive_strategy
        slot_ids = jnp.arange(C, dtype=jnp.int32)
        wire, ef, wire_k = self.wire, self.ef, self.wire_k
        bshapes, res_size = self.boundary_shapes, self.res_size
        wire_units = self.wire_units
        # fault-plane statics (DESIGN.md §13): gated at Python level on
        # `fz` throughout — zero-fault configs trace the identical program
        fc, fz = self.faults, self.fz
        disc = float(fc.staleness_discount)
        # streaming-plane statics (DESIGN.md §14): gated at Python level on
        # `cz` (presence churn) and `sz` (the streaming schedule's buffer)
        stc, cz, sz = self.stream, self.cz, self.sz
        B = int(stc.buffer_size)
        # ragged layout statics (DESIGN.md §12): the owned-prefix window of
        # the plane, the per-replica unit count (sequential), and the flat
        # slot-axis geometry (parallel).  Dense: window = whole plane,
        # CU = U, and the flat axis is the flattened (R, C) table
        layout = self.layout
        ragged_par = self.schedule != "sequential" and layout == "ragged"
        O, W = self.plane_offset, self.plane_width
        CU = self.client_units
        unit_ids_w = unit_ids[O:O + W]
        S = sig.slots if ragged_par else R * C
        C_loc = C if fm is None else C // dv
        # dense2d: the grid mesh splits each RSU's dense slot row into
        # vehicle-axis column blocks; segment-sums regroup through an
        # order-restoring all-gather (DESIGN.md §15)
        dense2d = (fm is not None and layout == "dense"
                   and self.schedule != "sequential" and dv > 1)
        if fm is not None and layout == "dense" and C % dv != 0:
            raise ValueError(
                f"dense slot capacity {C} must divide over the vehicle "
                f"sub-axis ({dv} devices); pad it with "
                f"FleetMesh.pad_slots upstream")
        paged, n_pages, page = False, 1, int(getattr(cfg, "page_slots", 0))
        if self.schedule != "sequential":
            if fm is None:
                S_loc, R_srv, psum_out = S, R, False
            elif layout == "dense":
                # RSU-aligned slot blocks: device (i, j)'s slots are its
                # R_loc RSU rows x its C_loc slot columns.  With dv == 1
                # segment-sums stay shard-local and the PR 5 bit-for-bit
                # all-gather combine applies unchanged; with dv > 1 the
                # per-RSU sums regroup over the vehicle axis first
                S_loc, R_srv, psum_out = R_loc * C_loc, R_loc, False
            else:
                # compacted slots shard by occupancy over the WHOLE device
                # grid: blocks of occupied slots, RSUs interleaved —
                # per-RSU sums are psum'd partials
                S_loc, R_srv, psum_out = S // fm.n_devices, R, True
            # slot-capacity paging (DESIGN.md §15): when the planned
            # compacted block exceeds the per-device concurrent window,
            # each local step sweeps the slots in fixed `page`-slot
            # windows instead of one S_loc-wide vmap — peak activation
            # memory is set by page_slots, while the slot axis (and the
            # program signature) tracks the planned capacity.  Ragged
            # parallel/streaming only: the dense grid's bit-exact regroup
            # needs the whole (R_loc, C) row in flight
            if ragged_par and page > 0 and S_loc > page:
                if S_loc % page:
                    raise ValueError(
                        f"page_slots={page} must divide the per-device "
                        f"compacted slot block {S_loc} (signature() pads "
                        f"planned slots to a page multiple — pass slots "
                        f"through SuperStepPrograms.signature)")
                paged, n_pages = True, S_loc // page

        def pick_cuts(serving, rates, residence):
            """(n,) int32 cuts, 0 = SKIP/uncovered (traced twin of the PR 2
            host strategy dispatch)."""
            if strategy in ("paper", "paper-literal"):
                cuts = adaptive.paper_threshold_traced(
                    rates, literal_eq3=(strategy == "paper-literal"))
            else:  # "residence" (validated by ScenarioEngine.__init__)
                cuts = adaptive.residence_aware_traced(
                    self.profile, jnp.maximum(rates, 1.0), flops,
                    cfg.server_flops, nb, batch, ep, residence)
            sched = cuts > 0
            cuts = jnp.where(sched, jnp.clip(cuts, 1, U - 1), 0)
            return jnp.where(serving >= 0, cuts, 0).astype(jnp.int32)

        def slot_sort(serving, cuts):
            """On-device segment grouping: one sort of (serving, cut,
            vehicle) keys.  Replaces the host-side ``np.unique`` + boolean
            indexing, preserving the ascending (cut, vehicle) server-update
            order per RSU.  Unscheduled vehicles get segment R (past every
            real RSU), so they sort to the tail."""
            sched = cuts > 0
            seg = jnp.where(sched, serving, R).astype(jnp.int32)
            key = seg * (U * n) + cuts * n + jnp.arange(n, dtype=jnp.int32)
            order = jnp.argsort(key).astype(jnp.int32)
            counts = jnp.sum(seg[None, :] == jnp.arange(R, dtype=jnp.int32)
                             [:, None], axis=1).astype(jnp.int32)
            return order, seg, counts

        def slot_table_seq(order, counts):
            """Per-RSU (R, C) member slots for the sequential schedule."""
            starts = jnp.concatenate(
                [jnp.zeros((1,), jnp.int32), jnp.cumsum(counts)[:-1]])
            flat = jnp.clip(starts[:, None] + slot_ids[None, :], 0, n - 1)
            members = order[flat]                        # (R, C)
            mask = slot_ids[None, :] < counts[:, None]   # (R, C)
            return members, mask

        def slot_table_flat(order, seg, counts):
            """Flat (S,) slot table for the parallel schedule: ``members``
            (vehicle per slot) and ``slot_seg`` (serving RSU per slot, R =
            phantom/parked — scatter contributions to row R are dropped).

            Ragged: slots are the sorted order's prefix — globally
            compacted, RSU-major, zero phantom slots between cohorts.
            Dense: the flattened (R, C) padded table, so the occupied slots
            appear in the IDENTICAL global order as the ragged table and
            the two layouts differ only by exact-zero phantom
            contributions (the bit-for-bit parity argument)."""
            if ragged_par:
                seg_sorted = seg[order]
                if S <= n:
                    return order[:S], seg_sorted[:S]
                pad = S - n
                members = jnp.concatenate(
                    [order, jnp.zeros((pad,), jnp.int32)])
                slot_seg = jnp.concatenate(
                    [seg_sorted, jnp.full((pad,), R, jnp.int32)])
                return members, slot_seg
            members2d, mask2d = slot_table_seq(order, counts)
            rows = jnp.repeat(jnp.arange(R, dtype=jnp.int32), C)
            slot_seg = jnp.where(mask2d.reshape(-1), rows,
                                 R).astype(jnp.int32)
            return members2d.reshape(-1), slot_seg

        def loss_fn(units, head, x, y):
            feats = model.apply_units(units, x, 0)
            loss, logits = model.head_loss(head, feats, y)
            return loss, logits

        def wire_loss(units, head, x, y, cut_j, res_j):
            """Forward with the wire boundary at the runtime cut ``cut_j``.

            The cut is data, so every unit boundary computes its compressed
            candidate and a ``where`` keeps the one at the cut (under the
            RSU/slot vmaps a cond would run both branches anyway).  For
            ``topk_int8`` the slot's residual buffer ``res_j`` is
            reinterpreted in the active boundary's smashed shape, added
            before top-k (error feedback), and the un-sent remainder comes
            back as the aux output; gradients cross the boundary through
            the scheme's custom_vjp (the compressed downlink)."""
            h, r = x, res_j
            for u in range(U - 1):
                h = model.apply_units([units[u]], h, u)
                if u >= wire_units:
                    # ragged layout: cuts never exceed the bucket, so
                    # boundaries at or past it can never be selected —
                    # skipping their candidates changes no selected value
                    continue
                is_b = cut_j == (u + 1)
                if ef:
                    sz = int(np.prod(bshapes[u]))
                    yb, r2 = compression.wire_boundary(
                        h, res_j[:sz].reshape(bshapes[u]), wire_k)
                    r = jnp.where(is_b,
                                  jnp.pad(r2.reshape(-1),
                                          (0, res_size - sz)), r)
                else:
                    yb = compression.quant_boundary(h)
                h = jnp.where(is_b, yb, h)
            feats = model.apply_units([units[U - 1]], h, U - 1)
            loss, _ = model.head_loss(head, feats, y)
            return loss, (r if ef else jnp.zeros((0,), jnp.float32))

        # ---- sequential schedule (paper §III-B: the RSU consumes the
        # cohort's smashed batches one at a time, in slot order) ---------
        def seq_slot_body(carry, inp):
            """One client batch at one slot: the full unit stack, with the
            units before the slot's cut taken from its replica and the rest
            from the RSU copy.  Only the RSU state mutates here; client
            gradients stream out as scan outputs and are applied vmapped
            after the slot scan (each replica is touched once per step, so
            deferring its update out of the sequential body is identical
            math at a fraction of the op count)."""
            sv, so = carry
            if ef:
                cu_j, m_j, cut_j, act, idx_j, res_j = inp
            else:
                cu_j, m_j, cut_j, act, idx_j = inp
            x = images[m_j][idx_j]
            y = labels[m_j][idx_j]
            # units at or past the max-cut bucket have no client replica in
            # the ragged layout (CU < U): no cut can reach them, so the
            # server copy is the effective parameter unconditionally — the
            # same value the dense select produces (its replica is never
            # updated there), hence bit-for-bit across layouts
            eff = [_select(u < cut_j, cu_j[u], sv["units"][u])
                   for u in range(CU)] \
                + [sv["units"][u] for u in range(CU, U)]
            if wire == "none":
                (loss, _), (g_units, g_head) = jax.value_and_grad(
                    loss_fn, argnums=(0, 1), has_aux=True)(
                        eff, sv["head"], x, y)
            else:
                (loss, res_new), (g_units, g_head) = jax.value_and_grad(
                    wire_loss, argnums=(0, 1), has_aux=True)(
                        eff, sv["head"], x, y, cut_j,
                        res_j if ef else None)
            keep_s = [act & (u >= cut_j) for u in range(U)]
            g_sv = {"units": [_select(u >= cut_j, g_units[u],
                                      jax.tree.map(jnp.zeros_like,
                                                   g_units[u]))
                              for u in range(U)],
                    "head": g_head}
            upd, so2 = opt.update(g_sv, so, sv)
            sv2 = optim.apply_updates(sv, upd)
            sv3 = {"units": [_select(keep_s[u], sv2["units"][u],
                                     sv["units"][u]) for u in range(U)],
                   "head": _select(act, sv2["head"], sv["head"])}
            so3 = _sel_server_state(so2, so, keep_s, act)
            ys = (list(g_units[:CU]), jnp.where(act, loss, 0.0))
            if ef:
                ys = ys + (jnp.where(act, res_new, res_j),)
            return (sv3, so3), ys

        def rsu_round_seq(edge_tree, members, mask, cut_slots, idx_slots,
                          *extra):
            """One RSU's whole round (replica init, every local step,
            unit-wise FedAvg) with the sequential server schedule — vmapped
            across the RSU axis by the round body.  Params stay in pytree
            form here: the sequential slot scan is dominated by per-slot
            tree math, and ravelling in/out of the flat plane per round
            measurably loses to plain trees on CPU.

            ``extra`` packs the statically gated optional planes, in order:
            the EF residual slots (when ``ef``), then the fault planes
            (when ``fz``): per-step slot activity (steps, C), survivor
            slots, straggler slots, and the incoming staleness bank."""
            i = 0
            if ef:
                res_slots = extra[0]
                i = 1
            if fz:
                (act_steps, surv_slots, strag_slots,
                 st_num_in, st_den_in) = extra[i:]
            sv = {"units": list(edge_tree["units"]),
                  "head": edge_tree["head"]}
            so = opt.init(sv)
            # ragged layout: replicas exist only for the CU units a cut can
            # reach — the per-slot memory and deferred-update math shrink
            # to the owned prefix
            cu = [jax.tree.map(
                lambda a: jnp.broadcast_to(a, (C,) + a.shape), u)
                for u in edge_tree["units"][:CU]]
            co = jax.vmap(opt.init)(cu)
            w_slots = lengths_f[members] * mask          # (C,)
            if not fz:
                keep_cu = [mask & (cut_slots > u) for u in range(CU)]

            def step_body(carry, x_s):
                if fz:
                    idx_s, act_s = x_s
                else:
                    idx_s, act_s = x_s, mask
                if ef:
                    sv, so, cu, co, res = carry
                    xs = (cu, members, cut_slots, act_s, idx_s, res)
                else:
                    sv, so, cu, co = carry
                    xs = (cu, members, cut_slots, act_s, idx_s)
                (sv, so), ys = lax.scan(
                    seq_slot_body, (sv, so), xs,
                    unroll=2 if C >= 64 else 1)
                if ef:
                    g_cu, losses, res = ys
                else:
                    g_cu, losses = ys
                upd_c, co2 = jax.vmap(opt.update)(g_cu, co, cu)
                cu2 = optim.apply_updates(cu, upd_c)
                # a dropout's replica stops updating at its drop step (per-
                # step keep); the zero-fault path keeps the hoisted masks
                keep_s = ([act_s & (cut_slots > u) for u in range(CU)]
                          if fz else keep_cu)
                cu = [_select(keep_s[u], cu2[u], cu[u])
                      for u in range(CU)]
                co = _sel_list_state(co2, co, keep_s, jnp.asarray(act_s))
                out = (sv, so, cu, co, res) if ef else (sv, so, cu, co)
                return out, (jnp.sum(losses),
                             jnp.sum(act_s.astype(jnp.float32)))

            init = (sv, so, cu, co, res_slots) if ef else (sv, so, cu, co)
            xs_steps = (idx_slots, act_steps) if fz else idx_slots
            (sv, so, cu, co, *res_t), (ls, cs) = lax.scan(
                step_body, init, xs_steps,
                unroll=min(steps, 2))
            if fz:
                # survivor weights (DESIGN.md §13): a dropped / lost /
                # straggling slot's client update folds into the FedAvg as
                # an exact +0 and the denominator renormalizes over the
                # survivors.  Server-side contributions stand for every
                # in-round-active slot — those gradients already landed on
                # the RSU's own copy
                w_merge = lengths_f[members] * surv_slots
                w_bank = lengths_f[members] * strag_slots
            else:
                w_merge = w_slots
            w_total = jnp.sum(w_merge)
            den = jnp.maximum(w_total, 1.0)
            merged, st_num_out, st_den_out = [], [], []
            for u in range(U):
                if u >= CU:
                    # no replica exists past the bucket: every slot's
                    # weight lands on the server copy, so the unit-wise
                    # FedAvg collapses to (w_total * sv) / den — the value
                    # the dense path computes through its all-zero client
                    # weights
                    merged.append(jax.tree.map(
                        lambda s, ref: jnp.where(
                            w_total > 0.0,
                            ((w_total * s.astype(jnp.float32))
                             / den).astype(ref.dtype), ref),
                        sv["units"][u], edge_tree["units"][u]))
                    continue
                w_u = w_slots * (cut_slots > u)
                if fz:
                    # survivor-weighted numerator + last round's staleness
                    # bank at the discount; den_u can sit in (0, 1) when
                    # only discounted bank weight remains, so the guard is
                    # a where, not a max
                    num = aggregation.survivor_weighted_sum(
                        cu[u], w_u, surv_slots)
                    swu = w_total - jnp.sum(w_merge * (cut_slots > u))
                    den_u = w_total + disc * st_den_in[u]
                    den_safe = jnp.where(den_u > 0.0, den_u, 1.0)
                    num = jax.tree.map(
                        lambda nm, s, st: (nm + swu * s.astype(jnp.float32)
                                           + disc * st),
                        num, sv["units"][u], st_num_in[u])
                    merged.append(jax.tree.map(
                        lambda nm, ref: jnp.where(
                            den_u > 0.0,
                            (nm / den_safe).astype(ref.dtype), ref),
                        num, edge_tree["units"][u]))
                    # this round's bank: straggler replicas fold with the
                    # same exact-+0 masking and merge NEXT round
                    st_num_out.append(aggregation.survivor_weighted_sum(
                        cu[u], w_u, strag_slots))
                    st_den_out.append(jnp.sum(w_bank * (cut_slots > u)))
                    continue
                swu = w_total - jnp.sum(w_u)
                num = aggregation.stacked_weighted_sum(cu[u], w_u)
                num = jax.tree.map(
                    lambda nm, s: nm + swu * s.astype(jnp.float32),
                    num, sv["units"][u])
                merged.append(jax.tree.map(
                    lambda nm, ref: jnp.where(
                        w_total > 0.0, (nm / den).astype(ref.dtype), ref),
                    num, edge_tree["units"][u]))
            out = {"units": merged, "head": sv["head"]}
            rets = [out, jnp.sum(ls), jnp.sum(cs), w_total]
            if ef:
                rets.append(res_t[0])
            if fz:
                rets.append(st_num_out)
                rets.append(jnp.stack(st_den_out))
            return tuple(rets)

        # ---- parallel schedule (arXiv:2405.18707: the RSUs execute the
        # cohorts' server-side passes in parallel and take one weighted
        # mean-gradient step per local step) ------------------------------
        def par_slot_grad(cu_j, cut_j, m_j, idx_j, sv_j, res_j=None):
            x = images[m_j][idx_j]
            y = labels[m_j][idx_j]
            # the effective plane: the slot's prefix replica where owned,
            # the serving RSU's plane elsewhere.  Dense layout: O = 0 and
            # W = P, so this is the old full-plane select verbatim
            own = jnp.where(unit_ids_w < cut_j, cu_j, sv_j[O:O + W])
            if O > 0 or O + W < P:
                plane = jnp.concatenate([sv_j[:O], own, sv_j[O + W:]])
            else:
                plane = own
            eff = unravel(plane)
            if wire == "none":
                (loss, _), (g_units, g_head) = jax.value_and_grad(
                    loss_fn, argnums=(0, 1), has_aux=True)(
                        eff["units"], eff["head"], x, y)
            else:
                (loss, res_new), (g_units, g_head) = jax.value_and_grad(
                    wire_loss, argnums=(0, 1), has_aux=True)(
                        eff["units"], eff["head"], x, y, cut_j, res_j)
            g = ravel_pytree({"units": list(g_units), "head": g_head})[0]
            if ef:
                return g, loss, res_new
            return g, loss

        def fleet_round_par(edge_stack_in, cuts, members_l, slot_seg_l,
                            idx_slots_l, *extra):
            """The whole fleet's round over ONE flat slot axis: vmapped
            client fwd/bwd over this shard's ``S_loc`` slots, per-RSU
            aggregation as segment-sums.  Both layouts run this code — they
            differ only in the slot table handed in (compacted occupied
            slots vs the flattened padded (R, C) grid).  Segment scatter-
            adds fold left from +0, so the dense table's phantom slots
            (segment R, dropped row; exact-zero weights) are bitwise
            neutral — the bit-for-bit layout-parity argument
            (tests/test_ragged.py).

            ``extra`` packs the statically gated optional planes, in order:
            the EF residual slots (when ``ef``), then the fault planes
            (when ``fz``): per-step slot activity (steps, S_loc), survivor
            slots, straggler slots, and the incoming staleness bank
            ((R_srv, W) numerator plane + (R_srv, CU) per-unit weight)."""
            i = 0
            if ef:
                res_slots_l = extra[0]
                i = 1
            if fz:
                (act_slots_l, surv_sl, strag_sl,
                 st_num_in, st_den_in) = extra[i:]
            slot_mask_l = slot_seg_l < R_srv             # (S_loc,)
            seg_gather = jnp.minimum(slot_seg_l, R_srv - 1)
            cut_slots_l = cuts[members_l]
            w_slots_l = lengths_f[members_l] * slot_mask_l

            def regroup(vals):
                """Order-restoring gather over the vehicle sub-axis
                (dense grid mesh): this device's (R_loc, C_loc) column
                block rejoins its row's other blocks, so per-RSU
                reductions see the full C columns in the single-device
                slot order — the bit-for-bit combine, where a psum of
                column-block partials would reassociate the fp adds."""
                v = lax.all_gather(vals, VEH_AXIS)      # (dv, S_loc, ...)
                v = v.reshape((dv, R_loc, C_loc) + vals.shape[1:])
                v = jnp.moveaxis(v, 0, 1)               # (R_loc, dv, C_loc)
                return v.reshape((R_loc * C,) + vals.shape[1:])

            seg_full = regroup(slot_seg_l) if dense2d else slot_seg_l

            def seg_sum(vals):
                if dense2d:
                    vals = regroup(vals)
                out = jnp.zeros((R_srv + 1,) + vals.shape[1:],
                                vals.dtype).at[seg_full].add(vals)[:R_srv]
                return lax.psum(out, ALL_AXES) if psum_out else out

            w_seg = seg_sum(w_slots_l)                   # (R_srv,)
            den = jnp.maximum(w_seg, 1.0)
            any_active = w_seg > 0.0
            gw = w_slots_l / den[seg_gather]             # (S_loc,)
            # (S_loc, P) / (S_loc, W): positions each slot's replica owns
            keep_full = slot_mask_l[:, None] \
                & (unit_ids[None, :] < cut_slots_l[:, None])
            keep_w = keep_full[:, O:O + W]
            sv0 = edge_stack_in                          # (R_srv, P)
            cu = sv0[:, O:O + W][seg_gather]             # (S_loc, W)
            co = jax.vmap(opt.init)(cu)
            so = jax.vmap(opt.init)(sv0)

            def paged_sweep(sv_stack, cu, idx_s, amask, gw_s, res):
                """One local step's fwd/bwd in fixed slot windows
                (DESIGN.md §15): each page vmaps ``page`` slots, scatters
                its weighted gradient share into an (R_srv + 1, P)
                accumulator (row R_srv drops the phantoms), and emits only
                its owned-window gradient columns — the full-width
                (S_loc, P) gradient and the S_loc-wide activations never
                materialize, so peak memory is set by ``page_slots``, not
                the planned compacted capacity.  Pages are a lax.scan of
                static length: paging churn is data, never a signature."""
                pg = lambda a: a.reshape((n_pages, page) + a.shape[1:])
                xs = {"cu": pg(cu), "cut": pg(cut_slots_l),
                      "m": pg(members_l), "idx": pg(idx_s),
                      "seg": pg(slot_seg_l), "mask": pg(slot_mask_l),
                      "amask": pg(amask), "gw": pg(gw_s)}
                if ef:
                    xs["res"] = pg(res)

                def page_fn(accs, xp):
                    g_acc, l_acc = accs
                    sv_g = sv_stack[jnp.minimum(xp["seg"], R_srv - 1)]
                    if ef:
                        g, losses, res_n = jax.vmap(
                            par_slot_grad, in_axes=(0, 0, 0, 0, 0, 0))(
                                xp["cu"], xp["cut"], xp["m"], xp["idx"],
                                sv_g, xp["res"])
                    else:
                        g, losses = jax.vmap(
                            par_slot_grad, in_axes=(0, 0, 0, 0, 0))(
                                xp["cu"], xp["cut"], xp["m"], xp["idx"],
                                sv_g)
                    keep_p = xp["mask"][:, None] \
                        & (unit_ids[None, :] < xp["cut"][:, None])
                    contrib = jnp.where(keep_p, 0.0, g) * xp["gw"][:, None]
                    g_acc = g_acc.at[xp["seg"]].add(contrib)
                    l_acc = l_acc.at[xp["seg"]].add(
                        jnp.where(xp["amask"], losses, 0.0))
                    ys = (g[:, O:O + W], res_n) if ef else g[:, O:O + W]
                    return (g_acc, l_acc), ys

                accs0 = (jnp.zeros((R_srv + 1, P), jnp.float32),
                         jnp.zeros((R_srv + 1,), jnp.float32))
                (g_acc, l_acc), ys = lax.scan(page_fn, accs0, xs)
                if ef:
                    g_w = ys[0].reshape(S_loc, W)
                    res_new = ys[1].reshape(S_loc, res_size)
                else:
                    g_w, res_new = ys.reshape(S_loc, W), None
                g_srv, ls_seg = g_acc[:R_srv], l_acc[:R_srv]
                if psum_out:
                    g_srv = lax.psum(g_srv, ALL_AXES)
                    ls_seg = lax.psum(ls_seg, ALL_AXES)
                return g_w, g_srv, ls_seg, res_new

            def step_body(carry, x_s):
                if fz:
                    idx_s, act_s = x_s
                else:
                    idx_s = x_s
                if ef:
                    sv_stack, so, cu, co, res = carry
                else:
                    sv_stack, so, cu, co = carry
                    res = None
                if fz:
                    # per-step survivorship: a dropped slot stops
                    # contributing weight (and gradient) after its drop
                    # step, so the server's |D_n|-weighted mean-gradient
                    # renormalizes per step over the still-active slots
                    amask = slot_mask_l & act_s
                    w_act = w_slots_l * act_s
                    w_seg_s = seg_sum(w_act)
                    den_s = jnp.maximum(w_seg_s, 1.0)
                    gw_s = w_act / den_s[seg_gather]
                    any_s = w_seg_s > 0.0
                else:
                    amask, gw_s, any_s = slot_mask_l, gw, any_active
                if paged:
                    g_w, g_srv, ls_seg, res_new = paged_sweep(
                        sv_stack, cu, idx_s, amask, gw_s, res)
                else:
                    if ef:
                        g, losses, res_new = jax.vmap(
                            par_slot_grad, in_axes=(0, 0, 0, 0, 0, 0))(
                                cu, cut_slots_l, members_l, idx_s,
                                sv_stack[seg_gather], res)
                    else:
                        g, losses = jax.vmap(
                            par_slot_grad, in_axes=(0, 0, 0, 0, 0))(
                                cu, cut_slots_l, members_l, idx_s,
                                sv_stack[seg_gather])
                        res_new = None
                    # RSUs: one |D_n|-weighted mean-gradient step each
                    # over their cohorts' server-side gradient shares
                    contrib = jnp.where(keep_full, 0.0, g) * gw_s[:, None]
                    g_srv = seg_sum(contrib)             # (R_srv, P)
                    ls_seg = seg_sum(jnp.where(amask, losses, 0.0))
                    g_w = g[:, O:O + W]
                if ef:
                    res = jnp.where(amask[:, None], res_new, res)
                upd_s, so2 = jax.vmap(opt.update)(g_srv, so, sv_stack)
                sv2 = optim.apply_updates(sv_stack, upd_s)
                sv_stack = jnp.where(any_s[:, None], sv2, sv_stack)
                so = _sel_flat_state(any_s[:, None], any_s,
                                     so2, so, sv_stack.shape)
                # vehicles: per-replica prefix updates over the slot axis
                upd_c, co2 = jax.vmap(opt.update)(g_w, co, cu)
                keep_w_s = keep_w & act_s[:, None] if fz else keep_w
                cu = jnp.where(keep_w_s, optim.apply_updates(cu, upd_c), cu)
                co = _sel_flat_state(keep_w_s, amask, co2, co,
                                     cu.shape)
                out = (sv_stack, so, cu, co, res) if ef \
                    else (sv_stack, so, cu, co)
                return out, ls_seg

            init = (sv0, so, cu, co, res_slots_l) if ef \
                else (sv0, so, cu, co)
            xs_steps = (idx_slots_l, act_slots_l) if fz else idx_slots_l
            (sv_stack, so, cu, co, *res_t), ls_steps = lax.scan(
                step_body, init, xs_steps,
                unroll=min(steps, 4))
            ls_rows = jnp.sum(ls_steps, axis=0)          # (R_srv,)
            if fz:
                # survivor-weighted unit-wise FedAvg (DESIGN.md §13): the
                # merge weight is the SURVIVING slot weight — dropped /
                # lost / straggling slots fold in as exact +0 — plus last
                # round's staleness bank at the discount.  The per-position
                # denominator can sit in (0, 1) when only discounted bank
                # weight remains, so the guards are wheres, not maxes
                w_surv = w_slots_l * surv_sl.astype(jnp.float32)
                w_seg_m = seg_sum(w_surv)                # (R_srv,)
                wk = w_surv[:, None] * keep_w            # (S_loc, W)
                num = seg_sum(wk * cu)                   # (R_srv, W)
                w_srv = w_seg_m[:, None] - seg_sum(wk)
                svw = sv_stack[:, O:O + W]
                st_den_pos = st_den_in[:, unit_ids_w]    # (R_srv, W)
                den_pos = w_seg_m[:, None] + disc * st_den_pos
                den_pos_safe = jnp.where(den_pos > 0.0, den_pos, 1.0)
                merged_w = jnp.where(
                    den_pos > 0.0,
                    (num + w_srv * svw + disc * st_num_in) / den_pos_safe,
                    edge_stack_in[:, O:O + W])
                row_act = w_seg_m > 0.0
                den_row = jnp.maximum(w_seg_m, 1.0)
                if O > 0 or O + W < P:
                    edge_new = jnp.concatenate(
                        [jnp.where(row_act[:, None],
                                   (w_seg_m[:, None] * sv_stack[:, :O])
                                   / den_row[:, None],
                                   edge_stack_in[:, :O]),
                         merged_w,
                         jnp.where(row_act[:, None],
                                   (w_seg_m[:, None] * sv_stack[:, O + W:])
                                   / den_row[:, None],
                                   edge_stack_in[:, O + W:])],
                        axis=1)
                else:
                    edge_new = merged_w
                # this round's bank: straggler replicas scattered into the
                # same per-RSU segment rows, merged NEXT round
                w_st = w_slots_l * strag_sl.astype(jnp.float32)
                st_num_out = seg_sum((w_st[:, None] * keep_w) * cu)
                unit_own = (cut_slots_l[:, None]
                            > jnp.arange(CU, dtype=jnp.int32)[None, :])
                st_den_out = seg_sum(w_st[:, None] * unit_own)
                rets = [edge_new, ls_rows, w_seg_m, slot_mask_l]
                if ef:
                    rets.append(res_t[0])
                rets += [st_num_out, st_den_out]
                return tuple(rets)
            # unit-wise FedAvg: segment-sums over the owned window, the
            # untouched remainder of the plane merges as (w_seg * sv) / den
            # (its client weight is identically zero)
            wk = w_slots_l[:, None] * keep_w             # (S_loc, W)
            num = seg_sum(wk * cu)                       # (R_srv, W)
            w_srv = w_seg[:, None] - seg_sum(wk)
            merged_w = (num + w_srv * sv_stack[:, O:O + W]) / den[:, None]
            if O > 0 or O + W < P:
                merged = jnp.concatenate(
                    [(w_seg[:, None] * sv_stack[:, :O]) / den[:, None],
                     merged_w,
                     (w_seg[:, None] * sv_stack[:, O + W:]) / den[:, None]],
                    axis=1)
            else:
                merged = merged_w
            edge_new = jnp.where(any_active[:, None], merged,
                                 edge_stack_in)
            if ef:
                return edge_new, ls_rows, w_seg, slot_mask_l, res_t[0]
            return edge_new, ls_rows, w_seg, slot_mask_l

        def round_body(carry, x):
            rnd = x["rnd"]
            if sig.staged:
                serving = x["serving"]
                rates = x["rates"]
                residence = x["residence"]
            else:
                t = rnd.astype(jnp.float32) * interval
                fkey = jax.random.fold_in(fading_key, rnd)
                st = sc.traced_fleet_state(t, fkey)
                serving, rates, residence = (st.serving_rsu, st.rates_bps,
                                             st.residence_s)
            if cz:
                # presence churn (DESIGN.md §14): each vehicle flips its
                # presence bit with P[churn_rate], round-keyed so a K-fused
                # window samples identically to K single rounds.  A vehicle
                # not admitted this round becomes indistinguishable from
                # one outside coverage before cut selection.  Synchronous
                # schedules admit a fresh arrival only NEXT round (it still
                # has to register and download the cohort model after the
                # round has formed); the streaming schedule admits it
                # immediately — its shard is already staged on device by
                # the double-buffered pipeline, and the buffered merge
                # never waits on cohort formation
                if stc.churn_source == "mobility":
                    # mobility-coupled stream (DESIGN.md §15): departures
                    # ARE the coverage state — a vehicle whose serving
                    # cell is -1 has left the stream, one re-entering
                    # coverage re-registers.  Same admission contract as
                    # the sampled chain: synchronous schedules admit the
                    # re-arrival next round, streaming immediately
                    present2 = serving >= 0
                else:
                    toggle = streaming.sample_toggles_traced(stc, rnd, n)
                    present2 = carry["present"] ^ toggle
                arrived = present2 & ~carry["present"]
                admit = present2 if sz else (present2 & ~arrived)
                serving, rates, residence = streaming.gate_presence(
                    serving, rates, residence, admit)
            cuts = pick_cuts(serving, rates, residence)
            if fz:
                drop, dfrac, lost, rsu_down = faults.sample_faults_traced(
                    fc, rnd, n, R)
                rsu_down = faults.ensure_rsu_up(rsu_down)
                # whole-RSU outage: the cohort's cuts drop to SKIP before
                # slot grouping — the cell trains nothing and accrues no
                # samples this round, so the cloud merge reweights around
                # it by construction
                down_v = rsu_down[jnp.clip(serving, 0, R - 1)] \
                    & (serving >= 0)
                cuts = jnp.where(down_v, 0, cuts).astype(jnp.int32)
            order, seg_v, counts = slot_sort(serving, cuts)
            idx_all = fleet_batch_indices_traced(
                jax.random.fold_in(base_key, rnd), lengths_dev, steps, batch)
            sched = cuts > 0
            if fz:
                # failure precedence: a mid-round dropout has nothing left
                # to upload; an upload loss discards what a straggler
                # would have banked
                drop = drop & sched
                lost = lost & sched & ~drop
                if fc.straggler_factor > 0.0:
                    # deadline stragglers are derived, not sampled: the
                    # analytic round latency at the CHOSEN cut against the
                    # scaled residence budget
                    lat_m = adaptive.latency_matrix_traced(
                        self.profile, jnp.maximum(rates, 1.0), flops,
                        cfg.server_flops, nb, batch, ep, range(1, U))
                    lat = lat_m[jnp.arange(n), jnp.clip(cuts - 1, 0, U - 2)]
                    strag = sched & (lat > fc.straggler_factor * residence)
                else:
                    strag = jnp.zeros_like(sched)
                strag = strag & ~drop & ~lost
                rescue = faults.rescue_mask(sched, drop | lost | strag)
                drop = drop & ~rescue
                lost = lost & ~rescue
                strag = strag & ~rescue
                surv = sched & ~drop & ~lost & ~strag
                dstep = faults.drop_steps(drop, dfrac, steps)
                # (steps, n) per-step activity: a dropout runs only its
                # first dstep local batches; everyone else runs them all
                act_v = (jnp.arange(steps, dtype=jnp.int32)[:, None]
                         < dstep[None, :]) & sched[None, :]
                # banked weight merging THIS round (telemetry)
                stale_w = jnp.sum(carry["stale_den"])
                if fm is not None and not ragged_par:
                    # the bank is per-RSU state, sharded over the RSU axis
                    # and replicated across the vehicle sub-axis — psum
                    # over the RSU axis only (both would multiply by dv)
                    stale_w = lax.psum(stale_w, RSU_AXIS)
            if ef:
                # residuals follow the vehicle (the plane is fleet-indexed
                # and replicated): zero where this round's cut differs from
                # the one the buffer was accumulated at, then gather each
                # shard's slot view
                stale = sched & (cuts != carry["wire_cut"])
                res_base = jnp.where(stale[:, None], 0.0,
                                     carry["wire_res"])
            if self.schedule == "sequential":
                members, mask = slot_table_seq(order, counts)
                if fm is not None:
                    # the slot table is fleet-wide and replicated; each
                    # RSU-axis shard trains its contiguous block of RSU
                    # rows.  The sequential schedule is a per-RSU slot
                    # CHAIN (slot i+1's server pass consumes slot i's
                    # updated state), so the vehicle sub-axis has nothing
                    # to split — it replicates the chain (DESIGN.md §15)
                    members_l = fleet_sharding.local_slice(
                        members, R_loc, axes=(RSU_AXIS,))
                    mask_l = fleet_sharding.local_slice(
                        mask, R_loc, axes=(RSU_AXIS,))
                else:
                    members_l, mask_l = members, mask
                idx_rsu = jnp.moveaxis(idx_all[:, members_l], 1, 0)
                cut_slots = cuts[members_l]            # (R_loc, C)
                args = [carry["edge"], members_l, mask_l, cut_slots,
                        idx_rsu]
                if ef:
                    res_slots = res_base[members_l]    # (R_loc, C, res)
                    args.append(res_slots)
                if fz:
                    act_rsu = jnp.moveaxis(act_v[:, members_l], 1, 0) \
                        & mask_l[:, None, :]           # (R_loc, steps, C)
                    args += [act_rsu, surv[members_l] & mask_l,
                             strag[members_l] & mask_l,
                             carry["stale_num"], carry["stale_den"]]
                outs = jax.vmap(rsu_round_seq)(*args)
                if fz:
                    st_num2, st_den2 = outs[-2], outs[-1]
                    outs = outs[:-2]
                if ef:
                    edge, ls, cs, w_tot, res_out = outs
                else:
                    edge, ls, cs, w_tot = outs
                ef_mask, ef_members = mask_l, members_l
                cnt = jnp.sum(cs)
                if fm is not None:
                    # per-RSU results come home via all_gather so every
                    # total (loss/count sums, the sample counters, the
                    # cloud merge) reduces the full (R,) stack in the SAME
                    # order as the single-device program — gather-then-
                    # reduce is the order-preserving form of the weighted
                    # all-reduce, which is what keeps sharded sgd
                    # bit-for-bit (a psum of per-shard partials would
                    # reassociate the fp additions)
                    ls = lax.all_gather(ls, RSU_AXIS, tiled=True)
                    cnt = jnp.sum(lax.all_gather(cs, RSU_AXIS,
                                                 tiled=True))
                    w_tot = lax.all_gather(w_tot, RSU_AXIS, tiled=True)
                    edge_stack = aggregation.gathered_stack(edge,
                                                            RSU_AXIS)
                else:
                    edge_stack = edge
            else:
                members, slot_seg = slot_table_flat(order, seg_v, counts)
                if fm is None:
                    members_l, slot_seg_l = members, slot_seg
                elif layout == "dense":
                    # RSU-aligned tiles: device (i, j)'s slots are its
                    # R_loc rows x C_loc columns of the padded (R, C)
                    # grid (with dv == 1 that is exactly the old R_loc-row
                    # block); localize segment ids and clip the phantom
                    # segment R onto the local drop row
                    members_l = fleet_sharding.local_block2d(
                        members.reshape(R, C), R_loc, C_loc).reshape(-1)
                    seg = fleet_sharding.local_block2d(
                        slot_seg.reshape(R, C), R_loc, C_loc).reshape(-1)
                    r0 = lax.axis_index(RSU_AXIS) * R_loc
                    slot_seg_l = jnp.minimum(seg - r0,
                                             R_loc).astype(jnp.int32)
                else:
                    # occupancy-balanced blocks of the compacted axis,
                    # split over the WHOLE (rsu, vehicle) device grid
                    members_l = fleet_sharding.local_slice(members, S_loc)
                    slot_seg_l = fleet_sharding.local_slice(slot_seg,
                                                            S_loc)
                idx_slots = idx_all[:, members_l]      # (steps, S_loc, b)
                args = [carry["edge"], cuts, members_l, slot_seg_l,
                        idx_slots]
                if ef:
                    res_slots = res_base[members_l]    # (S_loc, res)
                    args.append(res_slots)
                if fz:
                    args += [act_v[:, members_l], surv[members_l],
                             strag[members_l],
                             carry["stale_num"], carry["stale_den"]]
                outs = fleet_round_par(*args)
                if fz:
                    st_num2, st_den2 = outs[-2], outs[-1]
                    outs = outs[:-2]
                if ef:
                    edge, ls, w_tot, slot_mask_l, res_out = outs
                else:
                    edge, ls, w_tot, slot_mask_l = outs
                ef_mask, ef_members = slot_mask_l, members_l
                if fz:
                    # dropouts run only their dstep-batch prefix
                    cnt = jnp.sum(
                        jnp.where(sched, dstep, 0)).astype(jnp.float32)
                else:
                    # every occupied slot runs exactly `steps` batches
                    cnt = (jnp.sum(counts) * steps).astype(jnp.float32)
                if sz:
                    # StreamBuffer commit (DESIGN.md §14): the cohort's
                    # round update becomes a PENDING delta in the RSU's
                    # next free buffer slot; the edge model advances only
                    # when the buffer holds B deltas and the staleness-
                    # weighted survivor FedAvg fires.  Runs on the LOCAL
                    # edge rows (before any gather), so the committed edge
                    # is what the mesh combine sees.  All state is carry:
                    # buffer churn is data, never a program signature
                    edge_old = carry["edge"]
                    delta = edge - edge_old               # (R_srv, P)
                    pushed = w_tot > 0.0                  # (R_srv,)
                    cnt_b = carry["sbuf_cnt"]
                    slot_oh = (jnp.arange(B, dtype=jnp.int32)[None, :]
                               == cnt_b[:, None]) & pushed[:, None]
                    sb = jnp.where(slot_oh[:, :, None], delta[:, None, :],
                                   carry["sbuf"])
                    sbw = jnp.where(slot_oh, w_tot[:, None],
                                    carry["sbuf_w"])
                    sba = jnp.where(slot_oh, 0, carry["sbuf_age"])
                    cnt2 = cnt_b + pushed.astype(jnp.int32)
                    fire = cnt2 >= B                      # (R_srv,)
                    valid = (jnp.arange(B, dtype=jnp.int32)[None, :]
                             < cnt2[:, None])
                    # staleness-weighted survivor FedAvg over the pending
                    # deltas: weights are merge weight x kernel(age), and
                    # empty slots fold in as exact +0 through their zero
                    # weights.  The denominator can sit in (0, 1) under
                    # polynomial discounts, so the guard is a where
                    kw = (sbw * streaming.staleness_kernel(
                        stc.kernel, stc.alpha, sba)
                        * valid.astype(jnp.float32))
                    tot_b = jnp.sum(kw, axis=1)           # (R_srv,)
                    den_b = jnp.where(tot_b > 0.0, tot_b, 1.0)
                    merged_b = edge_old + jnp.einsum(
                        "rb,rbp->rp", kw, sb) / den_b[:, None]
                    edge = jnp.where(fire[:, None], merged_b, edge_old)
                    # merge telemetry, read BEFORE the post-fire reset
                    absorbed = jnp.sum(jnp.where(
                        fire[:, None], sbw * valid, 0.0))
                    st_stream = jnp.sum(jnp.where(
                        fire[:, None],
                        sba.astype(jnp.float32) * valid, 0.0))
                    fires = jnp.sum(fire.astype(jnp.int32))
                    occ = jnp.sum(jnp.where(fire, 0, cnt2))
                    # post-fire: fired buffers clear; survivors age one
                    # round.  The delta plane itself needs no clear — its
                    # weights are zero, the exact-+0 convention
                    sbuf2 = sb
                    sbw2 = jnp.where(fire[:, None], 0.0, sbw)
                    sba2 = jnp.where(fire[:, None], 0,
                                     jnp.where(valid, sba + 1, sba))
                    cnt3 = jnp.where(fire, 0, cnt2)
                    if fm is not None and not ragged_par:
                        # per-RSU scalars sharded over the RSU axis (and
                        # replicated across the vehicle sub-axis): sum
                        # home across the RSU shards only
                        rsu_only = (RSU_AXIS,)
                        absorbed = fleet_sharding.scalar_allsum(absorbed,
                                                                rsu_only)
                        st_stream = fleet_sharding.scalar_allsum(st_stream,
                                                                 rsu_only)
                        fires = fleet_sharding.scalar_allsum(fires,
                                                             rsu_only)
                        occ = fleet_sharding.scalar_allsum(occ, rsu_only)
                if fm is not None and layout == "dense":
                    # per-RSU rows are vehicle-replicated after the
                    # regrouped segment-sums, so the combine is the same
                    # RSU-axis gather as the 1-D mesh
                    ls = lax.all_gather(ls, RSU_AXIS, tiled=True)
                    w_tot = lax.all_gather(w_tot, RSU_AXIS, tiled=True)
                    edge_stack = aggregation.gathered_stack(edge,
                                                            RSU_AXIS)
                else:
                    # single device, or ragged mesh: segment-sums were
                    # already psum'd full-width and the edge is replicated
                    edge_stack = edge
            samples = carry["samples"] + w_tot
            if ef:
                # masked scatter-ADD of the residual deltas back onto the
                # fleet plane: padded slots carry a zero delta (their
                # member index is a clipped duplicate), active slots are
                # unique per round (a vehicle is served by one RSU), and
                # under a mesh the psum of per-shard deltas reassembles
                # the replicated plane — other shards contribute zeros
                delta = jnp.where(ef_mask[..., None], res_out - res_slots,
                                  0.0)
                upd = jnp.zeros_like(res_base).at[
                    ef_members.reshape(-1)].add(
                        delta.reshape(-1, delta.shape[-1]))
                if fm is not None:
                    # sequential: slots live on RSU-axis shards and the
                    # vehicle sub-axis replicates them (psum over both
                    # would multiply by dv); flat schedules: every slot
                    # lives on exactly one (rsu, vehicle) device
                    upd = lax.psum(upd,
                                   (RSU_AXIS,)
                                   if self.schedule == "sequential"
                                   else ALL_AXES)
                wire_res2 = res_base + upd
                wire_cut2 = jnp.where(sched, cuts,
                                      carry["wire_cut"]).astype(jnp.int32)
            handover = sched & (carry["prev"] >= 0) \
                & (carry["prev"] != serving)
            prev = jnp.where(serving >= 0, serving, -1).astype(jnp.int32)
            synced = (rnd + 1) % sync_every == 0
            merged_global = aggregation.stacked_cloud_merge(
                edge_stack, samples, carry["global"])
            carry2 = {
                "edge": jax.tree.map(
                    lambda stacked, g: jnp.where(
                        synced, jnp.broadcast_to(g, stacked.shape), stacked),
                    edge, merged_global),
                "samples": jnp.where(synced, jnp.zeros_like(samples),
                                     samples),
                "prev": prev,
                "global": jax.tree.map(
                    lambda g, old: jnp.where(synced, g, old),
                    merged_global, carry["global"]),
            }
            if ef:
                carry2["wire_res"] = wire_res2
                carry2["wire_cut"] = wire_cut2
            if fz:
                # the staleness bank drains every round: this round's
                # straggler captures replace last round's (now-merged) bank
                carry2["stale_num"] = st_num2
                carry2["stale_den"] = st_den2
            if cz:
                carry2["present"] = present2
            if sz:
                carry2["sbuf"] = sbuf2
                carry2["sbuf_w"] = sbw2
                carry2["sbuf_age"] = sba2
                carry2["sbuf_cnt"] = cnt3
            ys = {"loss": jnp.sum(ls), "cnt": cnt, "cuts": cuts,
                  "serving": serving.astype(jnp.int32),
                  "rates": rates.astype(jnp.float32),
                  "handover": handover, "counts": counts}
            if fz:
                ys.update({"drop": drop, "lost": lost, "strag": strag,
                           "rsu_down": rsu_down, "dstep": dstep,
                           "stale_w": stale_w})
            if cz:
                ys.update({
                    "present": jnp.sum(present2.astype(jnp.int32)),
                    "arrived": jnp.sum(arrived.astype(jnp.int32))})
            if sz:
                ys.update({"absorbed": absorbed, "stream_fires": fires,
                           "buf_occ": occ, "stream_stale": st_stream})
            return carry2, ys

        def superstep(carry, xs):
            return lax.scan(round_body, carry, xs)

        if fm is not None:
            # ragged + parallel replicates the edge stack (the mesh splits
            # the compacted slot axis, not the RSU axis); every other
            # combination shards the edge's leading RSU axis as before
            edge_spec = PSpec() if ragged_par else PSpec(RSU_AXIS)
            carry_spec = {"edge": edge_spec, "samples": PSpec(),
                          "prev": PSpec(), "global": PSpec()}
            if ef:
                carry_spec["wire_res"] = PSpec()
                carry_spec["wire_cut"] = PSpec()
            if fz:
                # the staleness bank is per-RSU state: it shards with the
                # edge stack (and replicates when the edge does)
                carry_spec["stale_num"] = edge_spec
                carry_spec["stale_den"] = edge_spec
            if cz:
                # presence is fleet-wide state, replicated like the slot
                # table it gates
                carry_spec["present"] = PSpec()
            if sz:
                # the stream buffer is per-RSU state: it shards with the
                # edge stack (and replicates when the edge does)
                for k in ("sbuf", "sbuf_w", "sbuf_age", "sbuf_cnt"):
                    carry_spec[k] = edge_spec
            superstep = shard_map(superstep, mesh=fm.mesh,
                                  in_specs=(carry_spec, PSpec()),
                                  out_specs=(carry_spec, PSpec()),
                                  check_rep=False)
        return jax.jit(superstep, donate_argnums=(0,))

    # ---- cache / AOT --------------------------------------------------
    def signature(self, k: int, capacity: int,
                  slots: int = 0) -> SuperStepSignature:
        """The compile-cache key for a K-window at per-RSU capacity
        ``capacity``.  ``slots`` (the bucketed max TOTAL covered count) is
        honored only by the ragged layout's parallel schedule; callers that
        do not plan it fall back to ``R * capacity`` — always sufficient,
        merely uncompacted."""
        if self.layout == "ragged" and self.schedule != "sequential":
            s = int(slots) if slots and int(slots) > 0 \
                else self.n_rsus_padded * int(capacity)
            if self.mesh is not None:
                s = self.mesh.balanced_slots(s)
            page = int(getattr(self.cfg, "page_slots", 0))
            if page > 0:
                # pad each device's block to a page multiple so the paged
                # sweep's fixed windows tile it exactly (padding is
                # phantom slots — inert by the exact-+0 convention)
                nd = 1 if self.mesh is None else self.mesh.n_devices
                per = -(-s // nd)
                if per > page:
                    per = -(-per // page) * page
                s = per * nd
        else:
            s = 0
        return SuperStepSignature(k, capacity, not self.traced_mobility,
                                  s, self.max_cut_bucket)

    def get(self, sig: SuperStepSignature):
        """The program for ``sig``; builds one (a counted compile fallback)
        if :meth:`precompile` did not cover it."""
        fn = self._programs.get(sig)
        if fn is None:
            self.compile_fallbacks += 1
            fn = self._build(sig)
            self._programs[sig] = fn
        return fn

    def precompile(self, sig: SuperStepSignature, carry, xs) -> None:
        """AOT-lower and compile the program for ``sig`` against the
        abstract shapes of (carry, xs) — leaves may be arrays or
        ``ShapeDtypeStruct``s."""
        if sig in self._programs:
            return

        def sds(a):
            if isinstance(a, jax.ShapeDtypeStruct):
                return a
            if self.mesh is not None and isinstance(a, jax.Array):
                # AOT-compiled executables check input shardings: keep the
                # carry's mesh placement in the abstract signature so the
                # run's (sharded, donated) carry matches what was compiled
                return jax.ShapeDtypeStruct(a.shape, a.dtype,
                                            sharding=a.sharding)
            a = jnp.asarray(a)
            return jax.ShapeDtypeStruct(a.shape, a.dtype)

        compiled = self._build(sig).lower(jax.tree.map(sds, carry),
                                          jax.tree.map(sds, xs)).compile()
        self._programs[sig] = compiled
