"""Fault plane: seeded, fully traced failure processes for the fused engines.

The paper's VEI setting is defined by mobility-induced failure (§II-C):
vehicles leave RSU coverage mid-round, uplinks fade after local work is
already done, slow links miss the residence deadline, and whole RSUs fail.
This module owns the *failure processes*; the engines own their
*consequences* (survivor-weighted merges, staleness banking, cohort skips).

Four stochastic processes, each an independent per-round Bernoulli draw from
a dedicated fault PRNG stream (``fold_in(fault_key, round)`` — so a K-fused
super-step samples identically to K single rounds, the same construction
the batch-index stream uses):

- **mid-round dropout** (per vehicle): the vehicle performs only a prefix of
  its local steps (``drop_step`` of ``steps``) and its client update never
  reaches the merge; the server-side gradients it contributed *before*
  dropping are kept (they already landed on the RSU).
- **upload loss** (per vehicle): full local work, but the model upload is
  lost.  Compute and transmit costs are charged; the update is not merged.
- **deadline straggler** (per vehicle, scenario engine only): the analytic
  round latency at the chosen cut exceeds ``straggler_factor x residence``.
  The update is not lost — it lands in a staleness bank on the super-step
  carry and merges next round with a ``staleness_discount``.
- **RSU outage** (per RSU, scenario engine only): the whole cohort sits the
  round out (cuts forced to SKIP); the cell's edge model and sample counter
  are untouched, so cloud-merge weights adjust by construction.

``coverage`` is the legacy deterministic §II-C in-range test from
``FederationSim.mobility_dropout`` (single-RSU engine only; multi-RSU
scenarios model coverage through the scenario itself via serving_rsu == -1).

Zero-fault invariant: every engine hook is gated at Python level on
``FaultConfig.enabled`` / ``.stochastic`` (the ``wire="none"`` precedent), so
the default config compiles to a byte-identical program and trains
bit-for-bit vs a build without the fault plane.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

# domain-separates the fault stream from the batch-index / fading streams,
# which already use seed*1000+rnd and seed^0x5EED5EED
FAULT_SALT = 0xFA17


@dataclasses.dataclass(frozen=True)
class FaultConfig:
    """Seeded failure processes injected into a federation engine.

    All-defaults means *no faults*: engines gate every fault hook at Python
    level on ``enabled`` so the zero-fault program is byte-identical to one
    built before the fault plane existed.
    """

    dropout_rate: float = 0.0       # P[vehicle drops mid-round]
    upload_loss_rate: float = 0.0   # P[client update lost after local work]
    straggler_factor: float = 0.0   # >0: deadline = factor * residence_s
    rsu_outage_rate: float = 0.0    # P[RSU misses the round entirely]
    staleness_discount: float = 0.5  # weight multiplier for banked updates
    coverage: bool = False          # legacy §II-C in-range test (FederationSim)
    seed: int = 0

    def __post_init__(self):
        for name in ("dropout_rate", "upload_loss_rate", "rsu_outage_rate"):
            v = getattr(self, name)
            if not 0.0 <= float(v) < 1.0:
                raise ValueError(f"{name} must be in [0, 1), got {v!r}")
        if not 0.0 <= float(self.staleness_discount) <= 1.0:
            raise ValueError(
                f"staleness_discount must be in [0, 1], got {self.staleness_discount!r}"
            )
        if float(self.straggler_factor) < 0.0:
            raise ValueError(
                f"straggler_factor must be >= 0, got {self.straggler_factor!r}"
            )

    @property
    def stochastic(self) -> bool:
        """Any traced (sampled) failure process active."""
        return (
            float(self.dropout_rate) > 0.0
            or float(self.upload_loss_rate) > 0.0
            or float(self.straggler_factor) > 0.0
            or float(self.rsu_outage_rate) > 0.0
        )

    @property
    def enabled(self) -> bool:
        return self.stochastic or self.coverage


def fault_key(cfg: FaultConfig, rnd) -> jax.Array:
    """Per-round fault PRNG key; ``rnd`` may be traced (window-independent)."""
    return jax.random.fold_in(jax.random.PRNGKey(cfg.seed ^ FAULT_SALT), rnd)


def sample_faults_traced(cfg: FaultConfig, rnd, n_vehicles: int, n_rsus: int):
    """Draw one round of failures inside the traced program.

    Returns ``(drop, drop_frac, lost, rsu_down)``: bool (n,), f32 (n,) in
    [0,1), bool (n,), bool (R,).  ``drop_frac`` positions the mid-round
    dropout within the local step schedule (see :func:`drop_steps`).
    Straggling is not sampled — it is *derived* from channel rates x
    residence by the engine.
    """
    kd, kf, ku, kr = jax.random.split(fault_key(cfg, rnd), 4)
    drop = jax.random.uniform(kd, (n_vehicles,)) < cfg.dropout_rate
    drop_frac = jax.random.uniform(kf, (n_vehicles,))
    lost = jax.random.uniform(ku, (n_vehicles,)) < cfg.upload_loss_rate
    rsu_down = jax.random.uniform(kr, (n_rsus,)) < cfg.rsu_outage_rate
    return drop, drop_frac, lost, rsu_down


def sample_faults_host(cfg: FaultConfig, rnd: int, n_vehicles: int):
    """Host-side twin for the legacy ``FederationSim`` round loop.

    An independent stream from the traced sampler (numpy vs threefry) — the
    two engines never share a fault schedule, only a distribution.
    """
    rng = np.random.default_rng((cfg.seed ^ FAULT_SALT) * 1_000_003 + rnd)
    drop = rng.random(n_vehicles) < cfg.dropout_rate
    drop_frac = rng.random(n_vehicles)
    lost = rng.random(n_vehicles) < cfg.upload_loss_rate
    return drop, drop_frac, lost


def drop_steps(drop, drop_frac, steps: int):
    """Per-vehicle performed local steps: ``floor(frac*steps)`` when dropped
    (possibly 0), the full ``steps`` otherwise.  int32 (n,)."""
    partial = jnp.floor(drop_frac * steps).astype(jnp.int32)
    return jnp.where(drop, partial, jnp.int32(steps))


def ensure_rsu_up(rsu_down):
    """Never let an outage take the whole network down: if every RSU drew an
    outage this round, RSU 0 is kept up."""
    all_down = jnp.all(rsu_down)
    keep = all_down & (jnp.arange(rsu_down.shape[0]) == 0)
    return rsu_down & ~keep


def rescue_mask(sched, failed):
    """At-least-one-participant guarantee.

    Returns a bool (n,) mask selecting the first *scheduled* vehicle iff the
    combined failures would wipe every scheduled vehicle; the engine clears
    that vehicle's failure bits.  All-False when any survivor exists (or
    nothing is scheduled), so the rescue is inert on typical rounds.
    """
    surv = sched & ~failed
    none_left = jnp.any(sched) & ~jnp.any(surv)
    first = jnp.argmax(sched)  # index of the first scheduled vehicle
    return none_left & sched & (jnp.arange(sched.shape[0]) == first)
