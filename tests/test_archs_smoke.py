"""Per-architecture smoke tests: REDUCED variant of each assigned arch runs
one forward + one train step on CPU; output shapes + no NaNs.  (The FULL
configs are exercised via the dry-run only — ShapeDtypeStruct, no alloc.)"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import transformer as T
from repro import optim

B, S = 2, 64


def _batch(cfg, key, seq=S):
    if cfg.frontend == "vision":
        s_text = seq - cfg.n_patches
        return {
            "tokens": jax.random.randint(key, (B, s_text), 0, cfg.vocab_size),
            "patch_embeds": jax.random.normal(key, (B, cfg.n_patches, cfg.d_model)),
            "labels": jax.random.randint(key, (B, s_text), 0, cfg.vocab_size),
        }
    if cfg.frontend == "audio":
        return {"codes": jax.random.randint(
            key, (B, cfg.n_codebooks, seq), 0, cfg.vocab_size)}
    return {
        "tokens": jax.random.randint(key, (B, seq), 0, cfg.vocab_size),
        "labels": jax.random.randint(key, (B, seq), 0, cfg.vocab_size),
    }


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_forward_shapes_and_train_step(arch):
    cfg = get_config(arch).reduced()
    assert cfg.d_model <= 256 and cfg.n_layers <= len(cfg.pattern) + len(cfg.tail)
    if cfg.moe:
        assert cfg.moe.n_experts <= 4
    key = jax.random.PRNGKey(0)
    params = T.init_params(key, cfg)
    batch = _batch(cfg, key)

    logits, aux, _ = T.forward(params, cfg, batch, "train")
    exp_s = S if cfg.frontend != "vision" else S
    if cfg.frontend == "audio":
        assert logits.shape == (B, S, cfg.n_codebooks, cfg.padded_vocab)
    else:
        assert logits.shape == (B, exp_s, cfg.padded_vocab)
    assert not bool(jnp.isnan(logits).any())

    # one train step: loss finite and params move
    opt = optim.adam(1e-3)
    state = opt.init(params)

    def loss_fn(p):
        return T.loss_fn(p, cfg, batch)[0]

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert np.isfinite(float(loss))
    upd, state = opt.update(grads, state, params)
    new_params = optim.apply_updates(params, upd)
    loss2 = loss_fn(new_params)
    assert np.isfinite(float(loss2))
    moved = jax.tree.reduce(
        lambda a, l: a + float(jnp.sum(jnp.abs(l))),
        jax.tree.map(lambda a, b: a - b, new_params, params), 0.0)
    assert moved > 0.0


@pytest.mark.parametrize("arch", ["smollm-360m", "mamba2-780m",
                                  "recurrentgemma-2b", "gemma3-4b",
                                  "deepseek-v2-lite-16b", "musicgen-large"])
def test_reduced_decode_matches_train(arch):
    """Prefill + one-token decode must reproduce the teacher-forced logits."""
    cfg = get_config(arch).reduced()
    key = jax.random.PRNGKey(42)
    params = T.init_params(key, cfg)
    s, cap = 33, 48
    batch = _batch(cfg, key, seq=s)
    full, _, _ = T.forward(params, cfg, batch, "train")
    if cfg.frontend == "audio":
        pre = {"codes": batch["codes"][:, :, :s - 1]}
        dec = {"codes": batch["codes"][:, :, s - 1:]}
    else:
        pre = {"tokens": batch["tokens"][:, :s - 1]}
        dec = {"tokens": batch["tokens"][:, s - 1:]}
    _, _, caches = T.forward(params, cfg, pre, "prefill", capacity=cap)
    dec_logits, _, _ = T.forward(params, cfg, dec, "decode", caches=caches,
                                 capacity=cap, pos_offset=s - 1)
    np.testing.assert_allclose(np.asarray(dec_logits[:, 0]),
                               np.asarray(full[:, -1]), rtol=2e-4, atol=2e-4)
