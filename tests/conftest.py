import os

# Kernels run in interpret mode on CPU; keep tests independent of any
# inherited XLA device-count flags (the dry-run sets its own in-process).
os.environ.setdefault("REPRO_PALLAS_INTERPRET", "1")
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)
