"""Multi-process ``jax.distributed`` smoke: 2 local processes x 4 forced
host devices must train the SAME model as one process (DESIGN.md §15).

The parent (no ``--process-id``) runs three children and compares:

* a single-process **reference** forcing all 8 host-platform CPU devices,
  so the engine builds the same ``(4, 2)`` 2-D ``(rsu, vehicle)`` mesh the
  workers will — every collective present, all of them in-process;
* two **worker** processes, each forcing 4 host devices and rendezvousing
  through ``jax.distributed`` on a loopback coordinator, so the SAME
  8-device mesh now spans a process boundary (cross-process collectives
  via gloo).

All three run the identical ``ExperimentSpec`` — the ``city`` scenario on
the fused ragged super-step engine with sgd — and process 0 of the worker
pair must reproduce the reference ``final_params`` bit for bit: splitting
the mesh across processes changes which transport moves the bytes, never
the math (§10/§15).  (Mesh-vs-single-device parity is the in-process
suites' job — ragged grid layouts carry the documented psum-partials
tolerance there.)

  PYTHONPATH=src python -m repro.launch.multiprocess_smoke

Exit status 0 on parity; non-zero on divergence, a worker crash, or a
rendezvous timeout.  CI runs this as the scale-out smoke.
"""
from __future__ import annotations

import argparse
import os
import socket
import subprocess
import sys
import tempfile


def _spec(api, args):
    """The one spec every process runs; only RuntimeConfig's process
    topology differs between reference and workers."""
    return api.ExperimentSpec(
        model="mlp9",
        train=api.TrainConfig(scheme="asfl", rounds=args.rounds,
                              local_steps=1, batch_size=8, lr=1e-3,
                              eval_every=0, optimizer="sgd",
                              server_schedule="sequential"),
        fleet=api.FleetConfig(n_vehicles=args.fleet, scenario="city",
                              scenario_kwargs={"seed": 7, "grid_x": 2,
                                               "grid_y": 2},
                              cloud_sync_every=1, round_interval_s=10.0,
                              per_vehicle_samples=16, data_seed=7),
        runtime=api.RuntimeConfig(
            superstep=2, superstep_layout="ragged", precompile=True,
            fleet_axis="grid",
            mesh_devices=args.mesh_devices,
            coordinator_address=args.coordinator,
            num_processes=args.num_processes,
            process_id=args.process_id))


def _run_and_save(args) -> None:
    """Child body (reference or worker): run the spec, save flattened
    ``final_params`` + losses as npz.  Every worker saves (host_fetch
    all-gathers non-addressable shards home), but only process 0's file is
    compared — the others just prove the gather works everywhere."""
    import numpy as np
    import jax
    from repro import api

    res = api.run(_spec(api, args))
    leaves = jax.tree.leaves(res.final_params)
    payload = {f"leaf{i}": np.asarray(a) for i, a in enumerate(leaves)}
    payload["losses"] = np.asarray([m.loss for m in res.history])
    payload["fallbacks"] = np.asarray(res.diagnostics["compile_fallbacks"])
    payload["n_processes"] = np.asarray(res.diagnostics["n_processes"])
    np.savez(args.out, **payload)
    print(f"[{args.tag}] devices={jax.device_count()} "
          f"local={jax.local_device_count()} "
          f"mesh={res.diagnostics['mesh_shape']} "
          f"losses={payload['losses'].tolist()}", flush=True)


def _child_env(local_devices: int) -> dict:
    env = dict(os.environ)
    flags = [f for f in env.get("XLA_FLAGS", "").split()
             if "host_platform_device_count" not in f]
    if local_devices > 1:
        flags.append(f"--xla_force_host_platform_device_count"
                     f"={local_devices}")
    env["XLA_FLAGS"] = " ".join(flags)
    # cross-process CPU collectives need the gloo implementation; the
    # default ("none") can only move bytes inside one process
    env.setdefault("JAX_CPU_COLLECTIVES_IMPLEMENTATION", "gloo")
    return env


def _parent(args) -> int:
    import numpy as np

    port = socket.socket()
    port.bind(("127.0.0.1", 0))
    coordinator = f"127.0.0.1:{port.getsockname()[1]}"
    port.close()

    with tempfile.TemporaryDirectory() as tmp:
        base = [sys.executable, "-m", "repro.launch.multiprocess_smoke",
                "--fleet", str(args.fleet), "--rounds", str(args.rounds)]
        ref = os.path.join(tmp, "ref.npz")
        total = 2 * args.local_devices
        print(f"[parent] single-process reference "
              f"({total} in-process devices) ...", flush=True)
        subprocess.run(base + ["--process-id", "0", "--num-processes", "1",
                               "--mesh-devices", str(total),
                               "--tag", "ref", "--out", ref],
                       env=_child_env(total), check=True,
                       timeout=args.timeout)

        print(f"[parent] 2 processes x {args.local_devices} devices via "
              f"{coordinator} ...", flush=True)
        outs, procs = [], []
        for pid in range(2):
            out = os.path.join(tmp, f"worker{pid}.npz")
            outs.append(out)
            procs.append(subprocess.Popen(
                base + ["--process-id", str(pid), "--num-processes", "2",
                        "--mesh-devices", str(2 * args.local_devices),
                        "--coordinator", coordinator,
                        "--tag", f"worker{pid}", "--out", out],
                env=_child_env(args.local_devices)))
        codes = [p.wait(timeout=args.timeout) for p in procs]
        if any(codes):
            print(f"[parent] FAIL: worker exit codes {codes}")
            return 1

        a, b = np.load(ref), np.load(outs[0])
        assert int(b["n_processes"]) == 2, "worker did not run distributed"
        assert int(b["fallbacks"]) == 0, "worker recompiled outside precompile"
        keys = sorted(k for k in a.files if k.startswith("leaf"))
        assert keys and keys == sorted(
            k for k in b.files if k.startswith("leaf"))
        worst = 0.0
        for k in keys + ["losses"]:
            d = np.abs(a[k].astype(np.float64) - b[k].astype(np.float64))
            worst = max(worst, float(d.max()) if d.size else 0.0)
        status = "bit-exact" if worst == 0.0 else f"max |delta|={worst:g}"
        print(f"[parent] single-process vs 2-process mesh: {status}")
        if worst != 0.0:
            print("[parent] FAIL: crossing the process boundary moved the "
                  "math — same mesh, same spec must be bit-identical")
            return 1
        print("[parent] PASS")
        return 0


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fleet", type=int, default=64)
    ap.add_argument("--rounds", type=int, default=2)
    ap.add_argument("--local-devices", type=int, default=4,
                    help="forced host devices per worker process")
    ap.add_argument("--timeout", type=float, default=600.0)
    # child-mode plumbing (set by the parent; absent => parent mode)
    ap.add_argument("--process-id", type=int, default=None)
    ap.add_argument("--num-processes", type=int, default=1)
    ap.add_argument("--mesh-devices", type=int, default=1)
    ap.add_argument("--coordinator", default=None)
    ap.add_argument("--tag", default="child")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    if args.process_id is None:
        return _parent(args)
    args.mesh_devices = int(args.mesh_devices)
    _run_and_save(args)
    return 0


if __name__ == "__main__":
    sys.exit(main())
