"""Extensions beyond the paper's case study: SFL over transformer stacks in
the simulator, mobility dropout, optimized-sharding model variants."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import channel
from repro.core.fedsim import FederationSim, ResNetModel, SimConfig
from repro.core.lm_unit import TransformerUnitModel
from repro.data.pipeline import ClientDataset, make_federated_data
from repro.data.synthetic import make_bigram_lm


def _lm_clients(cfg, n_clients=3, seq=32):
    clients = []
    for i in range(n_clients):
        s = np.asarray(make_bigram_lm(jax.random.PRNGKey(i), cfg.vocab_size,
                                      1500))
        n = (len(s) - 1) // seq
        x = np.stack([s[j * seq:(j + 1) * seq] for j in range(n)])
        y = np.stack([s[j * seq + 1:(j + 1) * seq + 1] for j in range(n)])
        clients.append(ClientDataset(x, y, i))
    t = np.asarray(make_bigram_lm(jax.random.PRNGKey(99), cfg.vocab_size, 700))
    test = {"images": jnp.asarray(np.stack([t[j * seq:(j + 1) * seq]
                                            for j in range(10)])),
            "labels": jnp.asarray(np.stack([t[j * seq + 1:(j + 1) * seq + 1]
                                            for j in range(10)]))}
    return clients, test


def test_transformer_unit_model_multi_cut_sfl():
    """ASFL over a 4-period smollm stack: every cut splits/learns."""
    base = get_config("smollm-360m").reduced()
    cfg = dataclasses.replace(base, n_layers=4)   # 4 periods -> 5 units
    model = TransformerUnitModel(cfg)
    assert model.n_units == 5
    clients, test = _lm_clients(cfg)
    sim = FederationSim(model, clients, test,
                        SimConfig(scheme="sfl", cut=2, rounds=2,
                                  local_steps=3, lr=3e-3, batch_size=4))
    hist = sim.run()
    assert hist[-1].loss < hist[0].loss + 1e-6
    assert np.isfinite(hist[-1].loss)


def test_transformer_unit_model_matches_whole_model():
    """Unit-stacked forward == monolithic transformer forward."""
    from repro.models import transformer as T
    cfg = dataclasses.replace(get_config("smollm-360m").reduced(), n_layers=3)
    model = TransformerUnitModel(cfg)
    key = jax.random.PRNGKey(0)
    units, head = model.init(key)
    toks = jax.random.randint(key, (2, 16), 0, cfg.vocab_size)
    feats = model.apply_units(units, toks, 0)
    logits_units = model.head_predict(head, feats)

    params = T.init_params(key, cfg)   # same key -> same weights
    logits_full, _, _ = T.forward(params, cfg, {"tokens": toks}, "train")
    np.testing.assert_allclose(np.asarray(logits_units),
                               np.asarray(logits_full), rtol=2e-4, atol=2e-4)


def test_mobility_dropout_skips_out_of_range_vehicles():
    clients, test = make_federated_data(0, n_train=256, n_test=64,
                                        n_clients=4)
    # fleet engineered so vehicles 2,3 are out of range at t=0
    fleet = [channel.VehicleProfile(x0_m=-100.0, speed_mps=0.0),
             channel.VehicleProfile(x0_m=-200.0, speed_mps=0.0),
             channel.VehicleProfile(x0_m=-900.0, speed_mps=0.0),
             channel.VehicleProfile(x0_m=-900.0, speed_mps=0.0)]
    cfg = SimConfig(scheme="sfl", cut=2, rounds=1, local_steps=1,
                    batch_size=8, mobility_dropout=True)
    sim = FederationSim(ResNetModel(), clients, test, cfg, fleet=fleet)
    assert sim._participants(0) == [0, 1]
    hist = sim.run()
    assert np.isfinite(hist[0].loss)


def test_ssm_split_proj_variant_param_count_unchanged():
    cfg = get_config("mamba2-780m")
    split = dataclasses.replace(cfg, ssm=dataclasses.replace(
        cfg.ssm, fused_proj=False))
    assert cfg.param_count() == split.param_count()


def test_megatron_specs_shard_experts():
    """EP preference: expert weights shard the expert dim over `model`."""
    import os
    from repro.launch import mesh as MX
    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    # fake 16-way model axis via a mesh-like shim is overkill; check the
    # rule function directly with a synthetic path
    class Leaf:
        shape = (27, 64, 2048, 1408)   # (periods, experts, d, ff)
    path = (jax.tree_util.DictKey("segments"), jax.tree_util.DictKey("wi_gate"))
    mesh16 = jax.make_mesh((1, 1), ("data", "model"),
                           axis_types=(jax.sharding.AxisType.Auto,) * 2)
    spec = MX._megatron_spec(path, Leaf(), mesh16, fsdp=False)
    # model axis size 1 divides everything; expert dim (-3) must be chosen
    assert spec == jax.sharding.PartitionSpec(None, "model", None, None)
