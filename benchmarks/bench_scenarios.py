"""Scenario-layer benchmark: rounds/s per mobility scenario at fleet scale.

Runs the multi-RSU :class:`ScenarioEngine` (one compiled CohortEngine cohort
per RSU per round, handover, hierarchical edge->cloud aggregation) over every
registered scenario at fleet sizes {64, 256}.  The round hot path is the
compiled cohort program — membership churn from mobility only reshuffles
rows/buckets (pow2-padded signatures key the compile cache), so the timed
re-run measures steady-state round throughput with warm caches.

  PYTHONPATH=src python benchmarks/bench_scenarios.py
  -> BENCH_scenarios.json (repo root) + benchmarks/out/BENCH_scenarios.json
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

import jax
import numpy as np

from bench_fedsim import MLPUnitModel, make_mlp_fleet_data
from repro.core import scenario
from repro.core.fedsim import ScenarioEngine, SimConfig

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
OUT_DIR = os.path.join(os.path.dirname(__file__), "out")


def bench_one(name: str, n: int, rounds: int, local_steps: int, batch: int,
              strategy: str, sync: int) -> dict:
    sc = scenario.make_scenario(name, n, seed=n)
    clients, test = make_mlp_fleet_data(n, 64, 48, seed=n)
    cfg = SimConfig(scheme="asfl", adaptive_strategy=strategy, rounds=rounds,
                    local_steps=local_steps, batch_size=batch, lr=1e-3,
                    eval_every=0, round_interval_s=10.0)
    eng = ScenarioEngine(MLPUnitModel(), clients, test, cfg, sc,
                         cloud_sync_every=sync)
    t_warm0 = time.perf_counter()
    eng.run()                      # warmup: compiles every round structure
    t_warm = time.perf_counter() - t_warm0
    eng.reset()
    t0 = time.perf_counter()
    hist = eng.run()
    dt = time.perf_counter() - t0
    assert all(np.isfinite(m.loss) for m in hist)
    sched = [m.n_scheduled for m in hist]
    return {
        "scenario": name, "n_vehicles": n, "n_rsus": len(sc.rsu_positions),
        "mode": eng.engine.mode, "rounds": rounds,
        "round_s": dt / rounds, "rounds_per_s": rounds / dt,
        "warmup_s": t_warm,
        "scheduled_per_round": sched,
        "handovers": int(sum(m.n_handover for m in hist)),
        "final_loss": float(hist[-1].loss),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--sizes", default="64,256")
    ap.add_argument("--scenarios", default=",".join(sorted(scenario.SCENARIOS)))
    ap.add_argument("--rounds", type=int, default=2)
    ap.add_argument("--local-steps", type=int, default=2)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--strategy", default="paper",
                    help="cut strategy (paper | residence | ...)")
    ap.add_argument("--sync", type=int, default=1)
    args = ap.parse_args()

    results = []
    for name in args.scenarios.split(","):
        for n in (int(s) for s in args.sizes.split(",")):
            row = bench_one(name, n, args.rounds, args.local_steps,
                            args.batch, args.strategy, args.sync)
            results.append(row)
            print(f"{name:17s} n={n:4d} rsus={row['n_rsus']} "
                  f"mode={row['mode']:6s} round={row['round_s']*1e3:9.1f} ms "
                  f"({row['rounds_per_s']:.2f} rounds/s) "
                  f"handovers={row['handovers']}", flush=True)

    out = {
        "config": {"local_steps": args.local_steps, "batch": args.batch,
                   "rounds": args.rounds, "strategy": args.strategy,
                   "cloud_sync_every": args.sync,
                   "backend": jax.default_backend()},
        "results": results,
    }
    os.makedirs(OUT_DIR, exist_ok=True)
    for path in (os.path.join(ROOT, "BENCH_scenarios.json"),
                 os.path.join(OUT_DIR, "BENCH_scenarios.json")):
        with open(path, "w") as f:
            json.dump(out, f, indent=1, default=float)
    print(f"wrote {os.path.join(ROOT, 'BENCH_scenarios.json')}")


if __name__ == "__main__":
    main()
