"""``repro.api`` — the declarative front door over the federation engines.

One experiment is one :class:`ExperimentSpec` (nested config groups,
registry-validated at construction); ``run(spec)`` routes it to the right
engine and returns a :class:`RunResult`.  DESIGN.md §9 has the
spec → router → engine picture and the registry extension recipe.

    from repro import api

    spec = api.ExperimentSpec(
        model="mlp9",
        train=api.TrainConfig(rounds=8, local_steps=2, batch_size=8,
                              lr=1e-3),
        adaptive=api.AdaptiveConfig(strategy="residence"),
        fleet=api.FleetConfig(n_vehicles=64, scenario="highway_corridor",
                              cloud_sync_every=2),
        runtime=api.RuntimeConfig(superstep=4, slot_capacity="tight8"),
    )
    result = api.run(spec, on_round=lambda m: print(m.round, m.loss))
    result.save("run.json")

This surface is the public contract: ``__all__`` below is snapshot-tested
(tests/test_api.py), so accidental breakage fails tier-1.
"""
from repro.api.registry import (  # noqa: F401
    FEDERATION, MODELS, SCENARIO, SCENARIOS, SCHEDULES, SINGLE_RSU,
    STRATEGIES, WIRES, ModelEntry, ScheduleEntry, StrategyEntry, WireEntry,
    build_model, build_scenario, make_lm_fleet_data, model_entry,
    register_model, register_schedule, register_scenario, register_strategy,
    register_wire)
from repro.api.runner import RunResult, build_engine, run  # noqa: F401
from repro.api.spec import (  # noqa: F401
    SIM_CONFIG_FIELD_MAP, AdaptiveConfig, ExperimentSpec, FaultsConfig,
    FleetConfig, RuntimeConfig, StreamConfig, TrainConfig)

__all__ = [
    # spec
    "ExperimentSpec", "TrainConfig", "AdaptiveConfig", "FleetConfig",
    "RuntimeConfig", "FaultsConfig", "StreamConfig",
    "SIM_CONFIG_FIELD_MAP",
    # registries
    "MODELS", "SCENARIOS", "STRATEGIES", "SCHEDULES", "WIRES",
    "ModelEntry", "StrategyEntry", "ScheduleEntry", "WireEntry",
    "register_model", "register_scenario", "register_strategy",
    "register_schedule", "register_wire", "model_entry", "build_model",
    "build_scenario", "make_lm_fleet_data",
    "FEDERATION", "SCENARIO", "SINGLE_RSU",
    # runner
    "run", "build_engine", "RunResult",
]
