from repro.data.synthetic import (  # noqa: F401
    make_cifar_like, make_bigram_lm, lm_batch_from_stream)
from repro.data.partition import (  # noqa: F401
    label_skew_power_law, dirichlet_partition, partition_stats)
from repro.data.pipeline import ClientDataset, make_federated_data  # noqa: F401
