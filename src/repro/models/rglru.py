"""RG-LRU recurrent block (Griffin / RecurrentGemma, arXiv:2402.19427).

Block: x -> {gate branch: linear+GeLU} x {recurrent branch: linear -> causal
conv1d(width 4) -> RG-LRU} -> output linear.  The linear recurrence
h_t = a_t h_{t-1} + sqrt(1-a_t^2) (i_t * x_t) is evaluated with
jax.lax.associative_scan in train/prefill and as a single step in decode;
state is constant-size -> long_500k eligible.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers as L

Params = Dict[str, Any]


def _d_rnn(cfg: ArchConfig) -> int:
    return cfg.rglru.d_rnn or cfg.d_model


def init_rglru(key, cfg: ArchConfig, dtype=jnp.float32) -> Params:
    r = cfg.rglru
    d, dr = cfg.d_model, _d_rnn(cfg)
    ks = jax.random.split(key, 6)
    s = 1.0 / math.sqrt(d)
    return {
        "w_gate": L.init_dense(ks[0], d, dr, dtype),
        "w_x": L.init_dense(ks[1], d, dr, dtype),
        "conv_w": L.trunc_normal(ks[2], (r.d_conv, dr), 1.0 / math.sqrt(r.d_conv), dtype),
        "conv_b": jnp.zeros((dr,), dtype),
        "w_a": L.trunc_normal(ks[3], (dr, dr), 1.0 / math.sqrt(dr), dtype),
        "b_a": jnp.zeros((dr,), jnp.float32),
        "w_i": L.trunc_normal(ks[4], (dr, dr), 1.0 / math.sqrt(dr), dtype),
        "b_i": jnp.zeros((dr,), jnp.float32),
        # Lambda init so a = sigmoid(L)^(c r) gives decay ~0.9..0.999
        "lam": jnp.linspace(2.0, 7.0, dr, dtype=jnp.float32),
        "w_out": L.init_dense(ks[5], dr, d, dtype),
    }


def _causal_conv(x, w, b):
    width = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    y = jnp.zeros_like(x)
    for i in range(width):
        y = y + pad[:, i:i + x.shape[1], :] * w[i].astype(x.dtype)
    return y + b.astype(x.dtype)


def _gates(p: Params, cfg: ArchConfig, xr: jnp.ndarray):
    """Returns (log_a, gated_input) for the recurrence, float32."""
    r32 = xr.astype(jnp.float32)
    rgate = jax.nn.sigmoid(r32 @ p["w_a"].astype(jnp.float32) + p["b_a"])
    igate = jax.nn.sigmoid(r32 @ p["w_i"].astype(jnp.float32) + p["b_i"])
    log_a = cfg.rglru.c_exponent * rgate * jax.nn.log_sigmoid(p["lam"])
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - jnp.square(a), 1e-6)) * (igate * r32)
    return a, gated


def rglru_seq(p: Params, cfg: ArchConfig, xr: jnp.ndarray,
              h0=None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Linear recurrence over the sequence via associative scan.
    xr (b,s,dr) post-conv; returns (h (b,s,dr), final state (b,dr))."""
    a, gated = _gates(p, cfg, xr)
    if h0 is not None:
        # fold the incoming state in as a virtual step 0
        a = jnp.concatenate([jnp.zeros_like(a[:, :1]), a], axis=1)
        gated = jnp.concatenate([h0[:, None].astype(jnp.float32), gated], axis=1)

    def combine(left, right):
        al, bl = left
        ar, br = right
        return al * ar, ar * bl + br

    av, hv = jax.lax.associative_scan(combine, (a, gated), axis=1)
    if h0 is not None:
        hv = hv[:, 1:]
    return hv.astype(xr.dtype), hv[:, -1]


def rglru_train(p: Params, cfg: ArchConfig, x: jnp.ndarray) -> jnp.ndarray:
    y, _ = _rglru_full(p, cfg, x, None)
    return y


def _rglru_full(p, cfg, x, h0):
    gate = jax.nn.gelu(L.dense(p["w_gate"], x), approximate=True)
    xr = L.dense(p["w_x"], x)
    xr_conv = _causal_conv(xr, p["conv_w"], p["conv_b"])
    h, h_last = rglru_seq(p, cfg, xr_conv, h0)
    return L.dense(p["w_out"], gate * h), (xr, h_last)


def init_rglru_cache(cfg: ArchConfig, batch: int, dtype=jnp.float32) -> Params:
    r = cfg.rglru
    dr = _d_rnn(cfg)
    return {
        "conv": jnp.zeros((batch, r.d_conv - 1, dr), dtype),
        "state": jnp.zeros((batch, dr), jnp.float32),
        "pos": jnp.zeros((), jnp.int32),
    }


def rglru_prefill(p: Params, cfg: ArchConfig, x: jnp.ndarray
                  ) -> Tuple[jnp.ndarray, Params]:
    y, (xr_pre, h_last) = _rglru_full(p, cfg, x, None)
    r = cfg.rglru
    cache = init_rglru_cache(cfg, x.shape[0], x.dtype)
    cache["conv"] = xr_pre[:, -(r.d_conv - 1):, :]
    cache["state"] = h_last.astype(jnp.float32)
    cache["pos"] = jnp.asarray(x.shape[1], jnp.int32)
    return y, cache


def rglru_decode(p: Params, cfg: ArchConfig, x: jnp.ndarray,
                 cache: Params) -> Tuple[jnp.ndarray, Params]:
    gate = jax.nn.gelu(L.dense(p["w_gate"], x), approximate=True)   # (b,1,dr)
    xr = L.dense(p["w_x"], x)
    window = jnp.concatenate([cache["conv"], xr], axis=1)
    conv_out = (jnp.einsum("bwc,wc->bc", window, p["conv_w"].astype(x.dtype))
                + p["conv_b"].astype(x.dtype))[:, None, :]
    a, gated = _gates(p, cfg, conv_out)
    h = a[:, 0] * cache["state"] + gated[:, 0]
    y = L.dense(p["w_out"], gate * h[:, None].astype(x.dtype))
    return y, {"conv": window[:, 1:], "state": h, "pos": cache["pos"] + 1}


def rglru_flops(cfg: ArchConfig) -> int:
    d, dr = cfg.d_model, _d_rnn(cfg)
    return 2 * d * dr * 3 + 2 * dr * dr * 2 + 2 * cfg.rglru.d_conv * dr + 10 * dr
