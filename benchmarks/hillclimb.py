"""Perf hillclimb driver (§Perf): re-lower a target (arch x shape) with
optimization knobs and report the three roofline terms vs baseline.

  PYTHONPATH=src python benchmarks/hillclimb.py --pair smollm-360m:train_4k \
      --variants baseline,sdpa_spread ...
"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("DRYRUN_XLA_FLAGS")
                           or "--xla_force_host_platform_device_count=512")
import argparse, json, sys

PEAK_FLOPS, HBM_BW, ICI_BW = 197e12, 819e9, 50e9

VARIANTS = {
    "baseline": {},
    "megatron": {"megatron": True},
    "sdpa_spread": {"sdpa_spread": True},
    "sdpa_norestore": {"sdpa_spread": "norestore"},
    "megatron+sdpa": {"megatron": True, "sdpa_spread": True},
    "ssm_split_proj": {"ssm_split_proj": True},
    "megatron+split": {"megatron": True, "ssm_split_proj": True},
    "compress": {"compress": True},
    "remat_dots": {"remat_policy": "dots"},
    "split+dots": {"ssm_split_proj": True, "remat_policy": "dots"},
    "sdpa+dots": {"sdpa_spread": "norestore", "remat_policy": "dots"},
    "megatron+dots": {"megatron": True, "remat_policy": "dots"},
    "mega+dots+nofsdp": {"megatron": True, "remat_policy": "dots", "no_fsdp": True},
    "megatron+compress": {"megatron": True, "compress": True},
}


def terms(rec):
    return (rec["flops_per_device"] / PEAK_FLOPS,
            rec["traffic_per_device"] / HBM_BW,
            rec["collective_bytes_per_device"] / ICI_BW)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--pair", required=True)  # arch:shape
    ap.add_argument("--variants", default="baseline")
    ap.add_argument("--cut", type=int, default=None)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    arch, shape = args.pair.split(":")
    from repro.launch.dryrun import dryrun_one
    rows = []
    for v in args.variants.split(","):
        kw = VARIANTS[v]
        rec = dryrun_one(arch, shape, multi_pod=False, cut=args.cut,
                         verbose=False, **kw)
        tc, tm, tl = terms(rec)
        coll = {k: round(x/1e9, 1) for k, x in rec["collectives"].items()
                if not k.startswith("count_")}
        rows.append({"variant": v, "t_compute": tc, "t_memory": tm,
                     "t_collective": tl, "coll_GB": coll,
                     "flops_dev": rec["flops_per_device"],
                     "compile_s": rec["t_compile_s"]})
        print(f"{arch}:{shape} [{v:16s}] comp={tc:.3f}s mem={tm:.3f}s "
              f"coll={tl:.3f}s  {coll}", flush=True)
    if args.out:
        json.dump(rows, open(args.out, "w"), indent=1)


if __name__ == "__main__":
    main()
