"""SFL mathematical-faithfulness tests.

1. The explicit message-flow step (client fwd -> smashed up -> server
   fwd/bwd -> cut-gradient down -> client bwd, via jax.vjp) produces EXACTLY
   the gradients of the composite loss — the paper's Fig. 3 flow computes
   true gradients.
2. Sync-SFL (K=1) equivalence used by the compiled datacenter step
   (DESIGN.md §3): FedAvg of one-SGD-step-diverged client models equals one
   SGD step with the |D_n|-weighted mean gradient.
3. Eq. 2 delta-form FedAvg == plain weighted average.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import aggregation
from repro.core.fedsim import ResNetModel, SimConfig, make_sfl_batch_step
from repro.models import resnet as R
from repro import optim


def _data(key, n=8):
    kx, ky = jax.random.split(key)
    return {"images": jax.random.normal(kx, (n, 32, 32, 3)),
            "labels": jax.random.randint(ky, (n,), 0, 10)}


def test_message_flow_grads_equal_composite_grads():
    model = ResNetModel()
    key = jax.random.PRNGKey(0)
    units, head = model.init(key)
    batch = _data(jax.random.PRNGKey(1))
    cut = 4

    # --- explicit message flow (what fedsim does) ---
    def client_fwd(cu):
        return model.apply_units(cu, batch["images"], 0)

    smashed, vjp = jax.vjp(client_fwd, units[:cut])

    def server_loss(sv, sm):
        feats = model.apply_units(sv["units"], sm, cut)
        return model.head_loss(sv["head"], feats, batch["labels"])[0]

    loss_mf, grads = jax.value_and_grad(server_loss, argnums=(0, 1))(
        {"units": units[cut:], "head": head}, smashed)
    g_server, g_smashed = grads
    (g_client,) = vjp(g_smashed)

    # --- composite grad (one jax.grad over the whole model) ---
    def full_loss(tree):
        feats = model.apply_units(tree["units"], batch["images"], 0)
        return model.head_loss(tree["head"], feats, batch["labels"])[0]

    loss_full, g_full = jax.value_and_grad(full_loss)(
        {"units": units, "head": head})

    np.testing.assert_allclose(float(loss_mf), float(loss_full), rtol=1e-6)
    for i in range(cut):
        jax.tree.map(lambda a, b: np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-5),
            g_client[i], g_full["units"][i])
    for i in range(cut, R.N_UNITS):
        jax.tree.map(lambda a, b: np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-5),
            g_server["units"][i - cut], g_full["units"][i])


def test_sync_sfl_equivalence():
    """FedAvg of one-step-SGD-diverged replicas == one step with the weighted
    mean gradient (the compiled K=1 datacenter formulation)."""
    key = jax.random.PRNGKey(3)
    w0 = {"a": jax.random.normal(key, (4, 4)), "b": jnp.ones((4,))}
    grads = [jax.tree.map(lambda x: jax.random.normal(k, x.shape), w0)
             for k in jax.random.split(key, 3)]
    weights = [1.0, 2.0, 5.0]
    lr = 0.1

    # per-client step then weighted FedAvg
    replicas = [jax.tree.map(lambda w, g: w - lr * g, w0, g) for g in grads]
    fedavg_result = aggregation.fedavg(replicas, weights)

    # weighted mean gradient, single step
    wsum = sum(weights)
    gmean = jax.tree.map(
        lambda *gs: sum(weights[i] / wsum * gs[i] for i in range(3)), *grads)
    direct = jax.tree.map(lambda w, g: w - lr * g, w0, gmean)

    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a), np.asarray(b), rtol=1e-6), fedavg_result, direct)


def test_fedavg_delta_form_matches_eq2():
    key = jax.random.PRNGKey(5)
    g = {"w": jax.random.normal(key, (3, 3))}
    clients = [{"w": jax.random.normal(k, (3, 3))}
               for k in jax.random.split(key, 4)]
    lhs = aggregation.fedavg_delta(g, clients)          # Eq. 2
    rhs = aggregation.fedavg(clients)                   # plain average
    np.testing.assert_allclose(np.asarray(lhs["w"]), np.asarray(rhs["w"]),
                               rtol=1e-5, atol=1e-6)


def test_sfl_batch_step_runs_and_learns():
    model = ResNetModel()
    cfg = SimConfig(scheme="sfl", cut=2, lr=1e-3)
    step = make_sfl_batch_step(model, cfg, cut=2)
    key = jax.random.PRNGKey(0)
    units, head = model.init(key)
    opt = optim.adam(cfg.lr)
    c_opt = opt.init(units[:2])
    s_opt = opt.init({"units": units[2:], "head": head})
    batch = _data(jax.random.PRNGKey(7), n=16)
    cu, su, head_, c_opt, s_opt, l0, _ = step(units[:2], units[2:], head,
                                              c_opt, s_opt, batch)
    for _ in range(8):
        cu, su, head_, c_opt, s_opt, loss, _ = step(cu, su, head_, c_opt,
                                                    s_opt, batch)
    assert float(loss) < float(l0), "SFL step should overfit one batch"
