"""Per-client data pipeline for the federation simulator."""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.partition import label_skew_power_law
from repro.data.synthetic import make_cifar_like


def sample_batch_indices(n_items: int, batch_size: int, seed: int) -> np.ndarray:
    """The index stream behind :meth:`ClientDataset.sample_batch` — exposed so
    the cohort engine can pre-stage whole rounds of batches as one tensor."""
    rng = np.random.default_rng(seed)
    return rng.choice(n_items, size=batch_size, replace=n_items < batch_size)


def fleet_batch_indices(lengths, steps: int, batch_size: int,
                        seed: int) -> np.ndarray:
    """Whole-cohort batch staging in ONE rng call: (steps, n, batch) uniform
    draws modulo each client's true shard length.  This is the scenario
    engine's index stream — fleet membership changes between rounds only
    reshuffle which rows of :class:`StackedClients` these indices gather
    from, so no per-vehicle Python loop and no retrace.  (Always samples
    with replacement; the per-client streams of :func:`sample_batch_indices`
    are kept for seed-loop parity.)"""
    lengths = np.asarray(lengths, dtype=np.int64)
    u = np.random.default_rng(seed).random((steps, len(lengths), batch_size))
    return (u * lengths[None, :, None]).astype(np.int32)


def fleet_batch_indices_traced(key, lengths, steps: int,
                               batch_size: int):
    """jit-traceable twin of :func:`fleet_batch_indices` for the fused
    super-step path: one threefry draw per round, (steps, n, batch) uniform
    indices modulo each vehicle's true shard length, computed on-device so
    K rounds of batch staging never return to Python.  (Different rng bits
    than the numpy path — the fused engine derives ``key`` by folding the
    round index into one base key, so the K-fused and per-round dispatch
    paths of the same engine consume identical streams.)"""
    lengths = jnp.asarray(lengths, jnp.int32)
    u = jax.random.uniform(key, (steps, lengths.shape[0], batch_size))
    return jnp.minimum((u * lengths[None, :, None]).astype(jnp.int32),
                       lengths[None, :, None] - 1)


def epoch_batch_indices(n_items: int, batch_size: int, seed: int) -> np.ndarray:
    """Full-batch permutation epoch (drop remainder) as an index matrix
    (n_full, batch) — the staged form of :meth:`ClientDataset.batches`."""
    rng = np.random.default_rng(seed)
    order = rng.permutation(n_items)
    n_full = n_items // batch_size
    return order[:n_full * batch_size].reshape(n_full, batch_size)


@dataclasses.dataclass
class ClientDataset:
    images: np.ndarray   # (n, ...) features
    labels: np.ndarray   # (n,)
    client_id: int

    def __len__(self) -> int:
        return len(self.labels)

    def batches(self, batch_size: int, seed: int,
                drop_remainder: bool = True) -> Iterator[Dict[str, jnp.ndarray]]:
        # same permutation draw as epoch_batch_indices (the staged form used
        # by the cohort engine), so both consume identical epochs
        order = np.random.default_rng(seed).permutation(len(self.labels))
        n_full = len(order) // batch_size
        splits = np.split(order[:n_full * batch_size], n_full) if n_full else []
        if not drop_remainder and len(order) % batch_size:
            splits.append(order[n_full * batch_size:])
        for sel in splits:
            yield {"images": jnp.asarray(self.images[sel]),
                   "labels": jnp.asarray(self.labels[sel])}

    def sample_batch(self, batch_size: int, seed: int) -> Dict[str, jnp.ndarray]:
        sel = sample_batch_indices(len(self.labels), batch_size, seed)
        return {"images": jnp.asarray(self.images[sel]),
                "labels": jnp.asarray(self.labels[sel])}


@dataclasses.dataclass
class StackedClients:
    """All client shards padded to a common length and stacked on a leading
    client axis, resident on device once — the cohort engine gathers batches
    out of these tensors *inside* its scanned round, so no per-batch host
    staging or transfer happens.

    Padding rows are never indexed: batch index streams are drawn modulo each
    client's true ``lengths[i]``."""
    images: jnp.ndarray   # (n_clients, max_len, ...)
    labels: jnp.ndarray   # (n_clients, max_len, ...)
    lengths: np.ndarray   # (n_clients,) true shard sizes (host-side, static)


def stack_clients(clients) -> StackedClients:
    n = len(clients)
    lengths = np.array([len(c) for c in clients], dtype=np.int64)
    max_len = int(lengths.max())
    img_shape = clients[0].images.shape[1:]
    lab_shape = clients[0].labels.shape[1:]
    images = np.zeros((n, max_len) + img_shape, dtype=clients[0].images.dtype)
    labels = np.zeros((n, max_len) + lab_shape, dtype=clients[0].labels.dtype)
    for i, c in enumerate(clients):
        images[i, :lengths[i]] = c.images
        labels[i, :lengths[i]] = c.labels
    return StackedClients(jnp.asarray(images), jnp.asarray(labels), lengths)


class DoubleBuffer:
    """Double-buffered host→device staging (DESIGN.md §14): one slot of
    prestaged arrays, keyed by what they stage.

    The scenario engine dispatches a fused super-step (an *async* jax call),
    then immediately :meth:`stage`\\ s the next window's batch/mobility
    arrays — host numpy staging and the device transfer overlap the
    in-flight window's compute, so a continuously arriving vehicle's shard
    is already resident when its first round forms.  :meth:`take` returns
    the prestaged value when the key matches and falls back to building
    synchronously when it does not (direct ``run_superstep`` calls, the
    first window of a run) — staging is an overlap optimization, never a
    semantic: ``build`` is pure, so both paths produce identical arrays.
    """

    def __init__(self):
        self._key = None
        self._val = None

    def stage(self, key, build) -> None:
        """Build and hold the value for ``key`` (device transfers start
        asynchronously; nothing blocks on them here)."""
        self._key, self._val = key, build()

    def take(self, key, build):
        """The prestaged value for ``key``, or ``build()`` on a miss.  The
        slot empties either way — each staged window is consumed once."""
        val = self._val if self._key == key else None
        self._key = self._val = None
        return val if val is not None else build()


def make_federated_data(seed: int, n_train: int = 4096, n_test: int = 1024,
                        n_clients: int = 4, iid: bool = False,
                        labels_per_client: int = 6):
    """The paper's case-study data: CIFAR-like, 4 vehicles, 6-of-10 labels,
    power-law sizes (non-IID) or uniform (IID)."""
    key = jax.random.PRNGKey(seed)
    k_train, k_test = jax.random.split(key)
    x, y = make_cifar_like(k_train, n_train)
    xt, yt = make_cifar_like(k_test, n_test)
    x, y = np.asarray(x), np.asarray(y)
    if iid:
        rng = np.random.default_rng(seed)
        order = rng.permutation(n_train)
        parts = np.array_split(order, n_clients)
    else:
        parts = label_skew_power_law(seed, y, n_clients,
                                     labels_per_client=labels_per_client)
    clients = [ClientDataset(x[p], y[p], i) for i, p in enumerate(parts)]
    test = {"images": jnp.asarray(np.asarray(xt)), "labels": jnp.asarray(np.asarray(yt))}
    return clients, test
