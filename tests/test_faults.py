"""Fault plane (ISSUE 8, DESIGN.md §13): zero-fault byte-identity, seeded
chaos schedules, survivor-weighted partial aggregation, the staleness bank,
and fault-aware telemetry — across both engines, both server schedules, and
both super-step layouts.

The CI ``chaos`` job re-runs this file plus the superstep/engine-parity
suites; the zero-fault invariants here are the PR's hard contract: a
default :class:`~repro.core.faults.FaultConfig` must compile the exact
program a pre-fault build compiled.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.core import faults
from repro.core.fedsim import FederationSim, ScenarioEngine, SimConfig

from test_scenario import TinyMLP, _two_cell_trace, _vector_clients

ROUNDS, INTERVAL = 4, 5.0
# the canonical seeded chaos schedule: ~20% dropout plus upload loss and an
# always-firing deadline (latencies are ~ms against multi-second residence,
# so a 1e-7 factor marks one vehicle per round as a straggler)
CHAOS = dict(fault_dropout=0.2, fault_upload_loss=0.1, fault_straggler=1e-7)


def _cfg(**kw):
    base = dict(scheme="asfl", adaptive_strategy="paper", rounds=ROUNDS,
                local_steps=2, batch_size=8, lr=1e-2, optimizer="sgd",
                round_interval_s=INTERVAL, eval_every=0, superstep=1)
    base.update(kw)
    return SimConfig(**base)


def _engine(cfg, sync=2):
    sc = _two_cell_trace(ROUNDS, INTERVAL)
    clients, test = _vector_clients(2)
    return ScenarioEngine(TinyMLP(), clients, test, cfg, sc,
                          cloud_sync_every=sync)


def _params(eng):
    return jax.tree.map(np.asarray, {"units": eng.units, "head": eng.head})


# ------------------------------------------------------------ FaultConfig
def test_fault_config_validation():
    for bad in ({"dropout_rate": 1.0}, {"upload_loss_rate": -0.1},
                {"rsu_outage_rate": 2.0}, {"staleness_discount": 1.5},
                {"straggler_factor": -1.0}):
        with pytest.raises(ValueError):
            faults.FaultConfig(**bad)


def test_fault_config_flags():
    assert not faults.FaultConfig().stochastic
    assert not faults.FaultConfig().enabled
    assert faults.FaultConfig(coverage=True).enabled
    assert not faults.FaultConfig(coverage=True).stochastic
    for kw in ({"dropout_rate": 0.1}, {"upload_loss_rate": 0.1},
               {"straggler_factor": 1.0}, {"rsu_outage_rate": 0.1}):
        assert faults.FaultConfig(**kw).stochastic


def test_sim_config_alias_and_conflict():
    """mobility_dropout is the legacy spelling of fault_coverage — the
    compress_smashed -> wire="int8" shim pattern."""
    assert SimConfig(mobility_dropout=True).fault_config().coverage
    assert SimConfig(fault_coverage=True).fault_config().coverage
    assert not SimConfig().fault_config().coverage
    with pytest.raises(ValueError, match="legacy spelling"):
        SimConfig(mobility_dropout=True, fault_coverage=True)
    with pytest.raises(ValueError, match="dropout_rate"):
        SimConfig(fault_dropout=1.0)


def test_drop_steps_bounds():
    drop = np.array([True, True, False])
    frac = np.array([0.0, 0.99, 0.5], np.float32)
    out = np.asarray(faults.drop_steps(drop, frac, 4))
    assert out.tolist() == [0, 3, 4]          # dropped strictly < steps


def test_ensure_rsu_up_keeps_one():
    down = np.array([True, True, True])
    kept = np.asarray(faults.ensure_rsu_up(down))
    assert kept.tolist() == [False, True, True]
    some = np.array([True, False, True])
    assert np.asarray(faults.ensure_rsu_up(some)).tolist() == some.tolist()


# ----------------------------------------------------- zero-fault identity
def test_zero_fault_carry_has_no_fault_planes():
    eng = _engine(_cfg())
    assert not eng.programs.fz
    assert "stale_num" not in eng._carry
    assert "stale_den" not in eng._carry


def test_zero_fault_never_samples(monkeypatch):
    """The Python-level gate: a default FaultConfig must never reach the
    fault sampler, so the traced program cannot contain fault ops."""
    def boom(*a, **kw):                      # pragma: no cover
        raise AssertionError("fault sampler invoked on zero-fault config")
    monkeypatch.setattr(faults, "sample_faults_traced", boom)
    eng = _engine(_cfg(superstep=ROUNDS))
    hist = eng.run()
    assert len(hist) == ROUNDS
    assert all(np.isfinite(m.loss) for m in hist)


@pytest.mark.parametrize("schedule", ["sequential", "parallel"])
def test_zero_fault_lowering_byte_identical_across_fault_seed(schedule):
    """Byte-identity, provable in-repo: with zero fault rates, nothing of
    the fault group may leak into the lowered program — two configs that
    differ only in fault_seed lower to the identical text."""
    txts = []
    for seed in (0, 99):
        eng = _engine(_cfg(server_schedule=schedule, superstep=ROUNDS,
                           fault_seed=seed))
        cap = eng._capacity(ROUNDS)
        sig = eng.programs.signature(ROUNDS, cap, eng._total_slots(ROUNDS))
        fn = eng.programs.get(sig)
        txts.append(fn.lower(eng._carry,
                             eng._window_xs(0, ROUNDS)).as_text())
    assert txts[0] == txts[1]


# --------------------------------------------------- chaos: fused engines
@pytest.mark.parametrize("schedule", ["sequential", "parallel"])
def test_fused_matches_per_round_under_faults(schedule):
    """K fused rounds == K per-round dispatches stays bit-for-bit under the
    seeded chaos schedule (sgd): the fault stream is round-indexed
    (fold_in(key, rnd)), so the window size cannot change the draws."""
    cfg1 = _cfg(server_schedule=schedule, **CHAOS)
    cfgK = dataclasses.replace(cfg1, superstep=ROUNDS)
    e1, eK = _engine(cfg1), _engine(cfgK)
    h1, hK = e1.run(), eK.run()
    jax.tree.map(np.testing.assert_array_equal, _params(e1), _params(eK))
    np.testing.assert_array_equal([m.loss for m in h1],
                                  [m.loss for m in hK])
    assert [m.n_dropout for m in h1] == [m.n_dropout for m in hK]
    assert [m.n_upload_lost for m in h1] == [m.n_upload_lost for m in hK]
    assert [m.n_straggler for m in h1] == [m.n_straggler for m in hK]
    # the schedule actually injected failures
    assert sum(m.n_dropout + m.n_upload_lost + m.n_straggler
               for m in h1) > 0
    assert any(m.survivor_frac < 1.0 for m in h1)


@pytest.mark.parametrize("schedule", ["sequential", "parallel"])
def test_layouts_agree_under_faults(schedule):
    """ragged == dense stays bit-for-bit with survivor-weighted merges and
    the staleness bank in play (sgd)."""
    engs = [_engine(_cfg(server_schedule=schedule, superstep=ROUNDS,
                         superstep_layout=lay, **CHAOS))
            for lay in ("ragged", "dense")]
    hists = [e.run() for e in engs]
    jax.tree.map(np.testing.assert_array_equal,
                 _params(engs[0]), _params(engs[1]))
    np.testing.assert_array_equal([m.loss for m in hists[0]],
                                  [m.loss for m in hists[1]])


@pytest.mark.parametrize("schedule", ["sequential", "parallel"])
@pytest.mark.skipif(jax.device_count() < 8,
                    reason="needs XLA_FLAGS=--xla_force_host_platform_"
                           "device_count=8")
def test_mesh_agrees_under_faults(schedule):
    """FleetMesh(8) == single device, bit-for-bit, under the chaos
    schedule (the staleness bank shards/replicates with the edge stack)."""
    ref = _engine(_cfg(server_schedule=schedule, superstep=ROUNDS, **CHAOS))
    msh = _engine(_cfg(server_schedule=schedule, superstep=ROUNDS,
                       mesh_devices=8, **CHAOS))
    hr, hm = ref.run(), msh.run()
    jax.tree.map(np.testing.assert_array_equal, _params(ref), _params(msh))
    np.testing.assert_array_equal([m.loss for m in hr],
                                  [m.loss for m in hm])


@pytest.mark.parametrize("schedule", ["sequential", "parallel"])
def test_fault_churn_precompiled_zero_fallbacks(schedule):
    """Fault churn is retrace-free: after precompile(), a chaos run builds
    and XLA-compiles nothing (fault masks are data, the bank is carry)."""
    eng = _engine(_cfg(server_schedule=schedule, superstep=2,
                       fault_rsu_outage=0.2, **CHAOS))
    eng.precompile()
    events = []
    jax.monitoring.register_event_duration_secs_listener(
        lambda name, *a, **kw: events.append(name))
    baseline = len([e for e in events if "compile" in e])
    hist = eng.run()
    assert eng.programs.compile_fallbacks == 0
    assert not [e for e in events[baseline:] if "compile" in e]
    assert len(hist) == ROUNDS
    assert all(np.isfinite(m.loss) for m in hist)


def test_staleness_bank_banks_and_merges():
    """A straggler's update is banked, not lost: the round after a
    straggler capture merges its discounted weight (stale_merged
    telemetry), and the bank drains every round."""
    eng = _engine(_cfg(fault_straggler=1e-7))
    hist = eng.run()
    strag = [m.n_straggler for m in hist]
    stale = [m.stale_merged for m in hist]
    assert sum(strag) > 0
    assert stale[0] == 0.0                    # nothing banked before round 0
    for prev, merged in zip(strag, stale[1:]):
        # bank drains in one round: weight merges iff something was banked
        assert (merged > 0.0) == (prev > 0)
    # the bank never double-merges: the carry holds only the LAST round's
    # captures
    den = np.asarray(eng._carry["stale_den"])
    assert den.sum() > 0.0 if strag[-1] else den.sum() == 0.0


def test_rsu_outage_sits_cohort_out():
    """An RSU outage forces its cohort to SKIP: scheduled counts drop on
    outage rounds, rsu_loads show the dark cell, and training still
    completes (ensure_rsu_up keeps the network alive)."""
    eng = _engine(_cfg(fault_rsu_outage=0.4, superstep=ROUNDS))
    hist = eng.run()
    assert any(m.n_rsu_down > 0 for m in hist)
    for m in hist:
        # a down cell contributes no scheduled vehicles
        assert sum(m.rsu_loads) == m.n_scheduled
    assert all(np.isfinite(m.loss) for m in hist)


def test_fault_telemetry_consistent():
    """Precedence accounting: dropout/upload-loss/straggler are disjoint
    and bounded by the scheduled count; survivor_frac matches them."""
    eng = _engine(_cfg(fault_rsu_outage=0.2, **CHAOS))
    hist = eng.run()
    for m in hist:
        failed = m.n_dropout + m.n_upload_lost + m.n_straggler
        assert failed <= m.n_scheduled
        if m.n_scheduled:
            expect = (m.n_scheduled - failed) / m.n_scheduled
            assert abs(m.survivor_frac - expect) < 1e-6
        assert m.lost_update_bytes >= 0.0
        # stragglers are banked, not lost: only drop/lost updates die
        if m.n_dropout + m.n_upload_lost == 0:
            assert m.lost_update_bytes == 0.0


def test_fault_schedule_is_seeded():
    """Same fault_seed -> identical failure schedule; different seed ->
    (this trace) a different one.  The stream is dedicated: it cannot
    collide with the batch-index or fading streams."""
    h1 = _engine(_cfg(**CHAOS)).run()
    h2 = _engine(_cfg(**CHAOS)).run()
    assert [m.n_dropout for m in h1] == [m.n_dropout for m in h2]
    assert [m.n_upload_lost for m in h1] == [m.n_upload_lost for m in h2]
    h3 = _engine(_cfg(fault_seed=123, **CHAOS)).run()
    assert ([m.n_dropout for m in h1] != [m.n_dropout for m in h3]
            or [m.n_upload_lost for m in h1]
            != [m.n_upload_lost for m in h3])


# ------------------------------------------------- host engine (single RSU)
def test_federation_fault_run_completes_with_telemetry():
    clients, test = _vector_clients(4)
    cfg = _cfg(fault_dropout=0.4, fault_upload_loss=0.2, superstep=1,
               rounds=3, adaptive_strategy="paper")
    sim = FederationSim(TinyMLP(), clients, test, cfg)
    hist = sim.run()
    assert all(np.isfinite(m.loss) for m in hist)
    assert sum(m.n_dropout + m.n_upload_lost for m in hist) > 0
    for m in hist:
        assert 0.0 < m.survivor_frac <= 1.0   # rescue keeps >= 1 survivor
        if m.n_dropout + m.n_upload_lost == 0:
            assert m.survivor_frac == 1.0
            assert m.lost_update_bytes == 0.0
        else:
            assert m.lost_update_bytes > 0.0
    # seeded host stream: the schedule reproduces
    sim2 = FederationSim(TinyMLP(), clients, test, cfg)
    h2 = sim2.run()
    assert [m.n_dropout for m in hist] == [m.n_dropout for m in h2]
    np.testing.assert_array_equal([m.loss for m in hist],
                                  [m.loss for m in h2])


def test_federation_rejects_scenario_faults():
    clients, test = _vector_clients(2)
    for kw in ({"fault_straggler": 1.0}, {"fault_rsu_outage": 0.1}):
        with pytest.raises(ValueError, match="multi-RSU"):
            FederationSim(TinyMLP(), clients, test, _cfg(**kw))
    with pytest.raises(ValueError, match="sfl | asfl"):
        FederationSim(TinyMLP(), clients, test,
                      _cfg(scheme="fl", fault_dropout=0.2))


def test_scenario_rejects_coverage_fault():
    with pytest.raises(ValueError, match="coverage"):
        _engine(_cfg(fault_coverage=True))
